"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes, bit-vectors and block sizes; assert_allclose
against the reference.  This is the CORE correctness signal for everything
the rust coordinator executes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binarize, fake_quant, qmatmul, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype("float32"))


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------


@given(
    c=st.integers(1, 70),
    k=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_matches_ref(c, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(c, k)).astype("float32"))
    bits = jnp.asarray(rng.integers(0, 33, size=(c,)).astype("float32"))
    np.testing.assert_allclose(
        np.asarray(fake_quant(x, bits)),
        np.asarray(ref.fake_quant_ref(x, bits)),
        rtol=0,
        atol=0,
    )


@pytest.mark.parametrize("block_c", [1, 4, 16, 64])
def test_fake_quant_block_size_invariant(block_c):
    x = rand((37, 23), seed=3)
    bits = jnp.asarray(np.arange(37, dtype="float32") % 9)
    out = fake_quant(x, bits, block_c=block_c)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.fake_quant_ref(x, bits)))


def test_fake_quant_zero_bits_prunes():
    x = rand((4, 8), seed=1)
    out = fake_quant(x, jnp.zeros(4))
    assert np.all(np.asarray(out) == 0.0)


def test_fake_quant_32_bits_passthrough():
    x = rand((4, 8), seed=2)
    out = fake_quant(x, jnp.full((4,), 32.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_fake_quant_levels():
    # 2-bit symmetric quantizer: 2^(2-1)-1 = 1 level each side → values in
    # {-s, 0, +s} where s = max|row|.
    x = jnp.asarray([[0.9, -0.4, 0.1, -0.95]], dtype=jnp.float32)
    out = np.asarray(fake_quant(x, jnp.full((1,), 2.0)))[0]
    s = 0.95
    for v in out:
        assert min(abs(v - t) for t in (-s, 0.0, s)) < 1e-6


def test_fake_quant_monotone_error_in_bits():
    # Quantization error must not increase with more bits (per channel).
    x = rand((1, 256), seed=5)
    errs = []
    for b in [2, 3, 4, 6, 8]:
        q = fake_quant(x, jnp.full((1,), float(b)))
        errs.append(float(jnp.mean(jnp.abs(q - x))))
    assert all(a >= b - 1e-9 for a, b in zip(errs, errs[1:])), errs


# ---------------------------------------------------------------------------
# binarize
# ---------------------------------------------------------------------------


@given(
    c=st.integers(1, 40),
    k=st.integers(1, 90),
    seed=st.integers(0, 2**31 - 1),
)
def test_binarize_matches_ref(c, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(c, k)).astype("float32"))
    bits = jnp.asarray(rng.integers(0, ref.MAX_BBN + 1, size=(c,)).astype("float32"))
    np.testing.assert_allclose(
        np.asarray(binarize(x, bits)),
        np.asarray(ref.binarize_ref(x, bits)),
        rtol=1e-6,
        atol=1e-6,
    )


def test_binarize_one_bit_is_sign_times_mean():
    x = rand((2, 64), seed=7)
    out = np.asarray(binarize(x, jnp.ones(2)))
    xn = np.asarray(x)
    for c in range(2):
        alpha = np.mean(np.abs(xn[c]))
        expect = np.where(xn[c] >= 0, alpha, -alpha)
        np.testing.assert_allclose(out[c], expect, rtol=1e-6)


def test_binarize_residual_error_decreases():
    x = rand((1, 512), seed=9)
    errs = []
    for b in range(1, ref.MAX_BBN + 1):
        out = binarize(x, jnp.full((1,), float(b)))
        errs.append(float(jnp.mean((out - x) ** 2)))
    assert all(a > b for a, b in zip(errs, errs[1:])), errs


def test_binarize_zero_bits_prunes():
    x = rand((3, 16), seed=11)
    assert np.all(np.asarray(binarize(x, jnp.zeros(3))) == 0.0)


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype("float32"))
    b = jnp.asarray(rng.normal(size=(k, n)).astype("float32"))
    np.testing.assert_allclose(
        np.asarray(qmatmul(a, b)),
        np.asarray(ref.qmatmul_ref(a, b)),
        rtol=1e-5,
        atol=1e-5,
    )


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 16, 64), (128, 128, 128)])
def test_qmatmul_tile_size_invariant(bm, bn, bk):
    a = rand((50, 33), seed=13)
    b = rand((33, 41), seed=14)
    out = qmatmul(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.qmatmul_ref(a, b)), rtol=1e-5, atol=1e-5
    )


def test_qmatmul_identity():
    a = rand((17, 17), seed=15)
    eye = jnp.eye(17, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(qmatmul(a, eye)), np.asarray(a), rtol=1e-6)
