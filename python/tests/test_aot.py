"""AOT contract tests: the manifest written by compile.aot matches what the
rust runtime expects, and lowered HLO text is parseable/stable."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import agent as A
from compile import model as M
from compile.aot import to_hlo_text

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_roundtrip_small():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
class TestManifest:
    @pytest.fixture(autouse=True)
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            self.man = json.load(f)

    def test_all_artifact_files_exist(self):
        for name, spec in self.man["artifacts"].items():
            path = os.path.join(ART, spec["file"])
            assert os.path.exists(path), f"{name}: {path} missing"
            assert os.path.getsize(path) > 100

    def test_model_families_complete(self):
        for m in M.MODEL_NAMES:
            for fam in ("eval_quant", "eval_binar", "train_quant", "train_binar"):
                assert f"{m}_{fam}" in self.man["artifacts"]

    def test_eval_input_arity(self):
        for m in M.MODEL_NAMES:
            meta = self.man["models"][m]
            spec = self.man["artifacts"][f"{m}_eval_quant"]
            assert len(spec["inputs"]) == len(meta["params"]) + 4
            # Last two inputs are the bit vectors.
            assert spec["inputs"][-2]["shape"] == [meta["w_channels"]]
            assert spec["inputs"][-1]["shape"] == [meta["a_channels"]]
            # Outputs: (correct, loss) scalars.
            assert [o["shape"] for o in spec["outputs"]] == [[], []]

    def test_train_io_symmetry(self):
        for m in M.MODEL_NAMES:
            meta = self.man["models"][m]
            spec = self.man["artifacts"][f"{m}_train_quant"]
            np_ = len(meta["params"])
            assert len(spec["inputs"]) == 2 * np_ + 5
            assert len(spec["outputs"]) == 2 * np_ + 1
            # Param shapes echo manifest order in both directions.
            for i, p in enumerate(meta["params"]):
                assert spec["inputs"][i]["shape"] == p["shape"]
                assert spec["outputs"][i]["shape"] == p["shape"]

    def test_agent_artifacts(self):
        for s in (16, 17):
            act = self.man["artifacts"][f"ddpg_act_s{s}"]
            assert act["inputs"][-1]["shape"] == [A.ACT_BATCH, s]
            assert act["outputs"][0]["shape"] == [A.ACT_BATCH, 1]
            upd = self.man["artifacts"][f"ddpg_update_s{s}"]
            assert len(upd["inputs"]) == 58
            assert len(upd["outputs"]) == 51

    def test_model_meta_matches_live_builder(self):
        """The shipped manifest must agree with model.py's current output —
        guards against stale artifacts after editing the zoo."""
        for m in M.MODEL_NAMES:
            live = M.model_meta(m)
            baked = self.man["models"][m]
            assert baked["w_channels"] == live["w_channels"]
            assert baked["a_channels"] == live["a_channels"]
            assert baked["total_macs"] == live["total_macs"]
            assert len(baked["layers"]) == len(live["layers"])
