"""L2 agent graphs: actor bounds, critic shapes, and one-step learning
behaviour of the fused DDPG update."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import agent as A


def init_params(shapes, seed=0, out_small=True):
    rng = np.random.default_rng(seed)
    out = []
    n = len(shapes)
    for i, shp in enumerate(shapes):
        if len(shp) == 2:
            bound = 3e-3 if (out_small and i >= n - 2) else 1.0 / np.sqrt(shp[0])
            out.append(jnp.asarray(rng.uniform(-bound, bound, shp).astype("float32")))
        else:
            out.append(jnp.zeros(shp, "float32"))
    return out


@pytest.mark.parametrize("s_dim", [16, 17])
def test_actor_output_bounded(s_dim):
    actor = init_params(A.actor_shapes(s_dim), seed=1)
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.normal(size=(A.ACT_BATCH, s_dim)).astype("float32") * 3)
    a = A.actor_forward(actor, s)
    assert a.shape == (A.ACT_BATCH, 1)
    assert float(jnp.min(a)) >= 0.0
    assert float(jnp.max(a)) <= 32.0


def test_zero_actor_emits_midpoint():
    actor = [jnp.zeros(s, "float32") for s in A.actor_shapes(16)]
    s = jnp.ones((A.ACT_BATCH, 16), "float32")
    a = A.actor_forward(actor, s)
    np.testing.assert_allclose(np.asarray(a), 16.0, rtol=1e-6)


def test_critic_shapes():
    critic = init_params(A.critic_shapes(16), seed=2, out_small=False)
    s = jnp.zeros((8, 16), "float32")
    a = jnp.zeros((8, 1), "float32")
    q = A.critic_forward(critic, s, a)
    assert q.shape == (8, 1)


def _update_args(s_dim, seed=0, reward=1.0):
    rng = np.random.default_rng(seed)
    a6 = init_params(A.actor_shapes(s_dim), seed=seed)
    c6 = init_params(A.critic_shapes(s_dim), seed=seed + 1, out_small=False)
    args = list(a6) + list(c6) + list(a6) + list(c6)
    zeros_like = lambda ps: [jnp.zeros_like(p) for p in ps]
    args += zeros_like(a6) + zeros_like(a6) + zeros_like(c6) + zeros_like(c6)
    args += [jnp.asarray(0.0, jnp.float32)]  # t
    B = A.UPD_BATCH
    s = jnp.asarray(rng.normal(size=(B, s_dim)).astype("float32"))
    act = jnp.asarray(rng.uniform(0, 32, size=(B, 1)).astype("float32"))
    r = jnp.full((B, 1), reward, dtype=jnp.float32)
    s2 = jnp.asarray(rng.normal(size=(B, s_dim)).astype("float32"))
    done = jnp.ones((B, 1), dtype=jnp.float32)
    args += [s, act, r, s2, done]
    args += [jnp.asarray(x, jnp.float32) for x in (0.99, 0.01, 1e-4, 1e-3)]
    return args


def test_update_output_arity():
    f = A.update_fn(16)
    outs = f(*_update_args(16))
    assert len(outs) == 51
    assert float(outs[48]) == 1.0  # t incremented


def test_update_reduces_critic_loss_on_fixed_batch():
    """Repeated updates on the same batch must fit the critic target."""
    f = jax.jit(A.update_fn(16))
    args = _update_args(16, seed=5, reward=0.7)
    losses = []
    for _ in range(30):
        outs = f(*args)
        # Thread all net/adam state back in; keep the batch fixed.
        args = list(outs[:48]) + [outs[48]] + args[49:]
        losses.append(float(outs[49]))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])


def test_soft_target_update_moves_slowly():
    f = A.update_fn(16)
    args = _update_args(16, seed=6)
    t_actor_before = args[12:18]
    outs = f(*args)
    t_actor_after = outs[12:18]
    # τ=0.01: target weights move by at most ~1% of the online-target gap.
    for b, a in zip(t_actor_before, t_actor_after):
        assert float(jnp.max(jnp.abs(a - b))) < 0.05


def test_agent_meta_contract():
    m = A.agent_meta(17)
    assert m["s_dim"] == 17
    assert m["actor_shapes"][0] == [17, 300]
    assert m["critic_shapes"][0] == [18, 300]
    assert m["action_scale"] == 32.0
