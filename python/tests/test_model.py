"""L2 correctness: model zoo metadata/compute consistency and quantization
semantics at the model level."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def init_params(meta, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for p in meta["params"]:
        shp = p["shape"]
        if p["init"] == "he":
            fan_in = int(np.prod(shp[:-1])) if len(shp) > 1 else shp[0]
            out[p["name"]] = jnp.asarray(
                rng.normal(0, np.sqrt(2.0 / max(fan_in, 1)), shp).astype("float32")
            )
        elif p["init"] == "ones":
            out[p["name"]] = jnp.ones(shp, "float32")
        else:
            out[p["name"]] = jnp.zeros(shp, "float32")
    return out


@pytest.fixture(scope="module")
def small_batch():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(4, 32, 32, 3)).astype("float32"))


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_meta_channel_slices_tile(name):
    meta = M.model_meta(name)
    w_total = sum(l["w_len"] for l in meta["layers"])
    a_total = sum(l["a_len"] for l in meta["layers"])
    assert w_total == meta["w_channels"]
    assert a_total == meta["a_channels"]
    # Slices are contiguous and ordered.
    off = 0
    for l in meta["layers"]:
        assert l["w_off"] == off
        off += l["w_len"]


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_meta_macs_positive_and_fc_single_act(name):
    meta = M.model_meta(name)
    for l in meta["layers"]:
        assert l["macs"] > 0
        if l["type"] == "fc":
            assert l["a_len"] == 1  # paper §3.2
        else:
            assert l["a_len"] == l["cin"]
        assert l["w_len"] == l["cout"]


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_forward_shapes(name, small_batch):
    meta = M.model_meta(name)
    params = init_params(meta)
    wb = jnp.full((meta["w_channels"],), 8.0)
    ab = jnp.full((meta["a_channels"],), 8.0)
    logits = M.forward(name, params, small_batch, wb, ab, "quant", use_pallas=False)
    assert logits.shape == (4, M.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ["cif10", "sqnet"])
def test_pallas_path_matches_ref_path_quant(name, small_batch):
    """Quant mode is bit-exact between the Pallas and reference paths."""
    meta = M.model_meta(name)
    params = init_params(meta)
    wb = jnp.full((meta["w_channels"],), 5.0)
    ab = jnp.full((meta["a_channels"],), 5.0)
    lp = M.forward(name, params, small_batch, wb, ab, "quant", use_pallas=True)
    lr = M.forward(name, params, small_batch, wb, ab, "quant", use_pallas=False)
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(lr))


def test_pallas_path_matches_ref_path_binar(small_batch):
    """Binar mode: sign() boundaries amplify fp accumulation-order noise, so
    the two paths agree statistically (see DESIGN.md), not bit-exactly."""
    meta = M.model_meta("cif10")
    params = init_params(meta)
    wb = jnp.full((meta["w_channels"],), 4.0)
    ab = jnp.full((meta["a_channels"],), 4.0)
    lp = M.forward("cif10", params, small_batch, wb, ab, "binar", use_pallas=True)
    lr = M.forward("cif10", params, small_batch, wb, ab, "binar", use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-2, atol=1e-2)


def test_bits32_equals_unquantized(small_batch):
    """32-bit config must match the raw float forward exactly (passthrough)."""
    meta = M.model_meta("cif10")
    params = init_params(meta)
    wb32 = jnp.full((meta["w_channels"],), 32.0)
    ab32 = jnp.full((meta["a_channels"],), 32.0)
    l32 = M.forward("cif10", params, small_batch, wb32, ab32, "quant", use_pallas=False)
    assert l32.shape == (4, 10)
    # Degrading one layer to 1 bit must change the logits.
    wb_low = wb32.at[:16].set(1.0)
    l_low = M.forward("cif10", params, small_batch, wb_low, ab32, "quant", use_pallas=False)
    assert float(jnp.max(jnp.abs(l32 - l_low))) > 1e-4


def test_pruned_first_layer_kills_signal(small_batch):
    meta = M.model_meta("cif10")
    params = init_params(meta)
    wb = jnp.full((meta["w_channels"],), 32.0).at[:16].set(0.0)  # prune layer 1
    ab = jnp.full((meta["a_channels"],), 32.0)
    logits = M.forward("cif10", params, small_batch, wb, ab, "quant", use_pallas=False)
    # All images produce identical logits (no input-dependent signal).
    diffs = jnp.max(jnp.abs(logits - logits[0:1]))
    assert float(diffs) < 1e-5


def test_eval_fn_counts_correct(small_batch):
    meta = M.model_meta("cif10")
    f, _ = M.eval_fn("cif10", "quant", use_pallas=False)
    params = init_params(meta)
    plist = [params[p["name"]] for p in meta["params"]]
    # Use the real eval batch size for the exported signature.
    rng = np.random.default_rng(1)
    images = jnp.asarray(rng.normal(size=(M.EVAL_BATCH, 32, 32, 3)).astype("float32"))
    labels = jnp.asarray(rng.integers(0, 10, size=(M.EVAL_BATCH,)).astype("int32"))
    wb = jnp.full((meta["w_channels"],), 32.0)
    ab = jnp.full((meta["a_channels"],), 32.0)
    correct, loss = f(*plist, images, labels, wb, ab)
    assert 0.0 <= float(correct) <= M.EVAL_BATCH
    assert float(loss) > 0.0


def test_train_fn_reduces_loss():
    """A few STE train steps on a fixed batch must reduce the loss."""
    name = "cif10"
    meta = M.model_meta(name)
    f, _ = M.train_fn(name, "quant")
    params = init_params(meta, seed=3)
    plist = [params[p["name"]] for p in meta["params"]]
    mlist = [jnp.zeros_like(p) for p in plist]
    rng = np.random.default_rng(2)
    images = jnp.asarray(rng.normal(size=(M.TRAIN_BATCH, 32, 32, 3)).astype("float32"))
    labels = jnp.asarray((np.arange(M.TRAIN_BATCH) % 10).astype("int32"))
    wb = jnp.full((meta["w_channels"],), 32.0)
    ab = jnp.full((meta["a_channels"],), 32.0)
    lr = jnp.asarray(0.05, dtype=jnp.float32)
    jf = jax.jit(f)
    np_ = len(plist)
    losses = []
    for _ in range(6):
        outs = jf(*plist, *mlist, images, labels, wb, ab, lr)
        plist = list(outs[:np_])
        mlist = list(outs[np_:2 * np_])
        losses.append(float(outs[-1]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_example_args_match_manifest_contract(name):
    meta = M.model_meta(name)
    ev = M.example_args(meta, "eval")
    assert len(ev) == len(meta["params"]) + 4
    tr = M.example_args(meta, "train")
    assert len(tr) == 2 * len(meta["params"]) + 5
