"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the Pallas kernels (and therefore the AOT'd HLO
artifacts executed from rust) are validated against in
``python/tests/test_kernel.py``.

Semantics (paper §3.1):
  * ``fake_quant_ref``   — linear (uniform, symmetric max-abs) per-channel
    quantize-dequantize [Zhou et al. 38]. ``bits == 0`` prunes the channel.
  * ``binarize_ref``     — multi-bit residual binarization [Lin et al. 17]
    (ABC-Net style): ``W ≈ Σ_k α_k · sign(r_k)`` with the residual update
    ``r_{k+1} = r_k − α_k · sign(r_k)``, per channel, ``bits`` levels.
  * ``qmatmul_ref``      — plain matmul over already-quantized operands (the
    arithmetic the FPGA accelerators implement bit-serially; numerically it
    is an exact f32 matmul of the dequantized values).
"""

from __future__ import annotations

import jax.numpy as jnp

# Residual-binarization levels are unrolled to this cap in the kernels.  The
# paper's searched BBNs average 3-5 bits; 8 covers the searched space while
# keeping the unrolled HLO small.  Documented in DESIGN.md.
MAX_BBN = 8


def _per_channel_scale(x2d: jnp.ndarray, levels: jnp.ndarray) -> jnp.ndarray:
    """Symmetric max-abs scale per row (channel) of a (C, K) matrix."""
    max_abs = jnp.max(jnp.abs(x2d), axis=1, keepdims=True)
    # Avoid 0/0 for all-zero channels or pruned channels.
    safe_levels = jnp.maximum(levels, 1.0)
    return jnp.where(max_abs > 0.0, max_abs / safe_levels, 1.0)


def fake_quant_ref(x2d: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Per-channel linear quantize-dequantize.

    Args:
      x2d:  (C, K) float32 — channel-major view of a weight/activation tensor.
      bits: (C,)   float32 — QBN per channel; fractional values are rounded.
            0 ⇒ channel pruned (output 0).  ≥ 24 ⇒ passthrough (beyond f32
            mantissa, quantization is an exact identity; also keeps
            ``exp2`` finite).

    Returns (C, K) float32 dequantized values.
    """
    b = jnp.round(bits).astype(jnp.float32)[:, None]  # (C, 1)
    pruned = b <= 0.0
    passthrough = b >= 24.0
    # Signed symmetric quantizer: 2^(b-1) - 1 positive levels.
    levels = jnp.exp2(jnp.clip(b, 1.0, 24.0) - 1.0) - 1.0
    # b == 1 gives levels == 0 → degenerate; use binary {-s, +s} with s = max|x|.
    levels = jnp.maximum(levels, 1.0)
    scale = _per_channel_scale(x2d, levels)
    q = jnp.round(x2d / scale)
    q = jnp.clip(q, -levels, levels)
    deq = q * scale
    out = jnp.where(passthrough, x2d, deq)
    return jnp.where(pruned, 0.0, out)


def binarize_ref(x2d: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Per-channel multi-bit residual binarization.

    Args:
      x2d:  (C, K) float32.
      bits: (C,)   float32 — BBN per channel, rounded; effective range
            [0, MAX_BBN].  0 ⇒ pruned.

    Returns (C, K) float32 — Σ_k α_k sign(r_k) with α_k = mean|r_k| per
    channel, accumulated for k < bits.
    """
    b = jnp.round(bits).astype(jnp.float32)[:, None]  # (C, 1)
    b = jnp.clip(b, 0.0, float(MAX_BBN))
    r = x2d
    out = jnp.zeros_like(x2d)
    for k in range(MAX_BBN):
        alpha = jnp.mean(jnp.abs(r), axis=1, keepdims=True)  # (C, 1)
        s = jnp.where(r >= 0.0, 1.0, -1.0)
        level = alpha * s
        active = (b > float(k)).astype(x2d.dtype)
        out = out + active * level
        r = r - active * level
    return out


def qmatmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(M, K) @ (K, N) in f32 — oracle for the Pallas tiled matmul."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)
