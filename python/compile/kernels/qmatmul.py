"""Pallas kernel: tiled matmul over quantized operands (L1).

The fully-connected layers and 1×1 (pointwise) convolutions of the model zoo
run through this kernel after their operands have been fake-quantized /
binarized.  On a real TPU the MXU consumes the dequantized (BM, BK)×(BK, BN)
tiles; the bit-serial cost the paper measures on FPGA is modelled separately
in ``rust/src/cost`` (see DESIGN.md §Hardware-Adaptation).

Classic 3-D grid (M/BM, N/BN, K/BK) with accumulation into the output tile
across the K grid dimension — the (BM, BN) accumulator stays resident in
VMEM for all K steps (revolving output), so HBM sees each operand exactly
once and the output exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes — multiples of the 128×128 MXU face where the operand
# allows; shrunk automatically for small operands.
BM, BN, BK = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (x.shape[0] + m0 - 1) // m0 * m0 - x.shape[0]
    p1 = (x.shape[1] + m1 - 1) // m1 * m1 - x.shape[1]
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _grid_cap_tile(dim: int, base: int, cap: int) -> int:
    """Grow the tile along `dim` (in multiples of `base`) until the grid is
    ≤ `cap` steps.  The pointwise convs of the zoo are extremely tall-skinny
    (M = N·H·W ≈ 262 144, K/N ≤ 128): a fixed 128-row tile costs ~2 048
    sequential grid steps whose loop overhead dominates; a 4 096-row tile is
    still only bm·bk·4 ≈ 2 MiB of VMEM and collapses the grid to ≤ 64 steps
    (EXPERIMENTS.md §Perf, L1 iteration 1)."""
    tile = min(base, dim)
    while dim > tile * cap and tile < 8192:
        tile *= 2
    return tile


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def qmatmul(a: jnp.ndarray, b: jnp.ndarray, bm: int = BM, bn: int = BN, bk: int = BK) -> jnp.ndarray:
    """(M, K) @ (K, N) → (M, N), f32, via the tiled Pallas kernel."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = _grid_cap_tile(m, bm, 64)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    # Round tiles down to the operand but keep them ≥ 8 for lane alignment.
    bm, bn, bk = max(bm, 1), max(bn, 1), max(bk, 1)
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    mp, kp = ap.shape
    _, np_ = bp.shape
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]
