"""Pallas kernel: per-channel multi-bit residual binarization (L1).

Implements the linear-combination binarization of [Lin et al. 17] used by
the paper (§3.1): a real tensor row (channel) is approximated as
``Σ_k α_k · sign(r_k)`` where ``α_k = mean|r_k|`` and
``r_{k+1} = r_k − α_k sign(r_k)``.  The per-channel BBN arrives as a runtime
vector, so one compiled artifact covers the whole 0..MAX_BBN design space —
the level loop is unrolled to MAX_BBN and masked by ``bits > k``.

The (BLOCK_C, K) tiling matches fake_quant.py: the residual ``r`` lives
entirely in VMEM across all MAX_BBN iterations (no HBM traffic between
levels), which is the TPU analogue of the paper's "binary filters are
streamed once" FPGA property.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MAX_BBN

BLOCK_C = 16


def _binarize_kernel(x_ref, bits_ref, o_ref):
    x = x_ref[...]                                   # (BC, K)
    b = jnp.round(bits_ref[...]).astype(jnp.float32)[:, None]
    b = jnp.clip(b, 0.0, float(MAX_BBN))
    r = x
    out = jnp.zeros_like(x)
    for k in range(MAX_BBN):  # unrolled: MAX_BBN fused VPU passes over VMEM
        alpha = jnp.mean(jnp.abs(r), axis=1, keepdims=True)
        s = jnp.where(r >= 0.0, 1.0, -1.0)
        level = alpha * s
        active = (b > float(k)).astype(x.dtype)
        out = out + active * level
        r = r - active * level
    o_ref[...] = out


def binarize(x2d: jnp.ndarray, bits: jnp.ndarray, block_c: int = BLOCK_C) -> jnp.ndarray:
    """Residual-binarize a (C, K) tensor row-wise with a (C,) BBN vector."""
    c, k = x2d.shape
    cp = (c + block_c - 1) // block_c * block_c
    if cp != c:
        x2d = jnp.pad(x2d, ((0, cp - c), (0, 0)))
        bits = jnp.pad(bits, (0, cp - c))
    out = pl.pallas_call(
        _binarize_kernel,
        grid=(cp // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, k), lambda i: (i, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_c, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, k), jnp.float32),
        interpret=True,
    )(x2d, bits)
    return out[:c]
