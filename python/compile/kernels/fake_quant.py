"""Pallas kernel: per-channel linear fake-quantization (L1 hot-spot).

This is the inner loop of the entire AutoQ search: every candidate
bit-assignment the RL agent proposes is evaluated by re-quantizing weights
and activations channel-by-channel and running inference.  The kernel tiles
the channel dimension so each grid step holds a (BLOCK_C, K) tile in
VMEM, computes the per-channel max-abs reduction in-register, and writes the
dequantized tile back — one HBM round-trip per tile.

TPU mapping (DESIGN.md §Hardware-Adaptation): the per-channel scale
reduction maps to an on-chip VPU reduction over the lane dimension; the
bits vector is a tiny (BLOCK_C,) operand kept resident per tile (scalar-
prefetch position).  ``interpret=True`` is mandatory on this image — real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Channel-block size.  16 rows × K lanes keeps the tile ≤ 16·K·4 bytes: for
# the largest layer in the zoo (K = 1152) that is ~72 KiB — comfortably
# inside a 16 MiB VMEM budget together with double-buffering.
BLOCK_C = 16


def _fake_quant_kernel(x_ref, bits_ref, o_ref):
    """One (BLOCK_C, K) tile: quantize-dequantize each row to its bit-width."""
    x = x_ref[...]                                   # (BC, K)
    b = jnp.round(bits_ref[...]).astype(jnp.float32)[:, None]  # (BC, 1)
    pruned = b <= 0.0
    passthrough = b >= 24.0
    levels = jnp.exp2(jnp.clip(b, 1.0, 24.0) - 1.0) - 1.0
    levels = jnp.maximum(levels, 1.0)
    max_abs = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(max_abs > 0.0, max_abs / levels, 1.0)
    q = jnp.clip(jnp.round(x / scale), -levels, levels)
    out = jnp.where(passthrough, x, q * scale)
    o_ref[...] = jnp.where(pruned, 0.0, out)


def fake_quant(x2d: jnp.ndarray, bits: jnp.ndarray, block_c: int = BLOCK_C) -> jnp.ndarray:
    """Per-channel fake-quantize a (C, K) tensor with a (C,) bits vector.

    Channels are padded up to a multiple of ``block_c`` so every grid step
    sees a full tile (padding rows carry bits=0 and are sliced off).
    """
    c, k = x2d.shape
    cp = (c + block_c - 1) // block_c * block_c
    if cp != c:
        x2d = jnp.pad(x2d, ((0, cp - c), (0, 0)))
        bits = jnp.pad(bits, (0, cp - c))
    out = pl.pallas_call(
        _fake_quant_kernel,
        grid=(cp // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, k), lambda i: (i, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_c, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, k), jnp.float32),
        interpret=True,
    )(x2d, bits)
    return out[:c]
