"""L1: Pallas kernels for the paper's compute hot-spots.

All kernels run with ``interpret=True`` (mandatory for CPU-PJRT execution on
this image) and are validated against the pure-jnp oracles in ``ref.py``.
"""

from .fake_quant import fake_quant
from .binarize import binarize
from .qmatmul import qmatmul
from . import ref

__all__ = ["fake_quant", "binarize", "qmatmul", "ref"]
