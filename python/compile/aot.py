"""AOT exporter: lower every L2 graph to HLO *text* + write the manifest.

Interchange format is HLO **text**, NOT serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 (behind the rust ``xla`` crate) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Artifacts (written to ``artifacts/``):
  {model}_eval_quant.hlo.txt   — Pallas-kernel path, per-channel QBN inputs
  {model}_eval_binar.hlo.txt   — Pallas-kernel path, per-channel BBN inputs
  {model}_train_quant.hlo.txt  — STE fine-tuning / pre-training step
  {model}_train_binar.hlo.txt  — STE fine-tuning for binarized models
  ddpg_act_s{16,17}.hlo.txt    — batched actor forward (HLC / LLC)
  ddpg_update_s{16,17}.hlo.txt — fused DDPG update step
  manifest.json                — input/output specs + model/agent metadata

Python runs only here (``make artifacts``); rust never imports it.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import agent as A
from . import model as M

HLC_S = 16  # Eq.-1 state feature count
LLC_S = 17  # state ⊕ goal


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(d) -> str:
    return {"float32": "f32", "int32": "s32"}.get(jnp.dtype(d).name, jnp.dtype(d).name)


def _specs(structs) -> list:
    out = []
    for s in structs:
        out.append({"shape": list(s.shape), "dtype": _dtype_name(s.dtype)})
    return out


def export_one(name: str, fn, args, out_dir: str, manifest: dict, force: bool) -> None:
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    out_shapes = jax.eval_shape(fn, *args)
    if not isinstance(out_shapes, tuple):
        out_shapes = (out_shapes,)
    manifest["artifacts"][name] = {
        "file": f"{name}.hlo.txt",
        "inputs": _specs(args),
        "outputs": _specs(out_shapes),
    }
    if os.path.exists(path) and not force:
        print(f"  [skip] {name} (exists)")
        return
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  [ok]   {name}: {len(text) / 1e6:.2f} MB HLO text")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--models", default=",".join(M.MODEL_NAMES),
                    help="comma-separated model subset")
    ap.add_argument("--force", action="store_true",
                    help="re-export even if the .hlo.txt already exists")
    opts = ap.parse_args()
    os.makedirs(opts.out, exist_ok=True)
    models = [m for m in opts.models.split(",") if m]

    manifest: dict = {"artifacts": {}, "models": {}, "agents": {}}

    for name in models:
        print(f"model {name}:")
        meta = M.model_meta(name)
        manifest["models"][name] = meta
        for mode in ("quant", "binar"):
            f, _ = M.eval_fn(name, mode, use_pallas=True)
            export_one(f"{name}_eval_{mode}", f, M.example_args(meta, "eval"),
                       opts.out, manifest, opts.force)
            tf, _ = M.train_fn(name, mode)
            export_one(f"{name}_train_{mode}", tf, M.example_args(meta, "train"),
                       opts.out, manifest, opts.force)

    for s_dim in (HLC_S, LLC_S):
        print(f"agent s{s_dim}:")
        manifest["agents"][f"s{s_dim}"] = A.agent_meta(s_dim)
        export_one(f"ddpg_act_s{s_dim}", A.act_fn(s_dim),
                   A.act_example_args(s_dim), opts.out, manifest, opts.force)
        export_one(f"ddpg_update_s{s_dim}", A.update_fn(s_dim),
                   A.update_example_args(s_dim), opts.out, manifest, opts.force)

    man_path = os.path.join(opts.out, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {man_path}")


if __name__ == "__main__":
    main()
