"""L2: DDPG actor/critic graphs for the hierarchical agent (HLC + LLC).

The paper's agent (§3.2, §4): actor = 2×300-unit hidden layers with a
sigmoid output scaled by 32 (goals/actions live in [0, 32]); critic =
2×300-unit hidden layers.  Soft target updates with τ = 0.01, batch 64.

Two artifact families are exported per input width S (S = 16 for the HLC on
the Eq.-1 state, S = 17 for the goal-conditioned LLC):

  * ``ddpg_act_s{S}``    — batched deterministic policy μ(s): (actor params,
    states (B, S)) → actions (B, 1) in [0, 32].  One call covers all
    channels of a layer (LLC) or a single layer state (HLC, padded) — this
    batching is the L3 hot-path optimisation that keeps the search loop at
    one executable dispatch per layer.
  * ``ddpg_update_s{S}`` — one fused off-policy step: critic TD(0)
    regression + deterministic-policy-gradient actor step + Adam for both +
    soft target update.  All parameters, Adam moments and the step counter
    are inputs AND outputs, so rust owns every buffer and the graph stays
    pure.

Rust instantiates four independent agents from these two artifacts
(weight-HLC, activation-HLC, weight-LLC, activation-LLC) by holding four
separate parameter sets — see rust/src/agent/.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

HIDDEN = 300
ACT_BATCH = 128   # max channels acted on in one call (max layer width in zoo)
UPD_BATCH = 64    # paper: replay minibatch of 64
ACTION_SCALE = 32.0

# Adam hyper-parameters (standard DDPG practice; the paper fixes τ=0.01 and
# batch 64 but leaves the optimiser unstated).
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def actor_shapes(s: int) -> List[Tuple[int, ...]]:
    return [(s, HIDDEN), (HIDDEN,), (HIDDEN, HIDDEN), (HIDDEN,), (HIDDEN, 1), (1,)]


def critic_shapes(s: int) -> List[Tuple[int, ...]]:
    # Critic consumes state ⊕ action.
    return [(s + 1, HIDDEN), (HIDDEN,), (HIDDEN, HIDDEN), (HIDDEN,), (HIDDEN, 1), (1,)]


def actor_forward(p: List[jnp.ndarray], s: jnp.ndarray) -> jnp.ndarray:
    """μ(s) ∈ [0, 32]^(B,1)."""
    h = jax.nn.relu(s @ p[0] + p[1])
    h = jax.nn.relu(h @ p[2] + p[3])
    return jax.nn.sigmoid(h @ p[4] + p[5]) * ACTION_SCALE


def critic_forward(p: List[jnp.ndarray], s: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Q(s, a) ∈ R^(B,1).  Action normalised to [0,1] before concat."""
    x = jnp.concatenate([s, a / ACTION_SCALE], axis=-1)
    h = jax.nn.relu(x @ p[0] + p[1])
    h = jax.nn.relu(h @ p[2] + p[3])
    return h @ p[4] + p[5]


def act_fn(s_dim: int):
    """(6 actor params, states (ACT_BATCH, s_dim)) -> actions (ACT_BATCH, 1)."""

    def f(*args):
        p = list(args[:6])
        states = args[6]
        return actor_forward(p, states)

    return f


def _adam(params, grads, m, v, t, lr):
    new_m = [ADAM_B1 * mi + (1 - ADAM_B1) * g for mi, g in zip(m, grads)]
    new_v = [ADAM_B2 * vi + (1 - ADAM_B2) * g * g for vi, g in zip(v, grads)]
    mh = [mi / (1 - ADAM_B1 ** t) for mi in new_m]
    vh = [vi / (1 - ADAM_B2 ** t) for vi in new_v]
    new_p = [p - lr * mhi / (jnp.sqrt(vhi) + ADAM_EPS)
             for p, mhi, vhi in zip(params, mh, vh)]
    return new_p, new_m, new_v


def update_fn(s_dim: int):
    """One fused DDPG update step.

    Input order (rust mirrors this via the manifest):
      actor(6), critic(6), target_actor(6), target_critic(6),
      adam_m_actor(6), adam_v_actor(6), adam_m_critic(6), adam_v_critic(6),
      t(scalar),
      s (B,S), a (B,1), r (B,1), s2 (B,S), done (B,1),
      gamma, tau, lr_actor, lr_critic (scalars)
    Output order:
      actor(6), critic(6), target_actor(6), target_critic(6),
      adam moments (24), t+1, critic_loss, actor_loss
    """

    def f(*args):
        i = 0

        def take(n):
            nonlocal i
            out = list(args[i:i + n])
            i += n
            return out

        actor = take(6)
        critic = take(6)
        t_actor = take(6)
        t_critic = take(6)
        m_a, v_a = take(6), take(6)
        m_c, v_c = take(6), take(6)
        (t,) = take(1)
        s, a, r, s2, done = take(5)
        gamma, tau, lr_a, lr_c = take(4)

        # --- critic: TD(0) target from target nets (paper Bellman error) ---
        a2 = actor_forward(t_actor, s2)
        q_tgt = r + gamma * (1.0 - done) * critic_forward(t_critic, s2, a2)
        q_tgt = jax.lax.stop_gradient(q_tgt)

        def critic_loss_fn(cp):
            q = critic_forward(cp, s, a)
            return jnp.mean((q - q_tgt) ** 2)

        closs, cgrads = jax.value_and_grad(critic_loss_fn)(critic)

        # --- actor: deterministic policy gradient through the critic -------
        def actor_loss_fn(ap):
            return -jnp.mean(critic_forward(critic, s, actor_forward(ap, s)))

        aloss, agrads = jax.value_and_grad(actor_loss_fn)(actor)

        t1 = t + 1.0
        new_critic, m_c, v_c = _adam(critic, cgrads, m_c, v_c, t1, lr_c)
        new_actor, m_a, v_a = _adam(actor, agrads, m_a, v_a, t1, lr_a)

        # --- soft target update (τ = 0.01) ---------------------------------
        new_t_actor = [tau * p + (1 - tau) * tp for p, tp in zip(new_actor, t_actor)]
        new_t_critic = [tau * p + (1 - tau) * tp for p, tp in zip(new_critic, t_critic)]

        return tuple(new_actor) + tuple(new_critic) + tuple(new_t_actor) + \
            tuple(new_t_critic) + tuple(m_a) + tuple(v_a) + tuple(m_c) + \
            tuple(v_c) + (t1, closs, aloss)

    return f


def act_example_args(s_dim: int):
    f32 = jnp.float32
    ps = [jax.ShapeDtypeStruct(shp, f32) for shp in actor_shapes(s_dim)]
    return ps + [jax.ShapeDtypeStruct((ACT_BATCH, s_dim), f32)]


def update_example_args(s_dim: int):
    f32 = jnp.float32
    sd = lambda shp: jax.ShapeDtypeStruct(shp, f32)
    a6 = [sd(s) for s in actor_shapes(s_dim)]
    c6 = [sd(s) for s in critic_shapes(s_dim)]
    args = a6 + c6 + a6 + c6            # nets + targets
    args += a6 + a6 + c6 + c6           # adam moments
    args += [sd(())]                    # t
    B = UPD_BATCH
    args += [sd((B, s_dim)), sd((B, 1)), sd((B, 1)), sd((B, s_dim)), sd((B, 1))]
    args += [sd(()), sd(()), sd(()), sd(())]  # gamma, tau, lr_a, lr_c
    return args


def agent_meta(s_dim: int) -> dict:
    """Parameter layout metadata for rust (shapes in artifact input order)."""
    return {
        "s_dim": s_dim,
        "hidden": HIDDEN,
        "act_batch": ACT_BATCH,
        "upd_batch": UPD_BATCH,
        "action_scale": ACTION_SCALE,
        "actor_shapes": [list(s) for s in actor_shapes(s_dim)],
        "critic_shapes": [list(s) for s in critic_shapes(s_dim)],
    }
