"""L2: the model zoo — JAX forward/backward graphs, calling kernels.*.

The paper evaluates five CNNs: CIFAR10-7CNN, ResNet18, ResNet50, SqueezeNetV1
and MobileNetV2.  Per the substitution rules (DESIGN.md), the architectures
are preserved topologically but scaled to 32×32 / 10-class so they can be
pre-trained, searched and fine-tuned on this CPU-only image: ``cif10`` (the
paper's 7-conv CNN, verbatim), ``res18`` (basic-block ResNet), ``sqnet``
(fire modules), ``monet`` (inverted-residual depthwise blocks).  ResNet50's
bottleneck topology is represented by ``res18``'s deeper stages; the search
behaviour the paper studies depends on the channel/topology structure, which
is preserved.

Per-channel quantization semantics (paper §3.1):
  * every conv/fc layer's weights get one QBN/BBN per *output* channel,
  * every conv layer's activations get one QBN/BBN per *input* channel,
  * fully-connected layers share a single activation QBN/BBN (paper §3.2,
    "AutoQB set the same QBN/BBN to all activation input channels in a
    fully-connected layer"),
  * bit-width 0 prunes the channel.

The bit vectors (``wbits``: one entry per weight output channel in network
order; ``abits``: one per activation input channel) are **runtime inputs**
of the exported HLO, so a single artifact per model serves every point of
the 32^channels design space the RL agent explores.

Two compute paths, proven numerically identical in python/tests:
  * ``use_pallas=True``  — routes quantize/binarize (and 1×1-conv / fc
    matmuls) through the L1 Pallas kernels; exported as the ``*_eval_*``
    artifacts (the search hot path).
  * ``use_pallas=False`` — the pure-jnp reference path; used inside
    ``train_step`` where gradients flow via STE and XLA can fuse freely.

GroupNorm (stateless) replaces BatchNorm so the whole training step stays
functional — no running statistics to thread through the AOT boundary.
Norm/bias parameters are not quantized (standard practice; they fold into
the accumulator on deployment).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import binarize as pallas_binarize
from .kernels import fake_quant as pallas_fake_quant
from .kernels import qmatmul as pallas_qmatmul
from .kernels import ref

# ---------------------------------------------------------------------------
# Architecture specs (node mini-DSL)
# ---------------------------------------------------------------------------

# Node kinds: conv / fc / pool / gap / basic (resnet block) / fire / irb.
SPECS: Dict[str, List[Dict[str, Any]]] = {
    # The paper's CIFAR10-7CNN: 7 conv layers + classifier.
    "cif10": [
        {"kind": "conv", "k": 3, "s": 1, "cout": 16},
        {"kind": "conv", "k": 3, "s": 1, "cout": 16},
        {"kind": "conv", "k": 3, "s": 2, "cout": 32},
        {"kind": "conv", "k": 3, "s": 1, "cout": 32},
        {"kind": "conv", "k": 3, "s": 2, "cout": 64},
        {"kind": "conv", "k": 3, "s": 1, "cout": 64},
        {"kind": "conv", "k": 3, "s": 1, "cout": 64},
        {"kind": "gap"},
        {"kind": "fc", "cout": 10},
    ],
    # ResNet-18 topology at CIFAR scale: stem + 4 stages x 2 basic blocks.
    "res18": [
        {"kind": "conv", "k": 3, "s": 1, "cout": 16},
        {"kind": "basic", "cout": 16, "s": 1},
        {"kind": "basic", "cout": 16, "s": 1},
        {"kind": "basic", "cout": 32, "s": 2},
        {"kind": "basic", "cout": 32, "s": 1},
        {"kind": "basic", "cout": 64, "s": 2},
        {"kind": "basic", "cout": 64, "s": 1},
        {"kind": "basic", "cout": 128, "s": 2},
        {"kind": "basic", "cout": 128, "s": 1},
        {"kind": "gap"},
        {"kind": "fc", "cout": 10},
    ],
    # SqueezeNet-V1 topology: stem + fire modules + conv classifier.
    "sqnet": [
        {"kind": "conv", "k": 3, "s": 1, "cout": 32},
        {"kind": "pool", "k": 2},
        {"kind": "fire", "sq": 16, "e1": 32, "e3": 32},
        {"kind": "fire", "sq": 16, "e1": 32, "e3": 32},
        {"kind": "pool", "k": 2},
        {"kind": "fire", "sq": 32, "e1": 64, "e3": 64},
        {"kind": "fire", "sq": 32, "e1": 64, "e3": 64},
        {"kind": "conv", "k": 1, "s": 1, "cout": 10, "norm": False, "act": "none"},
        {"kind": "gap_logits"},
    ],
    # MobileNetV2 topology: stem + inverted-residual (expand/dw/project).
    "monet": [
        {"kind": "conv", "k": 3, "s": 1, "cout": 16},
        {"kind": "irb", "t": 1, "cout": 16, "s": 1},
        {"kind": "irb", "t": 3, "cout": 24, "s": 2},
        {"kind": "irb", "t": 3, "cout": 24, "s": 1},
        {"kind": "irb", "t": 3, "cout": 32, "s": 2},
        {"kind": "irb", "t": 3, "cout": 32, "s": 1},
        {"kind": "conv", "k": 1, "s": 1, "cout": 96},
        {"kind": "gap"},
        {"kind": "fc", "cout": 10},
    ],
}

MODEL_NAMES = list(SPECS.keys())

IMAGE_HW = 32
NUM_CLASSES = 10
EVAL_BATCH = 256
TRAIN_BATCH = 128

# ---------------------------------------------------------------------------
# Shared traversal: one walker, two backends (metadata vs compute).
# ---------------------------------------------------------------------------


class MetaBackend:
    """Dry-run backend: records layer metadata and parameter specs."""

    def __init__(self) -> None:
        self.layers: List[Dict[str, Any]] = []
        self.params: List[Dict[str, Any]] = []
        self.w_channels = 0  # running weight-output-channel offset
        self.a_channels = 0  # running activation-input-channel offset

    # Each quantizable layer: record metadata + param specs, return None.
    def layer(self, name: str, typ: str, k: int, s: int, cin: int, cout: int,
              h: int, w: int, norm: bool, act: str, x: Any = None) -> Any:
        h_out = (h + s - 1) // s
        w_out = (w + s - 1) // s
        groups = cin if typ == "dwconv" else 1
        # MACs for one inference (the bit-independent logic_t of Eq. 1).
        if typ == "fc":
            macs = cin * cout
        elif typ == "dwconv":
            macs = h_out * w_out * k * k * cin
        else:
            macs = h_out * w_out * k * k * (cin // groups) * cout
        n_act = 1 if typ == "fc" else cin
        self.layers.append({
            "name": name, "type": typ, "k": k, "stride": s,
            "cin": cin, "cout": cout, "h_in": h, "w_in": w,
            "h_out": h_out, "w_out": w_out, "macs": macs,
            "w_off": self.w_channels, "w_len": cout,
            "a_off": self.a_channels, "a_len": n_act,
        })
        self.w_channels += cout
        self.a_channels += n_act
        if typ == "fc":
            self.params.append({"name": f"{name}.w", "shape": [cin, cout], "init": "he"})
            self.params.append({"name": f"{name}.b", "shape": [cout], "init": "zeros"})
        else:
            kk = [k, k, cin // groups, cout] if typ != "dwconv" else [k, k, 1, cin]
            self.params.append({"name": f"{name}.w", "shape": kk, "init": "he"})
            if norm:
                self.params.append({"name": f"{name}.g", "shape": [cout], "init": "ones"})
                self.params.append({"name": f"{name}.bta", "shape": [cout], "init": "zeros"})
            else:
                self.params.append({"name": f"{name}.b", "shape": [cout], "init": "zeros"})
        return None


class ComputeBackend:
    """Real backend: consumes params + bit slices in metadata order."""

    def __init__(self, layers_meta, params, wbits, abits, mode, use_pallas, ste):
        self.meta = layers_meta
        self.params = params      # dict name -> array
        self.wbits = wbits
        self.abits = abits
        self.mode = mode          # "quant" | "binar"
        self.use_pallas = use_pallas
        self.ste = ste            # straight-through estimator (training)
        self.idx = 0

    # -- bit application helpers -------------------------------------------
    def _apply_bits(self, x2d: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
        if self.mode == "quant":
            fn = pallas_fake_quant if self.use_pallas else ref.fake_quant_ref
        else:
            fn = pallas_binarize if self.use_pallas else ref.binarize_ref
        q = fn(x2d, bits)
        if self.ste:
            q = x2d + lax.stop_gradient(q - x2d)
        return q

    def _quant_weight(self, w: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
        """Per-output-channel quantization of a conv/fc weight."""
        if w.ndim == 2:  # fc: (cin, cout) -> rows = output channels
            w2 = w.T
            return self._apply_bits(w2, bits).T
        # conv: (k, k, cin_g, cout) -> (cout, k*k*cin_g)
        kh, kw, cin_g, cout = w.shape
        w2 = jnp.transpose(w, (3, 0, 1, 2)).reshape(cout, kh * kw * cin_g)
        q = self._apply_bits(w2, bits)
        return jnp.transpose(q.reshape(cout, kh, kw, cin_g), (1, 2, 3, 0))

    def _quant_act(self, x: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
        """Per-input-channel quantization of an activation tensor."""
        if x.ndim == 2:  # fc input: single shared channel (paper §3.2)
            n, c = x.shape
            x2 = x.reshape(1, n * c)
            return self._apply_bits(x2, bits).reshape(n, c)
        n, h, w, c = x.shape
        x2 = jnp.transpose(x, (3, 0, 1, 2)).reshape(c, n * h * w)
        q = self._apply_bits(x2, bits)
        return jnp.transpose(q.reshape(c, n, h, w), (1, 2, 3, 0))

    # -- the quantizable layer ---------------------------------------------
    def layer(self, name, typ, k, s, cin, cout, h, w, norm, act, x):
        m = self.meta[self.idx]
        self.idx += 1
        assert m["name"] == name, f"meta walk diverged: {m['name']} vs {name}"
        wb = lax.dynamic_slice(self.wbits, (m["w_off"],), (m["w_len"],))
        ab = lax.dynamic_slice(self.abits, (m["a_off"],), (m["a_len"],))
        weight = self.params[f"{name}.w"]
        x = self._quant_act(x, ab)
        weight = self._quant_weight(weight, wb)

        if typ == "fc":
            if self.use_pallas:
                y = pallas_qmatmul(x, weight)
            else:
                y = jnp.matmul(x, weight)
            return y + self.params[f"{name}.b"]

        if typ == "conv" and k == 1 and s == 1:
            # Pointwise conv == matmul over flattened pixels (Pallas path).
            n, hh, ww, c = x.shape
            xf = x.reshape(n * hh * ww, c)
            wf = weight.reshape(c, cout)
            y = pallas_qmatmul(xf, wf) if self.use_pallas else jnp.matmul(xf, wf)
            y = y.reshape(n, hh, ww, cout)
        else:
            groups = cin if typ == "dwconv" else 1
            y = lax.conv_general_dilated(
                x, weight,
                window_strides=(s, s), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=groups,
            )
        if norm:
            y = group_norm(y, self.params[f"{name}.g"], self.params[f"{name}.bta"])
        else:
            y = y + self.params[f"{name}.b"]
        if act == "relu":
            y = jax.nn.relu(y)
        return y


def group_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, groups: int = 8) -> jnp.ndarray:
    """Stateless GroupNorm over NHWC, ``groups`` divides C (fallback 1)."""
    n, h, w, c = x.shape
    gr = groups if c % groups == 0 else 1
    xg = x.reshape(n, h, w, gr, c // gr)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xn = (xg - mu) * lax.rsqrt(var + 1e-5)
    return xn.reshape(n, h, w, c) * g + b


def _walk(spec: List[Dict[str, Any]], backend, x, h: int, w: int, c: int):
    """Shared traversal over the node DSL.

    For MetaBackend ``x`` is None and only shapes (h, w, c) are threaded;
    for ComputeBackend the activation tensor is threaded too.
    """
    li = 0  # primitive layer counter (names must be deterministic)

    def nm(base):
        nonlocal li
        li += 1
        return f"l{li:02d}_{base}"

    compute = x is not None
    for node in spec:
        kind = node["kind"]
        if kind == "conv":
            norm = node.get("norm", True)
            act = node.get("act", "relu")
            name = nm("conv")
            y = backend.layer(name, "conv", node["k"], node["s"], c, node["cout"], h, w, norm, act, x)
            h = (h + node["s"] - 1) // node["s"]
            w = (w + node["s"] - 1) // node["s"]
            c = node["cout"]
            x = y if compute else None
        elif kind == "fc":
            name = nm("fc")
            y = backend.layer(name, "fc", 1, 1, c, node["cout"], 1, 1, False, "none", x)
            c = node["cout"]
            x = y if compute else None
        elif kind == "pool":
            if compute:
                x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            h, w = h // 2, w // 2
        elif kind == "gap":
            if compute:
                x = jnp.mean(x, axis=(1, 2))
            h = w = 1
        elif kind == "gap_logits":
            if compute:
                x = jnp.mean(x, axis=(1, 2))
            h = w = 1
        elif kind == "basic":
            cout, s = node["cout"], node["s"]
            proj = (s != 1) or (c != cout)
            inp = x
            y = backend.layer(nm("conv"), "conv", 3, s, c, cout, h, w, True, "relu", x)
            h2 = (h + s - 1) // s
            w2 = (w + s - 1) // s
            y = backend.layer(nm("conv"), "conv", 3, 1, cout, cout, h2, w2, True, "none", y)
            if proj:
                sc = backend.layer(nm("proj"), "conv", 1, s, c, cout, h, w, True, "none", inp)
            else:
                sc = inp
            if compute:
                x = jax.nn.relu(y + sc)
            h, w, c = h2, w2, cout
        elif kind == "fire":
            sq, e1, e3 = node["sq"], node["e1"], node["e3"]
            sqz = backend.layer(nm("squeeze"), "conv", 1, 1, c, sq, h, w, True, "relu", x)
            a = backend.layer(nm("expand1"), "conv", 1, 1, sq, e1, h, w, True, "relu", sqz)
            b = backend.layer(nm("expand3"), "conv", 3, 1, sq, e3, h, w, True, "relu", sqz)
            if compute:
                x = jnp.concatenate([a, b], axis=-1)
            c = e1 + e3
        elif kind == "irb":
            t, cout, s = node["t"], node["cout"], node["s"]
            cexp = c * t
            inp = x
            y = x
            if t != 1:
                y = backend.layer(nm("expand"), "conv", 1, 1, c, cexp, h, w, True, "relu", y)
            y = backend.layer(nm("dw"), "dwconv", 3, s, cexp, cexp, h, w, True, "relu", y)
            h2 = (h + s - 1) // s
            w2 = (w + s - 1) // s
            y = backend.layer(nm("project"), "conv", 1, 1, cexp, cout, h2, w2, True, "none", y)
            if compute:
                x = (inp + y) if (s == 1 and c == cout) else y
            h, w, c = h2, w2, cout
        else:
            raise ValueError(f"unknown node kind {kind!r}")
    return x


# ---------------------------------------------------------------------------
# Public model API
# ---------------------------------------------------------------------------


def model_meta(name: str) -> Dict[str, Any]:
    """Layer metadata + parameter specs for ``name`` (consumed by rust)."""
    be = MetaBackend()
    _walk(SPECS[name], be, None, IMAGE_HW, IMAGE_HW, 3)
    return {
        "name": name,
        "image_hw": IMAGE_HW,
        "num_classes": NUM_CLASSES,
        "eval_batch": EVAL_BATCH,
        "train_batch": TRAIN_BATCH,
        "layers": be.layers,
        "params": be.params,
        "w_channels": be.w_channels,
        "a_channels": be.a_channels,
        "total_macs": sum(l["macs"] for l in be.layers),
    }


def forward(name: str, params: Dict[str, jnp.ndarray], images: jnp.ndarray,
            wbits: jnp.ndarray, abits: jnp.ndarray, mode: str,
            use_pallas: bool, ste: bool = False) -> jnp.ndarray:
    """Logits for a batch under a per-channel bit configuration."""
    meta = model_meta(name)
    be = ComputeBackend(meta["layers"], params, wbits, abits, mode, use_pallas, ste)
    logits = _walk(SPECS[name], be, images, IMAGE_HW, IMAGE_HW, 3)
    assert be.idx == len(meta["layers"])
    return logits


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def eval_fn(name: str, mode: str, use_pallas: bool):
    """Builds eval(params..., images, labels, wbits, abits) -> (correct, loss).

    Returned callable takes a flat list of param arrays in manifest order.
    """
    meta = model_meta(name)
    pnames = [p["name"] for p in meta["params"]]

    def f(*args):
        np_ = len(pnames)
        params = dict(zip(pnames, args[:np_]))
        images, labels, wbits, abits = args[np_:]
        logits = forward(name, params, images, wbits, abits, mode, use_pallas)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
        loss = cross_entropy(logits, labels)
        return correct, loss

    return f, meta


def train_fn(name: str, mode: str):
    """Builds train(params..., momenta..., images, labels, wbits, abits, lr)
    -> (new_params..., new_momenta..., loss).  SGD with momentum 0.9, STE
    through the quantizers.  Pure-jnp path (see module docstring)."""
    meta = model_meta(name)
    pnames = [p["name"] for p in meta["params"]]
    np_ = len(pnames)

    def loss_fn(plist, images, labels, wbits, abits):
        params = dict(zip(pnames, plist))
        logits = forward(name, params, images, wbits, abits, mode,
                         use_pallas=False, ste=True)
        return cross_entropy(logits, labels)

    def f(*args):
        plist = list(args[:np_])
        mlist = list(args[np_:2 * np_])
        images, labels, wbits, abits, lr = args[2 * np_:]
        loss, grads = jax.value_and_grad(loss_fn)(plist, images, labels, wbits, abits)
        new_m = [0.9 * m + g for m, g in zip(mlist, grads)]
        new_p = [p - lr * m for p, m in zip(plist, new_m)]
        return tuple(new_p) + tuple(new_m) + (loss,)

    return f, meta


def example_args(meta: Dict[str, Any], kind: str):
    """ShapeDtypeStructs for lowering (kind: 'eval' | 'train')."""
    f32 = jnp.float32
    ps = [jax.ShapeDtypeStruct(tuple(p["shape"]), f32) for p in meta["params"]]
    wb = jax.ShapeDtypeStruct((meta["w_channels"],), f32)
    ab = jax.ShapeDtypeStruct((meta["a_channels"],), f32)
    if kind == "eval":
        img = jax.ShapeDtypeStruct((EVAL_BATCH, IMAGE_HW, IMAGE_HW, 3), f32)
        lbl = jax.ShapeDtypeStruct((EVAL_BATCH,), jnp.int32)
        return ps + [img, lbl, wb, ab]
    img = jax.ShapeDtypeStruct((TRAIN_BATCH, IMAGE_HW, IMAGE_HW, 3), f32)
    lbl = jax.ShapeDtypeStruct((TRAIN_BATCH,), jnp.int32)
    lr = jax.ShapeDtypeStruct((), f32)
    return ps + ps + [img, lbl, wb, ab, lr]
