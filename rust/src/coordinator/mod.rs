//! The paper's Layer-3 coordination contribution as a real subsystem: a
//! job-oriented orchestration API over the runtime, model zoo, search,
//! fine-tuning and simulators.
//!
//! - [`Coordinator`] owns the PJRT [`Runtime`], a cache of pre-trained
//!   [`ModelRunner`]s (pre-training on first use) and the artifact-directory
//!   layout — the plumbing every CLI subcommand used to hand-wire itself.
//! - [`JobSpec`] is the builder-validated unit of work
//!   (`JobSpec::search("cif10").mode(..).protocol(..).episodes(40).build()?`).
//! - [`Observer`] streams structured per-episode progress events;
//!   [`JobReport`] is the JSON-serializable result.
//! - [`Sweep`] fans a grid of search jobs across worker threads with
//!   deterministic per-cell seeds (`autoq sweep`).
//!
//! See DESIGN.md §Coordinator for the full API walkthrough.

pub mod job;
pub mod observer;
pub mod report;
pub mod sweep;

pub use job::{granularity_token, init_seed, JobBuilder, JobKind, JobSpec, SearchParams};
pub use observer::{FanOut, LogObserver, NullObserver, Observer};
pub use report::{JobOutcome, JobReport, SimCell};
pub use sweep::{derive_seed, Sweep, SweepResult};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::cost::Mode;
use crate::data::synth::{Split, SynthDataset};
use crate::finetune::TrainConfig;
use crate::models::{ModelRunner, ParamStore};
use crate::runtime::{BackendKind, Manifest, Parallelism, Runtime, RuntimeOpts};
use crate::search::SearchConfig;
use crate::serve::cache::CacheHandle;
use crate::sim::{Arch, FpgaSim};
use crate::util::rng::Rng;

/// Synthetic-dataset seed shared by search/eval/finetune jobs (the
/// testbed's fixed validation data — see DESIGN.md §Substitutions).
pub const DATA_SEED: u64 = 42;

/// SGD steps for pretrain-on-first-use (explicit `pretrain` jobs choose
/// their own step count).
const AUTO_PRETRAIN_STEPS: usize = 300;

/// The crate's front door: owns the runtime, the model-runner cache and the
/// artifact layout, and executes [`JobSpec`]s into [`JobReport`]s.
pub struct Coordinator {
    rt: Runtime,
    dir: PathBuf,
    runners: HashMap<String, ModelRunner>,
    /// Content-addressed eval memoization shared with every runner this
    /// coordinator creates (`autoq serve` attaches one per scheduler
    /// worker; `None` = uncached, the historical behavior).
    eval_cache: Option<Arc<CacheHandle>>,
}

impl Coordinator {
    /// Default artifact dir: `$AUTOQ_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        Runtime::default_dir()
    }

    /// Open with automatic backend selection (explicit > `$AUTOQ_BACKEND` >
    /// PJRT iff compiled in and artifacts exist > reference).
    pub fn open(dir: &Path) -> anyhow::Result<Coordinator> {
        Self::open_with(dir, None)
    }

    /// Open with an explicit backend choice (`None` = auto-resolve) and
    /// auto-resolved eval parallelism (`$AUTOQ_THREADS`, else all cores).
    pub fn open_with(dir: &Path, backend: Option<BackendKind>) -> anyhow::Result<Coordinator> {
        Self::open_with_opts(dir, backend, None)
    }

    /// Open with explicit backend and worker-thread choices (`None` =
    /// auto-resolve each, mirroring `--backend`/`--threads`).
    pub fn open_with_opts(
        dir: &Path,
        backend: Option<BackendKind>,
        threads: Option<Parallelism>,
    ) -> anyhow::Result<Coordinator> {
        Self::open_full(dir, backend, RuntimeOpts::threads(threads))
    }

    /// Open with the full option set (mirroring
    /// `--backend`/`--threads`/`--shard-workers`; every `None`
    /// auto-resolves).
    pub fn open_full(
        dir: &Path,
        backend: Option<BackendKind>,
        opts: RuntimeOpts,
    ) -> anyhow::Result<Coordinator> {
        let kind = BackendKind::resolve(dir, backend)?;
        let rt = Runtime::open_full(dir, kind, opts)?;
        // The reference backend needs no artifacts, but trained params still
        // persist under the artifact dir — make sure it exists.
        std::fs::create_dir_all(dir)?;
        Ok(Coordinator { rt, dir: dir.to_path_buf(), runners: HashMap::new(), eval_cache: None })
    }

    /// Attach a content-addressed eval cache: every cached and future
    /// runner routes `eval_config` through it.  Results stay byte-identical
    /// — the cache replays exact stored `EvalResult`s — so reports from a
    /// cached run must equal an uncached run's (`tests/eval_cache.rs`).
    pub fn set_eval_cache(&mut self, cache: Arc<CacheHandle>) {
        for runner in self.runners.values_mut() {
            runner.set_eval_cache(Some(cache.clone()));
        }
        self.eval_cache = Some(cache);
    }

    pub fn eval_cache(&self) -> Option<&Arc<CacheHandle>> {
        self.eval_cache.as_ref()
    }

    /// Hand the configured cache (if any) to a runner this coordinator made.
    fn attach_cache(&self, runner: &mut ModelRunner) {
        if let Some(cache) = &self.eval_cache {
            runner.set_eval_cache(Some(cache.clone()));
        }
    }

    pub fn open_default() -> anyhow::Result<Coordinator> {
        Self::open(&Self::default_dir())
    }

    /// Which execution backend this coordinator runs on.
    pub fn backend(&self) -> BackendKind {
        self.rt.backend_kind()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    /// Escape hatch for call sites that drive artifacts directly (repro
    /// internals, benches).
    pub fn runtime(&mut self) -> &mut Runtime {
        &mut self.rt
    }

    /// Where a model's trained parameters persist inside an artifact dir.
    pub fn params_path_in(dir: &Path, model: &str) -> PathBuf {
        dir.join(format!("{model}_trained.apb"))
    }

    /// Where a model's trained parameters persist inside the artifact dir.
    pub fn params_path(&self, model: &str) -> PathBuf {
        Self::params_path_in(&self.dir, model)
    }

    /// Load `model` into the runner cache, pre-training and persisting the
    /// params on first use (the logic formerly duplicated across
    /// `cmd_pretrain`, `load_runner` and `repro::runner_for`).
    pub fn ensure_pretrained(&mut self, model: &str) -> anyhow::Result<()> {
        if self.runners.contains_key(model) {
            return Ok(());
        }
        let meta = self.rt.manifest.model(model)?.clone();
        let path = self.params_path(model);
        let mut runner = if path.exists() {
            ModelRunner::new(meta, ParamStore::load(&path)?)?
        } else {
            crate::info!("no trained params for {model}; pre-training now ({AUTO_PRETRAIN_STEPS} steps)");
            let mut r = ModelRunner::init(meta, &mut Rng::new(init_seed(model)));
            let data = SynthDataset::new(DATA_SEED);
            let cfg = TrainConfig::pretrain_for(model, AUTO_PRETRAIN_STEPS);
            let rep = crate::finetune::train(&mut self.rt, &mut r, &data, &cfg)?;
            crate::info!("pretrained {model}: acc={:.4}", rep.final_eval.accuracy);
            r.params.save(&path)?;
            r
        };
        self.attach_cache(&mut runner);
        self.runners.insert(model.to_string(), runner);
        Ok(())
    }

    /// Owned copy of the cached pre-trained runner (fresh zero momenta) —
    /// for callers that mutate params, e.g. fine-tuning.
    pub fn fresh_runner(&mut self, model: &str) -> anyhow::Result<ModelRunner> {
        self.ensure_pretrained(model)?;
        let cached = self.runners.get(model).expect("ensured above");
        let mut runner = ModelRunner::new(cached.meta.clone(), cached.params.clone())?;
        self.attach_cache(&mut runner);
        Ok(runner)
    }

    /// Run a job with default stderr logging.
    pub fn run(&mut self, spec: &JobSpec) -> anyhow::Result<JobReport> {
        let mut obs = LogObserver::default();
        self.run_observed(spec, &mut obs)
    }

    /// Run a job, streaming progress into `obs`.
    pub fn run_observed(
        &mut self,
        spec: &JobSpec,
        obs: &mut dyn Observer,
    ) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        obs.job_started(spec);
        // Snapshot cache counters so the per-job delta can be surfaced as
        // an observer message (never in the JobReport itself — report JSON
        // must stay byte-identical between cached and uncached runs).
        let cache_snap = self.eval_cache.as_ref().map(|c| c.counts());
        let outcome = match &spec.kind {
            JobKind::Pretrain { steps, data_seed, persist } => {
                let meta = self.rt.manifest.model(&spec.model)?.clone();
                let mut runner = ModelRunner::init(meta, &mut Rng::new(spec.seed));
                self.attach_cache(&mut runner);
                let data = SynthDataset::new(*data_seed);
                let cfg = TrainConfig::pretrain_for(&spec.model, *steps);
                let rep = crate::finetune::train(&mut self.rt, &mut runner, &data, &cfg)?;
                if *persist {
                    let path = self.params_path(&spec.model);
                    runner.params.save(&path)?;
                    obs.message(spec, &format!("saved {}", path.display()));
                }
                self.runners.insert(spec.model.clone(), runner);
                JobOutcome::Train { before: None, final_eval: rep.final_eval, curve: rep.curve }
            }
            JobKind::Search(p) => {
                self.ensure_pretrained(&spec.model)?;
                let runner = self.runners.get(&spec.model).expect("ensured above");
                let data = SynthDataset::new(DATA_SEED);
                let mut cfg = SearchConfig::quick(p.mode, p.protocol, p.granularity);
                cfg.episodes = p.episodes;
                cfg.warmup = p.warmup;
                cfg.eval_batches = p.eval_batches;
                cfg.seed = spec.seed;
                cfg.relabel = p.relabel;
                if p.paper_scale {
                    cfg = cfg.paper_scale();
                }
                let res = crate::search::run_search_with(
                    &mut self.rt,
                    runner,
                    &data,
                    &cfg,
                    &mut |st, episodes, new_best| obs.episode_done(spec, st, episodes, new_best),
                )?;
                if let Some(out) = &p.out {
                    crate::quant::save_config(out, &spec.model, p.mode, &res.best)?;
                    obs.message(spec, &format!("wrote {}", out.display()));
                }
                JobOutcome::Search { best: res.best, history: res.history }
            }
            JobKind::Finetune { config, steps } => {
                let saved = crate::quant::load_config(config)?;
                if saved.model != spec.model {
                    crate::warn_!(
                        "config {} was searched on {:?}, fine-tuning {:?}",
                        config.display(),
                        saved.model,
                        spec.model
                    );
                }
                let mut runner = self.fresh_runner(&spec.model)?;
                let data = SynthDataset::new(DATA_SEED);
                let before = runner.eval_config(
                    &mut self.rt,
                    saved.mode,
                    &saved.wbits,
                    &saved.abits,
                    &data,
                    Split::Val,
                    2,
                )?;
                let tc = TrainConfig::finetune(saved.mode, saved.wbits, saved.abits, *steps);
                let rep = crate::finetune::train(&mut self.rt, &mut runner, &data, &tc)?;
                JobOutcome::Train {
                    before: Some(before),
                    final_eval: rep.final_eval,
                    curve: rep.curve,
                }
            }
            JobKind::Eval { config, batches } => {
                self.ensure_pretrained(&spec.model)?;
                let runner = self.runners.get(&spec.model).expect("ensured above");
                let data = SynthDataset::new(DATA_SEED);
                let res = match config {
                    None => runner.eval_fp32(&mut self.rt, &data, Split::Val, *batches)?,
                    Some(path) => {
                        let saved = crate::quant::load_config(path)?;
                        runner.eval_config(
                            &mut self.rt,
                            saved.mode,
                            &saved.wbits,
                            &saved.abits,
                            &data,
                            Split::Val,
                            *batches,
                        )?
                    }
                };
                JobOutcome::Eval(res)
            }
            JobKind::Sim { config } => {
                let meta = self.rt.manifest.model(&spec.model)?.clone();
                let (mode, wbits, abits) = match config {
                    None => (Mode::Quant, vec![5u8; meta.w_channels], vec![5u8; meta.a_channels]),
                    Some(path) => {
                        let saved = crate::quant::load_config(path)?;
                        (saved.mode, saved.wbits, saved.abits)
                    }
                };
                let rows = [Arch::Temporal, Arch::Spatial]
                    .iter()
                    .map(|&arch| {
                        let r = FpgaSim::new(arch, mode).run(&meta.layers, &wbits, &abits);
                        SimCell {
                            arch: arch.as_str().to_string(),
                            fps: r.fps,
                            energy_mj: r.energy_j * 1e3,
                            utilization: r.utilization,
                        }
                    })
                    .collect();
                JobOutcome::Sim(rows)
            }
        };
        if let (Some((h0, m0)), Some(cache)) = (cache_snap, &self.eval_cache) {
            let (h1, m1) = cache.counts();
            obs.message(spec, &format!("eval cache: {} hit(s) / {} miss(es)", h1 - h0, m1 - m0));
        }
        let report = JobReport { spec: spec.clone(), secs: t0.elapsed().as_secs_f64(), outcome };
        obs.job_finished(spec, &report);
        Ok(report)
    }
}
