// paper's L3 coordination contribution
