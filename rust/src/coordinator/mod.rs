//! The paper's Layer-3 coordination contribution as a real subsystem: a
//! job-oriented orchestration API over the runtime, model zoo, search,
//! fine-tuning and simulators.
//!
//! - [`Coordinator`] owns the PJRT [`Runtime`], a cache of pre-trained
//!   [`ModelRunner`]s (pre-training on first use) and the artifact-directory
//!   layout — the plumbing every CLI subcommand used to hand-wire itself.
//! - [`JobSpec`] is the builder-validated unit of work
//!   (`JobSpec::search("cif10").mode(..).protocol(..).episodes(40).build()?`).
//! - [`Observer`] streams structured per-episode progress events;
//!   [`JobReport`] is the JSON-serializable result.
//! - [`Sweep`] fans a grid of search jobs across worker threads with
//!   deterministic per-cell seeds (`autoq sweep`).
//!
//! See DESIGN.md §Coordinator for the full API walkthrough.

pub mod job;
pub mod observer;
pub mod report;
pub mod sweep;

pub use job::{granularity_token, init_seed, JobBuilder, JobKind, JobSpec, SearchParams};
pub use observer::{FanOut, LogObserver, NullObserver, Observer};
pub use report::{JobOutcome, JobReport, SimCell};
pub use sweep::{derive_seed, Sweep, SweepResult};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::cost::Mode;
use crate::data::synth::{Split, SynthDataset};
use crate::finetune::TrainConfig;
use crate::models::{ModelRunner, ParamStore};
use crate::runtime::{BackendKind, Manifest, Parallelism, Runtime, RuntimeOpts};
use crate::search::SearchConfig;
use crate::serve::cache::CacheHandle;
use crate::sim::{Arch, FpgaSim};
use crate::util::rng::Rng;

/// Synthetic-dataset seed shared by search/eval/finetune jobs (the
/// testbed's fixed validation data — see DESIGN.md §Substitutions).
pub const DATA_SEED: u64 = 42;

/// SGD steps for pretrain-on-first-use (explicit `pretrain` jobs choose
/// their own step count).
const AUTO_PRETRAIN_STEPS: usize = 300;

/// Calibration batches (train split) for static activation scales — the
/// scales never peek at validation data.
const CALIB_BATCHES: usize = 2;

/// How integer-path evals obtain activation scales (`--act-scales`,
/// `$AUTOQ_ACT_SCALES`): dynamic per-row max scales (the default, exact),
/// or static per-layer scales calibrated once per model at load time
/// (removes the per-row max pass from the eval hot loop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActScaleMode {
    Dynamic,
    Static,
}

impl ActScaleMode {
    /// Resolve from `$AUTOQ_ACT_SCALES` (unset or "dynamic" = Dynamic).
    pub fn from_env() -> ActScaleMode {
        match std::env::var("AUTOQ_ACT_SCALES").ok().as_deref() {
            Some(s) if s.eq_ignore_ascii_case("static") => ActScaleMode::Static,
            Some(s) if !s.trim().is_empty() && !s.eq_ignore_ascii_case("dynamic") => {
                crate::warn_!("ignoring unknown AUTOQ_ACT_SCALES={s:?} (want static|dynamic)");
                ActScaleMode::Dynamic
            }
            _ => ActScaleMode::Dynamic,
        }
    }

    /// Parse a `--act-scales` CLI value.
    pub fn parse(s: &str) -> anyhow::Result<ActScaleMode> {
        match s {
            "static" => Ok(ActScaleMode::Static),
            "dynamic" => Ok(ActScaleMode::Dynamic),
            other => anyhow::bail!("unknown --act-scales {other:?} (want static|dynamic)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ActScaleMode::Dynamic => "dynamic",
            ActScaleMode::Static => "static",
        }
    }
}

/// Resolve the default search checkpoint cadence from
/// `$AUTOQ_CHECKPOINT_EVERY` (unset, empty or 0 = disabled).
fn checkpoint_every_from_env() -> usize {
    match std::env::var("AUTOQ_CHECKPOINT_EVERY").ok() {
        Some(s) if !s.trim().is_empty() => match s.trim().parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                crate::warn_!("ignoring non-numeric AUTOQ_CHECKPOINT_EVERY={s:?}");
                0
            }
        },
        _ => 0,
    }
}

/// Fingerprint of a calibration table (model name + exact f32 bit
/// patterns of the per-layer maxes), keyed into the eval cache so static-
/// and dynamic-scale evals never alias.  Never returns 0 — 0 is the
/// reserved "dynamic scales" fingerprint.
pub fn act_table_fingerprint(model: &str, maxes: &[f32]) -> u64 {
    let mut h = crate::serve::cache::KeyHasher::new();
    h.str(model).u64(maxes.len() as u64);
    for &m in maxes {
        h.u64(m.to_bits() as u64);
    }
    let fp = h.finish();
    if fp == 0 {
        1
    } else {
        fp
    }
}

/// The crate's front door: owns the runtime, the model-runner cache and the
/// artifact layout, and executes [`JobSpec`]s into [`JobReport`]s.
pub struct Coordinator {
    rt: Runtime,
    dir: PathBuf,
    runners: HashMap<String, ModelRunner>,
    /// Content-addressed eval memoization shared with every runner this
    /// coordinator creates (`autoq serve` attaches one per scheduler
    /// worker; `None` = uncached, the historical behavior).
    eval_cache: Option<Arc<CacheHandle>>,
    /// Activation-scale mode for integer-path evals.  Static mode
    /// calibrates per-layer scales in [`Coordinator::ensure_pretrained`];
    /// set it before the first model loads.
    act_scales: ActScaleMode,
    /// Durable-checkpoint cadence for search jobs (DESIGN.md §Durable
    /// jobs): snapshot the full search state every N episodes to
    /// `dir/checkpoints/<job-id>.journal` so a killed search resumes from
    /// its last snapshot.  0 (the default) disables checkpointing.
    checkpoint_every: usize,
}

impl Coordinator {
    /// Default artifact dir: `$AUTOQ_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        Runtime::default_dir()
    }

    /// Open with automatic backend selection (explicit > `$AUTOQ_BACKEND` >
    /// PJRT iff compiled in and artifacts exist > reference).
    pub fn open(dir: &Path) -> anyhow::Result<Coordinator> {
        Self::open_with(dir, None)
    }

    /// Open with an explicit backend choice (`None` = auto-resolve) and
    /// auto-resolved eval parallelism (`$AUTOQ_THREADS`, else all cores).
    pub fn open_with(dir: &Path, backend: Option<BackendKind>) -> anyhow::Result<Coordinator> {
        Self::open_with_opts(dir, backend, None)
    }

    /// Open with explicit backend and worker-thread choices (`None` =
    /// auto-resolve each, mirroring `--backend`/`--threads`).
    pub fn open_with_opts(
        dir: &Path,
        backend: Option<BackendKind>,
        threads: Option<Parallelism>,
    ) -> anyhow::Result<Coordinator> {
        Self::open_full(dir, backend, RuntimeOpts::threads(threads))
    }

    /// Open with the full option set (mirroring
    /// `--backend`/`--threads`/`--shard-workers`; every `None`
    /// auto-resolves).
    pub fn open_full(
        dir: &Path,
        backend: Option<BackendKind>,
        opts: RuntimeOpts,
    ) -> anyhow::Result<Coordinator> {
        let kind = BackendKind::resolve(dir, backend)?;
        let rt = Runtime::open_full(dir, kind, opts)?;
        // The reference backend needs no artifacts, but trained params still
        // persist under the artifact dir — make sure it exists.
        std::fs::create_dir_all(dir)?;
        Ok(Coordinator {
            rt,
            dir: dir.to_path_buf(),
            runners: HashMap::new(),
            eval_cache: None,
            act_scales: ActScaleMode::from_env(),
            checkpoint_every: checkpoint_every_from_env(),
        })
    }

    /// Choose the search checkpoint cadence (mirrors `--checkpoint-every`;
    /// 0 disables).  Overrides `$AUTOQ_CHECKPOINT_EVERY`.
    pub fn set_checkpoint_every(&mut self, every: usize) {
        self.checkpoint_every = every;
    }

    pub fn checkpoint_every(&self) -> usize {
        self.checkpoint_every
    }

    /// Where a search job's durable checkpoint journal lives while the
    /// job runs (removed on successful completion).
    pub fn checkpoint_path(&self, spec: &JobSpec) -> PathBuf {
        self.dir.join("checkpoints").join(format!("{}.journal", spec.id()))
    }

    /// Choose the activation-scale mode (mirrors `--act-scales`).  Call
    /// before the first `ensure_pretrained` — calibration happens at model
    /// load and already-cached runners are not recalibrated.
    pub fn set_act_scale_mode(&mut self, mode: ActScaleMode) {
        self.act_scales = mode;
    }

    pub fn act_scale_mode(&self) -> ActScaleMode {
        self.act_scales
    }

    /// Attach a content-addressed eval cache: every cached and future
    /// runner routes `eval_config` through it.  Results stay byte-identical
    /// — the cache replays exact stored `EvalResult`s — so reports from a
    /// cached run must equal an uncached run's (`tests/eval_cache.rs`).
    pub fn set_eval_cache(&mut self, cache: Arc<CacheHandle>) {
        for runner in self.runners.values_mut() {
            runner.set_eval_cache(Some(cache.clone()));
        }
        self.eval_cache = Some(cache);
    }

    pub fn eval_cache(&self) -> Option<&Arc<CacheHandle>> {
        self.eval_cache.as_ref()
    }

    /// Hand the configured cache (if any) to a runner this coordinator made.
    fn attach_cache(&self, runner: &mut ModelRunner) {
        if let Some(cache) = &self.eval_cache {
            runner.set_eval_cache(Some(cache.clone()));
        }
    }

    pub fn open_default() -> anyhow::Result<Coordinator> {
        Self::open(&Self::default_dir())
    }

    /// Which execution backend this coordinator runs on.
    pub fn backend(&self) -> BackendKind {
        self.rt.backend_kind()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    /// Escape hatch for call sites that drive artifacts directly (repro
    /// internals, benches).
    pub fn runtime(&mut self) -> &mut Runtime {
        &mut self.rt
    }

    /// Where a model's trained parameters persist inside an artifact dir.
    pub fn params_path_in(dir: &Path, model: &str) -> PathBuf {
        dir.join(format!("{model}_trained.apb"))
    }

    /// Where a model's trained parameters persist inside the artifact dir.
    pub fn params_path(&self, model: &str) -> PathBuf {
        Self::params_path_in(&self.dir, model)
    }

    /// Where a model's calibrated activation-scale table persists.
    pub fn act_scales_path(&self, model: &str) -> PathBuf {
        self.dir.join(format!("{model}_act_scales.json"))
    }

    /// Calibrate and install static activation scales for `model` (no-op
    /// in dynamic mode).  Only the reference backend reads the in-process
    /// scale registry, so other backends warn and stay dynamic.  The table
    /// is a pure function of (graph, trained params, calibration batches),
    /// so repeated loads reproduce byte-identical scales and fingerprints.
    fn install_static_scales(
        &mut self,
        model: &str,
        runner: &mut ModelRunner,
    ) -> anyhow::Result<()> {
        if self.act_scales != ActScaleMode::Static {
            return Ok(());
        }
        if self.backend() != BackendKind::Reference {
            crate::warn_!(
                "--act-scales static only calibrates on the reference backend; \
                 {} evals keep dynamic scales",
                self.backend().as_str()
            );
            return Ok(());
        }
        use crate::runtime::reference::{model_exec, zoo};
        let g = zoo::model_graph(model)?;
        let data = SynthDataset::new(DATA_SEED);
        let hw = runner.meta.image_hw;
        let eb = runner.meta.eval_batch;
        let batches: Vec<crate::runtime::Tensor> = (0..CALIB_BATCHES)
            .map(|bi| {
                let b = data.batch(Split::Train, (bi * eb) as u64, eb);
                crate::runtime::Tensor::new(vec![b.n, hw, hw, 3], b.images)
            })
            .collect();
        let params: Vec<&crate::runtime::Tensor> = runner.params.tensors.iter().collect();
        let brefs: Vec<&crate::runtime::Tensor> = batches.iter().collect();
        let maxes = model_exec::calibrate_act_maxes(&g, false, &params, &brefs)?;
        let fp = act_table_fingerprint(model, &maxes);
        self.save_act_scales(model, &maxes, fp)?;
        model_exec::set_act_scales(
            model,
            Some(Arc::new(model_exec::ActScales { maxes, fingerprint: fp })),
        );
        runner.set_calib_fingerprint(fp);
        crate::info!("calibrated static activation scales for {model} (fingerprint {fp:016x})");
        Ok(())
    }

    /// Persist a calibration table next to the trained params: exact f32
    /// bit patterns (not decimal floats), so a reload reproduces the table
    /// and its fingerprint byte-for-byte.
    fn save_act_scales(&self, model: &str, maxes: &[f32], fp: u64) -> anyhow::Result<()> {
        use crate::util::json::Json;
        let bits: Vec<Json> = maxes.iter().map(|&m| Json::Num(m.to_bits() as f64)).collect();
        let v = Json::obj(vec![
            ("model", Json::from(model)),
            ("fingerprint", Json::from(format!("{fp:016x}"))),
            ("maxes_bits", Json::Arr(bits)),
        ]);
        std::fs::write(self.act_scales_path(model), format!("{v}\n"))?;
        Ok(())
    }

    /// Load `model` into the runner cache, pre-training and persisting the
    /// params on first use (the logic formerly duplicated across
    /// `cmd_pretrain`, `load_runner` and `repro::runner_for`).
    pub fn ensure_pretrained(&mut self, model: &str) -> anyhow::Result<()> {
        if self.runners.contains_key(model) {
            return Ok(());
        }
        let meta = self.rt.manifest.model(model)?.clone();
        let path = self.params_path(model);
        let mut runner = if path.exists() {
            ModelRunner::new(meta, ParamStore::load(&path)?)?
        } else {
            crate::info!("no trained params for {model}; pre-training now ({AUTO_PRETRAIN_STEPS} steps)");
            let mut r = ModelRunner::init(meta, &mut Rng::new(init_seed(model)));
            let data = SynthDataset::new(DATA_SEED);
            let cfg = TrainConfig::pretrain_for(model, AUTO_PRETRAIN_STEPS);
            let rep = crate::finetune::train(&mut self.rt, &mut r, &data, &cfg)?;
            crate::info!("pretrained {model}: acc={:.4}", rep.final_eval.accuracy);
            r.params.save(&path)?;
            r
        };
        self.attach_cache(&mut runner);
        self.install_static_scales(model, &mut runner)?;
        self.runners.insert(model.to_string(), runner);
        Ok(())
    }

    /// Owned copy of the cached pre-trained runner (fresh zero momenta) —
    /// for callers that mutate params, e.g. fine-tuning.
    pub fn fresh_runner(&mut self, model: &str) -> anyhow::Result<ModelRunner> {
        self.ensure_pretrained(model)?;
        let cached = self.runners.get(model).expect("ensured above");
        let mut runner = ModelRunner::new(cached.meta.clone(), cached.params.clone())?;
        runner.set_calib_fingerprint(cached.calib_fingerprint());
        self.attach_cache(&mut runner);
        Ok(runner)
    }

    /// Run a job with default stderr logging.
    pub fn run(&mut self, spec: &JobSpec) -> anyhow::Result<JobReport> {
        let mut obs = LogObserver::default();
        self.run_observed(spec, &mut obs)
    }

    /// Run a job, streaming progress into `obs`.
    pub fn run_observed(
        &mut self,
        spec: &JobSpec,
        obs: &mut dyn Observer,
    ) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        obs.job_started(spec);
        // Snapshot cache counters so the per-job delta can be surfaced as
        // an observer message (never in the JobReport itself — report JSON
        // must stay byte-identical between cached and uncached runs).
        let cache_snap = self.eval_cache.as_ref().map(|c| c.counts());
        let outcome = match &spec.kind {
            JobKind::Pretrain { steps, data_seed, persist } => {
                let meta = self.rt.manifest.model(&spec.model)?.clone();
                let mut runner = ModelRunner::init(meta, &mut Rng::new(spec.seed));
                self.attach_cache(&mut runner);
                let data = SynthDataset::new(*data_seed);
                let cfg = TrainConfig::pretrain_for(&spec.model, *steps);
                let rep = crate::finetune::train(&mut self.rt, &mut runner, &data, &cfg)?;
                if *persist {
                    let path = self.params_path(&spec.model);
                    runner.params.save(&path)?;
                    obs.message(spec, &format!("saved {}", path.display()));
                }
                self.runners.insert(spec.model.clone(), runner);
                JobOutcome::Train { before: None, final_eval: rep.final_eval, curve: rep.curve }
            }
            JobKind::Search(p) => {
                self.ensure_pretrained(&spec.model)?;
                let runner = self.runners.get(&spec.model).expect("ensured above");
                let data = SynthDataset::new(DATA_SEED);
                let mut cfg = SearchConfig::quick(p.mode, p.protocol, p.granularity);
                cfg.episodes = p.episodes;
                cfg.warmup = p.warmup;
                cfg.eval_batches = p.eval_batches;
                cfg.seed = spec.seed;
                cfg.relabel = p.relabel;
                if p.paper_scale {
                    cfg = cfg.paper_scale();
                }
                if self.checkpoint_every > 0 {
                    cfg.checkpoint = Some(crate::search::Checkpoint {
                        path: self.checkpoint_path(spec),
                        every: self.checkpoint_every,
                    });
                }
                let res = crate::search::run_search_with(
                    &mut self.rt,
                    runner,
                    &data,
                    &cfg,
                    &mut |st, episodes, new_best| obs.episode_done(spec, st, episodes, new_best),
                )?;
                if let Some(out) = &p.out {
                    crate::quant::save_config(out, &spec.model, p.mode, &res.best)?;
                    obs.message(spec, &format!("wrote {}", out.display()));
                }
                JobOutcome::Search { best: res.best, history: res.history }
            }
            JobKind::Finetune { config, steps } => {
                let saved = crate::quant::load_config(config)?;
                if saved.model != spec.model {
                    crate::warn_!(
                        "config {} was searched on {:?}, fine-tuning {:?}",
                        config.display(),
                        saved.model,
                        spec.model
                    );
                }
                let mut runner = self.fresh_runner(&spec.model)?;
                let data = SynthDataset::new(DATA_SEED);
                let before = runner.eval_config(
                    &mut self.rt,
                    saved.mode,
                    &saved.wbits,
                    &saved.abits,
                    &data,
                    Split::Val,
                    2,
                )?;
                let tc = TrainConfig::finetune(saved.mode, saved.wbits, saved.abits, *steps);
                let rep = crate::finetune::train(&mut self.rt, &mut runner, &data, &tc)?;
                JobOutcome::Train {
                    before: Some(before),
                    final_eval: rep.final_eval,
                    curve: rep.curve,
                }
            }
            JobKind::Eval { config, batches } => {
                self.ensure_pretrained(&spec.model)?;
                let runner = self.runners.get(&spec.model).expect("ensured above");
                let data = SynthDataset::new(DATA_SEED);
                let res = match config {
                    None => runner.eval_fp32(&mut self.rt, &data, Split::Val, *batches)?,
                    Some(path) => {
                        let saved = crate::quant::load_config(path)?;
                        runner.eval_config(
                            &mut self.rt,
                            saved.mode,
                            &saved.wbits,
                            &saved.abits,
                            &data,
                            Split::Val,
                            *batches,
                        )?
                    }
                };
                JobOutcome::Eval(res)
            }
            JobKind::Sim { config } => {
                let meta = self.rt.manifest.model(&spec.model)?.clone();
                let (mode, wbits, abits) = match config {
                    None => (Mode::Quant, vec![5u8; meta.w_channels], vec![5u8; meta.a_channels]),
                    Some(path) => {
                        let saved = crate::quant::load_config(path)?;
                        (saved.mode, saved.wbits, saved.abits)
                    }
                };
                let rows = [Arch::Temporal, Arch::Spatial]
                    .iter()
                    .map(|&arch| {
                        let r = FpgaSim::new(arch, mode).run(&meta.layers, &wbits, &abits);
                        SimCell {
                            arch: arch.as_str().to_string(),
                            fps: r.fps,
                            energy_mj: r.energy_j * 1e3,
                            utilization: r.utilization,
                        }
                    })
                    .collect();
                JobOutcome::Sim(rows)
            }
        };
        if let (Some((h0, m0)), Some(cache)) = (cache_snap, &self.eval_cache) {
            let (h1, m1) = cache.counts();
            obs.message(spec, &format!("eval cache: {} hit(s) / {} miss(es)", h1 - h0, m1 - m0));
        }
        let report = JobReport { spec: spec.clone(), secs: t0.elapsed().as_secs_f64(), outcome };
        obs.job_finished(spec, &report);
        Ok(report)
    }
}
