//! Job specifications: the coordinator's unit of work.
//!
//! A [`JobSpec`] describes one run — pretrain / search / finetune / eval /
//! sim — independent of the runtime that executes it, so the same spec can
//! be run serially (`Coordinator::run`) or fanned out by the
//! [`Sweep`](crate::coordinator::Sweep) scheduler.  Specs are constructed
//! through the builder (`JobSpec::search("cif10").mode(..).episodes(..)…`)
//! and validated once at `build()` time; a spec that builds always names a
//! well-formed job.

use std::path::PathBuf;

use crate::cost::Mode;
use crate::search::{Granularity, Protocol, ProtocolKind};
use crate::util::json::Json;

/// Deterministic parameter-init seed for a zoo model — the single home of
/// the `0xA0_70 ^ len` rule that `cmd_pretrain` and `load_runner` used to
/// duplicate.
pub fn init_seed(model: &str) -> u64 {
    0xA0_70_u64 ^ model.len() as u64
}

/// File-name-safe granularity token ("n5" | "l" | "c") used in job ids and
/// sweep cell keys.
pub fn granularity_token(g: Granularity) -> String {
    match g {
        Granularity::Network(b) => format!("n{b}"),
        Granularity::Layer => "l".to_string(),
        Granularity::Channel => "c".to_string(),
    }
}

/// Search-job parameters (mirrors `SearchConfig` plus artifact plumbing).
#[derive(Debug, Clone)]
pub struct SearchParams {
    pub mode: Mode,
    pub protocol: Protocol,
    pub granularity: Granularity,
    pub episodes: usize,
    pub warmup: usize,
    pub eval_batches: usize,
    pub relabel: bool,
    pub paper_scale: bool,
    /// Write the best searched config here (`quant::save_config` JSON).
    pub out: Option<PathBuf>,
}

#[derive(Debug, Clone)]
pub enum JobKind {
    /// Train a zoo model from a seeded init; `persist` saves the params to
    /// the artifact dir (throwaway drivers opt out to keep saved params).
    Pretrain { steps: usize, data_seed: u64, persist: bool },
    /// Hierarchical bit-width search for one (model, mode, protocol,
    /// granularity) cell.
    Search(SearchParams),
    /// Fine-tune a searched config (fresh copy of the pre-trained params).
    Finetune { config: PathBuf, steps: usize },
    /// Evaluate fp32 (no config) or a searched config.
    Eval { config: Option<PathBuf>, batches: usize },
    /// FPGA simulator report for a config (uniform 5-bit if none given).
    Sim { config: Option<PathBuf> },
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::Pretrain { .. } => "pretrain",
            JobKind::Search(_) => "search",
            JobKind::Finetune { .. } => "finetune",
            JobKind::Eval { .. } => "eval",
            JobKind::Sim { .. } => "sim",
        }
    }
}

/// A validated job. Construct through the `JobSpec::search(..)`-style
/// builder entry points.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub model: String,
    /// Agent seed for searches, param-init seed for pretraining.
    pub seed: u64,
    pub kind: JobKind,
}

impl JobSpec {
    pub fn search(model: &str) -> JobBuilder {
        JobBuilder::new(model, Tag::Search)
    }
    pub fn pretrain(model: &str) -> JobBuilder {
        JobBuilder::new(model, Tag::Pretrain)
    }
    pub fn finetune(model: &str, config: impl Into<PathBuf>) -> JobBuilder {
        let mut b = JobBuilder::new(model, Tag::Finetune);
        b.config = Some(config.into());
        b
    }
    pub fn eval(model: &str) -> JobBuilder {
        JobBuilder::new(model, Tag::Eval)
    }
    pub fn sim(model: &str) -> JobBuilder {
        JobBuilder::new(model, Tag::Sim)
    }

    /// Stable, file-name-safe identity (used for report files and logs).
    pub fn id(&self) -> String {
        match &self.kind {
            JobKind::Pretrain { .. } => format!("pretrain_{}_s{}", self.model, self.seed),
            JobKind::Search(p) => format!(
                "search_{}_{}_{}_{}_s{}",
                self.model,
                p.mode.as_str(),
                p.protocol.tag(),
                granularity_token(p.granularity),
                self.seed
            ),
            JobKind::Finetune { .. } => format!("finetune_{}_s{}", self.model, self.seed),
            JobKind::Eval { config, .. } => format!(
                "eval_{}_{}_s{}",
                self.model,
                if config.is_some() { "cfg" } else { "fp32" },
                self.seed
            ),
            JobKind::Sim { .. } => format!("sim_{}_s{}", self.model, self.seed),
        }
    }

    /// Seeds serialize as decimal strings: the JSON substrate stores numbers
    /// as f64, which would silently round u64 seeds above 2^53.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("model", self.model.as_str().into()),
            ("kind", self.kind.name().into()),
            ("seed", self.seed.to_string().into()),
        ];
        match &self.kind {
            JobKind::Pretrain { steps, data_seed, persist } => {
                pairs.push(("steps", (*steps).into()));
                pairs.push(("data_seed", data_seed.to_string().into()));
                pairs.push(("persist", (*persist).into()));
            }
            JobKind::Search(p) => {
                pairs.push(("mode", p.mode.as_str().into()));
                pairs.push(("protocol", p.protocol.tag().into()));
                pairs.push(("granularity", granularity_token(p.granularity).into()));
                pairs.push(("episodes", p.episodes.into()));
                pairs.push(("warmup", p.warmup.into()));
                pairs.push(("eval_batches", p.eval_batches.into()));
                pairs.push(("relabel", p.relabel.into()));
                pairs.push(("paper_scale", p.paper_scale.into()));
                if p.protocol.kind == ProtocolKind::ResourceConstrained {
                    pairs.push(("target_bits", p.protocol.target_bits.into()));
                }
            }
            JobKind::Finetune { config, steps } => {
                pairs.push(("config", config.display().to_string().into()));
                pairs.push(("steps", (*steps).into()));
            }
            JobKind::Eval { config, batches } => {
                if let Some(c) = config {
                    pairs.push(("config", c.display().to_string().into()));
                }
                pairs.push(("batches", (*batches).into()));
            }
            JobKind::Sim { config } => {
                if let Some(c) = config {
                    pairs.push(("config", c.display().to_string().into()));
                }
            }
        }
        Json::obj(pairs)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Tag {
    Pretrain,
    Search,
    Finetune,
    Eval,
    Sim,
}

/// Builder for [`JobSpec`]; setters irrelevant to the job kind are ignored
/// at `build()`.  Defaults mirror `SearchConfig::quick` and the historical
/// CLI defaults.
#[derive(Debug, Clone)]
pub struct JobBuilder {
    model: String,
    tag: Tag,
    mode: Mode,
    protocol: Protocol,
    granularity: Granularity,
    episodes: usize,
    warmup: usize,
    eval_batches: usize,
    seed: Option<u64>,
    data_seed: u64,
    steps: usize,
    relabel: bool,
    paper_scale: bool,
    config: Option<PathBuf>,
    batches: usize,
    out: Option<PathBuf>,
    persist: bool,
    target_bits: Option<f64>,
}

impl JobBuilder {
    fn new(model: &str, tag: Tag) -> JobBuilder {
        JobBuilder {
            model: model.to_string(),
            tag,
            mode: Mode::Quant,
            protocol: Protocol::resource_constrained(5.0),
            granularity: Granularity::Channel,
            episodes: 40,
            warmup: 10,
            eval_batches: 2,
            seed: None,
            data_seed: 42,
            steps: match tag {
                Tag::Pretrain => 300,
                Tag::Finetune => 200,
                _ => 0,
            },
            relabel: true,
            paper_scale: false,
            config: None,
            batches: 4,
            out: None,
            persist: true,
            target_bits: None,
        }
    }

    pub fn mode(mut self, mode: Mode) -> Self {
        self.mode = mode;
        self
    }
    pub fn protocol(mut self, protocol: Protocol) -> Self {
        self.protocol = protocol;
        self
    }
    pub fn granularity(mut self, granularity: Granularity) -> Self {
        self.granularity = granularity;
        self
    }
    pub fn episodes(mut self, episodes: usize) -> Self {
        self.episodes = episodes;
        self
    }
    pub fn warmup(mut self, warmup: usize) -> Self {
        self.warmup = warmup;
        self
    }
    pub fn eval_batches(mut self, eval_batches: usize) -> Self {
        self.eval_batches = eval_batches;
        self
    }
    /// Agent seed (search) / param-init seed (pretrain).  Defaults to 1 for
    /// searches and `init_seed(model)` for pretraining.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }
    /// Synthetic-dataset seed (pretrain jobs).
    pub fn data_seed(mut self, data_seed: u64) -> Self {
        self.data_seed = data_seed;
        self
    }
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }
    pub fn relabel(mut self, relabel: bool) -> Self {
        self.relabel = relabel;
        self
    }
    pub fn paper_scale(mut self, paper_scale: bool) -> Self {
        self.paper_scale = paper_scale;
        self
    }
    /// B̄ for Algorithm 1 (resource-constrained protocol only).  Applied at
    /// `build()`, so it composes with `.protocol(..)` in either order.
    pub fn target_bits(mut self, target_bits: f64) -> Self {
        self.target_bits = Some(target_bits);
        self
    }
    pub fn config(mut self, config: impl Into<PathBuf>) -> Self {
        self.config = Some(config.into());
        self
    }
    pub fn batches(mut self, batches: usize) -> Self {
        self.batches = batches;
        self
    }
    pub fn out(mut self, out: impl Into<PathBuf>) -> Self {
        self.out = Some(out.into());
        self
    }
    /// Whether a pretrain job saves its params to the artifact dir
    /// (default true; false keeps existing saved params untouched).
    pub fn persist(mut self, persist: bool) -> Self {
        self.persist = persist;
        self
    }

    /// Validate and freeze into a [`JobSpec`].
    pub fn build(self) -> anyhow::Result<JobSpec> {
        anyhow::ensure!(!self.model.trim().is_empty(), "job needs a non-empty model name");
        let kind = match self.tag {
            Tag::Pretrain => {
                anyhow::ensure!(self.steps > 0, "pretrain needs steps > 0");
                JobKind::Pretrain {
                    steps: self.steps,
                    data_seed: self.data_seed,
                    persist: self.persist,
                }
            }
            Tag::Search => {
                anyhow::ensure!(self.episodes > 0, "search needs episodes > 0");
                anyhow::ensure!(
                    self.warmup <= self.episodes,
                    "warmup {} exceeds episodes {}",
                    self.warmup,
                    self.episodes
                );
                anyhow::ensure!(self.eval_batches > 0, "search needs eval_batches > 0");
                if let Granularity::Network(b) = self.granularity {
                    anyhow::ensure!(
                        (1..=32).contains(&b),
                        "network granularity bits must be in 1..=32, got {b}"
                    );
                }
                let mut protocol = self.protocol;
                if let Some(tb) = self.target_bits {
                    protocol.target_bits = tb;
                }
                if protocol.kind == ProtocolKind::ResourceConstrained {
                    anyhow::ensure!(
                        protocol.target_bits > 0.0 && protocol.target_bits <= 32.0,
                        "resource-constrained target_bits must be in (0, 32], got {}",
                        protocol.target_bits
                    );
                }
                JobKind::Search(SearchParams {
                    mode: self.mode,
                    protocol,
                    granularity: self.granularity,
                    episodes: self.episodes,
                    warmup: self.warmup,
                    eval_batches: self.eval_batches,
                    relabel: self.relabel,
                    paper_scale: self.paper_scale,
                    out: self.out.clone(),
                })
            }
            Tag::Finetune => {
                let config = self
                    .config
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("finetune needs a searched-config path"))?;
                anyhow::ensure!(self.steps > 0, "finetune needs steps > 0");
                JobKind::Finetune { config, steps: self.steps }
            }
            Tag::Eval => {
                anyhow::ensure!(self.batches > 0, "eval needs batches > 0");
                JobKind::Eval { config: self.config.clone(), batches: self.batches }
            }
            Tag::Sim => JobKind::Sim { config: self.config.clone() },
        };
        let seed = self.seed.unwrap_or(match self.tag {
            Tag::Pretrain => init_seed(&self.model),
            _ => 1,
        });
        Ok(JobSpec { model: self.model, seed, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_builder_defaults_and_id() {
        let spec = JobSpec::search("cif10")
            .mode(Mode::Quant)
            .protocol(Protocol::resource_constrained(5.0))
            .granularity(Granularity::Channel)
            .episodes(40)
            .build()
            .unwrap();
        assert_eq!(spec.model, "cif10");
        assert_eq!(spec.seed, 1);
        assert_eq!(spec.id(), "search_cif10_quant_rc_c_s1");
        let JobKind::Search(p) = &spec.kind else { panic!("wrong kind") };
        assert_eq!(p.warmup, 10);
        assert_eq!(p.eval_batches, 2);
        assert!(p.relabel);
    }

    #[test]
    fn empty_model_rejected() {
        assert!(JobSpec::search("").episodes(10).build().is_err());
        assert!(JobSpec::pretrain("  ").build().is_err());
    }

    #[test]
    fn zero_episodes_rejected() {
        assert!(JobSpec::search("cif10").episodes(0).build().is_err());
    }

    #[test]
    fn warmup_beyond_episodes_rejected() {
        assert!(JobSpec::search("cif10").episodes(5).warmup(6).build().is_err());
        assert!(JobSpec::search("cif10").episodes(5).warmup(5).build().is_ok());
    }

    #[test]
    fn bad_granularity_bits_rejected() {
        assert!(JobSpec::search("cif10")
            .granularity(Granularity::Network(0))
            .build()
            .is_err());
        assert!(JobSpec::search("cif10")
            .granularity(Granularity::Network(33))
            .build()
            .is_err());
        assert!(JobSpec::search("cif10")
            .granularity(Granularity::Network(5))
            .build()
            .is_ok());
    }

    #[test]
    fn bad_rc_target_bits_rejected() {
        assert!(JobSpec::search("cif10").target_bits(0.0).build().is_err());
        assert!(JobSpec::search("cif10").target_bits(64.0).build().is_err());
        // AG ignores target_bits, so the same value is fine there.
        assert!(JobSpec::search("cif10")
            .protocol(Protocol::accuracy_guaranteed())
            .build()
            .is_ok());
    }

    #[test]
    fn target_bits_applies_regardless_of_setter_order() {
        for spec in [
            JobSpec::search("cif10")
                .target_bits(4.0)
                .protocol(Protocol::resource_constrained(5.0))
                .build()
                .unwrap(),
            JobSpec::search("cif10")
                .protocol(Protocol::resource_constrained(5.0))
                .target_bits(4.0)
                .build()
                .unwrap(),
        ] {
            let JobKind::Search(p) = &spec.kind else { panic!("wrong kind") };
            assert_eq!(p.protocol.target_bits, 4.0);
        }
    }

    #[test]
    fn finetune_and_eval_validation() {
        assert!(JobSpec::finetune("cif10", "cfg.json").steps(0).build().is_err());
        assert!(JobSpec::finetune("cif10", "cfg.json").build().is_ok());
        assert!(JobSpec::eval("cif10").batches(0).build().is_err());
        assert!(JobSpec::eval("cif10").build().is_ok());
        assert!(JobSpec::pretrain("cif10").steps(0).build().is_err());
    }

    #[test]
    fn pretrain_seed_defaults_to_init_seed() {
        let spec = JobSpec::pretrain("cif10").build().unwrap();
        assert_eq!(spec.seed, init_seed("cif10"));
        let spec = JobSpec::pretrain("cif10").seed(7).build().unwrap();
        assert_eq!(spec.seed, 7);
    }

    #[test]
    fn spec_json_is_parseable_and_typed() {
        let spec = JobSpec::search("cif10")
            .granularity(Granularity::Network(5))
            .seed(9)
            .build()
            .unwrap();
        let j = crate::util::json::Json::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(j.req("kind").unwrap().as_str(), Some("search"));
        assert_eq!(j.req("granularity").unwrap().as_str(), Some("n5"));
        assert_eq!(j.req("seed").unwrap().as_str(), Some("9"));
        assert_eq!(j.req("target_bits").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn huge_seeds_survive_json_exactly() {
        let spec = JobSpec::search("cif10").seed(u64::MAX - 1).build().unwrap();
        let j = crate::util::json::Json::parse(&spec.to_json().to_string()).unwrap();
        let back: u64 = j.req("seed").unwrap().as_str().unwrap().parse().unwrap();
        assert_eq!(back, u64::MAX - 1);
    }
}
