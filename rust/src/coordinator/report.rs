//! Structured job results: one [`JobReport`] type unifying what used to be
//! four ad-hoc shapes (`SearchResult`, `TrainReport`, `EvalResult` and the
//! printed sim table), JSON-serializable through the crate's own `Json`
//! substrate so sweeps can emit one machine-readable file per cell.

use std::path::Path;

use crate::coordinator::job::JobSpec;
use crate::models::EvalResult;
use crate::search::{EpisodeOutcome, EpisodeStats};
use crate::util::json::Json;

/// One simulated accelerator row (per `sim::Arch`).
#[derive(Debug, Clone)]
pub struct SimCell {
    pub arch: String,
    pub fps: f64,
    pub energy_mj: f64,
    pub utilization: f64,
}

/// Kind-specific payload of a finished job.
#[derive(Debug, Clone)]
pub enum JobOutcome {
    Search { best: EpisodeOutcome, history: Vec<EpisodeStats> },
    /// Pretrain and finetune; `before` is the pre-finetune eval when the
    /// job fine-tuned an existing config.
    Train { before: Option<EvalResult>, final_eval: EvalResult, curve: Vec<(usize, f32)> },
    Eval(EvalResult),
    Sim(Vec<SimCell>),
}

/// A finished job: the spec that ran, wall-clock, and its outcome.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub spec: JobSpec,
    pub secs: f64,
    pub outcome: JobOutcome,
}

impl JobReport {
    pub fn id(&self) -> String {
        self.spec.id()
    }

    /// Serialize as `{id, secs, spec: {...}, <kind>: {...}}`.
    pub fn to_json(&self) -> Json {
        let outcome = match &self.outcome {
            JobOutcome::Search { best, history } => Json::obj(vec![
                ("accuracy", best.accuracy.into()),
                ("loss", best.loss.into()),
                ("reward", best.reward.into()),
                ("score", best.score.into()),
                ("norm_logic", best.cost.norm_logic().into()),
                ("avg_wbits", best.avg_wbits.into()),
                ("avg_abits", best.avg_abits.into()),
                (
                    "wbits",
                    Json::Arr(best.wbits.iter().map(|&b| Json::Num(b as f64)).collect()),
                ),
                (
                    "abits",
                    Json::Arr(best.abits.iter().map(|&b| Json::Num(b as f64)).collect()),
                ),
                (
                    "history",
                    Json::Arr(
                        history
                            .iter()
                            .map(|st| {
                                Json::obj(vec![
                                    ("episode", st.episode.into()),
                                    ("accuracy", st.accuracy.into()),
                                    ("reward", st.reward.into()),
                                    ("avg_wbits", st.avg_wbits.into()),
                                    ("avg_abits", st.avg_abits.into()),
                                    ("norm_logic", st.norm_logic.into()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            JobOutcome::Train { before, final_eval, curve } => {
                let mut pairs: Vec<(&str, Json)> = vec![
                    ("accuracy", final_eval.accuracy.into()),
                    ("loss", final_eval.loss.into()),
                    ("images", final_eval.images.into()),
                    (
                        "curve",
                        Json::Arr(
                            curve
                                .iter()
                                .map(|&(s, l)| {
                                    Json::Arr(vec![Json::Num(s as f64), Json::Num(l as f64)])
                                })
                                .collect(),
                        ),
                    ),
                ];
                if let Some(b) = before {
                    pairs.push(("accuracy_before", b.accuracy.into()));
                }
                Json::obj(pairs)
            }
            JobOutcome::Eval(e) => Json::obj(vec![
                ("accuracy", e.accuracy.into()),
                ("loss", e.loss.into()),
                ("images", e.images.into()),
            ]),
            JobOutcome::Sim(rows) => Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("arch", r.arch.as_str().into()),
                            ("fps", r.fps.into()),
                            ("energy_mj", r.energy_mj.into()),
                            ("utilization", r.utilization.into()),
                        ])
                    })
                    .collect(),
            ),
        };
        Json::obj(vec![
            ("id", self.id().into()),
            ("secs", self.secs.into()),
            ("spec", self.spec.to_json()),
            (self.spec.kind.name(), outcome),
        ])
    }

    /// Write the JSON form to `path`.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::logic::model_cost;
    use crate::search::LayerBits;

    #[test]
    fn eval_report_serializes() {
        let report = JobReport {
            spec: JobSpec::eval("cif10").batches(2).build().unwrap(),
            secs: 1.25,
            outcome: JobOutcome::Eval(EvalResult { accuracy: 0.9, loss: 0.4, images: 512 }),
        };
        let j = Json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.req("id").unwrap().as_str(), Some("eval_cif10_fp32_s1"));
        let e = j.req("eval").unwrap();
        assert_eq!(e.req("images").unwrap().as_usize(), Some(512));
        assert!((e.req("accuracy").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-12);
        assert_eq!(j.req("spec").unwrap().req("kind").unwrap().as_str(), Some("eval"));
    }

    #[test]
    fn search_report_serializes_config_and_history() {
        let best = EpisodeOutcome {
            wbits: vec![4, 5],
            abits: vec![3],
            accuracy: 0.8,
            loss: 0.5,
            cost: model_cost(&[], &[], &[]),
            reward: 0.7,
            score: 12.0,
            per_layer: vec![LayerBits { name: "l01_conv".into(), avg_w: 4.5, avg_a: 3.0 }],
            avg_wbits: 4.5,
            avg_abits: 3.0,
        };
        let history = vec![EpisodeStats {
            episode: 0,
            accuracy: 0.8,
            reward: 0.7,
            avg_wbits: 4.5,
            avg_abits: 3.0,
            norm_logic: 0.1,
        }];
        let report = JobReport {
            spec: JobSpec::search("cif10").episodes(1).warmup(0).seed(3).build().unwrap(),
            secs: 2.0,
            outcome: JobOutcome::Search { best, history },
        };
        let j = Json::parse(&report.to_json().to_string()).unwrap();
        let s = j.req("search").unwrap();
        assert_eq!(s.req("wbits").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(s.req("history").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.req("spec").unwrap().req("seed").unwrap().as_str(), Some("3"));
    }
}
