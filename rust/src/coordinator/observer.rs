//! Progress observation: structured per-episode events instead of the
//! ad-hoc `info!`/`println!` calls that used to live inside the search
//! runner.  Implement [`Observer`] to stream progress into a UI, a log
//! aggregator or a test harness; [`LogObserver`] reproduces the historical
//! stderr logging, [`NullObserver`] drops everything.

use crate::coordinator::job::JobSpec;
use crate::coordinator::report::JobReport;
use crate::search::EpisodeStats;

/// Receives coordinator job lifecycle + per-episode progress events.  All
/// methods default to no-ops so implementors subscribe only to what they
/// need.
pub trait Observer {
    fn job_started(&mut self, _job: &JobSpec) {}
    /// One search episode finished.  `episodes` is the planned total;
    /// `new_best` marks episodes that improved the best reward so far.
    fn episode_done(
        &mut self,
        _job: &JobSpec,
        _stats: &EpisodeStats,
        _episodes: usize,
        _new_best: bool,
    ) {
    }
    /// Free-form progress note (artifact written, cache hit, …).
    fn message(&mut self, _job: &JobSpec, _text: &str) {}
    fn job_finished(&mut self, _job: &JobSpec, _report: &JobReport) {}
}

/// Discards every event.
pub struct NullObserver;

impl Observer for NullObserver {}

/// Forwards every event to each inner observer, in order — the serve
/// daemon tees job progress into stderr logging *and* the wire-event
/// stream with one of these.
pub struct FanOut<'a> {
    observers: Vec<&'a mut dyn Observer>,
}

impl<'a> FanOut<'a> {
    pub fn new(observers: Vec<&'a mut dyn Observer>) -> FanOut<'a> {
        FanOut { observers }
    }
}

impl Observer for FanOut<'_> {
    fn job_started(&mut self, job: &JobSpec) {
        for obs in &mut self.observers {
            obs.job_started(job);
        }
    }

    fn episode_done(&mut self, job: &JobSpec, stats: &EpisodeStats, episodes: usize, new_best: bool) {
        for obs in &mut self.observers {
            obs.episode_done(job, stats, episodes, new_best);
        }
    }

    fn message(&mut self, job: &JobSpec, text: &str) {
        for obs in &mut self.observers {
            obs.message(job, text);
        }
    }

    fn job_finished(&mut self, job: &JobSpec, report: &JobReport) {
        for obs in &mut self.observers {
            obs.job_finished(job, report);
        }
    }
}

/// Logs events through the crate logger (stderr), tagged with the job id —
/// the default observer for `Coordinator::run` and sweep workers.
#[derive(Debug, Clone)]
pub struct LogObserver {
    /// Log every n-th episode at info level (new bests always log at debug).
    pub every: usize,
}

impl Default for LogObserver {
    fn default() -> Self {
        LogObserver { every: 10 }
    }
}

impl Observer for LogObserver {
    fn job_started(&mut self, job: &JobSpec) {
        crate::info!("[{}] started", job.id());
    }

    fn episode_done(&mut self, job: &JobSpec, stats: &EpisodeStats, episodes: usize, new_best: bool) {
        crate::search::log_episode_progress(&job.id(), self.every, stats, episodes, new_best);
    }

    fn message(&mut self, job: &JobSpec, text: &str) {
        crate::info!("[{}] {text}", job.id());
    }

    fn job_finished(&mut self, job: &JobSpec, report: &JobReport) {
        crate::info!("[{}] finished in {:.1}s", job.id(), report.secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Records event order — also proves the trait is object-safe and
    /// implementable outside the crate's defaults.
    #[derive(Default)]
    struct Recorder {
        events: Vec<String>,
    }

    impl Observer for Recorder {
        fn job_started(&mut self, job: &JobSpec) {
            self.events.push(format!("start:{}", job.id()));
        }
        fn message(&mut self, _job: &JobSpec, text: &str) {
            self.events.push(format!("msg:{text}"));
        }
    }

    #[test]
    fn fanout_forwards_to_every_observer_in_order() {
        let spec = JobSpec::eval("cif10").build().unwrap();
        let mut a = Recorder::default();
        let mut b = Recorder::default();
        {
            let mut fan = FanOut::new(vec![&mut a, &mut b]);
            fan.job_started(&spec);
            fan.message(&spec, "x");
        }
        let want = vec!["start:eval_cif10_fp32_s1".to_string(), "msg:x".into()];
        assert_eq!(a.events, want);
        assert_eq!(b.events, want);
    }

    #[test]
    fn custom_observer_receives_events() {
        let spec = JobSpec::eval("cif10").build().unwrap();
        let mut rec = Recorder::default();
        let obs: &mut dyn Observer = &mut rec;
        obs.job_started(&spec);
        obs.message(&spec, "hello");
        // Default no-op methods must not panic.
        obs.episode_done(
            &spec,
            &EpisodeStats {
                episode: 0,
                accuracy: 0.5,
                reward: 0.1,
                avg_wbits: 5.0,
                avg_abits: 5.0,
                norm_logic: 0.2,
            },
            1,
            true,
        );
        assert_eq!(rec.events, vec!["start:eval_cif10_fp32_s1".to_string(), "msg:hello".into()]);
    }
}
