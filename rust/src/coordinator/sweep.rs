//! Sweep scheduler: fan a grid of search jobs (models × modes × protocols ×
//! granularities) across worker threads.
//!
//! Each worker owns its own `Coordinator` (and therefore its own PJRT
//! runtime — executables are not shared across threads); jobs are pulled
//! from a shared atomic cursor.  Per-job seeds are derived deterministically
//! from the base seed and the cell coordinates, so any sweep cell can be
//! reproduced bit-for-bit with a serial `autoq search --seed <job seed>`
//! invocation.  Model pre-training happens once, serially, before workers
//! spawn — workers only ever read the persisted params.
//!
//! Outer per-cell workers compose with the reference backend's inner
//! per-batch eval threads: unless `threads` pins a per-worker budget, the
//! machine's thread budget is split evenly across workers (never below
//! one thread each) so the grid never oversubscribes cores.  With the
//! shard backend each worker's budget is in turn the total its process
//! pool splits, so `cells × processes × threads` stays inside the same
//! machine budget.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::job::{granularity_token, JobSpec};
use crate::coordinator::observer::LogObserver;
use crate::coordinator::report::JobReport;
use crate::coordinator::Coordinator;
use crate::cost::Mode;
use crate::journal::{fingerprint, DurableLog};
use crate::runtime::{BackendKind, Parallelism, RuntimeOpts};
use crate::search::{Granularity, Protocol, ProtocolKind};

/// Cell-key token for a protocol: unlike `Protocol::tag`, distinguishes
/// resource-constrained protocols by their bit budget so rc@4 and rc@5
/// cells get distinct seeds and report files.
fn protocol_cell_token(p: &Protocol) -> String {
    match p.kind {
        ProtocolKind::ResourceConstrained => format!("rc-b{}", p.target_bits),
        _ => p.tag().to_string(),
    }
}

/// Deterministic per-cell seed: FNV-1a of the cell key mixed with the base
/// seed, masked to 48 bits so seeds survive a JSON f64 round-trip exactly.
pub fn derive_seed(base: u64, cell: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in cell.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h ^ base) & 0xFFFF_FFFF_FFFF
}

/// A grid of search jobs plus shared schedule knobs.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub models: Vec<String>,
    pub modes: Vec<Mode>,
    pub protocols: Vec<Protocol>,
    pub granularities: Vec<Granularity>,
    pub episodes: usize,
    pub warmup: usize,
    pub eval_batches: usize,
    pub base_seed: u64,
    pub relabel: bool,
    pub paper_scale: bool,
    /// Worker threads; clamped to [1, #jobs] at run time.
    pub workers: usize,
    /// Where per-cell `JobReport` JSONs land (default `reports/sweep`).
    pub out_dir: Option<PathBuf>,
    /// Execution backend for every worker (`None` = auto-resolve).  Each
    /// worker opens its own `Coordinator`/`Runtime` of this kind.
    pub backend: Option<BackendKind>,
    /// Inner eval-batch threads per worker (`None` = split the machine's
    /// thread budget evenly across workers, so outer per-cell and inner
    /// per-batch parallelism compose without oversubscription).
    pub threads: Option<Parallelism>,
    /// Worker **processes** per sweep worker when `backend` is
    /// [`BackendKind::Shard`] (`None` = `$AUTOQ_SHARD_WORKERS`, else 2);
    /// ignored by other backends.  The per-worker thread budget above is
    /// the total each shard pool splits across its processes, so the full
    /// grid runs `cells × processes × threads` under one machine budget.
    pub shard_workers: Option<usize>,
    /// Remote `autoq worker --listen` hosts for the shard backend
    /// (`None` = `$AUTOQ_SHARD_HOSTS`).  Resolved once up front, then
    /// round-robined into **disjoint** per-worker buckets — a listening
    /// worker serves one session at a time, so sweep workers must not
    /// share hosts.  The serial pre-warm may use the full list.
    pub shard_hosts: Option<Vec<String>>,
    /// Shard wire encoding (`None` = `$AUTOQ_SHARD_ENCODING`, else binary).
    pub shard_encoding: Option<crate::runtime::shard::Encoding>,
    /// Resume from `out_dir/sweep.journal` (`autoq sweep --resume`): cells
    /// already journaled as done — with an unchanged spec fingerprint —
    /// are skipped (their report files re-materialized from the journal if
    /// missing), and only the remainder is scheduled.  A non-resume run
    /// starts the journal fresh so stale cells can't leak across grids.
    pub resume: bool,
}

impl Default for Sweep {
    fn default() -> Sweep {
        Sweep {
            models: vec!["cif10".to_string()],
            modes: vec![Mode::Quant],
            protocols: vec![Protocol::resource_constrained(5.0)],
            granularities: vec![Granularity::Channel],
            episodes: 40,
            warmup: 10,
            eval_batches: 2,
            base_seed: 1,
            relabel: true,
            paper_scale: false,
            workers: 2,
            out_dir: None,
            backend: None,
            threads: None,
            shard_workers: None,
            shard_hosts: None,
            shard_encoding: None,
            resume: false,
        }
    }
}

/// Thread budget for the serial pre-warm: the grid's whole budget —
/// workers × per-worker threads when pinned (saturating: a pathological
/// `--threads` × `--workers` product must clamp, not overflow), the
/// resolved machine budget otherwise.
fn prewarm_budget(threads: Option<Parallelism>, workers: usize) -> anyhow::Result<Parallelism> {
    Ok(match threads {
        Some(p) => Parallelism::new(p.get().saturating_mul(workers.max(1))),
        None => Parallelism::resolve(None)?,
    })
}

/// Per-worker inner eval-thread budget: pinned explicitly, else an even
/// share of the machine budget with [`Parallelism::share_of`]'s ≥ 1 floor
/// — `workers > cores` must give every worker one thread, never a `0`
/// that downstream `Parallelism` parsing would re-read as "all cores"
/// (the oversubscription the split exists to prevent).
fn inner_budget(threads: Option<Parallelism>, workers: usize) -> anyhow::Result<Parallelism> {
    Ok(match threads {
        Some(p) => p,
        None => Parallelism::share_of(Parallelism::resolve(None)?.get(), workers),
    })
}

/// Everything a finished sweep produced, reports in grid order.
#[derive(Debug)]
pub struct SweepResult {
    pub reports: Vec<JobReport>,
    /// (job id, error) for cells that failed.
    pub failures: Vec<(String, String)>,
    /// (job id, report path) for cells skipped on `--resume` because the
    /// journal already holds their finished report.
    pub skipped: Vec<(String, PathBuf)>,
    pub secs: f64,
}

impl Sweep {
    pub fn cells(&self) -> usize {
        self.models.len() * self.modes.len() * self.protocols.len() * self.granularities.len()
    }

    /// Expand the grid into validated job specs with derived seeds.
    pub fn jobs(&self) -> anyhow::Result<Vec<JobSpec>> {
        anyhow::ensure!(!self.models.is_empty(), "sweep needs at least one model");
        anyhow::ensure!(!self.modes.is_empty(), "sweep needs at least one mode");
        anyhow::ensure!(!self.protocols.is_empty(), "sweep needs at least one protocol");
        anyhow::ensure!(!self.granularities.is_empty(), "sweep needs at least one granularity");
        let mut jobs = Vec::with_capacity(self.cells());
        let mut seen = BTreeSet::new();
        for model in &self.models {
            for &mode in &self.modes {
                for &protocol in &self.protocols {
                    for &granularity in &self.granularities {
                        let cell = format!(
                            "{model}/{}/{}/{}",
                            mode.as_str(),
                            protocol_cell_token(&protocol),
                            granularity_token(granularity)
                        );
                        // Duplicate grid entries would rerun the same job and
                        // overwrite the same report — keep the first.
                        if !seen.insert(cell.clone()) {
                            crate::warn_!("sweep: duplicate cell {cell} skipped");
                            continue;
                        }
                        let spec = JobSpec::search(model)
                            .mode(mode)
                            .protocol(protocol)
                            .granularity(granularity)
                            .episodes(self.episodes)
                            .warmup(self.warmup)
                            .eval_batches(self.eval_batches)
                            .relabel(self.relabel)
                            .paper_scale(self.paper_scale)
                            .seed(derive_seed(self.base_seed, &cell))
                            .build()?;
                        jobs.push(spec);
                    }
                }
            }
        }
        Ok(jobs)
    }

    /// Run the whole grid against the artifact directory `dir`, writing one
    /// JSON report per cell.  Failed cells are collected, not fatal.
    pub fn run(&self, dir: &Path) -> anyhow::Result<SweepResult> {
        let t0 = Instant::now();
        let jobs = self.jobs()?;

        // Fail on an unwritable report dir before burning hours of search.
        let out_dir = self
            .out_dir
            .clone()
            .unwrap_or_else(|| PathBuf::from("reports").join("sweep"));
        std::fs::create_dir_all(&out_dir)?;

        // Durable sweep journal (DESIGN.md §Durable jobs): every finished
        // cell is appended as a DONE record keyed by job id, fingerprinted
        // over the full spec JSON, carrying the exact report bytes.  On
        // `--resume` the journal is replayed and matching cells are
        // skipped; cells whose spec changed re-run under the same id.
        let journal_path = out_dir.join("sweep.journal");
        let mut log = if self.resume {
            DurableLog::open(&journal_path)?
        } else {
            DurableLog::fresh(&journal_path)?
        };
        let mut skipped: Vec<(String, PathBuf)> = Vec::new();
        let mut pending: Vec<JobSpec> = Vec::new();
        for spec in jobs {
            let id = spec.id();
            let fp = fingerprint(spec.to_json().to_string().as_bytes());
            match log.recorded(&id, fp) {
                Some(payload) => {
                    // Re-materialize the report file if the crash window
                    // (or a stray delete) lost it — the journal holds the
                    // exact bytes the finished cell wrote.
                    let path = out_dir.join(format!("{id}.json"));
                    let stale = match std::fs::read(&path) {
                        Ok(bytes) => bytes != payload,
                        Err(_) => true,
                    };
                    if stale {
                        std::fs::write(&path, payload)?;
                        crate::info!("sweep: restored {} from journal", path.display());
                    }
                    crate::info!("sweep: cell {id} already done — skipping");
                    skipped.push((id, path));
                }
                None => pending.push(spec),
            }
        }
        let jobs = pending;
        if jobs.is_empty() {
            crate::info!(
                "sweep: all {} cell(s) already journaled — nothing to run",
                skipped.len()
            );
            return Ok(SweepResult {
                reports: Vec::new(),
                failures: Vec::new(),
                skipped,
                secs: t0.elapsed().as_secs_f64(),
            });
        }

        let workers = self.workers.max(1).min(jobs.len());

        // Resolve the remote host list once so the env is read exactly one
        // time, then deal disjoint buckets to the workers below.
        let shard_hosts = crate::runtime::shard::resolve_hosts(self.shard_hosts.clone())?;

        // Pre-warm trained params serially so workers never race a pretrain.
        // Only worth opening a runtime when some model's params are missing.
        let models: BTreeSet<&str> = jobs.iter().map(|j| j.model.as_str()).collect();
        let missing: Vec<&str> = models
            .into_iter()
            .filter(|m| !Coordinator::params_path_in(dir, m).exists())
            .collect();
        if !missing.is_empty() {
            let warm = prewarm_budget(self.threads, workers)?;
            // The pre-warm runs alone, so it may dial the whole fleet.
            let opts = RuntimeOpts {
                threads: Some(warm),
                shard_workers: self.shard_workers,
                shard_hosts: Some(shard_hosts.clone()),
                shard_encoding: self.shard_encoding,
            };
            let mut coord = Coordinator::open_full(dir, self.backend, opts)?;
            for model in missing {
                coord.ensure_pretrained(model)?;
            }
        }

        // Compose outer (per-cell) with inner (per-batch) parallelism
        // without oversubscription.
        let inner = inner_budget(self.threads, workers)?;
        crate::info!(
            "sweep: {} jobs on {} worker(s) × {} eval thread(s)",
            jobs.len(),
            workers,
            inner.get()
        );
        let next = AtomicUsize::new(0);
        // Disjoint host buckets: worker w may only dial host_parts[w]
        // (possibly empty — its shard pool then falls back to local
        // subprocesses), so two sweep workers never serialize behind one
        // single-session listener.
        let host_parts = crate::runtime::shard::partition_hosts(&shard_hosts, workers);
        let (tx, rx) = mpsc::channel::<(usize, Result<JobReport, String>)>();
        let mut slots: Vec<Option<Result<JobReport, String>>> =
            (0..jobs.len()).map(|_| None).collect();
        // Cells whose report file could not be written; kept out of the
        // journal so a `--resume` re-runs them.
        let mut write_failures: Vec<(String, String)> = Vec::new();
        std::thread::scope(|s| {
            for w in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let jobs = &jobs;
                let backend = self.backend;
                let opts = RuntimeOpts {
                    threads: Some(inner),
                    shard_workers: self.shard_workers,
                    shard_hosts: Some(host_parts[w].clone()),
                    shard_encoding: self.shard_encoding,
                };
                s.spawn(move || {
                    let mut coord = match Coordinator::open_full(dir, backend, opts) {
                        Ok(c) => c,
                        Err(e) => {
                            // Don't claim queue slots: healthy workers drain
                            // the whole queue, and if every worker fails the
                            // unclaimed slots surface as "never scheduled".
                            crate::warn_!("sweep worker failed to open runtime: {e:#}");
                            return;
                        }
                    };
                    loop {
                        let i = next.fetch_add(1, Ordering::SeqCst);
                        if i >= jobs.len() {
                            break;
                        }
                        let mut obs = LogObserver::default();
                        let res = coord
                            .run_observed(&jobs[i], &mut obs)
                            .map_err(|e| format!("{e:#}"));
                        if tx.send((i, res)).is_err() {
                            break;
                        }
                    }
                });
            }
            // Drain results on the scope's main thread *while workers run*:
            // each finished cell is persisted the moment it lands — report
            // file first, then the journal DONE record — so a killed sweep
            // keeps everything completed before the kill and `--resume`
            // re-runs only the rest.
            drop(tx);
            for (i, res) in rx {
                if let Ok(report) = &res {
                    let path = out_dir.join(format!("{}.json", report.id()));
                    let body = report.to_json().to_string();
                    match std::fs::write(&path, &body) {
                        Ok(()) => {
                            crate::info!("wrote {}", path.display());
                            let fp =
                                fingerprint(jobs[i].to_json().to_string().as_bytes());
                            if let Err(e) =
                                log.record_done(&report.id(), fp, body.as_bytes())
                            {
                                crate::warn_!("sweep journal append failed: {e:#}");
                            }
                        }
                        // Keep the in-memory result; record the broken write.
                        Err(e) => write_failures
                            .push((report.id(), format!("report write failed: {e:#}"))),
                    }
                }
                slots[i] = Some(res);
            }
        });

        let mut reports = Vec::new();
        let mut failures = write_failures;
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(report)) => reports.push(report),
                Some(Err(e)) => failures.push((jobs[i].id(), e)),
                None => failures.push((
                    jobs[i].id(),
                    "job was never scheduled (all workers failed to start — see warnings)"
                        .to_string(),
                )),
            }
        }
        Ok(SweepResult { reports, failures, skipped, secs: t0.elapsed().as_secs_f64() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobKind;

    fn grid() -> Sweep {
        Sweep {
            protocols: vec![Protocol::resource_constrained(5.0), Protocol::accuracy_guaranteed()],
            granularities: vec![Granularity::Layer, Granularity::Channel],
            ..Sweep::default()
        }
    }

    #[test]
    fn grid_expands_with_unique_deterministic_seeds() {
        let sw = grid();
        let jobs = sw.jobs().unwrap();
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs.len(), sw.cells());
        let ids: BTreeSet<String> = jobs.iter().map(|j| j.id()).collect();
        assert_eq!(ids.len(), 4, "ids must be unique");
        let seeds: BTreeSet<u64> = jobs.iter().map(|j| j.seed).collect();
        assert_eq!(seeds.len(), 4, "per-cell seeds must differ");
        // Deterministic: a second expansion is identical.
        let again = sw.jobs().unwrap();
        for (a, b) in jobs.iter().zip(&again) {
            assert_eq!(a.id(), b.id());
            assert_eq!(a.seed, b.seed);
        }
        // Every cell is a search job over the configured schedule.
        for j in &jobs {
            let JobKind::Search(p) = &j.kind else { panic!("non-search job in sweep") };
            assert_eq!(p.episodes, sw.episodes);
        }
    }

    #[test]
    fn derived_seeds_are_json_safe_and_base_sensitive() {
        let a = derive_seed(1, "cif10/quant/rc/c");
        let b = derive_seed(2, "cif10/quant/rc/c");
        let c = derive_seed(1, "cif10/quant/rc/l");
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, derive_seed(1, "cif10/quant/rc/c"));
        for s in [a, b, c] {
            assert!(s < (1u64 << 53), "seed {s} would lose precision in JSON");
        }
    }

    #[test]
    fn rc_budgets_get_distinct_cells_and_duplicates_collapse() {
        let sw = Sweep {
            protocols: vec![
                Protocol::resource_constrained(4.0),
                Protocol::resource_constrained(5.0),
                Protocol::resource_constrained(4.0), // exact duplicate
            ],
            ..Sweep::default()
        };
        let jobs = sw.jobs().unwrap();
        assert_eq!(jobs.len(), 2, "duplicate rc@4 cell must collapse");
        assert_ne!(jobs[0].seed, jobs[1].seed, "rc@4 and rc@5 must get distinct seeds");
    }

    #[test]
    fn empty_dimensions_rejected() {
        let mut sw = grid();
        sw.models.clear();
        assert!(sw.jobs().is_err());
        let mut sw = grid();
        sw.granularities.clear();
        assert!(sw.jobs().is_err());
    }

    /// Regression: `workers > cores` used to be able to resolve the even
    /// split to `0` inner threads, which `Parallelism` parsing reads as
    /// "auto = all cores" — i.e. every worker grabbing the whole machine.
    #[test]
    fn inner_budget_never_drops_to_zero_when_workers_exceed_cores() {
        let cores = Parallelism::resolve(None).unwrap().get();
        for workers in [1, 2, cores, cores + 1, 2 * cores + 3, usize::MAX] {
            let inner = inner_budget(None, workers).unwrap();
            assert!(inner.get() >= 1, "workers={workers} resolved to a zero share");
            assert!(
                inner.get() <= cores.max(1),
                "workers={workers} share {} exceeds the machine budget {cores}",
                inner.get()
            );
        }
        // A pinned per-worker budget is taken verbatim.
        assert_eq!(inner_budget(Some(Parallelism::new(3)), 64).unwrap().get(), 3);
    }

    /// Regression: the serial pre-warm's `threads × workers` product must
    /// saturate instead of overflowing (and clamp to ≥ 1).
    #[test]
    fn prewarm_budget_saturates_and_floors() {
        assert_eq!(prewarm_budget(Some(Parallelism::new(3)), 4).unwrap().get(), 12);
        assert_eq!(prewarm_budget(Some(Parallelism::new(2)), 0).unwrap().get(), 2);
        assert_eq!(
            prewarm_budget(Some(Parallelism::new(usize::MAX)), usize::MAX).unwrap().get(),
            usize::MAX,
            "overflow must saturate, not wrap to a tiny budget"
        );
        assert!(prewarm_budget(None, usize::MAX).unwrap().get() >= 1);
    }
}
