//! Client side of the serve protocol: a thin typed wrapper over one TCP
//! connection (`autoq submit` / `autoq status`), plus the daemon-backed
//! sweep driver behind `autoq sweep --daemon`.

use std::net::TcpStream;

use crate::coordinator::{JobSpec, Sweep};
use crate::runtime::shard::proto::{read_frame, write_frame};
use crate::serve::wire;
use crate::util::json::Json;

/// One connection to an `autoq serve` daemon.  Every method is a
/// frame round-trip; an `{ok:false}` response surfaces as `Err` with the
/// daemon's error text.
pub struct DaemonClient {
    stream: TcpStream,
}

impl DaemonClient {
    pub fn connect(addr: &str) -> anyhow::Result<DaemonClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("cannot reach autoq serve at {addr}: {e}"))?;
        Ok(DaemonClient { stream })
    }

    /// Send one request frame, read one response frame, reject `{ok:false}`.
    fn roundtrip(&mut self, req: &Json) -> anyhow::Result<Json> {
        write_frame(&mut self.stream, req)?;
        let reply = read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow::anyhow!("daemon closed the connection"))?;
        match reply.req("ok")?.as_bool() {
            Some(true) => Ok(reply),
            _ => {
                let msg = reply
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("daemon reported an error");
                anyhow::bail!("{msg}")
            }
        }
    }

    /// Liveness probe; returns the daemon's pid.
    pub fn ping(&mut self) -> anyhow::Result<u32> {
        let reply = self.roundtrip(&wire::ping_json())?;
        Ok(reply.req("pid")?.as_f64().unwrap_or(0.0) as u32)
    }

    /// Submit a job; returns the queue-assigned handle (`job-<n>`).
    pub fn submit(&mut self, spec: &JobSpec) -> anyhow::Result<String> {
        let reply = self.roundtrip(&wire::submit_json(spec))?;
        reply
            .req("job")?
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("malformed submit reply"))
    }

    /// One job's status row, or the whole queue + cache totals.
    pub fn status(&mut self, job: Option<&str>) -> anyhow::Result<Json> {
        self.roundtrip(&wire::status_json(job))
    }

    /// A job's result row; `wait` blocks until the job is terminal.  The
    /// reply is `Ok` even for a *failed job* — the transport worked; check
    /// `state`/`error` in the row (the CLI turns failed states into its
    /// exit code).
    pub fn result(&mut self, job: &str, wait: bool) -> anyhow::Result<Json> {
        self.roundtrip(&wire::result_json(job, wait))
    }

    /// Ask the daemon to stop; `drain` finishes queued jobs first.  Blocks
    /// until the daemon is quiescent (the op responds after draining).
    pub fn shutdown(&mut self, drain: bool) -> anyhow::Result<Json> {
        self.roundtrip(&wire::shutdown_json(drain))
    }
}

/// Outcome of a daemon-backed sweep (the `--daemon` analogue of
/// `SweepResult`).
#[derive(Debug)]
pub struct DaemonSweepResult {
    /// (spec id, report path) per finished job, submission order.
    pub written: Vec<(String, std::path::PathBuf)>,
    /// (spec id, error) per failed job.
    pub failures: Vec<(String, String)>,
    /// Summed per-job eval-cache (hits, misses) deltas.
    pub cache: (u64, u64),
}

/// Wait for one submitted job and interpret its terminal row: the verbatim
/// report on `done`, the daemon's error text otherwise, plus the job's
/// eval-cache (hits, misses) delta.
fn wait_outcome(
    client: &mut DaemonClient,
    handle: &str,
) -> anyhow::Result<(Result<Json, String>, (u64, u64))> {
    let row = client.result(handle, true)?;
    let mut cache = (0u64, 0u64);
    if let Some(c) = row.get("cache") {
        cache.0 = c.get("hits").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        cache.1 = c.get("misses").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    }
    let outcome = match row.req("state")?.as_str() {
        Some("done") => Ok(row.req("report")?.clone()),
        Some(state) => {
            Err(row.get("error").and_then(Json::as_str).unwrap_or(state).to_string())
        }
        None => anyhow::bail!("malformed result row for {handle}"),
    };
    Ok((outcome, cache))
}

/// Run one job through a daemon and block for its verbatim report — the
/// single-job core of [`run_sweep_via_daemon`], reused by
/// `autoq repro --daemon` to route searches through a shared daemon (and
/// its eval cache) while fine-tunes and report assembly stay local.
pub fn run_job_via_daemon(addr: &str, spec: &JobSpec) -> anyhow::Result<Json> {
    let mut client = DaemonClient::connect(addr)?;
    let handle = client.submit(spec)?;
    crate::info!("[{}] submitted as {handle}", spec.id());
    let (outcome, cache) = wait_outcome(&mut client, &handle)?;
    crate::info!("[{}] eval cache {} hit(s) / {} miss(es)", spec.id(), cache.0, cache.1);
    outcome.map_err(|e| anyhow::anyhow!("[{}] daemon job failed: {e}", spec.id()))
}

/// Run a sweep through a daemon: expand the grid locally (same
/// `Sweep::jobs` expansion — same ids, same derived seeds), submit every
/// cell, wait for each result in submission order, and write each verbatim
/// report to `out_dir/<id>.json` exactly as `Sweep::run` would.
///
/// Scheduling, thread budgets and the artifact dir are the daemon's
/// business; `workers`, `threads`, and `shard_workers` on the sweep are
/// ignored here.
pub fn run_sweep_via_daemon(addr: &str, sweep: &Sweep) -> anyhow::Result<DaemonSweepResult> {
    let specs = sweep.jobs()?;
    anyhow::ensure!(!specs.is_empty(), "sweep expands to zero jobs");
    // Same default report dir as `Sweep::run`.
    let out_dir = sweep
        .out_dir
        .clone()
        .unwrap_or_else(|| std::path::PathBuf::from("reports").join("sweep"));
    std::fs::create_dir_all(&out_dir)
        .map_err(|e| anyhow::anyhow!("cannot create {}: {e}", out_dir.display()))?;
    let mut client = DaemonClient::connect(addr)?;
    let mut handles = Vec::with_capacity(specs.len());
    for spec in &specs {
        let handle = client.submit(spec)?;
        crate::info!("[{}] submitted as {handle}", spec.id());
        handles.push(handle);
    }
    let mut written = Vec::new();
    let mut failures = Vec::new();
    let mut cache = (0u64, 0u64);
    for (spec, handle) in specs.iter().zip(&handles) {
        let (outcome, delta) = wait_outcome(&mut client, handle)?;
        cache.0 += delta.0;
        cache.1 += delta.1;
        match outcome {
            Ok(report) => {
                let path = out_dir.join(format!("{}.json", spec.id()));
                // The report is written verbatim — byte-identical to what a
                // daemon-free `Sweep::run` of the same grid produces
                // (modulo wall-clock `secs`).
                std::fs::write(&path, report.to_string())
                    .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", path.display()))?;
                written.push((spec.id(), path));
            }
            Err(err) => {
                crate::warn_!("[{}] failed: {err}", spec.id());
                failures.push((spec.id(), err));
            }
        }
    }
    crate::info!(
        "daemon sweep: {} written, {} failed, eval cache {} hit(s) / {} miss(es)",
        written.len(),
        failures.len(),
        cache.0,
        cache.1
    );
    Ok(DaemonSweepResult { written, failures, cache })
}
