//! Wire types of the `autoq serve` protocol: requests, responses and
//! streamed job events.
//!
//! Transport is the shard backend's length-prefixed JSON framing
//! (`runtime::shard::proto::{read_frame, write_frame}`) over TCP — one
//! request frame in, one response frame out, except `subscribe`, which
//! answers `{ok:true}` and then streams event frames until the job's
//! terminal `finished` event.
//!
//! Parsing follows the untyped → typed progression: a frame arrives as the
//! substrate's untyped [`Json`], gets its `op` discriminant inspected, and
//! is then lifted field-by-field into the typed [`ServeRequest`] enum —
//! with job submissions lifted all the way into the crate's
//! builder-validated [`JobSpec`], so a spec that reaches the queue has
//! passed exactly the same validation as one built by the CLI.
//!
//! Determinism contract: the `report` object inside a `result` response is
//! the job's `JobReport::to_json()` **verbatim** — cache hit/miss counters
//! ride the response *envelope* (and `status`/event frames), never the
//! report, so a daemon-served report is byte-identical to one written by a
//! daemon-free run of the same spec (modulo the wall-clock `secs` field,
//! exactly as between backends in `tests/shard_backend.rs`).

use std::path::PathBuf;

use crate::coordinator::{JobKind, JobSpec};
use crate::cost::Mode;
use crate::search::{Granularity, Protocol, ProtocolKind};
use crate::util::json::Json;

/// A parsed client→daemon request.
#[derive(Debug)]
pub enum ServeRequest {
    /// Liveness probe; answers `{ok, pid}` like the shard handshake.
    Ping,
    /// Enqueue a validated job; answers `{ok, job, id}`.
    Submit(JobSpec),
    /// One job's state, or the whole queue plus cache totals.
    Status { job: Option<String> },
    /// A job's terminal state; `wait` blocks until the job finishes.
    Result { job: String, wait: bool },
    /// Stream this job's events until it finishes.
    Subscribe { job: String },
    /// Stop the daemon; `drain` finishes every queued job first, otherwise
    /// queued jobs are cancelled and only in-flight jobs complete.
    Shutdown { drain: bool },
}

fn req_str(j: &Json, key: &str) -> anyhow::Result<String> {
    j.req(key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("{key} must be a string"))
}

fn opt_bool(j: &Json, key: &str, default: bool) -> anyhow::Result<bool> {
    match j.get(key) {
        None => Ok(default),
        Some(v) => v.as_bool().ok_or_else(|| anyhow::anyhow!("{key} must be a bool")),
    }
}

fn opt_usize(j: &Json, key: &str) -> anyhow::Result<Option<usize>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let n = v.as_f64().ok_or_else(|| anyhow::anyhow!("{key} must be a number"))?;
            anyhow::ensure!(n >= 0.0 && n.fract() == 0.0, "{key} must be a non-negative integer");
            Ok(Some(n as usize))
        }
    }
}

/// Lift an untyped request frame into a [`ServeRequest`].  Unknown ops and
/// malformed fields are application errors (the connection answers
/// `{ok:false}` and keeps serving) — only framing/JSON corruption drops a
/// connection.
pub fn request_from_json(j: &Json) -> anyhow::Result<ServeRequest> {
    match j.req("op")?.as_str() {
        Some("ping") => Ok(ServeRequest::Ping),
        Some("submit") => Ok(ServeRequest::Submit(job_from_json(j.req("spec")?)?)),
        Some("status") => Ok(ServeRequest::Status {
            job: j.get("job").and_then(Json::as_str).map(str::to_string),
        }),
        Some("result") => Ok(ServeRequest::Result {
            job: req_str(j, "job")?,
            wait: opt_bool(j, "wait", false)?,
        }),
        Some("subscribe") => Ok(ServeRequest::Subscribe { job: req_str(j, "job")? }),
        Some("shutdown") => Ok(ServeRequest::Shutdown { drain: opt_bool(j, "drain", true)? }),
        other => anyhow::bail!("unknown serve op {other:?}"),
    }
}

// ---- job spec codec -------------------------------------------------------

/// Parse the `granularity_token` form ("n5" | "l" | "c") produced by
/// `JobSpec::to_json`, falling back to the CLI's `Granularity::parse`
/// spellings ("network:B" | "n" | "l" | "c") so hand-written submissions
/// work too.
pub fn granularity_from_token(s: &str) -> anyhow::Result<Granularity> {
    if let Some(bits) = s.strip_prefix('n') {
        if !bits.is_empty() {
            if let Ok(b) = bits.parse::<u8>() {
                return Ok(Granularity::Network(b));
            }
        }
    }
    Granularity::parse(s)
}

/// Inverse of [`JobSpec::to_json`]: lift an untyped spec object into a
/// **builder-validated** `JobSpec`.  Every constraint the CLI enforces
/// (episodes > 0, warmup ≤ episodes, rc target bits in range, …) applies
/// to daemon submissions identically, because the lift goes through the
/// same `JobBuilder::build`.
pub fn job_from_json(j: &Json) -> anyhow::Result<JobSpec> {
    let model = req_str(j, "model")?;
    let kind = req_str(j, "kind")?;
    // Seeds travel as decimal strings (u64 > 2^53 would round in f64).
    let seed: Option<u64> = match j.get("seed") {
        None => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| anyhow::anyhow!("seed must be a decimal string"))?
                .parse()
                .map_err(|_| anyhow::anyhow!("seed is not a u64"))?,
        ),
    };
    let spec = match kind.as_str() {
        "search" => {
            let mut b = JobSpec::search(&model);
            if let Some(m) = j.get("mode").and_then(Json::as_str) {
                b = b.mode(Mode::parse(m)?);
            }
            if let Some(p) = j.get("protocol").and_then(Json::as_str) {
                b = b.protocol(Protocol::parse(p)?);
            }
            if let Some(t) = j.get("target_bits").and_then(Json::as_f64) {
                b = b.target_bits(t);
            }
            if let Some(g) = j.get("granularity").and_then(Json::as_str) {
                b = b.granularity(granularity_from_token(g)?);
            }
            if let Some(e) = opt_usize(j, "episodes")? {
                b = b.episodes(e);
            }
            if let Some(w) = opt_usize(j, "warmup")? {
                b = b.warmup(w);
            }
            if let Some(eb) = opt_usize(j, "eval_batches")? {
                b = b.eval_batches(eb);
            }
            if let Some(r) = j.get("relabel").and_then(Json::as_bool) {
                b = b.relabel(r);
            }
            if let Some(p) = j.get("paper_scale").and_then(Json::as_bool) {
                b = b.paper_scale(p);
            }
            if let Some(s) = seed {
                b = b.seed(s);
            }
            b.build()?
        }
        "pretrain" => {
            let mut b = JobSpec::pretrain(&model);
            if let Some(s) = opt_usize(j, "steps")? {
                b = b.steps(s);
            }
            if let Some(ds) = j.get("data_seed").and_then(Json::as_str) {
                b = b.data_seed(
                    ds.parse().map_err(|_| anyhow::anyhow!("data_seed is not a u64"))?,
                );
            }
            if let Some(p) = j.get("persist").and_then(Json::as_bool) {
                b = b.persist(p);
            }
            if let Some(s) = seed {
                b = b.seed(s);
            }
            b.build()?
        }
        "finetune" => {
            let config = req_str(j, "config")?;
            let mut b = JobSpec::finetune(&model, PathBuf::from(config));
            if let Some(s) = opt_usize(j, "steps")? {
                b = b.steps(s);
            }
            if let Some(s) = seed {
                b = b.seed(s);
            }
            b.build()?
        }
        "eval" => {
            let mut b = JobSpec::eval(&model);
            if let Some(c) = j.get("config").and_then(Json::as_str) {
                b = b.config(PathBuf::from(c));
            }
            if let Some(n) = opt_usize(j, "batches")? {
                b = b.batches(n);
            }
            if let Some(s) = seed {
                b = b.seed(s);
            }
            b.build()?
        }
        "sim" => {
            let mut b = JobSpec::sim(&model);
            if let Some(c) = j.get("config").and_then(Json::as_str) {
                b = b.config(PathBuf::from(c));
            }
            if let Some(s) = seed {
                b = b.seed(s);
            }
            b.build()?
        }
        other => anyhow::bail!("unknown job kind {other:?}"),
    };
    Ok(spec)
}

// ---- request builders (client side) ---------------------------------------

pub fn ping_json() -> Json {
    Json::obj(vec![("op", "ping".into())])
}

pub fn submit_json(spec: &JobSpec) -> Json {
    Json::obj(vec![("op", "submit".into()), ("spec", spec.to_json())])
}

pub fn status_json(job: Option<&str>) -> Json {
    let mut pairs = vec![("op", "status".into())];
    if let Some(job) = job {
        pairs.push(("job", job.into()));
    }
    Json::obj(pairs)
}

pub fn result_json(job: &str, wait: bool) -> Json {
    Json::obj(vec![("op", "result".into()), ("job", job.into()), ("wait", wait.into())])
}

pub fn subscribe_json(job: &str) -> Json {
    Json::obj(vec![("op", "subscribe".into()), ("job", job.into())])
}

pub fn shutdown_json(drain: bool) -> Json {
    Json::obj(vec![("op", "shutdown".into()), ("drain", drain.into())])
}

// ---- response/event builders (daemon side) --------------------------------

pub fn ok_json(mut extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", true.into())];
    pairs.append(&mut extra);
    Json::obj(pairs)
}

pub fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", false.into()), ("error", msg.into())])
}

pub fn cache_json(hits: u64, misses: u64) -> Json {
    // Counters are masked into f64-exact range; a daemon would need ~2^53
    // lookups to wrap, and the JSON substrate cannot carry more exactly.
    Json::obj(vec![
        ("hits", ((hits & 0x1F_FFFF_FFFF_FFFF) as usize).into()),
        ("misses", ((misses & 0x1F_FFFF_FFFF_FFFF) as usize).into()),
    ])
}

/// Per-client cache accounting for the queue-wide `status` reply:
/// `[{client, hits, misses}, ...]`, ascending connection id.  Same f64-exact
/// masking rule as [`cache_json`].
pub fn clients_json(totals: &[(u64, u64, u64)]) -> Json {
    Json::Arr(
        totals
            .iter()
            .map(|&(client, hits, misses)| {
                Json::obj(vec![
                    ("client", ((client & 0x1F_FFFF_FFFF_FFFF) as usize).into()),
                    ("hits", ((hits & 0x1F_FFFF_FFFF_FFFF) as usize).into()),
                    ("misses", ((misses & 0x1F_FFFF_FFFF_FFFF) as usize).into()),
                ])
            })
            .collect(),
    )
}

/// Durability section of the queue-wide `status` reply: where the job
/// journal and disk cache tier live, how many entries each holds, and the
/// age (seconds) of each journal's newest record.  Either half is omitted
/// when that tier is not attached (e.g. durability degraded at bind time).
pub fn durability_json(
    jobs: Option<(PathBuf, Option<u64>, usize)>,
    disk: Option<(PathBuf, Option<u64>, usize)>,
) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    if let Some((path, age, entries)) = jobs {
        pairs.push(("jobs_journal", path.display().to_string().into()));
        pairs.push(("jobs_journaled", entries.into()));
        if let Some(age) = age {
            pairs.push(("jobs_journal_age_secs", ((age & 0x1F_FFFF_FFFF_FFFF) as usize).into()));
        }
    }
    if let Some((path, age, entries)) = disk {
        pairs.push(("disk_cache", path.display().to_string().into()));
        pairs.push(("disk_cache_entries", entries.into()));
        if let Some(age) = age {
            pairs.push(("disk_cache_age_secs", ((age & 0x1F_FFFF_FFFF_FFFF) as usize).into()));
        }
    }
    Json::obj(pairs)
}

pub fn event_started(job: &str, id: &str) -> Json {
    Json::obj(vec![("event", "started".into()), ("job", job.into()), ("id", id.into())])
}

pub fn event_episode(
    job: &str,
    stats: &crate::search::EpisodeStats,
    episodes: usize,
    new_best: bool,
) -> Json {
    Json::obj(vec![
        ("event", "episode".into()),
        ("job", job.into()),
        ("episode", stats.episode.into()),
        ("episodes", episodes.into()),
        ("accuracy", stats.accuracy.into()),
        ("reward", stats.reward.into()),
        ("avg_wbits", stats.avg_wbits.into()),
        ("avg_abits", stats.avg_abits.into()),
        ("norm_logic", stats.norm_logic.into()),
        ("new_best", new_best.into()),
    ])
}

pub fn event_message(job: &str, text: &str) -> Json {
    Json::obj(vec![("event", "message".into()), ("job", job.into()), ("text", text.into())])
}

/// Terminal event: `ok` + the verbatim report on success, `error` on
/// failure; cache counters are the job's delta on this worker.
pub fn event_finished(
    job: &str,
    outcome: &Result<Json, String>,
    cache: (u64, u64),
) -> Json {
    let mut pairs = vec![("event", Json::from("finished")), ("job", job.into())];
    match outcome {
        Ok(report) => {
            pairs.push(("ok", true.into()));
            pairs.push(("report", report.clone()));
        }
        Err(e) => {
            pairs.push(("ok", false.into()));
            pairs.push(("error", e.as_str().into()));
        }
    }
    pairs.push(("cache", cache_json(cache.0, cache.1)));
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_roundtrips_through_builder_validation() {
        let spec = JobSpec::search("cif10")
            .granularity(Granularity::Network(5))
            .episodes(7)
            .warmup(3)
            .eval_batches(1)
            .seed(u64::MAX - 3)
            .build()
            .unwrap();
        let frame = Json::parse(&submit_json(&spec).to_string()).unwrap();
        let ServeRequest::Submit(back) = request_from_json(&frame).unwrap() else {
            panic!("wrong op");
        };
        assert_eq!(back.id(), spec.id());
        assert_eq!(back.seed, spec.seed);
        assert_eq!(back.to_json().to_string(), spec.to_json().to_string());
    }

    #[test]
    fn every_job_kind_roundtrips() {
        let specs = vec![
            JobSpec::pretrain("cif10").steps(5).data_seed(9).persist(false).build().unwrap(),
            JobSpec::finetune("cif10", "cfg.json").steps(3).seed(2).build().unwrap(),
            JobSpec::eval("cif10").config("cfg.json").batches(3).build().unwrap(),
            JobSpec::eval("cif10").batches(1).build().unwrap(),
            JobSpec::sim("cif10").build().unwrap(),
        ];
        for spec in specs {
            let back = job_from_json(&spec.to_json()).unwrap();
            assert_eq!(back.to_json().to_string(), spec.to_json().to_string(), "{}", spec.id());
        }
    }

    #[test]
    fn invalid_specs_are_rejected_by_the_builder() {
        // episodes == 0 — the PR 5 structured-error case, now rejected at
        // the wire boundary by the same builder validation.
        let j = Json::parse(
            r#"{"op":"submit","spec":{"model":"cif10","kind":"search","episodes":0}}"#,
        )
        .unwrap();
        let err = request_from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("episodes"), "{err:#}");
        // Unknown kind.
        let j = Json::parse(r#"{"op":"submit","spec":{"model":"cif10","kind":"nope"}}"#).unwrap();
        assert!(request_from_json(&j).is_err());
        // Missing model.
        let j = Json::parse(r#"{"op":"submit","spec":{"kind":"search"}}"#).unwrap();
        assert!(request_from_json(&j).is_err());
        // Seed as a JSON number would round above 2^53 — strings only.
        let j = Json::parse(
            r#"{"op":"submit","spec":{"model":"cif10","kind":"search","seed":12}}"#,
        )
        .unwrap();
        assert!(request_from_json(&j).is_err());
    }

    #[test]
    fn granularity_tokens_parse_both_spellings() {
        assert_eq!(granularity_from_token("n5").unwrap(), Granularity::Network(5));
        assert_eq!(granularity_from_token("n12").unwrap(), Granularity::Network(12));
        assert_eq!(granularity_from_token("l").unwrap(), Granularity::Layer);
        assert_eq!(granularity_from_token("c").unwrap(), Granularity::Channel);
        assert_eq!(granularity_from_token("network:4").unwrap(), Granularity::Network(4));
        // Bare "n" is the CLI default spelling, not a token.
        assert_eq!(granularity_from_token("n").unwrap(), Granularity::Network(5));
        assert!(granularity_from_token("x").is_err());
        assert!(granularity_from_token("n999").is_err());
    }

    #[test]
    fn rc_target_bits_survive_the_roundtrip() {
        let spec = JobSpec::search("cif10")
            .protocol(Protocol::resource_constrained(4.0))
            .build()
            .unwrap();
        let back = job_from_json(&spec.to_json()).unwrap();
        let JobKind::Search(p) = &back.kind else { panic!("wrong kind") };
        assert_eq!(p.protocol.kind, ProtocolKind::ResourceConstrained);
        assert_eq!(p.protocol.target_bits, 4.0);
    }

    #[test]
    fn ops_parse_with_defaults() {
        let j = Json::parse(r#"{"op":"status"}"#).unwrap();
        assert!(matches!(request_from_json(&j).unwrap(), ServeRequest::Status { job: None }));
        let j = Json::parse(r#"{"op":"result","job":"job-3"}"#).unwrap();
        let ServeRequest::Result { job, wait } = request_from_json(&j).unwrap() else {
            panic!("wrong op");
        };
        assert_eq!(job, "job-3");
        assert!(!wait);
        let j = Json::parse(r#"{"op":"shutdown"}"#).unwrap();
        assert!(matches!(
            request_from_json(&j).unwrap(),
            ServeRequest::Shutdown { drain: true }
        ));
        assert!(request_from_json(&Json::parse(r#"{"op":"nope"}"#).unwrap()).is_err());
        assert!(request_from_json(&Json::parse(r#"{"no_op":1}"#).unwrap()).is_err());
    }
}
