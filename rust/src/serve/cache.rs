//! Content-addressed eval cache: exact memoization of
//! `ModelRunner::eval_config` results, shared by every scheduler worker of
//! one `autoq serve` daemon.
//!
//! Why this is sound: both deterministic backends (`reference`, `shard`)
//! produce **byte-identical** `EvalResult`s for the same inputs at every
//! thread/worker count (DESIGN.md §Determinism), so an evaluation is a pure
//! function of its content — not of who computed it or when.  The cache key
//! is therefore built from exactly the inputs that determine the result:
//!
//!   backend kind, model name, cost mode, the full per-channel
//!   wbits/abits vectors, dataset (seed, noise), split, batch schedule
//!   (n_batches × eval_batch), and a fingerprint of the parameter tensors.
//!
//! Search seed and protocol are deliberately **not** in the key: they decide
//! *which* configs the agent evaluates, never the value of an evaluation —
//! that is what makes the cache content-addressed rather than run-addressed.
//! Thread counts are excluded too (byte-identity makes them irrelevant);
//! backend kind is included because PJRT results are only
//! tolerance-identical to the reference interpreter, so a PJRT daemon must
//! never serve reference-computed numbers or vice versa.
//!
//! Keys hash with FNV-1a over a canonical little-endian byte encoding —
//! the same process-independent construction as `sweep::derive_seed`, and
//! **not** `std::collections::hash_map::DefaultHasher`, whose per-process
//! random state would break the "same spec → same key across processes"
//! contract that `tests/eval_cache.rs` pins.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::models::EvalResult;

/// Incremental FNV-1a 64 over a canonical byte encoding.  Every variable-
/// length field is length-prefixed so adjacent fields can never alias
/// (`"ab" + "c"` vs `"a" + "bc"`).
#[derive(Debug, Clone, Copy)]
pub struct KeyHasher(u64);

impl KeyHasher {
    pub fn new() -> KeyHasher {
        KeyHasher(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    /// Length-prefixed string.
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes())
    }

    /// Length-prefixed byte slice (bit-width vectors).
    pub fn blob(&mut self, bytes: &[u8]) -> &mut Self {
        self.u64(bytes.len() as u64);
        self.bytes(bytes)
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for KeyHasher {
    fn default() -> Self {
        KeyHasher::new()
    }
}

/// The canonical eval-cache key.  Field order is part of the wire-level
/// contract (DESIGN.md §Serve daemon — cache key definition); changing it
/// invalidates every persisted expectation, so `tests/eval_cache.rs`
/// re-derives the encoding independently.
#[allow(clippy::too_many_arguments)]
pub fn eval_key(
    backend: &str,
    model: &str,
    mode: &str,
    wbits: &[u8],
    abits: &[u8],
    data_seed: u64,
    data_noise: f32,
    split: &str,
    n_batches: usize,
    eval_batch: usize,
    param_fp: u64,
) -> u64 {
    let mut h = KeyHasher::new();
    h.str(backend)
        .str(model)
        .str(mode)
        .blob(wbits)
        .blob(abits)
        .u64(data_seed)
        .u64(data_noise.to_bits() as u64)
        .str(split)
        .u64(n_batches as u64)
        .u64(eval_batch as u64)
        .u64(param_fp);
    h.finish()
}

/// Fingerprint of a parameter set: FNV-1a over every tensor's name, shape
/// and exact f32 bit patterns.  Covers "which trained weights" — and
/// therefore subsumes pretrain seed/steps — so a fine-tuned runner can
/// never alias its pre-trained ancestor.
pub fn param_fingerprint(names: &[String], tensors: &[crate::runtime::Tensor]) -> u64 {
    let mut h = KeyHasher::new();
    h.u64(names.len() as u64);
    for (name, t) in names.iter().zip(tensors) {
        h.str(name);
        h.u64(t.shape.len() as u64);
        for &d in &t.shape {
            h.u64(d as u64);
        }
        h.u64(t.data.len() as u64);
        for &x in &t.data {
            h.u64(x.to_bits() as u64);
        }
    }
    h.finish()
}

/// The daemon-wide store: one map, global hit/miss counters.  Entries are
/// tiny (three scalars), so there is no eviction — a search that evaluates
/// ten thousand configs stores ~240 KB.
#[derive(Debug, Default)]
pub struct EvalCache {
    map: Mutex<HashMap<u64, EvalResult>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    pub fn len(&self) -> usize {
        self.map.lock().expect("eval cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Daemon-lifetime (hits, misses) across every worker.
    pub fn counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    fn get(&self, key: u64) -> Option<EvalResult> {
        let hit = self.map.lock().expect("eval cache poisoned").get(&key).copied();
        match hit {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn insert(&self, key: u64, result: EvalResult) {
        self.map.lock().expect("eval cache poisoned").insert(key, result);
    }
}

/// One worker's view of the shared cache, with its own monotonic counters
/// so the scheduler can report per-job deltas (each worker runs jobs
/// serially, so a snapshot before/after `run_observed` is race-free).
#[derive(Debug)]
pub struct CacheHandle {
    cache: Arc<EvalCache>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CacheHandle {
    pub fn new(cache: Arc<EvalCache>) -> Arc<CacheHandle> {
        Arc::new(CacheHandle { cache, hits: AtomicU64::new(0), misses: AtomicU64::new(0) })
    }

    /// A handle over a private cache — the in-process path used by tests
    /// and `Coordinator::set_eval_cache` callers outside the daemon.
    pub fn private() -> Arc<CacheHandle> {
        CacheHandle::new(Arc::new(EvalCache::new()))
    }

    pub fn cache(&self) -> &Arc<EvalCache> {
        &self.cache
    }

    /// This handle's monotonic (hits, misses).
    pub fn counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub fn get(&self, key: u64) -> Option<EvalResult> {
        let hit = self.cache.get(key);
        match hit {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        hit
    }

    pub fn insert(&self, key: u64, result: EvalResult) {
        self.cache.insert(key, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_key() -> u64 {
        eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77)
    }

    #[test]
    fn key_is_deterministic_and_field_sensitive() {
        assert_eq!(base_key(), base_key());
        let variants = [
            eval_key("shard", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77),
            eval_key("reference", "res18", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77),
            eval_key("reference", "cif10", "binar", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77),
            eval_key("reference", "cif10", "quant", &[5, 5], &[4], 42, 0.85, "val", 2, 256, 77),
            eval_key("reference", "cif10", "quant", &[5, 4], &[5], 42, 0.85, "val", 2, 256, 77),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 43, 0.85, "val", 2, 256, 77),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.9, "val", 2, 256, 77),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "train", 2, 256, 77),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 3, 256, 77),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 128, 77),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 78),
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(*v, base_key(), "variant {i} must change the key");
        }
    }

    #[test]
    fn length_prefixing_prevents_field_aliasing() {
        // Moving a bit between the two vectors must not alias.
        let a = eval_key("r", "m", "q", &[5, 4], &[3], 1, 0.0, "val", 1, 1, 0);
        let b = eval_key("r", "m", "q", &[5], &[4, 3], 1, 0.0, "val", 1, 1, 0);
        assert_ne!(a, b);
        let mut h1 = KeyHasher::new();
        h1.str("ab").str("c");
        let mut h2 = KeyHasher::new();
        h2.str("a").str("bc");
        assert_ne!(h1.finish(), h2.finish());
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let handle = CacheHandle::private();
        let r = EvalResult { accuracy: 0.5, loss: 1.0, images: 256 };
        assert!(handle.get(9).is_none());
        handle.insert(9, r);
        assert_eq!(handle.get(9), Some(r));
        assert_eq!(handle.counts(), (1, 1));
        assert_eq!(handle.cache().counts(), (1, 1));
        assert_eq!(handle.cache().len(), 1);
        // A second handle over the same store keeps its own counters.
        let other = CacheHandle::new(handle.cache().clone());
        assert_eq!(other.get(9), Some(r));
        assert_eq!(other.counts(), (1, 0));
        assert_eq!(handle.counts(), (1, 1));
        assert_eq!(handle.cache().counts(), (2, 1));
    }

    #[test]
    fn param_fingerprint_tracks_content() {
        use crate::runtime::Tensor;
        let names = vec!["l1.w".to_string()];
        let t = |x: f32| vec![Tensor::new(vec![2], vec![x, 1.0])];
        let a = param_fingerprint(&names, &t(0.5));
        assert_eq!(a, param_fingerprint(&names, &t(0.5)));
        assert_ne!(a, param_fingerprint(&names, &t(0.25)));
        assert_ne!(a, param_fingerprint(&["l2.w".to_string()], &t(0.5)));
        // -0.0 and 0.0 are distinct bit patterns on purpose.
        assert_ne!(param_fingerprint(&names, &t(0.0)), param_fingerprint(&names, &t(-0.0)));
    }
}
