//! Content-addressed eval cache: exact memoization of
//! `ModelRunner::eval_config` results, shared by every scheduler worker of
//! one `autoq serve` daemon.
//!
//! Why this is sound: both deterministic backends (`reference`, `shard`)
//! produce **byte-identical** `EvalResult`s for the same inputs at every
//! thread/worker count (DESIGN.md §Determinism), so an evaluation is a pure
//! function of its content — not of who computed it or when.  The cache key
//! is therefore built from exactly the inputs that determine the result:
//!
//!   backend kind, model name, cost mode, the full per-channel
//!   wbits/abits vectors, dataset (seed, noise), split, batch schedule
//!   (n_batches × eval_batch), a fingerprint of the parameter tensors,
//!   and a fingerprint of the static activation-scale calibration table
//!   (0 = dynamic per-row scales).
//!
//! Search seed and protocol are deliberately **not** in the key: they decide
//! *which* configs the agent evaluates, never the value of an evaluation —
//! that is what makes the cache content-addressed rather than run-addressed.
//! Thread counts are excluded too (byte-identity makes them irrelevant);
//! backend kind is included because PJRT results are only
//! tolerance-identical to the reference interpreter, so a PJRT daemon must
//! never serve reference-computed numbers or vice versa.
//!
//! Keys hash with FNV-1a over a canonical little-endian byte encoding —
//! the same process-independent construction as `sweep::derive_seed`, and
//! **not** `std::collections::hash_map::DefaultHasher`, whose per-process
//! random state would break the "same spec → same key across processes"
//! contract that `tests/eval_cache.rs` pins.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::journal::log::kind;
use crate::journal::{ByteReader, ByteWriter, DurableLog};
use crate::models::EvalResult;

/// Incremental FNV-1a 64 over a canonical byte encoding.  Every variable-
/// length field is length-prefixed so adjacent fields can never alias
/// (`"ab" + "c"` vs `"a" + "bc"`).
#[derive(Debug, Clone, Copy)]
pub struct KeyHasher(u64);

impl KeyHasher {
    pub fn new() -> KeyHasher {
        KeyHasher(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    /// Length-prefixed string.
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes())
    }

    /// Length-prefixed byte slice (bit-width vectors).
    pub fn blob(&mut self, bytes: &[u8]) -> &mut Self {
        self.u64(bytes.len() as u64);
        self.bytes(bytes)
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for KeyHasher {
    fn default() -> Self {
        KeyHasher::new()
    }
}

/// The canonical eval-cache key.  Field order is part of the wire-level
/// contract (DESIGN.md §Serve daemon — cache key definition); changing it
/// invalidates every persisted expectation, so `tests/eval_cache.rs`
/// re-derives the encoding independently.
#[allow(clippy::too_many_arguments)]
pub fn eval_key(
    backend: &str,
    model: &str,
    mode: &str,
    wbits: &[u8],
    abits: &[u8],
    data_seed: u64,
    data_noise: f32,
    split: &str,
    n_batches: usize,
    eval_batch: usize,
    param_fp: u64,
    calib_fp: u64,
) -> u64 {
    let mut h = KeyHasher::new();
    h.str(backend)
        .str(model)
        .str(mode)
        .blob(wbits)
        .blob(abits)
        .u64(data_seed)
        .u64(data_noise.to_bits() as u64)
        .str(split)
        .u64(n_batches as u64)
        .u64(eval_batch as u64)
        .u64(param_fp)
        .u64(calib_fp);
    h.finish()
}

/// Fingerprint of a parameter set: FNV-1a over every tensor's name, shape
/// and exact f32 bit patterns.  Covers "which trained weights" — and
/// therefore subsumes pretrain seed/steps — so a fine-tuned runner can
/// never alias its pre-trained ancestor.
pub fn param_fingerprint(names: &[String], tensors: &[crate::runtime::Tensor]) -> u64 {
    let mut h = KeyHasher::new();
    h.u64(names.len() as u64);
    for (name, t) in names.iter().zip(tensors) {
        h.str(name);
        h.u64(t.shape.len() as u64);
        for &d in &t.shape {
            h.u64(d as u64);
        }
        h.u64(t.data.len() as u64);
        for &x in &t.data {
            h.u64(x.to_bits() as u64);
        }
    }
    h.finish()
}

/// One cached evaluation plus the logical time of its last touch (an
/// LRU-ish recency stamp — see [`EvalCache::insert`]).
#[derive(Debug, Clone, Copy)]
struct Entry {
    result: EvalResult,
    tick: u64,
}

/// Default entry cap when `$AUTOQ_CACHE_MAX` is unset.  Entries are tiny
/// (three scalars + a stamp, ~40 bytes), so the default is generous — a
/// million entries is ~40 MB, far beyond what any sane sweep evaluates —
/// while still bounding a daemon that runs for weeks.
const DEFAULT_MAX_ENTRIES: usize = 1 << 20;

/// The daemon-wide store: one map, global hit/miss counters, and an entry
/// cap with least-recently-used eviction.  The cap comes from
/// `$AUTOQ_CACHE_MAX` (`0` = unlimited), else [`DEFAULT_MAX_ENTRIES`].
/// Eviction only ever drops entries — a surviving key still returns the
/// same byte-identical `EvalResult`, so hit/miss *semantics* and cached-
/// report byte-identity are unaffected; only the hit *rate* can change.
#[derive(Debug)]
pub struct EvalCache {
    map: Mutex<HashMap<u64, Entry>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// `u64::MAX` plays "unlimited" so the hot path is one compare.
    max_entries: usize,
    /// Logical clock: bumped on every get/insert, stamped onto entries.
    tick: AtomicU64,
    /// Optional disk tier (DESIGN.md §Durable jobs): every insert writes
    /// through to a journal, a restarted daemon reloads the index, and a
    /// memory miss falls through to it before counting as a miss.  Never
    /// nested with the map lock — always taken after it is released.
    disk: Option<Mutex<DiskTier>>,
}

/// The disk tier behind a capped memory map: a [`DurableLog`] of CACHE
/// records plus an in-memory index of every key on disk.  The index holds
/// results too (24 bytes each) — cheap next to re-running an eval, and it
/// makes disk hits a map lookup instead of a file scan.
#[derive(Debug)]
struct DiskTier {
    log: DurableLog,
    index: HashMap<u64, EvalResult>,
}

/// CACHE record payload: key, then the result's exact bit patterns —
/// f64 accuracy/loss as IEEE-754 bits so a reloaded result is
/// byte-identical to the computed one.
fn encode_cache_record(key: u64, r: &EvalResult) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(key);
    w.put_f64(r.accuracy);
    w.put_f64(r.loss);
    w.put_u64(r.images as u64);
    w.into_vec()
}

fn decode_cache_record(bytes: &[u8]) -> anyhow::Result<(u64, EvalResult)> {
    let mut r = ByteReader::new(bytes);
    let key = r.u64()?;
    let res = EvalResult {
        accuracy: r.f64()?,
        loss: r.f64()?,
        images: r.u64()? as usize,
    };
    r.finish()?;
    Ok((key, res))
}

impl EvalCache {
    pub fn new() -> EvalCache {
        let max = match std::env::var("AUTOQ_CACHE_MAX") {
            Ok(s) if !s.trim().is_empty() => match s.trim().parse::<usize>() {
                Ok(0) => usize::MAX,
                Ok(n) => n,
                Err(_) => {
                    crate::warn_!("ignoring non-numeric AUTOQ_CACHE_MAX={s:?}");
                    DEFAULT_MAX_ENTRIES
                }
            },
            _ => DEFAULT_MAX_ENTRIES,
        };
        EvalCache::with_cap(max)
    }

    /// A cache holding at most `max_entries` (tests pin small caps;
    /// `usize::MAX` = unlimited).
    pub fn with_cap(max_entries: usize) -> EvalCache {
        EvalCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            max_entries: max_entries.max(1),
            tick: AtomicU64::new(0),
            disk: None,
        }
    }

    /// Attach (and load) the durable disk tier at `path`: journaled
    /// entries are indexed immediately, every future insert writes
    /// through, and memory misses consult the disk index before counting
    /// as misses.  Returns how many entries the journal held.  Call before
    /// sharing the cache (`&mut` enforces it).
    pub fn attach_disk(&mut self, path: &Path) -> anyhow::Result<usize> {
        let mut log = DurableLog::open(path)?;
        let mut index = HashMap::new();
        for payload in log.extras(kind::CACHE) {
            match decode_cache_record(payload) {
                // Append order — a later record for the same key wins.
                Ok((key, res)) => {
                    index.insert(key, res);
                }
                Err(e) => crate::warn_!("disk cache record is malformed, skipping: {e:#}"),
            }
        }
        // Re-inserts of hot keys accumulate duplicate records; rewrite the
        // journal once the garbage clearly dominates the live set.
        if log.extras_len() > index.len().saturating_mul(2) + 64 {
            log.compact()?;
        }
        let loaded = index.len();
        self.disk = Some(Mutex::new(DiskTier { log, index }));
        Ok(loaded)
    }

    /// Entries in the disk tier's index (0 when no tier is attached).
    pub fn disk_entries(&self) -> usize {
        self.disk
            .as_ref()
            .map(|d| d.lock().expect("disk cache poisoned").index.len())
            .unwrap_or(0)
    }

    /// Durability info for `status`: `(journal path, newest-record age in
    /// seconds, indexed entries)`.  `None` when no disk tier is attached.
    pub fn disk_info(&self) -> Option<(PathBuf, Option<u64>, usize)> {
        let d = self.disk.as_ref()?;
        let g = d.lock().expect("disk cache poisoned");
        Some((g.log.path().to_path_buf(), g.log.age_secs(), g.index.len()))
    }

    pub fn len(&self) -> usize {
        self.map.lock().expect("eval cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Daemon-lifetime (hits, misses) across every worker.
    pub fn counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    fn get(&self, key: u64) -> Option<EvalResult> {
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        let hit = {
            let mut map = self.map.lock().expect("eval cache poisoned");
            map.get_mut(&key).map(|e| {
                e.tick = now; // refresh recency on hit
                e.result
            })
        };
        match hit {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            None => {
                // Memory miss: the disk tier may still know this key (a
                // restarted daemon, or an entry the LRU cap evicted).  The
                // map lock is already released here, so the two locks never
                // nest.
                if let Some(r) = self.disk_get(key) {
                    self.promote(key, r, now);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(r);
                }
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn disk_get(&self, key: u64) -> Option<EvalResult> {
        let d = self.disk.as_ref()?;
        d.lock().expect("disk cache poisoned").index.get(&key).copied()
    }

    /// Re-admit a disk hit into the memory map without touching the disk
    /// tier again.
    fn promote(&self, key: u64, result: EvalResult, now: u64) {
        let mut map = self.map.lock().expect("eval cache poisoned");
        self.evict_if_full(&mut map, key);
        map.insert(key, Entry { result, tick: now });
    }

    fn insert(&self, key: u64, result: EvalResult) {
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        {
            let mut map = self.map.lock().expect("eval cache poisoned");
            self.evict_if_full(&mut map, key);
            map.insert(key, Entry { result, tick: now });
        }
        // Write through to the disk tier (map lock released first).  A key
        // already on disk is skipped: results are content-addressed, so a
        // re-insert can never carry different bytes.
        if let Some(d) = self.disk.as_ref() {
            let mut g = d.lock().expect("disk cache poisoned");
            if !g.index.contains_key(&key) {
                if let Err(e) = g.log.append_extra(kind::CACHE, &encode_cache_record(key, &result))
                {
                    crate::warn_!("disk cache append failed: {e:#}");
                }
                g.index.insert(key, result);
            }
        }
    }

    fn evict_if_full(&self, map: &mut HashMap<u64, Entry>, key: u64) {
        if map.len() >= self.max_entries && !map.contains_key(&key) {
            // At capacity: drop the oldest ~1/8 (at least one) in one
            // sweep, so eviction cost amortizes instead of running a full
            // scan per insert right at the cap.
            let drop_n = (self.max_entries / 8).max(1);
            let mut ticks: Vec<u64> = map.values().map(|e| e.tick).collect();
            ticks.sort_unstable();
            let cutoff = ticks[(drop_n - 1).min(ticks.len() - 1)];
            map.retain(|_, e| e.tick > cutoff);
            crate::debug!(
                "eval cache at cap {}: evicted {} least-recently-used entr(ies)",
                self.max_entries,
                ticks.len() - map.len()
            );
        }
    }
}

impl Default for EvalCache {
    fn default() -> EvalCache {
        EvalCache::new()
    }
}

/// One worker's view of the shared cache, with its own monotonic counters
/// so the scheduler can report per-job deltas (each worker runs jobs
/// serially, so a snapshot before/after `run_observed` is race-free).
#[derive(Debug)]
pub struct CacheHandle {
    cache: Arc<EvalCache>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CacheHandle {
    pub fn new(cache: Arc<EvalCache>) -> Arc<CacheHandle> {
        Arc::new(CacheHandle { cache, hits: AtomicU64::new(0), misses: AtomicU64::new(0) })
    }

    /// A handle over a private cache — the in-process path used by tests
    /// and `Coordinator::set_eval_cache` callers outside the daemon.
    pub fn private() -> Arc<CacheHandle> {
        CacheHandle::new(Arc::new(EvalCache::new()))
    }

    pub fn cache(&self) -> &Arc<EvalCache> {
        &self.cache
    }

    /// This handle's monotonic (hits, misses).
    pub fn counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub fn get(&self, key: u64) -> Option<EvalResult> {
        let hit = self.cache.get(key);
        match hit {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        hit
    }

    pub fn insert(&self, key: u64, result: EvalResult) {
        self.cache.insert(key, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_key() -> u64 {
        eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0)
    }

    #[test]
    fn key_is_deterministic_and_field_sensitive() {
        assert_eq!(base_key(), base_key());
        let variants = [
            eval_key("shard", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "res18", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "binar", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 5], &[4], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[5], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 43, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.9, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "train", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 3, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 128, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 78, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 9),
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(*v, base_key(), "variant {i} must change the key");
        }
    }

    #[test]
    fn length_prefixing_prevents_field_aliasing() {
        // Moving a bit between the two vectors must not alias.
        let a = eval_key("r", "m", "q", &[5, 4], &[3], 1, 0.0, "val", 1, 1, 0, 0);
        let b = eval_key("r", "m", "q", &[5], &[4, 3], 1, 0.0, "val", 1, 1, 0, 0);
        assert_ne!(a, b);
        let mut h1 = KeyHasher::new();
        h1.str("ab").str("c");
        let mut h2 = KeyHasher::new();
        h2.str("a").str("bc");
        assert_ne!(h1.finish(), h2.finish());
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let handle = CacheHandle::private();
        let r = EvalResult { accuracy: 0.5, loss: 1.0, images: 256 };
        assert!(handle.get(9).is_none());
        handle.insert(9, r);
        assert_eq!(handle.get(9), Some(r));
        assert_eq!(handle.counts(), (1, 1));
        assert_eq!(handle.cache().counts(), (1, 1));
        assert_eq!(handle.cache().len(), 1);
        // A second handle over the same store keeps its own counters.
        let other = CacheHandle::new(handle.cache().clone());
        assert_eq!(other.get(9), Some(r));
        assert_eq!(other.counts(), (1, 0));
        assert_eq!(handle.counts(), (1, 1));
        assert_eq!(handle.cache().counts(), (2, 1));
    }

    #[test]
    fn capped_cache_evicts_least_recently_used() {
        let cache = Arc::new(EvalCache::with_cap(8));
        let handle = CacheHandle::new(cache.clone());
        let r = |i: usize| EvalResult { accuracy: i as f64, loss: 0.0, images: 1 };
        for i in 0..8u64 {
            handle.insert(i, r(i as usize));
        }
        assert_eq!(cache.len(), 8);
        // Touch key 0 so it is the most recently used, then overflow.
        assert!(handle.get(0).is_some());
        handle.insert(100, r(100));
        // The cap holds, the recently-touched key survives, the stalest
        // keys (1, 2, ...) are the ones that went.
        assert!(cache.len() <= 8);
        assert!(handle.get(0).is_some(), "recently-used entry must survive eviction");
        assert!(handle.get(100).is_some(), "the new entry must be present");
        assert!(handle.get(1).is_none(), "the least-recently-used entry must be gone");
        // Semantics of surviving entries are untouched.
        assert_eq!(handle.get(0).unwrap(), r(0));
    }

    #[test]
    fn reinserting_an_existing_key_never_evicts() {
        let cache = Arc::new(EvalCache::with_cap(4));
        let handle = CacheHandle::new(cache.clone());
        let r = EvalResult { accuracy: 0.1, loss: 0.2, images: 3 };
        for i in 0..4u64 {
            handle.insert(i, r);
        }
        for _ in 0..10 {
            handle.insert(2, r); // overwrite in place, no eviction sweep
        }
        assert_eq!(cache.len(), 4);
        for i in 0..4u64 {
            assert!(handle.get(i).is_some(), "key {i} must still be cached");
        }
    }

    #[test]
    fn disk_tier_survives_restart_and_catches_memory_misses() {
        let p = std::env::temp_dir()
            .join(format!("autoq_cache_disk_{}.journal", std::process::id()));
        std::fs::remove_file(&p).ok();
        let r = EvalResult { accuracy: 0.875, loss: 0.125, images: 512 };
        {
            let mut cache = EvalCache::with_cap(8);
            assert_eq!(cache.attach_disk(&p).unwrap(), 0);
            cache.insert(7, r);
            cache.insert(7, r); // re-insert: no duplicate disk record
            assert_eq!(cache.disk_entries(), 1);
        }
        {
            // "Restart": a fresh cache over the same journal serves the
            // entry as a hit even though memory is empty.
            let mut cache = EvalCache::with_cap(8);
            assert_eq!(cache.attach_disk(&p).unwrap(), 1);
            assert_eq!(cache.len(), 0);
            assert_eq!(cache.get(7), Some(r));
            assert_eq!(cache.counts(), (1, 0), "disk fallthrough must count as a hit");
            // The hit was promoted into the memory map.
            assert_eq!(cache.len(), 1);
            assert_eq!(cache.get(99), None);
            assert_eq!(cache.counts(), (1, 1));
            let (path, age, entries) = cache.disk_info().unwrap();
            assert_eq!(path, p);
            assert!(age.is_some());
            assert_eq!(entries, 1);
        }
        {
            // LRU eviction from memory must not lose the entry: the disk
            // tier still answers it.
            let mut cache = EvalCache::with_cap(2);
            cache.attach_disk(&p).unwrap();
            for i in 100..110u64 {
                cache.insert(i, r);
            }
            assert!(cache.get(7).is_some(), "evicted key must come back from disk");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn param_fingerprint_tracks_content() {
        use crate::runtime::Tensor;
        let names = vec!["l1.w".to_string()];
        let t = |x: f32| vec![Tensor::new(vec![2], vec![x, 1.0])];
        let a = param_fingerprint(&names, &t(0.5));
        assert_eq!(a, param_fingerprint(&names, &t(0.5)));
        assert_ne!(a, param_fingerprint(&names, &t(0.25)));
        assert_ne!(a, param_fingerprint(&["l2.w".to_string()], &t(0.5)));
        // -0.0 and 0.0 are distinct bit patterns on purpose.
        assert_ne!(param_fingerprint(&names, &t(0.0)), param_fingerprint(&names, &t(-0.0)));
    }
}
