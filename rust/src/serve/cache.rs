//! Content-addressed eval cache: exact memoization of
//! `ModelRunner::eval_config` results, shared by every scheduler worker of
//! one `autoq serve` daemon.
//!
//! Why this is sound: both deterministic backends (`reference`, `shard`)
//! produce **byte-identical** `EvalResult`s for the same inputs at every
//! thread/worker count (DESIGN.md §Determinism), so an evaluation is a pure
//! function of its content — not of who computed it or when.  The cache key
//! is therefore built from exactly the inputs that determine the result:
//!
//!   backend kind, model name, cost mode, the full per-channel
//!   wbits/abits vectors, dataset (seed, noise), split, batch schedule
//!   (n_batches × eval_batch), a fingerprint of the parameter tensors,
//!   and a fingerprint of the static activation-scale calibration table
//!   (0 = dynamic per-row scales).
//!
//! Search seed and protocol are deliberately **not** in the key: they decide
//! *which* configs the agent evaluates, never the value of an evaluation —
//! that is what makes the cache content-addressed rather than run-addressed.
//! Thread counts are excluded too (byte-identity makes them irrelevant);
//! backend kind is included because PJRT results are only
//! tolerance-identical to the reference interpreter, so a PJRT daemon must
//! never serve reference-computed numbers or vice versa.
//!
//! Keys hash with FNV-1a over a canonical little-endian byte encoding —
//! the same process-independent construction as `sweep::derive_seed`, and
//! **not** `std::collections::hash_map::DefaultHasher`, whose per-process
//! random state would break the "same spec → same key across processes"
//! contract that `tests/eval_cache.rs` pins.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::models::EvalResult;

/// Incremental FNV-1a 64 over a canonical byte encoding.  Every variable-
/// length field is length-prefixed so adjacent fields can never alias
/// (`"ab" + "c"` vs `"a" + "bc"`).
#[derive(Debug, Clone, Copy)]
pub struct KeyHasher(u64);

impl KeyHasher {
    pub fn new() -> KeyHasher {
        KeyHasher(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    /// Length-prefixed string.
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes())
    }

    /// Length-prefixed byte slice (bit-width vectors).
    pub fn blob(&mut self, bytes: &[u8]) -> &mut Self {
        self.u64(bytes.len() as u64);
        self.bytes(bytes)
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for KeyHasher {
    fn default() -> Self {
        KeyHasher::new()
    }
}

/// The canonical eval-cache key.  Field order is part of the wire-level
/// contract (DESIGN.md §Serve daemon — cache key definition); changing it
/// invalidates every persisted expectation, so `tests/eval_cache.rs`
/// re-derives the encoding independently.
#[allow(clippy::too_many_arguments)]
pub fn eval_key(
    backend: &str,
    model: &str,
    mode: &str,
    wbits: &[u8],
    abits: &[u8],
    data_seed: u64,
    data_noise: f32,
    split: &str,
    n_batches: usize,
    eval_batch: usize,
    param_fp: u64,
    calib_fp: u64,
) -> u64 {
    let mut h = KeyHasher::new();
    h.str(backend)
        .str(model)
        .str(mode)
        .blob(wbits)
        .blob(abits)
        .u64(data_seed)
        .u64(data_noise.to_bits() as u64)
        .str(split)
        .u64(n_batches as u64)
        .u64(eval_batch as u64)
        .u64(param_fp)
        .u64(calib_fp);
    h.finish()
}

/// Fingerprint of a parameter set: FNV-1a over every tensor's name, shape
/// and exact f32 bit patterns.  Covers "which trained weights" — and
/// therefore subsumes pretrain seed/steps — so a fine-tuned runner can
/// never alias its pre-trained ancestor.
pub fn param_fingerprint(names: &[String], tensors: &[crate::runtime::Tensor]) -> u64 {
    let mut h = KeyHasher::new();
    h.u64(names.len() as u64);
    for (name, t) in names.iter().zip(tensors) {
        h.str(name);
        h.u64(t.shape.len() as u64);
        for &d in &t.shape {
            h.u64(d as u64);
        }
        h.u64(t.data.len() as u64);
        for &x in &t.data {
            h.u64(x.to_bits() as u64);
        }
    }
    h.finish()
}

/// One cached evaluation plus the logical time of its last touch (an
/// LRU-ish recency stamp — see [`EvalCache::insert`]).
#[derive(Debug, Clone, Copy)]
struct Entry {
    result: EvalResult,
    tick: u64,
}

/// Default entry cap when `$AUTOQ_CACHE_MAX` is unset.  Entries are tiny
/// (three scalars + a stamp, ~40 bytes), so the default is generous — a
/// million entries is ~40 MB, far beyond what any sane sweep evaluates —
/// while still bounding a daemon that runs for weeks.
const DEFAULT_MAX_ENTRIES: usize = 1 << 20;

/// The daemon-wide store: one map, global hit/miss counters, and an entry
/// cap with least-recently-used eviction.  The cap comes from
/// `$AUTOQ_CACHE_MAX` (`0` = unlimited), else [`DEFAULT_MAX_ENTRIES`].
/// Eviction only ever drops entries — a surviving key still returns the
/// same byte-identical `EvalResult`, so hit/miss *semantics* and cached-
/// report byte-identity are unaffected; only the hit *rate* can change.
#[derive(Debug)]
pub struct EvalCache {
    map: Mutex<HashMap<u64, Entry>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// `u64::MAX` plays "unlimited" so the hot path is one compare.
    max_entries: usize,
    /// Logical clock: bumped on every get/insert, stamped onto entries.
    tick: AtomicU64,
}

impl EvalCache {
    pub fn new() -> EvalCache {
        let max = match std::env::var("AUTOQ_CACHE_MAX") {
            Ok(s) if !s.trim().is_empty() => match s.trim().parse::<usize>() {
                Ok(0) => usize::MAX,
                Ok(n) => n,
                Err(_) => {
                    crate::warn_!("ignoring non-numeric AUTOQ_CACHE_MAX={s:?}");
                    DEFAULT_MAX_ENTRIES
                }
            },
            _ => DEFAULT_MAX_ENTRIES,
        };
        EvalCache::with_cap(max)
    }

    /// A cache holding at most `max_entries` (tests pin small caps;
    /// `usize::MAX` = unlimited).
    pub fn with_cap(max_entries: usize) -> EvalCache {
        EvalCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            max_entries: max_entries.max(1),
            tick: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.map.lock().expect("eval cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Daemon-lifetime (hits, misses) across every worker.
    pub fn counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    fn get(&self, key: u64) -> Option<EvalResult> {
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        let hit = {
            let mut map = self.map.lock().expect("eval cache poisoned");
            map.get_mut(&key).map(|e| {
                e.tick = now; // refresh recency on hit
                e.result
            })
        };
        match hit {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn insert(&self, key: u64, result: EvalResult) {
        let now = self.tick.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().expect("eval cache poisoned");
        if map.len() >= self.max_entries && !map.contains_key(&key) {
            // At capacity: drop the oldest ~1/8 (at least one) in one
            // sweep, so eviction cost amortizes instead of running a full
            // scan per insert right at the cap.
            let drop_n = (self.max_entries / 8).max(1);
            let mut ticks: Vec<u64> = map.values().map(|e| e.tick).collect();
            ticks.sort_unstable();
            let cutoff = ticks[(drop_n - 1).min(ticks.len() - 1)];
            map.retain(|_, e| e.tick > cutoff);
            crate::debug!(
                "eval cache at cap {}: evicted {} least-recently-used entr(ies)",
                self.max_entries,
                ticks.len() - map.len()
            );
        }
        map.insert(key, Entry { result, tick: now });
    }
}

impl Default for EvalCache {
    fn default() -> EvalCache {
        EvalCache::new()
    }
}

/// One worker's view of the shared cache, with its own monotonic counters
/// so the scheduler can report per-job deltas (each worker runs jobs
/// serially, so a snapshot before/after `run_observed` is race-free).
#[derive(Debug)]
pub struct CacheHandle {
    cache: Arc<EvalCache>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CacheHandle {
    pub fn new(cache: Arc<EvalCache>) -> Arc<CacheHandle> {
        Arc::new(CacheHandle { cache, hits: AtomicU64::new(0), misses: AtomicU64::new(0) })
    }

    /// A handle over a private cache — the in-process path used by tests
    /// and `Coordinator::set_eval_cache` callers outside the daemon.
    pub fn private() -> Arc<CacheHandle> {
        CacheHandle::new(Arc::new(EvalCache::new()))
    }

    pub fn cache(&self) -> &Arc<EvalCache> {
        &self.cache
    }

    /// This handle's monotonic (hits, misses).
    pub fn counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub fn get(&self, key: u64) -> Option<EvalResult> {
        let hit = self.cache.get(key);
        match hit {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        hit
    }

    pub fn insert(&self, key: u64, result: EvalResult) {
        self.cache.insert(key, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_key() -> u64 {
        eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0)
    }

    #[test]
    fn key_is_deterministic_and_field_sensitive() {
        assert_eq!(base_key(), base_key());
        let variants = [
            eval_key("shard", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "res18", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "binar", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 5], &[4], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[5], 42, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 43, 0.85, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.9, "val", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "train", 2, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 3, 256, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 128, 77, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 78, 0),
            eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 9),
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(*v, base_key(), "variant {i} must change the key");
        }
    }

    #[test]
    fn length_prefixing_prevents_field_aliasing() {
        // Moving a bit between the two vectors must not alias.
        let a = eval_key("r", "m", "q", &[5, 4], &[3], 1, 0.0, "val", 1, 1, 0, 0);
        let b = eval_key("r", "m", "q", &[5], &[4, 3], 1, 0.0, "val", 1, 1, 0, 0);
        assert_ne!(a, b);
        let mut h1 = KeyHasher::new();
        h1.str("ab").str("c");
        let mut h2 = KeyHasher::new();
        h2.str("a").str("bc");
        assert_ne!(h1.finish(), h2.finish());
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let handle = CacheHandle::private();
        let r = EvalResult { accuracy: 0.5, loss: 1.0, images: 256 };
        assert!(handle.get(9).is_none());
        handle.insert(9, r);
        assert_eq!(handle.get(9), Some(r));
        assert_eq!(handle.counts(), (1, 1));
        assert_eq!(handle.cache().counts(), (1, 1));
        assert_eq!(handle.cache().len(), 1);
        // A second handle over the same store keeps its own counters.
        let other = CacheHandle::new(handle.cache().clone());
        assert_eq!(other.get(9), Some(r));
        assert_eq!(other.counts(), (1, 0));
        assert_eq!(handle.counts(), (1, 1));
        assert_eq!(handle.cache().counts(), (2, 1));
    }

    #[test]
    fn capped_cache_evicts_least_recently_used() {
        let cache = Arc::new(EvalCache::with_cap(8));
        let handle = CacheHandle::new(cache.clone());
        let r = |i: usize| EvalResult { accuracy: i as f64, loss: 0.0, images: 1 };
        for i in 0..8u64 {
            handle.insert(i, r(i as usize));
        }
        assert_eq!(cache.len(), 8);
        // Touch key 0 so it is the most recently used, then overflow.
        assert!(handle.get(0).is_some());
        handle.insert(100, r(100));
        // The cap holds, the recently-touched key survives, the stalest
        // keys (1, 2, ...) are the ones that went.
        assert!(cache.len() <= 8);
        assert!(handle.get(0).is_some(), "recently-used entry must survive eviction");
        assert!(handle.get(100).is_some(), "the new entry must be present");
        assert!(handle.get(1).is_none(), "the least-recently-used entry must be gone");
        // Semantics of surviving entries are untouched.
        assert_eq!(handle.get(0).unwrap(), r(0));
    }

    #[test]
    fn reinserting_an_existing_key_never_evicts() {
        let cache = Arc::new(EvalCache::with_cap(4));
        let handle = CacheHandle::new(cache.clone());
        let r = EvalResult { accuracy: 0.1, loss: 0.2, images: 3 };
        for i in 0..4u64 {
            handle.insert(i, r);
        }
        for _ in 0..10 {
            handle.insert(2, r); // overwrite in place, no eviction sweep
        }
        assert_eq!(cache.len(), 4);
        for i in 0..4u64 {
            assert!(handle.get(i).is_some(), "key {i} must still be cached");
        }
    }

    #[test]
    fn param_fingerprint_tracks_content() {
        use crate::runtime::Tensor;
        let names = vec!["l1.w".to_string()];
        let t = |x: f32| vec![Tensor::new(vec![2], vec![x, 1.0])];
        let a = param_fingerprint(&names, &t(0.5));
        assert_eq!(a, param_fingerprint(&names, &t(0.5)));
        assert_ne!(a, param_fingerprint(&names, &t(0.25)));
        assert_ne!(a, param_fingerprint(&["l2.w".to_string()], &t(0.5)));
        // -0.0 and 0.0 are distinct bit patterns on purpose.
        assert_ne!(param_fingerprint(&names, &t(0.0)), param_fingerprint(&names, &t(-0.0)));
    }
}
