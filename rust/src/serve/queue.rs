//! The daemon's job queue: submissions in, FIFO scheduling out, terminal
//! states and event fan-out in between.
//!
//! One mutex + condvar guards everything; every state change does a
//! `notify_all`, so scheduler workers blocked in [`JobQueue::next_job`] and
//! connection handlers blocked in [`JobQueue::wait_terminal`] both wake on
//! the transitions they care about.  Job handles are queue-assigned
//! (`job-<seq>`), not spec ids — two clients may legitimately submit the
//! same spec (that is what the eval cache is for) and each must be able to
//! query its own submission.
//!
//! Shutdown has two flavors (DESIGN.md §Serve daemon):
//!   * **drain** (`shutdown` op default): no new submissions, workers run
//!     the queue dry, then exit.
//!   * **now** (SIGINT/SIGTERM): queued jobs are cancelled, in-flight jobs
//!     finish — the daemon never kills a running job half way.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};

use crate::coordinator::JobSpec;
use crate::journal::{fingerprint, ByteReader, ByteWriter, DurableLog};
use crate::util::json::Json;

/// Lifecycle of one submission.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    Queued,
    Running,
    /// Finished OK: the verbatim `JobReport::to_json()` plus this job's
    /// cache (hits, misses) delta — kept outside the report on purpose.
    Done { report: Json, cache: (u64, u64) },
    /// Finished with a structured error.
    Failed { error: String, cache: (u64, u64) },
    /// Never ran (immediate shutdown or explicit drain cancel).
    Cancelled,
    /// Still terminal, but the heavyweight payload (report/error) was
    /// dropped by the `$AUTOQ_QUEUE_RETAIN` retention cap.  `was` keeps
    /// the original terminal name so `status` output is unchanged;
    /// `result`/`subscribe` answer a structured "evicted" error.
    Evicted { was: &'static str },
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done { .. } => "done",
            JobState::Failed { .. } => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Evicted { was } => was,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Done { .. }
                | JobState::Failed { .. }
                | JobState::Cancelled
                | JobState::Evicted { .. }
        )
    }
}

/// Default retained terminal payloads when `$AUTOQ_QUEUE_RETAIN` is unset
/// — generous (reports are a few KB; 4096 of them is ~tens of MB) while
/// still bounding a daemon that runs for weeks.
const DEFAULT_QUEUE_RETAIN: usize = 4096;

/// Resolve the retention cap from `$AUTOQ_QUEUE_RETAIN` (`0` = unlimited).
fn retain_from_env() -> usize {
    match std::env::var("AUTOQ_QUEUE_RETAIN") {
        Ok(s) if !s.trim().is_empty() => match s.trim().parse::<usize>() {
            Ok(0) => usize::MAX,
            Ok(n) => n,
            Err(_) => {
                crate::warn_!("ignoring non-numeric AUTOQ_QUEUE_RETAIN={s:?}");
                DEFAULT_QUEUE_RETAIN
            }
        },
        _ => DEFAULT_QUEUE_RETAIN,
    }
}

// Journal payload state bytes (DESIGN.md §Durable jobs — job records).
const JR_SUBMITTED: u8 = 0;
const JR_DONE: u8 = 1;
const JR_FAILED: u8 = 2;
const JR_CANCELLED: u8 = 3;

/// Encode one job-journal payload: lifecycle byte, spec JSON, terminal
/// payload (report JSON / error text / empty), cache delta.
fn encode_job_record(state: u8, spec_json: &str, payload: &str, cache: (u64, u64)) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u8(state);
    w.put_str(spec_json);
    w.put_str(payload);
    w.put_u64(cache.0);
    w.put_u64(cache.1);
    w.into_vec()
}

/// Decode [`encode_job_record`] output back into `(state byte, spec JSON,
/// payload, cache delta)`.
fn decode_job_record(bytes: &[u8]) -> anyhow::Result<(u8, String, String, (u64, u64))> {
    let mut r = ByteReader::new(bytes);
    let state = r.u8()?;
    let spec_json = r.str()?.to_string();
    let payload = r.str()?.to_string();
    let cache = (r.u64()?, r.u64()?);
    r.finish()?;
    Ok((state, spec_json, payload, cache))
}

struct JobEntry {
    handle: String,
    spec: JobSpec,
    state: JobState,
    /// Connection id of the submitting client (`autoq status` reports
    /// per-client cache hit/miss totals).
    client: u64,
    /// Live event subscribers; senders whose receiver hung up are pruned
    /// on the next publish.
    subscribers: Vec<mpsc::Sender<Json>>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Shutdown {
    No,
    /// Run the queue dry, then stop.
    Drain,
    /// Cancel queued jobs, finish in-flight ones, stop.
    Now,
}

struct Inner {
    jobs: Vec<JobEntry>,
    pending: VecDeque<usize>,
    running: usize,
    shutdown: Shutdown,
    /// Accumulated eval-cache (hits, misses) per submitting client,
    /// summed from each finished job's delta (BTreeMap so status output
    /// is in stable client-id order).
    client_totals: BTreeMap<u64, (u64, u64)>,
    /// Durable job journal (DESIGN.md §Durable jobs): submissions and
    /// terminal states append under the queue lock, so record order always
    /// matches state order.  `None` = ephemeral queue (tests, embedders).
    journal: Option<DurableLog>,
}

impl Inner {
    /// Append a job-journal record keyed by the job's handle; append
    /// failures are logged, never fatal — the queue keeps serving and the
    /// worst case is a re-run after restart.
    fn journal_job(&mut self, idx: usize, state: u8, payload: &str, cache: (u64, u64)) {
        let spec_json = self.jobs[idx].spec.to_json().to_string();
        let handle = self.jobs[idx].handle.clone();
        if let Some(log) = self.journal.as_mut() {
            let fp = fingerprint(spec_json.as_bytes());
            let rec = encode_job_record(state, &spec_json, payload, cache);
            if let Err(e) = log.record_done(&handle, fp, &rec) {
                crate::warn_!("job journal append failed for {handle}: {e:#}");
            }
        }
    }

    /// Enforce the retention cap: beyond `retain` heavyweight terminal
    /// payloads, the oldest are swapped to [`JobState::Evicted`] in place —
    /// entries are never removed, so `job-<idx>` indexing stays valid.
    fn apply_retention(&mut self, retain: usize) {
        let heavy: Vec<usize> = self
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| matches!(j.state, JobState::Done { .. } | JobState::Failed { .. }))
            .map(|(i, _)| i)
            .collect();
        if heavy.len() > retain {
            for &i in &heavy[..heavy.len() - retain] {
                let was = self.jobs[i].state.name();
                self.jobs[i].state = JobState::Evicted { was };
            }
        }
    }
}

pub struct JobQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    /// Max terminal jobs whose report/error payload is kept in memory
    /// (`$AUTOQ_QUEUE_RETAIN`; `usize::MAX` = unlimited).
    retain: usize,
}

impl Default for JobQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl JobQueue {
    pub fn new() -> JobQueue {
        Self::with_parts(retain_from_env(), None)
    }

    /// A queue with an explicit retention cap (tests pin small caps).
    pub fn with_retain(retain: usize) -> JobQueue {
        Self::with_parts(retain.max(1), None)
    }

    fn with_parts(retain: usize, journal: Option<DurableLog>) -> JobQueue {
        JobQueue {
            inner: Mutex::new(Inner {
                jobs: Vec::new(),
                pending: VecDeque::new(),
                running: 0,
                shutdown: Shutdown::No,
                client_totals: BTreeMap::new(),
                journal,
            }),
            cv: Condvar::new(),
            retain: retain.max(1),
        }
    }

    /// A queue backed by a durable job journal at `path`: prior sessions'
    /// jobs are replayed into their original `job-<idx>` slots (jobs that
    /// were submitted but never reached a terminal state come back as
    /// `Failed` — the daemon restarted under them), and every new
    /// submission/terminal transition appends a record.  Returns the queue
    /// plus how many jobs were restored.
    pub fn with_journal(path: &Path) -> anyhow::Result<(JobQueue, usize)> {
        let log = DurableLog::open(path)?;
        let q = Self::with_parts(retain_from_env(), Some(log));
        let restored = {
            let mut g = q.inner.lock().expect("job queue poisoned");
            Self::restore_from_journal(&mut g)?
        };
        if restored > 0 {
            let mut g = q.inner.lock().expect("job queue poisoned");
            g.apply_retention(q.retain);
        }
        Ok((q, restored))
    }

    /// Rebuild the jobs vec from the journal's done map.  Handles are
    /// `job-<idx>`; records replay into exactly those slots so handles
    /// issued before the restart still resolve.
    fn restore_from_journal(g: &mut Inner) -> anyhow::Result<usize> {
        let Some(log) = g.journal.as_ref() else { return Ok(0) };
        let mut rows: Vec<(usize, Vec<u8>)> = Vec::new();
        for (id, payload) in log.done_entries() {
            let Some(idx) = id.strip_prefix("job-").and_then(|n| n.parse::<usize>().ok()) else {
                crate::warn_!("job journal holds foreign id {id:?} — skipping");
                continue;
            };
            rows.push((idx, payload.to_vec()));
        }
        rows.sort_by_key(|(idx, _)| *idx);
        for (idx, rec) in rows {
            let (state, spec_json, payload, cache) = match decode_job_record(&rec) {
                Ok(parts) => parts,
                Err(e) => {
                    crate::warn_!("job journal record for job-{idx} is malformed: {e:#}");
                    continue;
                }
            };
            let spec = match Json::parse(&spec_json)
                .map_err(anyhow::Error::msg)
                .and_then(|j| crate::serve::wire::job_from_json(&j))
            {
                Ok(s) => s,
                Err(e) => {
                    crate::warn_!("job journal spec for job-{idx} no longer parses: {e:#}");
                    continue;
                }
            };
            let state = match state {
                JR_DONE => match Json::parse(&payload) {
                    Ok(report) => JobState::Done { report, cache },
                    Err(e) => JobState::Failed {
                        error: format!("journaled report no longer parses: {e}"),
                        cache,
                    },
                },
                JR_FAILED => JobState::Failed { error: payload, cache },
                JR_CANCELLED => JobState::Cancelled,
                // Submitted (or unknown lifecycle byte) without a terminal
                // record: the daemon died under it.
                _ => JobState::Failed {
                    error: "daemon restarted before the job finished".to_string(),
                    cache: (0, 0),
                },
            };
            // Fill any gap with cancelled placeholders so `job-<idx>`
            // stays an index (a torn journal tail can only lose a suffix,
            // but stay robust anyway).
            while g.jobs.len() < idx {
                let h = format!("job-{}", g.jobs.len());
                g.jobs.push(JobEntry {
                    handle: h,
                    spec: spec.clone(),
                    state: JobState::Cancelled,
                    client: 0,
                    subscribers: Vec::new(),
                });
            }
            g.jobs.push(JobEntry {
                handle: format!("job-{idx}"),
                spec,
                state,
                client: 0,
                subscribers: Vec::new(),
            });
        }
        Ok(g.jobs.len())
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("job queue poisoned")
    }

    /// Durability info for `status`: `(journal path, newest-record age in
    /// seconds, journaled job count)`.  `None` when the queue is ephemeral.
    pub fn journal_info(&self) -> Option<(PathBuf, Option<u64>, usize)> {
        let g = self.lock();
        let log = g.journal.as_ref()?;
        Some((log.path().to_path_buf(), log.age_secs(), log.done_len()))
    }

    /// Enqueue a validated spec from connection `client`; returns the
    /// queue-assigned handle.  Rejected once shutdown has begun.
    pub fn submit(&self, spec: JobSpec, client: u64) -> anyhow::Result<String> {
        let mut g = self.lock();
        anyhow::ensure!(g.shutdown == Shutdown::No, "daemon is shutting down");
        let idx = g.jobs.len();
        let handle = format!("job-{idx}");
        g.jobs.push(JobEntry {
            handle: handle.clone(),
            spec,
            state: JobState::Queued,
            client,
            subscribers: Vec::new(),
        });
        g.pending.push_back(idx);
        g.journal_job(idx, JR_SUBMITTED, "", (0, 0));
        drop(g);
        self.cv.notify_all();
        Ok(handle)
    }

    /// Blocking FIFO dequeue for scheduler workers.  Marks the job Running
    /// and returns `(index, spec)`; `None` means "shut down" — either the
    /// queue ran dry under a drain, or an immediate shutdown was requested.
    pub fn next_job(&self) -> Option<(usize, JobSpec)> {
        let mut g = self.lock();
        loop {
            if g.shutdown == Shutdown::Now {
                return None;
            }
            if let Some(idx) = g.pending.pop_front() {
                g.jobs[idx].state = JobState::Running;
                g.running += 1;
                let spec = g.jobs[idx].spec.clone();
                drop(g);
                self.cv.notify_all();
                return Some((idx, spec));
            }
            if g.shutdown == Shutdown::Drain {
                return None;
            }
            g = self.cv.wait(g).expect("job queue poisoned");
        }
    }

    /// Record a job's terminal state and fan the `finished` event out to
    /// its subscribers.
    pub fn finish(&self, idx: usize, outcome: Result<Json, String>, cache: (u64, u64)) {
        let event = crate::serve::wire::event_finished(
            &format!("job-{idx}"),
            &outcome,
            cache,
        );
        let mut g = self.lock();
        let (state, jr, payload) = match outcome {
            Ok(report) => {
                let body = report.to_string();
                (JobState::Done { report, cache }, JR_DONE, body)
            }
            Err(error) => {
                let body = error.clone();
                (JobState::Failed { error, cache }, JR_FAILED, body)
            }
        };
        g.jobs[idx].state = state;
        g.journal_job(idx, jr, &payload, cache);
        g.apply_retention(self.retain);
        let client = g.jobs[idx].client;
        let t = g.client_totals.entry(client).or_insert((0, 0));
        t.0 += cache.0;
        t.1 += cache.1;
        g.running -= 1;
        let subs: Vec<mpsc::Sender<Json>> = std::mem::take(&mut g.jobs[idx].subscribers);
        drop(g);
        for sub in subs {
            let _ = sub.send(event.clone());
        }
        self.cv.notify_all();
    }

    /// Fan a progress event (started/episode/message) out to subscribers.
    /// `publish` and `finish` for one job are only ever called from the
    /// worker running that job, so taking the subscriber list out of the
    /// lock for the sends cannot race a concurrent `finish`.
    pub fn publish(&self, idx: usize, event: Json) {
        let mut g = self.lock();
        let subs = std::mem::take(&mut g.jobs[idx].subscribers);
        drop(g);
        let mut live: Vec<mpsc::Sender<Json>> = subs
            .into_iter()
            .filter(|sub| sub.send(event.clone()).is_ok())
            .collect();
        let mut g = self.lock();
        g.jobs[idx].subscribers.append(&mut live);
    }

    /// Register an event subscriber.  Terminal jobs get their `finished`
    /// event replayed immediately, so subscribing is never a lost race.
    pub fn subscribe(&self, handle: &str, sender: mpsc::Sender<Json>) -> anyhow::Result<()> {
        let mut g = self.lock();
        let idx = Self::index_of(&g, handle)?;
        match &g.jobs[idx].state {
            JobState::Done { report, cache } => {
                let ev =
                    crate::serve::wire::event_finished(handle, &Ok(report.clone()), *cache);
                let _ = sender.send(ev);
            }
            JobState::Failed { error, cache } => {
                let ev =
                    crate::serve::wire::event_finished(handle, &Err(error.clone()), *cache);
                let _ = sender.send(ev);
            }
            JobState::Cancelled => {
                let ev = crate::serve::wire::event_finished(
                    handle,
                    &Err("job was cancelled".to_string()),
                    (0, 0),
                );
                let _ = sender.send(ev);
            }
            JobState::Evicted { was } => {
                let ev = crate::serve::wire::event_finished(
                    handle,
                    &Err(format!(
                        "job ended {was} but its result was evicted by the retention cap \
                         (AUTOQ_QUEUE_RETAIN)"
                    )),
                    (0, 0),
                );
                let _ = sender.send(ev);
            }
            _ => g.jobs[idx].subscribers.push(sender),
        }
        Ok(())
    }

    fn index_of(g: &Inner, handle: &str) -> anyhow::Result<usize> {
        g.jobs
            .iter()
            .position(|j| j.handle == handle)
            .ok_or_else(|| anyhow::anyhow!("unknown job {handle:?}"))
    }

    /// One job's `(spec id, state)` snapshot.
    pub fn state_of(&self, handle: &str) -> anyhow::Result<(String, JobState)> {
        let g = self.lock();
        let idx = Self::index_of(&g, handle)?;
        Ok((g.jobs[idx].spec.id(), g.jobs[idx].state.clone()))
    }

    /// Block until `handle` reaches a terminal state; returns it.
    pub fn wait_terminal(&self, handle: &str) -> anyhow::Result<(String, JobState)> {
        let mut g = self.lock();
        let idx = Self::index_of(&g, handle)?;
        while !g.jobs[idx].state.is_terminal() {
            g = self.cv.wait(g).expect("job queue poisoned");
        }
        Ok((g.jobs[idx].spec.id(), g.jobs[idx].state.clone()))
    }

    /// `(handle, spec id, state name)` rows for the status op, submission
    /// order.
    pub fn snapshot(&self) -> Vec<(String, String, &'static str)> {
        let g = self.lock();
        g.jobs
            .iter()
            .map(|j| (j.handle.clone(), j.spec.id(), j.state.name()))
            .collect()
    }

    /// Per-client `(client id, hits, misses)` eval-cache totals, summed
    /// over each client's finished jobs, ascending client id.
    pub fn client_totals(&self) -> Vec<(u64, u64, u64)> {
        let g = self.lock();
        g.client_totals.iter().map(|(&c, &(h, m))| (c, h, m)).collect()
    }

    /// Counts of (queued, running, finished) jobs.
    pub fn load(&self) -> (usize, usize, usize) {
        let g = self.lock();
        let queued = g.pending.len();
        let done = g.jobs.len() - queued - g.running;
        (queued, g.running, done)
    }

    /// Begin shutdown.  `drain` keeps queued jobs; otherwise they are
    /// cancelled (their subscribers get a terminal event).
    pub fn begin_shutdown(&self, drain: bool) {
        let mut g = self.lock();
        // Never downgrade Now back to Drain (signal beats a later op).
        if g.shutdown == Shutdown::No || (g.shutdown == Shutdown::Drain && !drain) {
            g.shutdown = if drain { Shutdown::Drain } else { Shutdown::Now };
        }
        let mut cancelled: Vec<(usize, Vec<mpsc::Sender<Json>>)> = Vec::new();
        if g.shutdown == Shutdown::Now {
            while let Some(idx) = g.pending.pop_front() {
                g.jobs[idx].state = JobState::Cancelled;
                g.journal_job(idx, JR_CANCELLED, "", (0, 0));
                cancelled.push((idx, std::mem::take(&mut g.jobs[idx].subscribers)));
            }
        }
        drop(g);
        for (idx, subs) in cancelled {
            let ev = crate::serve::wire::event_finished(
                &format!("job-{idx}"),
                &Err("job was cancelled by shutdown".to_string()),
                (0, 0),
            );
            for sub in subs {
                let _ = sub.send(ev.clone());
            }
        }
        self.cv.notify_all();
    }

    pub fn shutting_down(&self) -> bool {
        self.lock().shutdown != Shutdown::No
    }

    /// Block until shutdown has begun **and** nothing is queued or running
    /// (the `shutdown` op responds only once the daemon is quiescent).
    pub fn wait_drained(&self) {
        let mut g = self.lock();
        while g.shutdown == Shutdown::No || g.running > 0 || !g.pending.is_empty() {
            g = self.cv.wait(g).expect("job queue poisoned");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec::eval("cif10").batches(1).build().unwrap()
    }

    #[test]
    fn fifo_order_and_states() {
        let q = JobQueue::new();
        let a = q.submit(spec(), 0).unwrap();
        let b = q.submit(spec(), 0).unwrap();
        assert_eq!((a.as_str(), b.as_str()), ("job-0", "job-1"));
        assert_eq!(q.load(), (2, 0, 0));
        let (i0, _) = q.next_job().unwrap();
        assert_eq!(i0, 0);
        assert_eq!(q.state_of(&a).unwrap().1, JobState::Running);
        q.finish(i0, Ok(Json::Null), (3, 1));
        let (_, st) = q.state_of(&a).unwrap();
        assert_eq!(st.name(), "done");
        let JobState::Done { cache, .. } = st else { panic!() };
        assert_eq!(cache, (3, 1));
        assert_eq!(q.state_of(&b).unwrap().1, JobState::Queued);
        assert!(q.state_of("job-9").is_err());
    }

    #[test]
    fn drain_shutdown_runs_queue_dry_then_stops() {
        let q = std::sync::Arc::new(JobQueue::new());
        q.submit(spec(), 0).unwrap();
        q.submit(spec(), 1).unwrap();
        q.begin_shutdown(true);
        assert!(q.submit(spec(), 2).is_err(), "submissions rejected after shutdown");
        let (i, _) = q.next_job().unwrap();
        q.finish(i, Err("x".into()), (0, 0));
        let (i, _) = q.next_job().unwrap();
        q.finish(i, Ok(Json::Null), (0, 0));
        assert!(q.next_job().is_none(), "dry queue + drain = stop");
        q.wait_drained(); // must not block
    }

    #[test]
    fn immediate_shutdown_cancels_queued_jobs() {
        let q = JobQueue::new();
        let a = q.submit(spec(), 0).unwrap();
        let (i, _) = q.next_job().unwrap();
        let b = q.submit(spec(), 0).unwrap();
        q.begin_shutdown(false);
        assert!(q.next_job().is_none());
        assert_eq!(q.state_of(&b).unwrap().1, JobState::Cancelled);
        // In-flight job still finishes and is recorded.
        q.finish(i, Ok(Json::Null), (0, 0));
        assert_eq!(q.state_of(&a).unwrap().1.name(), "done");
        // A later drain request must not resurrect the queue.
        q.begin_shutdown(true);
        assert!(q.next_job().is_none());
    }

    #[test]
    fn wait_terminal_blocks_until_finish() {
        let q = std::sync::Arc::new(JobQueue::new());
        let h = q.submit(spec(), 0).unwrap();
        let (i, _) = q.next_job().unwrap();
        let q2 = q.clone();
        let h2 = h.clone();
        let waiter = std::thread::spawn(move || q2.wait_terminal(&h2).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.finish(i, Ok(Json::Bool(true)), (1, 0));
        let (_, st) = waiter.join().unwrap();
        let JobState::Done { report, cache } = st else { panic!("not done") };
        assert_eq!(report, Json::Bool(true));
        assert_eq!(cache, (1, 0));
    }

    #[test]
    fn subscribers_get_live_and_replayed_events() {
        let q = JobQueue::new();
        let h = q.submit(spec(), 0).unwrap();
        let (i, _) = q.next_job().unwrap();
        let (tx, rx) = mpsc::channel();
        q.subscribe(&h, tx).unwrap();
        q.publish(i, Json::Str("ev".into()));
        assert_eq!(rx.recv().unwrap(), Json::Str("ev".into()));
        q.finish(i, Ok(Json::Null), (0, 0));
        let fin = rx.recv().unwrap();
        assert_eq!(fin.req("event").unwrap().as_str(), Some("finished"));
        // Late subscriber: terminal event replays immediately.
        let (tx2, rx2) = mpsc::channel();
        q.subscribe(&h, tx2).unwrap();
        let fin = rx2.recv().unwrap();
        assert_eq!(fin.req("event").unwrap().as_str(), Some("finished"));
        assert_eq!(fin.req("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn retention_cap_evicts_oldest_terminal_payloads() {
        let q = JobQueue::with_retain(2);
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(q.submit(spec(), 0).unwrap());
        }
        for _ in 0..4 {
            let (i, _) = q.next_job().unwrap();
            q.finish(i, Ok(Json::Bool(true)), (0, 0));
        }
        // Oldest two payloads evicted; status name and terminality kept.
        let (_, st) = q.state_of(&handles[0]).unwrap();
        assert_eq!(st, JobState::Evicted { was: "done" });
        assert_eq!(st.name(), "done");
        assert!(st.is_terminal());
        let (_, st) = q.state_of(&handles[3]).unwrap();
        assert!(matches!(st, JobState::Done { .. }), "newest results must survive");
        // Late subscribe on an evicted job answers a structured error event.
        let (tx, rx) = mpsc::channel();
        q.subscribe(&handles[0], tx).unwrap();
        let ev = rx.recv().unwrap();
        assert_eq!(ev.req("ok").unwrap().as_bool(), Some(false));
        assert!(ev.req("error").unwrap().as_str().unwrap().contains("evicted"));
    }

    #[test]
    fn journal_restores_jobs_across_restart() {
        let path = std::env::temp_dir()
            .join(format!("autoq_queue_restart_{}.journal", std::process::id()));
        std::fs::remove_file(&path).ok();
        {
            let (q, restored) = JobQueue::with_journal(&path).unwrap();
            assert_eq!(restored, 0);
            assert_eq!(q.submit(spec(), 0).unwrap(), "job-0");
            assert_eq!(q.submit(spec(), 0).unwrap(), "job-1");
            let (i, _) = q.next_job().unwrap();
            q.finish(i, Ok(Json::Bool(true)), (2, 1));
            // job-1 never reaches a terminal state — the "crash" is here.
        }
        let (q, restored) = JobQueue::with_journal(&path).unwrap();
        assert_eq!(restored, 2);
        let (_, st) = q.state_of("job-0").unwrap();
        let JobState::Done { report, cache } = st else { panic!("job-0 not done: {st:?}") };
        assert_eq!(report, Json::Bool(true));
        assert_eq!(cache, (2, 1));
        let (_, st) = q.state_of("job-1").unwrap();
        let JobState::Failed { error, .. } = st else { panic!("job-1 must fail on restart") };
        assert!(error.contains("restarted"), "{error}");
        // New submissions continue after the restored slots.
        assert_eq!(q.submit(spec(), 0).unwrap(), "job-2");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn client_totals_accumulate_per_submitter() {
        let q = JobQueue::new();
        assert!(q.client_totals().is_empty());
        q.submit(spec(), 7).unwrap();
        q.submit(spec(), 3).unwrap();
        q.submit(spec(), 7).unwrap();
        // Nothing counted until a job finishes.
        assert!(q.client_totals().is_empty());
        let (i0, _) = q.next_job().unwrap();
        q.finish(i0, Ok(Json::Null), (2, 1));
        let (i1, _) = q.next_job().unwrap();
        q.finish(i1, Err("boom".into()), (0, 4));
        let (i2, _) = q.next_job().unwrap();
        q.finish(i2, Ok(Json::Null), (5, 0));
        // Sorted by client id; failed jobs still count their delta.
        assert_eq!(q.client_totals(), vec![(3, 0, 4), (7, 7, 1)]);
    }
}
