//! The `autoq serve` daemon: a TCP accept loop, a pool of scheduler
//! workers, and the shared content-addressed eval cache.
//!
//! Threading model:
//!   * the caller's thread runs [`Server::run`]: a non-blocking accept loop
//!     that polls the shutdown flag between accepts;
//!   * each connection gets a handler thread speaking the length-prefixed
//!     frame protocol (`runtime::shard::proto`);
//!   * `workers` scheduler threads each own a full `Coordinator` (and so a
//!     runtime — PJRT executables are not shared across threads, mirroring
//!     `Sweep`) and pull jobs FIFO from the [`JobQueue`].
//!
//! Thread budget: unless `--threads` pins a per-worker budget, the
//! machine's budget is split evenly across the scheduler workers via
//! [`Parallelism::share_of`] — the same no-oversubscription rule as
//! `Sweep` and the shard pool, so `workers × threads` (or, on the shard
//! backend, `workers × processes × threads`) stays inside one machine.
//!
//! Model pre-training is serialized by a warm lock: the first job that
//! needs a model's params trains them while every other worker needing the
//! same model waits, then loads the persisted bytes — workers never race a
//! pretrain (same invariant `Sweep::run` establishes with its serial
//! pre-warm phase).
//!
//! Shutdown: SIGINT/SIGTERM (via `util::signal`) or a `shutdown` op stop
//! the accept loop, cancel or drain queued jobs ([`JobQueue`]'s two
//! flavors), let in-flight jobs finish, then join the workers — dropping
//! each worker's `Coordinator`, whose shard pool `Drop` sends exit frames
//! to its worker processes.  No job is ever killed mid-run and no `autoq
//! worker` subprocess is orphaned.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::coordinator::{Coordinator, FanOut, JobKind, JobSpec, LogObserver, Observer};
use crate::runtime::shard::proto::{read_frame, write_frame};
use crate::runtime::{BackendKind, Parallelism, RuntimeOpts};
use crate::search::EpisodeStats;
use crate::serve::cache::{CacheHandle, EvalCache};
use crate::serve::queue::{JobQueue, JobState};
use crate::serve::wire::{self, ServeRequest};
use crate::util::json::Json;

/// How the daemon opens its coordinators (mirrors the CLI's shared
/// `--backend`/`--threads`/`--shard-*` knobs plus `--workers`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifact directory every scheduler worker opens.
    pub dir: PathBuf,
    /// Execution backend (`None` = auto-resolve).
    pub backend: Option<BackendKind>,
    /// Per-worker eval threads (`None` = split the machine budget evenly
    /// across workers via `Parallelism::share_of`).
    pub threads: Option<Parallelism>,
    /// Shard worker processes per scheduler worker (shard backend only).
    pub shard_workers: Option<usize>,
    /// Remote `autoq worker --listen` hosts for the shard backend (`None`
    /// = `$AUTOQ_SHARD_HOSTS`).  Resolved once, then round-robined into
    /// disjoint per-scheduler-worker buckets — a listening worker serves
    /// one session at a time, so daemon workers must not share hosts.
    pub shard_hosts: Option<Vec<String>>,
    /// Shard wire encoding (`None` = `$AUTOQ_SHARD_ENCODING`, else binary).
    pub shard_encoding: Option<crate::runtime::shard::Encoding>,
    /// Scheduler workers (concurrent jobs).
    pub workers: usize,
    /// Per-connection read timeout: a client silent this long is dropped
    /// cleanly while the daemon keeps serving (`None` = wait forever).
    /// Generous by default — `submit --wait` round-trips legitimately sit
    /// idle for the length of a job.
    pub idle_timeout: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            dir: crate::runtime::Runtime::default_dir(),
            backend: None,
            threads: None,
            shard_workers: None,
            shard_hosts: None,
            shard_encoding: None,
            workers: 2,
            idle_timeout: Some(Duration::from_secs(600)),
        }
    }
}

/// Per-worker inner thread budget under one shared machine budget —
/// `Sweep::inner_budget`'s rule, applied to the daemon's worker pool.
pub fn worker_thread_budget(
    threads: Option<Parallelism>,
    workers: usize,
) -> anyhow::Result<Parallelism> {
    Ok(match threads {
        Some(p) => p,
        None => Parallelism::share_of(Parallelism::resolve(None)?.get(), workers),
    })
}

pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    cfg: ServeConfig,
    queue: Arc<JobQueue>,
    cache: Arc<EvalCache>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind the listen socket (port 0 picks a free port — tests and
    /// `--listen 127.0.0.1:0` both rely on the resolved address being
    /// printed/queryable before any client connects).
    pub fn bind(listen: &str, cfg: ServeConfig) -> anyhow::Result<Server> {
        anyhow::ensure!(cfg.workers >= 1, "serve needs at least one worker");
        let listener = TcpListener::bind(listen)
            .map_err(|e| anyhow::anyhow!("cannot listen on {listen}: {e}"))?;
        let addr = listener.local_addr()?;
        // Durable state lives under `<dir>/serve/`: the job journal (every
        // submitted/terminal job) and the eval cache's disk tier.  A dir
        // that cannot hold them degrades to in-memory-only with a warning —
        // a read-only artifacts mount must not keep the daemon down.
        let (queue, cache) = match open_durable(&cfg.dir) {
            Ok(pair) => pair,
            Err(e) => {
                crate::warn_!(
                    "serve: durability disabled ({e:#}); jobs and cached evals will not \
                     survive a restart"
                );
                (JobQueue::new(), EvalCache::new())
            }
        };
        Ok(Server {
            listener,
            addr,
            cfg,
            queue: Arc::new(queue),
            cache: Arc::new(cache),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared queue handle — lets embedders/tests inspect job states after
    /// `run` returns.
    pub fn queue(&self) -> Arc<JobQueue> {
        self.queue.clone()
    }

    /// Shared cache handle (global hit/miss counters).
    pub fn cache(&self) -> Arc<EvalCache> {
        self.cache.clone()
    }

    /// Flag that stops the accept loop; trip it from another thread (or
    /// let SIGINT/SIGTERM do it through `util::signal`).
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Serve until shutdown, then drain and return.  Consumes the server:
    /// when this returns, every scheduler worker has exited and every
    /// shard subprocess has been told to exit.
    pub fn run(self) -> anyhow::Result<()> {
        let inner = worker_thread_budget(self.cfg.threads, self.cfg.workers)?;
        crate::info!(
            "serve: listening on {} with {} worker(s) × {} eval thread(s), backend {:?}",
            self.addr,
            self.cfg.workers,
            inner.get(),
            self.cfg.backend
        );
        let warm_lock = Arc::new(Mutex::new(()));
        let conns = Arc::new(AtomicUsize::new(0));
        // Monotone connection ids: each accepted client gets the next one,
        // and every job it submits is tagged with it so `status` can report
        // per-client cache hit/miss totals.
        let next_client = AtomicU64::new(0);
        // Resolve the remote shard-host list once, then deal disjoint
        // buckets to the scheduler workers (single-session listeners must
        // not be shared — two pools on one host would serialize).
        let shard_hosts = crate::runtime::shard::resolve_hosts(self.cfg.shard_hosts.clone())?;
        let host_parts = crate::runtime::shard::partition_hosts(&shard_hosts, self.cfg.workers);
        std::thread::scope(|s| -> anyhow::Result<()> {
            // Scheduler workers.
            for wid in 0..self.cfg.workers {
                let queue = self.queue.clone();
                let cache = self.cache.clone();
                let warm_lock = warm_lock.clone();
                let cfg = self.cfg.clone();
                let hosts = host_parts[wid].clone();
                s.spawn(move || worker_loop(wid, &cfg, inner, hosts, queue, cache, warm_lock));
            }

            // Accept loop: non-blocking so the shutdown flag is honoured
            // within one poll interval even when no client ever connects.
            self.listener.set_nonblocking(true)?;
            loop {
                if self.stop.load(Ordering::SeqCst)
                    || crate::util::signal::shutdown_requested()
                {
                    // Signal path: cancel queued jobs, finish in-flight.
                    self.queue.begin_shutdown(false);
                    break;
                }
                if self.queue.shutting_down() {
                    // `shutdown` op path: the handler already chose a flavor.
                    break;
                }
                match self.listener.accept() {
                    Ok((stream, peer)) => {
                        let client = next_client.fetch_add(1, Ordering::SeqCst);
                        crate::debug!("serve: connection {client} from {peer}");
                        let queue = self.queue.clone();
                        let cache = self.cache.clone();
                        let conns = conns.clone();
                        let idle = self.cfg.idle_timeout;
                        conns.fetch_add(1, Ordering::SeqCst);
                        // Detached, not scoped: a client idling in
                        // `read_frame` must not hold the shutdown join
                        // hostage — the grace loop below waits briefly for
                        // handlers still writing a response, then exits.
                        std::thread::spawn(move || {
                            match handle_connection(stream, idle, client, &queue, &cache) {
                                Ok(()) => {}
                                // A stalled client is a clean drop, not a
                                // failure — the daemon keeps serving.
                                Err(e) if crate::runtime::shard::proto::is_timeout(&e) => {
                                    crate::debug!(
                                        "serve: dropping idle connection from {peer}"
                                    );
                                }
                                Err(e) => crate::debug!("serve: connection ended: {e:#}"),
                            }
                            conns.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(25));
                    }
                    Err(e) => {
                        self.queue.begin_shutdown(false);
                        anyhow::bail!("accept failed: {e}");
                    }
                }
            }
            crate::info!("serve: shutting down — draining in-flight jobs");
            // Workers exit via `next_job() == None`; their `Coordinator`s
            // drop here, sending exit frames to any shard subprocesses.
            // (The scope joins the worker threads automatically.)
            Ok(())
        })?;
        // Give response-writing handler threads a moment to flush before
        // the process exits; a handler stuck on an idle client does not
        // hold the daemon open.
        for _ in 0..80 {
            if conns.load(Ordering::SeqCst) == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
        let abandoned = conns.load(Ordering::SeqCst);
        if abandoned > 0 {
            // Visible, not silent: these detached handlers die with the
            // process mid-write — clients see a dropped connection.
            crate::warn_!(
                "serve: abandoning {abandoned} connection handler(s) still live after the \
                 drain grace period"
            );
        }
        let (hits, misses) = self.cache.counts();
        crate::info!(
            "serve: stopped ({} cache entr(ies), {hits} hit(s) / {misses} miss(es))",
            self.cache.len()
        );
        Ok(())
    }
}

/// Open (and restore) the daemon's durable state under `<dir>/serve/`.
fn open_durable(dir: &std::path::Path) -> anyhow::Result<(JobQueue, EvalCache)> {
    let serve_dir = dir.join("serve");
    std::fs::create_dir_all(&serve_dir)?;
    let (queue, restored) = JobQueue::with_journal(&serve_dir.join("jobs.journal"))?;
    let mut cache = EvalCache::new();
    let loaded = cache.attach_disk(&serve_dir.join("eval_cache.journal"))?;
    if restored > 0 || loaded > 0 {
        crate::info!(
            "serve: restored {restored} journaled job(s) and {loaded} disk-cached eval(s) \
             from {}",
            serve_dir.display()
        );
    }
    Ok((queue, cache))
}

/// Streams job progress onto the wire as typed events.
struct WireObserver {
    queue: Arc<JobQueue>,
    idx: usize,
    handle: String,
}

impl Observer for WireObserver {
    fn job_started(&mut self, job: &JobSpec) {
        self.queue.publish(self.idx, wire::event_started(&self.handle, &job.id()));
    }

    fn episode_done(&mut self, _job: &JobSpec, stats: &EpisodeStats, episodes: usize, new_best: bool) {
        self.queue
            .publish(self.idx, wire::event_episode(&self.handle, stats, episodes, new_best));
    }

    fn message(&mut self, _job: &JobSpec, text: &str) {
        self.queue.publish(self.idx, wire::event_message(&self.handle, text));
    }
}

/// One scheduler worker: own coordinator, own cache handle (per-job
/// counter deltas), jobs pulled FIFO until shutdown.
fn worker_loop(
    wid: usize,
    cfg: &ServeConfig,
    inner: Parallelism,
    shard_hosts: Vec<String>,
    queue: Arc<JobQueue>,
    cache: Arc<EvalCache>,
    warm_lock: Arc<Mutex<()>>,
) {
    // The explicit (possibly empty) host bucket stops the shard backend
    // from re-reading $AUTOQ_SHARD_HOSTS and un-partitioning the fleet.
    let opts = RuntimeOpts {
        threads: Some(inner),
        shard_workers: cfg.shard_workers,
        shard_hosts: Some(shard_hosts),
        shard_encoding: cfg.shard_encoding,
    };
    let mut coord = match Coordinator::open_full(&cfg.dir, cfg.backend, opts) {
        Ok(c) => c,
        Err(e) => {
            // A worker that cannot open its runtime would strand queued
            // jobs silently; fail the whole daemon loudly instead.
            crate::warn_!("serve worker {wid} failed to open runtime: {e:#}");
            queue.begin_shutdown(false);
            return;
        }
    };
    let handle = CacheHandle::new(cache);
    coord.set_eval_cache(handle.clone());
    while let Some((idx, spec)) = queue.next_job() {
        let job_handle = format!("job-{idx}");
        // Serialize pretrain-on-first-use across workers.
        if matches!(
            spec.kind,
            JobKind::Search(_) | JobKind::Eval { .. } | JobKind::Finetune { .. }
        ) {
            let guard = warm_lock.lock().expect("warm lock poisoned");
            if let Err(e) = coord.ensure_pretrained(&spec.model) {
                drop(guard);
                queue.finish(idx, Err(format!("{e:#}")), (0, 0));
                continue;
            }
        }
        let snap = handle.counts();
        let mut log = LogObserver::default();
        let mut wire_obs =
            WireObserver { queue: queue.clone(), idx, handle: job_handle.clone() };
        let res = {
            let mut fan = FanOut::new(vec![&mut log, &mut wire_obs]);
            coord.run_observed(&spec, &mut fan)
        };
        let (h1, m1) = handle.counts();
        let delta = (h1 - snap.0, m1 - snap.1);
        match res {
            Ok(report) => queue.finish(idx, Ok(report.to_json()), delta),
            Err(e) => queue.finish(idx, Err(format!("{e:#}")), delta),
        }
    }
    crate::debug!("serve worker {wid} exiting");
}

/// One connection: frames in, frames out.  Application-level errors
/// (unknown op, invalid spec, unknown job) answer `{ok:false}` and keep
/// the connection; framing/JSON corruption ends the connection — but
/// never the daemon.
fn handle_connection(
    stream: TcpStream,
    idle: Option<Duration>,
    client: u64,
    queue: &Arc<JobQueue>,
    cache: &Arc<EvalCache>,
) -> anyhow::Result<()> {
    // A silent client times the read out; the caller recognizes it via
    // `proto::is_timeout` and drops the connection cleanly.
    stream.set_read_timeout(idle)?;
    let mut reader = stream.try_clone()?;
    let mut writer = stream;
    while let Some(frame) = read_frame(&mut reader)? {
        let request = match wire::request_from_json(&frame) {
            Ok(r) => r,
            Err(e) => {
                write_frame(&mut writer, &wire::err_json(&format!("{e:#}")))?;
                continue;
            }
        };
        match request {
            ServeRequest::Ping => {
                write_frame(
                    &mut writer,
                    &wire::ok_json(vec![("pid", (std::process::id() as usize).into())]),
                )?;
            }
            ServeRequest::Submit(spec) => {
                let reply = match queue.submit(spec.clone(), client) {
                    Ok(handle) => wire::ok_json(vec![
                        ("job", handle.into()),
                        ("id", spec.id().into()),
                    ]),
                    Err(e) => wire::err_json(&format!("{e:#}")),
                };
                write_frame(&mut writer, &reply)?;
            }
            ServeRequest::Status { job: Some(handle) } => {
                let reply = match queue.state_of(&handle) {
                    Ok((id, state)) => status_row(&handle, &id, &state),
                    Err(e) => wire::err_json(&format!("{e:#}")),
                };
                write_frame(&mut writer, &reply)?;
            }
            ServeRequest::Status { job: None } => {
                let rows = queue
                    .snapshot()
                    .into_iter()
                    .map(|(handle, id, state)| {
                        Json::obj(vec![
                            ("job", handle.into()),
                            ("id", id.into()),
                            ("state", state.into()),
                        ])
                    })
                    .collect();
                let (queued, running, finished) = queue.load();
                let (hits, misses) = cache.counts();
                write_frame(
                    &mut writer,
                    &wire::ok_json(vec![
                        ("jobs", Json::Arr(rows)),
                        ("queued", queued.into()),
                        ("running", running.into()),
                        ("finished", finished.into()),
                        ("cache", wire::cache_json(hits, misses)),
                        ("clients", wire::clients_json(&queue.client_totals())),
                        ("cache_entries", cache.len().into()),
                        (
                            "durability",
                            wire::durability_json(queue.journal_info(), cache.disk_info()),
                        ),
                    ]),
                )?;
            }
            ServeRequest::Result { job: handle, wait } => {
                let looked_up = if wait {
                    queue.wait_terminal(&handle)
                } else {
                    queue.state_of(&handle)
                };
                let reply = match looked_up {
                    Ok((id, state)) => status_row(&handle, &id, &state),
                    Err(e) => wire::err_json(&format!("{e:#}")),
                };
                write_frame(&mut writer, &reply)?;
            }
            ServeRequest::Subscribe { job: handle } => {
                let (tx, rx) = mpsc::channel::<Json>();
                match queue.subscribe(&handle, tx) {
                    Ok(()) => {
                        write_frame(&mut writer, &wire::ok_json(vec![]))?;
                        // Stream until the terminal event (or client drop).
                        for event in rx {
                            let terminal =
                                event.get("event").and_then(Json::as_str) == Some("finished");
                            write_frame(&mut writer, &event)?;
                            if terminal {
                                break;
                            }
                        }
                    }
                    Err(e) => write_frame(&mut writer, &wire::err_json(&format!("{e:#}")))?,
                }
            }
            ServeRequest::Shutdown { drain } => {
                queue.begin_shutdown(drain);
                // Respond only once quiescent, so a client's `shutdown`
                // round-trip doubles as "wait for my jobs".
                queue.wait_drained();
                let (queued, running, finished) = queue.load();
                debug_assert_eq!((queued, running), (0, 0));
                write_frame(&mut writer, &wire::ok_json(vec![("finished", finished.into())]))?;
                return Ok(());
            }
        }
    }
    Ok(())
}

/// `{ok, job, id, state [, report, cache | error, cache]}` — the shared
/// shape of single-job `status` and `result` replies.
fn status_row(handle: &str, id: &str, state: &JobState) -> Json {
    let mut pairs: Vec<(&str, Json)> =
        vec![("job", handle.into()), ("id", id.into()), ("state", state.name().into())];
    match state {
        JobState::Done { report, cache } => {
            pairs.push(("report", report.clone()));
            pairs.push(("cache", wire::cache_json(cache.0, cache.1)));
        }
        JobState::Failed { error, cache } => {
            pairs.push(("error", error.as_str().into()));
            pairs.push(("cache", wire::cache_json(cache.0, cache.1)));
        }
        JobState::Evicted { was } => {
            // `state` already reports the original terminal name; tell the
            // client why the payload itself is gone.
            pairs.push((
                "error",
                format!(
                    "job ended {was} but its result was evicted by the retention cap \
                     (AUTOQ_QUEUE_RETAIN)"
                )
                .into(),
            ));
        }
        _ => {}
    }
    wire::ok_json(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_budget_splits_or_pins() {
        let cores = Parallelism::resolve(None).unwrap().get();
        // Pinned budgets are taken verbatim.
        assert_eq!(worker_thread_budget(Some(Parallelism::new(3)), 8).unwrap().get(), 3);
        // Unpinned: an even share_of split, floored at one.
        for workers in [1usize, 2, cores, cores + 5] {
            let b = worker_thread_budget(None, workers).unwrap().get();
            assert!(b >= 1);
            assert!(b <= cores.max(1));
            assert_eq!(b, Parallelism::share_of(cores, workers).get());
        }
    }

    /// A per-test artifacts dir: `bind` now opens journals under
    /// `<dir>/serve/`, so tests must not share the working directory.
    fn tmp_cfg(tag: &str) -> ServeConfig {
        ServeConfig {
            dir: std::env::temp_dir()
                .join(format!("autoq_server_{tag}_{}", std::process::id())),
            ..ServeConfig::default()
        }
    }

    #[test]
    fn bind_rejects_zero_workers_and_bad_addrs() {
        let cfg = ServeConfig { workers: 0, ..tmp_cfg("reject") };
        assert!(Server::bind("127.0.0.1:0", cfg).is_err());
        assert!(Server::bind("not-an-addr", tmp_cfg("reject")).is_err());
        std::fs::remove_dir_all(tmp_cfg("reject").dir).ok();
    }

    #[test]
    fn bind_resolves_port_zero_and_opens_durable_state() {
        let cfg = tmp_cfg("port0");
        let dir = cfg.dir.clone();
        let srv = Server::bind("127.0.0.1:0", cfg).unwrap();
        assert_ne!(srv.local_addr().port(), 0);
        let (jpath, _, journaled) = srv.queue().journal_info().expect("job journal attached");
        assert_eq!(jpath, dir.join("serve").join("jobs.journal"));
        assert_eq!(journaled, 0);
        let (cpath, _, entries) = srv.cache().disk_info().expect("disk cache attached");
        assert_eq!(cpath, dir.join("serve").join("eval_cache.journal"));
        assert_eq!(entries, 0);
        drop(srv);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn status_row_embeds_terminal_payloads() {
        let done = JobState::Done { report: Json::Bool(true), cache: (2, 1) };
        let j = status_row("job-0", "eval_cif10_fp32_s1", &done);
        assert_eq!(j.req("state").unwrap().as_str(), Some("done"));
        assert_eq!(j.req("report").unwrap(), &Json::Bool(true));
        assert_eq!(j.req("cache").unwrap().req("hits").unwrap().as_usize(), Some(2));
        let failed = JobState::Failed { error: "boom".into(), cache: (0, 0) };
        let j = status_row("job-1", "x", &failed);
        assert_eq!(j.req("error").unwrap().as_str(), Some("boom"));
        assert!(j.get("report").is_none());
        // An evicted job keeps its terminal name but explains the missing
        // payload.
        let evicted = JobState::Evicted { was: "done" };
        let j = status_row("job-2", "x", &evicted);
        assert_eq!(j.req("state").unwrap().as_str(), Some("done"));
        assert!(j.req("error").unwrap().as_str().unwrap().contains("evicted"));
        assert!(j.get("report").is_none());
    }
}
