//! `autoq serve`: a long-running job-queue coordinator daemon with a
//! content-addressed eval cache.
//!
//! The daemon accepts JSON job submissions over a TCP socket (the shard
//! backend's length-prefixed framing, [`wire`]), validates them into
//! builder-checked `JobSpec`s, schedules them FIFO across a pool of
//! coordinator workers under one shared thread budget
//! (`Parallelism::share_of`, [`server`]), streams per-episode `Observer`
//! events to subscribed clients ([`queue`]), and serves status/result
//! queries.  In front of every worker's `eval_config` sits a shared
//! exact-memoization cache keyed on the full semantic identity of an
//! evaluation ([`cache`]) — model params, bit config, data identity, split
//! and backend — so repeated configs across episodes, jobs and clients are
//! answered from memory, with hit/miss counters surfaced per job.
//!
//! Determinism contract: caching never changes results (exact memoization
//! on deterministic backends) and never changes report bytes — counters
//! ride the wire envelope, not `JobReport::to_json()`.  DESIGN.md §Serve
//! daemon specifies the protocol, the scheduling rule and the cache key.

pub mod cache;
pub mod client;
pub mod queue;
pub mod server;
pub mod wire;

pub use cache::{CacheHandle, EvalCache};
pub use client::{run_job_via_daemon, run_sweep_via_daemon, DaemonClient, DaemonSweepResult};
pub use queue::{JobQueue, JobState};
pub use server::{worker_thread_budget, ServeConfig, Server};
pub use wire::ServeRequest;
