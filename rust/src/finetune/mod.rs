//! Training driver: pre-training the zoo (fp32 = all-32-bit config, an
//! exact passthrough) and post-search fine-tuning of the best-explored
//! configuration (paper §3: "the best-explored model is fine-tuned to
//! obtain the best inference accuracy").  Runs the `{model}_train_{mode}`
//! artifact; rust owns params + momenta.

use crate::cost::Mode;
use crate::data::synth::{Split, SynthDataset};
use crate::models::{EvalResult, ModelRunner};
use crate::runtime::Runtime;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// Cosine decay to lr_min over the run.
    pub lr_min: f32,
    pub mode: Mode,
    /// Per-channel bit config; `None` trains at full precision (32s).
    pub bits: Option<(Vec<u8>, Vec<u8>)>,
    /// Distinct training samples to draw from.
    pub pool: u64,
    pub log_every: usize,
    pub eval_batches: usize,
    pub seed: u64,
}

impl TrainConfig {
    pub fn pretrain(steps: usize) -> TrainConfig {
        TrainConfig {
            steps,
            lr: 0.05,
            lr_min: 0.002,
            mode: Mode::Quant,
            bits: None,
            pool: 20_000,
            log_every: 50,
            eval_batches: 2,
            seed: 7,
        }
    }

    /// Model-aware pre-training: deeper residual nets need a gentler peak
    /// learning rate to converge from He init under GroupNorm.
    pub fn pretrain_for(model: &str, steps: usize) -> TrainConfig {
        let mut cfg = Self::pretrain(steps);
        if model == "res18" || model == "monet" {
            cfg.lr = 0.02;
        }
        cfg
    }

    pub fn finetune(mode: Mode, wbits: Vec<u8>, abits: Vec<u8>, steps: usize) -> TrainConfig {
        TrainConfig {
            steps,
            lr: 0.01,
            lr_min: 0.0005,
            mode,
            bits: Some((wbits, abits)),
            pool: 20_000,
            log_every: 50,
            eval_batches: 2,
            seed: 11,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    /// (step, loss) curve, sampled at log_every.
    pub curve: Vec<(usize, f32)>,
    pub final_eval: EvalResult,
    pub secs: f64,
}

pub fn train(
    rt: &mut Runtime,
    runner: &mut ModelRunner,
    data: &SynthDataset,
    cfg: &TrainConfig,
) -> anyhow::Result<TrainReport> {
    let t0 = std::time::Instant::now();
    let (wbits, abits) = match &cfg.bits {
        Some((w, a)) => (w.clone(), a.clone()),
        None => (
            vec![32u8; runner.meta.w_channels],
            vec![32u8; runner.meta.a_channels],
        ),
    };
    let tb = runner.meta.train_batch;
    let mut curve = Vec::new();
    for step in 0..cfg.steps {
        // Cosine learning-rate decay.
        let prog = step as f32 / cfg.steps.max(1) as f32;
        let lr = cfg.lr_min
            + 0.5 * (cfg.lr - cfg.lr_min) * (1.0 + (std::f32::consts::PI * prog).cos());
        let batch = data.train_batch(cfg.seed.wrapping_add(step as u64), tb, cfg.pool);
        let loss = runner.train_step(rt, cfg.mode, &batch, &wbits, &abits, lr)?;
        anyhow::ensure!(loss.is_finite(), "training diverged at step {step}: loss {loss}");
        if step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps {
            curve.push((step, loss));
            crate::debug!("{} train step {step}/{}: loss {loss:.4} lr {lr:.4}", runner.meta.name, cfg.steps);
        }
    }
    let final_eval =
        runner.eval_config(rt, cfg.mode, &wbits, &abits, data, Split::Val, cfg.eval_batches)?;
    Ok(TrainReport { curve, final_eval, secs: t0.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_have_sane_defaults() {
        let p = TrainConfig::pretrain(100);
        assert!(p.bits.is_none());
        assert!(p.lr > p.lr_min);
        let f = TrainConfig::finetune(Mode::Binar, vec![4; 8], vec![4; 3], 50);
        assert_eq!(f.mode, Mode::Binar);
        assert!(f.bits.is_some());
    }
}
