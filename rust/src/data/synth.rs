//! Synthetic structured image dataset (substitution for CIFAR-10/ImageNet —
//! see DESIGN.md).  10 classes of 32×32×3 images, each class a distinct
//! mixture of oriented sinusoidal textures with class-specific colour
//! response, plus per-instance phase/amplitude jitter and pixel noise.
//!
//! Properties that matter for the reproduction:
//!   * learnable by the model zoo (>90 % val accuracy after pre-training),
//!   * accuracy degrades smoothly as channel bit-widths shrink — the same
//!     accuracy-vs-bits response surface the RL search exploits on CIFAR,
//!   * fully deterministic from (seed, split, index): train/val never leak.

use crate::util::rng::Rng;

pub const HW: usize = 32;
pub const CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 10;

/// Class-conditional generator parameters (fixed by dataset seed).
#[derive(Debug, Clone)]
struct ClassProto {
    /// Two texture components: (fx, fy, phase, weight) each.
    comps: [(f32, f32, f32, f32); 2],
    /// Per-RGB-channel response of each component.
    color: [[f32; CHANNELS]; 2],
    /// Radial component weight (distinguishes classes with similar angles).
    radial: f32,
}

#[derive(Debug)]
pub struct SynthDataset {
    protos: Vec<ClassProto>,
    seed: u64,
    pub noise: f32,
}

/// One batch, layout matches the artifact inputs: images NHWC f32 in
/// [-1, 1], labels s32.
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

impl Split {
    fn stream(self) -> u64 {
        match self {
            Split::Train => 0x11,
            Split::Val => 0x22,
            Split::Test => 0x33,
        }
    }

    /// Stable token used in eval-cache keys and logs.
    pub fn as_str(self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::Val => "val",
            Split::Test => "test",
        }
    }
}

impl SynthDataset {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A_5EED);
        let mut protos = Vec::with_capacity(NUM_CLASSES);
        for c in 0..NUM_CLASSES {
            // Spread base orientations evenly, then jitter — classes are
            // separable but neighbours overlap enough to make bits matter.
            let base = c as f32 / NUM_CLASSES as f32 * std::f32::consts::PI;
            let mut comp = |i: usize| {
                let ang = base + rng.range_f64(-0.2, 0.2) as f32 + i as f32 * 0.9;
                let freq = 2.0 + rng.range_f64(0.0, 4.0) as f32 + c as f32 * 0.3;
                (
                    freq * ang.cos(),
                    freq * ang.sin(),
                    rng.range_f64(0.0, std::f64::consts::TAU) as f32,
                    0.5 + rng.f32() * 0.5,
                )
            };
            let comps = [comp(0), comp(1)];
            let mut color = [[0.0f32; CHANNELS]; 2];
            for comp_color in color.iter_mut() {
                for ch in comp_color.iter_mut() {
                    *ch = rng.range_f64(-1.0, 1.0) as f32;
                }
            }
            protos.push(ClassProto { comps, color, radial: rng.range_f64(-0.5, 0.5) as f32 });
        }
        // Noise level tuned so the accuracy-vs-bits response is smooth:
        // fp32 ≈ 0.95+, graceful degradation through 4→2 bits (the regime
        // the RL search discriminates in), chance at 1 bit.
        SynthDataset { protos, seed, noise: 0.85 }
    }

    /// The generator seed this dataset was built from (every sample is a
    /// pure function of it — the eval cache keys on it).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Render sample `index` of `split` — O(HW²), deterministic.
    pub fn render(&self, split: Split, index: u64, images: &mut [f32], label: &mut i32) {
        debug_assert_eq!(images.len(), HW * HW * CHANNELS);
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(split.stream())
                .wrapping_add(index.wrapping_mul(0xD1B5_4A32_D192_ED03)),
        );
        let cls = (index % NUM_CLASSES as u64) as usize;
        *label = cls as i32;
        let p = &self.protos[cls];
        // Instance jitter.
        let phase_j: [f32; 2] = [
            rng.range_f64(-0.8, 0.8) as f32,
            rng.range_f64(-0.8, 0.8) as f32,
        ];
        let amp = 0.7 + rng.f32() * 0.6;
        let (cx, cy) = (
            rng.range_f64(-0.3, 0.3) as f32,
            rng.range_f64(-0.3, 0.3) as f32,
        );
        for y in 0..HW {
            for x in 0..HW {
                let u = x as f32 / HW as f32 - 0.5;
                let v = y as f32 / HW as f32 - 0.5;
                let r2 = (u - cx) * (u - cx) + (v - cy) * (v - cy);
                let radial = (r2 * 40.0 * p.radial).sin();
                for ch in 0..CHANNELS {
                    let mut val = 0.3 * radial;
                    for (i, &(fx, fy, ph, w)) in p.comps.iter().enumerate() {
                        let t = fx * u * std::f32::consts::TAU
                            + fy * v * std::f32::consts::TAU
                            + ph
                            + phase_j[i];
                        val += w * p.color[i][ch] * t.sin();
                    }
                    val = amp * val + self.noise * rng.normal() as f32;
                    images[(y * HW + x) * CHANNELS + ch] = val.clamp(-1.5, 1.5);
                }
            }
        }
    }

    /// Materialize a batch of `n` consecutive samples starting at `start`.
    pub fn batch(&self, split: Split, start: u64, n: usize) -> Batch {
        let mut images = vec![0.0f32; n * HW * HW * CHANNELS];
        let mut labels = vec![0i32; n];
        for i in 0..n {
            let off = i * HW * HW * CHANNELS;
            self.render(
                split,
                start + i as u64,
                &mut images[off..off + HW * HW * CHANNELS],
                &mut labels[i],
            );
        }
        Batch { images, labels, n }
    }

    /// Shuffled training batch for step `step` (deterministic curriculum).
    pub fn train_batch(&self, step: u64, n: usize, pool: u64) -> Batch {
        let mut rng = Rng::new(self.seed ^ step.wrapping_mul(0xA076_1D64_78BD_642F));
        let mut images = vec![0.0f32; n * HW * HW * CHANNELS];
        let mut labels = vec![0i32; n];
        for i in 0..n {
            let idx = rng.next_u64() % pool;
            let off = i * HW * HW * CHANNELS;
            self.render(
                Split::Train,
                idx,
                &mut images[off..off + HW * HW * CHANNELS],
                &mut labels[i],
            );
        }
        Batch { images, labels, n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_rendering() {
        let ds = SynthDataset::new(7);
        let a = ds.batch(Split::Val, 0, 8);
        let b = ds.batch(Split::Val, 0, 8);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn splits_differ() {
        let ds = SynthDataset::new(7);
        let a = ds.batch(Split::Train, 0, 4);
        let b = ds.batch(Split::Val, 0, 4);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn labels_cycle_all_classes() {
        let ds = SynthDataset::new(1);
        let b = ds.batch(Split::Val, 0, NUM_CLASSES);
        let mut seen = [false; NUM_CLASSES];
        for &l in &b.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pixel_range_bounded() {
        let ds = SynthDataset::new(3);
        let b = ds.batch(Split::Train, 100, 16);
        assert!(b.images.iter().all(|&x| (-1.5..=1.5).contains(&x)));
        // Not degenerate: nonzero variance.
        assert!(crate::util::stats::variance_f32(&b.images) > 0.01);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-centroid accuracy on raw pixels must beat chance by a lot
        // (sanity floor for learnability), using per-class mean images.
        let ds = SynthDataset::new(5);
        let dim = HW * HW * CHANNELS;
        let train = ds.batch(Split::Train, 0, 200);
        let mut centroids = vec![vec![0.0f64; dim]; NUM_CLASSES];
        let mut counts = vec![0usize; NUM_CLASSES];
        for i in 0..train.n {
            let c = train.labels[i] as usize;
            counts[c] += 1;
            for j in 0..dim {
                centroids[c][j] += train.images[i * dim + j] as f64;
            }
        }
        for c in 0..NUM_CLASSES {
            for x in centroids[c].iter_mut() {
                *x /= counts[c].max(1) as f64;
            }
        }
        let val = ds.batch(Split::Val, 0, 100);
        let mut correct = 0;
        for i in 0..val.n {
            let mut best = (f64::INFINITY, 0usize);
            for (c, cent) in centroids.iter().enumerate() {
                let d: f64 = (0..dim)
                    .map(|j| {
                        let diff = val.images[i * dim + j] as f64 - cent[j];
                        diff * diff
                    })
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == val.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / val.n as f64;
        assert!(acc > 0.5, "nearest-centroid accuracy only {acc}");
    }
}
