//! Data substrate: deterministic synthetic image dataset (CIFAR/ImageNet
//! substitution — DESIGN.md) and batching.

pub mod synth;

pub use synth::{Batch, Split, SynthDataset};
