//! [`DurableLog`]: the shared "enumerate units → skip done → run → record"
//! seam over the raw record log.
//!
//! Every run-to-completion loop in the system (sweep cells, search
//! episodes, serve jobs, repro cells) reduces to the same shape: a set of
//! deterministic units identified by a stable id and a config
//! *fingerprint*; units whose recorded fingerprint matches are replayed
//! from their journaled bytes, units that are missing or whose fingerprint
//! changed are re-run and recorded.  [`DurableLog::run_unit`] is that
//! control flow; the layers differ only in what a "unit" is and how its
//! payload decodes.
//!
//! Replay semantics: later records win.  The done set keeps one entry per
//! id (a re-run overwrites), snapshots keep the latest blob per tag, and
//! extra records (e.g. disk-tier cache entries) replay in append order.
//! [`DurableLog::compact`] rewrites the file down to exactly that surviving
//! state — done entries, the newest snapshot per tag, extras deduplicated
//! by their leading 8-byte key — via a temp file + atomic rename.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::codec::{ByteReader, ByteWriter};
use super::log::{kind, Journal, Record};

/// A completed unit: the config fingerprint it ran under and its recorded
/// result bytes.
#[derive(Debug, Clone)]
pub struct DoneEntry {
    pub fingerprint: u64,
    pub payload: Vec<u8>,
}

#[derive(Debug)]
pub struct DurableLog {
    journal: Journal,
    done: BTreeMap<String, DoneEntry>,
    /// tag → (seq, blob); later records overwrite, so this is the newest.
    snapshots: BTreeMap<String, (u64, Vec<u8>)>,
    /// Raw records of non-done/snapshot kinds, in append order.
    extras: Vec<(u8, Vec<u8>)>,
    /// Unix seconds of the newest record (replayed or appended).
    newest_ts: Option<u64>,
}

impl DurableLog {
    /// Open for resume: replay the existing log (if any).
    pub fn open(path: &Path) -> anyhow::Result<DurableLog> {
        let (journal, records) = Journal::open(path)?;
        let mut log = DurableLog {
            journal,
            done: BTreeMap::new(),
            snapshots: BTreeMap::new(),
            extras: Vec::new(),
            newest_ts: None,
        };
        for rec in records {
            log.replay(rec)?;
        }
        Ok(log)
    }

    /// Start fresh: discard any existing log at `path` first.
    pub fn fresh(path: &Path) -> anyhow::Result<DurableLog> {
        match std::fs::remove_file(path) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(e.into()),
        }
        DurableLog::open(path)
    }

    fn replay(&mut self, rec: Record) -> anyhow::Result<()> {
        self.newest_ts = Some(self.newest_ts.unwrap_or(0).max(rec.ts));
        match rec.kind {
            kind::DONE => {
                let mut r = ByteReader::new(&rec.payload);
                let id = r.str()?.to_string();
                let fingerprint = r.u64()?;
                let payload = r.bytes()?.to_vec();
                self.done.insert(id, DoneEntry { fingerprint, payload });
            }
            kind::SNAPSHOT => {
                let mut r = ByteReader::new(&rec.payload);
                let tag = r.str()?.to_string();
                let seq = r.u64()?;
                let blob = r.bytes()?.to_vec();
                self.snapshots.insert(tag, (seq, blob));
            }
            other => self.extras.push((other, rec.payload)),
        }
        Ok(())
    }

    pub fn path(&self) -> &Path {
        self.journal.path()
    }

    /// The recorded result for `id`, if it finished under the same
    /// fingerprint (a changed fingerprint means the unit's config changed
    /// — it must re-run).
    pub fn recorded(&self, id: &str, fingerprint: u64) -> Option<&[u8]> {
        self.done
            .get(id)
            .filter(|e| e.fingerprint == fingerprint)
            .map(|e| e.payload.as_slice())
    }

    /// Record a completed unit (overwrites any previous entry for `id`).
    pub fn record_done(&mut self, id: &str, fingerprint: u64, payload: &[u8]) -> anyhow::Result<()> {
        let mut w = ByteWriter::new();
        w.put_str(id);
        w.put_u64(fingerprint);
        w.put_bytes(payload);
        let ts = self.journal.append(kind::DONE, &w.into_vec())?;
        self.newest_ts = Some(self.newest_ts.unwrap_or(0).max(ts));
        self.done
            .insert(id.to_string(), DoneEntry { fingerprint, payload: payload.to_vec() });
        Ok(())
    }

    /// The shared skip-done-or-run-and-record control flow.  Returns the
    /// unit's result bytes and whether they were replayed from the journal.
    pub fn run_unit<F>(
        &mut self,
        id: &str,
        fingerprint: u64,
        run: F,
    ) -> anyhow::Result<(Vec<u8>, bool)>
    where
        F: FnOnce() -> anyhow::Result<Vec<u8>>,
    {
        if let Some(payload) = self.recorded(id, fingerprint) {
            return Ok((payload.to_vec(), true));
        }
        let payload = run()?;
        self.record_done(id, fingerprint, &payload)?;
        Ok((payload, false))
    }

    pub fn done_len(&self) -> usize {
        self.done.len()
    }
    pub fn done_ids(&self) -> impl Iterator<Item = &str> {
        self.done.keys().map(String::as_str)
    }

    /// Every done entry as `(id, payload)`, ignoring fingerprints — for
    /// callers that replay a whole journal (the serve job queue) rather
    /// than skip-scan known ids.
    pub fn done_entries(&self) -> impl Iterator<Item = (&str, &[u8])> {
        self.done.iter().map(|(id, e)| (id.as_str(), e.payload.as_slice()))
    }

    /// Append a state snapshot for `tag`; `seq` is a monotone sequence
    /// number (episode count) so readers can sanity-check ordering.
    pub fn snapshot(&mut self, tag: &str, seq: u64, blob: &[u8]) -> anyhow::Result<()> {
        let mut w = ByteWriter::new();
        w.put_str(tag);
        w.put_u64(seq);
        w.put_bytes(blob);
        let ts = self.journal.append(kind::SNAPSHOT, &w.into_vec())?;
        self.newest_ts = Some(self.newest_ts.unwrap_or(0).max(ts));
        self.snapshots.insert(tag.to_string(), (seq, blob.to_vec()));
        Ok(())
    }

    /// The newest snapshot recorded for `tag`.
    pub fn latest_snapshot(&self, tag: &str) -> Option<(u64, &[u8])> {
        self.snapshots.get(tag).map(|(seq, blob)| (*seq, blob.as_slice()))
    }

    /// Append a raw record of a custom kind (payload convention: the first
    /// 8 bytes are the record's dedup key — see [`DurableLog::compact`]).
    pub fn append_extra(&mut self, kd: u8, payload: &[u8]) -> anyhow::Result<()> {
        let ts = self.journal.append(kd, payload)?;
        self.newest_ts = Some(self.newest_ts.unwrap_or(0).max(ts));
        self.extras.push((kd, payload.to_vec()));
        Ok(())
    }

    /// Replayed + appended raw records of `kd`, in order.
    pub fn extras(&self, kd: u8) -> impl Iterator<Item = &[u8]> {
        self.extras.iter().filter(move |(k, _)| *k == kd).map(|(_, p)| p.as_slice())
    }
    pub fn extras_len(&self) -> usize {
        self.extras.len()
    }

    /// Seconds since the newest record, if any (status reporting).
    pub fn age_secs(&self) -> Option<u64> {
        let newest = self.newest_ts?;
        let now = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        Some(now.saturating_sub(newest))
    }

    /// Current on-disk size in bytes.
    pub fn len_bytes(&self) -> u64 {
        self.journal.len_bytes()
    }

    /// Rewrite the log down to its surviving state: every done entry, the
    /// newest snapshot per tag, and extras deduplicated by their leading
    /// 8-byte key (later wins).  Temp file + rename, so a crash during
    /// compaction leaves either the old or the new log intact.
    pub fn compact(&mut self) -> anyhow::Result<()> {
        let path: PathBuf = self.journal.path().to_path_buf();
        let tmp = path.with_extension("journal.tmp");
        std::fs::remove_file(&tmp).ok();
        {
            let (mut out, _) = Journal::open(&tmp)?;
            for (id, e) in &self.done {
                let mut w = ByteWriter::new();
                w.put_str(id);
                w.put_u64(e.fingerprint);
                w.put_bytes(&e.payload);
                out.append(kind::DONE, &w.into_vec())?;
            }
            for (tag, (seq, blob)) in &self.snapshots {
                let mut w = ByteWriter::new();
                w.put_str(tag);
                w.put_u64(*seq);
                w.put_bytes(blob);
                out.append(kind::SNAPSHOT, &w.into_vec())?;
            }
            // Dedup extras by (kind, leading 8 bytes), keeping the last
            // occurrence but preserving first-seen order.
            let mut order: Vec<(u8, u64)> = Vec::new();
            let mut latest: BTreeMap<(u8, u64), &[u8]> = BTreeMap::new();
            for (k, p) in &self.extras {
                let key = if p.len() >= 8 {
                    u64::from_le_bytes(p[..8].try_into().unwrap())
                } else {
                    super::log::fingerprint(p)
                };
                if latest.insert((*k, key), p.as_slice()).is_none() {
                    order.push((*k, key));
                }
            }
            for ok in &order {
                out.append(ok.0, latest[ok])?;
            }
        }
        std::fs::rename(&tmp, &path)?;
        // Reopen so the append handle points at the compacted file.
        let compacted = DurableLog::open(&path)?;
        *self = compacted;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("autoq_durable_{tag}_{}.journal", std::process::id()))
    }

    #[test]
    fn run_unit_skips_done_and_reruns_changed_fingerprint() {
        let p = tmp("run_unit");
        std::fs::remove_file(&p).ok();
        let mut runs = 0;
        {
            let mut log = DurableLog::fresh(&p).unwrap();
            let (out, cached) = log
                .run_unit("cell/a", 11, || {
                    runs += 1;
                    Ok(b"result-a".to_vec())
                })
                .unwrap();
            assert_eq!(out, b"result-a");
            assert!(!cached);
        }
        {
            // Same fingerprint: replayed without running.
            let mut log = DurableLog::open(&p).unwrap();
            let (out, cached) = log
                .run_unit("cell/a", 11, || {
                    runs += 1;
                    Ok(b"never".to_vec())
                })
                .unwrap();
            assert_eq!(out, b"result-a");
            assert!(cached);
            // Changed fingerprint: re-runs and overwrites.
            let (out, cached) = log
                .run_unit("cell/a", 12, || {
                    runs += 1;
                    Ok(b"result-a2".to_vec())
                })
                .unwrap();
            assert_eq!(out, b"result-a2");
            assert!(!cached);
        }
        let log = DurableLog::open(&p).unwrap();
        assert_eq!(log.recorded("cell/a", 12).unwrap(), b"result-a2");
        assert_eq!(log.recorded("cell/a", 11), None);
        assert_eq!(runs, 2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn latest_snapshot_wins_across_reopen() {
        let p = tmp("snap");
        std::fs::remove_file(&p).ok();
        {
            let mut log = DurableLog::fresh(&p).unwrap();
            log.snapshot("search", 2, b"old").unwrap();
            log.snapshot("search", 4, b"new").unwrap();
        }
        let log = DurableLog::open(&p).unwrap();
        let (seq, blob) = log.latest_snapshot("search").unwrap();
        assert_eq!(seq, 4);
        assert_eq!(blob, b"new");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn compact_keeps_state_and_shrinks() {
        let p = tmp("compact");
        std::fs::remove_file(&p).ok();
        let mut log = DurableLog::fresh(&p).unwrap();
        for i in 0..20u64 {
            // 20 snapshots for one tag: only the last survives compaction.
            log.snapshot("search", i, &vec![7u8; 256]).unwrap();
        }
        log.record_done("cell/a", 1, b"ra").unwrap();
        log.record_done("cell/b", 2, b"rb").unwrap();
        // Two extras with the same leading key: later wins.
        let mut e1 = 99u64.to_le_bytes().to_vec();
        e1.extend_from_slice(b"old");
        let mut e2 = 99u64.to_le_bytes().to_vec();
        e2.extend_from_slice(b"new");
        log.append_extra(kind::CACHE, &e1).unwrap();
        log.append_extra(kind::CACHE, &e2).unwrap();
        let before = log.len_bytes();
        log.compact().unwrap();
        assert!(log.len_bytes() < before);
        assert_eq!(log.latest_snapshot("search").unwrap().0, 19);
        assert_eq!(log.recorded("cell/a", 1).unwrap(), b"ra");
        assert_eq!(log.recorded("cell/b", 2).unwrap(), b"rb");
        let extras: Vec<&[u8]> = log.extras(kind::CACHE).collect();
        assert_eq!(extras.len(), 1);
        assert!(extras[0].ends_with(b"new"));
        // And the compacted file replays identically.
        let re = DurableLog::open(&p).unwrap();
        assert_eq!(re.done_len(), 2);
        assert_eq!(re.latest_snapshot("search").unwrap().0, 19);
        assert_eq!(re.extras(kind::CACHE).count(), 1);
        std::fs::remove_file(&p).ok();
    }
}
