//! The append-only record log under every durable surface.
//!
//! File layout (all integers little-endian):
//!
//! ```text
//!   magic  "AUTOQJL1"                                     (8 bytes)
//!   record [ len: u32 | kind: u8 | ts: u64 | crc: u64 | payload: len bytes ]
//!   record …
//! ```
//!
//! `ts` is unix seconds at append time (status reporting only — payloads
//! never contain wall-clock, so replayed results stay byte-identical);
//! `crc` is FNV-1a 64 over the kind byte, the ts bytes and the payload.
//! Appends go straight to the file descriptor, so every record that
//! `append` returned `Ok` for survives a SIGKILL of this process (page
//! cache; power-loss durability would need fsync, which the deterministic
//! replay story doesn't require — a lost tail is just re-run work).
//!
//! `open` replays the log and *truncates a torn or corrupt tail* at the
//! last good record: a crash mid-append costs exactly the record being
//! written, never the log.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Journal file magic (8 bytes; the trailing `1` is the format version).
pub const MAGIC: &[u8; 8] = b"AUTOQJL1";

/// Per-record header size: len u32 + kind u8 + ts u64 + crc u64.
const HEADER: usize = 4 + 1 + 8 + 8;

/// Corruption guard: a valid record never exceeds this (a search snapshot
/// with four full replay buffers is a few MB).
const MAX_RECORD: usize = 1 << 30;

/// Record kinds.  Payload schemas live with their writers (see
/// [`super::DurableLog`] and `serve::cache`).
pub mod kind {
    /// A completed unit of work: `str id | u64 fingerprint | bytes result`.
    pub const DONE: u8 = 1;
    /// A resumable state snapshot: `str tag | u64 seq | bytes blob`.
    pub const SNAPSHOT: u8 = 2;
    /// A disk-tier eval-cache entry (see `serve::cache` for the schema).
    pub const CACHE: u8 = 3;
}

/// FNV-1a 64 over a byte slice, continuing from `h` (seed with
/// [`FNV_OFFSET`]).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Convenience: FNV-1a 64 of one buffer from the standard offset.
pub fn fingerprint(bytes: &[u8]) -> u64 {
    fnv1a(FNV_OFFSET, bytes)
}

fn record_crc(kind: u8, ts: u64, payload: &[u8]) -> u64 {
    let h = fnv1a(FNV_OFFSET, &[kind]);
    let h = fnv1a(h, &ts.to_le_bytes());
    fnv1a(h, payload)
}

/// One replayed record.
#[derive(Debug, Clone)]
pub struct Record {
    pub kind: u8,
    /// Unix seconds at append time.
    pub ts: u64,
    pub payload: Vec<u8>,
}

/// An open journal positioned for appends.
#[derive(Debug)]
pub struct Journal {
    path: PathBuf,
    file: File,
    /// Byte length of the valid prefix (== file length after open).
    end: u64,
}

impl Journal {
    /// Open (creating if absent), replay every intact record, and truncate
    /// any torn/corrupt tail.  A file that exists but does not start with
    /// [`MAGIC`] is rejected — that is somebody else's file, not a tail to
    /// silently eat.
    pub fn open(path: &Path) -> anyhow::Result<(Journal, Vec<Record>)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut file = OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let mut records = Vec::new();
        let good;
        if bytes.is_empty() {
            file.write_all(MAGIC)?;
            good = MAGIC.len() as u64;
        } else {
            anyhow::ensure!(
                bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC,
                "{} exists but is not an autoq journal (bad magic)",
                path.display()
            );
            let mut pos = MAGIC.len();
            loop {
                if pos == bytes.len() {
                    break;
                }
                if pos + HEADER > bytes.len() {
                    break; // torn header
                }
                let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
                let kd = bytes[pos + 4];
                let ts = u64::from_le_bytes(bytes[pos + 5..pos + 13].try_into().unwrap());
                let crc = u64::from_le_bytes(bytes[pos + 13..pos + 21].try_into().unwrap());
                if len > MAX_RECORD || pos + HEADER + len > bytes.len() {
                    break; // torn payload
                }
                let payload = &bytes[pos + HEADER..pos + HEADER + len];
                if record_crc(kd, ts, payload) != crc {
                    break; // corrupt record
                }
                records.push(Record { kind: kd, ts, payload: payload.to_vec() });
                pos += HEADER + len;
            }
            good = pos as u64;
            if (pos) < bytes.len() {
                crate::warn_!(
                    "journal {}: dropping {} torn/corrupt tail byte(s) after {} intact record(s)",
                    path.display(),
                    bytes.len() - pos,
                    records.len()
                );
                file.set_len(good)?;
            }
        }
        file.seek(SeekFrom::Start(good))?;
        Ok((Journal { path: path.to_path_buf(), file, end: good }, records))
    }

    /// Append one record and hand it to the OS before returning.
    pub fn append(&mut self, kind: u8, payload: &[u8]) -> anyhow::Result<u64> {
        anyhow::ensure!(payload.len() <= MAX_RECORD, "journal record too large");
        let ts = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut rec = Vec::with_capacity(HEADER + payload.len());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.push(kind);
        rec.extend_from_slice(&ts.to_le_bytes());
        rec.extend_from_slice(&record_crc(kind, ts, payload).to_le_bytes());
        rec.extend_from_slice(payload);
        self.file.write_all(&rec)?;
        self.end += rec.len() as u64;
        Ok(ts)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes in the valid prefix (grows with every append).
    pub fn len_bytes(&self) -> u64 {
        self.end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("autoq_journal_{tag}_{}.journal", std::process::id()))
    }

    #[test]
    fn append_then_replay() {
        let p = tmp("roundtrip");
        std::fs::remove_file(&p).ok();
        {
            let (mut j, recs) = Journal::open(&p).unwrap();
            assert!(recs.is_empty());
            j.append(kind::DONE, b"alpha").unwrap();
            j.append(kind::SNAPSHOT, b"beta").unwrap();
        }
        let (_, recs) = Journal::open(&p).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].kind, kind::DONE);
        assert_eq!(recs[0].payload, b"alpha");
        assert_eq!(recs[1].kind, kind::SNAPSHOT);
        assert_eq!(recs[1].payload, b"beta");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn torn_tail_is_truncated() {
        let p = tmp("torn");
        std::fs::remove_file(&p).ok();
        {
            let (mut j, _) = Journal::open(&p).unwrap();
            j.append(kind::DONE, b"keep me").unwrap();
        }
        // Simulate a crash mid-append: a half-written header.
        {
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(&[0x99, 0x00, 0x00]).unwrap();
        }
        let before = std::fs::metadata(&p).unwrap().len();
        let (j, recs) = Journal::open(&p).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].payload, b"keep me");
        assert!(std::fs::metadata(&p).unwrap().len() < before);
        assert_eq!(j.len_bytes(), std::fs::metadata(&p).unwrap().len());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupt_record_drops_it_and_everything_after() {
        let p = tmp("corrupt");
        std::fs::remove_file(&p).ok();
        let second_start;
        {
            let (mut j, _) = Journal::open(&p).unwrap();
            j.append(kind::DONE, b"first").unwrap();
            second_start = j.len_bytes();
            j.append(kind::DONE, b"second").unwrap();
            j.append(kind::DONE, b"third").unwrap();
        }
        // Flip one payload byte of the middle record: it and the (intact)
        // record after it are both dropped — replay never skips over a bad
        // record, it stops at it.
        {
            let mut f = OpenOptions::new().read(true).write(true).open(&p).unwrap();
            f.seek(SeekFrom::Start(second_start + HEADER as u64)).unwrap();
            f.write_all(b"X").unwrap();
        }
        let (_, recs) = Journal::open(&p).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].payload, b"first");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn foreign_file_rejected() {
        let p = tmp("foreign");
        std::fs::write(&p, b"definitely not a journal").unwrap();
        assert!(Journal::open(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn fnv_matches_known_vector() {
        // FNV-1a 64 of "a" is a published test vector.
        assert_eq!(fingerprint(b"a"), 0xaf63dc4c8601ec8c);
    }
}
