//! Durable job journal: crash-safe checkpoint/resume for every
//! run-to-completion loop (DESIGN.md §Durable jobs).
//!
//! Three layers:
//!
//! - [`log`] — the append-only, length-prefixed, checksummed record file.
//!   A SIGKILL mid-append costs exactly the torn record: `open` truncates
//!   the tail at the last intact checksum and replays the rest.
//! - [`codec`] — the byte codec payloads are written in.  Floats travel as
//!   IEEE-754 bit patterns so snapshots restore *byte-exactly*.
//! - [`DurableLog`] — the shared "enumerate units → skip done → run →
//!   record" control flow: a done set keyed by unit id + config
//!   fingerprint (cheap to scan on startup, cheap to diff against a
//!   changed grid), latest-wins state snapshots for mid-unit resume, and
//!   a compaction pass that rewrites the file down to surviving state.
//!
//! Consumers: `coordinator::Sweep` (skip journaled cells, `--resume`),
//! `search::run_search_with` (episode checkpoints via
//! `search::checkpoint`), the serve daemon (job journal + disk-tier eval
//! cache), and `repro` config caching.  The determinism contract is
//! pinned across all of them: a resumed run produces byte-identical
//! results to an uninterrupted one (modulo the wall-clock `secs` field,
//! exactly as the existing byte-identity tests already treat it).

pub mod codec;
pub mod durable;
pub mod log;

pub use codec::{ByteReader, ByteWriter};
pub use durable::{DoneEntry, DurableLog};
pub use log::{fingerprint, fnv1a, Journal, Record, MAGIC};
