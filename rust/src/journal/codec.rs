//! Length-checked little-endian byte codec for journal payloads.
//!
//! Snapshots must round-trip *byte-exactly* — floats are stored as their
//! IEEE-754 bit patterns (the shard wire-codec convention), never as
//! decimal text — so a resumed search replays the uninterrupted run
//! bit-for-bit.  Readers fail with a structured error on truncation
//! instead of panicking: a torn journal tail surfaces as a recoverable
//! decode error, not a crash.

/// Append-only payload builder.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    /// f32 as its raw bit pattern (byte-exact round-trip).
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }
    /// f64 as its raw bit pattern (byte-exact round-trip).
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }
    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }
    /// Length-prefixed raw bytes.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }
    /// Length-prefixed f32 slice as raw bit patterns.
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_u32(vs.len() as u32);
        for v in vs {
            self.put_f32(*v);
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor over a payload; every accessor checks bounds.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "journal payload truncated: wanted {n} byte(s) at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    pub fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    pub fn bool(&mut self) -> anyhow::Result<bool> {
        Ok(self.u8()? != 0)
    }
    pub fn bytes(&mut self) -> anyhow::Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }
    pub fn str(&mut self) -> anyhow::Result<&'a str> {
        Ok(std::str::from_utf8(self.bytes()?)?)
    }
    pub fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        // Sanity cap so a corrupt length cannot ask for terabytes.
        anyhow::ensure!(n * 4 <= self.buf.len() - self.pos, "journal f32 run overruns payload");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    /// Assert the payload was consumed exactly (schema drift guard).
    pub fn finish(self) -> anyhow::Result<()> {
        anyhow::ensure!(self.remaining() == 0, "journal payload has {} trailing byte(s)", self.remaining());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_types() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 3);
        w.put_f32(-0.0);
        w.put_f64(f64::NAN);
        w.put_bool(true);
        w.put_bytes(b"abc");
        w.put_str("héllo");
        w.put_f32s(&[1.5, -2.25, f32::INFINITY]);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f64().unwrap().to_bits(), f64::NAN.to_bits());
        assert!(r.bool().unwrap());
        assert_eq!(r.bytes().unwrap(), b"abc");
        assert_eq!(r.str().unwrap(), "héllo");
        let fs = r.f32s().unwrap();
        assert_eq!(fs.len(), 3);
        assert_eq!(fs[0], 1.5);
        assert_eq!(fs[2], f32::INFINITY);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.put_str("hello world");
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf[..buf.len() - 2]);
        assert!(r.str().is_err());
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let mut w = ByteWriter::new();
        w.put_u32(1);
        w.put_u8(9);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        r.u32().unwrap();
        assert!(r.finish().is_err());
    }
}
