//! DDPG agent: rust owns every parameter/optimizer buffer; the actor
//! forward pass and the fused update step are the `ddpg_act_s{S}` /
//! `ddpg_update_s{S}` artifacts, dispatched through whichever execution
//! backend the [`Runtime`] carries (PJRT or the reference interpreter).
//!
//! One `DdpgAgent` instance is a *flat* DDPG.  The hierarchical agent
//! (hiro.rs) composes four of them: weight/activation HLC (S=16) and
//! weight/activation LLC (S=17, state ⊕ goal).

use crate::agent::replay::{ReplayBuffer, Transition};
use crate::runtime::{AgentMeta, Runtime, Tensor, Value};
use crate::util::rng::Rng;

/// Hyper-parameters of one DDPG update call.
#[derive(Debug, Clone, Copy)]
pub struct DdpgHyper {
    pub gamma: f32,
    pub tau: f32,
    pub lr_actor: f32,
    pub lr_critic: f32,
}

impl Default for DdpgHyper {
    fn default() -> Self {
        // τ from the paper; γ/lrs standard DDPG values.
        DdpgHyper { gamma: 0.99, tau: 0.01, lr_actor: 1e-4, lr_critic: 1e-3 }
    }
}

pub struct DdpgAgent {
    pub meta: AgentMeta,
    pub hyper: DdpgHyper,
    // All network/optimizer state is held as host values so update/act
    // dispatches borrow them directly — no copy per call (EXPERIMENTS.md
    // §Perf, L3 iteration 2).  Order: actor(6), critic(6), t_actor(6),
    // t_critic(6), m_a(6), v_a(6), m_c(6), v_c(6).
    state: Vec<Value>,
    t: f32,
    act_name: String,
    update_name: String,
    pub last_critic_loss: f32,
    pub last_actor_loss: f32,
    pub updates: u64,
}

/// DDPG-standard MLP init: hidden layers U(±1/√fan_in), output layer
/// U(±3e-3) so initial actions sit mid-range (sigmoid(≈0)·32 ≈ 16).
fn init_mlp(shapes: &[Vec<usize>], rng: &mut Rng) -> Vec<Tensor> {
    let n = shapes.len();
    shapes
        .iter()
        .enumerate()
        .map(|(i, shp)| {
            let mut t = Tensor::zeros(shp.clone());
            let is_weight = shp.len() == 2;
            let last_pair = i >= n - 2;
            if is_weight {
                let bound = if last_pair { 3e-3 } else { 1.0 / (shp[0] as f32).sqrt() };
                for x in t.data.iter_mut() {
                    *x = (rng.f32() * 2.0 - 1.0) * bound;
                }
            }
            t
        })
        .collect()
}

impl DdpgAgent {
    pub fn new(meta: AgentMeta, hyper: DdpgHyper, rng: &mut Rng) -> Self {
        let actor = init_mlp(&meta.actor_shapes, rng);
        let critic = init_mlp(&meta.critic_shapes, rng);
        let zeros = |src: &[Tensor]| -> Vec<Tensor> {
            src.iter().map(|t| Tensor::zeros(t.shape.clone())).collect()
        };
        let groups: Vec<Vec<Tensor>> = vec![
            actor.clone(),
            critic.clone(),
            actor.clone(),  // target actor
            critic.clone(), // target critic
            zeros(&actor),
            zeros(&actor),
            zeros(&critic),
            zeros(&critic),
        ];
        let state = groups.into_iter().flatten().map(Value::F32).collect();
        let s = meta.s_dim;
        DdpgAgent {
            hyper,
            state,
            t: 0.0,
            act_name: format!("ddpg_act_s{s}"),
            update_name: format!("ddpg_update_s{s}"),
            meta,
            last_critic_loss: 0.0,
            last_actor_loss: 0.0,
            updates: 0,
        }
    }

    /// The 6 actor-parameter values (the first group of `state`).
    fn actor_values(&self) -> &[Value] {
        &self.state[0..6]
    }

    /// Full network/optimizer state for byte-exact checkpointing: the 48
    /// parameter/target/Adam tensors (in their fixed group order) plus the
    /// Adam time step.  `restore_state` with these values resumes the
    /// exact agent.
    pub fn snapshot_state(&self) -> (&[Value], f32) {
        (&self.state, self.t)
    }

    /// Restore from [`DdpgAgent::snapshot_state`] output.  The snapshot
    /// must match this agent's architecture tensor-for-tensor — a config
    /// change surfaces here as a structured error, never as silent shape
    /// corruption.
    pub fn restore_state(&mut self, state: Vec<Value>, t: f32) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.len() == self.state.len(),
            "agent snapshot has {} tensor(s), expected {}",
            state.len(),
            self.state.len()
        );
        for (i, (new, old)) in state.iter().zip(self.state.iter()).enumerate() {
            let (new, old) = (new.as_f32()?, old.as_f32()?);
            anyhow::ensure!(
                new.shape == old.shape,
                "agent snapshot tensor {i} shape {:?} != expected {:?}",
                new.shape,
                old.shape
            );
        }
        self.state = state;
        self.t = t;
        Ok(())
    }

    /// Deterministic policy μ(s) for up to `act_batch` states in one
    /// executable call.  `states` is row-major (n, s_dim); n ≤ act_batch.
    pub fn act(&self, rt: &mut Runtime, states: &[f32], n: usize) -> anyhow::Result<Vec<f32>> {
        let s_dim = self.meta.s_dim;
        let b = self.meta.act_batch;
        anyhow::ensure!(n <= b, "act batch {n} exceeds artifact batch {b}");
        anyhow::ensure!(states.len() == n * s_dim, "states len");
        let mut padded = vec![0.0f32; b * s_dim];
        padded[..n * s_dim].copy_from_slice(states);
        let states_val = Value::f32(vec![b, s_dim], padded);
        let mut inputs: Vec<&Value> = Vec::with_capacity(7);
        inputs.extend(self.actor_values());
        inputs.push(&states_val);
        let outs = rt.exec(&self.act_name, &inputs)?;
        let actions = outs[0].as_f32()?;
        Ok(actions.data[..n].to_vec())
    }

    /// μ(s) for a single state.
    pub fn act_one(&self, rt: &mut Runtime, state: &[f32]) -> anyhow::Result<f32> {
        Ok(self.act(rt, state, 1)?[0])
    }

    /// One fused update step from a replay sample.
    pub fn update(
        &mut self,
        rt: &mut Runtime,
        replay: &ReplayBuffer,
        rng: &mut Rng,
    ) -> anyhow::Result<()> {
        let b = self.meta.upd_batch;
        if replay.len() < b {
            return Ok(()); // not enough experience yet
        }
        let s_dim = self.meta.s_dim;
        let mut sample: Vec<&Transition> = Vec::with_capacity(b);
        replay.sample_into(rng, &mut sample, b);

        let mut s = vec![0.0f32; b * s_dim];
        let mut a = vec![0.0f32; b];
        let mut r = vec![0.0f32; b];
        let mut s2 = vec![0.0f32; b * s_dim];
        let mut done = vec![0.0f32; b];
        for (i, tr) in sample.iter().enumerate() {
            debug_assert_eq!(tr.s.len(), s_dim);
            s[i * s_dim..(i + 1) * s_dim].copy_from_slice(&tr.s);
            s2[i * s_dim..(i + 1) * s_dim].copy_from_slice(&tr.s2);
            a[i] = tr.a;
            r[i] = tr.r;
            done[i] = if tr.done { 1.0 } else { 0.0 };
        }

        // Batch + hyper values (small); parameter/optimizer values are
        // borrowed from `self.state` — no copies.
        let scratch: Vec<Value> = vec![
            Value::scalar(self.t),
            Value::f32(vec![b, s_dim], s),
            Value::f32(vec![b, 1], a),
            Value::f32(vec![b, 1], r),
            Value::f32(vec![b, s_dim], s2),
            Value::f32(vec![b, 1], done),
            Value::scalar(self.hyper.gamma),
            Value::scalar(self.hyper.tau),
            Value::scalar(self.hyper.lr_actor),
            Value::scalar(self.hyper.lr_critic),
        ];
        let mut inputs: Vec<&Value> = Vec::with_capacity(58);
        inputs.extend(self.state.iter());
        inputs.extend(scratch.iter());

        let mut outs = rt.exec(&self.update_name, &inputs)?;
        anyhow::ensure!(outs.len() == 51, "update artifact returned {}", outs.len());
        self.last_actor_loss = outs[50].scalar_f32()?;
        self.last_critic_loss = outs[49].scalar_f32()?;
        self.t = outs[48].scalar_f32()?;
        outs.truncate(48);
        // Output values become the new state verbatim.
        self.state = outs;
        self.updates += 1;
        Ok(())
    }

    /// LLC log-likelihood surrogate for HIRO relabeling: −‖a − μ(s, g̃)‖²
    /// summed over the stored sequence (the Gaussian behaviour policy's
    /// log-prob up to constants).
    pub fn action_log_prob(
        &self,
        rt: &mut Runtime,
        states: &[f32],
        n: usize,
        actions: &[f32],
    ) -> anyhow::Result<f64> {
        let mu = self.act(rt, states, n)?;
        Ok(-mu
            .iter()
            .zip(actions)
            .map(|(m, a)| ((m - a) as f64).powi(2))
            .sum::<f64>())
    }
}
