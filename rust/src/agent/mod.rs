//! The DRL agent stack: replay buffer, exploration-noise schedule, flat
//! DDPG (AOT'd actor/critic), and the HIRO-style hierarchical composition.

pub mod ddpg;
pub mod hiro;
pub mod noise;
pub mod replay;

pub use ddpg::{DdpgAgent, DdpgHyper};
pub use hiro::{HiroAgent, HiroConfig, Side};
pub use noise::NoiseSchedule;
pub use replay::{ReplayBuffer, Transition};
