//! Experience replay buffer (paper §4: capacity 2000, minibatch 64).
//!
//! Fixed-capacity ring; sampling is allocation-free into a caller-provided
//! scratch (hot path of the search loop).

use crate::util::rng::Rng;

/// One off-policy transition.  For the LLC the goal is folded into the
/// state vector (s = features ⊕ g), matching the s17 artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    pub s: Vec<f32>,
    pub a: f32,
    pub r: f32,
    pub s2: Vec<f32>,
    pub done: bool,
}

#[derive(Debug)]
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    capacity: usize,
    next: usize,
    /// Total pushes ever (for diagnostics).
    pub pushed: u64,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer { buf: Vec::with_capacity(capacity), capacity, next: 0, pushed: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn push(&mut self, t: Transition) {
        self.pushed += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.next] = t;
        }
        self.next = (self.next + 1) % self.capacity;
    }

    /// Sample `out.len()` transitions uniformly with replacement.
    pub fn sample_into<'a>(&'a self, rng: &mut Rng, out: &mut Vec<&'a Transition>, n: usize) {
        out.clear();
        for _ in 0..n {
            out.push(&self.buf[rng.below(self.buf.len())]);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &Transition> {
        self.buf.iter()
    }

    /// Internal state for byte-exact checkpointing: the ring contents *in
    /// storage order* (not insertion order), the next overwrite slot, and
    /// the lifetime push counter.  `restore_parts` with exactly these
    /// values resumes identical sampling behaviour.
    pub fn raw_parts(&self) -> (&[Transition], usize, u64) {
        (&self.buf, self.next, self.pushed)
    }

    /// Rebuild the ring from [`ReplayBuffer::raw_parts`] output.  The
    /// capacity is kept from `self`; the snapshot must fit it and name a
    /// valid overwrite slot.
    pub fn restore_parts(
        &mut self,
        buf: Vec<Transition>,
        next: usize,
        pushed: u64,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            buf.len() <= self.capacity,
            "replay snapshot holds {} transition(s), capacity is {}",
            buf.len(),
            self.capacity
        );
        anyhow::ensure!(
            next < self.capacity,
            "replay snapshot next slot {next} out of range for capacity {}",
            self.capacity
        );
        self.buf = buf;
        self.next = next;
        self.pushed = pushed;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(v: f32) -> Transition {
        Transition { s: vec![v; 3], a: v, r: v, s2: vec![v; 3], done: false }
    }

    #[test]
    fn fills_then_wraps() {
        let mut rb = ReplayBuffer::new(4);
        for i in 0..6 {
            rb.push(tr(i as f32));
        }
        assert_eq!(rb.len(), 4);
        assert_eq!(rb.pushed, 6);
        // Oldest two (0,1) overwritten by 4,5.
        let vals: Vec<f32> = rb.iter().map(|t| t.a).collect();
        assert!(vals.contains(&4.0) && vals.contains(&5.0));
        assert!(!vals.contains(&0.0) && !vals.contains(&1.0));
    }

    #[test]
    fn sampling_uniform_coverage() {
        let mut rb = ReplayBuffer::new(16);
        for i in 0..16 {
            rb.push(tr(i as f32));
        }
        let mut rng = Rng::new(1);
        let mut out = Vec::new();
        let mut seen = [false; 16];
        for _ in 0..50 {
            rb.sample_into(&mut rng, &mut out, 8);
            assert_eq!(out.len(), 8);
            for t in &out {
                seen[t.a as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all slots should be sampled");
    }

    #[test]
    fn raw_parts_restore_resumes_identical_sampling() {
        let mut rb = ReplayBuffer::new(4);
        for i in 0..6 {
            rb.push(tr(i as f32));
        }
        let (buf, next, pushed) = rb.raw_parts();
        let (buf, next, pushed) = (buf.to_vec(), next, pushed);
        let mut restored = ReplayBuffer::new(4);
        restored.restore_parts(buf, next, pushed).unwrap();
        assert_eq!(restored.pushed, 6);
        // Same ring state ⇒ same samples and same future overwrites.
        let (mut r1, mut r2) = (Rng::new(9), Rng::new(9));
        let (mut o1, mut o2) = (Vec::new(), Vec::new());
        rb.sample_into(&mut r1, &mut o1, 4);
        restored.sample_into(&mut r2, &mut o2, 4);
        assert_eq!(o1, o2);
        rb.push(tr(6.0));
        restored.push(tr(6.0));
        assert_eq!(rb.iter().collect::<Vec<_>>(), restored.iter().collect::<Vec<_>>());
    }

    #[test]
    fn restore_parts_rejects_bad_shapes() {
        let mut rb = ReplayBuffer::new(2);
        assert!(rb.restore_parts(vec![tr(0.0); 3], 0, 3).is_err());
        assert!(rb.restore_parts(vec![tr(0.0)], 2, 1).is_err());
    }

    #[test]
    fn prop_ring_never_exceeds_capacity() {
        crate::util::prop::forall_ns(
            9,
            |r| (1 + r.below(32), r.below(200)),
            |&(cap, pushes)| {
                let mut rb = ReplayBuffer::new(cap);
                for i in 0..pushes {
                    rb.push(tr(i as f32));
                }
                if rb.len() <= cap && rb.len() == pushes.min(cap) {
                    Ok(())
                } else {
                    Err(format!("len {} cap {cap} pushes {pushes}", rb.len()))
                }
            },
        );
    }
}
