//! HIRO-style hierarchical agent (paper §3.2): four flat DDPG controllers —
//! weight/activation HLC (goals, Eq.-1 state, s16) and weight/activation
//! LLC (channel actions, state ⊕ goal, s17) — plus the off-policy goal
//! relabeling correction of "Correcting High level Training".

use crate::agent::ddpg::{DdpgAgent, DdpgHyper};
use crate::agent::noise::NoiseSchedule;
use crate::agent::replay::{ReplayBuffer, Transition};
use crate::env::state::STATE_DIM;
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// Which controller pair (weights or activations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Weight,
    Act,
}

/// LLC state = 16 Eq.-1 features ⊕ goal.  The goal also shadows feature
/// 11/12 (gw/ga), so relabeling must rewrite both slots.
pub const LLC_DIM: usize = STATE_DIM + 1;

pub fn set_goal(s: &mut [f32], side: Side, g: f32) {
    match side {
        Side::Weight => s[11] = g / 32.0,
        Side::Act => s[12] = g / 32.0,
    }
    s[STATE_DIM] = g / 32.0;
}

/// Configuration of the hierarchical agent.
#[derive(Debug, Clone)]
pub struct HiroConfig {
    pub hyper: DdpgHyper,
    /// Intrinsic-reward mixing ζ (paper §3.3).
    pub zeta: f32,
    /// Gaussian candidates for goal relabeling (paper: 8, plus g_t and G_t).
    pub relabel_candidates: usize,
    /// σ of the relabel candidate Gaussian (bits).
    pub relabel_sigma: f64,
    /// Replay capacity (paper: 2000).
    pub replay_capacity: usize,
    pub noise: NoiseSchedule,
}

impl Default for HiroConfig {
    fn default() -> Self {
        HiroConfig {
            hyper: DdpgHyper::default(),
            zeta: 0.5,
            relabel_candidates: 8,
            relabel_sigma: 4.0,
            replay_capacity: 2000,
            noise: NoiseSchedule::paper(),
        }
    }
}

pub struct HiroAgent {
    pub cfg: HiroConfig,
    pub hlc_w: DdpgAgent,
    pub hlc_a: DdpgAgent,
    pub llc_w: DdpgAgent,
    pub llc_a: DdpgAgent,
    pub replay_hlc_w: ReplayBuffer,
    pub replay_hlc_a: ReplayBuffer,
    pub replay_llc_w: ReplayBuffer,
    pub replay_llc_a: ReplayBuffer,
    pub rng: Rng,
}

impl HiroAgent {
    pub fn new(rt: &Runtime, cfg: HiroConfig, seed: u64) -> anyhow::Result<HiroAgent> {
        let m16 = rt.manifest.agent(STATE_DIM)?.clone();
        let m17 = rt.manifest.agent(LLC_DIM)?.clone();
        let mut rng = Rng::new(seed);
        let mk16 = |r: &mut Rng| DdpgAgent::new(m16.clone(), cfg.hyper, r);
        let hlc_w = mk16(&mut rng);
        let hlc_a = mk16(&mut rng);
        let mk17 = |r: &mut Rng| DdpgAgent::new(m17.clone(), cfg.hyper, r);
        let llc_w = mk17(&mut rng);
        let llc_a = mk17(&mut rng);
        let cap = cfg.replay_capacity;
        Ok(HiroAgent {
            cfg,
            hlc_w,
            hlc_a,
            llc_w,
            llc_a,
            replay_hlc_w: ReplayBuffer::new(cap),
            replay_hlc_a: ReplayBuffer::new(cap),
            replay_llc_w: ReplayBuffer::new(cap),
            replay_llc_a: ReplayBuffer::new(cap),
            rng: Rng::new(seed ^ 0x5EED_0001),
        })
    }

    fn hlc(&self, side: Side) -> &DdpgAgent {
        match side {
            Side::Weight => &self.hlc_w,
            Side::Act => &self.hlc_a,
        }
    }
    fn llc(&self, side: Side) -> &DdpgAgent {
        match side {
            Side::Weight => &self.llc_w,
            Side::Act => &self.llc_a,
        }
    }

    /// HLC goal for a layer: μ(s) + exploration noise, clamped to [0, 32].
    pub fn propose_goal(
        &mut self,
        rt: &mut Runtime,
        side: Side,
        state: &[f32],
    ) -> anyhow::Result<f32> {
        let mu = self.hlc(side).act_one(rt, state)?;
        let sigma = self.cfg.noise.sigma_scaled(32.0);
        let g = (mu as f64 + self.rng.normal() * sigma).clamp(0.0, 32.0);
        Ok(g as f32)
    }

    /// LLC action for one channel: round(μ(s ⊕ g) + noise) ∈ {0..32}.
    pub fn propose_action(
        &mut self,
        rt: &mut Runtime,
        side: Side,
        llc_state: &[f32],
    ) -> anyhow::Result<f32> {
        let mu = self.llc(side).act_one(rt, llc_state)?;
        let sigma = self.cfg.noise.sigma_scaled(32.0);
        let a = (mu as f64 + self.rng.normal() * sigma).clamp(0.0, 32.0);
        Ok(a as f32)
    }

    /// Batched LLC actions for a whole layer: one executable dispatch for
    /// up to `act_batch` channels (the L3 fast path — see DESIGN.md §Perf).
    /// Noise is applied per row; rounding/clamping matches propose_action.
    pub fn propose_actions_batch(
        &mut self,
        rt: &mut Runtime,
        side: Side,
        states: &[f32],
        n: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let cap = self.llc(side).meta.act_batch;
        let sigma = self.cfg.noise.sigma_scaled(32.0);
        let mut out = Vec::with_capacity(n);
        for chunk_start in (0..n).step_by(cap) {
            let m = (n - chunk_start).min(cap);
            let slice = &states[chunk_start * LLC_DIM..(chunk_start + m) * LLC_DIM];
            let mu = self.llc(side).act(rt, slice, m)?;
            for v in mu {
                out.push(((v as f64 + self.rng.normal() * sigma).clamp(0.0, 32.0)) as f32);
            }
        }
        Ok(out)
    }

    /// HIRO goal relabeling for one layer segment: pick, among
    /// {g_t, G_t, 8 × N(G_t, σ)}, the goal maximizing the LLC's likelihood
    /// of the executed actions; following the paper, among near-maximal
    /// candidates (within 5 % of the best score's range) the *minimal*
    /// goal is selected.
    ///
    /// `seg_states` — row-major (n, 17) LLC states of the segment;
    /// `actions` — the executed actions.
    pub fn relabel_goal(
        &mut self,
        rt: &mut Runtime,
        side: Side,
        seg_states: &[f32],
        actions: &[f32],
        g_orig: f32,
        g_min: f32,
    ) -> anyhow::Result<f32> {
        let n = actions.len();
        if n == 0 {
            return Ok(g_orig);
        }
        let g_real = actions.iter().sum::<f32>() / n as f32; // G_t
        let mut cands = vec![g_orig, g_real];
        for _ in 0..self.cfg.relabel_candidates {
            let g = (g_real as f64 + self.rng.normal() * self.cfg.relabel_sigma)
                .clamp(g_min as f64, 32.0);
            cands.push(g as f32);
        }
        let mut scored = Vec::with_capacity(cands.len());
        let mut buf = seg_states.to_vec();
        for &g in &cands {
            for row in buf.chunks_mut(LLC_DIM) {
                set_goal(row, side, g);
            }
            let lp = self.llc(side).action_log_prob(rt, &buf, n, actions)?;
            scored.push((g, lp));
        }
        let best = scored.iter().map(|&(_, lp)| lp).fold(f64::NEG_INFINITY, f64::max);
        let worst = scored.iter().map(|&(_, lp)| lp).fold(f64::INFINITY, f64::min);
        let tol = (best - worst).abs() * 0.05;
        let g = scored
            .iter()
            .filter(|&&(_, lp)| lp >= best - tol)
            .map(|&(g, _)| g)
            .fold(f32::INFINITY, f32::min);
        Ok(g)
    }

    pub fn push_llc(&mut self, side: Side, t: Transition) {
        match side {
            Side::Weight => self.replay_llc_w.push(t),
            Side::Act => self.replay_llc_a.push(t),
        }
    }
    pub fn push_hlc(&mut self, side: Side, t: Transition) {
        match side {
            Side::Weight => self.replay_hlc_w.push(t),
            Side::Act => self.replay_hlc_a.push(t),
        }
    }

    /// Off-policy updates after an episode: `n_llc` minibatch steps per LLC
    /// and `n_hlc` per HLC.
    pub fn train(&mut self, rt: &mut Runtime, n_llc: usize, n_hlc: usize) -> anyhow::Result<()> {
        for _ in 0..n_llc {
            self.llc_w.update(rt, &self.replay_llc_w, &mut self.rng)?;
            self.llc_a.update(rt, &self.replay_llc_a, &mut self.rng)?;
        }
        for _ in 0..n_hlc {
            self.hlc_w.update(rt, &self.replay_hlc_w, &mut self.rng)?;
            self.hlc_a.update(rt, &self.replay_hlc_a, &mut self.rng)?;
        }
        Ok(())
    }

    pub fn end_episode(&mut self) {
        self.cfg.noise.advance_episode();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_goal_updates_both_slots() {
        let mut s = vec![0.0f32; LLC_DIM];
        set_goal(&mut s, Side::Weight, 16.0);
        assert_eq!(s[11], 0.5);
        assert_eq!(s[STATE_DIM], 0.5);
        set_goal(&mut s, Side::Act, 8.0);
        assert_eq!(s[12], 0.25);
        assert_eq!(s[STATE_DIM], 0.25);
    }
}
