//! Exploration noise schedule (paper §3.2/§4): actions are collected as
//! a ~ N(μ(s), δ) with δ = 0.5 held constant for the first 100 warm-up
//! episodes, then decayed exponentially each episode during exploitation.
//!
//! δ is expressed as a fraction of the action scale (32), matching the
//! DDPG convention the paper inherits.

#[derive(Debug, Clone)]
pub struct NoiseSchedule {
    pub sigma0: f64,
    pub warmup_episodes: usize,
    pub decay: f64,
    episode: usize,
}

impl NoiseSchedule {
    /// Paper settings: δ=0.5, 100 explore episodes, then exponential decay
    /// over the 300 exploit episodes (δ≈0.05 by the end).
    pub fn paper() -> Self {
        NoiseSchedule { sigma0: 0.5, warmup_episodes: 100, decay: 0.99, episode: 0 }
    }

    pub fn new(sigma0: f64, warmup_episodes: usize, decay: f64) -> Self {
        NoiseSchedule { sigma0, warmup_episodes, decay, episode: 0 }
    }

    /// Current δ (fraction of action scale).
    pub fn sigma(&self) -> f64 {
        if self.episode < self.warmup_episodes {
            self.sigma0
        } else {
            self.sigma0 * self.decay.powi((self.episode - self.warmup_episodes) as i32)
        }
    }

    /// Absolute σ in action units for scale (e.g. 32).
    pub fn sigma_scaled(&self, scale: f64) -> f64 {
        self.sigma() * scale
    }

    pub fn advance_episode(&mut self) {
        self.episode += 1;
    }

    pub fn episode(&self) -> usize {
        self.episode
    }

    /// Restore the episode counter from a checkpoint (the schedule's only
    /// mutable state; σ₀/warmup/decay are rebuilt from config).
    pub fn set_episode(&mut self, episode: usize) {
        self.episode = episode;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_during_warmup_then_decays() {
        let mut n = NoiseSchedule::paper();
        assert_eq!(n.sigma(), 0.5);
        for _ in 0..100 {
            n.advance_episode();
        }
        assert_eq!(n.sigma(), 0.5);
        n.advance_episode();
        assert!(n.sigma() < 0.5);
        let s1 = n.sigma();
        n.advance_episode();
        assert!(n.sigma() < s1);
    }

    #[test]
    fn decay_is_exponential() {
        let mut n = NoiseSchedule::new(1.0, 0, 0.5);
        n.advance_episode();
        assert!((n.sigma() - 0.5).abs() < 1e-12);
        n.advance_episode();
        assert!((n.sigma() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn scaled_sigma() {
        let n = NoiseSchedule::paper();
        assert_eq!(n.sigma_scaled(32.0), 16.0);
    }
}
