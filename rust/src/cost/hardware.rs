//! Fig.-1 hardware cost model: (32 nm) transistor counts of the logic that
//! finishes one output channel's convolutions per cycle, for quantized
//! (fixed-point multiply-accumulate) vs binarized (XNOR + popcount)
//! datapaths, normalized to a 32-bit floating-point MAC unit.
//!
//! The paper plots normalized transistor counts; absolute constants below
//! are standard static-CMOS gate budgets (NAND2 = 4T, XOR/XNOR = 8T,
//! 1-bit full adder = 28T, 6T SRAM cell) — the *ratios* reproduce Fig. 1's
//! qualitative shape: cost falls with bit-width, and a binarized datapath
//! undercuts a quantized one at equal nominal bits.

/// Transistors of a 1-bit full adder (mirror CMOS).
const FA_T: f64 = 28.0;
/// Transistors of an AND gate.
const AND_T: f64 = 6.0;
/// Transistors of an XNOR gate.
const XNOR_T: f64 = 8.0;
/// 32-bit floating point MAC (multiplier + adder + normalization) — the
/// normalization denominator of Fig. 1.
pub const FP32_MAC_T: f64 = 33_000.0;

/// Array multiplier for bw × ba fixed point: bw·ba AND terms + carry-save
/// adder array of ~bw·ba full adders.
pub fn quant_mult_transistors(bw: u32, ba: u32) -> f64 {
    if bw == 0 || ba == 0 {
        return 0.0;
    }
    let partial = (bw * ba) as f64 * AND_T;
    let reduce = (bw * ba) as f64 * FA_T;
    // Accumulator adder sized to the product width + 4 guard bits.
    let acc = (bw + ba + 4) as f64 * FA_T;
    partial + reduce + acc
}

/// Binarized datapath for BBN_w × BBN_a: one XNOR per bit-plane pair, a
/// shared popcount tree (~FA per input bit), and BBN_w·BBN_a scale
/// multiplies amortized over the channel (fixed small multiplier).
pub fn binar_unit_transistors(bw: u32, ba: u32) -> f64 {
    if bw == 0 || ba == 0 {
        return 0.0;
    }
    let planes = (bw * ba) as f64;
    let xnor = planes * XNOR_T;
    // Popcount: ~1 FA per counted bit (Wallace-style tree), shared.
    let popcount = planes * FA_T * 0.5;
    // α·β scale-and-add per plane pair, amortized over the ~256 MACs of a
    // typical output channel (one scale multiply per plane per channel).
    let scale = planes * quant_mult_transistors(8, 8) / 256.0;
    xnor + popcount + scale
}

/// Normalized hardware cost (Fig. 1): transistors / fp32-MAC transistors.
pub fn normalized_cost(mode: Mode, bw: u32, ba: u32) -> f64 {
    let t = match mode {
        Mode::Quant => quant_mult_transistors(bw, ba),
        Mode::Binar => binar_unit_transistors(bw, ba),
    };
    t / FP32_MAC_T
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Quant,
    Binar,
}

impl Mode {
    pub fn parse(s: &str) -> anyhow::Result<Mode> {
        match s {
            "quant" | "q" => Ok(Mode::Quant),
            "binar" | "b" => Ok(Mode::Binar),
            _ => anyhow::bail!("mode must be quant|binar, got {s:?}"),
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Quant => "quant",
            Mode::Binar => "binar",
        }
    }
}

/// The Fig.-1 sweep rows: (bits, normalized quant cost, normalized binar
/// cost) for symmetric weight/activation bit-widths.
pub fn fig1_table(max_bits: u32) -> Vec<(u32, f64, f64)> {
    (1..=max_bits)
        .map(|b| {
            (
                b,
                normalized_cost(Mode::Quant, b, b),
                normalized_cost(Mode::Binar, b, b),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_monotone_in_bits() {
        for b in 1..32 {
            assert!(
                quant_mult_transistors(b + 1, b + 1) > quant_mult_transistors(b, b),
                "quant not monotone at {b}"
            );
            assert!(
                binar_unit_transistors(b + 1, b + 1) > binar_unit_transistors(b, b),
                "binar not monotone at {b}"
            );
        }
    }

    #[test]
    fn binar_cheaper_than_quant_same_bits() {
        // Fig. 1's headline: same nominal bit-widths, binarized logic costs
        // much less than the fixed-point datapath.
        for b in 1..=8 {
            let q = quant_mult_transistors(b, b);
            let x = binar_unit_transistors(b, b);
            assert!(x < q, "bits={b}: binar {x} !< quant {q}");
        }
    }

    #[test]
    fn normalization_below_one_for_low_bits() {
        // A ≤8-bit datapath is far below a fp32 MAC (paper: "significantly
        // reduced").
        assert!(normalized_cost(Mode::Quant, 8, 8) < 0.2);
        assert!(normalized_cost(Mode::Binar, 8, 8) < 0.1);
        // Pruned = free.
        assert_eq!(normalized_cost(Mode::Quant, 0, 5), 0.0);
    }

    #[test]
    fn fig1_rows_complete() {
        let t = fig1_table(32);
        assert_eq!(t.len(), 32);
        assert_eq!(t[0].0, 1);
        assert!(t[31].1 > t[0].1);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(Mode::parse("quant").unwrap(), Mode::Quant);
        assert_eq!(Mode::parse("b").unwrap(), Mode::Binar);
        assert!(Mode::parse("x").is_err());
    }
}
