//! Cost models: bit-level logic-op counting (m(N), Algorithm-1 budgets) and
//! the Fig.-1 transistor-level hardware cost model.

pub mod hardware;
pub mod logic;

pub use hardware::Mode;
pub use logic::{model_cost, ModelCost};
