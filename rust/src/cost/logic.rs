//! Logic-operation cost model (paper §3.2–3.3).
//!
//! Quantized inference: a `bw`-bit × `ba`-bit fixed-point multiply performs
//! `bw · ba` bit-level AND operations inside a serial-parallel multiplier
//! [Gnanasekaran 6].  Binarized inference: BBN_w × BBN_a binary filter
//! pairs each contribute one XNOR per MAC position [Lin 17].  Either way,
//! the bit-level logic-op count of a MAC between a weight channel with
//! bit-width `bw` and an activation channel with `ba` is `bw · ba` — the
//! quantity `m(N)` in NetScore and the budget of Algorithm 1.
//!
//! Channel-level factorization: for a dense conv layer every (output
//! channel, input channel) pair contributes `h_out·w_out·k²` MACs, so
//!   logic = h_out·w_out·k² · (Σ_oc bw[oc]) · (Σ_ic ba[ic])
//! For depthwise conv, channel c pairs only with itself; for fc layers all
//! inputs share one activation bit-width (paper §3.2).

use crate::runtime::LayerMeta;

/// Full-precision reference bit-width (32-bit IEEE754 in the paper).
pub const FP_BITS: u64 = 32;

/// Bit-level logic ops of one layer under per-channel bit assignments.
///
/// `wbits` — one entry per weight output channel of this layer;
/// `abits` — one entry per activation input channel (len 1 for fc).
pub fn layer_logic_ops(layer: &LayerMeta, wbits: &[u8], abits: &[u8]) -> u64 {
    assert_eq!(wbits.len(), layer.w_len, "{}: wbits len", layer.name);
    assert_eq!(abits.len(), layer.a_len, "{}: abits len", layer.name);
    let sum_w: u64 = wbits.iter().map(|&b| b as u64).sum();
    match layer.typ.as_str() {
        "fc" => {
            // One shared activation bit-width; each output unit does cin MACs.
            let ba = abits[0] as u64;
            layer.cin as u64 * sum_w * ba
        }
        "dwconv" => {
            // Channel c's filter convolves only input channel c.
            let per_c = (layer.h_out * layer.w_out * layer.k * layer.k) as u64;
            wbits
                .iter()
                .zip(abits)
                .map(|(&bw, &ba)| per_c * bw as u64 * ba as u64)
                .sum()
        }
        _ => {
            let per_pair = (layer.h_out * layer.w_out * layer.k * layer.k) as u64;
            let sum_a: u64 = abits.iter().map(|&b| b as u64).sum();
            per_pair * sum_w * sum_a
        }
    }
}

/// Logic ops of the layer at full precision (all channels FP_BITS).
pub fn layer_logic_fp(layer: &LayerMeta) -> u64 {
    layer.macs * FP_BITS * FP_BITS
}

/// logic_t of Eq. 1: the MAC count of the layer (bit-independent part).
pub fn layer_macs(layer: &LayerMeta) -> u64 {
    layer.macs
}

/// Quantized-weight storage bits: Σ_c (elems per channel · bw[c]).
/// `w_elems_per_channel` = k·k·(cin/groups) for conv, cin for fc.
pub fn layer_weight_bits(layer: &LayerMeta, wbits: &[u8]) -> u64 {
    let per_c = match layer.typ.as_str() {
        "fc" => layer.cin as u64,
        "dwconv" => (layer.k * layer.k) as u64,
        _ => (layer.k * layer.k * layer.cin) as u64,
    };
    wbits.iter().map(|&b| per_c * b as u64).sum()
}

/// Whole-model audit under a bit config (both vectors in network order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelCost {
    /// Bit-level logic ops (ANDs for quant, XNORs for binar).
    pub logic_ops: u64,
    /// Same model at 32-bit full precision.
    pub logic_fp: u64,
    /// Quantized weight payload in bits.
    pub weight_bits: u64,
    /// Full-precision weight payload in bits.
    pub weight_bits_fp: u64,
}

impl ModelCost {
    /// m(N) normalized to the full-precision model (paper Table 4 "Norm.
    /// Logic" column).
    pub fn norm_logic(&self) -> f64 {
        self.logic_ops as f64 / self.logic_fp.max(1) as f64
    }
    /// p(N): Σ QBN per weight / 32, normalized by weight count — the
    /// architectural-complexity term of NetScore.
    pub fn norm_params(&self) -> f64 {
        self.weight_bits as f64 / self.weight_bits_fp.max(1) as f64
    }
}

pub fn model_cost(layers: &[LayerMeta], wbits: &[u8], abits: &[u8]) -> ModelCost {
    let mut c = ModelCost { logic_ops: 0, logic_fp: 0, weight_bits: 0, weight_bits_fp: 0 };
    for l in layers {
        let wb = &wbits[l.w_off..l.w_off + l.w_len];
        let ab = &abits[l.a_off..l.a_off + l.a_len];
        c.logic_ops += layer_logic_ops(l, wb, ab);
        c.logic_fp += layer_logic_fp(l);
        c.weight_bits += layer_weight_bits(l, wb);
        c.weight_bits_fp += layer_weight_bits(l, &vec![FP_BITS as u8; l.w_len]);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_ns;
    use crate::util::rng::Rng;

    fn conv_layer() -> LayerMeta {
        LayerMeta {
            name: "l01_conv".into(),
            typ: "conv".into(),
            k: 3,
            stride: 1,
            cin: 4,
            cout: 8,
            h_in: 16,
            w_in: 16,
            h_out: 16,
            w_out: 16,
            macs: (16 * 16 * 3 * 3 * 4 * 8) as u64,
            w_off: 0,
            w_len: 8,
            a_off: 0,
            a_len: 4,
        }
    }

    fn fc_layer() -> LayerMeta {
        LayerMeta {
            name: "l02_fc".into(),
            typ: "fc".into(),
            k: 1,
            stride: 1,
            cin: 64,
            cout: 10,
            h_in: 1,
            w_in: 1,
            h_out: 1,
            w_out: 1,
            macs: 640,
            w_off: 8,
            w_len: 10,
            a_off: 4,
            a_len: 1,
        }
    }

    #[test]
    fn uniform_bits_match_closed_form() {
        let l = conv_layer();
        let logic = layer_logic_ops(&l, &[5; 8], &[4; 4]);
        // macs * bw * ba
        assert_eq!(logic, l.macs * 5 * 4);
        assert_eq!(layer_logic_fp(&l), l.macs * 1024);
    }

    #[test]
    fn fc_shares_activation_bits() {
        let l = fc_layer();
        let logic = layer_logic_ops(&l, &[3; 10], &[6]);
        assert_eq!(logic, 64 * 10 * 3 * 6);
    }

    #[test]
    fn pruned_channels_cost_zero() {
        let l = conv_layer();
        let mut wb = [5u8; 8];
        wb[0] = 0;
        let full = layer_logic_ops(&l, &[5; 8], &[4; 4]) as i64;
        let cut = layer_logic_ops(&l, &wb, &[4; 4]) as i64;
        // Removing one of 8 output channels removes exactly 1/8 of the ops.
        assert_eq!(full - cut, full / 8);
    }

    #[test]
    fn prop_monotone_in_bits() {
        // Raising any channel's bits never lowers logic ops or weight bits.
        forall_ns(
            42,
            |r: &mut Rng| {
                let wb: Vec<u8> = (0..8).map(|_| r.below(9) as u8).collect();
                let ab: Vec<u8> = (0..4).map(|_| r.below(9) as u8).collect();
                let which = r.below(8);
                (wb, ab, which)
            },
            |(wb, ab, which)| {
                let l = conv_layer();
                let base = layer_logic_ops(&l, wb, ab);
                let mut hi = wb.clone();
                hi[*which] = (hi[*which] + 1).min(32);
                let bumped = layer_logic_ops(&l, &hi, ab);
                if bumped >= base {
                    Ok(())
                } else {
                    Err(format!("bumped {bumped} < base {base}"))
                }
            },
        );
    }

    #[test]
    fn model_cost_aggregates_and_normalizes() {
        let layers = vec![conv_layer(), fc_layer()];
        let wbits = vec![5u8; 18];
        let abits = vec![5u8; 5];
        let c = model_cost(&layers, &wbits, &abits);
        assert_eq!(c.logic_ops, (conv_layer().macs + 640) * 25);
        assert!((c.norm_logic() - 25.0 / 1024.0).abs() < 1e-12);
        assert!((c.norm_params() - 5.0 / 32.0).abs() < 1e-12);
    }
}
