//! AutoQ: hierarchical-DRL kernel-wise (channel-level) network quantization
//! and binarization — a rust + JAX + Pallas reproduction of "AutoQ:
//! Automated Kernel-Wise Neural Network Quantization" (ICLR 2020; arXiv
//! title "AutoQB").
//!
//! Layer 3 (this crate) owns the search loop, hierarchical agent state,
//! rewards, cost models and FPGA simulators; Layer 2 (JAX) and Layer 1
//! (Pallas) are AOT-compiled to HLO text and executed via PJRT — python is
//! never on the search path.  See DESIGN.md.

pub mod agent;
pub mod env;
pub mod finetune;
pub mod search;
pub mod baselines;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod journal;
pub mod models;
pub mod quant;
pub mod repro;
pub mod reward;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
