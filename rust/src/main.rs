//! AutoQ leader binary: CLI over the coordinator library.
//!
//! Subcommands:
//!   pretrain   — train a zoo model (fp32) on the synthetic dataset
//!   search     — hierarchical channel/layer/network bit-width search
//!   finetune   — fine-tune a searched bit configuration
//!   eval       — evaluate a model / bit config
//!   sim        — run a searched config through the FPGA simulators
//!   repro      — regenerate a paper table/figure (see DESIGN.md index)
//!   stats      — dump runtime executable statistics
//!
//! Run `autoq <cmd> --help` for options.

use std::path::PathBuf;

use autoq::cost::Mode;
use autoq::data::synth::SynthDataset;
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::Runtime;
use autoq::search::{Granularity, Protocol, SearchConfig};
use autoq::util::cli::Args;
use autoq::util::rng::Rng;

fn main() {
    autoq::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let code = match run(&cmd, rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, rest: &[String]) -> anyhow::Result<()> {
    match cmd {
        "pretrain" => cmd_pretrain(rest),
        "search" => cmd_search(rest),
        "finetune" => cmd_finetune(rest),
        "eval" => cmd_eval(rest),
        "sim" => cmd_sim(rest),
        "repro" => autoq::repro::cmd_repro(rest),
        "stats" => cmd_stats(rest),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "autoq — hierarchical-DRL kernel-wise quantization/binarization

commands:
  pretrain --model M --steps N            pre-train a zoo model
  search   --model M --mode quant|binar --protocol rc|ag|fr \\
           --granularity n|l|c --episodes N   run a search
  finetune --model M --config FILE --steps N  fine-tune a searched config
  eval     --model M [--config FILE]          evaluate fp32 or a config
  sim      --model M --config FILE            FPGA simulator report
  repro    <fig1|table2|table3|table4|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|storage|all>
  stats                                        runtime executable stats";

fn params_path(model: &str) -> PathBuf {
    PathBuf::from(format!("artifacts/{model}_trained.apb"))
}

/// Load a pre-trained runner (pretraining first if missing).
pub fn load_runner(rt: &mut Runtime, model: &str, auto_pretrain: bool) -> anyhow::Result<ModelRunner> {
    let meta = rt.manifest.model(model)?.clone();
    let path = params_path(model);
    if path.exists() {
        let params = ParamStore::load(&path)?;
        return ModelRunner::new(meta, params);
    }
    anyhow::ensure!(auto_pretrain, "{} not found — run `autoq pretrain --model {model}`", path.display());
    autoq::info!("no trained params for {model}; pre-training now");
    let mut runner = ModelRunner::init(meta, &mut Rng::new(0xA0_70_u64 ^ model.len() as u64));
    let data = SynthDataset::new(42);
    let cfg = autoq::finetune::TrainConfig::pretrain_for(model, 300);
    let rep = autoq::finetune::train(rt, &mut runner, &data, &cfg)?;
    autoq::info!("pretrained {model}: acc={:.4}", rep.final_eval.accuracy);
    runner.params.save(&path)?;
    Ok(runner)
}

fn cmd_pretrain(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("pretrain")
        .opt("model", "cif10", "zoo model name")
        .opt("steps", "300", "SGD steps")
        .opt("seed", "42", "dataset seed")
        .parse(rest)?;
    let model = a.get("model");
    let mut rt = Runtime::open_default()?;
    let meta = rt.manifest.model(&model)?.clone();
    let mut runner = ModelRunner::init(meta, &mut Rng::new(0xA0_70_u64 ^ model.len() as u64));
    let data = SynthDataset::new(a.get_u64("seed")?);
    let cfg = autoq::finetune::TrainConfig::pretrain_for(&model, a.get_usize("steps")?);
    let rep = autoq::finetune::train(&mut rt, &mut runner, &data, &cfg)?;
    println!("pretrain {model}: final loss curve tail {:?}", rep.curve.last());
    println!("val accuracy: {:.4} ({} images)", rep.final_eval.accuracy, rep.final_eval.images);
    runner.params.save(&params_path(&model))?;
    println!("saved {}", params_path(&model).display());
    Ok(())
}

fn cmd_search(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("search")
        .opt("model", "cif10", "zoo model name")
        .opt("mode", "quant", "quant|binar")
        .opt("protocol", "rc", "rc|ag|fr")
        .opt("granularity", "c", "n|l|c (network/layer/channel)")
        .opt("episodes", "40", "search episodes")
        .opt("warmup", "10", "constant-noise episodes")
        .opt("eval-batches", "2", "val batches per evaluation")
        .opt("seed", "1", "agent seed")
        .opt("target-bits", "5", "B-bar for Algorithm 1 (rc)")
        .opt("out", "", "write best config JSON here")
        .flag("paper-scale", "use the paper's 400-episode schedule")
        .flag("no-relabel", "disable HIRO goal relabeling (ablation)")
        .parse(rest)?;
    let model = a.get("model");
    let mut rt = Runtime::open_default()?;
    let runner = load_runner(&mut rt, &model, true)?;
    let data = SynthDataset::new(42);
    let mode = Mode::parse(&a.get("mode"))?;
    let mut protocol = Protocol::parse(&a.get("protocol"))?;
    protocol.target_bits = a.get_f64("target-bits")?;
    let gran = Granularity::parse(&a.get("granularity"))?;
    let mut cfg = SearchConfig::quick(mode, protocol, gran);
    cfg.episodes = a.get_usize("episodes")?;
    cfg.warmup = a.get_usize("warmup")?;
    cfg.eval_batches = a.get_usize("eval-batches")?;
    cfg.seed = a.get_u64("seed")?;
    cfg.relabel = !a.get_bool("no-relabel");
    if a.get_bool("paper-scale") {
        cfg = cfg.paper_scale();
    }
    let res = autoq::search::run_search(&mut rt, &runner, &data, &cfg)?;
    let b = &res.best;
    println!(
        "best: acc={:.4} reward={:.4} score={:.2} avg_wbits={:.2} avg_abits={:.2} norm_logic={:.4}",
        b.accuracy, b.reward, b.score, b.avg_wbits, b.avg_abits, b.cost.norm_logic()
    );
    println!("search took {:.1}s over {} episodes", res.secs, res.history.len());
    let out = a.get("out");
    if !out.is_empty() {
        autoq::quant::save_config(&PathBuf::from(&out), &model, mode, b)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_finetune(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("finetune")
        .opt("model", "cif10", "zoo model name")
        .opt("config", "", "searched config JSON (from search --out)")
        .opt("steps", "200", "fine-tune steps")
        .parse(rest)?;
    let model = a.get("model");
    let mut rt = Runtime::open_default()?;
    let mut runner = load_runner(&mut rt, &model, true)?;
    let cfgf = a.get("config");
    anyhow::ensure!(!cfgf.is_empty(), "--config required");
    let saved = autoq::quant::load_config(&PathBuf::from(&cfgf))?;
    let data = SynthDataset::new(42);
    let tc = autoq::finetune::TrainConfig::finetune(
        saved.mode,
        saved.wbits.clone(),
        saved.abits.clone(),
        a.get_usize("steps")?,
    );
    let before = runner.eval_config(
        &mut rt, saved.mode, &saved.wbits, &saved.abits, &data,
        autoq::data::Split::Val, 2,
    )?;
    let rep = autoq::finetune::train(&mut rt, &mut runner, &data, &tc)?;
    println!(
        "finetune {model}: acc {:.4} -> {:.4} over {} steps ({:.1}s)",
        before.accuracy, rep.final_eval.accuracy, a.get_usize("steps")?, rep.secs
    );
    Ok(())
}

fn cmd_eval(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("eval")
        .opt("model", "cif10", "zoo model name")
        .opt("config", "", "optional searched config JSON")
        .opt("batches", "4", "val batches")
        .parse(rest)?;
    let model = a.get("model");
    let mut rt = Runtime::open_default()?;
    let runner = load_runner(&mut rt, &model, true)?;
    let data = SynthDataset::new(42);
    let nb = a.get_usize("batches")?;
    let cfgf = a.get("config");
    let res = if cfgf.is_empty() {
        runner.eval_fp32(&mut rt, &data, autoq::data::Split::Val, nb)?
    } else {
        let saved = autoq::quant::load_config(&PathBuf::from(&cfgf))?;
        runner.eval_config(
            &mut rt, saved.mode, &saved.wbits, &saved.abits, &data,
            autoq::data::Split::Val, nb,
        )?
    };
    println!("{model}: accuracy {:.4} loss {:.4} ({} images)", res.accuracy, res.loss, res.images);
    Ok(())
}

fn cmd_sim(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("sim")
        .opt("model", "cif10", "zoo model name")
        .opt("config", "", "searched config JSON")
        .parse(rest)?;
    let model = a.get("model");
    let rt = Runtime::open_default()?;
    let meta = rt.manifest.model(&model)?.clone();
    let cfgf = a.get("config");
    let (mode, wbits, abits) = if cfgf.is_empty() {
        (Mode::Quant, vec![5u8; meta.w_channels], vec![5u8; meta.a_channels])
    } else {
        let saved = autoq::quant::load_config(&PathBuf::from(&cfgf))?;
        (saved.mode, saved.wbits, saved.abits)
    };
    println!("{:<10} {:>10} {:>12} {:>8}", "arch", "fps", "energy(mJ)", "util");
    for arch in [autoq::sim::Arch::Temporal, autoq::sim::Arch::Spatial] {
        let sim = autoq::sim::FpgaSim::new(arch, mode);
        let r = sim.run(&meta.layers, &wbits, &abits);
        println!(
            "{:<10} {:>10.1} {:>12.3} {:>8.3}",
            arch.as_str(), r.fps, r.energy_j * 1e3, r.utilization
        );
    }
    Ok(())
}

fn cmd_stats(_rest: &[String]) -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    println!("{}", rt.stats_report());
    Ok(())
}
