//! AutoQ leader binary: a thin argument-parsing shell over the coordinator
//! job API (`autoq::coordinator`).  Every subcommand builds a validated
//! `JobSpec`, hands it to the `Coordinator`, and prints from the returned
//! `JobReport` — no runtime/model plumbing lives here.
//!
//! Subcommands:
//!   pretrain   — train a zoo model (fp32) on the synthetic dataset
//!   search     — hierarchical channel/layer/network bit-width search
//!   sweep      — fan a models × modes × protocols × granularities grid of
//!                searches across worker threads (one JSON report per cell)
//!   finetune   — fine-tune a searched bit configuration
//!   eval       — evaluate a model / bit config
//!   sim        — run a searched config through the FPGA simulators
//!   repro      — regenerate a paper table/figure (see DESIGN.md index)
//!   stats      — dump runtime executable statistics
//!
//! Run `autoq <cmd> --help` for options.

use std::path::PathBuf;

use autoq::coordinator::{ActScaleMode, Coordinator, JobOutcome, JobSpec, Sweep};
use autoq::cost::Mode;
use autoq::runtime::{shard, BackendKind, Parallelism, RuntimeOpts};
use autoq::search::{Granularity, Protocol, ProtocolKind};
use autoq::serve::{run_sweep_via_daemon, DaemonClient, ServeConfig, Server};
use autoq::util::cli::{Args, HelpRequested, UsageError};
use autoq::util::json::Json;

/// Shared `--backend` option help (pjrt|reference|shard; empty = auto).
const BACKEND_HELP: &str = "pjrt|reference|shard (default: $AUTOQ_BACKEND, else auto)";

/// Shared `--threads` option help (empty/auto/0 = auto-resolve).
const THREADS_HELP: &str =
    "reference-backend eval worker threads (default: $AUTOQ_THREADS, else all cores)";

/// Shared `--shard-workers` option help (empty/auto/0 = auto-resolve).
const SHARD_WORKERS_HELP: &str =
    "worker processes for --backend shard (default: $AUTOQ_SHARD_WORKERS, else 2)";

/// Shared `--shard-hosts` option help (empty = env, no hosts by default).
const SHARD_HOSTS_HELP: &str = "comma-separated host:port list of remote `autoq worker --listen` \
     peers for --backend shard (default: $AUTOQ_SHARD_HOSTS)";

/// Shared `--shard-encoding` option help (empty/auto = env, else binary).
const SHARD_ENCODING_HELP: &str =
    "shard wire encoding json|binary (default: $AUTOQ_SHARD_ENCODING, else binary)";

/// Shared `--act-scales` option help (empty = env, else dynamic).
const ACT_SCALES_HELP: &str = "activation quantization scales static|dynamic — static runs a \
     deterministic calibration pass and reuses one scale per layer (default: $AUTOQ_ACT_SCALES, \
     else dynamic per-row scales)";

/// Shared `--checkpoint-every` option help (empty = env, else off).
const CHECKPOINT_HELP: &str = "snapshot the full search state to a durable journal every N \
     episodes so a killed run resumes from its last snapshot; 0 disables (default: \
     $AUTOQ_CHECKPOINT_EVERY, else 0)";

/// Apply the shared `--checkpoint-every` option to an opened coordinator
/// (empty string = keep the env-resolved cadence).
fn apply_checkpoint_every(a: &Args, coord: &mut Coordinator) -> anyhow::Result<()> {
    let s = a.get("checkpoint-every");
    if !s.is_empty() {
        coord.set_checkpoint_every(
            s.parse::<usize>()
                .map_err(|_| UsageError(format!("--checkpoint-every wants a number, got {s:?}")))?,
        );
    }
    Ok(())
}

/// Apply the shared `--act-scales` option to an opened coordinator (empty
/// string = keep the env-resolved mode).  Must run before the first model
/// load so calibration happens during `ensure_pretrained`.
fn apply_act_scales(a: &Args, coord: &mut Coordinator) -> anyhow::Result<()> {
    let s = a.get("act-scales");
    if !s.is_empty() {
        coord.set_act_scale_mode(ActScaleMode::parse(&s)?);
    }
    Ok(())
}

/// Parse the shared `--backend` option (empty string = auto-resolve).
fn backend_arg(a: &Args) -> anyhow::Result<Option<BackendKind>> {
    BackendKind::parse_opt(&a.get("backend"))
}

/// Parse the shared `--threads` option (empty/auto/0 = auto-resolve).
fn threads_arg(a: &Args) -> anyhow::Result<Option<Parallelism>> {
    Parallelism::parse_opt(&a.get("threads"))
}

/// Parse the shared `--shard-workers` option (empty/auto/0 = auto-resolve).
fn shard_workers_arg(a: &Args) -> anyhow::Result<Option<usize>> {
    shard::parse_workers_opt(&a.get("shard-workers"))
}

/// Parse the shared `--shard-hosts` option (empty = env-resolve).
fn shard_hosts_arg(a: &Args) -> anyhow::Result<Option<Vec<String>>> {
    shard::parse_hosts_opt(&a.get("shard-hosts"))
}

/// Parse the shared `--shard-encoding` option (empty/auto = env-resolve).
fn shard_encoding_arg(a: &Args) -> anyhow::Result<Option<shard::Encoding>> {
    shard::Encoding::parse_opt(&a.get("shard-encoding"))
}

/// The shared runtime knobs behind `--threads`/`--shard-*`.
fn runtime_opts(a: &Args) -> anyhow::Result<RuntimeOpts> {
    Ok(RuntimeOpts {
        threads: threads_arg(a)?,
        shard_workers: shard_workers_arg(a)?,
        shard_hosts: shard_hosts_arg(a)?,
        shard_encoding: shard_encoding_arg(a)?,
    })
}

/// Open the default-artifact-dir coordinator honouring `--backend`,
/// `--threads` and `--shard-workers`.
fn open_coord(a: &Args) -> anyhow::Result<Coordinator> {
    Coordinator::open_full(&Coordinator::default_dir(), backend_arg(a)?, runtime_opts(a)?)
}

fn main() {
    autoq::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    // Exit codes: 0 success (including --help), 1 job/runtime failure
    // (structured errors like a rejected spec or a failed daemon job),
    // 2 caller mistakes (unknown command/option, malformed values).
    let code = match run(&cmd, rest) {
        Ok(()) => 0,
        Err(e) if e.downcast_ref::<HelpRequested>().is_some() => {
            println!("{e}");
            0
        }
        Err(e) if e.downcast_ref::<UsageError>().is_some() => {
            eprintln!("error: {e}");
            2
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, rest: &[String]) -> anyhow::Result<()> {
    match cmd {
        "pretrain" => cmd_pretrain(rest),
        "search" => cmd_search(rest),
        "sweep" => cmd_sweep(rest),
        "finetune" => cmd_finetune(rest),
        "eval" => cmd_eval(rest),
        "sim" => cmd_sim(rest),
        "repro" => autoq::repro::cmd_repro(rest),
        "stats" => cmd_stats(rest),
        "serve" => cmd_serve(rest),
        "submit" => cmd_submit(rest),
        "status" => cmd_status(rest),
        // Hidden: the shard backend's subprocess entry point.  Speaks the
        // length-prefixed JSON protocol on stdin/stdout (see
        // runtime/shard/proto.rs) — never invoked by hand.
        "worker" => cmd_worker(rest),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow::Error::new(UsageError(format!(
            "unknown command {other:?}\n{HELP}"
        )))),
    }
}

const HELP: &str = "autoq — hierarchical-DRL kernel-wise quantization/binarization

commands:
  pretrain --model M --steps N            pre-train a zoo model
  search   --model M --mode quant|binar --protocol rc|ag|fr \\
           --granularity n|l|c --episodes N   run a search
  sweep    --models M1,M2 --modes quant,binar --protocols rc,ag \\
           --granularities l,c --workers K    parallel search grid via the
                                              Coordinator (one JSON JobReport
                                              per cell, deterministic seeds)
  finetune --model M --config FILE --steps N  fine-tune a searched config
  eval     --model M [--config FILE]          evaluate fp32 or a config
  sim      --model M --config FILE            FPGA simulator report
  repro    <fig1|table2|table3|table4|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|storage|all>
  stats                                        runtime executable stats
  serve    --listen ADDR --workers K           job-queue daemon with a shared
                                               content-addressed eval cache
                                               (DESIGN.md §Serve daemon)
  submit   --addr ADDR --kind search|... [job options]  submit a job to a
                                               daemon; --wait blocks for the
                                               result (failed job = exit 1)
  status   --addr ADDR [--job job-N]           query a daemon's queue/job

exit codes: 0 success (and --help), 1 job or runtime failure, 2 bad usage
(unknown command/option, malformed values).

Every command takes --backend {pjrt,reference,shard} (or $AUTOQ_BACKEND):
`pjrt` executes the AOT HLO artifacts, `reference` interprets the same
graphs in pure Rust — no artifacts, no XLA library, runs anywhere — and
`shard` fans exec calls across `--shard-workers` worker *processes* (or
$AUTOQ_SHARD_WORKERS; default 2) that each run a reference runtime, with
results byte-identical to `reference` at every worker count.  Default:
pjrt iff compiled in and artifacts exist, else reference (never shard —
multi-process fan-out is an explicit opt-in).

The shard pool also scales across machines: start `autoq worker --listen
HOST:PORT` on each remote box and point any command at the fleet with
--shard-hosts h1:p,h2:p (or $AUTOQ_SHARD_HOSTS); remote slots compose
with local --shard-workers slots in one pool (with hosts given, the local
count defaults to 0).  --shard-encoding {json,binary} (or
$AUTOQ_SHARD_ENCODING; default binary) picks the wire encoding — results
stay byte-identical across transports and encodings.

Every command also takes --threads N (or $AUTOQ_THREADS; default all
cores): the reference backend fans independent eval batches across N
worker threads with byte-identical results at any N; for `shard`, N is
the total budget split evenly across the worker processes.  For `sweep`,
--threads is the per-worker eval budget (default: cores split evenly
across --workers, so the grid never oversubscribes).

The coordinator job API behind these commands is documented in DESIGN.md.";

fn parse_list<T>(s: &str, f: impl Fn(&str) -> anyhow::Result<T>) -> anyhow::Result<Vec<T>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(f)
        .collect()
}

fn cmd_pretrain(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("pretrain")
        .opt("model", "cif10", "zoo model name")
        .opt("steps", "300", "SGD steps")
        .opt("seed", "42", "dataset seed")
        .opt("backend", "", BACKEND_HELP)
        .opt("threads", "", THREADS_HELP)
        .opt("shard-workers", "", SHARD_WORKERS_HELP)
        .opt("shard-hosts", "", SHARD_HOSTS_HELP)
        .opt("shard-encoding", "", SHARD_ENCODING_HELP)
        .parse(rest)?;
    let model = a.get("model");
    let spec = JobSpec::pretrain(&model)
        .steps(a.get_usize("steps")?)
        .data_seed(a.get_u64("seed")?)
        .build()?;
    let mut coord = open_coord(&a)?;
    let report = coord.run(&spec)?;
    let JobOutcome::Train { final_eval, curve, .. } = &report.outcome else {
        anyhow::bail!("pretrain job returned an unexpected report kind");
    };
    println!("pretrain {model}: final loss curve tail {:?}", curve.last());
    println!("val accuracy: {:.4} ({} images)", final_eval.accuracy, final_eval.images);
    println!("saved {}", coord.params_path(&model).display());
    Ok(())
}

fn cmd_search(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("search")
        .opt("model", "cif10", "zoo model name")
        .opt("mode", "quant", "quant|binar")
        .opt("protocol", "rc", "rc|ag|fr")
        .opt("granularity", "c", "n|l|c (network/layer/channel)")
        .opt("episodes", "40", "search episodes")
        .opt("warmup", "10", "constant-noise episodes")
        .opt("eval-batches", "2", "val batches per evaluation")
        .opt("seed", "1", "agent seed")
        .opt("target-bits", "5", "B-bar for Algorithm 1 (rc)")
        .opt("out", "", "write best config JSON here")
        .opt("backend", "", BACKEND_HELP)
        .opt("threads", "", THREADS_HELP)
        .opt("shard-workers", "", SHARD_WORKERS_HELP)
        .opt("shard-hosts", "", SHARD_HOSTS_HELP)
        .opt("shard-encoding", "", SHARD_ENCODING_HELP)
        .opt("act-scales", "", ACT_SCALES_HELP)
        .opt("checkpoint-every", "", CHECKPOINT_HELP)
        .flag("paper-scale", "use the paper's 400-episode schedule")
        .flag("no-relabel", "disable HIRO goal relabeling (ablation)")
        .parse(rest)?;
    let model = a.get("model");
    let mut protocol = Protocol::parse(&a.get("protocol"))?;
    protocol.target_bits = a.get_f64("target-bits")?;
    let mut builder = JobSpec::search(&model)
        .mode(Mode::parse(&a.get("mode"))?)
        .protocol(protocol)
        .granularity(Granularity::parse(&a.get("granularity"))?)
        .episodes(a.get_usize("episodes")?)
        .warmup(a.get_usize("warmup")?)
        .eval_batches(a.get_usize("eval-batches")?)
        .seed(a.get_u64("seed")?)
        .relabel(!a.get_bool("no-relabel"))
        .paper_scale(a.get_bool("paper-scale"));
    let out = a.get("out");
    if !out.is_empty() {
        builder = builder.out(PathBuf::from(&out));
    }
    let mut coord = open_coord(&a)?;
    apply_act_scales(&a, &mut coord)?;
    apply_checkpoint_every(&a, &mut coord)?;
    let report = coord.run(&builder.build()?)?;
    let JobOutcome::Search { best, history } = &report.outcome else {
        anyhow::bail!("search job returned an unexpected report kind");
    };
    println!(
        "best: acc={:.4} reward={:.4} score={:.2} avg_wbits={:.2} avg_abits={:.2} norm_logic={:.4}",
        best.accuracy,
        best.reward,
        best.score,
        best.avg_wbits,
        best.avg_abits,
        best.cost.norm_logic()
    );
    println!("search took {:.1}s over {} episodes", report.secs, history.len());
    if !out.is_empty() {
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_sweep(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("sweep")
        .opt("models", "cif10", "comma-separated zoo models")
        .opt("modes", "quant", "comma-separated quant|binar")
        .opt("protocols", "rc,ag", "comma-separated rc|ag|fr")
        .opt("granularities", "l,c", "comma-separated n|l|c|network:B")
        .opt("episodes", "40", "search episodes per cell")
        .opt("warmup", "10", "constant-noise episodes")
        .opt("eval-batches", "2", "val batches per evaluation")
        .opt("seed", "1", "base seed (per-cell seeds derived deterministically)")
        .opt("target-bits", "5", "B-bar for Algorithm 1 (rc cells)")
        .opt("workers", "2", "worker threads, each with its own runtime/backend")
        .opt("out-dir", "reports/sweep", "one JobReport JSON per cell lands here")
        .opt("daemon", "", "route every cell through an autoq serve daemon at this address")
        .opt("backend", "", BACKEND_HELP)
        .opt("threads", "", "eval threads per worker (default: split cores across workers)")
        .opt("shard-workers", "", SHARD_WORKERS_HELP)
        .opt("shard-hosts", "", SHARD_HOSTS_HELP)
        .opt("shard-encoding", "", SHARD_ENCODING_HELP)
        .flag("paper-scale", "use the paper's 400-episode schedule")
        .flag("no-relabel", "disable HIRO goal relabeling (ablation)")
        .flag(
            "resume",
            "skip cells already journaled as done in out-dir/sweep.journal and run only the rest",
        )
        .parse(rest)?;
    let target_bits = a.get_f64("target-bits")?;
    let sweep = Sweep {
        models: parse_list(&a.get("models"), |s| Ok(s.to_string()))?,
        modes: parse_list(&a.get("modes"), Mode::parse)?,
        protocols: parse_list(&a.get("protocols"), |s| {
            let mut p = Protocol::parse(s)?;
            if p.kind == ProtocolKind::ResourceConstrained {
                p.target_bits = target_bits;
            }
            Ok(p)
        })?,
        granularities: parse_list(&a.get("granularities"), Granularity::parse)?,
        episodes: a.get_usize("episodes")?,
        warmup: a.get_usize("warmup")?,
        eval_batches: a.get_usize("eval-batches")?,
        base_seed: a.get_u64("seed")?,
        relabel: !a.get_bool("no-relabel"),
        paper_scale: a.get_bool("paper-scale"),
        workers: a.get_usize("workers")?,
        out_dir: Some(PathBuf::from(a.get("out-dir"))),
        backend: backend_arg(&a)?,
        threads: threads_arg(&a)?,
        shard_workers: shard_workers_arg(&a)?,
        shard_hosts: shard_hosts_arg(&a)?,
        shard_encoding: shard_encoding_arg(&a)?,
        resume: a.get_bool("resume"),
    };
    let daemon = a.get("daemon");
    if !daemon.is_empty() {
        anyhow::ensure!(
            !sweep.resume,
            "--resume is local-journal based and not supported with --daemon \
             (the daemon's eval cache already makes repeats cheap)"
        );
        // Same grid, same ids, same report bytes — but evaluated by the
        // daemon's warm workers and shared eval cache.
        let result = run_sweep_via_daemon(&daemon, &sweep)?;
        for (id, path) in &result.written {
            println!("{id}  ->  {}", path.display());
        }
        println!(
            "{} job(s) done, {} failure(s); eval cache {} hit(s) / {} miss(es)",
            result.written.len(),
            result.failures.len(),
            result.cache.0,
            result.cache.1
        );
        for (id, err) in &result.failures {
            eprintln!("FAILED {id}: {err}");
        }
        anyhow::ensure!(
            result.failures.is_empty(),
            "{} sweep job(s) failed",
            result.failures.len()
        );
        return Ok(());
    }
    let result = sweep.run(&Coordinator::default_dir())?;
    println!(
        "{:<44} {:>15} {:>8} {:>8} {:>7} {:>7}",
        "job", "seed", "acc", "reward", "wbits", "abits"
    );
    for report in &result.reports {
        if let JobOutcome::Search { best, .. } = &report.outcome {
            println!(
                "{:<44} {:>15} {:>8.4} {:>8.4} {:>7.2} {:>7.2}",
                report.id(),
                report.spec.seed,
                best.accuracy,
                best.reward,
                best.avg_wbits,
                best.avg_abits
            );
        }
    }
    for (id, path) in &result.skipped {
        println!("{id}  already done  ({})", path.display());
    }
    println!(
        "{} job(s) completed in {:.1}s; {} skipped (journaled), {} failure(s); reports under {}",
        result.reports.len(),
        result.secs,
        result.skipped.len(),
        result.failures.len(),
        a.get("out-dir")
    );
    for (id, err) in &result.failures {
        eprintln!("FAILED {id}: {err}");
    }
    anyhow::ensure!(result.failures.is_empty(), "{} sweep job(s) failed", result.failures.len());
    Ok(())
}

fn cmd_finetune(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("finetune")
        .opt("model", "cif10", "zoo model name")
        .opt("config", "", "searched config JSON (from search --out)")
        .opt("steps", "200", "fine-tune steps")
        .opt("backend", "", BACKEND_HELP)
        .opt("threads", "", THREADS_HELP)
        .opt("shard-workers", "", SHARD_WORKERS_HELP)
        .opt("shard-hosts", "", SHARD_HOSTS_HELP)
        .opt("shard-encoding", "", SHARD_ENCODING_HELP)
        .parse(rest)?;
    let model = a.get("model");
    let cfgf = a.get("config");
    anyhow::ensure!(!cfgf.is_empty(), "--config required");
    let steps = a.get_usize("steps")?;
    let spec = JobSpec::finetune(&model, PathBuf::from(&cfgf)).steps(steps).build()?;
    let mut coord = open_coord(&a)?;
    let report = coord.run(&spec)?;
    let JobOutcome::Train { before, final_eval, .. } = &report.outcome else {
        anyhow::bail!("finetune job returned an unexpected report kind");
    };
    println!(
        "finetune {model}: acc {:.4} -> {:.4} over {steps} steps ({:.1}s)",
        before.as_ref().map(|e| e.accuracy).unwrap_or(f64::NAN),
        final_eval.accuracy,
        report.secs
    );
    Ok(())
}

fn cmd_eval(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("eval")
        .opt("model", "cif10", "zoo model name")
        .opt("config", "", "optional searched config JSON")
        .opt("batches", "4", "val batches")
        .opt("backend", "", BACKEND_HELP)
        .opt("threads", "", THREADS_HELP)
        .opt("shard-workers", "", SHARD_WORKERS_HELP)
        .opt("shard-hosts", "", SHARD_HOSTS_HELP)
        .opt("shard-encoding", "", SHARD_ENCODING_HELP)
        .opt("act-scales", "", ACT_SCALES_HELP)
        .parse(rest)?;
    let model = a.get("model");
    let mut builder = JobSpec::eval(&model).batches(a.get_usize("batches")?);
    let cfgf = a.get("config");
    if !cfgf.is_empty() {
        builder = builder.config(PathBuf::from(&cfgf));
    }
    let mut coord = open_coord(&a)?;
    apply_act_scales(&a, &mut coord)?;
    let report = coord.run(&builder.build()?)?;
    let JobOutcome::Eval(res) = &report.outcome else {
        anyhow::bail!("eval job returned an unexpected report kind");
    };
    println!("{model}: accuracy {:.4} loss {:.4} ({} images)", res.accuracy, res.loss, res.images);
    Ok(())
}

fn cmd_sim(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("sim")
        .opt("model", "cif10", "zoo model name")
        .opt("config", "", "searched config JSON")
        .opt("backend", "", BACKEND_HELP)
        .opt("threads", "", THREADS_HELP)
        .opt("shard-workers", "", SHARD_WORKERS_HELP)
        .opt("shard-hosts", "", SHARD_HOSTS_HELP)
        .opt("shard-encoding", "", SHARD_ENCODING_HELP)
        .parse(rest)?;
    let model = a.get("model");
    let mut builder = JobSpec::sim(&model);
    let cfgf = a.get("config");
    if !cfgf.is_empty() {
        builder = builder.config(PathBuf::from(&cfgf));
    }
    let mut coord = open_coord(&a)?;
    let report = coord.run(&builder.build()?)?;
    let JobOutcome::Sim(rows) = &report.outcome else {
        anyhow::bail!("sim job returned an unexpected report kind");
    };
    println!("{:<10} {:>10} {:>12} {:>8}", "arch", "fps", "energy(mJ)", "util");
    for row in rows {
        println!(
            "{:<10} {:>10.1} {:>12.3} {:>8.3}",
            row.arch, row.fps, row.energy_mj, row.utilization
        );
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("serve")
        .opt("listen", "127.0.0.1:7070", "listen address (port 0 picks a free port)")
        .opt("workers", "2", "scheduler workers = jobs run concurrently")
        .opt("backend", "", BACKEND_HELP)
        .opt("threads", "", "eval threads per worker (default: split cores across workers)")
        .opt("shard-workers", "", SHARD_WORKERS_HELP)
        .opt("shard-hosts", "", SHARD_HOSTS_HELP)
        .opt("shard-encoding", "", SHARD_ENCODING_HELP)
        .opt("idle-secs", "600", "drop client connections silent this long (0 = never)")
        .parse(rest)?;
    // SIGINT/SIGTERM flip a flag the accept loop polls: in-flight jobs
    // drain, shard subprocesses get their exit frames, then we return.
    autoq::util::signal::install_shutdown_flag();
    let idle = a.get_usize("idle-secs")?;
    let cfg = ServeConfig {
        dir: Coordinator::default_dir(),
        backend: backend_arg(&a)?,
        threads: threads_arg(&a)?,
        shard_workers: shard_workers_arg(&a)?,
        shard_hosts: shard_hosts_arg(&a)?,
        shard_encoding: shard_encoding_arg(&a)?,
        workers: a.get_usize("workers")?,
        idle_timeout: (idle > 0).then(|| std::time::Duration::from_secs(idle as u64)),
    };
    let server = Server::bind(&a.get("listen"), cfg)?;
    // Scripts and tests parse this line for the resolved port-0 address.
    println!("autoq serve listening on {}", server.local_addr());
    use std::io::Write;
    std::io::stdout().flush().ok();
    server.run()
}

/// Build the JobSpec for `autoq submit` from `--kind` + the job options.
fn submit_spec(a: &Args) -> anyhow::Result<JobSpec> {
    let model = a.get("model");
    let cfgf = a.get("config");
    match a.get("kind").as_str() {
        "search" => {
            let mut protocol = Protocol::parse(&a.get("protocol"))?;
            protocol.target_bits = a.get_f64("target-bits")?;
            JobSpec::search(&model)
                .mode(Mode::parse(&a.get("mode"))?)
                .protocol(protocol)
                .granularity(Granularity::parse(&a.get("granularity"))?)
                .episodes(a.get_usize("episodes")?)
                .warmup(a.get_usize("warmup")?)
                .eval_batches(a.get_usize("eval-batches")?)
                .seed(a.get_u64("seed")?)
                .relabel(!a.get_bool("no-relabel"))
                .paper_scale(a.get_bool("paper-scale"))
                .build()
        }
        "pretrain" => JobSpec::pretrain(&model)
            .steps(a.get_usize("steps")?)
            .data_seed(a.get_u64("data-seed")?)
            .build(),
        "finetune" => {
            anyhow::ensure!(!cfgf.is_empty(), "--config required for --kind finetune");
            JobSpec::finetune(&model, PathBuf::from(&cfgf))
                .steps(a.get_usize("steps")?)
                .build()
        }
        "eval" => {
            let mut b = JobSpec::eval(&model).batches(a.get_usize("batches")?);
            if !cfgf.is_empty() {
                b = b.config(PathBuf::from(&cfgf));
            }
            b.build()
        }
        "sim" => {
            let mut b = JobSpec::sim(&model);
            if !cfgf.is_empty() {
                b = b.config(PathBuf::from(&cfgf));
            }
            b.build()
        }
        other => Err(anyhow::Error::new(UsageError(format!(
            "--kind must be search|pretrain|finetune|eval|sim, got {other:?}"
        )))),
    }
}

fn cmd_submit(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("submit")
        .opt("addr", "127.0.0.1:7070", "autoq serve address")
        .opt("kind", "search", "search|pretrain|finetune|eval|sim")
        .opt("model", "cif10", "zoo model name")
        .opt("mode", "quant", "quant|binar (search)")
        .opt("protocol", "rc", "rc|ag|fr (search)")
        .opt("granularity", "c", "n|l|c (search)")
        .opt("episodes", "40", "search episodes")
        .opt("warmup", "10", "constant-noise episodes (search)")
        .opt("eval-batches", "2", "val batches per evaluation (search)")
        .opt("seed", "1", "agent seed (search)")
        .opt("target-bits", "5", "B-bar for Algorithm 1 (rc)")
        .opt("steps", "300", "steps (pretrain/finetune)")
        .opt("data-seed", "42", "dataset seed (pretrain)")
        .opt("config", "", "config JSON path (finetune/eval/sim)")
        .opt("batches", "4", "val batches (eval)")
        .flag("wait", "block until the job finishes (failed job = exit 1)")
        .flag("paper-scale", "use the paper's 400-episode schedule")
        .flag("no-relabel", "disable HIRO goal relabeling (ablation)")
        .parse(rest)?;
    let spec = submit_spec(&a)?;
    let mut client = DaemonClient::connect(&a.get("addr"))?;
    let handle = client.submit(&spec)?;
    println!("submitted {} as {handle}", spec.id());
    if a.get_bool("wait") {
        let row = client.result(&handle, true)?;
        print_job_row(&row)?;
        let state = row.req("state")?.as_str().unwrap_or("?");
        anyhow::ensure!(state == "done", "job {handle} ended {state}");
    }
    Ok(())
}

/// Print one job's status/result row (state, cache counters, error).
fn print_job_row(row: &Json) -> anyhow::Result<()> {
    let handle = row.req("job")?.as_str().unwrap_or("?").to_string();
    let id = row.req("id")?.as_str().unwrap_or("?").to_string();
    let state = row.req("state")?.as_str().unwrap_or("?").to_string();
    println!("{handle}  {id}  {state}");
    if let Some(c) = row.get("cache") {
        println!(
            "eval cache: {} hit(s) / {} miss(es)",
            c.get("hits").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            c.get("misses").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        );
    }
    if let Some(err) = row.get("error").and_then(Json::as_str) {
        eprintln!("error: {err}");
    }
    Ok(())
}

fn cmd_status(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("status")
        .opt("addr", "127.0.0.1:7070", "autoq serve address")
        .opt("job", "", "job handle (job-N); empty = whole queue")
        .parse(rest)?;
    let mut client = DaemonClient::connect(&a.get("addr"))?;
    let job = a.get("job");
    if job.is_empty() {
        let reply = client.status(None)?;
        for row in reply.req("jobs")?.as_arr().unwrap_or(&[]) {
            println!(
                "{}  {}  {}",
                row.req("job")?.as_str().unwrap_or("?"),
                row.req("id")?.as_str().unwrap_or("?"),
                row.req("state")?.as_str().unwrap_or("?"),
            );
        }
        let cache = reply.req("cache")?;
        println!(
            "{} queued, {} running, {} finished; eval cache {} entr(ies), {} hit(s) / {} miss(es)",
            reply.req("queued")?.as_usize().unwrap_or(0),
            reply.req("running")?.as_usize().unwrap_or(0),
            reply.req("finished")?.as_usize().unwrap_or(0),
            reply.req("cache_entries")?.as_usize().unwrap_or(0),
            cache.get("hits").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            cache.get("misses").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        );
        // Per-client accounting: one line per connection that has finished
        // at least one job (hit/miss deltas of its jobs, summed).
        for row in reply.get("clients").and_then(Json::as_arr).unwrap_or(&[]) {
            println!(
                "  client {}: {} hit(s) / {} miss(es)",
                row.get("client").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                row.get("hits").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                row.get("misses").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            );
        }
        // Durability: where the daemon's journals live and how fresh they
        // are (absent on daemons running with durability degraded).
        if let Some(d) = reply.get("durability") {
            if let Some(path) = d.get("jobs_journal").and_then(Json::as_str) {
                let n = d.get("jobs_journaled").and_then(Json::as_usize).unwrap_or(0);
                let age = d
                    .get("jobs_journal_age_secs")
                    .and_then(Json::as_usize)
                    .map(|s| format!(", newest record {s}s old"))
                    .unwrap_or_default();
                println!("job journal: {path} ({n} job(s){age})");
            }
            if let Some(path) = d.get("disk_cache").and_then(Json::as_str) {
                let n = d.get("disk_cache_entries").and_then(Json::as_usize).unwrap_or(0);
                let age = d
                    .get("disk_cache_age_secs")
                    .and_then(Json::as_usize)
                    .map(|s| format!(", newest record {s}s old"))
                    .unwrap_or_default();
                println!("disk cache: {path} ({n} entr(ies){age})");
            }
        }
    } else {
        print_job_row(&client.status(Some(&job))?)?;
    }
    Ok(())
}

/// The `autoq worker` entry point.  Without `--listen` (the hidden
/// subprocess mode) it serves shard-protocol frames over stdio until
/// EOF/exit; with `--listen ADDR` it accepts TCP sessions — one at a
/// time — so remote `--shard-hosts` clients can dial in.  `--threads` is
/// this process's inner eval budget (the local shard client passes its
/// per-worker share of the total; a listening worker sizes itself).
fn cmd_worker(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("worker")
        .opt("threads", "", THREADS_HELP)
        .opt("listen", "", "serve the shard protocol over TCP at host:port (port 0 = free port)")
        .opt("idle-secs", "600", "drop TCP sessions silent this long (0 = never)")
        .parse(rest)?;
    let listen = a.get("listen");
    if listen.is_empty() {
        // A Ctrl-C in the leader's terminal reaches the whole process
        // group; stdio workers must outlive the signal so in-flight exec
        // frames finish and the leader's drain can complete.  Lifecycle
        // stays EOF/exit-frame driven (`ShardClient::Drop`), so ignoring
        // the signal cannot orphan a worker — the pipe closing always
        // takes it down.
        autoq::util::signal::ignore_termination();
        return autoq::runtime::shard::worker::run(threads_arg(&a)?);
    }
    // A listening worker has no parent pipe to take it down, so SIGTERM
    // must actually stop the accept loop (same flag the daemon polls).
    autoq::util::signal::install_shutdown_flag();
    let idle = a.get_usize("idle-secs")?;
    autoq::runtime::shard::worker::run_listen(
        &listen,
        threads_arg(&a)?,
        (idle > 0).then(|| std::time::Duration::from_secs(idle as u64)),
    )
}

fn cmd_stats(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("stats")
        .opt("backend", "", BACKEND_HELP)
        .opt("threads", "", THREADS_HELP)
        .opt("shard-workers", "", SHARD_WORKERS_HELP)
        .opt("shard-hosts", "", SHARD_HOSTS_HELP)
        .opt("shard-encoding", "", SHARD_ENCODING_HELP)
        .parse(rest)?;
    let mut coord = open_coord(&a)?;
    println!("{}", coord.runtime().stats_report());
    Ok(())
}
