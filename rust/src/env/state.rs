//! Eq.-1 observation builder: the 16-feature state vector
//!
//!   s_i = (i, t, c_in, c_out, w, h, str, k, logic_t, rdc, rst,
//!          gw_t, ga_t, aw_i, aa_i, wvar_i)
//!
//! All features are normalized to ~[0,1] per HAQ/AMC practice so one actor
//! works across models; the LLC state is this vector ⊕ the active goal
//! (s17 artifacts).

use crate::runtime::ModelMeta;

pub const STATE_DIM: usize = 16;

/// Static per-model normalizers.
#[derive(Debug, Clone)]
pub struct StateBuilder {
    pub n_layers: f32,
    pub total_channels: f32,
    pub max_cin: f32,
    pub max_cout: f32,
    pub max_hw: f32,
    pub max_macs: f32,
    pub total_macs: f64,
    pub max_wvar: f64,
}

/// Dynamic episode context for one observation.
#[derive(Debug, Clone, Copy)]
pub struct StateCtx {
    /// Global channel walk index.
    pub i: usize,
    /// Layer index.
    pub t: usize,
    /// Reduced logic ops so far (weight-linear units, see env/mod.rs).
    pub rdc: f64,
    /// Remaining logic ops in the unvisited suffix.
    pub rst: f64,
    pub gw: f32,
    pub ga: f32,
    /// Previous weight / activation actions.
    pub prev_aw: f32,
    pub prev_aa: f32,
    /// Weight variance of the current output channel (0 for act channels).
    pub wvar: f64,
}

impl StateBuilder {
    pub fn new(meta: &ModelMeta, wvar: &[f64]) -> StateBuilder {
        let max_wvar = wvar.iter().cloned().fold(1e-12f64, f64::max);
        StateBuilder {
            n_layers: meta.layers.len() as f32,
            total_channels: (meta.w_channels + meta.a_channels) as f32,
            max_cin: meta.layers.iter().map(|l| l.cin).max().unwrap_or(1) as f32,
            max_cout: meta.layers.iter().map(|l| l.cout).max().unwrap_or(1) as f32,
            max_hw: meta.image_hw as f32,
            max_macs: meta.layers.iter().map(|l| l.macs).max().unwrap_or(1) as f32,
            total_macs: meta.total_macs as f64,
            max_wvar,
        }
    }

    /// Build the normalized 16-vector for layer `layer` under `ctx`.
    pub fn state(&self, meta: &ModelMeta, layer_idx: usize, ctx: &StateCtx) -> [f32; STATE_DIM] {
        let l = &meta.layers[layer_idx];
        [
            ctx.i as f32 / self.total_channels,
            ctx.t as f32 / self.n_layers,
            l.cin as f32 / self.max_cin,
            l.cout as f32 / self.max_cout,
            l.w_in as f32 / self.max_hw,
            l.h_in as f32 / self.max_hw,
            l.stride as f32 / 2.0,
            l.k as f32 / 3.0,
            l.macs as f32 / self.max_macs,
            (ctx.rdc / self.total_macs) as f32,
            (ctx.rst / self.total_macs) as f32,
            ctx.gw / 32.0,
            ctx.ga / 32.0,
            ctx.prev_aw / 32.0,
            ctx.prev_aa / 32.0,
            (ctx.wvar / self.max_wvar) as f32,
        ]
    }
}

/// Project the LLC's weight actions for one layer onto the §3.2 constraint
/// set: ∀x,y (aw_x/aw_y − 1)(wvar_x/wvar_y − 1) > 0 — i.e. action order
/// must agree with variance order.  Sort the proposed actions and assign
/// them to channels by variance rank (the closest point of the constraint
/// set under any rank-respecting metric).
pub fn enforce_variance_order(actions: &mut [f32], vars: &[f64]) {
    debug_assert_eq!(actions.len(), vars.len());
    let n = actions.len();
    let mut var_rank: Vec<usize> = (0..n).collect();
    var_rank.sort_by(|&a, &b| vars[a].partial_cmp(&vars[b]).unwrap());
    let mut sorted = actions.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (rank, &ch) in var_rank.iter().enumerate() {
        actions[ch] = sorted[rank];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{LayerMeta, ModelMeta};

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "m".into(),
            image_hw: 32,
            num_classes: 10,
            eval_batch: 256,
            train_batch: 128,
            layers: vec![LayerMeta {
                name: "l01_conv".into(),
                typ: "conv".into(),
                k: 3,
                stride: 2,
                cin: 3,
                cout: 16,
                h_in: 32,
                w_in: 32,
                h_out: 16,
                w_out: 16,
                macs: 110_592,
                w_off: 0,
                w_len: 16,
                a_off: 0,
                a_len: 3,
            }],
            params: vec![],
            w_channels: 16,
            a_channels: 3,
            total_macs: 110_592,
        }
    }

    #[test]
    fn state_is_normalized() {
        let m = meta();
        let sb = StateBuilder::new(&m, &vec![0.01; 16]);
        let ctx = StateCtx {
            i: 4,
            t: 0,
            rdc: 10_000.0,
            rst: 100_000.0,
            gw: 16.0,
            ga: 8.0,
            prev_aw: 32.0,
            prev_aa: 0.0,
            wvar: 0.005,
        };
        let s = sb.state(&m, 0, &ctx);
        assert_eq!(s.len(), STATE_DIM);
        for (j, &x) in s.iter().enumerate() {
            assert!((0.0..=1.5).contains(&x), "feature {j} = {x}");
        }
        assert_eq!(s[11], 0.5); // gw/32
        assert_eq!(s[13], 1.0); // prev_aw/32
        assert!((s[15] - 0.5).abs() < 1e-6); // wvar / max_wvar
    }

    #[test]
    fn variance_order_projection() {
        let vars = vec![0.3, 0.1, 0.9, 0.5];
        let mut actions = vec![4.0, 8.0, 2.0, 6.0];
        enforce_variance_order(&mut actions, &vars);
        // Highest-variance channel (2) gets the largest action, etc.
        assert_eq!(actions, vec![4.0, 2.0, 8.0, 6.0]);
        // Constraint holds for all pairs with distinct vars/actions.
        for x in 0..4 {
            for y in 0..4 {
                if x != y {
                    let c = (actions[x] / actions[y] - 1.0) as f64 * (vars[x] / vars[y] - 1.0);
                    assert!(c > 0.0, "pair ({x},{y}) violates constraint");
                }
            }
        }
    }

    #[test]
    fn prop_projection_is_permutation() {
        crate::util::prop::forall_ns(
            31,
            |r| {
                let n = 2 + r.below(20);
                let acts: Vec<f32> = (0..n).map(|_| r.f32() * 32.0).collect();
                let vars: Vec<f64> = (0..n).map(|_| r.f64() + 1e-6).collect();
                (acts, vars)
            },
            |(acts, vars)| {
                let mut proj = acts.clone();
                enforce_variance_order(&mut proj, vars);
                let mut a = acts.clone();
                let mut b = proj.clone();
                a.sort_by(|x, y| x.partial_cmp(y).unwrap());
                b.sort_by(|x, y| x.partial_cmp(y).unwrap());
                if a != b {
                    return Err("projection changed the multiset".into());
                }
                // Order agreement: higher variance ⇒ action not smaller.
                for x in 0..proj.len() {
                    for y in 0..proj.len() {
                        if vars[x] > vars[y] && proj[x] < proj[y] {
                            return Err(format!("order violated at ({x},{y})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
