//! Quantization environment: Eq.-1 observation construction and the §3.2
//! action-space constraints.  The episode walk itself lives in
//! `search::episode` (it needs the agents and the runtime).

pub mod state;

pub use state::{enforce_variance_order, StateBuilder, StateCtx, STATE_DIM};
