//! Searched bit-configuration persistence: JSON for humans/tools plus the
//! §3.4 6-bit packed form for deployment-size audits.

use std::path::Path;

use crate::cost::Mode;
use crate::models::storage;
use crate::search::EpisodeOutcome;
use crate::util::json::Json;

/// A searched per-channel configuration, as written by `autoq search --out`.
#[derive(Debug, Clone)]
pub struct SavedConfig {
    pub model: String,
    pub mode: Mode,
    pub wbits: Vec<u8>,
    pub abits: Vec<u8>,
    pub accuracy: f64,
    pub score: f64,
}

pub fn save_config(
    path: &Path,
    model: &str,
    mode: Mode,
    out: &EpisodeOutcome,
) -> anyhow::Result<()> {
    let j = Json::obj(vec![
        ("model", model.into()),
        ("mode", mode.as_str().into()),
        ("accuracy", out.accuracy.into()),
        ("score", out.score.into()),
        ("norm_logic", out.cost.norm_logic().into()),
        ("avg_wbits", out.avg_wbits.into()),
        ("avg_abits", out.avg_abits.into()),
        (
            "wbits",
            Json::Arr(out.wbits.iter().map(|&b| Json::Num(b as f64)).collect()),
        ),
        (
            "abits",
            Json::Arr(out.abits.iter().map(|&b| Json::Num(b as f64)).collect()),
        ),
        (
            "per_layer",
            Json::Arr(
                out.per_layer
                    .iter()
                    .map(|l| {
                        Json::obj(vec![
                            ("name", l.name.as_str().into()),
                            ("avg_w", l.avg_w.into()),
                            ("avg_a", l.avg_a.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(path, j.to_string())?;
    Ok(())
}

pub fn load_config(path: &Path) -> anyhow::Result<SavedConfig> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let bits = |k: &str| -> anyhow::Result<Vec<u8>> {
        j.req(k)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("{k} not an array"))?
            .iter()
            .map(|v| {
                let n = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("{k} entry is not a number"))?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (0.0..=32.0).contains(&n),
                    "{k} entry {n} is not an integer bit-width in 0..=32"
                );
                Ok(n as u8)
            })
            .collect()
    };
    Ok(SavedConfig {
        model: j.req("model")?.as_str().unwrap_or("").to_string(),
        mode: Mode::parse(j.req("mode")?.as_str().unwrap_or("quant"))?,
        wbits: bits("wbits")?,
        abits: bits("abits")?,
        accuracy: j.req("accuracy")?.as_f64().unwrap_or(0.0),
        score: j.req("score")?.as_f64().unwrap_or(0.0),
    })
}

/// Deployment payload audit of a saved config (§3.4).
pub fn audit(
    layers: &[crate::runtime::LayerMeta],
    wbits: &[u8],
    abits: &[u8],
) -> storage::StorageAudit {
    let mut elems = Vec::with_capacity(wbits.len());
    for l in layers {
        let per_c: u64 = match l.typ.as_str() {
            "fc" => l.cin as u64,
            "dwconv" => (l.k * l.k) as u64,
            _ => (l.k * l.k * l.cin) as u64,
        };
        elems.extend(std::iter::repeat(per_c).take(l.w_len));
    }
    storage::storage_audit(&elems, wbits, abits.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::logic::model_cost;
    use crate::search::LayerBits;

    #[test]
    fn config_roundtrip() {
        let out = EpisodeOutcome {
            wbits: vec![4, 5, 0, 32],
            abits: vec![3, 3],
            accuracy: 0.91,
            loss: 0.3,
            cost: model_cost(&[], &[], &[]),
            reward: 0.5,
            score: 10.0,
            per_layer: vec![LayerBits { name: "l01_conv".into(), avg_w: 4.5, avg_a: 3.0 }],
            avg_wbits: 10.25,
            avg_abits: 3.0,
        };
        let path = std::env::temp_dir().join("autoq_cfg_test.json");
        save_config(&path, "cif10", Mode::Binar, &out).unwrap();
        let back = load_config(&path).unwrap();
        assert_eq!(back.model, "cif10");
        assert_eq!(back.mode, Mode::Binar);
        assert_eq!(back.wbits, vec![4, 5, 0, 32]);
        assert_eq!(back.abits, vec![3, 3]);
        assert!((back.accuracy - 0.91).abs() < 1e-9);
        assert!((back.score - 10.0).abs() < 1e-9);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn saved_json_carries_report_fields() {
        let out = EpisodeOutcome {
            wbits: vec![4],
            abits: vec![3],
            accuracy: 0.5,
            loss: 0.9,
            cost: model_cost(&[], &[], &[]),
            reward: 0.25,
            score: 5.0,
            per_layer: vec![LayerBits { name: "l01_conv".into(), avg_w: 4.0, avg_a: 3.0 }],
            avg_wbits: 4.0,
            avg_abits: 3.0,
        };
        let path = std::env::temp_dir().join("autoq_cfg_fields_test.json");
        save_config(&path, "res18", Mode::Quant, &out).unwrap();
        let j = crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.req("model").unwrap().as_str(), Some("res18"));
        assert!(j.req("norm_logic").unwrap().as_f64().is_some());
        let per_layer = j.req("per_layer").unwrap().as_arr().unwrap();
        assert_eq!(per_layer.len(), 1);
        assert_eq!(per_layer[0].req("name").unwrap().as_str(), Some("l01_conv"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_malformed_configs() {
        let path = std::env::temp_dir().join("autoq_cfg_bad_test.json");
        std::fs::write(&path, "not json at all").unwrap();
        assert!(load_config(&path).is_err(), "non-JSON must error");
        std::fs::write(&path, r#"{"model":"m","mode":"quant","accuracy":1,"score":1}"#).unwrap();
        assert!(load_config(&path).is_err(), "missing wbits/abits must error");
        std::fs::write(&path, r#"{"model":"m","mode":"warp","accuracy":1,"score":1,"wbits":[],"abits":[]}"#)
            .unwrap();
        assert!(load_config(&path).is_err(), "unknown mode must error");
        std::fs::write(
            &path,
            r#"{"model":"m","mode":"quant","accuracy":1,"score":1,"wbits":["4x",5],"abits":[3]}"#,
        )
        .unwrap();
        assert!(load_config(&path).is_err(), "non-numeric bit entries must error, not become 0");
        std::fs::write(
            &path,
            r#"{"model":"m","mode":"quant","accuracy":1,"score":1,"wbits":[40],"abits":[3]}"#,
        )
        .unwrap();
        assert!(load_config(&path).is_err(), "out-of-range bit entries must error");
        std::fs::remove_file(path).ok();
    }
}
