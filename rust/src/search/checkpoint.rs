//! Episode-loop checkpointing: byte-exact snapshot/restore of the whole
//! search state (agent nets, Adam momenta, replay buffers, RNG stream,
//! noise schedule, best outcome and learning-curve history) through the
//! journal substrate.
//!
//! The determinism contract: restoring a snapshot taken after episode *k*
//! and running episodes *k+1..n* produces the **same final `SearchResult`
//! bytes** as an uninterrupted *0..n* run (modulo wall-clock `secs`).
//! Everything the loop mutates is captured here; everything else
//! (`StateBuilder`, weight variances, `EpisodeConfig`) is rebuilt
//! deterministically from the [`SearchConfig`], whose fingerprint is
//! pinned into every snapshot — a changed config invalidates the
//! checkpoint instead of resuming into the wrong run.

use std::path::PathBuf;

use crate::agent::ddpg::DdpgAgent;
use crate::agent::hiro::HiroAgent;
use crate::agent::replay::{ReplayBuffer, Transition};
use crate::cost::logic::ModelCost;
use crate::journal::codec::{ByteReader, ByteWriter};
use crate::journal::log::{fingerprint, FNV_OFFSET};
use crate::runtime::{Tensor, Value};
use crate::search::episode::{EpisodeOutcome, LayerBits};
use crate::search::protocol::Granularity;
use crate::search::runner::{EpisodeStats, SearchConfig};
use crate::util::rng::Rng;

/// Snapshot-blob schema version (bump on layout changes; old blobs are
/// then ignored and the search restarts clean).
const VERSION: u8 = 1;

/// Snapshot tag within a search journal.
pub const TAG: &str = "search";

/// Where and how often a search checkpoints.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Journal file (one per search job).
    pub path: PathBuf,
    /// Snapshot every N finished episodes (0 disables checkpointing).
    pub every: usize,
}

/// Fingerprint of everything that shapes a search's trajectory.  Two
/// configs with equal fingerprints produce byte-identical runs, so a
/// snapshot is resumable iff the fingerprints match.
pub fn config_fingerprint(cfg: &SearchConfig, model: &str) -> u64 {
    let mut w = ByteWriter::new();
    w.put_str(model);
    w.put_str(cfg.mode.as_str());
    w.put_str(cfg.protocol.tag());
    w.put_f64(cfg.protocol.target_bits);
    match cfg.granularity {
        Granularity::Network(b) => {
            w.put_u8(0);
            w.put_u32(b);
        }
        Granularity::Layer => w.put_u8(1),
        Granularity::Channel => w.put_u8(2),
    }
    w.put_u64(cfg.episodes as u64);
    w.put_u64(cfg.warmup as u64);
    w.put_f64(cfg.noise_decay);
    w.put_u64(cfg.eval_batches as u64);
    w.put_u64(cfg.seed);
    w.put_f32(cfg.zeta);
    w.put_bool(cfg.relabel);
    w.put_u64(cfg.llc_updates_div as u64);
    crate::journal::log::fnv1a(FNV_OFFSET, &w.into_vec())
}

fn put_value(w: &mut ByteWriter, v: &Value) -> anyhow::Result<()> {
    let t = v.as_f32()?;
    w.put_u32(t.shape.len() as u32);
    for &d in &t.shape {
        w.put_u64(d as u64);
    }
    w.put_f32s(&t.data);
    Ok(())
}

fn read_value(r: &mut ByteReader) -> anyhow::Result<Value> {
    let nd = r.u32()? as usize;
    let mut shape = Vec::with_capacity(nd);
    for _ in 0..nd {
        shape.push(r.u64()? as usize);
    }
    Ok(Value::F32(Tensor::new(shape, r.f32s()?)))
}

fn put_agent(w: &mut ByteWriter, agent: &DdpgAgent) -> anyhow::Result<()> {
    let (state, t) = agent.snapshot_state();
    w.put_u32(state.len() as u32);
    for v in state {
        put_value(w, v)?;
    }
    w.put_f32(t);
    w.put_f32(agent.last_critic_loss);
    w.put_f32(agent.last_actor_loss);
    w.put_u64(agent.updates);
    Ok(())
}

fn read_agent(r: &mut ByteReader, agent: &mut DdpgAgent) -> anyhow::Result<()> {
    let n = r.u32()? as usize;
    let mut state = Vec::with_capacity(n);
    for _ in 0..n {
        state.push(read_value(r)?);
    }
    let t = r.f32()?;
    agent.restore_state(state, t)?;
    agent.last_critic_loss = r.f32()?;
    agent.last_actor_loss = r.f32()?;
    agent.updates = r.u64()?;
    Ok(())
}

fn put_replay(w: &mut ByteWriter, rb: &ReplayBuffer) {
    let (buf, next, pushed) = rb.raw_parts();
    w.put_u64(next as u64);
    w.put_u64(pushed);
    w.put_u32(buf.len() as u32);
    for tr in buf {
        w.put_f32s(&tr.s);
        w.put_f32(tr.a);
        w.put_f32(tr.r);
        w.put_f32s(&tr.s2);
        w.put_bool(tr.done);
    }
}

fn read_replay(r: &mut ByteReader, rb: &mut ReplayBuffer) -> anyhow::Result<()> {
    let next = r.u64()? as usize;
    let pushed = r.u64()?;
    let n = r.u32()? as usize;
    let mut buf = Vec::with_capacity(n);
    for _ in 0..n {
        buf.push(Transition {
            s: r.f32s()?,
            a: r.f32()?,
            r: r.f32()?,
            s2: r.f32s()?,
            done: r.bool()?,
        });
    }
    rb.restore_parts(buf, next, pushed)
}

fn put_outcome(w: &mut ByteWriter, out: &EpisodeOutcome) {
    w.put_bytes(&out.wbits);
    w.put_bytes(&out.abits);
    w.put_f64(out.accuracy);
    w.put_f64(out.loss);
    w.put_u64(out.cost.logic_ops);
    w.put_u64(out.cost.logic_fp);
    w.put_u64(out.cost.weight_bits);
    w.put_u64(out.cost.weight_bits_fp);
    w.put_f64(out.reward);
    w.put_f64(out.score);
    w.put_u32(out.per_layer.len() as u32);
    for l in &out.per_layer {
        w.put_str(&l.name);
        w.put_f64(l.avg_w);
        w.put_f64(l.avg_a);
    }
    w.put_f64(out.avg_wbits);
    w.put_f64(out.avg_abits);
}

fn read_outcome(r: &mut ByteReader) -> anyhow::Result<EpisodeOutcome> {
    let wbits = r.bytes()?.to_vec();
    let abits = r.bytes()?.to_vec();
    let accuracy = r.f64()?;
    let loss = r.f64()?;
    let cost = ModelCost {
        logic_ops: r.u64()?,
        logic_fp: r.u64()?,
        weight_bits: r.u64()?,
        weight_bits_fp: r.u64()?,
    };
    let reward = r.f64()?;
    let score = r.f64()?;
    let nl = r.u32()? as usize;
    let mut per_layer = Vec::with_capacity(nl);
    for _ in 0..nl {
        per_layer.push(LayerBits { name: r.str()?.to_string(), avg_w: r.f64()?, avg_a: r.f64()? });
    }
    Ok(EpisodeOutcome {
        wbits,
        abits,
        accuracy,
        loss,
        cost,
        reward,
        score,
        per_layer,
        avg_wbits: r.f64()?,
        avg_abits: r.f64()?,
    })
}

/// Serialize the complete mutable search state after `episodes_done`
/// episodes.
pub fn encode(
    fp: u64,
    episodes_done: usize,
    history: &[EpisodeStats],
    best: Option<&EpisodeOutcome>,
    agents: &HiroAgent,
) -> anyhow::Result<Vec<u8>> {
    let mut w = ByteWriter::new();
    w.put_u8(VERSION);
    w.put_u64(fp);
    w.put_u64(episodes_done as u64);
    w.put_u32(history.len() as u32);
    for st in history {
        w.put_u64(st.episode as u64);
        w.put_f64(st.accuracy);
        w.put_f64(st.reward);
        w.put_f64(st.avg_wbits);
        w.put_f64(st.avg_abits);
        w.put_f64(st.norm_logic);
    }
    w.put_bool(best.is_some());
    if let Some(b) = best {
        put_outcome(&mut w, b);
    }
    w.put_u64(agents.cfg.noise.episode() as u64);
    let (s, spare) = agents.rng.state();
    for word in s {
        w.put_u64(word);
    }
    w.put_bool(spare.is_some());
    w.put_u64(spare.unwrap_or(0));
    for agent in [&agents.hlc_w, &agents.hlc_a, &agents.llc_w, &agents.llc_a] {
        put_agent(&mut w, agent)?;
    }
    for rb in
        [&agents.replay_hlc_w, &agents.replay_hlc_a, &agents.replay_llc_w, &agents.replay_llc_a]
    {
        put_replay(&mut w, rb);
    }
    Ok(w.into_vec())
}

/// The loop-position part of a restored snapshot (the agent part is
/// applied directly to `agents`).
#[derive(Debug)]
pub struct ResumeState {
    pub episodes_done: usize,
    pub history: Vec<EpisodeStats>,
    pub best: Option<EpisodeOutcome>,
}

/// Decode a snapshot blob into `agents` and return the loop position.
/// Returns `Ok(None)` — start clean — when the blob's version or config
/// fingerprint does not match; corrupt blobs are a structured error.
pub fn decode_into(
    blob: &[u8],
    expect_fp: u64,
    agents: &mut HiroAgent,
) -> anyhow::Result<Option<ResumeState>> {
    let mut r = ByteReader::new(blob);
    if r.u8()? != VERSION {
        return Ok(None);
    }
    if r.u64()? != expect_fp {
        return Ok(None);
    }
    let episodes_done = r.u64()? as usize;
    let nh = r.u32()? as usize;
    let mut history = Vec::with_capacity(nh);
    for _ in 0..nh {
        history.push(EpisodeStats {
            episode: r.u64()? as usize,
            accuracy: r.f64()?,
            reward: r.f64()?,
            avg_wbits: r.f64()?,
            avg_abits: r.f64()?,
            norm_logic: r.f64()?,
        });
    }
    let best = if r.bool()? { Some(read_outcome(&mut r)?) } else { None };
    agents.cfg.noise.set_episode(r.u64()? as usize);
    let s = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    let has_spare = r.bool()?;
    let spare_bits = r.u64()?;
    agents.rng = Rng::restore(s, has_spare.then_some(spare_bits));
    {
        let HiroAgent { hlc_w, hlc_a, llc_w, llc_a, .. } = agents;
        for agent in [hlc_w, hlc_a, llc_w, llc_a] {
            read_agent(&mut r, agent)?;
        }
    }
    {
        let HiroAgent { replay_hlc_w, replay_hlc_a, replay_llc_w, replay_llc_a, .. } = agents;
        for rb in [replay_hlc_w, replay_hlc_a, replay_llc_w, replay_llc_a] {
            read_replay(&mut r, rb)?;
        }
    }
    r.finish()?;
    Ok(Some(ResumeState { episodes_done, history, best }))
}

/// Fingerprint of an arbitrary byte blob (re-exported convenience for the
/// sweep/repro done-set callers).
pub fn blob_fingerprint(bytes: &[u8]) -> u64 {
    fingerprint(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_round_trips_byte_exactly() {
        let out = EpisodeOutcome {
            wbits: vec![3, 5, 8],
            abits: vec![4, 4],
            accuracy: 0.123456789,
            loss: 1.5e-3,
            cost: ModelCost { logic_ops: 7, logic_fp: 11, weight_bits: 13, weight_bits_fp: 17 },
            reward: -0.25,
            score: 19.75,
            per_layer: vec![LayerBits { name: "conv1".into(), avg_w: 5.5, avg_a: 6.25 }],
            avg_wbits: 5.33,
            avg_abits: 4.0,
        };
        let mut w = ByteWriter::new();
        put_outcome(&mut w, &out);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        let back = read_outcome(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(back.wbits, out.wbits);
        assert_eq!(back.abits, out.abits);
        assert_eq!(back.accuracy.to_bits(), out.accuracy.to_bits());
        assert_eq!(back.loss.to_bits(), out.loss.to_bits());
        assert_eq!(back.cost.logic_ops, out.cost.logic_ops);
        assert_eq!(back.cost.weight_bits_fp, out.cost.weight_bits_fp);
        assert_eq!(back.per_layer.len(), 1);
        assert_eq!(back.per_layer[0].name, "conv1");
        assert_eq!(back.avg_wbits.to_bits(), out.avg_wbits.to_bits());
    }

    #[test]
    fn fingerprint_sensitive_to_every_field() {
        use crate::cost::Mode;
        use crate::search::protocol::Protocol;
        let base = SearchConfig::quick(
            Mode::Quant,
            Protocol::resource_constrained(5.0),
            Granularity::Channel,
        );
        let f0 = config_fingerprint(&base, "cif10");
        assert_eq!(f0, config_fingerprint(&base, "cif10"), "fingerprint must be stable");
        assert_ne!(f0, config_fingerprint(&base, "monet"));
        let mut c = base.clone();
        c.episodes += 1;
        assert_ne!(f0, config_fingerprint(&c, "cif10"));
        let mut c = base.clone();
        c.seed ^= 1;
        assert_ne!(f0, config_fingerprint(&c, "cif10"));
        let mut c = base.clone();
        c.relabel = !c.relabel;
        assert_ne!(f0, config_fingerprint(&c, "cif10"));
    }
}
