//! Algorithm 1 (paper §3.3): HLC goal bounding for resource-constrained
//! searches.  The search is free for the first layers and starts limiting
//! goals once the remaining budget could not be met even if every
//! following layer ran at the minimal goal.
//!
//! We apply the algorithm per controller side (weights and activations
//! each bound against their own average-bit target B̄), the linear
//! per-side form of the paper's XNOR-budget recurrence; the product of the
//! two sides then meets the joint bit-op budget.

/// Per-side goal bounder over an m-layer network.
#[derive(Debug, Clone)]
pub struct LayerBound {
    /// MAC count of each layer (logic_i, bit-independent).
    layer_macs: Vec<f64>,
    /// Σ logic_i · B̄/32 — the budget in weight-linear units.
    budget: f64,
    /// Minimal allowed goal g_min.
    pub g_min: f64,
    /// Actual charged units so far (logic_curr).
    curr: f64,
    /// Next layer expected (guards against out-of-order use).
    next_t: usize,
}

impl LayerBound {
    /// `avg_bits` = B̄ (the paper's \overline{BBN}/\overline{QBN} target).
    pub fn new(layer_macs: Vec<f64>, avg_bits: f64, g_min: f64) -> LayerBound {
        let budget = layer_macs.iter().sum::<f64>() * (avg_bits / 32.0);
        LayerBound { layer_macs, budget, g_min, curr: 0.0, next_t: 0 }
    }

    /// Bound the HLC's proposed goal for layer `t` (must be called in
    /// layer order).  Implements lines 8–18 of Algorithm 1.
    pub fn bound(&mut self, t: usize, proposed: f64) -> f64 {
        assert_eq!(t, self.next_t, "LayerBound must be driven in layer order");
        self.next_t += 1;
        let logic_t = self.layer_macs[t];
        // line 10: floor at g_min
        let mut g = proposed.max(self.g_min).min(32.0);
        // line 12: remaining layers' logic
        let logic_rest: f64 = self.layer_macs[t + 1..].iter().sum();
        // line 14: what must be cut at L_t if the suffix runs at g_min
        let duty = self.budget - (self.g_min / 32.0) * logic_rest - self.curr;
        // line 16: cap the goal so duty is met
        let cap = (duty / logic_t) * 32.0;
        g = g.min(cap.max(self.g_min)).max(0.0);
        // line 18: charge
        self.curr += g / 32.0 * logic_t;
        g
    }

    /// Units spent so far (for reports/tests).
    pub fn spent(&self) -> f64 {
        self.curr
    }
    pub fn budget(&self) -> f64 {
        self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_ns;

    #[test]
    fn early_layers_unconstrained() {
        // Huge budget → proposals pass through (clamped to [g_min, 32]).
        let mut lb = LayerBound::new(vec![100.0; 4], 32.0, 1.0);
        assert_eq!(lb.bound(0, 7.3), 7.3);
        assert_eq!(lb.bound(1, 40.0), 32.0);
        assert_eq!(lb.bound(2, 0.2), 1.0);
    }

    #[test]
    fn budget_enforced_across_layers() {
        // 4 equal layers, target average 4 bits, g_min 1: asking 32 bits
        // everywhere must be capped so that the total ≈ budget.
        let macs = vec![1000.0; 4];
        let mut lb = LayerBound::new(macs.clone(), 4.0, 1.0);
        let mut total = 0.0;
        for t in 0..4 {
            let g = lb.bound(t, 32.0);
            total += g / 32.0 * macs[t];
        }
        let budget = macs.iter().sum::<f64>() * (4.0 / 32.0);
        assert!(total <= budget + 1e-9, "spent {total} > budget {budget}");
        // Greedy: the first layer takes what it can, suffix pinned at g_min.
        assert!(lb.spent() <= lb.budget() + 1e-9);
    }

    #[test]
    fn modest_proposals_unchanged_under_budget() {
        let macs = vec![500.0, 1000.0, 2000.0];
        let mut lb = LayerBound::new(macs, 8.0, 1.0);
        for t in 0..3 {
            let g = lb.bound(t, 6.0);
            assert!((g - 6.0).abs() < 1e-9, "layer {t} got {g}");
        }
    }

    #[test]
    #[should_panic(expected = "layer order")]
    fn out_of_order_rejected() {
        let mut lb = LayerBound::new(vec![1.0; 3], 4.0, 1.0);
        lb.bound(1, 4.0);
    }

    #[test]
    fn prop_never_exceeds_budget_when_feasible() {
        forall_ns(
            17,
            |r| {
                let n = 1 + r.below(8);
                let macs: Vec<f64> = (0..n).map(|_| 10.0 + r.f64() * 1000.0).collect();
                let proposals: Vec<f64> = (0..n).map(|_| r.f64() * 40.0).collect();
                let avg = 1.0 + r.f64() * 8.0;
                (macs, proposals, avg)
            },
            |(macs, proposals, avg)| {
                // Feasible iff budget ≥ all-layers-at-g_min; use g_min=1 ≤ avg.
                let g_min = 1.0;
                let mut lb = LayerBound::new(macs.clone(), *avg, g_min);
                let mut spent = 0.0;
                for (t, &p) in proposals.iter().enumerate() {
                    let g = lb.bound(t, p);
                    if !(0.0..=32.0).contains(&g) {
                        return Err(format!("goal {g} out of range"));
                    }
                    spent += g / 32.0 * macs[t];
                }
                let budget = macs.iter().sum::<f64>() * (avg / 32.0);
                if spent <= budget + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("spent {spent} > budget {budget}"))
                }
            },
        );
    }
}
