//! The paper's system contribution: hierarchical channel-level search —
//! protocols (§3.3), Algorithm-1 goal bounding, the episode walk (§3.2) and
//! the explore/exploit runner (§4).

pub mod algorithm1;
pub mod checkpoint;
pub mod episode;
pub mod protocol;
pub mod runner;

pub use algorithm1::LayerBound;
pub use checkpoint::Checkpoint;
pub use episode::{EpisodeConfig, EpisodeOutcome, LayerBits};
pub use protocol::{Granularity, Protocol, ProtocolKind};
pub use runner::{
    log_episode_progress, run_search, run_search_with, EpisodeStats, SearchConfig, SearchResult,
};
