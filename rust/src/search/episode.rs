//! One search episode (§3.2): the hierarchical walk over all layers and
//! channels of a model, producing a complete per-channel bit configuration,
//! its validation score, and the HLC/LLC transitions pushed to replay.
//!
//! Timeline per layer L_t:
//!   1. HLC_w / HLC_a observe the Eq.-1 layer state and emit goals gw_t /
//!      ga_t (bounded by Algorithm 1 under the resource-constrained
//!      protocol).
//!   2. LLC_w walks the c_out weight output channels; LLC_a walks the
//!      c_in activation input channels (1 for fc layers).  Each step is a
//!      goal-conditioned action in {0..32}; weight actions are projected
//!      onto the §3.2 variance-ordering constraint.
//!   3. The episode ends with one validation evaluation (no fine-tuning —
//!      the [9] delegate), NetScore reward assignment, HIRO goal
//!      relabeling of the HLC transitions, and replay pushes.

use crate::agent::hiro::{set_goal, HiroAgent, Side, LLC_DIM};
use crate::agent::replay::Transition;
use crate::cost::logic::{model_cost, ModelCost};
use crate::cost::Mode;
use crate::data::synth::{Split, SynthDataset};
use crate::env::state::{enforce_variance_order, StateBuilder, StateCtx};
use crate::models::ModelRunner;
use crate::runtime::Runtime;
use crate::search::protocol::{Granularity, Protocol};

/// Per-episode knobs (scaled-down defaults; paper-scale via CLI flags).
#[derive(Debug, Clone)]
pub struct EpisodeConfig {
    /// Validation batches per evaluation (× eval_batch images).
    pub eval_batches: usize,
    /// LLC minibatch updates per episode = llc_steps / this.
    pub llc_updates_div: usize,
    /// HLC minibatch updates per episode (0 → one per layer).
    pub hlc_updates: usize,
    /// Enable HIRO goal relabeling.
    pub relabel: bool,
    /// Batch all LLC actions of a layer into one executable dispatch (the
    /// fast path; the sequential walk feeds each channel the exact previous
    /// action per Eq. 1 — see DESIGN.md §Perf for the measured trade-off).
    pub batch_llc: bool,
}

impl Default for EpisodeConfig {
    fn default() -> Self {
        EpisodeConfig {
            eval_batches: 2,
            llc_updates_div: 4,
            hlc_updates: 0,
            relabel: true,
            batch_llc: true,
        }
    }
}

/// Average searched bit-widths of one layer (Figs 4–7).
#[derive(Debug, Clone)]
pub struct LayerBits {
    pub name: String,
    pub avg_w: f64,
    pub avg_a: f64,
}

#[derive(Debug, Clone)]
pub struct EpisodeOutcome {
    pub wbits: Vec<u8>,
    pub abits: Vec<u8>,
    pub accuracy: f64,
    pub loss: f64,
    pub cost: ModelCost,
    /// Extrinsic reward (NetScore/20) used by the agent.
    pub reward: f64,
    /// Full NetScore Ω.
    pub score: f64,
    pub per_layer: Vec<LayerBits>,
    pub avg_wbits: f64,
    pub avg_abits: f64,
}

/// One controller side's staged segment (a layer's worth of LLC steps).
struct Seg {
    side: Side,
    s16: [f32; 16],
    goal: f32,
    states: Vec<f32>, // (n, LLC_DIM) row-major
    actions: Vec<f32>,
}

pub fn run_episode(
    rt: &mut Runtime,
    runner: &ModelRunner,
    sb: &StateBuilder,
    wvar: &[f64],
    agents: &mut HiroAgent,
    protocol: &Protocol,
    gran: Granularity,
    mode: Mode,
    data: &SynthDataset,
    cfg: &EpisodeConfig,
) -> anyhow::Result<EpisodeOutcome> {
    let meta = runner.meta.clone();
    let layer_macs: Vec<f64> = meta.layers.iter().map(|l| l.macs as f64).collect();
    let mut bound_w = protocol.bounder(&layer_macs);
    let mut bound_a = protocol.bounder(&layer_macs);

    let mut wbits = vec![0u8; meta.w_channels];
    let mut abits = vec![0u8; meta.a_channels];
    let mut segs: Vec<Seg> = Vec::with_capacity(meta.layers.len() * 2);

    let mut rdc = 0.0f64;
    let mut visited = 0.0f64;
    let mut gi = 0usize;
    let (mut prev_aw, mut prev_aa) = (32.0f32, 32.0f32);
    let (mut prev_gw, mut prev_ga) = (32.0f32, 32.0f32);

    for (t, l) in meta.layers.iter().enumerate() {
        let rst = sb.total_macs - visited;
        let layer_wvar = &wvar[l.w_off..l.w_off + l.w_len];
        let mean_var = layer_wvar.iter().sum::<f64>() / l.w_len as f64;
        let ctx = StateCtx {
            i: gi,
            t,
            rdc,
            rst,
            gw: prev_gw,
            ga: prev_ga,
            prev_aw,
            prev_aa,
            wvar: mean_var,
        };
        let s16 = sb.state(&meta, t, &ctx);

        // --- HLC goals, Algorithm-1 bounded under RC -----------------------
        let gw_prop = agents.propose_goal(rt, Side::Weight, &s16)? as f64;
        let gw = match &mut bound_w {
            Some(b) => b.bound(t, gw_prop) as f32,
            None => gw_prop.clamp(0.0, 32.0) as f32,
        };
        let ga_prop = agents.propose_goal(rt, Side::Act, &s16)? as f64;
        let ga = match &mut bound_a {
            Some(b) => b.bound(t, ga_prop) as f32,
            None => ga_prop.clamp(0.0, 32.0) as f32,
        };
        prev_gw = gw;
        prev_ga = ga;

        // --- LLC walks ------------------------------------------------------
        let macs_per_oc = l.macs as f64 / l.w_len as f64;
        match gran {
            Granularity::Network(b) => {
                wbits[l.w_off..l.w_off + l.w_len].fill(b);
                abits[l.a_off..l.a_off + l.a_len].fill(b);
                rdc += l.macs as f64 * (32.0 - b as f64) / 32.0;
                gi += l.w_len + l.a_len;
            }
            Granularity::Layer => {
                let bw = gw.round().clamp(0.0, 32.0) as u8;
                let ba = ga.round().clamp(0.0, 32.0) as u8;
                wbits[l.w_off..l.w_off + l.w_len].fill(bw);
                abits[l.a_off..l.a_off + l.a_len].fill(ba);
                rdc += l.macs as f64 * (32.0 - bw as f64) / 32.0;
                gi += l.w_len + l.a_len;
                segs.push(Seg { side: Side::Weight, s16, goal: gw, states: vec![], actions: vec![bw as f32; l.w_len] });
                segs.push(Seg { side: Side::Act, s16, goal: ga, states: vec![], actions: vec![ba as f32; l.a_len] });
            }
            Granularity::Channel => {
                // Weight output channels.
                let mut wstates = Vec::with_capacity(l.w_len * LLC_DIM);
                let mut wactions = Vec::with_capacity(l.w_len);
                if cfg.batch_llc {
                    // Fast path: one dispatch for the whole layer.  Channel
                    // states share the layer-entry rdc/rst/prev-action
                    // context (the per-channel walk features only drift
                    // within a layer).
                    for c in 0..l.w_len {
                        let ctx = StateCtx {
                            i: gi + c,
                            t,
                            rdc,
                            rst,
                            gw,
                            ga,
                            prev_aw,
                            prev_aa,
                            wvar: layer_wvar[c],
                        };
                        let base = sb.state(&meta, t, &ctx);
                        let mut s17 = [0.0f32; LLC_DIM];
                        s17[..16].copy_from_slice(&base);
                        set_goal(&mut s17, Side::Weight, gw);
                        wstates.extend_from_slice(&s17);
                    }
                    wactions =
                        agents.propose_actions_batch(rt, Side::Weight, &wstates, l.w_len)?;
                    for a in wactions.iter_mut() {
                        *a = a.round().clamp(0.0, 32.0);
                        rdc += macs_per_oc * (32.0 - *a as f64) / 32.0;
                    }
                    prev_aw = *wactions.last().unwrap_or(&prev_aw);
                    gi += l.w_len;
                } else {
                    for c in 0..l.w_len {
                        let ctx = StateCtx {
                            i: gi,
                            t,
                            rdc,
                            rst,
                            gw,
                            ga,
                            prev_aw,
                            prev_aa,
                            wvar: layer_wvar[c],
                        };
                        let base = sb.state(&meta, t, &ctx);
                        let mut s17 = [0.0f32; LLC_DIM];
                        s17[..16].copy_from_slice(&base);
                        set_goal(&mut s17, Side::Weight, gw);
                        let a = agents.propose_action(rt, Side::Weight, &s17)?;
                        let a = a.round().clamp(0.0, 32.0);
                        rdc += macs_per_oc * (32.0 - a as f64) / 32.0;
                        prev_aw = a;
                        gi += 1;
                        wstates.extend_from_slice(&s17);
                        wactions.push(a);
                    }
                }
                // §3.2 constraint: action order must match variance order.
                enforce_variance_order(&mut wactions, layer_wvar);
                for (c, &a) in wactions.iter().enumerate() {
                    wbits[l.w_off + c] = a as u8;
                }
                segs.push(Seg { side: Side::Weight, s16, goal: gw, states: wstates, actions: wactions });

                // Activation input channels (one shared for fc).
                let mut astates = Vec::with_capacity(l.a_len * LLC_DIM);
                let mut aactions = Vec::with_capacity(l.a_len);
                if cfg.batch_llc {
                    for c in 0..l.a_len {
                        let ctx = StateCtx {
                            i: gi + c,
                            t,
                            rdc,
                            rst,
                            gw,
                            ga,
                            prev_aw,
                            prev_aa,
                            wvar: 0.0,
                        };
                        let base = sb.state(&meta, t, &ctx);
                        let mut s17 = [0.0f32; LLC_DIM];
                        s17[..16].copy_from_slice(&base);
                        set_goal(&mut s17, Side::Act, ga);
                        astates.extend_from_slice(&s17);
                    }
                    aactions = agents.propose_actions_batch(rt, Side::Act, &astates, l.a_len)?;
                    for (c, a) in aactions.iter_mut().enumerate() {
                        *a = a.round().clamp(0.0, 32.0);
                        abits[l.a_off + c] = *a as u8;
                    }
                    prev_aa = *aactions.last().unwrap_or(&prev_aa);
                    gi += l.a_len;
                } else {
                    for c in 0..l.a_len {
                        let ctx = StateCtx {
                            i: gi,
                            t,
                            rdc,
                            rst,
                            gw,
                            ga,
                            prev_aw,
                            prev_aa,
                            wvar: 0.0,
                        };
                        let base = sb.state(&meta, t, &ctx);
                        let mut s17 = [0.0f32; LLC_DIM];
                        s17[..16].copy_from_slice(&base);
                        set_goal(&mut s17, Side::Act, ga);
                        let a = agents.propose_action(rt, Side::Act, &s17)?;
                        let a = a.round().clamp(0.0, 32.0);
                        prev_aa = a;
                        gi += 1;
                        astates.extend_from_slice(&s17);
                        abits[l.a_off + c] = a as u8;
                        aactions.push(a);
                    }
                }
                segs.push(Seg { side: Side::Act, s16, goal: ga, states: astates, actions: aactions });
            }
        }
        visited += l.macs as f64;
    }

    // --- Evaluate the complete configuration (no fine-tuning) --------------
    let eval = runner.eval_config(rt, mode, &wbits, &abits, data, Split::Val, cfg.eval_batches)?;
    let cost = model_cost(&meta.layers, &wbits, &abits);
    let reward = protocol.netscore.reward(eval.accuracy, &cost);
    let score = protocol.netscore.score(eval.accuracy, &cost);

    // --- Stage → replay: LLC shaped-intrinsic + HLC relabeled ---------------
    push_transitions(rt, agents, &segs, reward as f32, protocol.g_min as f32, cfg)?;

    // --- Reports -------------------------------------------------------------
    let per_layer = meta
        .layers
        .iter()
        .map(|l| LayerBits {
            name: l.name.clone(),
            avg_w: wbits[l.w_off..l.w_off + l.w_len].iter().map(|&b| b as f64).sum::<f64>()
                / l.w_len as f64,
            avg_a: abits[l.a_off..l.a_off + l.a_len].iter().map(|&b| b as f64).sum::<f64>()
                / l.a_len as f64,
        })
        .collect();
    let avg_wbits = wbits.iter().map(|&b| b as f64).sum::<f64>() / wbits.len() as f64;
    let avg_abits = abits.iter().map(|&b| b as f64).sum::<f64>() / abits.len() as f64;

    Ok(EpisodeOutcome {
        wbits,
        abits,
        accuracy: eval.accuracy,
        loss: eval.loss,
        cost,
        reward,
        score,
        per_layer,
        avg_wbits,
        avg_abits,
    })
}

/// Build transitions from staged segments and push to the four replays.
fn push_transitions(
    rt: &mut Runtime,
    agents: &mut HiroAgent,
    segs: &[Seg],
    extrinsic: f32,
    g_min: f32,
    cfg: &EpisodeConfig,
) -> anyhow::Result<()> {
    let zeta = agents.cfg.zeta;
    for side in [Side::Weight, Side::Act] {
        let side_segs: Vec<&Seg> = segs.iter().filter(|s| s.side == side).collect();
        // ---- LLC transitions (channel granularity only) -------------------
        let mut flat_states: Vec<&[f32]> = Vec::new();
        let mut flat_rewards: Vec<f32> = Vec::new();
        let mut flat_actions: Vec<f32> = Vec::new();
        for seg in &side_segs {
            let n = seg.actions.len();
            if seg.states.is_empty() {
                continue;
            }
            let mut cum = 0.0f32;
            for i in 0..n {
                cum += seg.actions[i];
                // Shaped intrinsic (§3.3): deviation of the executed prefix
                // from the goal track, normalized to [0,1] bits-fraction.
                let dev = (seg.goal * (i + 1) as f32 - cum).abs() / ((i + 1) as f32 * 32.0);
                let r = zeta * (-dev) + (1.0 - zeta) * extrinsic;
                flat_states.push(&seg.states[i * LLC_DIM..(i + 1) * LLC_DIM]);
                flat_rewards.push(r);
                flat_actions.push(seg.actions[i]);
            }
        }
        for i in 0..flat_states.len() {
            let s2 = if i + 1 < flat_states.len() {
                flat_states[i + 1].to_vec()
            } else {
                flat_states[i].to_vec()
            };
            agents.push_llc(
                side,
                Transition {
                    s: flat_states[i].to_vec(),
                    a: flat_actions[i] / 32.0 * 32.0, // action in bit units
                    r: flat_rewards[i],
                    s2,
                    done: i + 1 == flat_states.len(),
                },
            );
        }
        // ---- HLC transitions (relabeled) -----------------------------------
        for (j, seg) in side_segs.iter().enumerate() {
            let g = if cfg.relabel && !seg.states.is_empty() {
                agents.relabel_goal(rt, side, &seg.states, &seg.actions, seg.goal, g_min)?
            } else {
                seg.goal
            };
            let s2 = if j + 1 < side_segs.len() {
                side_segs[j + 1].s16.to_vec()
            } else {
                seg.s16.to_vec()
            };
            agents.push_hlc(
                side,
                Transition {
                    s: seg.s16.to_vec(),
                    a: g,
                    r: extrinsic,
                    s2,
                    done: j + 1 == side_segs.len(),
                },
            );
        }
    }
    Ok(())
}

/// Per-episode training schedule derived from the staged step counts.
pub fn train_after_episode(
    rt: &mut Runtime,
    agents: &mut HiroAgent,
    llc_steps: usize,
    n_layers: usize,
    cfg: &EpisodeConfig,
) -> anyhow::Result<()> {
    let n_llc = (llc_steps / cfg.llc_updates_div.max(1)).max(1);
    let n_hlc = if cfg.hlc_updates == 0 { n_layers } else { cfg.hlc_updates };
    agents.train(rt, n_llc, n_hlc)
}
