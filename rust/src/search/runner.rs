//! Search runner: the explore→exploit episode loop (paper §4: 100 explore
//! episodes at δ=0.5, then 300 exploit episodes with exponential decay),
//! best-configuration tracking, and learning-curve capture (Fig. 8).

use crate::agent::hiro::{HiroAgent, HiroConfig};
use crate::agent::noise::NoiseSchedule;
use crate::cost::Mode;
use crate::data::synth::SynthDataset;
use crate::env::state::StateBuilder;
use crate::journal::DurableLog;
use crate::models::ModelRunner;
use crate::runtime::Runtime;
use crate::search::checkpoint::{self, Checkpoint};
use crate::search::episode::{run_episode, train_after_episode, EpisodeConfig, EpisodeOutcome};
use crate::search::protocol::{Granularity, Protocol};

#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub mode: Mode,
    pub protocol: Protocol,
    pub granularity: Granularity,
    pub episodes: usize,
    /// Warm-up episodes at constant noise (paper: 100).
    pub warmup: usize,
    pub noise_decay: f64,
    pub eval_batches: usize,
    pub seed: u64,
    pub zeta: f32,
    pub relabel: bool,
    pub llc_updates_div: usize,
    /// Durable checkpointing (DESIGN.md §Durable jobs): snapshot the full
    /// search state to a journal every `every` episodes and resume from
    /// the newest matching snapshot at startup.  `None` runs ephemeral.
    pub checkpoint: Option<Checkpoint>,
}

impl SearchConfig {
    /// Scaled-down default (this testbed); `paper_scale` restores §4.
    pub fn quick(mode: Mode, protocol: Protocol, granularity: Granularity) -> SearchConfig {
        SearchConfig {
            mode,
            protocol,
            granularity,
            episodes: 40,
            warmup: 10,
            noise_decay: 0.95,
            eval_batches: 2,
            seed: 1,
            zeta: 0.5,
            relabel: true,
            llc_updates_div: 4,
            checkpoint: None,
        }
    }

    pub fn paper_scale(mut self) -> SearchConfig {
        self.episodes = 400;
        self.warmup = 100;
        self.noise_decay = 0.99;
        self
    }
}

/// Learning-curve row (one per episode) — Fig. 8's series.
#[derive(Debug, Clone, Copy)]
pub struct EpisodeStats {
    pub episode: usize,
    pub accuracy: f64,
    pub reward: f64,
    pub avg_wbits: f64,
    pub avg_abits: f64,
    pub norm_logic: f64,
}

#[derive(Debug)]
pub struct SearchResult {
    pub best: EpisodeOutcome,
    pub history: Vec<EpisodeStats>,
    /// Wall-clock of the whole search.
    pub secs: f64,
}

/// Episode-progress logging shared by [`run_search`]'s default hook and
/// the coordinator's `LogObserver` — new bests at debug level, every
/// `every`-th episode at info level.
pub fn log_episode_progress(
    tag: &str,
    every: usize,
    st: &EpisodeStats,
    episodes: usize,
    new_best: bool,
) {
    if new_best {
        crate::debug!(
            "[{tag}] ep {}: new best acc={:.4} reward={:.4} wb={:.2} ab={:.2}",
            st.episode,
            st.accuracy,
            st.reward,
            st.avg_wbits,
            st.avg_abits
        );
    }
    if st.episode % every.max(1) == 0 {
        crate::info!(
            "[{tag}] ep {}/{episodes} acc={:.4} reward={:.4}",
            st.episode,
            st.accuracy,
            st.reward
        );
    }
}

/// Run a full hierarchical search for one (model, mode, protocol,
/// granularity) cell, logging progress through the crate logger.
///
/// Structured consumers (the coordinator's `Observer`) should use
/// [`run_search_with`] and receive the per-episode events directly.
pub fn run_search(
    rt: &mut Runtime,
    runner: &ModelRunner,
    data: &SynthDataset,
    cfg: &SearchConfig,
) -> anyhow::Result<SearchResult> {
    let tag = format!(
        "{}-{} {} {}",
        runner.meta.name,
        cfg.granularity.tag(),
        cfg.mode.as_str(),
        cfg.protocol.name()
    );
    run_search_with(rt, runner, data, cfg, &mut |st: &EpisodeStats, episodes, new_best| {
        log_episode_progress(&tag, 10, st, episodes, new_best)
    })
}

/// [`run_search`] with a per-episode progress hook: called once per
/// finished episode with the just-recorded stats, the planned episode
/// count, and whether the episode set a new best reward.
pub fn run_search_with(
    rt: &mut Runtime,
    runner: &ModelRunner,
    data: &SynthDataset,
    cfg: &SearchConfig,
    on_episode: &mut dyn FnMut(&EpisodeStats, usize, bool),
) -> anyhow::Result<SearchResult> {
    // `JobSpec::build` rejects this, but `SearchConfig` is also driven
    // directly (repro tables, benches, tests) — a structured error here
    // beats the old `best.expect(..)` panic after a zero-iteration loop.
    anyhow::ensure!(
        cfg.episodes >= 1,
        "search needs at least one episode, got episodes == 0"
    );
    let t0 = std::time::Instant::now();
    let wvar = runner.weight_variances();
    let sb = StateBuilder::new(&runner.meta, &wvar);
    let mut hiro_cfg = HiroConfig {
        zeta: cfg.zeta,
        noise: NoiseSchedule::new(0.5, cfg.warmup, cfg.noise_decay),
        ..HiroConfig::default()
    };
    // Network granularity needs no agent exploration at all.
    if matches!(cfg.granularity, Granularity::Network(_)) {
        hiro_cfg.noise = NoiseSchedule::new(0.0, 0, 1.0);
    }
    let mut agents = HiroAgent::new(rt, hiro_cfg, cfg.seed)?;
    let ep_cfg = EpisodeConfig {
        eval_batches: cfg.eval_batches,
        llc_updates_div: cfg.llc_updates_div,
        hlc_updates: 0,
        relabel: cfg.relabel,
        batch_llc: true,
    };

    let episodes = if matches!(cfg.granularity, Granularity::Network(_)) { 1 } else { cfg.episodes };
    let mut best: Option<EpisodeOutcome> = None;
    let mut history = Vec::with_capacity(episodes);
    let llc_steps = runner.meta.w_channels + runner.meta.a_channels;
    let n_layers = runner.meta.layers.len();

    // Durable checkpointing: open (or resume) the journal and restore the
    // newest snapshot whose config fingerprint matches, continuing from
    // the episode after it.  Restored episodes are not replayed through
    // `on_episode` — their observers saw them before the interruption —
    // but the final report carries the full restored history, so a
    // resumed run's result bytes equal an uninterrupted run's.
    let fp = checkpoint::config_fingerprint(cfg, &runner.meta.name);
    let mut ckpt = match &cfg.checkpoint {
        Some(ck) if ck.every > 0 => Some((DurableLog::open(&ck.path)?, ck.every)),
        _ => None,
    };
    let mut start_ep = 0usize;
    if let Some((log, _)) = ckpt.as_mut() {
        if let Some((_, blob)) = log.latest_snapshot(checkpoint::TAG) {
            match checkpoint::decode_into(blob, fp, &mut agents)? {
                Some(st) => {
                    start_ep = st.episodes_done.min(episodes);
                    history = st.history;
                    best = st.best;
                    crate::info!(
                        "resuming search from {} at episode {start_ep}/{episodes}",
                        log.path().display()
                    );
                }
                None => crate::warn_!(
                    "checkpoint {} does not match this search config — starting clean",
                    log.path().display()
                ),
            }
        }
    }

    for ep in start_ep..episodes {
        let out = run_episode(
            rt,
            runner,
            &sb,
            &wvar,
            &mut agents,
            &cfg.protocol,
            cfg.granularity,
            cfg.mode,
            data,
            &ep_cfg,
        )?;
        if !matches!(cfg.granularity, Granularity::Network(_)) {
            train_after_episode(rt, &mut agents, llc_steps, n_layers, &ep_cfg)?;
        }
        agents.end_episode();
        // Log/observe from the just-built stats value — `history[ep]` would
        // re-index what we only just pushed.
        let stats = EpisodeStats {
            episode: ep,
            accuracy: out.accuracy,
            reward: out.reward,
            avg_wbits: out.avg_wbits,
            avg_abits: out.avg_abits,
            norm_logic: out.cost.norm_logic(),
        };
        history.push(stats);
        let better = best.as_ref().map_or(true, |b| out.reward > b.reward);
        if better {
            best = Some(out);
        }
        on_episode(&stats, episodes, better);
        if let Some((log, every)) = ckpt.as_mut() {
            let done = ep + 1;
            // No snapshot after the final episode — the finished result is
            // recorded at the layer above (report file / sweep journal /
            // config cache), not as a resumable mid-run state.
            if done % *every == 0 && done < episodes {
                let blob = checkpoint::encode(fp, done, &history, best.as_ref(), &agents)?;
                log.snapshot(checkpoint::TAG, done as u64, &blob)?;
            }
        }
    }

    // The search finished: its checkpoint journal is spent state (the
    // result now lives in the caller's report), so drop it — a later
    // identical run starts clean and reproduces the same bytes anyway.
    if let Some((log, _)) = ckpt.take() {
        let path = log.path().to_path_buf();
        drop(log);
        std::fs::remove_file(&path).ok();
    }

    let best = best.ok_or_else(|| {
        anyhow::anyhow!("search finished without completing a single episode")
    })?;
    Ok(SearchResult { best, history, secs: t0.elapsed().as_secs_f64() })
}
