//! §3.3 search protocols: NetScore coefficient presets plus the structural
//! budget (Algorithm 1) the resource-constrained protocol uses instead of a
//! cost term in the reward.

use crate::reward::NetScore;
use crate::search::algorithm1::LayerBound;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProtocolKind {
    /// α=1, β=γ=0 — best accuracy under a hardware budget (drones);
    /// the budget is enforced by Algorithm-1 goal bounding.
    ResourceConstrained,
    /// α=2, β=γ=0.5 — smallest/fastest model with no accuracy loss
    /// (fingerprint locks).
    AccuracyGuaranteed,
    /// The §4.3 ablation: AMC's FLOP-only reward (β=0).
    FlopReward,
}

#[derive(Debug, Clone, Copy)]
pub struct Protocol {
    pub kind: ProtocolKind,
    pub netscore: NetScore,
    /// B̄ — target average bit-width for Algorithm 1 (RC only).
    pub target_bits: f64,
    /// Minimal allowed goal g_min.
    pub g_min: f64,
}

impl Protocol {
    pub fn resource_constrained(target_bits: f64) -> Protocol {
        Protocol {
            kind: ProtocolKind::ResourceConstrained,
            netscore: NetScore::RESOURCE_CONSTRAINED,
            target_bits,
            g_min: 1.0,
        }
    }

    pub fn accuracy_guaranteed() -> Protocol {
        Protocol {
            kind: ProtocolKind::AccuracyGuaranteed,
            netscore: NetScore::ACCURACY_GUARANTEED,
            target_bits: 0.0,
            g_min: 0.0,
        }
    }

    pub fn flop_reward() -> Protocol {
        Protocol {
            kind: ProtocolKind::FlopReward,
            netscore: NetScore::FLOP_BASED,
            target_bits: 0.0,
            g_min: 0.0,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Protocol> {
        match s {
            "rc" | "resource-constrained" => Ok(Self::resource_constrained(5.0)),
            "ag" | "accuracy-guaranteed" => Ok(Self::accuracy_guaranteed()),
            "fr" | "flop" => Ok(Self::flop_reward()),
            _ => anyhow::bail!("protocol must be rc|ag|fr, got {s:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self.kind {
            ProtocolKind::ResourceConstrained => "resource-constrained",
            ProtocolKind::AccuracyGuaranteed => "accuracy-guaranteed",
            ProtocolKind::FlopReward => "flop-reward",
        }
    }

    /// Short CLI/file-name tag — the inverse of `parse`.
    pub fn tag(&self) -> &'static str {
        match self.kind {
            ProtocolKind::ResourceConstrained => "rc",
            ProtocolKind::AccuracyGuaranteed => "ag",
            ProtocolKind::FlopReward => "fr",
        }
    }

    /// Algorithm-1 bounder for one controller side, if this protocol uses
    /// structural budgeting.
    pub fn bounder(&self, layer_macs: &[f64]) -> Option<LayerBound> {
        match self.kind {
            ProtocolKind::ResourceConstrained => {
                Some(LayerBound::new(layer_macs.to_vec(), self.target_bits, self.g_min))
            }
            _ => None,
        }
    }
}

/// Search granularity — the N / L / C rows of Tables 2 and 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One fixed QBN/BBN for the whole network (the empirical policy).
    Network(u8),
    /// One QBN/BBN per layer (HAQ-style; HLC goals applied verbatim).
    Layer,
    /// One QBN/BBN per weight output / activation input channel (AutoQ).
    Channel,
}

impl Granularity {
    pub fn parse(s: &str) -> anyhow::Result<Granularity> {
        if let Some(b) = s.strip_prefix("network:") {
            return Ok(Granularity::Network(b.parse()?));
        }
        match s {
            "network" | "n" => Ok(Granularity::Network(5)),
            "layer" | "l" => Ok(Granularity::Layer),
            "channel" | "c" => Ok(Granularity::Channel),
            _ => anyhow::bail!("granularity must be network[:B]|layer|channel, got {s:?}"),
        }
    }
    pub fn tag(&self) -> &'static str {
        match self {
            Granularity::Network(_) => "N",
            Granularity::Layer => "L",
            Granularity::Channel => "C",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_coefficients() {
        let rc = Protocol::resource_constrained(5.0);
        assert_eq!((rc.netscore.alpha, rc.netscore.beta, rc.netscore.gamma), (1.0, 0.0, 0.0));
        assert!(rc.bounder(&[1.0, 2.0]).is_some());
        let ag = Protocol::accuracy_guaranteed();
        assert_eq!((ag.netscore.alpha, ag.netscore.beta, ag.netscore.gamma), (2.0, 0.5, 0.5));
        assert!(ag.bounder(&[1.0]).is_none());
        let fr = Protocol::flop_reward();
        assert_eq!(fr.netscore.beta, 0.0);
    }

    #[test]
    fn parsing() {
        assert_eq!(Protocol::parse("rc").unwrap().kind, ProtocolKind::ResourceConstrained);
        assert_eq!(Protocol::parse("ag").unwrap().kind, ProtocolKind::AccuracyGuaranteed);
        assert!(Protocol::parse("zz").is_err());
        for tag in ["rc", "ag", "fr"] {
            assert_eq!(Protocol::parse(tag).unwrap().tag(), tag);
        }
        assert_eq!(Granularity::parse("network:4").unwrap(), Granularity::Network(4));
        assert_eq!(Granularity::parse("c").unwrap(), Granularity::Channel);
        assert_eq!(Granularity::parse("c").unwrap().tag(), "C");
    }
}
