//! Backend-neutral host values crossing the executable boundary.
//!
//! Everything the coordinator dispatches (parameters, images, bit vectors,
//! scalars) and everything an executable returns is a [`Value`] — a typed
//! host buffer with a shape.  Backends translate at their own edge: the
//! PJRT backend converts to/from `xla::Literal`, the reference interpreter
//! reads the buffers directly.  Only the two dtypes the manifest uses
//! exist: `f32` and `s32`.

use crate::runtime::tensor::Tensor;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn scalar(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Value {
        Value::F32(Tensor::new(shape, data))
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Value {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Value::I32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32 { shape, .. } => shape,
        }
    }

    /// Manifest dtype token.
    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "f32",
            Value::I32 { .. } => "s32",
        }
    }

    pub fn elems(&self) -> usize {
        match self {
            Value::F32(t) => t.elems(),
            Value::I32 { data, .. } => data.len(),
        }
    }

    pub fn as_f32(&self) -> anyhow::Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32 { .. } => anyhow::bail!("expected f32 value, got s32"),
        }
    }

    pub fn into_f32(self) -> anyhow::Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32 { .. } => anyhow::bail!("expected f32 value, got s32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            Value::F32(_) => anyhow::bail!("expected s32 value, got f32"),
        }
    }

    /// Read a scalar (or single-element) f32.
    pub fn scalar_f32(&self) -> anyhow::Result<f32> {
        let t = self.as_f32()?;
        anyhow::ensure!(t.elems() == 1, "expected scalar, got shape {:?}", t.shape);
        Ok(t.data[0])
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_and_dtypes() {
        let f = Value::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(f.dtype(), "f32");
        assert_eq!(f.shape(), &[2, 2]);
        assert_eq!(f.elems(), 4);
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());

        let i = Value::i32(vec![3], vec![1, 2, 3]);
        assert_eq!(i.dtype(), "s32");
        assert_eq!(i.as_i32().unwrap(), &[1, 2, 3]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    fn scalar_reads() {
        assert_eq!(Value::scalar(2.5).scalar_f32().unwrap(), 2.5);
        assert!(Value::f32(vec![2], vec![1.0, 2.0]).scalar_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn i32_shape_checked() {
        let _ = Value::i32(vec![2], vec![1, 2, 3]);
    }
}
