//! Reference interpreter for the model artifacts: `{model}_eval_{mode}`
//! (forward + accuracy/loss head) and `{model}_train_{mode}` (forward,
//! STE backward, SGD-momentum update) — the same graphs
//! `python/compile/model.py` lowers to HLO, walked node-by-node in Rust.
//!
//! STE semantics match the JAX export: the forward pass computes with
//! quantized weights/activations, the backward pass treats both quantizers
//! as identity (`q = x + stop_gradient(q − x)`), so weight gradients are
//! taken at the quantized point and flow to the raw parameters unchanged.
//!
//! Since PR 4 the executables dispatch through the **planned execution
//! engine** (`plan.rs`): graphs compile once into slot-assigned step lists
//! and execute against reusable per-worker workspaces.  The original
//! allocate-per-call tree-walk below (`forward`/`backward`) is retained as
//! the semantic reference — `run_walk` exposes it, and
//! `tests/plan_engine.rs` asserts planned output is byte-identical to it
//! for every model × mode × thread count.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::runtime::backend::{Executable, ScratchStats};
use crate::runtime::reference::kernels::{quantize_weights_alloc, wrep, WRep, I8_LEVELS};
use crate::runtime::reference::nn::{
    add_bias, bias_bwd, cmajor_to_nhwc, cmajor_to_w, conv2d, conv2d_bwd, dwconv2d, dwconv2d_bwd,
    gap, gap_bwd, group_norm, group_norm_bwd, matmul, matmul_a_bt, matmul_at_b_acc, maxpool2,
    maxpool2_bwd, nhwc_to_cmajor, qconv2d, qdwconv2d, qfc, relu, relu_bwd, softmax_xent,
    w_to_cmajor, Dims, GnCache,
};
use crate::runtime::reference::plan::{
    compile_eval, compile_train, run_eval, run_train, Plan, Workspace,
};
use crate::runtime::reference::quantize::{is_passthrough, linear_scale, quantize_rows};
use crate::runtime::reference::zoo::{LType, ModelGraph, Node, EVAL_BATCH, TRAIN_BATCH};
use crate::runtime::tensor::Tensor;
use crate::runtime::value::Value;
use crate::util::pool::{ScratchArena, WorkerPool};

/// Activation flowing through the walk: NHWC feature maps, or the flat
/// (n, c) form after global average pooling.
#[derive(Clone)]
enum ActT {
    A4(Dims, Vec<f32>),
    A2 { n: usize, c: usize, data: Vec<f32> },
}

impl ActT {
    fn channels(&self) -> usize {
        match self {
            ActT::A4(d, _) => d.c,
            ActT::A2 { c, .. } => *c,
        }
    }
    fn into4(self) -> (Dims, Vec<f32>) {
        match self {
            ActT::A4(d, data) => (d, data),
            ActT::A2 { .. } => panic!("expected NHWC activation"),
        }
    }
}

/// Per-layer backward state.
struct LayerTape {
    li: usize,
    xq: ActT,
    /// Quantized weight in the parameter's row-major layout.
    wq: Vec<f32>,
    gn: Option<GnCache>,
    out_d: Dims,
    /// Post-ReLU output (mask source) when the layer activates.
    relu_out: Option<Vec<f32>>,
}

/// Per-node backward state.
enum Tape {
    Layer(LayerTape),
    Pool { idx: Vec<u32>, in_d: Dims },
    Gap { d: Dims },
    Basic { c1: LayerTape, c2: LayerTape, proj: Option<LayerTape>, relu_out: Vec<f32> },
    Fire { sq: LayerTape, e1: LayerTape, e3: LayerTape, e1_cout: usize },
    Irb { expand: Option<LayerTape>, dw: LayerTape, project: LayerTape, residual: bool },
}

fn add_vec(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

// ---------------------------------------------------------------------------
// Static activation scales (calibration)
// ---------------------------------------------------------------------------

/// Calibrated static activation scales for one model: per-layer max
/// |input| observed over the calibration batches, plus the fingerprint
/// the eval cache keys the table under (0 is reserved for dynamic mode).
#[derive(Debug, Clone, PartialEq)]
pub struct ActScales {
    /// max|activation| entering each graph layer (layer index order).
    pub maxes: Vec<f32>,
    /// FNV fingerprint over the exact f32 bit patterns of `maxes`.
    pub fingerprint: u64,
}

/// How a forward walk obtains activation scales on the integer path.
pub enum ActMode<'a> {
    /// Dynamic per-row max scales (the default).
    Dynamic,
    /// Static per-layer scales from a calibration table of per-layer
    /// max|input| values: one precomputed i8 grid per layer, no max pass
    /// in the hot loop.
    Static(&'a [f32]),
    /// Calibration pass: record per-layer max|input| into the table.
    /// Callers run this with passthrough bit-widths, so layers execute
    /// the plain f32 path and nothing dispatches the integer kernels.
    Record(&'a mut [f32]),
}

static ACT_SCALES: OnceLock<RwLock<HashMap<String, Arc<ActScales>>>> = OnceLock::new();

fn act_scale_registry() -> &'static RwLock<HashMap<String, Arc<ActScales>>> {
    ACT_SCALES.get_or_init(Default::default)
}

/// Register (`Some`) or clear (`None`) the static activation-scale table
/// for `model`.  Reference-backend evals pick the table up by graph name
/// on every batch, so flipping the registration immediately changes how
/// subsequent evals quantize activations (the coordinator owns this
/// lifecycle and keys the eval cache on the table's fingerprint).
pub fn set_act_scales(model: &str, scales: Option<Arc<ActScales>>) {
    let mut reg = act_scale_registry().write().expect("act-scale registry poisoned");
    match scales {
        Some(s) => {
            reg.insert(model.to_string(), s);
        }
        None => {
            reg.remove(model);
        }
    }
}

/// The registered static-scale table for `model`, if any.
pub fn act_scales_for(model: &str) -> Option<Arc<ActScales>> {
    act_scale_registry().read().expect("act-scale registry poisoned").get(model).cloned()
}

/// Deterministic calibration pass for static activation scales: a plain
/// f32 passthrough forward (32-bit everywhere, so nothing quantizes or
/// dispatches int kernels) over `batches`, recording each layer's
/// max|input|.  A pure function of (graph, params, batches) — identical
/// inputs produce byte-identical maxes on every host, which is what
/// keeps cached reports reproducible under `--act-scales static`.
pub fn calibrate_act_maxes(
    g: &ModelGraph,
    binar: bool,
    params: &[&Tensor],
    batches: &[&Tensor],
) -> anyhow::Result<Vec<f32>> {
    let wbits = vec![32.0f32; g.w_channels];
    let abits = vec![32.0f32; g.a_channels];
    let mut maxes = vec![0.0f32; g.layers.len()];
    for images in batches {
        let mut act = ActMode::Record(&mut maxes);
        forward(g, params, images, &wbits, &abits, binar, false, &mut act)?;
    }
    Ok(maxes)
}

/// One primitive layer: per-channel quantize input + weight, conv/matmul,
/// norm or bias, optional ReLU.  Returns the output and (in training) the
/// backward tape.
#[allow(clippy::too_many_arguments)]
fn layer_fwd(
    g: &ModelGraph,
    li: usize,
    params: &[&Tensor],
    wbits: &[f32],
    abits: &[f32],
    binar: bool,
    x: ActT,
    want_tape: bool,
    act: &mut ActMode,
) -> (ActT, Option<LayerTape>) {
    let l = &g.layers[li];
    let wb = &wbits[l.w_off..l.w_off + l.w_len];
    let ab = &abits[l.a_off..l.a_off + l.a_len];

    // Calibration: record the raw input's max|x| before any quantization.
    // The raw max upper-bounds the fake-quantized activation's max for
    // every abits setting (symmetric max-abs grids never exceed their
    // row max), so one fp32 calibration pass serves all bit configs.
    if let ActMode::Record(maxes) = act {
        let data = match &x {
            ActT::A4(_, data) => data,
            ActT::A2 { data, .. } => data,
        };
        let mx = data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if mx > maxes[li] {
            maxes[li] = mx;
        }
    }

    // Per-input-channel activation quantization (fc: one shared channel).
    // Exact-passthrough bit slices (≥ 24 bits, quant mode) skip the
    // channel-major round-trip — the quantized copy equals the source
    // bit-for-bit, so the skip preserves byte-identity.
    let xq: ActT = match &x {
        ActT::A4(d, data) => {
            debug_assert_eq!(d.c, l.a_len, "{}: activation channels", l.name);
            if is_passthrough(ab, binar) {
                ActT::A4(*d, data.clone())
            } else {
                let mut cm = nhwc_to_cmajor(data, *d);
                quantize_rows(&mut cm, d.c, d.n * d.h * d.w, ab, binar);
                ActT::A4(*d, cmajor_to_nhwc(&cm, *d))
            }
        }
        ActT::A2 { n, c, data } => {
            let mut q = data.clone();
            if !is_passthrough(ab, binar) {
                quantize_rows(&mut q, 1, n * c, ab, binar);
            }
            ActT::A2 { n: *n, c: *c, data: q }
        }
    };

    // Integer-path dispatch: same [`wrep`] rule as the plan executor (so
    // the walk and the planned engine stay byte-identical), eval only —
    // training tapes need the f32 quantized operands.  Depthwise convs
    // dispatch through `qdwconv2d` with per-(image, channel) scales.
    let int_ok = !want_tape;
    let rep = if int_ok { wrep(wb, binar) } else { WRep::F32 };
    if rep != WRep::F32 {
        let w = params[l.p_w];
        let rest = w.data.len() / l.w_len;
        let (qw, sw) = quantize_weights_alloc(&w.data, rest, l.w_len, wb, rep);
        let i4 = rep == WRep::I4;
        // Static mode derives one i8 grid per layer from the calibrated
        // max — the identical expression the plan executor uses, so the
        // two engines stay byte-identical in every act-scale mode.
        let act_scale = match act {
            ActMode::Static(maxes) => Some(linear_scale(maxes[li], I8_LEVELS)),
            _ => None,
        };
        return match l.typ {
            LType::Fc => {
                let ActT::A2 { n, c, data } = &xq else { panic!("fc expects flat input") };
                let mut y = qfc(data, *n, *c, &qw, &sw, i4, l.cout, act_scale);
                add_bias(&mut y, l.cout, &params[l.p_w + 1].data);
                (ActT::A2 { n: *n, c: l.cout, data: y }, None)
            }
            LType::Conv => {
                let ActT::A4(d, data) = &xq else { panic!("conv expects NHWC input") };
                let (mut y, od) = qconv2d(data, *d, &qw, &sw, i4, l.k, l.s, l.cout, act_scale);
                if l.norm {
                    let (yy, _) =
                        group_norm(&y, od, &params[l.p_w + 1].data, &params[l.p_w + 2].data);
                    y = yy;
                } else {
                    add_bias(&mut y, od.c, &params[l.p_w + 1].data);
                }
                if l.relu {
                    relu(&mut y);
                }
                (ActT::A4(od, y), None)
            }
            LType::DwConv => {
                let ActT::A4(d, data) = &xq else { panic!("dwconv expects NHWC input") };
                let (mut y, od) = qdwconv2d(data, *d, &qw, &sw, i4, l.k, l.s, act_scale);
                if l.norm {
                    let (yy, _) =
                        group_norm(&y, od, &params[l.p_w + 1].data, &params[l.p_w + 2].data);
                    y = yy;
                } else {
                    add_bias(&mut y, od.c, &params[l.p_w + 1].data);
                }
                if l.relu {
                    relu(&mut y);
                }
                (ActT::A4(od, y), None)
            }
        };
    }

    // Per-output-channel weight quantization (same passthrough skip: one
    // clone instead of two full-weight transposed copies + quantize scan).
    let w = params[l.p_w];
    let wq = if is_passthrough(wb, binar) {
        w.data.clone()
    } else {
        let rest = w.data.len() / l.w_len;
        let mut w2 = w_to_cmajor(&w.data, rest, l.w_len);
        quantize_rows(&mut w2, l.w_len, rest, wb, binar);
        cmajor_to_w(&w2, rest, l.w_len)
    };

    match l.typ {
        LType::Fc => {
            let (n, c) = match &xq {
                ActT::A2 { n, c, .. } => (*n, *c),
                ActT::A4(..) => panic!("fc expects flat input"),
            };
            let ActT::A2 { data, .. } = &xq else { unreachable!() };
            let mut y = matmul(data, &wq, n, c, l.cout);
            add_bias(&mut y, l.cout, &params[l.p_w + 1].data);
            let out = ActT::A2 { n, c: l.cout, data: y };
            let out_d = Dims { n, h: 1, w: 1, c: l.cout };
            let tape = want_tape
                .then(|| LayerTape { li, xq, wq, gn: None, out_d, relu_out: None });
            (out, tape)
        }
        LType::Conv | LType::DwConv => {
            let ActT::A4(d, data) = &xq else { panic!("conv expects NHWC input") };
            let (mut y, od) = if l.typ == LType::DwConv {
                dwconv2d(data, *d, &wq, l.k, l.s)
            } else {
                conv2d(data, *d, &wq, l.k, l.s, l.cout)
            };
            let gn = if l.norm {
                let (yy, cache) =
                    group_norm(&y, od, &params[l.p_w + 1].data, &params[l.p_w + 2].data);
                y = yy;
                Some(cache)
            } else {
                add_bias(&mut y, od.c, &params[l.p_w + 1].data);
                None
            };
            if l.relu {
                relu(&mut y);
            }
            let relu_out = (want_tape && l.relu).then(|| y.clone());
            let tape = want_tape.then(|| LayerTape { li, xq, wq, gn, out_d: od, relu_out });
            (ActT::A4(od, y), tape)
        }
    }
}

/// Backward of one primitive layer: accumulates parameter gradients and
/// returns the gradient w.r.t. the layer input (STE through both
/// quantizers).
fn layer_bwd(
    g: &ModelGraph,
    t: &LayerTape,
    params: &[&Tensor],
    mut dy: Vec<f32>,
    grads: &mut [Vec<f32>],
) -> ActT {
    let l = &g.layers[t.li];
    match l.typ {
        LType::Fc => {
            let ActT::A2 { n, c, data: xqd } = &t.xq else { panic!("fc tape") };
            add_vec(&mut grads[l.p_w + 1], &bias_bwd(&dy, l.cout));
            matmul_at_b_acc(&mut grads[l.p_w], xqd, &dy, *n, *c, l.cout);
            let dx = matmul_a_bt(&dy, &t.wq, *n, l.cout, *c);
            ActT::A2 { n: *n, c: *c, data: dx }
        }
        LType::Conv | LType::DwConv => {
            if let Some(out) = &t.relu_out {
                relu_bwd(&mut dy, out);
            }
            if l.norm {
                let (dxn, dg, db) =
                    group_norm_bwd(&dy, t.out_d, &params[l.p_w + 1].data, t.gn.as_ref().unwrap());
                add_vec(&mut grads[l.p_w + 1], &dg);
                add_vec(&mut grads[l.p_w + 2], &db);
                dy = dxn;
            } else {
                add_vec(&mut grads[l.p_w + 1], &bias_bwd(&dy, t.out_d.c));
            }
            let ActT::A4(din, xqd) = &t.xq else { panic!("conv tape") };
            let (dx, dw) = if l.typ == LType::DwConv {
                dwconv2d_bwd(xqd, *din, &t.wq, l.k, l.s, &dy)
            } else {
                conv2d_bwd(xqd, *din, &t.wq, l.k, l.s, l.cout, &dy)
            };
            add_vec(&mut grads[l.p_w], &dw);
            ActT::A4(*din, dx)
        }
    }
}

/// Full forward walk.  Returns (logits data, n, classes, tapes-if-train).
#[allow(clippy::too_many_arguments)]
fn forward(
    g: &ModelGraph,
    params: &[&Tensor],
    images: &Tensor,
    wbits: &[f32],
    abits: &[f32],
    binar: bool,
    want_tape: bool,
    act: &mut ActMode,
) -> anyhow::Result<(Vec<f32>, usize, usize, Option<Vec<Tape>>)> {
    anyhow::ensure!(images.shape.len() == 4, "images must be NHWC");
    let d0 = Dims { n: images.shape[0], h: images.shape[1], w: images.shape[2], c: images.shape[3] };
    anyhow::ensure!(wbits.len() == g.w_channels, "wbits len {} vs {}", wbits.len(), g.w_channels);
    anyhow::ensure!(abits.len() == g.a_channels, "abits len {} vs {}", abits.len(), g.a_channels);
    let mut x = ActT::A4(d0, images.data.clone());
    let mut tapes: Vec<Tape> = Vec::new();
    let mut li = 0usize;
    let mut fwd =
        |li: usize, x: ActT| layer_fwd(g, li, params, wbits, abits, binar, x, want_tape, act);

    for node in &g.nodes {
        match *node {
            Node::Conv { .. } | Node::Fc { .. } => {
                let (y, t) = fwd(li, x);
                li += 1;
                x = y;
                if want_tape {
                    tapes.push(Tape::Layer(t.unwrap()));
                }
            }
            Node::Pool => {
                let (d, data) = x.into4();
                let (y, idx, od) = maxpool2(&data, d);
                x = ActT::A4(od, y);
                if want_tape {
                    tapes.push(Tape::Pool { idx, in_d: d });
                }
            }
            Node::Gap => {
                let (d, data) = x.into4();
                let y = gap(&data, d);
                x = ActT::A2 { n: d.n, c: d.c, data: y };
                if want_tape {
                    tapes.push(Tape::Gap { d });
                }
            }
            Node::Basic { cout, s } => {
                let proj = s != 1 || x.channels() != cout;
                let inp = x.clone();
                let (y1, t1) = fwd(li, x);
                let (y2, t2) = fwd(li + 1, y1);
                let (sc, tp) = if proj {
                    let (sc, tp) = fwd(li + 2, inp);
                    (sc, tp)
                } else {
                    (inp, None)
                };
                li += if proj { 3 } else { 2 };
                let (od, mut data) = y2.into4();
                let (_, scd) = sc.into4();
                add_vec(&mut data, &scd);
                relu(&mut data);
                if want_tape {
                    tapes.push(Tape::Basic {
                        c1: t1.unwrap(),
                        c2: t2.unwrap(),
                        proj: tp,
                        relu_out: data.clone(),
                    });
                }
                x = ActT::A4(od, data);
            }
            Node::Fire { e1, .. } => {
                let (sqz, tsq) = fwd(li, x);
                let (a, te1) = fwd(li + 1, sqz.clone());
                let (b, te3) = fwd(li + 2, sqz);
                li += 3;
                let (da, adata) = a.into4();
                let (db, bdata) = b.into4();
                debug_assert_eq!(da.c, e1);
                let od = Dims { n: da.n, h: da.h, w: da.w, c: da.c + db.c };
                let mut out = vec![0.0f32; od.elems()];
                for p in 0..da.n * da.h * da.w {
                    out[p * od.c..p * od.c + da.c]
                        .copy_from_slice(&adata[p * da.c..(p + 1) * da.c]);
                    out[p * od.c + da.c..(p + 1) * od.c]
                        .copy_from_slice(&bdata[p * db.c..(p + 1) * db.c]);
                }
                if want_tape {
                    tapes.push(Tape::Fire {
                        sq: tsq.unwrap(),
                        e1: te1.unwrap(),
                        e3: te3.unwrap(),
                        e1_cout: da.c,
                    });
                }
                x = ActT::A4(od, out);
            }
            Node::Irb { t, cout, s } => {
                let cin_cur = x.channels();
                let residual = s == 1 && cin_cur == cout;
                let inp = if residual { Some(x.clone()) } else { None };
                let mut cur = x;
                let texp = if t != 1 {
                    let (y, tp) = fwd(li, cur);
                    li += 1;
                    cur = y;
                    tp
                } else {
                    None
                };
                let (y, tdw) = fwd(li, cur);
                li += 1;
                let (y, tpr) = fwd(li, y);
                li += 1;
                let (od, mut data) = y.into4();
                if let Some(inp) = inp {
                    let (_, inpd) = inp.into4();
                    add_vec(&mut data, &inpd);
                }
                if want_tape {
                    tapes.push(Tape::Irb {
                        expand: texp,
                        dw: tdw.unwrap(),
                        project: tpr.unwrap(),
                        residual,
                    });
                }
                x = ActT::A4(od, data);
            }
        }
    }
    anyhow::ensure!(li == g.layers.len(), "layer walk diverged: {li} vs {}", g.layers.len());
    match x {
        ActT::A2 { n, c, data } => Ok((data, n, c, want_tape.then_some(tapes))),
        ActT::A4(..) => anyhow::bail!("model {} does not end in a flat head", g.name),
    }
}

/// Full backward walk from d(logits); returns per-parameter gradients.
fn backward(
    g: &ModelGraph,
    tapes: &[Tape],
    params: &[&Tensor],
    dlogits: Vec<f32>,
    n: usize,
    classes: usize,
) -> Vec<Vec<f32>> {
    let mut grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0f32; p.data.len()]).collect();
    let mut dy = ActT::A2 { n, c: classes, data: dlogits };
    for tape in tapes.iter().rev() {
        dy = match tape {
            Tape::Layer(t) => {
                let data = match dy {
                    ActT::A4(_, data) => data,
                    ActT::A2 { data, .. } => data,
                };
                layer_bwd(g, t, params, data, &mut grads)
            }
            Tape::Pool { idx, in_d } => {
                let (_, data) = dy.into4();
                ActT::A4(*in_d, maxpool2_bwd(&data, idx, in_d.elems()))
            }
            Tape::Gap { d } => {
                let ActT::A2 { data, .. } = dy else { panic!("gap grad") };
                ActT::A4(*d, gap_bwd(&data, *d))
            }
            Tape::Basic { c1, c2, proj, relu_out } => {
                let (_, mut data) = dy.into4();
                relu_bwd(&mut data, relu_out);
                let d_sc = data.clone();
                let (_, dy1) = layer_bwd(g, c2, params, data, &mut grads).into4();
                let (din, mut dinp) = layer_bwd(g, c1, params, dy1, &mut grads).into4();
                let dinp_b = match proj {
                    Some(tp) => {
                        let (_, d) = layer_bwd(g, tp, params, d_sc, &mut grads).into4();
                        d
                    }
                    None => d_sc,
                };
                add_vec(&mut dinp, &dinp_b);
                ActT::A4(din, dinp)
            }
            Tape::Fire { sq, e1, e3, e1_cout } => {
                let (od, data) = dy.into4();
                let ca = *e1_cout;
                let cb = od.c - ca;
                let pixels = od.n * od.h * od.w;
                let mut da = vec![0.0f32; pixels * ca];
                let mut db = vec![0.0f32; pixels * cb];
                for p in 0..pixels {
                    da[p * ca..(p + 1) * ca].copy_from_slice(&data[p * od.c..p * od.c + ca]);
                    db[p * cb..(p + 1) * cb].copy_from_slice(&data[p * od.c + ca..(p + 1) * od.c]);
                }
                let (_, mut dsq) = layer_bwd(g, e1, params, da, &mut grads).into4();
                let (_, dsq2) = layer_bwd(g, e3, params, db, &mut grads).into4();
                add_vec(&mut dsq, &dsq2);
                let (din, dinp) = layer_bwd(g, sq, params, dsq, &mut grads).into4();
                ActT::A4(din, dinp)
            }
            Tape::Irb { expand, dw, project, residual } => {
                let (_, data) = dy.into4();
                let dres = residual.then(|| data.clone());
                let (_, d1) = layer_bwd(g, project, params, data, &mut grads).into4();
                let (d2d, d2) = layer_bwd(g, dw, params, d1, &mut grads).into4();
                let (din, mut dx) = match expand {
                    Some(te) => layer_bwd(g, te, params, d2, &mut grads).into4(),
                    None => (d2d, d2),
                };
                if let Some(r) = dres {
                    add_vec(&mut dx, &r);
                }
                ActT::A4(din, dx)
            }
        };
    }
    grads
}

// ---------------------------------------------------------------------------
// Executables
// ---------------------------------------------------------------------------

/// Parsed `{model}_eval_{mode}` inputs: (params, images, labels, wbits,
/// abits).
type EvalInputs<'a> = (Vec<&'a Tensor>, &'a Tensor, &'a [i32], &'a Tensor, &'a Tensor);

fn parse_eval_inputs<'a>(np: usize, inputs: &'a [&Value]) -> anyhow::Result<EvalInputs<'a>> {
    anyhow::ensure!(inputs.len() == np + 4, "eval arity");
    let params: Vec<&Tensor> =
        inputs[..np].iter().map(|v| v.as_f32()).collect::<anyhow::Result<_>>()?;
    let images = inputs[np].as_f32()?;
    anyhow::ensure!(images.shape.len() == 4, "images must be NHWC");
    let labels = inputs[np + 1].as_i32()?;
    Ok((params, images, labels, inputs[np + 2].as_f32()?, inputs[np + 3].as_f32()?))
}

pub struct RefModelEval {
    pub graph: ModelGraph,
    pub binar: bool,
    /// Shared fan-out pool (from the owning `RefBackend`); `execute_batch`
    /// spreads independent batches across it.
    pool: Arc<WorkerPool>,
    /// Compiled plans per batch size (the manifest batch is compiled at
    /// build time; odd sizes — small test batches — compile on first use).
    plans: Mutex<HashMap<usize, Arc<Plan>>>,
    /// Per-worker workspace handout; bounded by the pool's peak width and
    /// flat across steady-state batches.
    arena: ScratchArena<Workspace>,
}

impl RefModelEval {
    pub fn new(graph: ModelGraph, binar: bool, pool: Arc<WorkerPool>) -> RefModelEval {
        let mut plans = HashMap::new();
        plans.insert(EVAL_BATCH, Arc::new(compile_eval(&graph, EVAL_BATCH)));
        RefModelEval { graph, binar, pool, plans: Mutex::new(plans), arena: ScratchArena::new() }
    }

    fn plan_for(&self, n: usize) -> Arc<Plan> {
        let mut plans = self.plans.lock().expect("plan cache poisoned");
        plans.entry(n).or_insert_with(|| Arc::new(compile_eval(&self.graph, n))).clone()
    }

    /// One batch through the planned engine against a worker-owned
    /// workspace.  Immutable so the pool can run many batches against one
    /// executable concurrently.
    fn run_one(&self, inputs: &[&Value], ws: &mut Workspace) -> anyhow::Result<Vec<Value>> {
        let (params, images, labels, wbits, abits) =
            parse_eval_inputs(self.graph.params.len(), inputs)?;
        let plan = self.plan_for(images.shape[0]);
        let table = act_scales_for(&self.graph.name);
        let (correct, loss) = run_eval(
            &plan,
            &self.graph,
            self.binar,
            &params,
            images,
            labels,
            &wbits.data,
            &abits.data,
            table.as_ref().map(|t| t.maxes.as_slice()),
            ws,
        )?;
        Ok(vec![Value::scalar(correct), Value::scalar(loss)])
    }

    /// The PR 3 allocate-per-call tree-walk — kept as the semantic
    /// reference the planned engine is byte-compared against
    /// (`tests/plan_engine.rs`).
    pub fn run_walk(&self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        let (params, images, labels, wbits, abits) =
            parse_eval_inputs(self.graph.params.len(), inputs)?;
        let table = act_scales_for(&self.graph.name);
        let mut act = match &table {
            Some(t) => ActMode::Static(&t.maxes),
            None => ActMode::Dynamic,
        };
        let (logits, n, classes, _) = forward(
            &self.graph,
            &params,
            images,
            &wbits.data,
            &abits.data,
            self.binar,
            false,
            &mut act,
        )?;
        anyhow::ensure!(labels.len() == n, "labels len {} vs batch {n}", labels.len());
        let (correct, loss, _) = softmax_xent(&logits, n, classes, labels, false);
        Ok(vec![Value::scalar(correct), Value::scalar(loss)])
    }
}

impl Executable for RefModelEval {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        let mut ws = self.arena.checkout(Workspace::new);
        let out = self.run_one(inputs, &mut ws);
        self.arena.give_back(ws);
        out
    }

    /// Independent batches fan out across the worker pool, each worker
    /// reusing one checked-out workspace for every batch it processes.
    /// Each batch runs the exact serial `run_one` and results come back in
    /// batch order, so output bytes match a serial `execute` loop at every
    /// thread count (enforced by `tests/determinism.rs`).
    fn execute_batch(&mut self, batches: &[Vec<&Value>]) -> anyhow::Result<Vec<Vec<Value>>> {
        let this = &*self;
        this.pool
            .run_indexed_scratch(batches.len(), &this.arena, Workspace::new, |ws, i| {
                this.run_one(&batches[i], ws)
            })
            .into_iter()
            .collect()
    }

    fn scratch_stats(&self) -> Option<ScratchStats> {
        let (f32_len, u32_len) = self
            .arena
            .peek(|ws| ws.iter().fold((0, 0), |(f, u), w| (f + w.f32_len(), u + w.u32_len())));
        Some(ScratchStats { workspaces: self.arena.created(), f32_len, u32_len })
    }
}

/// Parsed `{model}_train_{mode}` inputs.
type TrainInputs<'a> = (
    Vec<&'a Tensor>,
    Vec<&'a Tensor>,
    &'a Tensor,
    &'a [i32],
    &'a Tensor,
    &'a Tensor,
    f32,
);

fn parse_train_inputs<'a>(np: usize, inputs: &'a [&Value]) -> anyhow::Result<TrainInputs<'a>> {
    anyhow::ensure!(inputs.len() == 2 * np + 5, "train arity");
    let params: Vec<&Tensor> =
        inputs[..np].iter().map(|v| v.as_f32()).collect::<anyhow::Result<_>>()?;
    let momenta: Vec<&Tensor> =
        inputs[np..2 * np].iter().map(|v| v.as_f32()).collect::<anyhow::Result<_>>()?;
    let images = inputs[2 * np].as_f32()?;
    anyhow::ensure!(images.shape.len() == 4, "images must be NHWC");
    Ok((
        params,
        momenta,
        images,
        inputs[2 * np + 1].as_i32()?,
        inputs[2 * np + 2].as_f32()?,
        inputs[2 * np + 3].as_f32()?,
        inputs[2 * np + 4].scalar_f32()?,
    ))
}

pub struct RefModelTrain {
    pub graph: ModelGraph,
    pub binar: bool,
    /// Compiled train plan (rebuilt only when the batch size changes —
    /// effectively once, for the manifest's train batch).
    plan: Arc<Plan>,
    /// Reusable workspace; train executes serially, so one suffices.
    ws: Workspace,
}

impl RefModelTrain {
    pub fn new(graph: ModelGraph, binar: bool) -> RefModelTrain {
        let plan = Arc::new(compile_train(&graph, TRAIN_BATCH));
        RefModelTrain { graph, binar, plan, ws: Workspace::new() }
    }

    /// The PR 3 tree-walk train step — the semantic reference for
    /// `tests/plan_engine.rs`.
    pub fn run_walk(&self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        let np = self.graph.params.len();
        let (params, momenta, images, labels, wbits, abits, lr) =
            parse_train_inputs(np, inputs)?;
        let (logits, n, classes, tapes) = forward(
            &self.graph,
            &params,
            images,
            &wbits.data,
            &abits.data,
            self.binar,
            true,
            &mut ActMode::Dynamic,
        )?;
        anyhow::ensure!(labels.len() == n, "labels len {} vs batch {n}", labels.len());
        let (_, loss, dlogits) = softmax_xent(&logits, n, classes, labels, true);
        let grads = backward(
            &self.graph,
            &tapes.expect("train tape"),
            &params,
            dlogits.expect("train grad"),
            n,
            classes,
        );

        // SGD with momentum 0.9: new_m = 0.9·m + g, new_p = p − lr·new_m.
        let mut new_params = Vec::with_capacity(np);
        let mut new_momenta = Vec::with_capacity(np);
        for i in 0..np {
            let mut m = momenta[i].data.clone();
            for (mv, &gv) in m.iter_mut().zip(&grads[i]) {
                *mv = 0.9 * *mv + gv;
            }
            let mut p = params[i].data.clone();
            for (pv, &mv) in p.iter_mut().zip(&m) {
                *pv -= lr * mv;
            }
            new_params.push(Value::f32(params[i].shape.clone(), p));
            new_momenta.push(Value::f32(momenta[i].shape.clone(), m));
        }
        let mut outs = new_params;
        outs.extend(new_momenta);
        outs.push(Value::scalar(loss));
        Ok(outs)
    }
}

impl Executable for RefModelTrain {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        let np = self.graph.params.len();
        let (params, momenta, images, labels, wbits, abits, lr) =
            parse_train_inputs(np, inputs)?;
        if self.plan.batch() != images.shape[0] {
            self.plan = Arc::new(compile_train(&self.graph, images.shape[0]));
        }
        run_train(
            &self.plan,
            &self.graph,
            self.binar,
            &params,
            &momenta,
            images,
            labels,
            &wbits.data,
            &abits.data,
            lr,
            &mut self.ws,
        )
    }

    fn scratch_stats(&self) -> Option<ScratchStats> {
        Some(ScratchStats {
            workspaces: 1,
            f32_len: self.ws.f32_len(),
            u32_len: self.ws.u32_len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ParamStore;
    use crate::runtime::reference::zoo::{model_graph, IMAGE_HW};
    use crate::util::rng::Rng;

    fn tiny_images(n: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0f32; n * IMAGE_HW * IMAGE_HW * 3];
        rng.fill_normal_f32(&mut data, 0.5);
        Tensor::new(vec![n, IMAGE_HW, IMAGE_HW, 3], data)
    }

    fn graph_params(g: &ModelGraph, seed: u64) -> ParamStore {
        ParamStore::init(&g.params, &mut Rng::new(seed))
    }

    #[test]
    fn forward_shapes_for_every_model() {
        for name in crate::runtime::reference::zoo::MODEL_NAMES {
            let g = model_graph(name).unwrap();
            let ps = graph_params(&g, 3);
            let params: Vec<&Tensor> = ps.tensors.iter().collect();
            let images = tiny_images(2, 9);
            let wbits = vec![32.0f32; g.w_channels];
            let abits = vec![32.0f32; g.a_channels];
            let (logits, n, c, _) =
                forward(&g, &params, &images, &wbits, &abits, false, false, &mut ActMode::Dynamic)
                    .unwrap();
            assert_eq!(n, 2, "{name}");
            assert_eq!(c, 10, "{name}");
            assert_eq!(logits.len(), 20, "{name}");
            assert!(logits.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn pruned_everything_zeroes_logits() {
        // All weight channels pruned → logits reduce to biases (zeros at
        // init) for cif10's bias-free conv stack + zero-init fc bias.
        let g = model_graph("cif10").unwrap();
        let ps = graph_params(&g, 5);
        let params: Vec<&Tensor> = ps.tensors.iter().collect();
        let images = tiny_images(2, 1);
        let wbits = vec![0.0f32; g.w_channels];
        let abits = vec![32.0f32; g.a_channels];
        let (logits, ..) =
            forward(&g, &params, &images, &wbits, &abits, false, false, &mut ActMode::Dynamic)
                .unwrap();
        assert!(logits.iter().all(|&v| v.abs() < 1e-5), "{logits:?}");
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        // A few SGD steps on one small batch must reduce the loss — the
        // end-to-end check that backward matches forward.
        let g = model_graph("cif10").unwrap();
        let mut ps = graph_params(&g, 7);
        let mut momenta = ps.zeros_like();
        let n = 8;
        let images = tiny_images(n, 11);
        let labels: Vec<i32> = (0..n as i32).map(|i| i % 10).collect();
        let wbits = Value::f32(vec![g.w_channels], vec![32.0; g.w_channels]);
        let abits = Value::f32(vec![g.a_channels], vec![32.0; g.a_channels]);
        let img_v = Value::F32(images);
        let lbl_v = Value::i32(vec![n], labels);
        let lr = Value::scalar(0.05);
        let mut exe = RefModelTrain::new(g.clone(), false);
        let np = g.params.len();
        let mut losses = Vec::new();
        for _ in 0..6 {
            let mut inputs: Vec<Value> = Vec::with_capacity(2 * np + 5);
            for t in &ps.tensors {
                inputs.push(Value::F32(t.clone()));
            }
            for t in &momenta.tensors {
                inputs.push(Value::F32(t.clone()));
            }
            inputs.push(img_v.clone());
            inputs.push(lbl_v.clone());
            inputs.push(wbits.clone());
            inputs.push(abits.clone());
            inputs.push(lr.clone());
            let refs: Vec<&Value> = inputs.iter().collect();
            let outs = exe.execute(&refs).unwrap();
            assert_eq!(outs.len(), 2 * np + 1);
            losses.push(outs[2 * np].scalar_f32().unwrap());
            for i in 0..np {
                ps.tensors[i] = outs[i].as_f32().unwrap().clone();
                momenta.tensors[i] = outs[np + i].as_f32().unwrap().clone();
            }
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "loss did not drop: {losses:?}"
        );
        assert!(losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn eval_outputs_bounded() {
        let g = model_graph("cif10").unwrap();
        let ps = graph_params(&g, 13);
        let np = g.params.len();
        let n = 16;
        let images = tiny_images(n, 17);
        let labels: Vec<i32> = (0..n as i32).map(|i| i % 10).collect();
        let mut inputs: Vec<Value> = ps.tensors.iter().map(|t| Value::F32(t.clone())).collect();
        inputs.push(Value::F32(images));
        inputs.push(Value::i32(vec![n], labels));
        inputs.push(Value::f32(vec![g.w_channels], vec![4.0; g.w_channels]));
        inputs.push(Value::f32(vec![g.a_channels], vec![4.0; g.a_channels]));
        let refs: Vec<&Value> = inputs.iter().collect();
        let mut exe = RefModelEval::new(g, false, Arc::new(WorkerPool::new(1)));
        let outs = exe.execute(&refs).unwrap();
        assert_eq!(outs.len(), 2);
        let correct = outs[0].scalar_f32().unwrap();
        let loss = outs[1].scalar_f32().unwrap();
        assert!((0.0..=n as f32).contains(&correct));
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(inputs.len(), np + 4);
    }

    #[test]
    fn execute_batch_fans_out_bit_identically() {
        // Three distinct batches through a 1-thread and a 3-thread pool:
        // outputs must match the serial execute loop bit-for-bit and stay
        // in batch order.
        let g = model_graph("cif10").unwrap();
        let ps = graph_params(&g, 31);
        let base: Vec<Value> = ps.tensors.iter().map(|t| Value::F32(t.clone())).collect();
        let wbits = Value::f32(vec![g.w_channels], vec![5.0; g.w_channels]);
        let abits = Value::f32(vec![g.a_channels], vec![4.0; g.a_channels]);
        let n = 4;
        let batches_owned: Vec<(Value, Value)> = (0..3u64)
            .map(|bi| {
                let images = tiny_images(n, 100 + bi);
                let labels: Vec<i32> = (0..n as i32).map(|i| (i + bi as i32) % 10).collect();
                (Value::F32(images), Value::i32(vec![n], labels))
            })
            .collect();
        let batches: Vec<Vec<&Value>> = batches_owned
            .iter()
            .map(|(img, lbl)| {
                let mut row: Vec<&Value> = base.iter().collect();
                row.push(img);
                row.push(lbl);
                row.push(&wbits);
                row.push(&abits);
                row
            })
            .collect();
        let mut serial = RefModelEval::new(g.clone(), false, Arc::new(WorkerPool::new(1)));
        let mut parallel = RefModelEval::new(g, false, Arc::new(WorkerPool::new(3)));
        let expect: Vec<Vec<Value>> =
            batches.iter().map(|b| serial.execute(b).unwrap()).collect();
        for exe in [&mut serial, &mut parallel] {
            let outs = exe.execute_batch(&batches).unwrap();
            assert_eq!(outs.len(), 3);
            for (o, e) in outs.iter().zip(&expect) {
                let (oc, ec) =
                    (o[0].scalar_f32().unwrap(), e[0].scalar_f32().unwrap());
                let (ol, el) =
                    (o[1].scalar_f32().unwrap(), e[1].scalar_f32().unwrap());
                assert_eq!(oc.to_bits(), ec.to_bits());
                assert_eq!(ol.to_bits(), el.to_bits());
            }
        }
        // Distinct batches should actually differ (order is observable).
        let l0 = expect[0][1].scalar_f32().unwrap();
        let l1 = expect[1][1].scalar_f32().unwrap();
        assert_ne!(l0.to_bits(), l1.to_bits(), "batches too similar to detect reordering");
    }

    #[test]
    fn planned_eval_matches_walk_bitwise() {
        // Quick in-crate guard (full sweep lives in tests/plan_engine.rs):
        // the planned engine must reproduce the tree-walk to the bit.
        let g = model_graph("cif10").unwrap();
        let ps = graph_params(&g, 41);
        let n = 3;
        let mut inputs: Vec<Value> = ps.tensors.iter().map(|t| Value::F32(t.clone())).collect();
        inputs.push(Value::F32(tiny_images(n, 43)));
        inputs.push(Value::i32(vec![n], (0..n as i32).map(|i| i % 10).collect()));
        inputs.push(Value::f32(vec![g.w_channels], vec![5.0; g.w_channels]));
        inputs.push(Value::f32(vec![g.a_channels], vec![4.0; g.a_channels]));
        let refs: Vec<&Value> = inputs.iter().collect();
        let mut exe = RefModelEval::new(g, false, Arc::new(WorkerPool::new(1)));
        let planned = exe.execute(&refs).unwrap();
        let walk = exe.run_walk(&refs).unwrap();
        for (p, w) in planned.iter().zip(&walk) {
            assert_eq!(
                p.scalar_f32().unwrap().to_bits(),
                w.scalar_f32().unwrap().to_bits()
            );
        }
        // Second dispatch reuses the warm workspace with identical bytes.
        let again = exe.execute(&refs).unwrap();
        assert_eq!(again, planned);
        let stats = exe.scratch_stats().unwrap();
        assert_eq!(stats.workspaces, 1, "serial eval must reuse one workspace");
    }

    #[test]
    fn planned_train_matches_walk_bitwise() {
        let g = model_graph("cif10").unwrap();
        let ps = graph_params(&g, 47);
        let momenta = ps.zeros_like();
        let n = 2;
        let np = g.params.len();
        let mut inputs: Vec<Value> = Vec::with_capacity(2 * np + 5);
        inputs.extend(ps.tensors.iter().map(|t| Value::F32(t.clone())));
        inputs.extend(momenta.tensors.iter().map(|t| Value::F32(t.clone())));
        inputs.push(Value::F32(tiny_images(n, 53)));
        inputs.push(Value::i32(vec![n], (0..n as i32).map(|i| i % 10).collect()));
        inputs.push(Value::f32(vec![g.w_channels], vec![6.0; g.w_channels]));
        inputs.push(Value::f32(vec![g.a_channels], vec![5.0; g.a_channels]));
        inputs.push(Value::scalar(0.05));
        let refs: Vec<&Value> = inputs.iter().collect();
        let mut exe = RefModelTrain::new(g, false);
        let planned = exe.execute(&refs).unwrap();
        let walk = exe.run_walk(&refs).unwrap();
        assert_eq!(planned.len(), walk.len());
        for (i, (p, w)) in planned.iter().zip(&walk).enumerate() {
            let (pt, wt) = (p.as_f32().unwrap(), w.as_f32().unwrap());
            assert_eq!(pt.shape, wt.shape, "output {i}");
            for (a, b) in pt.data.iter().zip(&wt.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "output {i}");
            }
        }
    }

    #[test]
    fn binar_mode_forward_is_finite_on_all_models() {
        for name in crate::runtime::reference::zoo::MODEL_NAMES {
            let g = model_graph(name).unwrap();
            let ps = graph_params(&g, 23);
            let params: Vec<&Tensor> = ps.tensors.iter().collect();
            let images = tiny_images(2, 29);
            let wbits = vec![3.0f32; g.w_channels];
            let abits = vec![3.0f32; g.a_channels];
            let (logits, ..) =
                forward(&g, &params, &images, &wbits, &abits, true, false, &mut ActMode::Dynamic)
                    .unwrap();
            assert!(logits.iter().all(|v| v.is_finite()), "{name}");
        }
    }
}
