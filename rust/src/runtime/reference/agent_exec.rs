//! Reference interpreter for the DDPG artifacts (`ddpg_act_s{S}`,
//! `ddpg_update_s{S}`) — the actor/critic MLP graphs of
//! `python/compile/agent.py`: 2×300-unit ReLU hidden layers, sigmoid·32
//! actor head, fused TD(0) critic + deterministic-policy-gradient actor
//! update with Adam for both and τ-soft target updates.

use crate::runtime::backend::Executable;
use crate::runtime::reference::nn::{matmul_a_bt, matmul_at_b_acc, relu_bwd};
use crate::runtime::reference::zoo::ACTION_SCALE;
use crate::runtime::tensor::Tensor;
use crate::runtime::value::Value;

// Adam hyper-parameters (python `agent.py`).
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// One 3-layer MLP parameter view: [w1, b1, w2, b2, w3, b3].
struct Mlp<'a> {
    w1: &'a Tensor,
    b1: &'a Tensor,
    w2: &'a Tensor,
    b2: &'a Tensor,
    w3: &'a Tensor,
    b3: &'a Tensor,
}

impl<'a> Mlp<'a> {
    fn from(params: &[&'a Tensor]) -> anyhow::Result<Mlp<'a>> {
        anyhow::ensure!(params.len() == 6, "MLP needs 6 parameter tensors");
        Ok(Mlp {
            w1: params[0],
            b1: params[1],
            w2: params[2],
            b2: params[3],
            w3: params[4],
            b3: params[5],
        })
    }

    fn in_dim(&self) -> usize {
        self.w1.shape[0]
    }
    fn hidden(&self) -> usize {
        self.w1.shape[1]
    }
}

/// Forward cache for the backward pass: post-ReLU hiddens + linear output.
struct MlpCache {
    h1: Vec<f32>,
    h2: Vec<f32>,
    /// z = h2·w3 + b3, pre-head (B, 1).
    z: Vec<f32>,
}

/// x (B, in) → z (B, 1); `relu(x·w1+b1) → relu(·w2+b2) → ·w3+b3`.
fn mlp_forward(p: &Mlp, x: &[f32], b: usize) -> MlpCache {
    let (din, h) = (p.in_dim(), p.hidden());
    debug_assert_eq!(x.len(), b * din);
    let mut h1 = vec![0.0f32; b * h];
    for i in 0..b {
        h1[i * h..(i + 1) * h].copy_from_slice(&p.b1.data);
    }
    crate::runtime::reference::nn::matmul_acc(&mut h1, x, &p.w1.data, b, din, h);
    for v in h1.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    let mut h2 = vec![0.0f32; b * h];
    for i in 0..b {
        h2[i * h..(i + 1) * h].copy_from_slice(&p.b2.data);
    }
    crate::runtime::reference::nn::matmul_acc(&mut h2, &h1, &p.w2.data, b, h, h);
    for v in h2.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    let mut z = vec![0.0f32; b];
    for i in 0..b {
        let row = &h2[i * h..(i + 1) * h];
        let mut acc = p.b3.data[0];
        for (j, &v) in row.iter().enumerate() {
            acc += v * p.w3.data[j]; // w3 is (h, 1)
        }
        z[i] = acc;
    }
    MlpCache { h1, h2, z }
}

/// Backward through the MLP given dz (B, 1): returns param grads in
/// [w1, b1, w2, b2, w3, b3] order plus the input gradient (B, in).
fn mlp_backward(p: &Mlp, x: &[f32], b: usize, cache: &MlpCache, dz: &[f32]) -> (Vec<Vec<f32>>, Vec<f32>) {
    let (din, h) = (p.in_dim(), p.hidden());
    // Head: z = h2·w3 + b3.
    let mut dw3 = vec![0.0f32; h];
    let mut db3 = 0.0f32;
    let mut dh2 = vec![0.0f32; b * h];
    for i in 0..b {
        let g = dz[i];
        db3 += g;
        let h2row = &cache.h2[i * h..(i + 1) * h];
        let drow = &mut dh2[i * h..(i + 1) * h];
        for j in 0..h {
            dw3[j] += h2row[j] * g;
            drow[j] = p.w3.data[j] * g;
        }
    }
    relu_bwd(&mut dh2, &cache.h2);
    // Layer 2: h2 = relu(h1·w2 + b2).
    let mut dw2 = vec![0.0f32; h * h];
    matmul_at_b_acc(&mut dw2, &cache.h1, &dh2, b, h, h);
    let db2 = col_sums(&dh2, b, h);
    let mut dh1 = matmul_a_bt(&dh2, &p.w2.data, b, h, h);
    relu_bwd(&mut dh1, &cache.h1);
    // Layer 1: h1 = relu(x·w1 + b1).
    let mut dw1 = vec![0.0f32; din * h];
    matmul_at_b_acc(&mut dw1, x, &dh1, b, din, h);
    let db1 = col_sums(&dh1, b, h);
    let dx = matmul_a_bt(&dh1, &p.w1.data, b, h, din);
    (vec![dw1, db1, dw2, db2, dw3, vec![db3]], dx)
}

fn refs(ts: &[Tensor]) -> Vec<&Tensor> {
    ts.iter().collect()
}

fn col_sums(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; cols];
    for r in 0..rows {
        for c in 0..cols {
            out[c] += x[r * cols + c];
        }
    }
    out
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// μ(s) = sigmoid(z)·32 for each row; returns (actions, sigmoids).
fn actor_head(z: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let sig: Vec<f32> = z.iter().map(|&v| sigmoid(v)).collect();
    let act: Vec<f32> = sig.iter().map(|&s| s * ACTION_SCALE as f32).collect();
    (act, sig)
}

/// Critic input: concat(s, a/32) row-wise.
fn critic_input(s: &[f32], a: &[f32], b: usize, s_dim: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; b * (s_dim + 1)];
    for i in 0..b {
        x[i * (s_dim + 1)..i * (s_dim + 1) + s_dim]
            .copy_from_slice(&s[i * s_dim..(i + 1) * s_dim]);
        x[i * (s_dim + 1) + s_dim] = a[i] / ACTION_SCALE as f32;
    }
    x
}

// ---------------------------------------------------------------------------
// Executables
// ---------------------------------------------------------------------------

/// `ddpg_act_s{S}`: (actor(6), states (B, S)) → actions (B, 1) ∈ [0, 32].
pub struct RefDdpgAct {
    pub s_dim: usize,
}

impl Executable for RefDdpgAct {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        anyhow::ensure!(inputs.len() == 7, "act arity");
        let params: Vec<&Tensor> =
            inputs[..6].iter().map(|v| v.as_f32()).collect::<anyhow::Result<_>>()?;
        let actor = Mlp::from(&params)?;
        let states = inputs[6].as_f32()?;
        anyhow::ensure!(states.shape.len() == 2 && states.shape[1] == self.s_dim, "states shape");
        let b = states.shape[0];
        let cache = mlp_forward(&actor, &states.data, b);
        let (actions, _) = actor_head(&cache.z);
        Ok(vec![Value::f32(vec![b, 1], actions)])
    }
}

/// `ddpg_update_s{S}`: one fused off-policy step (python `update_fn`).
pub struct RefDdpgUpdate {
    pub s_dim: usize,
}

impl Executable for RefDdpgUpdate {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        anyhow::ensure!(inputs.len() == 58, "update arity");
        let mut i = 0usize;
        let mut take6 = |inputs: &[&Value]| -> anyhow::Result<Vec<Tensor>> {
            let out: anyhow::Result<Vec<Tensor>> =
                inputs[i..i + 6].iter().map(|v| Ok(v.as_f32()?.clone())).collect();
            i += 6;
            out
        };
        let actor = take6(inputs)?;
        let critic = take6(inputs)?;
        let t_actor = take6(inputs)?;
        let t_critic = take6(inputs)?;
        let m_a = take6(inputs)?;
        let v_a = take6(inputs)?;
        let m_c = take6(inputs)?;
        let v_c = take6(inputs)?;
        let t = inputs[i].scalar_f32()?;
        let s = inputs[i + 1].as_f32()?;
        let a = inputs[i + 2].as_f32()?;
        let r = inputs[i + 3].as_f32()?;
        let s2 = inputs[i + 4].as_f32()?;
        let done = inputs[i + 5].as_f32()?;
        let gamma = inputs[i + 6].scalar_f32()?;
        let tau = inputs[i + 7].scalar_f32()?;
        let lr_a = inputs[i + 8].scalar_f32()?;
        let lr_c = inputs[i + 9].scalar_f32()?;

        let s_dim = self.s_dim;
        let b = s.shape[0];
        anyhow::ensure!(s.shape == vec![b, s_dim] && s2.shape == vec![b, s_dim], "state shapes");
        anyhow::ensure!(a.data.len() == b && r.data.len() == b && done.data.len() == b, "batch");

        // --- critic target: r + γ(1−done)·Q'(s2, μ'(s2)), stop-gradient ----
        let ta = Mlp::from(&refs(&t_actor))?;
        let tc = Mlp::from(&refs(&t_critic))?;
        let c2 = mlp_forward(&ta, &s2.data, b);
        let (a2, _) = actor_head(&c2.z);
        let x2 = critic_input(&s2.data, &a2, b, s_dim);
        let q2 = mlp_forward(&tc, &x2, b).z;
        let q_tgt: Vec<f32> = (0..b)
            .map(|j| r.data[j] + gamma * (1.0 - done.data[j]) * q2[j])
            .collect();

        // --- critic: TD(0) regression --------------------------------------
        let cr = Mlp::from(&refs(&critic))?;
        let xc = critic_input(&s.data, &a.data, b, s_dim);
        let qc = mlp_forward(&cr, &xc, b);
        let closs = qc
            .z
            .iter()
            .zip(&q_tgt)
            .map(|(&q, &qt)| {
                let d = q - qt;
                (d * d) as f64
            })
            .sum::<f64>() as f32
            / b as f32;
        let dq: Vec<f32> = qc.z.iter().zip(&q_tgt).map(|(&q, &qt)| 2.0 * (q - qt) / b as f32).collect();
        let (cgrads, _) = mlp_backward(&cr, &xc, b, &qc, &dq);

        // --- actor: deterministic policy gradient through the critic -------
        let ac = Mlp::from(&refs(&actor))?;
        let pa = mlp_forward(&ac, &s.data, b);
        let (mu, sig) = actor_head(&pa.z);
        let xa = critic_input(&s.data, &mu, b, s_dim);
        let qa = mlp_forward(&cr, &xa, b);
        let aloss = -(qa.z.iter().map(|&q| q as f64).sum::<f64>() as f32) / b as f32;
        let dqa: Vec<f32> = vec![-1.0 / b as f32; b];
        let (_, dxa) = mlp_backward(&cr, &xa, b, &qa, &dqa);
        // d(action) = dx[:, s_dim] / 32; through sigmoid·32 head: ·32·σ(1−σ).
        let dz: Vec<f32> = (0..b)
            .map(|j| {
                let da = dxa[j * (s_dim + 1) + s_dim] / ACTION_SCALE as f32;
                da * ACTION_SCALE as f32 * sig[j] * (1.0 - sig[j])
            })
            .collect();
        let (agrads, _) = mlp_backward(&ac, &s.data, b, &pa, &dz);

        // --- Adam + soft target updates ------------------------------------
        let t1 = t + 1.0;
        let (new_critic, m_c, v_c) = adam(&critic, &cgrads, &m_c, &v_c, t1, lr_c);
        let (new_actor, m_a, v_a) = adam(&actor, &agrads, &m_a, &v_a, t1, lr_a);
        let new_t_actor = soft_update(&new_actor, &t_actor, tau);
        let new_t_critic = soft_update(&new_critic, &t_critic, tau);

        let mut outs: Vec<Value> = Vec::with_capacity(51);
        for group in [new_actor, new_critic, new_t_actor, new_t_critic, m_a, v_a, m_c, v_c] {
            for t in group {
                outs.push(Value::F32(t));
            }
        }
        outs.push(Value::scalar(t1));
        outs.push(Value::scalar(closs));
        outs.push(Value::scalar(aloss));
        Ok(outs)
    }
}

/// Bias-corrected Adam step (python `_adam`): returns (params, m, v).
fn adam(
    params: &[Tensor],
    grads: &[Vec<f32>],
    m: &[Tensor],
    v: &[Tensor],
    t1: f32,
    lr: f32,
) -> (Vec<Tensor>, Vec<Tensor>, Vec<Tensor>) {
    let bc1 = 1.0 - ADAM_B1.powf(t1);
    let bc2 = 1.0 - ADAM_B2.powf(t1);
    let mut new_p = Vec::with_capacity(params.len());
    let mut new_m = Vec::with_capacity(params.len());
    let mut new_v = Vec::with_capacity(params.len());
    for idx in 0..params.len() {
        let g = &grads[idx];
        let mut mi = m[idx].data.clone();
        let mut vi = v[idx].data.clone();
        let mut pi = params[idx].data.clone();
        for j in 0..pi.len() {
            mi[j] = ADAM_B1 * mi[j] + (1.0 - ADAM_B1) * g[j];
            vi[j] = ADAM_B2 * vi[j] + (1.0 - ADAM_B2) * g[j] * g[j];
            let mh = mi[j] / bc1;
            let vh = vi[j] / bc2;
            pi[j] -= lr * mh / (vh.sqrt() + ADAM_EPS);
        }
        new_p.push(Tensor::new(params[idx].shape.clone(), pi));
        new_m.push(Tensor::new(m[idx].shape.clone(), mi));
        new_v.push(Tensor::new(v[idx].shape.clone(), vi));
    }
    (new_p, new_m, new_v)
}

/// τ·p + (1−τ)·target, element-wise per tensor.
fn soft_update(p: &[Tensor], target: &[Tensor], tau: f32) -> Vec<Tensor> {
    p.iter()
        .zip(target)
        .map(|(pi, ti)| {
            let data: Vec<f32> =
                pi.data.iter().zip(&ti.data).map(|(&a, &b)| tau * a + (1.0 - tau) * b).collect();
            Tensor::new(pi.shape.clone(), data)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::zoo::{actor_shapes, critic_shapes, ACT_BATCH, UPD_BATCH};

    fn zeros_of(shapes: &[Vec<usize>]) -> Vec<Value> {
        shapes.iter().map(|s| Value::F32(Tensor::zeros(s.clone()))).collect()
    }

    #[test]
    fn zero_actor_emits_midrange_actions() {
        let mut exe = RefDdpgAct { s_dim: 16 };
        let mut inputs = zeros_of(&actor_shapes(16));
        inputs.push(Value::F32(Tensor::zeros(vec![ACT_BATCH, 16])));
        let refs: Vec<&Value> = inputs.iter().collect();
        let outs = exe.execute(&refs).unwrap();
        assert_eq!(outs.len(), 1);
        let a = outs[0].as_f32().unwrap();
        assert_eq!(a.shape, vec![ACT_BATCH, 1]);
        for &x in &a.data {
            assert!((x - 16.0).abs() < 1e-5, "sigmoid(0)·32 must be 16, got {x}");
        }
    }

    #[test]
    fn actions_stay_in_range_for_random_params() {
        let mut rng = crate::util::rng::Rng::new(3);
        let mut exe = RefDdpgAct { s_dim: 17 };
        let mut inputs: Vec<Value> = actor_shapes(17)
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s.clone());
                rng.fill_normal_f32(&mut t.data, 0.3);
                Value::F32(t)
            })
            .collect();
        let mut st = Tensor::zeros(vec![ACT_BATCH, 17]);
        rng.fill_normal_f32(&mut st.data, 1.0);
        inputs.push(Value::F32(st));
        let refs: Vec<&Value> = inputs.iter().collect();
        let outs = exe.execute(&refs).unwrap();
        for &x in &outs[0].as_f32().unwrap().data {
            assert!((0.0..=32.0).contains(&x));
        }
    }

    /// Build a full 58-input update call with small random nets.
    fn update_inputs(s_dim: usize, seed: u64) -> Vec<Value> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut mk = |shapes: &[Vec<usize>], sigma: f32| -> Vec<Value> {
            shapes
                .iter()
                .map(|s| {
                    let mut t = Tensor::zeros(s.clone());
                    if sigma > 0.0 {
                        rng.fill_normal_f32(&mut t.data, sigma);
                    }
                    Value::F32(t)
                })
                .collect()
        };
        let a6 = actor_shapes(s_dim);
        let c6 = critic_shapes(s_dim);
        let mut inputs = Vec::new();
        inputs.extend(mk(&a6, 0.1)); // actor
        inputs.extend(mk(&c6, 0.1)); // critic
        inputs.extend(mk(&a6, 0.1)); // target actor
        inputs.extend(mk(&c6, 0.1)); // target critic
        inputs.extend(mk(&a6, 0.0)); // m_a
        inputs.extend(mk(&a6, 0.0)); // v_a
        inputs.extend(mk(&c6, 0.0)); // m_c
        inputs.extend(mk(&c6, 0.0)); // v_c
        inputs.push(Value::scalar(0.0)); // t
        let b = UPD_BATCH;
        let mut s = Tensor::zeros(vec![b, s_dim]);
        rng.fill_normal_f32(&mut s.data, 0.5);
        inputs.push(Value::F32(s));
        let a = Tensor::full(vec![b, 1], 12.0);
        inputs.push(Value::F32(a));
        inputs.push(Value::F32(Tensor::full(vec![b, 1], 0.3))); // r
        let mut s2 = Tensor::zeros(vec![b, s_dim]);
        rng.fill_normal_f32(&mut s2.data, 0.5);
        inputs.push(Value::F32(s2));
        inputs.push(Value::F32(Tensor::zeros(vec![b, 1]))); // done
        inputs.push(Value::scalar(0.99)); // gamma
        inputs.push(Value::scalar(0.01)); // tau
        inputs.push(Value::scalar(1e-3)); // lr_a
        inputs.push(Value::scalar(1e-3)); // lr_c
        inputs
    }

    #[test]
    fn update_shapes_losses_and_time_counter() {
        let mut exe = RefDdpgUpdate { s_dim: 16 };
        let inputs = update_inputs(16, 5);
        let refs: Vec<&Value> = inputs.iter().collect();
        let outs = exe.execute(&refs).unwrap();
        assert_eq!(outs.len(), 51);
        assert_eq!(outs[48].scalar_f32().unwrap(), 1.0); // t+1
        let closs = outs[49].scalar_f32().unwrap();
        let aloss = outs[50].scalar_f32().unwrap();
        assert!(closs.is_finite() && closs >= 0.0);
        assert!(aloss.is_finite());
        // Output shapes mirror the input parameter shapes.
        for (j, v) in outs[..48].iter().enumerate() {
            assert_eq!(v.shape(), inputs[j].shape(), "output {j}");
        }
        // Parameters actually moved.
        let p0_in = inputs[0].as_f32().unwrap();
        let p0_out = outs[0].as_f32().unwrap();
        assert_ne!(p0_in.data, p0_out.data);
    }

    #[test]
    fn repeated_updates_reduce_critic_loss() {
        // Fixed batch, fixed target values → TD regression must descend.
        let mut exe = RefDdpgUpdate { s_dim: 16 };
        let mut inputs = update_inputs(16, 11);
        let mut losses = Vec::new();
        for _ in 0..30 {
            let refs: Vec<&Value> = inputs.iter().collect();
            let outs = exe.execute(&refs).unwrap();
            losses.push(outs[49].scalar_f32().unwrap());
            for (j, v) in outs.into_iter().take(49).enumerate() {
                inputs[j] = v; // feed nets, moments and t back in
            }
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "critic loss did not drop: first {} last {}",
            losses.first().unwrap(),
            losses.last().unwrap()
        );
    }

    #[test]
    fn soft_update_interpolates() {
        let p = vec![Tensor::full(vec![2], 1.0)];
        let t = vec![Tensor::full(vec![2], 0.0)];
        let out = soft_update(&p, &t, 0.25);
        assert_eq!(out[0].data, vec![0.25, 0.25]);
    }
}
