//! Reference interpreter for the DDPG artifacts (`ddpg_act_s{S}`,
//! `ddpg_update_s{S}`) — the actor/critic MLP graphs of
//! `python/compile/agent.py`: 2×300-unit ReLU hidden layers, sigmoid·32
//! actor head, fused TD(0) critic + deterministic-policy-gradient actor
//! update with Adam for both and τ-soft target updates.
//!
//! Both executables run through the planned-execution machinery
//! (`plan.rs`): the fixed MLP dataflow compiles at build time into a
//! [`Planner`]-assigned slot layout (released slots are recycled across
//! the update's three forward / three backward passes), and dispatch
//! executes against one reusable [`Workspace`] — steady-state calls
//! allocate only the returned output tensors.  The arithmetic and its
//! ordering are exactly the PR 3 walk's; skipping the walk's *discarded*
//! results (target-net hidden caches it never reread, input-gradients it
//! dropped, the 6 full parameter-set clones per call) is output-invariant.

use crate::runtime::backend::{Executable, ScratchStats};
use crate::runtime::reference::nn::{
    matmul_a_bt_into, matmul_acc_scratch, matmul_at_b_acc, matmul_panel_len, relu, relu_bwd,
};
use crate::runtime::reference::plan::{Planner, Slot, Workspace};
use crate::runtime::reference::zoo::ACTION_SCALE;
use crate::runtime::tensor::Tensor;
use crate::runtime::value::Value;

// Adam hyper-parameters (python `agent.py`).
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// One 3-layer MLP parameter view: [w1, b1, w2, b2, w3, b3].
struct Mlp<'a> {
    w1: &'a Tensor,
    b1: &'a Tensor,
    w2: &'a Tensor,
    b2: &'a Tensor,
    w3: &'a Tensor,
    b3: &'a Tensor,
}

impl<'a> Mlp<'a> {
    fn from(params: &[&'a Tensor]) -> anyhow::Result<Mlp<'a>> {
        anyhow::ensure!(params.len() == 6, "MLP needs 6 parameter tensors");
        Ok(Mlp {
            w1: params[0],
            b1: params[1],
            w2: params[2],
            b2: params[3],
            w3: params[4],
            b3: params[5],
        })
    }

    fn in_dim(&self) -> usize {
        self.w1.shape[0]
    }
    fn hidden(&self) -> usize {
        self.w1.shape[1]
    }

    /// Parameter element counts, [w1, b1, w2, b2, w3, b3] order.
    fn lens(&self) -> [usize; 6] {
        [
            self.w1.data.len(),
            self.b1.data.len(),
            self.w2.data.len(),
            self.b2.data.len(),
            self.w3.data.len(),
            self.b3.data.len(),
        ]
    }
}

/// Matmul packing scratch one MLP forward needs (max over its two
/// hidden-layer contractions).
fn mlp_panel_len(din: usize, h: usize) -> usize {
    matmul_panel_len(din, h).max(matmul_panel_len(h, h))
}

/// x (B, in) → z (B, 1) into caller slices (all fully overwritten):
/// `relu(x·w1+b1) → relu(·w2+b2) → ·w3+b3`.  `panel` is packing scratch
/// of ≥ [`mlp_panel_len`] elements.
fn mlp_forward_into(
    p: &Mlp,
    x: &[f32],
    b: usize,
    h1: &mut [f32],
    h2: &mut [f32],
    z: &mut [f32],
    panel: &mut [f32],
) {
    let (din, h) = (p.in_dim(), p.hidden());
    debug_assert_eq!(x.len(), b * din);
    debug_assert_eq!(h1.len(), b * h);
    debug_assert_eq!(h2.len(), b * h);
    debug_assert_eq!(z.len(), b);
    debug_assert!(panel.len() >= mlp_panel_len(din, h));
    for i in 0..b {
        h1[i * h..(i + 1) * h].copy_from_slice(&p.b1.data);
    }
    matmul_acc_scratch(h1, x, &p.w1.data, b, din, h, &mut panel[..matmul_panel_len(din, h)]);
    relu(h1);
    for i in 0..b {
        h2[i * h..(i + 1) * h].copy_from_slice(&p.b2.data);
    }
    matmul_acc_scratch(h2, h1, &p.w2.data, b, h, h, &mut panel[..matmul_panel_len(h, h)]);
    relu(h2);
    for i in 0..b {
        let row = &h2[i * h..(i + 1) * h];
        let mut acc = p.b3.data[0];
        for (j, &v) in row.iter().enumerate() {
            acc += v * p.w3.data[j]; // w3 is (h, 1)
        }
        z[i] = acc;
    }
}

/// Mutable views of one MLP's six gradient buffers.
struct MlpGrads<'a> {
    w1: &'a mut [f32],
    b1: &'a mut [f32],
    w2: &'a mut [f32],
    b2: &'a mut [f32],
    w3: &'a mut [f32],
    b3: &'a mut [f32],
}

/// Column sums of x (rows, cols) into `out` (zero-filled first).
fn col_sums_into(x: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), cols);
    out.fill(0.0);
    for r in 0..rows {
        for c in 0..cols {
            out[c] += x[r * cols + c];
        }
    }
}

/// Backward through the MLP given dz (B, 1): fills `g` with parameter
/// gradients and (when wanted) `dx` with the input gradient.  `dh1`/`dh2`
/// are (B, hidden) scratch; `h1`/`h2` are the forward's post-ReLU hiddens.
#[allow(clippy::too_many_arguments)]
fn mlp_backward_into(
    p: &Mlp,
    x: &[f32],
    b: usize,
    h1: &[f32],
    h2: &[f32],
    dz: &[f32],
    dh1: &mut [f32],
    dh2: &mut [f32],
    g: &mut MlpGrads<'_>,
    dx: Option<&mut [f32]>,
) {
    let (din, h) = (p.in_dim(), p.hidden());
    // Head: z = h2·w3 + b3.
    g.w3.fill(0.0);
    let mut db3 = 0.0f32;
    for i in 0..b {
        let gz = dz[i];
        db3 += gz;
        let h2row = &h2[i * h..(i + 1) * h];
        let drow = &mut dh2[i * h..(i + 1) * h];
        for j in 0..h {
            g.w3[j] += h2row[j] * gz;
            drow[j] = p.w3.data[j] * gz;
        }
    }
    g.b3[0] = db3;
    relu_bwd(dh2, h2);
    // Layer 2: h2 = relu(h1·w2 + b2).
    g.w2.fill(0.0);
    matmul_at_b_acc(g.w2, h1, dh2, b, h, h);
    col_sums_into(dh2, b, h, g.b2);
    matmul_a_bt_into(dh1, dh2, &p.w2.data, b, h, h);
    relu_bwd(dh1, h1);
    // Layer 1: h1 = relu(x·w1 + b1).
    g.w1.fill(0.0);
    matmul_at_b_acc(g.w1, x, dh1, b, din, h);
    col_sums_into(dh1, b, h, g.b1);
    if let Some(dx) = dx {
        matmul_a_bt_into(dx, dh1, &p.w1.data, b, h, din);
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// μ(s) = sigmoid(z)·32 per row, into `act` (and `sig` when kept for the
/// policy-gradient chain).
fn actor_head_into(z: &[f32], act: &mut [f32], mut sig: Option<&mut [f32]>) {
    for (j, &v) in z.iter().enumerate() {
        let s = sigmoid(v);
        if let Some(sig) = sig.as_mut() {
            sig[j] = s;
        }
        act[j] = s * ACTION_SCALE as f32;
    }
}

/// Borrow the next six parameter tensors from the input list.
fn take6<'a>(inputs: &'a [&'a Value], i: &mut usize) -> anyhow::Result<Vec<&'a Tensor>> {
    let out: anyhow::Result<Vec<&Tensor>> =
        inputs[*i..*i + 6].iter().map(|v| v.as_f32()).collect();
    *i += 6;
    out
}

/// Critic input: concat(s, a/32) row-wise into `x` (full overwrite).
fn critic_input_into(s: &[f32], a: &[f32], b: usize, s_dim: usize, x: &mut [f32]) {
    debug_assert_eq!(x.len(), b * (s_dim + 1));
    for i in 0..b {
        x[i * (s_dim + 1)..i * (s_dim + 1) + s_dim]
            .copy_from_slice(&s[i * s_dim..(i + 1) * s_dim]);
        x[i * (s_dim + 1) + s_dim] = a[i] / ACTION_SCALE as f32;
    }
}

// ---------------------------------------------------------------------------
// Executables
// ---------------------------------------------------------------------------

/// `ddpg_act_s{S}`: (actor(6), states (B, S)) → actions (B, 1) ∈ [0, 32].
///
/// Plan: four slots (h1, h2, z, packing panel), re-sized when the batch
/// **or the actor's hidden width** changes — keying on both keeps a
/// mismatched caller a clean re-plan, not an out-of-bounds index; the
/// output actions are written directly into the returned tensor.
pub struct RefDdpgAct {
    s_dim: usize,
    b: usize,
    h: usize,
    caps: Vec<usize>,
    ws: Workspace,
}

const ACT_H1: Slot = 0;
const ACT_H2: Slot = 1;
const ACT_Z: Slot = 2;
const ACT_PAN: Slot = 3;

fn act_caps(s_dim: usize, h: usize, b: usize) -> Vec<usize> {
    // max(1): a zero-capacity slot would trip the take-twice guard.
    vec![b * h, b * h, b, mlp_panel_len(s_dim, h).max(1)]
}

impl RefDdpgAct {
    pub fn new(s_dim: usize, hidden: usize, b: usize) -> RefDdpgAct {
        RefDdpgAct { s_dim, b, h: hidden, caps: act_caps(s_dim, hidden, b), ws: Workspace::new() }
    }
}

impl Executable for RefDdpgAct {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        anyhow::ensure!(inputs.len() == 7, "act arity");
        let params: Vec<&Tensor> =
            inputs[..6].iter().map(|v| v.as_f32()).collect::<anyhow::Result<_>>()?;
        let actor = Mlp::from(&params)?;
        let states = inputs[6].as_f32()?;
        anyhow::ensure!(states.shape.len() == 2 && states.shape[1] == self.s_dim, "states shape");
        anyhow::ensure!(actor.in_dim() == self.s_dim, "actor input dim");
        let b = states.shape[0];
        let h = actor.hidden();
        if b != self.b || h != self.h {
            self.b = b;
            self.h = h;
            self.caps = act_caps(self.s_dim, h, b);
        }
        self.ws.ensure_caps(&self.caps, &[]);
        let mut h1 = self.ws.take(ACT_H1);
        let mut h2 = self.ws.take(ACT_H2);
        let mut z = self.ws.take(ACT_Z);
        let mut pan = self.ws.take(ACT_PAN);
        mlp_forward_into(
            &actor,
            &states.data,
            b,
            &mut h1[..b * h],
            &mut h2[..b * h],
            &mut z[..b],
            &mut pan,
        );
        let mut actions = vec![0.0f32; b];
        actor_head_into(&z[..b], &mut actions, None);
        self.ws.put(ACT_H1, h1);
        self.ws.put(ACT_H2, h2);
        self.ws.put(ACT_Z, z);
        self.ws.put(ACT_PAN, pan);
        Ok(vec![Value::f32(vec![b, 1], actions)])
    }

    fn scratch_stats(&self) -> Option<ScratchStats> {
        let f32_len = self.ws.f32_len();
        Some(ScratchStats { workspaces: usize::from(f32_len > 0), f32_len, u32_len: 0 })
    }
}

/// Slot layout for one fused DDPG update, compiled by [`compile_update`].
/// Lifetimes follow the walk's dataflow; released slots are recycled by
/// the planner, so the whole update runs in a fraction of the buffers the
/// walk allocated.
struct UpdatePlan {
    b: usize,
    h: usize,
    caps: Vec<usize>,
    /// Matmul packing panel shared by all five MLP forwards.
    pan: Slot,
    // target critic path
    t_h1: Slot,
    t_h2: Slot,
    t_z: Slot,
    a2: Slot,
    x2: Slot,
    t2_h1: Slot,
    t2_h2: Slot,
    q2: Slot,
    q_tgt: Slot,
    // critic TD regression
    xc: Slot,
    qc_h1: Slot,
    qc_h2: Slot,
    qc_z: Slot,
    dq: Slot,
    dh1: Slot,
    dh2: Slot,
    cg: [Slot; 6],
    // actor policy gradient
    pa_h1: Slot,
    pa_h2: Slot,
    pa_z: Slot,
    sig: Slot,
    mu: Slot,
    xa: Slot,
    qa_h1: Slot,
    qa_h2: Slot,
    qa_z: Slot,
    dqa: Slot,
    sg: [Slot; 6],
    dxa: Slot,
    dz: Slot,
    ag: [Slot; 6],
}

/// Compile the update's slot layout for batch `b`.  Alloc/release order
/// mirrors `RefDdpgUpdate::execute` step for step — a slot is released
/// exactly when its last reader has run, never earlier.
fn compile_update(
    b: usize,
    h: usize,
    s_dim: usize,
    a_lens: [usize; 6],
    c_lens: [usize; 6],
) -> UpdatePlan {
    let mut p = Planner::new();
    let bh = b * h;
    let bs1 = b * (s_dim + 1);
    let alloc6 = |p: &mut Planner, lens: [usize; 6]| -> [Slot; 6] {
        [
            p.alloc(lens[0]),
            p.alloc(lens[1]),
            p.alloc(lens[2]),
            p.alloc(lens[3]),
            p.alloc(lens[4]),
            p.alloc(lens[5]),
        ]
    };
    // Packing panel for every MLP forward (actor nets read s_dim inputs,
    // critic nets s_dim+1); live until the last forward (Q(s, μ(s))).
    let pan = p.alloc(mlp_panel_len(s_dim, h).max(mlp_panel_len(s_dim + 1, h)).max(1));
    // 1. μ'(s2) through the target actor.
    let t_h1 = p.alloc(bh);
    let t_h2 = p.alloc(bh);
    let t_z = p.alloc(b);
    p.release(t_h1);
    p.release(t_h2);
    let a2 = p.alloc(b);
    p.release(t_z);
    // 2. Q'(s2, a2) through the target critic.
    let x2 = p.alloc(bs1);
    p.release(a2);
    let t2_h1 = p.alloc(bh);
    let t2_h2 = p.alloc(bh);
    let q2 = p.alloc(b);
    p.release(t2_h1);
    p.release(t2_h2);
    p.release(x2);
    let q_tgt = p.alloc(b);
    p.release(q2);
    // 3. Critic TD(0): forward + backward (cache and input live through
    //    the backward).
    let xc = p.alloc(bs1);
    let qc_h1 = p.alloc(bh);
    let qc_h2 = p.alloc(bh);
    let qc_z = p.alloc(b);
    let dq = p.alloc(b);
    p.release(q_tgt);
    let dh1 = p.alloc(bh);
    let dh2 = p.alloc(bh);
    let cg = alloc6(&mut p, c_lens);
    p.release(dq);
    p.release(qc_z);
    p.release(qc_h1);
    p.release(qc_h2);
    p.release(xc);
    // 4. Actor policy gradient: μ(s), Q(s, μ(s)), chain through the head.
    let pa_h1 = p.alloc(bh);
    let pa_h2 = p.alloc(bh);
    let pa_z = p.alloc(b);
    let sig = p.alloc(b);
    let mu = p.alloc(b);
    p.release(pa_z);
    let xa = p.alloc(bs1);
    p.release(mu);
    let qa_h1 = p.alloc(bh);
    let qa_h2 = p.alloc(bh);
    let qa_z = p.alloc(b);
    p.release(pan); // last MLP forward done
    let dqa = p.alloc(b);
    let sg = alloc6(&mut p, c_lens);
    let dxa = p.alloc(bs1);
    p.release(dqa);
    p.release(qa_z);
    p.release(qa_h1);
    p.release(qa_h2);
    p.release(xa);
    for s in sg {
        p.release(s);
    }
    let dz = p.alloc(b);
    p.release(sig);
    p.release(dxa);
    let ag = alloc6(&mut p, a_lens);
    p.release(dz);
    p.release(pa_h1);
    p.release(pa_h2);
    p.release(dh1);
    p.release(dh2);
    UpdatePlan {
        b,
        h,
        caps: p.finish(),
        pan,
        t_h1,
        t_h2,
        t_z,
        a2,
        x2,
        t2_h1,
        t2_h2,
        q2,
        q_tgt,
        xc,
        qc_h1,
        qc_h2,
        qc_z,
        dq,
        dh1,
        dh2,
        cg,
        pa_h1,
        pa_h2,
        pa_z,
        sig,
        mu,
        xa,
        qa_h1,
        qa_h2,
        qa_z,
        dqa,
        sg,
        dxa,
        dz,
        ag,
    }
}

/// `ddpg_update_s{S}`: one fused off-policy step (python `update_fn`).
pub struct RefDdpgUpdate {
    s_dim: usize,
    plan: Option<UpdatePlan>,
    ws: Workspace,
}

impl RefDdpgUpdate {
    pub fn new(s_dim: usize) -> RefDdpgUpdate {
        RefDdpgUpdate { s_dim, plan: None, ws: Workspace::new() }
    }
}

impl Executable for RefDdpgUpdate {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        anyhow::ensure!(inputs.len() == 58, "update arity");
        let mut i = 0usize;
        // Hold borrows — no parameter-set clones (the walk cloned all
        // eight 6-tensor groups per call).
        let actor = take6(inputs, &mut i)?;
        let critic = take6(inputs, &mut i)?;
        let t_actor = take6(inputs, &mut i)?;
        let t_critic = take6(inputs, &mut i)?;
        let m_a = take6(inputs, &mut i)?;
        let v_a = take6(inputs, &mut i)?;
        let m_c = take6(inputs, &mut i)?;
        let v_c = take6(inputs, &mut i)?;
        let t = inputs[i].scalar_f32()?;
        let s = inputs[i + 1].as_f32()?;
        let a = inputs[i + 2].as_f32()?;
        let r = inputs[i + 3].as_f32()?;
        let s2 = inputs[i + 4].as_f32()?;
        let done = inputs[i + 5].as_f32()?;
        let gamma = inputs[i + 6].scalar_f32()?;
        let tau = inputs[i + 7].scalar_f32()?;
        let lr_a = inputs[i + 8].scalar_f32()?;
        let lr_c = inputs[i + 9].scalar_f32()?;

        let s_dim = self.s_dim;
        let b = s.shape[0];
        anyhow::ensure!(s.shape == vec![b, s_dim] && s2.shape == vec![b, s_dim], "state shapes");
        anyhow::ensure!(a.data.len() == b && r.data.len() == b && done.data.len() == b, "batch");

        let ac = Mlp::from(&actor)?;
        let cr = Mlp::from(&critic)?;
        let ta = Mlp::from(&t_actor)?;
        let tc = Mlp::from(&t_critic)?;
        let h = ac.hidden();
        // Mismatched widths get a clean error here, never a slot overrun.
        anyhow::ensure!(
            cr.hidden() == h && ta.hidden() == h && tc.hidden() == h,
            "hidden width mismatch across actor/critic/target nets"
        );
        anyhow::ensure!(ac.in_dim() == s_dim && ta.in_dim() == s_dim, "actor input dim");
        anyhow::ensure!(
            cr.in_dim() == s_dim + 1 && tc.in_dim() == s_dim + 1,
            "critic input dim"
        );
        let bh = b * h;
        let bs1 = b * (s_dim + 1);
        if self.plan.as_ref().map(|p| (p.b, p.h)) != Some((b, h)) {
            self.plan = Some(compile_update(b, h, s_dim, ac.lens(), cr.lens()));
        }
        let plan = self.plan.as_ref().expect("compiled above");
        self.ws.ensure_caps(&plan.caps, &[]);
        let ws = &mut self.ws;

        // --- critic target: r + γ(1−done)·Q'(s2, μ'(s2)), stop-gradient ----
        let mut pan = ws.take(plan.pan);
        let mut h1 = ws.take(plan.t_h1);
        let mut h2 = ws.take(plan.t_h2);
        let mut z = ws.take(plan.t_z);
        mlp_forward_into(&ta, &s2.data, b, &mut h1[..bh], &mut h2[..bh], &mut z[..b], &mut pan);
        ws.put(plan.t_h1, h1);
        ws.put(plan.t_h2, h2);
        let mut a2 = ws.take(plan.a2);
        actor_head_into(&z[..b], &mut a2[..b], None);
        ws.put(plan.t_z, z);
        let mut x2 = ws.take(plan.x2);
        critic_input_into(&s2.data, &a2[..b], b, s_dim, &mut x2[..bs1]);
        ws.put(plan.a2, a2);
        let mut h1 = ws.take(plan.t2_h1);
        let mut h2 = ws.take(plan.t2_h2);
        let mut q2 = ws.take(plan.q2);
        mlp_forward_into(&tc, &x2[..bs1], b, &mut h1[..bh], &mut h2[..bh], &mut q2[..b], &mut pan);
        ws.put(plan.t2_h1, h1);
        ws.put(plan.t2_h2, h2);
        ws.put(plan.x2, x2);
        let mut q_tgt = ws.take(plan.q_tgt);
        for j in 0..b {
            q_tgt[j] = r.data[j] + gamma * (1.0 - done.data[j]) * q2[j];
        }
        ws.put(plan.q2, q2);

        // --- critic: TD(0) regression --------------------------------------
        let mut xc = ws.take(plan.xc);
        critic_input_into(&s.data, &a.data, b, s_dim, &mut xc[..bs1]);
        let mut qc_h1 = ws.take(plan.qc_h1);
        let mut qc_h2 = ws.take(plan.qc_h2);
        let mut qc_z = ws.take(plan.qc_z);
        mlp_forward_into(
            &cr,
            &xc[..bs1],
            b,
            &mut qc_h1[..bh],
            &mut qc_h2[..bh],
            &mut qc_z[..b],
            &mut pan,
        );
        let closs = qc_z[..b]
            .iter()
            .zip(&q_tgt[..b])
            .map(|(&q, &qt)| {
                let d = q - qt;
                (d * d) as f64
            })
            .sum::<f64>() as f32
            / b as f32;
        let mut dq = ws.take(plan.dq);
        for j in 0..b {
            dq[j] = 2.0 * (qc_z[j] - q_tgt[j]) / b as f32;
        }
        ws.put(plan.q_tgt, q_tgt);
        let mut dh1 = ws.take(plan.dh1);
        let mut dh2 = ws.take(plan.dh2);
        let c_lens = cr.lens();
        let mut cg_bufs: Vec<Vec<f32>> = plan.cg.iter().map(|&sl| ws.take(sl)).collect();
        {
            let [g0, g1, g2, g3, g4, g5] = &mut cg_bufs[..] else { unreachable!() };
            let mut grads = MlpGrads {
                w1: &mut g0[..c_lens[0]],
                b1: &mut g1[..c_lens[1]],
                w2: &mut g2[..c_lens[2]],
                b2: &mut g3[..c_lens[3]],
                w3: &mut g4[..c_lens[4]],
                b3: &mut g5[..c_lens[5]],
            };
            mlp_backward_into(
                &cr,
                &xc[..bs1],
                b,
                &qc_h1[..bh],
                &qc_h2[..bh],
                &dq[..b],
                &mut dh1[..bh],
                &mut dh2[..bh],
                &mut grads,
                None, // the walk discarded the critic-input gradient here
            );
        }
        ws.put(plan.dq, dq);
        ws.put(plan.qc_z, qc_z);
        ws.put(plan.qc_h1, qc_h1);
        ws.put(plan.qc_h2, qc_h2);
        ws.put(plan.xc, xc);

        // --- actor: deterministic policy gradient through the critic -------
        let mut pa_h1 = ws.take(plan.pa_h1);
        let mut pa_h2 = ws.take(plan.pa_h2);
        let mut pa_z = ws.take(plan.pa_z);
        mlp_forward_into(
            &ac,
            &s.data,
            b,
            &mut pa_h1[..bh],
            &mut pa_h2[..bh],
            &mut pa_z[..b],
            &mut pan,
        );
        let mut sig = ws.take(plan.sig);
        let mut mu = ws.take(plan.mu);
        actor_head_into(&pa_z[..b], &mut mu[..b], Some(&mut sig[..b]));
        ws.put(plan.pa_z, pa_z);
        let mut xa = ws.take(plan.xa);
        critic_input_into(&s.data, &mu[..b], b, s_dim, &mut xa[..bs1]);
        ws.put(plan.mu, mu);
        let mut qa_h1 = ws.take(plan.qa_h1);
        let mut qa_h2 = ws.take(plan.qa_h2);
        let mut qa_z = ws.take(plan.qa_z);
        mlp_forward_into(
            &cr,
            &xa[..bs1],
            b,
            &mut qa_h1[..bh],
            &mut qa_h2[..bh],
            &mut qa_z[..b],
            &mut pan,
        );
        ws.put(plan.pan, pan); // last MLP forward done
        let aloss = -(qa_z[..b].iter().map(|&q| q as f64).sum::<f64>() as f32) / b as f32;
        let mut dqa = ws.take(plan.dqa);
        dqa[..b].fill(-1.0 / b as f32);
        let mut sg_bufs: Vec<Vec<f32>> = plan.sg.iter().map(|&sl| ws.take(sl)).collect();
        let mut dxa = ws.take(plan.dxa);
        {
            let [g0, g1, g2, g3, g4, g5] = &mut sg_bufs[..] else { unreachable!() };
            let mut grads = MlpGrads {
                w1: &mut g0[..c_lens[0]],
                b1: &mut g1[..c_lens[1]],
                w2: &mut g2[..c_lens[2]],
                b2: &mut g3[..c_lens[3]],
                w3: &mut g4[..c_lens[4]],
                b3: &mut g5[..c_lens[5]],
            };
            mlp_backward_into(
                &cr,
                &xa[..bs1],
                b,
                &qa_h1[..bh],
                &qa_h2[..bh],
                &dqa[..b],
                &mut dh1[..bh],
                &mut dh2[..bh],
                &mut grads, // discarded — only dxa is consumed
                Some(&mut dxa[..bs1]),
            );
        }
        ws.put(plan.dqa, dqa);
        ws.put(plan.qa_z, qa_z);
        ws.put(plan.qa_h1, qa_h1);
        ws.put(plan.qa_h2, qa_h2);
        ws.put(plan.xa, xa);
        for (&sl, buf) in plan.sg.iter().zip(sg_bufs) {
            ws.put(sl, buf);
        }
        // d(action) = dx[:, s_dim] / 32; through sigmoid·32 head: ·32·σ(1−σ).
        let mut dz = ws.take(plan.dz);
        for j in 0..b {
            let da = dxa[j * (s_dim + 1) + s_dim] / ACTION_SCALE as f32;
            dz[j] = da * ACTION_SCALE as f32 * sig[j] * (1.0 - sig[j]);
        }
        ws.put(plan.sig, sig);
        ws.put(plan.dxa, dxa);
        let a_lens = ac.lens();
        let mut ag_bufs: Vec<Vec<f32>> = plan.ag.iter().map(|&sl| ws.take(sl)).collect();
        {
            let [g0, g1, g2, g3, g4, g5] = &mut ag_bufs[..] else { unreachable!() };
            let mut grads = MlpGrads {
                w1: &mut g0[..a_lens[0]],
                b1: &mut g1[..a_lens[1]],
                w2: &mut g2[..a_lens[2]],
                b2: &mut g3[..a_lens[3]],
                w3: &mut g4[..a_lens[4]],
                b3: &mut g5[..a_lens[5]],
            };
            mlp_backward_into(
                &ac,
                &s.data,
                b,
                &pa_h1[..bh],
                &pa_h2[..bh],
                &dz[..b],
                &mut dh1[..bh],
                &mut dh2[..bh],
                &mut grads,
                None, // the walk discarded the state gradient
            );
        }
        ws.put(plan.dz, dz);
        ws.put(plan.pa_h1, pa_h1);
        ws.put(plan.pa_h2, pa_h2);
        ws.put(plan.dh1, dh1);
        ws.put(plan.dh2, dh2);

        // --- Adam + soft target updates ------------------------------------
        let t1 = t + 1.0;
        let cg_slices: Vec<&[f32]> =
            cg_bufs.iter().zip(c_lens).map(|(buf, l)| &buf[..l]).collect();
        let ag_slices: Vec<&[f32]> =
            ag_bufs.iter().zip(a_lens).map(|(buf, l)| &buf[..l]).collect();
        let (new_critic, m_c, v_c) = adam(&critic, &cg_slices, &m_c, &v_c, t1, lr_c);
        let (new_actor, m_a, v_a) = adam(&actor, &ag_slices, &m_a, &v_a, t1, lr_a);
        for (&sl, buf) in plan.cg.iter().zip(cg_bufs) {
            ws.put(sl, buf);
        }
        for (&sl, buf) in plan.ag.iter().zip(ag_bufs) {
            ws.put(sl, buf);
        }
        let new_t_actor = soft_update(&new_actor, &t_actor, tau);
        let new_t_critic = soft_update(&new_critic, &t_critic, tau);

        let mut outs: Vec<Value> = Vec::with_capacity(51);
        for group in [new_actor, new_critic, new_t_actor, new_t_critic, m_a, v_a, m_c, v_c] {
            for t in group {
                outs.push(Value::F32(t));
            }
        }
        outs.push(Value::scalar(t1));
        outs.push(Value::scalar(closs));
        outs.push(Value::scalar(aloss));
        Ok(outs)
    }

    fn scratch_stats(&self) -> Option<ScratchStats> {
        let f32_len = self.ws.f32_len();
        Some(ScratchStats { workspaces: usize::from(f32_len > 0), f32_len, u32_len: 0 })
    }
}

/// Bias-corrected Adam step (python `_adam`): returns (params, m, v).
fn adam(
    params: &[&Tensor],
    grads: &[&[f32]],
    m: &[&Tensor],
    v: &[&Tensor],
    t1: f32,
    lr: f32,
) -> (Vec<Tensor>, Vec<Tensor>, Vec<Tensor>) {
    let bc1 = 1.0 - ADAM_B1.powf(t1);
    let bc2 = 1.0 - ADAM_B2.powf(t1);
    let mut new_p = Vec::with_capacity(params.len());
    let mut new_m = Vec::with_capacity(params.len());
    let mut new_v = Vec::with_capacity(params.len());
    for idx in 0..params.len() {
        let g = grads[idx];
        let mut mi = m[idx].data.clone();
        let mut vi = v[idx].data.clone();
        let mut pi = params[idx].data.clone();
        for j in 0..pi.len() {
            mi[j] = ADAM_B1 * mi[j] + (1.0 - ADAM_B1) * g[j];
            vi[j] = ADAM_B2 * vi[j] + (1.0 - ADAM_B2) * g[j] * g[j];
            let mh = mi[j] / bc1;
            let vh = vi[j] / bc2;
            pi[j] -= lr * mh / (vh.sqrt() + ADAM_EPS);
        }
        new_p.push(Tensor::new(params[idx].shape.clone(), pi));
        new_m.push(Tensor::new(m[idx].shape.clone(), mi));
        new_v.push(Tensor::new(v[idx].shape.clone(), vi));
    }
    (new_p, new_m, new_v)
}

/// τ·p + (1−τ)·target, element-wise per tensor.
fn soft_update(p: &[Tensor], target: &[&Tensor], tau: f32) -> Vec<Tensor> {
    p.iter()
        .zip(target)
        .map(|(pi, ti)| {
            let data: Vec<f32> =
                pi.data.iter().zip(&ti.data).map(|(&a, &b)| tau * a + (1.0 - tau) * b).collect();
            Tensor::new(pi.shape.clone(), data)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::zoo::{actor_shapes, critic_shapes, ACT_BATCH, HIDDEN, UPD_BATCH};

    fn zeros_of(shapes: &[Vec<usize>]) -> Vec<Value> {
        shapes.iter().map(|s| Value::F32(Tensor::zeros(s.clone()))).collect()
    }

    fn act_exe(s_dim: usize) -> RefDdpgAct {
        RefDdpgAct::new(s_dim, HIDDEN, ACT_BATCH)
    }

    #[test]
    fn zero_actor_emits_midrange_actions() {
        let mut exe = act_exe(16);
        let mut inputs = zeros_of(&actor_shapes(16));
        inputs.push(Value::F32(Tensor::zeros(vec![ACT_BATCH, 16])));
        let refs: Vec<&Value> = inputs.iter().collect();
        let outs = exe.execute(&refs).unwrap();
        assert_eq!(outs.len(), 1);
        let a = outs[0].as_f32().unwrap();
        assert_eq!(a.shape, vec![ACT_BATCH, 1]);
        for &x in &a.data {
            assert!((x - 16.0).abs() < 1e-5, "sigmoid(0)·32 must be 16, got {x}");
        }
    }

    #[test]
    fn actions_stay_in_range_for_random_params() {
        let mut rng = crate::util::rng::Rng::new(3);
        let mut exe = act_exe(17);
        let mut inputs: Vec<Value> = actor_shapes(17)
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s.clone());
                rng.fill_normal_f32(&mut t.data, 0.3);
                Value::F32(t)
            })
            .collect();
        let mut st = Tensor::zeros(vec![ACT_BATCH, 17]);
        rng.fill_normal_f32(&mut st.data, 1.0);
        inputs.push(Value::F32(st));
        let refs: Vec<&Value> = inputs.iter().collect();
        let outs = exe.execute(&refs).unwrap();
        for &x in &outs[0].as_f32().unwrap().data {
            assert!((0.0..=32.0).contains(&x));
        }
        // A second call with a smaller batch reuses the workspace.
        let mut small: Vec<Value> = inputs[..6].to_vec();
        small.push(Value::F32(Tensor::zeros(vec![4, 17])));
        let refs: Vec<&Value> = small.iter().collect();
        assert_eq!(exe.execute(&refs).unwrap()[0].shape(), &[4, 1]);
        assert_eq!(exe.scratch_stats().unwrap().workspaces, 1);
    }

    /// Build a full 58-input update call with small random nets.
    fn update_inputs(s_dim: usize, seed: u64) -> Vec<Value> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut mk = |shapes: &[Vec<usize>], sigma: f32| -> Vec<Value> {
            shapes
                .iter()
                .map(|s| {
                    let mut t = Tensor::zeros(s.clone());
                    if sigma > 0.0 {
                        rng.fill_normal_f32(&mut t.data, sigma);
                    }
                    Value::F32(t)
                })
                .collect()
        };
        let a6 = actor_shapes(s_dim);
        let c6 = critic_shapes(s_dim);
        let mut inputs = Vec::new();
        inputs.extend(mk(&a6, 0.1)); // actor
        inputs.extend(mk(&c6, 0.1)); // critic
        inputs.extend(mk(&a6, 0.1)); // target actor
        inputs.extend(mk(&c6, 0.1)); // target critic
        inputs.extend(mk(&a6, 0.0)); // m_a
        inputs.extend(mk(&a6, 0.0)); // v_a
        inputs.extend(mk(&c6, 0.0)); // m_c
        inputs.extend(mk(&c6, 0.0)); // v_c
        inputs.push(Value::scalar(0.0)); // t
        let b = UPD_BATCH;
        let mut s = Tensor::zeros(vec![b, s_dim]);
        rng.fill_normal_f32(&mut s.data, 0.5);
        inputs.push(Value::F32(s));
        let a = Tensor::full(vec![b, 1], 12.0);
        inputs.push(Value::F32(a));
        inputs.push(Value::F32(Tensor::full(vec![b, 1], 0.3))); // r
        let mut s2 = Tensor::zeros(vec![b, s_dim]);
        rng.fill_normal_f32(&mut s2.data, 0.5);
        inputs.push(Value::F32(s2));
        inputs.push(Value::F32(Tensor::zeros(vec![b, 1]))); // done
        inputs.push(Value::scalar(0.99)); // gamma
        inputs.push(Value::scalar(0.01)); // tau
        inputs.push(Value::scalar(1e-3)); // lr_a
        inputs.push(Value::scalar(1e-3)); // lr_c
        inputs
    }

    #[test]
    fn update_shapes_losses_and_time_counter() {
        let mut exe = RefDdpgUpdate::new(16);
        let inputs = update_inputs(16, 5);
        let refs: Vec<&Value> = inputs.iter().collect();
        let outs = exe.execute(&refs).unwrap();
        assert_eq!(outs.len(), 51);
        assert_eq!(outs[48].scalar_f32().unwrap(), 1.0); // t+1
        let closs = outs[49].scalar_f32().unwrap();
        let aloss = outs[50].scalar_f32().unwrap();
        assert!(closs.is_finite() && closs >= 0.0);
        assert!(aloss.is_finite());
        // Output shapes mirror the input parameter shapes.
        for (j, v) in outs[..48].iter().enumerate() {
            assert_eq!(v.shape(), inputs[j].shape(), "output {j}");
        }
        // Parameters actually moved.
        let p0_in = inputs[0].as_f32().unwrap();
        let p0_out = outs[0].as_f32().unwrap();
        assert_ne!(p0_in.data, p0_out.data);
    }

    #[test]
    fn repeated_updates_reduce_critic_loss_with_flat_workspace() {
        // Fixed batch, fixed target values → TD regression must descend;
        // the planned workspace must not grow after the first call.
        let mut exe = RefDdpgUpdate::new(16);
        let mut inputs = update_inputs(16, 11);
        let mut losses = Vec::new();
        let mut warm_len = 0usize;
        for step in 0..30 {
            let refs: Vec<&Value> = inputs.iter().collect();
            let outs = exe.execute(&refs).unwrap();
            let stats = exe.scratch_stats().unwrap();
            if step == 0 {
                warm_len = stats.f32_len;
                assert!(warm_len > 0);
            } else {
                assert_eq!(stats.f32_len, warm_len, "workspace grew at step {step}");
            }
            losses.push(outs[49].scalar_f32().unwrap());
            for (j, v) in outs.into_iter().take(49).enumerate() {
                inputs[j] = v; // feed nets, moments and t back in
            }
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "critic loss did not drop: first {} last {}",
            losses.first().unwrap(),
            losses.last().unwrap()
        );
    }

    #[test]
    fn soft_update_interpolates() {
        let p = vec![Tensor::full(vec![2], 1.0)];
        let t = Tensor::full(vec![2], 0.0);
        let out = soft_update(&p, &[&t], 0.25);
        assert_eq!(out[0].data, vec![0.25, 0.25]);
    }

    #[test]
    fn update_slot_plan_recycles_buffers() {
        // The planner must fold the update's ~40 virtual buffers onto far
        // fewer physical slots than a no-reuse layout would need.
        let b = UPD_BATCH;
        let a6: Vec<usize> = actor_shapes(16).iter().map(|s| s.iter().product()).collect();
        let c6: Vec<usize> = critic_shapes(16).iter().map(|s| s.iter().product()).collect();
        let plan = compile_update(
            b,
            HIDDEN,
            16,
            [a6[0], a6[1], a6[2], a6[3], a6[4], a6[5]],
            [c6[0], c6[1], c6[2], c6[3], c6[4], c6[5]],
        );
        let total: usize = plan.caps.len();
        assert!(total < 30, "expected heavy slot reuse, got {total} slots");
        // Against the no-reuse footprint (every virtual buffer distinct):
        // 5 MLP forward caches, dh scratch, three grad sets, three critic
        // inputs and the small b-sized vectors.
        let virtual_total: usize = 5 * (2 * b * HIDDEN + b)
            + 2 * b * HIDDEN
            + 2 * c6.iter().sum::<usize>()
            + a6.iter().sum::<usize>()
            + 3 * b * (16 + 1)
            + 8 * b;
        let planned: usize = plan.caps.iter().sum();
        assert!(planned < virtual_total, "planned {planned} vs no-reuse {virtual_total}");
    }
}
