//! Explicit SIMD lane loops for the blocked f32 kernels and the integer
//! GEMM inner products.
//!
//! # f32: [`axpy`]
//!
//! `c[j] += a * b[j]` over equal-length slices — the exact shape of the
//! inner j-loop the GEBP panels in `matmul.rs` are laid out for.  The
//! vectorized dimension indexes *independent* output elements, and each
//! element still sees exactly one IEEE multiply followed by one IEEE add
//! (`_mm256_mul_ps` + `_mm256_add_ps`, never an FMA), so the result is
//! bit-identical to the scalar loop — the naive kernels stay the oracle
//! and the existing `to_bits()` equality tests cover this path for free.
//! The reduction-form kernel `matmul_a_bt_into` is *not* routed through
//! here: its inner loop is the f32 accumulation itself, and vectorizing it
//! would reassociate the sum and break the determinism contract.
//!
//! # int8/int4: [`try_dot_i8`] / [`try_dot_i8_i4`]
//!
//! The qgemm inner loops are *integer* reductions with exact i32
//! accumulation, so — unlike the f32 reductions — any lane order computes
//! the same sum and vectorizing them is legal under the determinism
//! contract.  The AVX2 path widens with the classic sign-transfer
//! `maddubs` scheme: for 32 code pairs per iteration,
//!
//! ```text
//! abs_a = |a|                         (codes are clamped to ±127, so no
//!                                      −128 edge case)
//! sb    = sign(b, a)                  (b negated where a < 0, zeroed
//!                                      where a == 0 — the term is 0)
//! p16   = maddubs(abs_a, sb)          (u8×i8 pairs → i16, saturating)
//! p32   = madd(p16, 1)                (i16 pairs → exact i32)
//! ```
//!
//! Saturation in `maddubs` can never fire: |a|·|b| ≤ 127·127 = 16129 per
//! product, ≤ 32258 per pair sum — inside i16.  Every step is therefore
//! exact integer arithmetic and the result is **bit-identical to the
//! scalar loop by construction** (pinned by the unit tests below and by
//! `tests/int_kernels.rs` at the model level).  The int4 variant unpacks
//! 16 packed weight bytes into 32 sign-extended nibble codes in-register
//! (`(x ^ 8) − 8` bytewise) and feeds the same multiply-accumulate.
//!
//! The AVX paths are compiled behind the `simd` cargo feature (default-on)
//! and selected once per process by runtime CPU detection (AVX for `axpy`,
//! AVX2 for the int dots); everything else (feature off, non-x86, hosts
//! without the instruction set) takes the scalar loops in the callers.  A
//! process-wide switch ([`set_simd_int_enabled`]) additionally lets tests
//! and benches force the scalar int path to pin byte-equality and measure
//! the speedup.

use std::sync::atomic::{AtomicBool, Ordering};

static SIMD_INT: AtomicBool = AtomicBool::new(true);

/// Whether the SIMD integer inner loops may be dispatched (they also need
/// the `simd` feature and a runtime AVX2 host to actually run).
pub fn simd_int_enabled() -> bool {
    SIMD_INT.load(Ordering::Relaxed)
}

/// Flip SIMD integer-dot dispatch on/off (returns the previous value).
/// Results are bit-identical either way; benches use this to measure the
/// speedup and tests to pin the byte-equality.
pub fn set_simd_int_enabled(on: bool) -> bool {
    SIMD_INT.swap(on, Ordering::Relaxed)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::super::qgemm::{unpack4_hi, unpack4_lo};
    use std::arch::x86_64::{
        __m256i, _mm256_abs_epi8, _mm256_add_epi32, _mm256_add_ps, _mm256_and_si256,
        _mm256_castsi256_si128, _mm256_cvtepu8_epi16, _mm256_extracti128_si256,
        _mm256_loadu_ps, _mm256_loadu_si256, _mm256_madd_epi16, _mm256_maddubs_epi16,
        _mm256_mul_ps, _mm256_or_si256, _mm256_set1_epi16, _mm256_set1_epi8, _mm256_set1_ps,
        _mm256_setzero_si256, _mm256_sign_epi8, _mm256_slli_epi16, _mm256_storeu_ps,
        _mm256_sub_epi8, _mm256_xor_si256, _mm_add_epi32, _mm_cvtsi128_si32, _mm_loadu_si128,
        _mm_shuffle_epi32, _mm_unpackhi_epi64,
    };
    use std::sync::OnceLock;

    /// One-time AVX detection, cached for the life of the process.
    pub fn available() -> bool {
        static AVX: OnceLock<bool> = OnceLock::new();
        *AVX.get_or_init(|| std::arch::is_x86_feature_detected!("avx"))
    }

    /// One-time AVX2 detection (the int dots need the 256-bit integer ops).
    pub fn avx2_available() -> bool {
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }

    /// `c[j] += a * b[j]` in 8-wide AVX lanes, scalar tail.
    ///
    /// # Safety
    /// The caller must have verified AVX support (see [`available`]); slices
    /// must be equal length (checked by the safe wrapper).
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
        let n = c.len();
        let av = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + 8 <= n {
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            let cv = _mm256_loadu_ps(c.as_ptr().add(i));
            // mul then add as two rounded ops — keeps every element
            // bit-identical to the scalar `c[j] += a * b[j]`.
            _mm256_storeu_ps(c.as_mut_ptr().add(i), _mm256_add_ps(cv, _mm256_mul_ps(av, bv)));
            i += 8;
        }
        for j in i..n {
            c[j] += a * b[j];
        }
    }

    /// Horizontal sum of the 8 i32 lanes (exact integer adds).
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32(v: __m256i) -> i32 {
        let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
        let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01>(s));
        _mm_cvtsi128_si32(s)
    }

    /// Exact i32 dot product of two i8 slices, 32 codes per iteration via
    /// the sign-transfer `maddubs` scheme (module docs), scalar tail.
    ///
    /// # Safety
    /// The caller must have verified AVX2 support (see [`avx2_available`])
    /// and equal slice lengths; codes must lie in −127..=127 (the
    /// quantizers clamp there), which rules out both the `abs(−128)` edge
    /// and i16 saturation in `maddubs`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let mut acc = _mm256_setzero_si256();
        let ones = _mm256_set1_epi16(1);
        let n32 = n & !31;
        let mut i = 0usize;
        while i < n32 {
            let va = _mm256_loadu_si256(a.as_ptr().add(i).cast());
            let vb = _mm256_loadu_si256(b.as_ptr().add(i).cast());
            let abs_a = _mm256_abs_epi8(va);
            let sb = _mm256_sign_epi8(vb, va);
            let p16 = _mm256_maddubs_epi16(abs_a, sb);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(p16, ones));
            i += 32;
        }
        let mut sum = hsum_epi32(acc);
        for j in n32..n {
            sum += i32::from(*a.get_unchecked(j)) * i32::from(*b.get_unchecked(j));
        }
        sum
    }

    /// Exact i32 dot product of an i8 slice against a nibble-packed weight
    /// row of `k` codes: 16 packed bytes unpack to 32 sign-extended codes
    /// in-register per iteration, then the same `maddubs` path as
    /// [`dot_i8`]; scalar tail for the last `k mod 32` codes.
    ///
    /// # Safety
    /// Same contract as [`dot_i8`]; `a` holds `k` codes and `wp` at least
    /// `packed4_row_len(k)` bytes.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8_i4(a: &[i8], wp: &[i8], k: usize) -> i32 {
        let mut acc = _mm256_setzero_si256();
        let ones = _mm256_set1_epi16(1);
        let lo_mask = _mm256_set1_epi16(0x000f);
        let hi_mask = _mm256_set1_epi16(0x0f00);
        let sign = _mm256_set1_epi8(0x08);
        let k32 = k & !31;
        let mut i = 0usize;
        while i < k32 {
            // 16 packed bytes = 32 nibble codes; widening each byte to a
            // 16-bit lane lets one shift+mask pair place the low nibble in
            // the even output byte and the high nibble in the odd one —
            // exactly the packer's low-nibble-first code order.
            let p = _mm_loadu_si128(wp.as_ptr().add(i / 2).cast());
            let p16 = _mm256_cvtepu8_epi16(p);
            let lo = _mm256_and_si256(p16, lo_mask);
            let hi = _mm256_and_si256(_mm256_slli_epi16::<4>(p16), hi_mask);
            // Sign-extend nibbles bytewise: (x ^ 8) − 8 maps 0..15 → −8..7.
            let codes = _mm256_or_si256(lo, hi);
            let w = _mm256_sub_epi8(_mm256_xor_si256(codes, sign), sign);
            let va = _mm256_loadu_si256(a.as_ptr().add(i).cast());
            let abs_a = _mm256_abs_epi8(va);
            let sw = _mm256_sign_epi8(w, va);
            let p16m = _mm256_maddubs_epi16(abs_a, sw);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(p16m, ones));
            i += 32;
        }
        let mut sum = hsum_epi32(acc);
        let mut j = k32 / 2;
        while 2 * j + 1 < k {
            let byte = *wp.get_unchecked(j);
            sum += i32::from(*a.get_unchecked(2 * j)) * unpack4_lo(byte)
                + i32::from(*a.get_unchecked(2 * j + 1)) * unpack4_hi(byte);
            j += 1;
        }
        if k % 2 == 1 {
            sum += i32::from(*a.get_unchecked(k - 1)) * unpack4_lo(*wp.get_unchecked(k / 2));
        }
        sum
    }
}

/// `c[j] += a * b[j]` for equal-length slices, dispatched once per process
/// to the widest available implementation.  Bit-identical across all
/// implementations (see module docs).
#[inline]
pub fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if x86::available() {
        // SAFETY: AVX presence verified at runtime just above.
        unsafe { x86::axpy(c, a, b) };
        return;
    }
    for (cv, &bv) in c.iter_mut().zip(b) {
        *cv += a * bv;
    }
}

/// AVX2 i8·i8 dot product when the SIMD int path is on, available, and
/// enabled; `None` sends the caller to its scalar loop.  The value, when
/// present, is bit-identical to the scalar sum (exact i32, module docs).
#[inline]
pub fn try_dot_i8(a: &[i8], b: &[i8]) -> Option<i32> {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(a.iter().chain(b).all(|&v| v > i8::MIN), "codes must be clamped to ±127");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_int_enabled() && x86::avx2_available() {
        // SAFETY: AVX2 presence verified at runtime just above; code range
        // checked by the debug assertion (guaranteed by the quantizers).
        return Some(unsafe { x86::dot_i8(a, b) });
    }
    let _ = (a, b);
    None
}

/// AVX2 i8 · nibble-packed-i4 dot product ([`try_dot_i8`] semantics).
#[inline]
pub fn try_dot_i8_i4(a: &[i8], wp: &[i8], k: usize) -> Option<i32> {
    debug_assert_eq!(a.len(), k);
    debug_assert!(a.iter().all(|&v| v > i8::MIN), "codes must be clamped to ±127");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_int_enabled() && x86::avx2_available() {
        // SAFETY: AVX2 presence verified at runtime just above.
        return Some(unsafe { x86::dot_i8_i4(a, wp, k) });
    }
    let _ = (a, wp, k);
    None
}

#[cfg(test)]
mod tests {
    use super::super::qgemm::{pack_i4, packed4_row_len, unpack4_hi, unpack4_lo};
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn axpy_matches_scalar_bitwise() {
        let mut r = Rng::new(23);
        // Lengths straddling the 8-lane width, including the empty slice.
        for len in [0usize, 1, 7, 8, 9, 16, 31, 64, 100] {
            let mut b = vec![0.0f32; len];
            let mut c0 = vec![0.0f32; len];
            r.fill_normal_f32(&mut b, 1.0);
            r.fill_normal_f32(&mut c0, 1.0);
            let a = 0.37f32;
            let mut c1 = c0.clone();
            axpy(&mut c1, a, &b);
            for j in 0..len {
                let expect = c0[j] + a * b[j];
                assert_eq!(c1[j].to_bits(), expect.to_bits(), "len={len} j={j}");
            }
        }
    }

    fn codes(r: &mut Rng, len: usize, lim: i32) -> Vec<i8> {
        (0..len).map(|_| ((r.next_u64() % (2 * lim as u64 + 1)) as i32 - lim) as i8).collect()
    }

    #[test]
    fn simd_dot_i8_matches_scalar_exactly() {
        let mut r = Rng::new(29);
        // Lengths straddling the 32-lane width, including extremes that
        // would expose maddubs saturation if the exactness proof were off.
        for len in [0usize, 1, 15, 16, 31, 32, 33, 63, 64, 65, 100, 256, 1000] {
            let a = codes(&mut r, len, 127);
            let b = codes(&mut r, len, 127);
            let scalar: i32 =
                a.iter().zip(&b).map(|(&x, &y)| i32::from(x) * i32::from(y)).sum();
            if let Some(simd) = try_dot_i8(&a, &b) {
                assert_eq!(simd, scalar, "len={len}");
            }
        }
        // Worst-case magnitude rows: every pair sum hits ±32258.
        let a = vec![127i8; 640];
        let mut b = vec![127i8; 640];
        if let Some(simd) = try_dot_i8(&a, &b) {
            assert_eq!(simd, 640 * 16129);
        }
        for v in b.iter_mut() {
            *v = -127;
        }
        if let Some(simd) = try_dot_i8(&a, &b) {
            assert_eq!(simd, -640 * 16129);
        }
    }

    #[test]
    fn simd_dot_i8_i4_matches_scalar_exactly() {
        let mut r = Rng::new(31);
        for k in [0usize, 1, 2, 7, 15, 16, 31, 32, 33, 63, 64, 65, 100, 513] {
            let a = codes(&mut r, k, 127);
            let w = codes(&mut r, k, 7); // nibble range
            let mut wp = vec![0i8; packed4_row_len(k).max(1)];
            pack_i4(&w, k, 1, &mut wp);
            let mut scalar = 0i32;
            for (j, &x) in a.iter().enumerate() {
                let wc = if j % 2 == 0 { unpack4_lo(wp[j / 2]) } else { unpack4_hi(wp[j / 2]) };
                scalar += i32::from(x) * wc;
            }
            if let Some(simd) = try_dot_i8_i4(&a, &wp, k) {
                assert_eq!(simd, scalar, "k={k}");
            }
        }
    }

    #[test]
    fn simd_int_switch_forces_scalar_path() {
        let prev = set_simd_int_enabled(false);
        assert!(try_dot_i8(&[1, 2], &[3, 4]).is_none(), "switch off must decline");
        assert!(try_dot_i8_i4(&[1, 2], &[0x21], 2).is_none());
        set_simd_int_enabled(prev);
        // On AVX2 hosts the re-enabled path must come back (and still agree).
        if let Some(v) = try_dot_i8(&[1, 2], &[3, 4]) {
            assert_eq!(v, 11);
            assert!(prev, "default switch state is on");
        }
    }
}
