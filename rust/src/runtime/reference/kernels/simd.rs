//! Explicit SIMD lane loops for the blocked f32 kernels.
//!
//! One helper, [`axpy`]: `c[j] += a * b[j]` over equal-length slices — the
//! exact shape of the inner j-loop the GEBP panels in `matmul.rs` are laid
//! out for.  The vectorized dimension indexes *independent* output
//! elements, and each element still sees exactly one IEEE multiply followed
//! by one IEEE add (`_mm256_mul_ps` + `_mm256_add_ps`, never an FMA), so
//! the result is bit-identical to the scalar loop — the naive kernels stay
//! the oracle and the existing `to_bits()` equality tests cover this path
//! for free.
//!
//! The AVX path is compiled behind the `simd` cargo feature (default-on)
//! and selected once per process by runtime CPU detection; everything else
//! (feature off, non-x86, AVX-less hosts) takes the scalar loop.  The
//! reduction-form kernel `matmul_a_bt_into` is *not* routed through here:
//! its inner loop is the accumulation itself, and vectorizing it would
//! reassociate the sum and break the determinism contract.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };
    use std::sync::OnceLock;

    /// One-time AVX detection, cached for the life of the process.
    pub fn available() -> bool {
        static AVX: OnceLock<bool> = OnceLock::new();
        *AVX.get_or_init(|| std::arch::is_x86_feature_detected!("avx"))
    }

    /// `c[j] += a * b[j]` in 8-wide AVX lanes, scalar tail.
    ///
    /// # Safety
    /// The caller must have verified AVX support (see [`available`]); slices
    /// must be equal length (checked by the safe wrapper).
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
        let n = c.len();
        let av = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + 8 <= n {
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            let cv = _mm256_loadu_ps(c.as_ptr().add(i));
            // mul then add as two rounded ops — keeps every element
            // bit-identical to the scalar `c[j] += a * b[j]`.
            _mm256_storeu_ps(c.as_mut_ptr().add(i), _mm256_add_ps(cv, _mm256_mul_ps(av, bv)));
            i += 8;
        }
        for j in i..n {
            c[j] += a * b[j];
        }
    }
}

/// `c[j] += a * b[j]` for equal-length slices, dispatched once per process
/// to the widest available implementation.  Bit-identical across all
/// implementations (see module docs).
#[inline]
pub fn axpy(c: &mut [f32], a: f32, b: &[f32]) {
    debug_assert_eq!(c.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if x86::available() {
        // SAFETY: AVX presence verified at runtime just above.
        unsafe { x86::axpy(c, a, b) };
        return;
    }
    for (cv, &bv) in c.iter_mut().zip(b) {
        *cv += a * bv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn axpy_matches_scalar_bitwise() {
        let mut r = Rng::new(23);
        // Lengths straddling the 8-lane width, including the empty slice.
        for len in [0usize, 1, 7, 8, 9, 16, 31, 64, 100] {
            let mut b = vec![0.0f32; len];
            let mut c0 = vec![0.0f32; len];
            r.fill_normal_f32(&mut b, 1.0);
            r.fill_normal_f32(&mut c0, 1.0);
            let a = 0.37f32;
            let mut c1 = c0.clone();
            axpy(&mut c1, a, &b);
            for j in 0..len {
                let expect = c0[j] + a * b[j];
                assert_eq!(c1[j].to_bits(), expect.to_bits(), "len={len} j={j}");
            }
        }
    }
}
