//! The reference backend's compute kernels: packed, cache-blocked matmul
//! variants plus the im2col/col2im patch shuffles every convolution lowers
//! to.  `nn.rs` is layer logic over this API; nothing above the kernels
//! touches a raw triple loop.
//!
//! # Determinism contract
//!
//! Every kernel here is **bit-exact** against its naive reference
//! counterpart (`naive::*`): blocking only re-tiles the *independent* loop
//! dimensions, while the floating-point accumulation order of each output
//! element is left untouched (reduction index ascending, one `mul` + one
//! `add` per term, never fused or reassociated).  `tests/properties.rs`
//! enforces this across randomized shapes including edge tiles, and the
//! parallel batch executor above relies on it for byte-identical results
//! at every thread count.
//!
//! # Packing layout and tile sizes
//!
//! `matmul_acc` packs B into row-major `KC×NC` panels (`KC = 64` rows,
//! `NC = 128` columns → 32 KiB per panel, L1-resident) and streams every
//! row of A against the hot panel — the GEBP loop order `jc → pc → i`.
//! Packing is pure data movement; see DESIGN.md §Reference kernels.
//!
//! # Integer kernels
//!
//! `qgemm` executes low-bit layers in genuine int8/int4 arithmetic
//! (channel-major packed weights, per-row dynamic activation scales, exact
//! i32 accumulation, one f32 dequantize on store).  It has no f32 naive
//! twin — its oracle is the fake-quant f32 reference under a *proven
//! tolerance* rather than bit-equality, but its integer accumulation is
//! exact and therefore even more strongly deterministic than the f32
//! paths.  `simd` holds the runtime-dispatched AVX lane loop the blocked
//! f32 kernels share *and* the AVX2 `maddubs` widening integer dot
//! products the qgemm inner loops route through; both are bit-identical
//! to their scalar loops by construction (exact integer accumulation on
//! the int side).  See DESIGN.md §Integer kernels.

pub mod im2col;
pub mod matmul;
pub mod qgemm;
pub mod simd;

pub use im2col::{col2im_acc, im2col};
pub use matmul::{
    matmul, matmul_a_bt, matmul_a_bt_into, matmul_acc, matmul_acc_scratch, matmul_at_b_acc,
    matmul_panel_len, naive, KC, MC, NC,
};
pub use qgemm::{int_kernels_enabled, set_int_kernels_enabled, wrep, wrep_with, WRep};
pub use qgemm::{pack_i4, packed4_row_len, qgemm_i4, qgemm_i8, qgemm_into, qweight_len};
pub use qgemm::{quantize_rows_i8, quantize_rows_i8_static, quantize_w_i8, quantize_weights_alloc};
pub use qgemm::I8_LEVELS;
pub use simd::{axpy, set_simd_int_enabled, simd_int_enabled};
