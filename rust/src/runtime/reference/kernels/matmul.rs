//! Packed, cache-blocked matmul family behind one entry point per
//! contraction shape.  Each public kernel dispatches between a blocked
//! path (large operands) and the naive triple loop (small operands, panel
//! already L1-resident) — both produce bit-identical output because the
//! per-element accumulation order never changes (see module docs in
//! `kernels/mod.rs`).

/// Reduction-dimension rows per packed B panel (`matmul_acc`) and per C
/// tile (`matmul_at_b_acc`).
pub const KC: usize = 64;

/// Columns per packed B panel / C tile: `KC × NC` f32 = 32 KiB, sized to
/// stay L1-resident while every row of A streams against it.
pub const NC: usize = 128;

/// B-row chunk for the `a @ bᵀ` kernel: `MC` rows of B are reused across
/// all rows of A before moving on.
pub const MC: usize = 64;

/// The unblocked reference kernels.  These are the semantics: the blocked
/// paths above must match them bit-for-bit (`tests/properties.rs`), and
/// the bench compares throughput against them.
pub mod naive {
    /// c += a @ b for a (m,k), b (k,n), c (m,n).
    pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }

    /// c += aᵀ @ b for a (m,k), b (m,n), c (k,n).
    pub fn matmul_at_b_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        debug_assert_eq!(c.len(), k * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let crow = &mut c[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }

    /// a @ bᵀ into caller storage: full overwrite of c (m,k).
    pub fn matmul_a_bt_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
        debug_assert_eq!(a.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * k);
        for i in 0..m {
            let arow = &a[i * n..(i + 1) * n];
            let crow = &mut c[i * k..(i + 1) * k];
            for (kk, cv) in crow.iter_mut().enumerate() {
                let brow = &b[kk * n..(kk + 1) * n];
                let mut acc = 0.0f32;
                for j in 0..n {
                    acc += arow[j] * brow[j];
                }
                *cv = acc;
            }
        }
    }

    /// a @ bᵀ for a (m,n), b (k,n) → (m,k): rows of a dotted with rows of b.
    pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * k];
        matmul_a_bt_into(&mut c, a, b, m, n, k);
        c
    }
}

/// Packed-panel scratch length for [`matmul_acc_scratch`]'s blocked path
/// (0 when the shape dispatches to the naive loop and never packs).
pub fn matmul_panel_len(k: usize, n: usize) -> usize {
    if k <= KC && n <= NC {
        0
    } else {
        KC.min(k) * NC.min(n)
    }
}

/// c += a @ b for a (m,k), b (k,n), c (m,n), with caller-provided packing
/// scratch of [`matmul_panel_len`] elements (ignored on the naive path) —
/// the planned execution engine feeds a workspace slot here so the hot
/// path packs without allocating.
///
/// Blocked path (k or n beyond one panel): pack B into row-major `KC×NC`
/// panels and stream every A row against the hot panel (GEBP order
/// `jc → pc → i`).  For each element c\[i]\[j] the k-index still ascends
/// 0..k across panels, so the result is bit-identical to the naive loop
/// (every panel element read is written first — stale scratch is safe).
pub fn matmul_acc_scratch(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    packed: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if k <= KC && n <= NC {
        return naive::matmul_acc(c, a, b, m, k, n);
    }
    debug_assert_eq!(packed.len(), KC.min(k) * NC.min(n));
    let mut jc = 0;
    while jc < n {
        let nb = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kb = KC.min(k - pc);
            // Pack b[pc..pc+kb, jc..jc+nb] into a contiguous panel.
            for kk in 0..kb {
                let src = (pc + kk) * n + jc;
                packed[kk * nb..(kk + 1) * nb].copy_from_slice(&b[src..src + nb]);
            }
            let panel = &packed[..kb * nb];
            for i in 0..m {
                let arow = &a[i * k + pc..i * k + pc + kb];
                let crow = &mut c[i * n + jc..i * n + jc + nb];
                for (kk, &av) in arow.iter().enumerate() {
                    let brow = &panel[kk * nb..(kk + 1) * nb];
                    // j indexes independent output elements → SIMD lanes
                    // stay bit-identical to the scalar loop (simd.rs docs).
                    super::simd::axpy(crow, av, brow);
                }
            }
            pc += KC;
        }
        jc += NC;
    }
}

/// c += a @ b for a (m,k), b (k,n), c (m,n), allocating the packing panel
/// when the blocked path needs one.
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    let mut packed = vec![0.0f32; matmul_panel_len(k, n)];
    matmul_acc_scratch(c, a, b, m, k, n, &mut packed);
}

/// a @ b for a (m,k), b (k,n) → (m,n).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(&mut c, a, b, m, k, n);
    c
}

/// c += aᵀ @ b for a (m,k), b (m,n), c (k,n).
///
/// Blocked path: tile C into `KC×NC` blocks kept hot across the full
/// reduction sweep over i.  Per element the i-index still ascends 0..m, so
/// the result is bit-identical to the naive loop.
pub fn matmul_at_b_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    if k <= KC && n <= NC {
        return naive::matmul_at_b_acc(c, a, b, m, k, n);
    }
    let mut jc = 0;
    while jc < n {
        let nb = NC.min(n - jc);
        let mut kc = 0;
        while kc < k {
            let kb = KC.min(k - kc);
            for i in 0..m {
                let arow = &a[i * k + kc..i * k + kc + kb];
                let brow = &b[i * n + jc..i * n + jc + nb];
                for (kk, &av) in arow.iter().enumerate() {
                    let crow = &mut c[(kc + kk) * n + jc..(kc + kk) * n + jc + nb];
                    super::simd::axpy(crow, av, brow);
                }
            }
            kc += KC;
        }
        jc += NC;
    }
}

/// a @ bᵀ into caller storage (full overwrite of c (m,k)): rows of a
/// dotted with rows of b (k,n).
///
/// Blocked path: chunks of `MC` B-rows are reused across every A row
/// before the next chunk loads.  Each output element is one whole dot
/// product with j ascending, exactly as in the naive loop.
pub fn matmul_a_bt_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    if k <= MC {
        return naive::matmul_a_bt_into(c, a, b, m, n, k);
    }
    let mut kc = 0;
    while kc < k {
        let kb = MC.min(k - kc);
        for i in 0..m {
            let arow = &a[i * n..(i + 1) * n];
            let crow = &mut c[i * k + kc..i * k + kc + kb];
            for (kk, cv) in crow.iter_mut().enumerate() {
                let brow = &b[(kc + kk) * n..(kc + kk + 1) * n];
                let mut acc = 0.0f32;
                for j in 0..n {
                    acc += arow[j] * brow[j];
                }
                *cv = acc;
            }
        }
        kc += MC;
    }
}

/// a @ bᵀ for a (m,n), b (k,n) → (m,k), allocating the output.
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * k];
    matmul_a_bt_into(&mut c, a, b, m, n, k);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(r: &mut Rng, len: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        r.fill_normal_f32(&mut v, 1.0);
        v
    }

    /// Shapes that straddle every dispatch cutoff and tile edge.
    fn shapes() -> Vec<(usize, usize, usize)> {
        vec![
            (1, 1, 1),
            (3, 5, 2),
            (7, KC, NC),
            (4, KC + 1, NC + 1),
            (9, 2 * KC + 3, 5),
            (2, 5, 2 * NC + 7),
            (5, KC + 9, NC + 17),
            (3, MC + 2, MC + 2),
        ]
    }

    #[test]
    fn blocked_matmul_acc_is_bit_exact() {
        let mut r = Rng::new(11);
        for (m, k, n) in shapes() {
            let a = fill(&mut r, m * k);
            let b = fill(&mut r, k * n);
            let init = fill(&mut r, m * n);
            let mut c_blocked = init.clone();
            let mut c_naive = init;
            matmul_acc(&mut c_blocked, &a, &b, m, k, n);
            naive::matmul_acc(&mut c_naive, &a, &b, m, k, n);
            for (x, y) in c_blocked.iter().zip(&c_naive) {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn blocked_at_b_acc_is_bit_exact() {
        let mut r = Rng::new(13);
        for (m, k, n) in shapes() {
            let a = fill(&mut r, m * k);
            let b = fill(&mut r, m * n);
            let init = fill(&mut r, k * n);
            let mut c_blocked = init.clone();
            let mut c_naive = init;
            matmul_at_b_acc(&mut c_blocked, &a, &b, m, k, n);
            naive::matmul_at_b_acc(&mut c_naive, &a, &b, m, k, n);
            for (x, y) in c_blocked.iter().zip(&c_naive) {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn blocked_a_bt_is_bit_exact() {
        let mut r = Rng::new(17);
        for (m, n, k) in shapes() {
            let a = fill(&mut r, m * n);
            let b = fill(&mut r, k * n);
            let c_blocked = matmul_a_bt(&a, &b, m, n, k);
            let c_naive = naive::matmul_a_bt(&a, &b, m, n, k);
            for (x, y) in c_blocked.iter().zip(&c_naive) {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{n},{k})");
            }
        }
    }

    #[test]
    fn matmul_known_values() {
        // (2,3) @ (3,2) — the nn.rs identity, now owned by the kernels.
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        assert_eq!(matmul(&a, &b, 2, 3, 2), vec![58.0, 64.0, 139.0, 154.0]);
        let abt = matmul_a_bt(&a, &b, 2, 3, 2);
        assert_eq!(abt, vec![50.0, 68.0, 122.0, 167.0]);
    }
}
