//! True quantized integer GEMM: packed int8 (and bit-packed int4) kernels
//! that execute low-bit layers in genuine integer arithmetic instead of
//! round-tripping fake-quantized f32 through the f32 GEBP path.
//!
//! # Representation
//!
//! * **Weights** are quantized once per dispatch (the `WQ` plan step /
//!   walk preamble) from their row-major `(rest, cout)` parameter layout
//!   into **channel-major** `(cout, rest)` i8 codes plus one f32 scale per
//!   output channel.  The codes and scales come from the *exact* max-abs
//!   quantizer `quantize.rs::fake_quant_row` uses — same
//!   [`linear_levels`]/[`linear_scale`]/[`round_te`] recipe — so
//!   `code[c][r] as f32 * scale[c]` reproduces the fake-quant f32 weight
//!   bit-for-bit.  When every channel's rounded bit-width fits a signed
//!   nibble (≤ 4 → levels ≤ 7), rows are additionally **bit-packed two
//!   codes per byte** (low nibble first, odd tail zero-padded).
//! * **Activations** arrive already fake-quantized in f32 (their own
//!   per-channel grid lives on the reduction side of the contraction, so
//!   its scales cannot be hoisted out of an integer accumulator).  They
//!   are re-quantized **dynamically per row** — per sample / output pixel —
//!   onto a symmetric 127-level i8 grid: `sa[i] = max|row| / 127`.  This is
//!   the int path's only approximation and is what the tolerance contract
//!   below bounds.  Under calibrated **static** activation scales
//!   ([`quantize_rows_i8_static`], `--act-scales static`) the per-row
//!   max pass is replaced by one precomputed per-layer scale; rows whose
//!   max exceeds the calibrated one saturate at ±127, trading the strict
//!   per-element bound for a model-level agreement bound
//!   (`tests/act_scales.rs`).
//!
//! # Kernel shape
//!
//! Dot-product form with the weight matrix consumed in `MC`-row chunks
//! (the `matmul_a_bt_into` blocking — channel-major weights make each
//! output element one contiguous dot product):
//!
//! ```text
//! out[i][j] = (sa[i] * sw[j]) * Σ_k qa[i][k] · qw[j][k]     (i32 sum)
//! ```
//!
//! The i32 accumulation is **exact** (|q| ≤ 127 ⇒ |term| ≤ 16129, safe for
//! k up to ~133 000), therefore order-independent: the kernel is freely
//! tileable and byte-deterministic across thread counts, workers, and
//! hosts — the same determinism contract as the f32 kernels, with a
//! stronger proof.  A single f32 dequantize happens on store.
//!
//! # Tolerance contract (vs the fake-quant f32 reference)
//!
//! Let `A` be the fake-quantized f32 activations, `W` the fake-quantized
//! f32 weights, `ref = A @ Wᵀ` under sequential f32 accumulation, and
//! `int` this kernel's output.  Three error sources:
//!
//! 1. activation re-quantization: `|qa[i][k]·sa[i] − A[i][k]| ≤ sa[i]/2`
//!    (ties-to-even ≤ half step; the clamp at ±127 loses ≤ half a step
//!    because `|A| ≤ 127·sa` by construction), so ≤ `maxa_i / 254` with
//!    `maxa_i = max_k |A[i][k]|`;
//! 2. the f32 reference's own sequential rounding, standard `γ_k` bound
//!    `≈ k·2⁻²⁴` relative;
//! 3. the int path's dequantize store: one i32→f32 cast and two f32
//!    multiplies, ≤ 3 ulp relative.
//!
//! With `maxw_j = max_k |W[j][k]|` this gives the per-element bound
//!
//! ```text
//! |int[i][j] − ref[i][j]| ≤ k·maxa_i·maxw_j·(1/254 + (k + 4)·2⁻²³)
//! ```
//!
//! (the 2⁻²³ term doubles the γ_k estimate for slack).
//! `tests/int_kernels.rs` asserts exactly this bound across randomized
//! shapes, and pins model-level `EvalResult` agreement on the zoo.
//!
//! # Dispatch rule
//!
//! [`wrep`] — shared verbatim by the plan executor and the tree walk so
//! both backends pick the same representation: the int path runs only for
//! linear fake-quant (never binar, whose quantizer is not a uniform grid),
//! only on forward-only evaluation (training tapes need the f32 quantized
//! operands), and only when **every** channel's rounded weight bit-width
//! is ≤ 8 (≤ 4 selects the packed int4 form).  Everything else — including
//! passthrough (≥ 24 bit) and the 9..23-bit range — falls back to f32.
//! A process-wide switch (default: the `int-kernels` cargo feature) lets
//! tests force the f32 reference.

use crate::runtime::reference::quantize::{linear_levels, linear_scale, round_te};
use std::sync::atomic::{AtomicBool, Ordering};

use super::MC;

/// Positive levels of the dynamic per-row activation grid (i8 full range).
pub const I8_LEVELS: f32 = 127.0;

static INT_ENABLED: AtomicBool = AtomicBool::new(cfg!(feature = "int-kernels"));

/// Whether integer-kernel dispatch is enabled for this process.
pub fn int_kernels_enabled() -> bool {
    INT_ENABLED.load(Ordering::Relaxed)
}

/// Flip integer-kernel dispatch on/off (returns the previous value).
/// Tests use this to compute the forced-f32 reference; serialize tests
/// that touch it.
pub fn set_int_kernels_enabled(on: bool) -> bool {
    INT_ENABLED.swap(on, Ordering::Relaxed)
}

/// Weight representation chosen for one layer at dispatch time.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum WRep {
    /// Fake-quantized f32 through the f32 GEBP kernels (the reference).
    F32,
    /// Channel-major i8 codes, one byte per weight.
    I8,
    /// Channel-major signed-nibble codes, two weights per byte.
    I4,
}

/// The dispatch rule (module docs): pick the representation for a layer
/// from its per-channel weight bit-widths.  Identical on the plan and
/// tree-walk backends by construction — both call this.
pub fn wrep(wbits: &[f32], binar: bool) -> WRep {
    wrep_with(int_kernels_enabled(), wbits, binar)
}

/// [`wrep`] with the process switch passed explicitly (pure — testable
/// without mutating global state).
pub fn wrep_with(enabled: bool, wbits: &[f32], binar: bool) -> WRep {
    if binar || !enabled {
        return WRep::F32;
    }
    let mut max_b = 0.0f32;
    for &b in wbits {
        let r = round_te(b);
        if r > max_b {
            max_b = r;
        }
    }
    if max_b <= 4.0 {
        WRep::I4
    } else if max_b <= 8.0 {
        WRep::I8
    } else {
        WRep::F32
    }
}

/// Static-scale variant of [`quantize_rows_i8`]: every row shares one
/// precomputed calibration `scale` (`> 0`), so the max-abs pass over the
/// activation matrix disappears from the hot loop — codes come from a
/// single sweep.  Values beyond `127·scale` saturate at ±127; the
/// calibration pass picks `scale` from the per-layer max over the
/// calibration batches, so saturation only hits data outside the
/// calibrated range (the EvalResult agreement bound in
/// `tests/act_scales.rs` covers this).
pub fn quantize_rows_i8_static(
    a: &[f32],
    m: usize,
    k: usize,
    scale: f32,
    qa: &mut [i8],
    sa: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert!(qa.len() >= m * k);
    debug_assert!(sa.len() >= m);
    debug_assert!(scale > 0.0, "static activation scale must be positive");
    sa[..m].fill(scale);
    for (q, &x) in qa[..m * k].iter_mut().zip(a) {
        *q = round_te(x / scale).clamp(-I8_LEVELS, I8_LEVELS) as i8;
    }
}

/// Dynamic per-row symmetric i8 quantization of a row-major `(m, k)`
/// matrix: `qa[i*k + t] = round_te(a[i*k + t] / sa[i])` clamped to ±127,
/// `sa[i] = max|row i| / 127` (1.0 for an all-zero row, whose codes are
/// all zero regardless).  Fully overwrites the first `m*k` codes and `m`
/// scales.
pub fn quantize_rows_i8(a: &[f32], m: usize, k: usize, qa: &mut [i8], sa: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert!(qa.len() >= m * k);
    debug_assert!(sa.len() >= m);
    for i in 0..m {
        let row = &a[i * k..(i + 1) * k];
        let max_abs = row.iter().fold(0.0f32, |mx, &x| mx.max(x.abs()));
        let scale = linear_scale(max_abs, I8_LEVELS);
        sa[i] = scale;
        for (q, &x) in qa[i * k..(i + 1) * k].iter_mut().zip(row) {
            *q = round_te(x / scale).clamp(-I8_LEVELS, I8_LEVELS) as i8;
        }
    }
}

/// Per-output-channel symmetric int quantization of a row-major
/// `(rest, cout)` weight into channel-major `(cout, rest)` i8 codes plus
/// per-channel scales — the exact `fake_quant_row` grid (see module docs).
/// Rounded bits ≤ 0 prunes the channel (zero codes, zero scale); the
/// caller guarantees rounded bits ≤ 8 via [`wrep`].
pub fn quantize_w_i8(
    w: &[f32],
    rest: usize,
    cout: usize,
    bits: &[f32],
    q: &mut [i8],
    scales: &mut [f32],
) {
    debug_assert_eq!(w.len(), rest * cout);
    debug_assert_eq!(bits.len(), cout);
    debug_assert!(q.len() >= rest * cout);
    debug_assert!(scales.len() >= cout);
    for co in 0..cout {
        let b = round_te(bits[co]);
        debug_assert!(b <= 8.0, "int path dispatched with {b} rounded bits");
        let qrow = &mut q[co * rest..(co + 1) * rest];
        if b <= 0.0 {
            qrow.fill(0);
            scales[co] = 0.0;
            continue;
        }
        let levels = linear_levels(b);
        let mut max_abs = 0.0f32;
        for r in 0..rest {
            max_abs = max_abs.max(w[r * cout + co].abs());
        }
        let scale = linear_scale(max_abs, levels);
        scales[co] = scale;
        for (r, qv) in qrow.iter_mut().enumerate() {
            *qv = round_te(w[r * cout + co] / scale).clamp(-levels, levels) as i8;
        }
    }
}

/// Bytes per int4-packed channel row of `rest` codes.
pub fn packed4_row_len(rest: usize) -> usize {
    rest.div_ceil(2)
}

/// Bit-pack signed-nibble codes (each in −7..=7) two per byte along the
/// reduction dimension: channel row `co` occupies [`packed4_row_len`]
/// bytes from `co * packed4_row_len(rest)`, low nibble first, odd tail
/// padded with a zero nibble.
pub fn pack_i4(q: &[i8], rest: usize, cout: usize, out: &mut [i8]) {
    let prow = packed4_row_len(rest);
    debug_assert!(q.len() >= rest * cout);
    debug_assert!(out.len() >= prow * cout);
    for co in 0..cout {
        let src = &q[co * rest..(co + 1) * rest];
        let dst = &mut out[co * prow..(co + 1) * prow];
        for (byte, pair) in dst.iter_mut().zip(src.chunks(2)) {
            debug_assert!(pair.iter().all(|&v| (-7..=7).contains(&v)));
            let lo = (pair[0] as u8) & 0x0f;
            let hi = if pair.len() > 1 { (pair[1] as u8) & 0x0f } else { 0 };
            *byte = (lo | (hi << 4)) as i8;
        }
    }
}

/// Sign-extend the low nibble of a packed byte.
#[inline]
pub fn unpack4_lo(b: i8) -> i32 {
    ((((b as u8) << 4) as i8) >> 4) as i32
}

/// Sign-extend the high nibble of a packed byte.
#[inline]
pub fn unpack4_hi(b: i8) -> i32 {
    (b >> 4) as i32
}

/// Exact i32 dot product of two i8 slices: the explicit AVX2 `maddubs`
/// path when available (`simd.rs` — bit-identical by exactness), else a
/// scalar loop whose fixed-width 16-lane inner chunks give LLVM a clean
/// widen-multiply-accumulate shape to vectorize.
#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    if let Some(acc) = super::simd::try_dot_i8(a, b) {
        return acc;
    }
    let mut acc = 0i32;
    let mut ca = a.chunks_exact(16);
    let mut cb = b.chunks_exact(16);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        let mut s = 0i32;
        for (&x, &y) in xa.iter().zip(xb) {
            s += i32::from(x) * i32::from(y);
        }
        acc += s;
    }
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        acc += i32::from(x) * i32::from(y);
    }
    acc
}

/// Exact i32 dot product of an i8 slice against a nibble-packed row of
/// `k` codes, unpacking on the fly (in-register on the AVX2 path).
#[inline]
fn dot_i8_i4(a: &[i8], wp: &[i8], k: usize) -> i32 {
    debug_assert_eq!(a.len(), k);
    debug_assert!(wp.len() >= packed4_row_len(k));
    if let Some(acc) = super::simd::try_dot_i8_i4(a, wp, k) {
        return acc;
    }
    let mut acc = 0i32;
    for (&byte, pair) in wp.iter().zip(a.chunks_exact(2)) {
        acc += i32::from(pair[0]) * unpack4_lo(byte) + i32::from(pair[1]) * unpack4_hi(byte);
    }
    if k % 2 == 1 {
        acc += i32::from(a[k - 1]) * unpack4_lo(wp[k / 2]);
    }
    acc
}

/// `out = dequant(QA @ QWᵀ)` for i8 activations `qa` (row-major `(m, k)`,
/// per-row scales `sa`) against i8 weights `qw` (channel-major `(n, k)`,
/// per-channel scales `sw`).  Full overwrite of `out` (`m × n`, row-major);
/// exact i32 accumulation, one f32 dequantize per element (module docs).
pub fn qgemm_i8(
    out: &mut [f32],
    qa: &[i8],
    sa: &[f32],
    qw: &[i8],
    sw: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert!(qa.len() >= m * k);
    debug_assert!(sa.len() >= m);
    debug_assert!(qw.len() >= n * k);
    debug_assert!(sw.len() >= n);
    debug_assert!(out.len() >= m * n);
    debug_assert!(k as u64 * 16129 <= i32::MAX as u64, "k too large for exact i32 accumulation");
    let mut jc = 0;
    while jc < n {
        // MC weight rows stay hot across every activation row (the
        // matmul_a_bt_into chunking — exactness makes re-tiling free).
        let jb = MC.min(n - jc);
        for i in 0..m {
            let arow = &qa[i * k..(i + 1) * k];
            let si = sa[i];
            let orow = &mut out[i * n + jc..i * n + jc + jb];
            for (jj, o) in orow.iter_mut().enumerate() {
                let j = jc + jj;
                let acc = dot_i8(arow, &qw[j * k..(j + 1) * k]);
                *o = acc as f32 * (si * sw[j]);
            }
        }
        jc += MC;
    }
}

/// [`qgemm_i8`] with nibble-packed weights (`qwp`: channel-major, each row
/// [`packed4_row_len`]`(k)` bytes).
pub fn qgemm_i4(
    out: &mut [f32],
    qa: &[i8],
    sa: &[f32],
    qwp: &[i8],
    sw: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let prow = packed4_row_len(k);
    debug_assert!(qa.len() >= m * k);
    debug_assert!(sa.len() >= m);
    debug_assert!(qwp.len() >= n * prow);
    debug_assert!(sw.len() >= n);
    debug_assert!(out.len() >= m * n);
    let mut jc = 0;
    while jc < n {
        let jb = MC.min(n - jc);
        for i in 0..m {
            let arow = &qa[i * k..(i + 1) * k];
            let si = sa[i];
            let orow = &mut out[i * n + jc..i * n + jc + jb];
            for (jj, o) in orow.iter_mut().enumerate() {
                let j = jc + jj;
                let acc = dot_i8_i4(arow, &qwp[j * prow..(j + 1) * prow], k);
                *o = acc as f32 * (si * sw[j]);
            }
        }
        jc += MC;
    }
}

/// Representation-dispatching GEMM: `i4` selects the nibble-packed weight
/// kernel.  One call site shape for the plan executor and layer helpers.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_into(
    out: &mut [f32],
    qa: &[i8],
    sa: &[f32],
    qw: &[i8],
    sw: &[f32],
    m: usize,
    k: usize,
    n: usize,
    i4: bool,
) {
    if i4 {
        qgemm_i4(out, qa, sa, qw, sw, m, k, n);
    } else {
        qgemm_i8(out, qa, sa, qw, sw, m, k, n);
    }
}

/// Number of i8 bytes the quantized weight of a layer occupies under
/// `rep`: full codes for I8, nibble-packed rows for I4.
pub fn qweight_len(rest: usize, cout: usize, rep: WRep) -> usize {
    match rep {
        WRep::I4 => packed4_row_len(rest) * cout,
        _ => rest * cout,
    }
}

/// Allocating weight quantizer for the tree-walk backend: row-major
/// `(rest, cout)` f32 → (channel-major codes — packed iff `rep == I4` —
/// and per-channel scales).
pub fn quantize_weights_alloc(
    w: &[f32],
    rest: usize,
    cout: usize,
    bits: &[f32],
    rep: WRep,
) -> (Vec<i8>, Vec<f32>) {
    let mut q = vec![0i8; rest * cout];
    let mut scales = vec![0.0f32; cout];
    quantize_w_i8(w, rest, cout, bits, &mut q, &mut scales);
    if rep == WRep::I4 {
        let mut packed = vec![0i8; packed4_row_len(rest) * cout];
        pack_i4(&q, rest, cout, &mut packed);
        return (packed, scales);
    }
    (q, scales)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nibble_pack_roundtrips() {
        let rest = 5; // odd → zero-padded tail nibble
        let cout = 3;
        let codes: Vec<i8> = vec![-7, -1, 0, 3, 7, 1, -2, 5, -6, 0, 7, -7, 2, -3, 4];
        let mut packed = vec![0i8; packed4_row_len(rest) * cout];
        pack_i4(&codes, rest, cout, &mut packed);
        let prow = packed4_row_len(rest);
        for co in 0..cout {
            for r in 0..rest {
                let byte = packed[co * prow + r / 2];
                let got = if r % 2 == 0 { unpack4_lo(byte) } else { unpack4_hi(byte) };
                assert_eq!(got, i32::from(codes[co * rest + r]), "co={co} r={r}");
            }
            // Padded tail nibble decodes to zero.
            assert_eq!(unpack4_hi(packed[co * prow + prow - 1]), 0);
        }
    }

    #[test]
    fn int8_gemm_known_values() {
        // Power-of-two scales on both sides make every dequantize exact,
        // so the expected outputs are reachable by hand.
        let a = vec![127.0f32, -127.0, 254.0, 127.0]; // (2, 2): sa = [1, 2]
        let mut qa = vec![0i8; 4];
        let mut sa = vec![0.0f32; 2];
        quantize_rows_i8(&a, 2, 2, &mut qa, &mut sa);
        assert_eq!(sa, vec![1.0, 2.0]);
        // 127/2 = 63.5 rounds ties-to-even → 64.
        assert_eq!(qa, vec![127, -127, 127, 64]);
        // 1-bit channels: scale = channel max-abs → [0.5, 2], codes ±1.
        let w = vec![0.5f32, -2.0, -0.5, 2.0]; // row-major (rest=2, cout=2)
        let (qw, sw) = quantize_weights_alloc(&w, 2, 2, &[1.0, 1.0], WRep::I8);
        assert_eq!(sw, vec![0.5, 2.0]);
        assert_eq!(qw, vec![1, -1, -1, 1]); // channel-major
        let mut out = vec![0.0f32; 4];
        qgemm_i8(&mut out, &qa, &sa, &qw, &sw, 2, 2, 2);
        // out[i][j] = sa_i·sw_j·Σ qa·qw, exact at every step:
        // [1·0.5·254, 1·2·(−254), 2·0.5·63, 2·2·(−63)]
        assert_eq!(out, vec![127.0, -508.0, 63.0, -252.0]);
    }

    #[test]
    fn pruned_and_zero_channels_are_exact_zero() {
        let a = vec![0.5f32, -0.25, 0.0, 0.0]; // row 1 all-zero
        let w = vec![0.3f32, 0.0, -0.7, 0.0]; // channel 1 all-zero
        let mut qa = vec![0i8; 4];
        let mut sa = vec![0.0f32; 2];
        quantize_rows_i8(&a, 2, 2, &mut qa, &mut sa);
        assert_eq!(&qa[2..], &[0, 0], "all-zero row quantizes to zero codes");
        for rep in [WRep::I8, WRep::I4] {
            // bits[0] = 0 prunes channel 0 entirely; channel 1 is all-zero.
            let (qw, sw) = quantize_weights_alloc(&w, 2, 2, &[0.0, 4.0], rep);
            let mut out = vec![1.0f32; 4];
            qgemm_into(&mut out, &qa, &sa, &qw, &sw, 2, 2, 2, rep == WRep::I4);
            assert_eq!(out, vec![0.0; 4], "{rep:?}");
        }
    }

    #[test]
    fn i4_matches_i8_on_low_bit_weights() {
        // With every channel ≤ 4 rounded bits the packed-nibble kernel
        // must reproduce the plain i8 kernel exactly (same codes, exact
        // integer accumulation, identical dequantize expression).
        let m = 3;
        let k = 7; // odd: exercises the padded tail nibble
        let n = 5;
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 37 % 101) as f32 / 50.0) - 1.0).collect();
        let w: Vec<f32> = (0..k * n).map(|i| ((i * 53 % 89) as f32 / 44.0) - 1.0).collect();
        let bits = [4.0f32, 2.0, 3.0, 1.0, 4.0];
        let mut qa = vec![0i8; m * k];
        let mut sa = vec![0.0f32; m];
        quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
        let (q8, s8) = quantize_weights_alloc(&w, k, n, &bits, WRep::I8);
        let (q4, s4) = quantize_weights_alloc(&w, k, n, &bits, WRep::I4);
        assert_eq!(s8, s4);
        let mut o8 = vec![0.0f32; m * n];
        let mut o4 = vec![0.0f32; m * n];
        qgemm_i8(&mut o8, &qa, &sa, &q8, &s8, m, k, n);
        qgemm_i4(&mut o4, &qa, &sa, &q4, &s4, m, k, n);
        for (x, y) in o8.iter().zip(&o4) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn wrep_follows_the_dispatch_rule() {
        assert_eq!(wrep_with(true, &[4.0, 2.0, 0.0], false), WRep::I4);
        assert_eq!(wrep_with(true, &[4.0, 5.0], false), WRep::I8);
        assert_eq!(wrep_with(true, &[8.0, 8.4], false), WRep::I8, "8.4 rounds to 8");
        assert_eq!(wrep_with(true, &[8.0, 9.0], false), WRep::F32, "9 bits exceeds i8");
        assert_eq!(wrep_with(true, &[2.0, 32.0], false), WRep::F32, "passthrough channel");
        assert_eq!(wrep_with(true, &[2.0, 2.0], true), WRep::F32, "binar never dispatches int");
        assert_eq!(wrep_with(false, &[2.0, 2.0], false), WRep::F32, "switch off forces f32");
    }
}
