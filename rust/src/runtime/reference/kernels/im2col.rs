//! Patch shuffles for the convolution lowering: SAME-padding geometry,
//! im2col (NHWC image → patch-row matrix) and its scatter-add inverse
//! (col2im).  Pure data movement — all arithmetic happens in the matmul
//! kernels these matrices feed.

/// SAME-padding geometry: (out, pad_lo, pad_hi).
pub fn same_pad(inp: usize, k: usize, s: usize) -> (usize, usize, usize) {
    let out = (inp + s - 1) / s;
    let total = ((out - 1) * s + k).saturating_sub(inp);
    (out, total / 2, total - total / 2)
}

/// im2col for one image: rows = ho·wo, cols = k·k·cin ordered [kh][kw][ci]
/// to match the (k,k,cin,cout) weight layout flattened row-major.
pub fn im2col(img: &[f32], h: usize, w: usize, cin: usize, k: usize, s: usize, out: &mut [f32]) {
    let (ho, pad_t, _) = same_pad(h, k, s);
    let (wo, pad_l, _) = same_pad(w, k, s);
    let cols = k * k * cin;
    debug_assert_eq!(out.len(), ho * wo * cols);
    out.fill(0.0);
    for oy in 0..ho {
        for ox in 0..wo {
            let row = &mut out[(oy * wo + ox) * cols..(oy * wo + ox + 1) * cols];
            for ky in 0..k {
                let iy = (oy * s + ky) as isize - pad_t as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * s + kx) as isize - pad_l as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let src = ((iy as usize) * w + ix as usize) * cin;
                    let dst = (ky * k + kx) * cin;
                    row[dst..dst + cin].copy_from_slice(&img[src..src + cin]);
                }
            }
        }
    }
}

/// Scatter-add of a patch-gradient matrix back to the image (col2im).
pub fn col2im_acc(
    dpatch: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    k: usize,
    s: usize,
    dimg: &mut [f32],
) {
    let (ho, pad_t, _) = same_pad(h, k, s);
    let (wo, pad_l, _) = same_pad(w, k, s);
    let cols = k * k * cin;
    debug_assert_eq!(dpatch.len(), ho * wo * cols);
    for oy in 0..ho {
        for ox in 0..wo {
            let row = &dpatch[(oy * wo + ox) * cols..(oy * wo + ox + 1) * cols];
            for ky in 0..k {
                let iy = (oy * s + ky) as isize - pad_t as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * s + kx) as isize - pad_l as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let dst = ((iy as usize) * w + ix as usize) * cin;
                    let src = (ky * k + kx) * cin;
                    for ci in 0..cin {
                        dimg[dst + ci] += row[src + ci];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_pad_matches_xla() {
        assert_eq!(same_pad(32, 3, 1), (32, 1, 1));
        assert_eq!(same_pad(32, 3, 2), (16, 0, 1));
        assert_eq!(same_pad(32, 1, 1), (32, 0, 0));
        assert_eq!(same_pad(5, 3, 2), (3, 1, 1));
    }

    #[test]
    fn im2col_col2im_are_adjoint() {
        // ⟨im2col(x), y⟩ == ⟨x, col2im(y)⟩ — the linear-map adjoint pair
        // the conv forward/backward relies on.
        let (h, w, cin, k, s) = (4usize, 5usize, 2usize, 3usize, 1usize);
        let (ho, _, _) = same_pad(h, k, s);
        let (wo, _, _) = same_pad(w, k, s);
        let cols = k * k * cin;
        let x: Vec<f32> = (0..h * w * cin).map(|i| (i as f32 * 0.37).sin()).collect();
        let y: Vec<f32> = (0..ho * wo * cols).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut px = vec![0.0f32; ho * wo * cols];
        im2col(&x, h, w, cin, k, s, &mut px);
        let mut cy = vec![0.0f32; h * w * cin];
        col2im_acc(&y, h, w, cin, k, s, &mut cy);
        let lhs: f64 = px.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
        let rhs: f64 = x.iter().zip(&cy).map(|(a, b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }
}
