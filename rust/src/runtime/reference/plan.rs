//! Two-phase planned execution engine for the reference backend.
//!
//! **Phase 1 (compile, at `Executable` build time):** [`compile_eval`] /
//! [`compile_train`] walk a [`ModelGraph`] once and lower it to a [`Plan`]
//! — a flat [`Step`] list with every shape statically resolved and every
//! intermediate assigned a **buffer slot**.  A liveness pass
//! ([`assign_slots`]) maps the virtual buffers onto a minimal set of
//! physical slots: a buffer's slot is recycled as soon as its last reader
//! has run, so non-overlapping intermediates share storage (training tapes
//! stay live from their forward def to their backward use automatically —
//! liveness sees the backward read).
//!
//! **Phase 2 (dispatch):** [`run_eval`] / [`run_train`] execute the plan
//! against a reusable [`Workspace`] arena (one per worker, handed out by
//! `util::pool::ScratchArena`).  Steps write into workspace slots through
//! the `_into` kernels of `nn.rs`, so steady-state batches perform zero
//! heap allocation for intermediates.
//!
//! # Determinism contract
//!
//! A plan computes **exactly** the arithmetic of the PR 3 tree-walk
//! (`model_exec::forward`/`backward`), in the same per-element order: every
//! step either fully overwrites its output slot or zero-fills before
//! accumulating, replicating what a freshly `vec![0.0; _]`-allocated
//! buffer would hold.  Planned output is therefore byte-identical to the
//! walk at every thread count — `tests/plan_engine.rs` enforces this for
//! all zoo models × quant/binar × eval/train.
//!
//! The one *compute* short-cut is shared with the walk: when a per-channel
//! bit slice is an exact passthrough (`quantize::is_passthrough`, bits
//! ≥ 24 in quant mode), the channel-major round-trip and quantize scan are
//! skipped and the tensor is copied through unchanged — bit-identical by
//! construction since the transpose pair is a pure permutation and the
//! quantizer is the identity on every row.

use crate::runtime::reference::kernels::{
    pack_i4, packed4_row_len, quantize_w_i8, wrep, WRep, I8_LEVELS,
};
use crate::runtime::reference::nn::{
    add_bias, bias_bwd_acc, cmajor_to_nhwc_into, cmajor_to_w_into, conv2d_bwd_into, conv2d_into,
    conv_panel_len, conv_patch_len, conv_qpatch_len, conv_qrows, dwconv2d_bwd_into, dwconv2d_into,
    dwconv_qrows, gap_bwd_into, gap_into, gn_groups, group_norm_bwd_into, group_norm_into,
    matmul_a_bt_into, matmul_acc_scratch, matmul_at_b_acc, matmul_panel_len, maxpool2_bwd_into,
    maxpool2_into, nhwc_to_cmajor_into, qconv2d_into, qdwconv2d_into, qfc_into, relu, relu_bwd,
    same_pad, softmax_xent_into, w_to_cmajor_into, Dims,
};
use crate::runtime::reference::quantize::{is_passthrough, linear_scale, quantize_rows};
use crate::runtime::reference::zoo::{LType, ModelGraph, Node};
use crate::runtime::tensor::Tensor;
use crate::runtime::value::Value;

/// Physical f32 buffer-slot id (index into `Workspace::bufs`).
pub type Slot = usize;

/// Physical u32 buffer-slot id (pool argmax tapes).
pub type USlot = usize;

/// Physical i8 buffer-slot id (integer-kernel weight codes and dynamic
/// activation codes; eval plans only).
pub type ISlot = usize;

// ---------------------------------------------------------------------------
// Workspace
// ---------------------------------------------------------------------------

/// Per-worker scratch arena a plan executes against.  Buffers grow to the
/// plan's slot capacities on first use and are never shrunk, so a warm
/// workspace re-runs any already-seen plan with zero allocation.  Contents
/// between dispatches are garbage by contract — every step fully
/// overwrites or zero-fills what it writes.
#[derive(Debug, Default)]
pub struct Workspace {
    bufs: Vec<Vec<f32>>,
    ubufs: Vec<Vec<u32>>,
    ibufs: Vec<Vec<i8>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Grow to satisfy `plan` (monotonic; no-op when already warm).
    pub fn ensure(&mut self, plan: &Plan) {
        self.ensure_caps(&plan.slot_caps, &plan.uslot_caps);
        self.ensure_icaps(&plan.islot_caps);
    }

    /// Grow to raw slot capacities (the agent plans carry these directly).
    pub fn ensure_caps(&mut self, f32_caps: &[usize], u32_caps: &[usize]) {
        if self.bufs.len() < f32_caps.len() {
            self.bufs.resize_with(f32_caps.len(), Vec::new);
        }
        for (b, &cap) in self.bufs.iter_mut().zip(f32_caps) {
            if b.len() < cap {
                b.resize(cap, 0.0);
            }
        }
        if self.ubufs.len() < u32_caps.len() {
            self.ubufs.resize_with(u32_caps.len(), Vec::new);
        }
        for (b, &cap) in self.ubufs.iter_mut().zip(u32_caps) {
            if b.len() < cap {
                b.resize(cap, 0);
            }
        }
    }

    /// Grow the i8 arena (integer-kernel scratch; kept out of the public
    /// two-arena [`Workspace::ensure_caps`] signature the agent plans use).
    pub fn ensure_icaps(&mut self, i8_caps: &[usize]) {
        if self.ibufs.len() < i8_caps.len() {
            self.ibufs.resize_with(i8_caps.len(), Vec::new);
        }
        for (b, &cap) in self.ibufs.iter_mut().zip(i8_caps) {
            if b.len() < cap {
                b.resize(cap, 0);
            }
        }
    }

    /// Move a slot's buffer out for the duration of a step (no allocation
    /// — swaps in an empty `Vec`).  Must be paired with [`Workspace::put`].
    pub(crate) fn take(&mut self, s: Slot) -> Vec<f32> {
        let v = std::mem::take(&mut self.bufs[s]);
        debug_assert!(!v.is_empty(), "slot {s} taken twice (or workspace not ensured)");
        v
    }

    pub(crate) fn put(&mut self, s: Slot, v: Vec<f32>) {
        self.bufs[s] = v;
    }

    fn take_u(&mut self, s: USlot) -> Vec<u32> {
        std::mem::take(&mut self.ubufs[s])
    }

    fn put_u(&mut self, s: USlot, v: Vec<u32>) {
        self.ubufs[s] = v;
    }

    fn take_i(&mut self, s: ISlot) -> Vec<i8> {
        std::mem::take(&mut self.ibufs[s])
    }

    fn put_i(&mut self, s: ISlot, v: Vec<i8>) {
        self.ibufs[s] = v;
    }

    fn slice(&self, s: Slot, len: usize) -> &[f32] {
        &self.bufs[s][..len]
    }

    /// Total resident f32 elements — flat across steady-state batches (the
    /// workspace-reuse regression guard reads this via `scratch_stats`).
    pub fn f32_len(&self) -> usize {
        self.bufs.iter().map(Vec::len).sum()
    }

    /// Total resident u32 elements.
    pub fn u32_len(&self) -> usize {
        self.ubufs.iter().map(Vec::len).sum()
    }

    /// Total resident i8 bytes (integer-kernel scratch).
    pub fn i8_len(&self) -> usize {
        self.ibufs.iter().map(Vec::len).sum()
    }
}

// ---------------------------------------------------------------------------
// Slot planner (physical-slot allocator)
// ---------------------------------------------------------------------------

/// Free-list allocator for physical slots.  `alloc` prefers the smallest
/// free slot that already fits (best fit), else grows the largest free
/// slot, else mints a new one; `release` returns a slot for reuse.
/// Deterministic: the slot layout is a pure function of the call sequence.
#[derive(Debug, Default)]
pub struct Planner {
    caps: Vec<usize>,
    free: Vec<Slot>,
}

impl Planner {
    pub fn new() -> Planner {
        Planner::default()
    }

    pub fn alloc(&mut self, len: usize) -> Slot {
        let mut best: Option<usize> = None; // position in `free`, cap >= len
        let mut largest: Option<usize> = None;
        for (pos, &s) in self.free.iter().enumerate() {
            if self.caps[s] >= len {
                let tighter = match best {
                    None => true,
                    Some(b) => self.caps[self.free[b]] > self.caps[s],
                };
                if tighter {
                    best = Some(pos);
                }
            }
            let bigger = match largest {
                None => true,
                Some(b) => self.caps[self.free[b]] < self.caps[s],
            };
            if bigger {
                largest = Some(pos);
            }
        }
        let pos = match best.or(largest) {
            Some(p) => p,
            None => {
                self.caps.push(len);
                return self.caps.len() - 1;
            }
        };
        let s = self.free.swap_remove(pos);
        if self.caps[s] < len {
            self.caps[s] = len;
        }
        s
    }

    pub fn release(&mut self, s: Slot) {
        debug_assert!(!self.free.contains(&s), "slot {s} double-released");
        self.free.push(s);
    }

    /// Final per-slot capacities (f32 elements).
    pub fn finish(self) -> Vec<usize> {
        self.caps
    }
}

// ---------------------------------------------------------------------------
// Steps
// ---------------------------------------------------------------------------

/// Where an activation-quantize step reads from: the dispatch's images
/// input, or an earlier step's output slot.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Src {
    Images,
    Slot(Slot),
}

fn expect_slot(src: Src) -> Slot {
    match src {
        Src::Slot(s) => s,
        Src::Images => panic!("plan: node consumes raw images (zoo graphs start with a conv)"),
    }
}

/// Integer-path slots of a `WQ` step (eval plans on int-eligible layers).
/// Bit configs arrive per dispatch, so the plan cannot know which
/// representation [`wrep`] will pick — it reserves scratch for either and
/// the executor writes exactly one (f32 `dst` *or* these; the unwritten
/// twin is never read because the consuming step re-derives the same
/// `wrep` from the same bit slice).
#[derive(Debug, Clone, Copy)]
pub(crate) struct IntWq {
    /// Channel-major i8 weight codes (nibble-packed iff the dispatch
    /// picks `WRep::I4`).
    qdst: ISlot,
    /// Unpacked-code scratch for the I4 pack step.
    qscratch: ISlot,
    /// Per-output-channel f32 scales (the exact fake-quant grid).
    wscales: Slot,
}

/// Integer-path slots of an `Fc`/`Conv` step: the producing `WQ` step's
/// weight codes/scales plus dynamic per-row activation scratch.
#[derive(Debug, Clone, Copy)]
pub(crate) struct IntGemm {
    /// The `WQ` step's `qdst`.
    qw: ISlot,
    /// The `WQ` step's `wscales`.
    wsc: Slot,
    /// Dynamic i8 activation codes ([`conv_qpatch_len`] / `n·cin`).
    qa: ISlot,
    /// Dynamic per-row activation scales ([`conv_qrows`] / `n`).
    ascale: Slot,
}

/// Integer-path slots of a `DwConv` step: the producing `WQ` step's weight
/// codes/scales plus per-(image, channel) activation scratch (depthwise
/// contractions reduce over k·k taps of one channel, so the activation
/// scale granularity is (n, c) rather than per im2col row).
#[derive(Debug, Clone, Copy)]
pub(crate) struct IntDw {
    /// The `WQ` step's `qdst` (channel-major tap codes, nibble-packed on I4).
    qw: ISlot,
    /// The `WQ` step's `wscales`.
    wsc: Slot,
    /// i8 activation codes (`d.elems()` bytes, NHWC order).
    qx: ISlot,
    /// Per-(image, channel) activation scales ([`dwconv_qrows`]).
    xsc: Slot,
}

/// One planned operation.  Layer steps carry the layer index `li` so the
/// executor can read kernel geometry and parameter offsets from the graph;
/// all activation geometry is resolved at compile time.
#[derive(Debug, Clone)]
pub(crate) enum Step {
    /// Per-input-channel activation quantize, NHWC via channel-major
    /// scratch `cm` (skipped wholesale on passthrough bits).
    ActQ4 { src: Src, dst: Slot, cm: Slot, d: Dims, a_off: usize },
    /// Flat (n, c) activation quantize — fc's single shared channel.
    ActQ2 { src: Src, dst: Slot, n: usize, c: usize, a_off: usize },
    /// Per-output-channel weight quantize of `params[l.p_w]` into `dst`
    /// via channel-major `scratch` (copied through on passthrough bits),
    /// or onto the integer grid when `int` is planned and [`wrep`] picks
    /// an int representation at dispatch time.
    WQ { li: usize, dst: Slot, scratch: Slot, int: Option<IntWq> },
    /// dst = xq @ w + bias (fc layer); `panel` is matmul packing scratch
    /// (None when the shape stays on the naive path).
    Fc {
        li: usize,
        xq: Slot,
        wq: Slot,
        dst: Slot,
        n: usize,
        panel: Option<Slot>,
        int: Option<IntGemm>,
    },
    /// dst = conv(xq, wq); `patches` is im2col scratch (None = pointwise),
    /// `panel` is matmul packing scratch (None on small shapes).
    Conv {
        li: usize,
        xq: Slot,
        wq: Slot,
        dst: Slot,
        patches: Option<Slot>,
        panel: Option<Slot>,
        d: Dims,
        int: Option<IntGemm>,
    },
    /// dst = dwconv(xq, wq); runs the per-channel integer kernel when
    /// `int` is planned and [`wrep`] picks an int representation.
    DwConv { li: usize, xq: Slot, wq: Slot, dst: Slot, d: Dims, int: Option<IntDw> },
    /// GroupNorm src → dst; `cache` = (xn, istd) tape slots when training.
    Gn { li: usize, src: Slot, dst: Slot, d: Dims, cache: Option<(Slot, Slot)> },
    /// In-place bias add on a conv output.
    Bias { li: usize, buf: Slot, c: usize, len: usize },
    /// In-place ReLU; `save` copies the post-ReLU tensor for the tape.
    Relu { buf: Slot, save: Option<Slot>, len: usize },
    /// 2×2 max-pool; `idx` keeps argmax indices for the backward pass.
    Pool { src: Slot, dst: Slot, idx: Option<USlot>, d: Dims },
    /// Global average pool: NHWC → (n, c).
    Gap { src: Slot, dst: Slot, d: Dims },
    /// Channel concat (Fire): dst = a ++ b.
    Concat { a: Slot, b: Slot, dst: Slot, d_a: Dims, d_b: Dims },
    /// buf += add (residual merges, gradient joins).
    Add { buf: Slot, add: Slot, len: usize },
    /// dst = src (gradient forks for residual branches).
    Copy { src: Slot, dst: Slot, len: usize },

    // --- backward (train plans only) -----------------------------------
    /// In-place dy ⊙ 1[out > 0].
    BRelu { dy: Slot, out: Slot, len: usize },
    /// GroupNorm backward: dy → dst; accumulates dγ/dβ into the layer's
    /// grad slots.
    BGn { li: usize, dy: Slot, dst: Slot, d: Dims, xn: Slot, istd: Slot },
    /// Bias backward: accumulates dβ into the layer's grad slot.
    BBias { li: usize, dy: Slot, c: usize, len: usize },
    /// Fc backward: writes dx into dst, accumulates dw/db.
    BFc { li: usize, xq: Slot, wq: Slot, dy: Slot, dst: Slot, n: usize },
    /// Conv backward: writes dx, accumulates dw (d = input dims).
    BConv {
        li: usize,
        xq: Slot,
        wq: Slot,
        dy: Slot,
        dst: Slot,
        patches: Option<Slot>,
        dpatch: Option<Slot>,
        d: Dims,
    },
    /// Depthwise conv backward: writes dx, accumulates dw.
    BDwConv { li: usize, xq: Slot, wq: Slot, dy: Slot, dst: Slot, d: Dims },
    /// Max-pool backward through the forward argmax tape.
    BPool { dy: Slot, idx: USlot, dst: Slot, in_d: Dims },
    /// GAP backward (broadcast /hw).
    BGap { dy: Slot, dst: Slot, d: Dims },
    /// Channel un-concat (Fire backward): src → (a, b).
    BSplit { src: Slot, a: Slot, b: Slot, d: Dims, ca: usize },
}

/// Visit every f32 slot id a step touches, in a fixed field order — the
/// single source of truth for liveness scanning and physical remapping.
fn visit_slots(step: &mut Step, f: &mut impl FnMut(&mut Slot)) {
    match step {
        Step::ActQ4 { src, dst, cm, .. } => {
            if let Src::Slot(s) = src {
                f(s);
            }
            f(dst);
            f(cm);
        }
        Step::ActQ2 { src, dst, .. } => {
            if let Src::Slot(s) = src {
                f(s);
            }
            f(dst);
        }
        Step::WQ { dst, scratch, int, .. } => {
            f(dst);
            f(scratch);
            if let Some(i) = int {
                f(&mut i.wscales);
            }
        }
        Step::Fc { xq, wq, dst, panel, int, .. } => {
            f(xq);
            f(wq);
            f(dst);
            if let Some(p) = panel {
                f(p);
            }
            if let Some(i) = int {
                f(&mut i.wsc);
                f(&mut i.ascale);
            }
        }
        Step::Conv { xq, wq, dst, patches, panel, int, .. } => {
            f(xq);
            f(wq);
            f(dst);
            if let Some(p) = patches {
                f(p);
            }
            if let Some(p) = panel {
                f(p);
            }
            if let Some(i) = int {
                f(&mut i.wsc);
                f(&mut i.ascale);
            }
        }
        Step::DwConv { xq, wq, dst, int, .. } => {
            f(xq);
            f(wq);
            f(dst);
            if let Some(i) = int {
                f(&mut i.wsc);
                f(&mut i.xsc);
            }
        }
        Step::Gn { src, dst, cache, .. } => {
            f(src);
            f(dst);
            if let Some((a, b)) = cache {
                f(a);
                f(b);
            }
        }
        Step::Bias { buf, .. } => f(buf),
        Step::Relu { buf, save, .. } => {
            f(buf);
            if let Some(s) = save {
                f(s);
            }
        }
        Step::Pool { src, dst, .. } => {
            f(src);
            f(dst);
        }
        Step::Gap { src, dst, .. } => {
            f(src);
            f(dst);
        }
        Step::Concat { a, b, dst, .. } => {
            f(a);
            f(b);
            f(dst);
        }
        Step::Add { buf, add, .. } => {
            f(buf);
            f(add);
        }
        Step::Copy { src, dst, .. } => {
            f(src);
            f(dst);
        }
        Step::BRelu { dy, out, .. } => {
            f(dy);
            f(out);
        }
        Step::BGn { dy, dst, xn, istd, .. } => {
            f(dy);
            f(dst);
            f(xn);
            f(istd);
        }
        Step::BBias { dy, .. } => f(dy),
        Step::BFc { xq, wq, dy, dst, .. } => {
            f(xq);
            f(wq);
            f(dy);
            f(dst);
        }
        Step::BConv { xq, wq, dy, dst, patches, dpatch, .. } => {
            f(xq);
            f(wq);
            f(dy);
            f(dst);
            if let Some(p) = patches {
                f(p);
            }
            if let Some(p) = dpatch {
                f(p);
            }
        }
        Step::BDwConv { xq, wq, dy, dst, .. } => {
            f(xq);
            f(wq);
            f(dy);
            f(dst);
        }
        Step::BPool { dy, dst, .. } => {
            f(dy);
            f(dst);
        }
        Step::BGap { dy, dst, .. } => {
            f(dy);
            f(dst);
        }
        Step::BSplit { src, a, b, .. } => {
            f(src);
            f(a);
            f(b);
        }
    }
}

/// Visit every i8 slot id a step touches — the liveness/remap twin of
/// [`visit_slots`] for the integer-kernel arena (int-path steps only).
fn visit_islots(step: &mut Step, f: &mut impl FnMut(&mut ISlot)) {
    match step {
        Step::WQ { int: Some(i), .. } => {
            f(&mut i.qdst);
            f(&mut i.qscratch);
        }
        Step::Fc { int: Some(i), .. } | Step::Conv { int: Some(i), .. } => {
            f(&mut i.qw);
            f(&mut i.qa);
        }
        Step::DwConv { int: Some(i), .. } => {
            f(&mut i.qw);
            f(&mut i.qx);
        }
        _ => {}
    }
}

/// Liveness pass: map virtual buffers (step fields as emitted by the
/// builder) onto physical slots.  A virtual buffer's first appearance is
/// always its defining write; its slot returns to the free list right
/// after the step holding its last appearance (pinned buffers — logits,
/// d(logits) — are read by the executor outside the step list and are
/// never released).  Returns the virtual → physical map.  `visit` selects
/// the arena: the same pass runs once over the f32 slots
/// ([`visit_slots`]) and once over the i8 slots ([`visit_islots`]).
fn assign_slots(
    steps: &mut [Step],
    sizes: &[usize],
    pinned: &[bool],
    planner: &mut Planner,
    mut visit: impl FnMut(&mut Step, &mut dyn FnMut(&mut Slot)),
) -> Vec<Option<Slot>> {
    let mut last = vec![0usize; sizes.len()];
    for (i, s) in steps.iter_mut().enumerate() {
        visit(s, &mut |v| last[*v] = i);
    }
    let mut map: Vec<Option<Slot>> = vec![None; sizes.len()];
    for (i, step) in steps.iter_mut().enumerate() {
        let mut dying: Vec<Slot> = Vec::new();
        visit(step, &mut |v| {
            if map[*v].is_none() {
                map[*v] = Some(planner.alloc(sizes[*v]));
            }
            if last[*v] == i && !pinned[*v] {
                dying.push(map[*v].expect("assigned above"));
            }
        });
        visit(step, &mut |v| *v = map[*v].expect("assigned above"));
        dying.sort_unstable();
        dying.dedup();
        for s in dying {
            planner.release(s);
        }
    }
    map
}

// ---------------------------------------------------------------------------
// Plan + compiler
// ---------------------------------------------------------------------------

/// A compiled model graph: flat step list over physical buffer slots.
#[derive(Debug)]
pub struct Plan {
    steps: Vec<Step>,
    /// Steps `[..fwd_len]` are the forward pass; the rest (train plans)
    /// are the backward pass, separated by the executor-run loss head.
    fwd_len: usize,
    /// Physical f32 slot capacities (elements).
    pub slot_caps: Vec<usize>,
    /// Physical u32 slot capacities (pool argmax tapes).
    pub uslot_caps: Vec<usize>,
    /// Physical i8 slot capacities (integer-kernel scratch; empty for
    /// train plans, whose tapes need the f32 quantized operands).
    pub islot_caps: Vec<usize>,
    /// Per-parameter gradient slots (train plans; pinned).
    grad_slots: Vec<Slot>,
    logits: Slot,
    dlogits: Slot,
    n: usize,
    classes: usize,
    d0: Dims,
}

impl Plan {
    /// Batch size this plan was compiled for.
    pub fn batch(&self) -> usize {
        self.n
    }

    /// Number of planned steps (fwd, bwd).
    pub fn step_counts(&self) -> (usize, usize) {
        (self.fwd_len, self.steps.len() - self.fwd_len)
    }
}

/// Activation shape flowing through the planner (mirrors the walk's ActT).
#[derive(Debug, Clone, Copy)]
enum Shape {
    A4(Dims),
    A2 { n: usize, c: usize },
}

impl Shape {
    fn channels(&self) -> usize {
        match *self {
            Shape::A4(d) => d.c,
            Shape::A2 { c, .. } => c,
        }
    }

    fn elems(&self) -> usize {
        match *self {
            Shape::A4(d) => d.elems(),
            Shape::A2 { n, c } => n * c,
        }
    }
}

/// Planner-side tape of one primitive layer (slot ids, not data).
#[derive(Debug, Clone)]
struct PLayer {
    li: usize,
    xq: Slot,
    xq_shape: Shape,
    wq: Slot,
    gn: Option<(Slot, Slot)>,
    relu_out: Option<Slot>,
    out_d: Dims,
}

/// Planner-side tape of one graph node.
#[derive(Debug, Clone)]
enum PTape {
    Layer(PLayer),
    Pool { idx: USlot, in_d: Dims },
    Gap { d: Dims },
    Basic { c1: PLayer, c2: PLayer, proj: Option<PLayer>, relu_out: Slot, out_d: Dims },
    Fire { sq: PLayer, e1: PLayer, e3: PLayer, ca: usize, out_d: Dims },
    Irb { expand: Option<PLayer>, dw: PLayer, project: PLayer, residual: bool, out_d: Dims },
}

struct PlanBuilder<'g> {
    g: &'g ModelGraph,
    train: bool,
    steps: Vec<Step>,
    sizes: Vec<usize>,
    pinned: Vec<bool>,
    usizes: Vec<usize>,
    isizes: Vec<usize>,
    tapes: Vec<PTape>,
}

impl<'g> PlanBuilder<'g> {
    /// New virtual f32 buffer of `len` elements.
    fn vb(&mut self, len: usize) -> Slot {
        self.sizes.push(len);
        self.pinned.push(false);
        self.sizes.len() - 1
    }

    /// New u32 buffer (u32 buffers are few; no liveness reuse).
    fn uvb(&mut self, len: usize) -> USlot {
        self.usizes.push(len);
        self.usizes.len() - 1
    }

    /// New virtual i8 buffer of `len` bytes (int-path scratch; liveness
    /// runs over these exactly like the f32 slots, never pinned).
    fn ivb(&mut self, len: usize) -> ISlot {
        self.isizes.push(len);
        self.isizes.len() - 1
    }

    fn pin(&mut self, v: Slot) {
        self.pinned[v] = true;
    }

    /// Plan one primitive layer (mirrors `model_exec::layer_fwd`):
    /// quantize activation + weight, contraction, norm/bias, ReLU.
    fn plan_layer(&mut self, li: usize, cur: (Src, Shape)) -> ((Src, Shape), PLayer) {
        let l = &self.g.layers[li];
        let (src, shape) = cur;
        let (xq, xq_shape) = match shape {
            Shape::A4(d) => {
                debug_assert_eq!(d.c, l.a_len, "{}: activation channels", l.name);
                let xq = self.vb(d.elems());
                let cm = self.vb(d.elems());
                self.steps.push(Step::ActQ4 { src, dst: xq, cm, d, a_off: l.a_off });
                (xq, Shape::A4(d))
            }
            Shape::A2 { n, c } => {
                let xq = self.vb(n * c);
                self.steps.push(Step::ActQ2 { src, dst: xq, n, c, a_off: l.a_off });
                (xq, shape)
            }
        };
        let wlen: usize = self.g.params[l.p_w].shape.iter().product();
        let wq = self.vb(wlen);
        let scratch = self.vb(wlen);
        // Int-path scratch (eval only).  Which representation runs is a
        // per-dispatch decision — the plan reserves capacity so any of
        // them can.
        let int_ok = !self.train;
        let int_wq = int_ok.then(|| IntWq {
            qdst: self.ivb(wlen),
            qscratch: self.ivb(wlen),
            wscales: self.vb(l.w_len),
        });
        self.steps.push(Step::WQ { li, dst: wq, scratch, int: int_wq });

        match l.typ {
            LType::Fc => {
                let Shape::A2 { n, c } = xq_shape else { panic!("fc expects flat input") };
                debug_assert_eq!(c, l.cin);
                let dst = self.vb(n * l.cout);
                let pan = matmul_panel_len(l.cin, l.cout);
                let panel = (pan > 0).then(|| self.vb(pan));
                let int = int_wq.map(|iw| IntGemm {
                    qw: iw.qdst,
                    wsc: iw.wscales,
                    qa: self.ivb(n * l.cin),
                    ascale: self.vb(n),
                });
                self.steps.push(Step::Fc { li, xq, wq, dst, n, panel, int });
                let out_d = Dims { n, h: 1, w: 1, c: l.cout };
                let tape = PLayer { li, xq, xq_shape, wq, gn: None, relu_out: None, out_d };
                ((Src::Slot(dst), Shape::A2 { n, c: l.cout }), tape)
            }
            LType::Conv | LType::DwConv => {
                let Shape::A4(d) = xq_shape else { panic!("conv expects NHWC input") };
                let (ho, _, _) = same_pad(d.h, l.k, l.s);
                let (wo, _, _) = same_pad(d.w, l.k, l.s);
                let oc = if l.typ == LType::DwConv { d.c } else { l.cout };
                let od = Dims { n: d.n, h: ho, w: wo, c: oc };
                let dst = self.vb(od.elems());
                if l.typ == LType::DwConv {
                    let int = int_wq.map(|iw| IntDw {
                        qw: iw.qdst,
                        wsc: iw.wscales,
                        qx: self.ivb(d.elems()),
                        xsc: self.vb(dwconv_qrows(d)),
                    });
                    self.steps.push(Step::DwConv { li, xq, wq, dst, d, int });
                } else {
                    let plen = conv_patch_len(d, l.k, l.s);
                    let patches = (plen > 0).then(|| self.vb(plen));
                    let pan = conv_panel_len(d, l.k, l.cout);
                    let panel = (pan > 0).then(|| self.vb(pan));
                    let int = int_wq.map(|iw| IntGemm {
                        qw: iw.qdst,
                        wsc: iw.wscales,
                        qa: self.ivb(conv_qpatch_len(d, l.k, l.s)),
                        ascale: self.vb(conv_qrows(d, l.k, l.s)),
                    });
                    self.steps.push(Step::Conv { li, xq, wq, dst, patches, panel, d, int });
                }
                let (out, gn) = if l.norm {
                    let gdst = self.vb(od.elems());
                    let cache = self
                        .train
                        .then(|| (self.vb(od.elems()), self.vb(od.n * gn_groups(od.c))));
                    self.steps.push(Step::Gn { li, src: dst, dst: gdst, d: od, cache });
                    (gdst, cache)
                } else {
                    self.steps.push(Step::Bias { li, buf: dst, c: od.c, len: od.elems() });
                    (dst, None)
                };
                let relu_out = if l.relu {
                    let save = self.train.then(|| self.vb(od.elems()));
                    self.steps.push(Step::Relu { buf: out, save, len: od.elems() });
                    save
                } else {
                    None
                };
                let tape = PLayer { li, xq, xq_shape, wq, gn, relu_out, out_d: od };
                ((Src::Slot(out), Shape::A4(od)), tape)
            }
        }
    }

    /// Plan the backward of one primitive layer (mirrors
    /// `model_exec::layer_bwd`); returns the input-gradient slot + shape.
    fn plan_layer_bwd(&mut self, t: &PLayer, mut dy: Slot) -> (Slot, Shape) {
        let l = &self.g.layers[t.li];
        match l.typ {
            LType::Fc => {
                let Shape::A2 { n, c } = t.xq_shape else { panic!("fc tape") };
                let dst = self.vb(n * c);
                self.steps.push(Step::BFc { li: t.li, xq: t.xq, wq: t.wq, dy, dst, n });
                (dst, t.xq_shape)
            }
            LType::Conv | LType::DwConv => {
                if let Some(out) = t.relu_out {
                    self.steps.push(Step::BRelu { dy, out, len: t.out_d.elems() });
                }
                if l.norm {
                    let (xn, istd) = t.gn.expect("norm layer planned with cache");
                    let dst = self.vb(t.out_d.elems());
                    self.steps.push(Step::BGn { li: t.li, dy, dst, d: t.out_d, xn, istd });
                    dy = dst;
                } else {
                    self.steps.push(Step::BBias {
                        li: t.li,
                        dy,
                        c: t.out_d.c,
                        len: t.out_d.elems(),
                    });
                }
                let Shape::A4(din) = t.xq_shape else { panic!("conv tape") };
                let dst = self.vb(din.elems());
                if l.typ == LType::DwConv {
                    self.steps.push(Step::BDwConv {
                        li: t.li,
                        xq: t.xq,
                        wq: t.wq,
                        dy,
                        dst,
                        d: din,
                    });
                } else {
                    let plen = conv_patch_len(din, l.k, l.s);
                    let (patches, dpatch) = if plen > 0 {
                        (Some(self.vb(plen)), Some(self.vb(plen)))
                    } else {
                        (None, None)
                    };
                    self.steps.push(Step::BConv {
                        li: t.li,
                        xq: t.xq,
                        wq: t.wq,
                        dy,
                        dst,
                        patches,
                        dpatch,
                        d: din,
                    });
                }
                (dst, t.xq_shape)
            }
        }
    }

    /// Plan the whole backward walk (mirrors `model_exec::backward`).
    fn plan_backward(&mut self, tapes: &[PTape], dlogits: Slot, n: usize, classes: usize) {
        let mut dy: (Slot, Shape) = (dlogits, Shape::A2 { n, c: classes });
        for tape in tapes.iter().rev() {
            dy = match tape {
                PTape::Layer(t) => self.plan_layer_bwd(t, dy.0),
                PTape::Pool { idx, in_d } => {
                    let dst = self.vb(in_d.elems());
                    self.steps.push(Step::BPool { dy: dy.0, idx: *idx, dst, in_d: *in_d });
                    (dst, Shape::A4(*in_d))
                }
                PTape::Gap { d } => {
                    let dst = self.vb(d.elems());
                    self.steps.push(Step::BGap { dy: dy.0, dst, d: *d });
                    (dst, Shape::A4(*d))
                }
                PTape::Basic { c1, c2, proj, relu_out, out_d } => {
                    self.steps.push(Step::BRelu { dy: dy.0, out: *relu_out, len: out_d.elems() });
                    let d_sc = self.vb(out_d.elems());
                    self.steps.push(Step::Copy { src: dy.0, dst: d_sc, len: out_d.elems() });
                    let (dy1, _) = self.plan_layer_bwd(c2, dy.0);
                    let (dinp, din_shape) = self.plan_layer_bwd(c1, dy1);
                    let dinp_b = match proj {
                        Some(tp) => self.plan_layer_bwd(tp, d_sc).0,
                        None => d_sc,
                    };
                    self.steps.push(Step::Add {
                        buf: dinp,
                        add: dinp_b,
                        len: din_shape.elems(),
                    });
                    (dinp, din_shape)
                }
                PTape::Fire { sq, e1, e3, ca, out_d } => {
                    let pixels = out_d.n * out_d.h * out_d.w;
                    let cb = out_d.c - ca;
                    let da = self.vb(pixels * ca);
                    let db = self.vb(pixels * cb);
                    self.steps.push(Step::BSplit { src: dy.0, a: da, b: db, d: *out_d, ca: *ca });
                    let (dsq, dsq_shape) = self.plan_layer_bwd(e1, da);
                    let (dsq2, _) = self.plan_layer_bwd(e3, db);
                    self.steps.push(Step::Add { buf: dsq, add: dsq2, len: dsq_shape.elems() });
                    self.plan_layer_bwd(sq, dsq)
                }
                PTape::Irb { expand, dw, project, residual, out_d } => {
                    let dres = if *residual {
                        let s = self.vb(out_d.elems());
                        self.steps.push(Step::Copy { src: dy.0, dst: s, len: out_d.elems() });
                        Some(s)
                    } else {
                        None
                    };
                    let (d1, _) = self.plan_layer_bwd(project, dy.0);
                    let (d2, d2_shape) = self.plan_layer_bwd(dw, d1);
                    let (dx, dx_shape) = match expand {
                        Some(te) => self.plan_layer_bwd(te, d2),
                        None => (d2, d2_shape),
                    };
                    if let Some(r) = dres {
                        self.steps.push(Step::Add { buf: dx, add: r, len: dx_shape.elems() });
                    }
                    (dx, dx_shape)
                }
            };
        }
    }
}

/// Shared compile: forward walk (+ backward for train) → liveness →
/// physical plan.
fn compile(g: &ModelGraph, n: usize, train: bool) -> Plan {
    let mut b = PlanBuilder {
        g,
        train,
        steps: Vec::new(),
        sizes: Vec::new(),
        pinned: Vec::new(),
        usizes: Vec::new(),
        isizes: Vec::new(),
        tapes: Vec::new(),
    };
    let d0 = Dims { n, h: g.layers[0].h_in, w: g.layers[0].w_in, c: g.layers[0].cin };
    let mut cur: (Src, Shape) = (Src::Images, Shape::A4(d0));
    let mut li = 0usize;
    for node in &g.nodes {
        match *node {
            Node::Conv { .. } | Node::Fc { .. } => {
                let (next, tape) = b.plan_layer(li, cur);
                li += 1;
                cur = next;
                if train {
                    b.tapes.push(PTape::Layer(tape));
                }
            }
            Node::Pool => {
                let Shape::A4(d) = cur.1 else { panic!("pool expects NHWC") };
                let src = expect_slot(cur.0);
                let od = Dims { n: d.n, h: d.h / 2, w: d.w / 2, c: d.c };
                let dst = b.vb(od.elems());
                let idx = train.then(|| b.uvb(od.elems()));
                b.steps.push(Step::Pool { src, dst, idx, d });
                if train {
                    b.tapes.push(PTape::Pool { idx: idx.expect("train pool tape"), in_d: d });
                }
                cur = (Src::Slot(dst), Shape::A4(od));
            }
            Node::Gap => {
                let Shape::A4(d) = cur.1 else { panic!("gap expects NHWC") };
                let src = expect_slot(cur.0);
                let dst = b.vb(d.n * d.c);
                b.steps.push(Step::Gap { src, dst, d });
                if train {
                    b.tapes.push(PTape::Gap { d });
                }
                cur = (Src::Slot(dst), Shape::A2 { n: d.n, c: d.c });
            }
            Node::Basic { cout, s } => {
                let proj = s != 1 || cur.1.channels() != cout;
                let inp = cur;
                let (y1, t1) = b.plan_layer(li, inp);
                let (y2, t2) = b.plan_layer(li + 1, y1);
                let (sc, tp) = if proj {
                    let (sc, tp) = b.plan_layer(li + 2, inp);
                    (sc, Some(tp))
                } else {
                    (inp, None)
                };
                li += if proj { 3 } else { 2 };
                let Shape::A4(od) = y2.1 else { panic!("basic block output") };
                let buf = expect_slot(y2.0);
                b.steps.push(Step::Add { buf, add: expect_slot(sc.0), len: od.elems() });
                let save = train.then(|| b.vb(od.elems()));
                b.steps.push(Step::Relu { buf, save, len: od.elems() });
                if train {
                    b.tapes.push(PTape::Basic {
                        c1: t1,
                        c2: t2,
                        proj: tp,
                        relu_out: save.expect("train basic tape"),
                        out_d: od,
                    });
                }
                cur = (Src::Slot(buf), Shape::A4(od));
            }
            Node::Fire { .. } => {
                let (sqz, tsq) = b.plan_layer(li, cur);
                let (ya, te1) = b.plan_layer(li + 1, sqz);
                let (yb, te3) = b.plan_layer(li + 2, sqz);
                li += 3;
                let Shape::A4(da) = ya.1 else { panic!("fire expand1 output") };
                let Shape::A4(db) = yb.1 else { panic!("fire expand3 output") };
                let od = Dims { n: da.n, h: da.h, w: da.w, c: da.c + db.c };
                let dst = b.vb(od.elems());
                b.steps.push(Step::Concat {
                    a: expect_slot(ya.0),
                    b: expect_slot(yb.0),
                    dst,
                    d_a: da,
                    d_b: db,
                });
                if train {
                    b.tapes.push(PTape::Fire { sq: tsq, e1: te1, e3: te3, ca: da.c, out_d: od });
                }
                cur = (Src::Slot(dst), Shape::A4(od));
            }
            Node::Irb { t, cout, s } => {
                let residual = s == 1 && cur.1.channels() == cout;
                let inp = cur;
                let mut mid = cur;
                let texp = if t != 1 {
                    let (y, tp) = b.plan_layer(li, mid);
                    li += 1;
                    mid = y;
                    Some(tp)
                } else {
                    None
                };
                let (y, tdw) = b.plan_layer(li, mid);
                li += 1;
                let (y, tpr) = b.plan_layer(li, y);
                li += 1;
                let Shape::A4(od) = y.1 else { panic!("irb output") };
                let buf = expect_slot(y.0);
                if residual {
                    b.steps.push(Step::Add { buf, add: expect_slot(inp.0), len: od.elems() });
                }
                if train {
                    b.tapes.push(PTape::Irb {
                        expand: texp,
                        dw: tdw,
                        project: tpr,
                        residual,
                        out_d: od,
                    });
                }
                cur = (Src::Slot(buf), Shape::A4(od));
            }
        }
    }
    assert_eq!(li, g.layers.len(), "plan walk diverged from layer list");
    let Shape::A2 { n: out_n, c: classes } = cur.1 else {
        panic!("model {} does not end in a flat head", g.name)
    };
    debug_assert_eq!(out_n, n);
    let logits_vb = expect_slot(cur.0);
    b.pin(logits_vb);

    let fwd_len = b.steps.len();
    let mut dlogits_vb = usize::MAX;
    if train {
        dlogits_vb = b.vb(n * classes);
        b.pin(dlogits_vb);
        let tapes = std::mem::take(&mut b.tapes);
        b.plan_backward(&tapes, dlogits_vb, n, classes);
    }

    let mut planner = Planner::new();
    // Gradient slots first: pinned, read by the SGD epilogue outside the
    // step list, so they must never enter the free list.
    let grad_slots: Vec<Slot> = if train {
        g.params.iter().map(|p| planner.alloc(p.shape.iter().product())).collect()
    } else {
        Vec::new()
    };
    let map = assign_slots(&mut b.steps, &b.sizes, &b.pinned, &mut planner, |s, f| {
        visit_slots(s, &mut |v| f(v))
    });
    let logits = map[logits_vb].expect("logits slot assigned");
    let dlogits = if train { map[dlogits_vb].expect("dlogits slot assigned") } else { 0 };
    // Second liveness pass over the disjoint i8 arena (no pinned slots).
    let mut iplanner = Planner::new();
    let ipinned = vec![false; b.isizes.len()];
    assign_slots(&mut b.steps, &b.isizes, &ipinned, &mut iplanner, |s, f| {
        visit_islots(s, &mut |v| f(v))
    });
    Plan {
        steps: b.steps,
        fwd_len,
        slot_caps: planner.finish(),
        uslot_caps: b.usizes,
        islot_caps: iplanner.finish(),
        grad_slots,
        logits,
        dlogits,
        n,
        classes,
        d0,
    }
}

/// Compile the eval graph (forward + accuracy/loss head) for batch `n`.
pub fn compile_eval(g: &ModelGraph, n: usize) -> Plan {
    compile(g, n, false)
}

/// Compile the train graph (forward with tapes, STE backward, SGD) for
/// batch `n`.
pub fn compile_train(g: &ModelGraph, n: usize) -> Plan {
    compile(g, n, true)
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

fn add_vec(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Per-dispatch context shared by every step.
struct Ctx<'a> {
    g: &'a ModelGraph,
    binar: bool,
    params: &'a [&'a Tensor],
    images: &'a [f32],
    wbits: &'a [f32],
    abits: &'a [f32],
    /// Calibrated per-layer activation maxima (static activation scales);
    /// `None` = dynamic per-row scales.  Same table the walk reads, so
    /// planned output stays byte-identical either way.
    act_maxes: Option<&'a [f32]>,
    grad_slots: &'a [Slot],
}

/// Static activation scale for layer `li`, when a calibration table is
/// installed (the exact expression `model_exec::layer_fwd` uses).
fn static_scale(cx: &Ctx, li: usize) -> Option<f32> {
    cx.act_maxes.map(|t| linear_scale(t[li], I8_LEVELS))
}

fn exec_steps(steps: &[Step], cx: &Ctx, ws: &mut Workspace) {
    for step in steps {
        exec_step(step, cx, ws);
    }
}

fn exec_step(step: &Step, cx: &Ctx, ws: &mut Workspace) {
    match *step {
        Step::ActQ4 { src, dst, cm, d, a_off } => {
            let len = d.elems();
            let ab = &cx.abits[a_off..a_off + d.c];
            let srcv = match src {
                Src::Slot(s) => Some(ws.take(s)),
                Src::Images => None,
            };
            let sref: &[f32] = match &srcv {
                Some(v) => &v[..len],
                None => &cx.images[..len],
            };
            let mut dstv = ws.take(dst);
            if is_passthrough(ab, cx.binar) {
                dstv[..len].copy_from_slice(sref);
            } else {
                let mut cmv = ws.take(cm);
                nhwc_to_cmajor_into(sref, d, &mut cmv[..len]);
                quantize_rows(&mut cmv[..len], d.c, d.n * d.h * d.w, ab, cx.binar);
                cmajor_to_nhwc_into(&cmv[..len], d, &mut dstv[..len]);
                ws.put(cm, cmv);
            }
            ws.put(dst, dstv);
            if let (Src::Slot(s), Some(v)) = (src, srcv) {
                ws.put(s, v);
            }
        }
        Step::ActQ2 { src, dst, n, c, a_off } => {
            let len = n * c;
            let ab = &cx.abits[a_off..a_off + 1];
            let srcv = match src {
                Src::Slot(s) => Some(ws.take(s)),
                Src::Images => None,
            };
            let sref: &[f32] = match &srcv {
                Some(v) => &v[..len],
                None => &cx.images[..len],
            };
            let mut dstv = ws.take(dst);
            dstv[..len].copy_from_slice(sref);
            if !is_passthrough(ab, cx.binar) {
                quantize_rows(&mut dstv[..len], 1, len, ab, cx.binar);
            }
            ws.put(dst, dstv);
            if let (Src::Slot(s), Some(v)) = (src, srcv) {
                ws.put(s, v);
            }
        }
        Step::WQ { li, dst, scratch, int } => {
            let l = &cx.g.layers[li];
            let w = &cx.params[l.p_w].data;
            let wb = &cx.wbits[l.w_off..l.w_off + l.w_len];
            let rest = w.len() / l.w_len;
            let rep = if int.is_some() { wrep(wb, cx.binar) } else { WRep::F32 };
            if let (Some(iw), false) = (int, rep == WRep::F32) {
                // Integer path: quantize straight onto the int grid (the
                // same codes/scales `fake_quant_row` would produce).  The
                // f32 `dst` slot keeps garbage — the consuming Fc/Conv
                // re-derives the same `rep` and never reads it.
                let mut qv = ws.take_i(iw.qdst);
                let mut sv = ws.take(iw.wscales);
                if rep == WRep::I4 {
                    let mut qs = ws.take_i(iw.qscratch);
                    quantize_w_i8(w, rest, l.w_len, wb, &mut qs[..w.len()], &mut sv[..l.w_len]);
                    let plen = packed4_row_len(rest) * l.w_len;
                    pack_i4(&qs[..w.len()], rest, l.w_len, &mut qv[..plen]);
                    ws.put_i(iw.qscratch, qs);
                } else {
                    quantize_w_i8(w, rest, l.w_len, wb, &mut qv[..w.len()], &mut sv[..l.w_len]);
                }
                ws.put_i(iw.qdst, qv);
                ws.put(iw.wscales, sv);
                return;
            }
            let mut dstv = ws.take(dst);
            if is_passthrough(wb, cx.binar) {
                dstv[..w.len()].copy_from_slice(w);
            } else {
                let mut sc = ws.take(scratch);
                w_to_cmajor_into(w, rest, l.w_len, &mut sc[..w.len()]);
                quantize_rows(&mut sc[..w.len()], l.w_len, rest, wb, cx.binar);
                cmajor_to_w_into(&sc[..w.len()], rest, l.w_len, &mut dstv[..w.len()]);
                ws.put(scratch, sc);
            }
            ws.put(dst, dstv);
        }
        Step::Fc { li, xq, wq, dst, n, panel, int } => {
            let l = &cx.g.layers[li];
            let wlen = cx.params[l.p_w].data.len();
            let wb = &cx.wbits[l.w_off..l.w_off + l.w_len];
            let rep = if int.is_some() { wrep(wb, cx.binar) } else { WRep::F32 };
            if let (Some(ig), false) = (int, rep == WRep::F32) {
                let xqv = ws.take(xq);
                let mut dstv = ws.take(dst);
                let qwv = ws.take_i(ig.qw);
                let swv = ws.take(ig.wsc);
                let mut qav = ws.take_i(ig.qa);
                let mut asv = ws.take(ig.ascale);
                qfc_into(
                    &xqv[..n * l.cin],
                    n,
                    l.cin,
                    &qwv,
                    &swv[..l.w_len],
                    rep == WRep::I4,
                    l.cout,
                    &mut dstv[..n * l.cout],
                    &mut qav[..n * l.cin],
                    &mut asv[..n],
                    static_scale(cx, li),
                );
                add_bias(&mut dstv[..n * l.cout], l.cout, &cx.params[l.p_w + 1].data);
                ws.put(xq, xqv);
                ws.put_i(ig.qw, qwv);
                ws.put(ig.wsc, swv);
                ws.put_i(ig.qa, qav);
                ws.put(ig.ascale, asv);
                ws.put(dst, dstv);
                return;
            }
            let xqv = ws.take(xq);
            let wqv = ws.take(wq);
            let mut dstv = ws.take(dst);
            let mut panv = panel.map(|p| ws.take(p));
            let pan_len = matmul_panel_len(l.cin, l.cout);
            let pan_s: &mut [f32] = match &mut panv {
                Some(v) => &mut v[..pan_len],
                None => &mut [],
            };
            let out = &mut dstv[..n * l.cout];
            out.fill(0.0);
            matmul_acc_scratch(out, &xqv[..n * l.cin], &wqv[..wlen], n, l.cin, l.cout, pan_s);
            add_bias(out, l.cout, &cx.params[l.p_w + 1].data);
            if let (Some(p), Some(v)) = (panel, panv) {
                ws.put(p, v);
            }
            ws.put(xq, xqv);
            ws.put(wq, wqv);
            ws.put(dst, dstv);
        }
        Step::Conv { li, xq, wq, dst, patches, panel, d, int } => {
            let l = &cx.g.layers[li];
            let wlen = cx.params[l.p_w].data.len();
            let (ho, _, _) = same_pad(d.h, l.k, l.s);
            let (wo, _, _) = same_pad(d.w, l.k, l.s);
            let od_len = d.n * ho * wo * l.cout;
            let wb = &cx.wbits[l.w_off..l.w_off + l.w_len];
            let rep = if int.is_some() { wrep(wb, cx.binar) } else { WRep::F32 };
            if let (Some(ig), false) = (int, rep == WRep::F32) {
                let xqv = ws.take(xq);
                let mut dstv = ws.take(dst);
                let qwv = ws.take_i(ig.qw);
                let swv = ws.take(ig.wsc);
                let mut qpv = ws.take_i(ig.qa);
                let mut asv = ws.take(ig.ascale);
                let mut pv = patches.map(|p| ws.take(p));
                let patch_len = conv_patch_len(d, l.k, l.s);
                let patches_s: &mut [f32] = match &mut pv {
                    Some(v) => &mut v[..patch_len],
                    None => &mut [],
                };
                qconv2d_into(
                    &xqv[..d.elems()],
                    d,
                    &qwv,
                    &swv[..l.w_len],
                    rep == WRep::I4,
                    l.k,
                    l.s,
                    l.cout,
                    &mut dstv[..od_len],
                    patches_s,
                    &mut qpv[..conv_qpatch_len(d, l.k, l.s)],
                    &mut asv[..conv_qrows(d, l.k, l.s)],
                    static_scale(cx, li),
                );
                if let (Some(p), Some(v)) = (patches, pv) {
                    ws.put(p, v);
                }
                ws.put(xq, xqv);
                ws.put_i(ig.qw, qwv);
                ws.put(ig.wsc, swv);
                ws.put_i(ig.qa, qpv);
                ws.put(ig.ascale, asv);
                ws.put(dst, dstv);
                return;
            }
            let xqv = ws.take(xq);
            let wqv = ws.take(wq);
            let mut dstv = ws.take(dst);
            let mut pv = patches.map(|p| ws.take(p));
            let mut panv = panel.map(|p| ws.take(p));
            let patch_len = conv_patch_len(d, l.k, l.s);
            let pan_len = conv_panel_len(d, l.k, l.cout);
            let patches_s: &mut [f32] = match &mut pv {
                Some(v) => &mut v[..patch_len],
                None => &mut [],
            };
            let pan_s: &mut [f32] = match &mut panv {
                Some(v) => &mut v[..pan_len],
                None => &mut [],
            };
            conv2d_into(
                &xqv[..d.elems()],
                d,
                &wqv[..wlen],
                l.k,
                l.s,
                l.cout,
                &mut dstv[..od_len],
                patches_s,
                pan_s,
            );
            if let (Some(p), Some(v)) = (patches, pv) {
                ws.put(p, v);
            }
            if let (Some(p), Some(v)) = (panel, panv) {
                ws.put(p, v);
            }
            ws.put(xq, xqv);
            ws.put(wq, wqv);
            ws.put(dst, dstv);
        }
        Step::DwConv { li, xq, wq, dst, d, int } => {
            let l = &cx.g.layers[li];
            let wlen = cx.params[l.p_w].data.len();
            let (ho, _, _) = same_pad(d.h, l.k, l.s);
            let (wo, _, _) = same_pad(d.w, l.k, l.s);
            let od_len = d.n * ho * wo * d.c;
            let wb = &cx.wbits[l.w_off..l.w_off + l.w_len];
            let rep = if int.is_some() { wrep(wb, cx.binar) } else { WRep::F32 };
            if let (Some(id), false) = (int, rep == WRep::F32) {
                let xqv = ws.take(xq);
                let mut dstv = ws.take(dst);
                let qwv = ws.take_i(id.qw);
                let swv = ws.take(id.wsc);
                let mut qxv = ws.take_i(id.qx);
                let mut xsv = ws.take(id.xsc);
                qdwconv2d_into(
                    &xqv[..d.elems()],
                    d,
                    &qwv,
                    &swv[..l.w_len],
                    rep == WRep::I4,
                    l.k,
                    l.s,
                    &mut dstv[..od_len],
                    &mut qxv[..d.elems()],
                    &mut xsv[..dwconv_qrows(d)],
                    static_scale(cx, li),
                );
                ws.put(xq, xqv);
                ws.put_i(id.qw, qwv);
                ws.put(id.wsc, swv);
                ws.put_i(id.qx, qxv);
                ws.put(id.xsc, xsv);
                ws.put(dst, dstv);
                return;
            }
            let xqv = ws.take(xq);
            let wqv = ws.take(wq);
            let mut dstv = ws.take(dst);
            dwconv2d_into(&xqv[..d.elems()], d, &wqv[..wlen], l.k, l.s, &mut dstv[..od_len]);
            ws.put(xq, xqv);
            ws.put(wq, wqv);
            ws.put(dst, dstv);
        }
        Step::Gn { li, src, dst, d, cache } => {
            let l = &cx.g.layers[li];
            let gamma = &cx.params[l.p_w + 1].data;
            let beta = &cx.params[l.p_w + 2].data;
            let len = d.elems();
            let srcv = ws.take(src);
            let mut dstv = ws.take(dst);
            match cache {
                Some((xn, istd)) => {
                    let glen = d.n * gn_groups(d.c);
                    let mut xnv = ws.take(xn);
                    let mut isv = ws.take(istd);
                    group_norm_into(
                        &srcv[..len],
                        d,
                        gamma,
                        beta,
                        &mut dstv[..len],
                        Some((&mut xnv[..len], &mut isv[..glen])),
                    );
                    ws.put(xn, xnv);
                    ws.put(istd, isv);
                }
                None => {
                    group_norm_into(&srcv[..len], d, gamma, beta, &mut dstv[..len], None);
                }
            }
            ws.put(src, srcv);
            ws.put(dst, dstv);
        }
        Step::Bias { li, buf, c, len } => {
            let l = &cx.g.layers[li];
            let mut bufv = ws.take(buf);
            add_bias(&mut bufv[..len], c, &cx.params[l.p_w + 1].data);
            ws.put(buf, bufv);
        }
        Step::Relu { buf, save, len } => {
            let mut bufv = ws.take(buf);
            relu(&mut bufv[..len]);
            if let Some(s) = save {
                let mut sv = ws.take(s);
                sv[..len].copy_from_slice(&bufv[..len]);
                ws.put(s, sv);
            }
            ws.put(buf, bufv);
        }
        Step::Pool { src, dst, idx, d } => {
            let od_len = d.n * (d.h / 2) * (d.w / 2) * d.c;
            let srcv = ws.take(src);
            let mut dstv = ws.take(dst);
            match idx {
                Some(u) => {
                    let mut uv = ws.take_u(u);
                    let idx_out = Some(&mut uv[..od_len]);
                    maxpool2_into(&srcv[..d.elems()], d, &mut dstv[..od_len], idx_out);
                    ws.put_u(u, uv);
                }
                None => {
                    maxpool2_into(&srcv[..d.elems()], d, &mut dstv[..od_len], None);
                }
            }
            ws.put(src, srcv);
            ws.put(dst, dstv);
        }
        Step::Gap { src, dst, d } => {
            let srcv = ws.take(src);
            let mut dstv = ws.take(dst);
            gap_into(&srcv[..d.elems()], d, &mut dstv[..d.n * d.c]);
            ws.put(src, srcv);
            ws.put(dst, dstv);
        }
        Step::Concat { a, b, dst, d_a, d_b } => {
            let av = ws.take(a);
            let bv = ws.take(b);
            let mut dstv = ws.take(dst);
            let oc = d_a.c + d_b.c;
            for p in 0..d_a.n * d_a.h * d_a.w {
                dstv[p * oc..p * oc + d_a.c].copy_from_slice(&av[p * d_a.c..(p + 1) * d_a.c]);
                dstv[p * oc + d_a.c..(p + 1) * oc]
                    .copy_from_slice(&bv[p * d_b.c..(p + 1) * d_b.c]);
            }
            ws.put(a, av);
            ws.put(b, bv);
            ws.put(dst, dstv);
        }
        Step::Add { buf, add, len } => {
            let mut bufv = ws.take(buf);
            let addv = ws.take(add);
            add_vec(&mut bufv[..len], &addv[..len]);
            ws.put(buf, bufv);
            ws.put(add, addv);
        }
        Step::Copy { src, dst, len } => {
            let srcv = ws.take(src);
            let mut dstv = ws.take(dst);
            dstv[..len].copy_from_slice(&srcv[..len]);
            ws.put(src, srcv);
            ws.put(dst, dstv);
        }
        Step::BRelu { dy, out, len } => {
            let mut dyv = ws.take(dy);
            let outv = ws.take(out);
            relu_bwd(&mut dyv[..len], &outv[..len]);
            ws.put(dy, dyv);
            ws.put(out, outv);
        }
        Step::BGn { li, dy, dst, d, xn, istd } => {
            let l = &cx.g.layers[li];
            let gamma = &cx.params[l.p_w + 1].data;
            let len = d.elems();
            let glen = d.n * gn_groups(d.c);
            let dyv = ws.take(dy);
            let mut dstv = ws.take(dst);
            let xnv = ws.take(xn);
            let isv = ws.take(istd);
            let mut g1 = ws.take(cx.grad_slots[l.p_w + 1]);
            let mut g2 = ws.take(cx.grad_slots[l.p_w + 2]);
            g1[..d.c].fill(0.0);
            g2[..d.c].fill(0.0);
            group_norm_bwd_into(
                &dyv[..len],
                d,
                gamma,
                &xnv[..len],
                &isv[..glen],
                &mut dstv[..len],
                &mut g1[..d.c],
                &mut g2[..d.c],
            );
            ws.put(cx.grad_slots[l.p_w + 1], g1);
            ws.put(cx.grad_slots[l.p_w + 2], g2);
            ws.put(dy, dyv);
            ws.put(dst, dstv);
            ws.put(xn, xnv);
            ws.put(istd, isv);
        }
        Step::BBias { li, dy, c, len } => {
            let l = &cx.g.layers[li];
            let dyv = ws.take(dy);
            let mut g = ws.take(cx.grad_slots[l.p_w + 1]);
            g[..c].fill(0.0);
            bias_bwd_acc(&dyv[..len], c, &mut g[..c]);
            ws.put(cx.grad_slots[l.p_w + 1], g);
            ws.put(dy, dyv);
        }
        Step::BFc { li, xq, wq, dy, dst, n } => {
            let l = &cx.g.layers[li];
            let wlen = cx.params[l.p_w].data.len();
            let xqv = ws.take(xq);
            let wqv = ws.take(wq);
            let dyv = ws.take(dy);
            let mut dstv = ws.take(dst);
            let mut gb = ws.take(cx.grad_slots[l.p_w + 1]);
            gb[..l.cout].fill(0.0);
            bias_bwd_acc(&dyv[..n * l.cout], l.cout, &mut gb[..l.cout]);
            ws.put(cx.grad_slots[l.p_w + 1], gb);
            let mut gw = ws.take(cx.grad_slots[l.p_w]);
            gw[..wlen].fill(0.0);
            let (xqs, dys) = (&xqv[..n * l.cin], &dyv[..n * l.cout]);
            matmul_at_b_acc(&mut gw[..wlen], xqs, dys, n, l.cin, l.cout);
            ws.put(cx.grad_slots[l.p_w], gw);
            matmul_a_bt_into(
                &mut dstv[..n * l.cin],
                &dyv[..n * l.cout],
                &wqv[..wlen],
                n,
                l.cout,
                l.cin,
            );
            ws.put(xq, xqv);
            ws.put(wq, wqv);
            ws.put(dy, dyv);
            ws.put(dst, dstv);
        }
        Step::BConv { li, xq, wq, dy, dst, patches, dpatch, d } => {
            let l = &cx.g.layers[li];
            let wlen = cx.params[l.p_w].data.len();
            let (ho, _, _) = same_pad(d.h, l.k, l.s);
            let (wo, _, _) = same_pad(d.w, l.k, l.s);
            let dy_len = d.n * ho * wo * l.cout;
            let xqv = ws.take(xq);
            let wqv = ws.take(wq);
            let dyv = ws.take(dy);
            let mut dstv = ws.take(dst);
            let mut gw = ws.take(cx.grad_slots[l.p_w]);
            gw[..wlen].fill(0.0);
            match (patches, dpatch) {
                (Some(p), Some(dp)) => {
                    let plen = conv_patch_len(d, l.k, l.s);
                    let mut pv = ws.take(p);
                    let mut dpv = ws.take(dp);
                    conv2d_bwd_into(
                        &xqv[..d.elems()],
                        d,
                        &wqv[..wlen],
                        l.k,
                        l.s,
                        l.cout,
                        &dyv[..dy_len],
                        &mut dstv[..d.elems()],
                        &mut gw[..wlen],
                        &mut pv[..plen],
                        &mut dpv[..plen],
                    );
                    ws.put(p, pv);
                    ws.put(dp, dpv);
                }
                _ => {
                    conv2d_bwd_into(
                        &xqv[..d.elems()],
                        d,
                        &wqv[..wlen],
                        l.k,
                        l.s,
                        l.cout,
                        &dyv[..dy_len],
                        &mut dstv[..d.elems()],
                        &mut gw[..wlen],
                        &mut [],
                        &mut [],
                    );
                }
            }
            ws.put(cx.grad_slots[l.p_w], gw);
            ws.put(xq, xqv);
            ws.put(wq, wqv);
            ws.put(dy, dyv);
            ws.put(dst, dstv);
        }
        Step::BDwConv { li, xq, wq, dy, dst, d } => {
            let l = &cx.g.layers[li];
            let wlen = cx.params[l.p_w].data.len();
            let (ho, _, _) = same_pad(d.h, l.k, l.s);
            let (wo, _, _) = same_pad(d.w, l.k, l.s);
            let dy_len = d.n * ho * wo * d.c;
            let xqv = ws.take(xq);
            let wqv = ws.take(wq);
            let dyv = ws.take(dy);
            let mut dstv = ws.take(dst);
            let mut gw = ws.take(cx.grad_slots[l.p_w]);
            gw[..wlen].fill(0.0);
            dwconv2d_bwd_into(
                &xqv[..d.elems()],
                d,
                &wqv[..wlen],
                l.k,
                l.s,
                &dyv[..dy_len],
                &mut dstv[..d.elems()],
                &mut gw[..wlen],
            );
            ws.put(cx.grad_slots[l.p_w], gw);
            ws.put(xq, xqv);
            ws.put(wq, wqv);
            ws.put(dy, dyv);
            ws.put(dst, dstv);
        }
        Step::BPool { dy, idx, dst, in_d } => {
            let dy_len = in_d.n * (in_d.h / 2) * (in_d.w / 2) * in_d.c;
            let dyv = ws.take(dy);
            let mut dstv = ws.take(dst);
            let uv = ws.take_u(idx);
            maxpool2_bwd_into(&dyv[..dy_len], &uv[..dy_len], &mut dstv[..in_d.elems()]);
            ws.put_u(idx, uv);
            ws.put(dy, dyv);
            ws.put(dst, dstv);
        }
        Step::BGap { dy, dst, d } => {
            let dyv = ws.take(dy);
            let mut dstv = ws.take(dst);
            gap_bwd_into(&dyv[..d.n * d.c], d, &mut dstv[..d.elems()]);
            ws.put(dy, dyv);
            ws.put(dst, dstv);
        }
        Step::BSplit { src, a, b, d, ca } => {
            let pixels = d.n * d.h * d.w;
            let cb = d.c - ca;
            let srcv = ws.take(src);
            let mut av = ws.take(a);
            let mut bv = ws.take(b);
            for p in 0..pixels {
                av[p * ca..(p + 1) * ca].copy_from_slice(&srcv[p * d.c..p * d.c + ca]);
                bv[p * cb..(p + 1) * cb].copy_from_slice(&srcv[p * d.c + ca..(p + 1) * d.c]);
            }
            ws.put(src, srcv);
            ws.put(a, av);
            ws.put(b, bv);
        }
    }
}

/// Shared input validation for both executors.
fn check_inputs(
    plan: &Plan,
    g: &ModelGraph,
    images: &Tensor,
    labels: &[i32],
    wbits: &[f32],
    abits: &[f32],
) -> anyhow::Result<()> {
    let d0 = plan.d0;
    anyhow::ensure!(
        images.shape == vec![d0.n, d0.h, d0.w, d0.c],
        "images shape {:?} vs plan {:?}",
        images.shape,
        [d0.n, d0.h, d0.w, d0.c]
    );
    anyhow::ensure!(wbits.len() == g.w_channels, "wbits len {} vs {}", wbits.len(), g.w_channels);
    anyhow::ensure!(abits.len() == g.a_channels, "abits len {} vs {}", abits.len(), g.a_channels);
    anyhow::ensure!(labels.len() == plan.n, "labels len {} vs batch {}", labels.len(), plan.n);
    Ok(())
}

/// Execute an eval plan: forward + accuracy/loss head.  Returns (correct,
/// loss) — byte-identical to the tree-walk.  `acts` is the calibrated
/// per-layer activation-max table (static scales) or `None` for dynamic
/// per-row scales.
#[allow(clippy::too_many_arguments)]
pub fn run_eval(
    plan: &Plan,
    g: &ModelGraph,
    binar: bool,
    params: &[&Tensor],
    images: &Tensor,
    labels: &[i32],
    wbits: &[f32],
    abits: &[f32],
    acts: Option<&[f32]>,
    ws: &mut Workspace,
) -> anyhow::Result<(f32, f32)> {
    check_inputs(plan, g, images, labels, wbits, abits)?;
    if let Some(t) = acts {
        anyhow::ensure!(t.len() == g.layers.len(), "act table len {} vs {}", t.len(), g.layers.len());
    }
    ws.ensure(plan);
    let cx = Ctx {
        g,
        binar,
        params,
        images: &images.data,
        wbits,
        abits,
        act_maxes: acts,
        grad_slots: &plan.grad_slots,
    };
    exec_steps(&plan.steps[..plan.fwd_len], &cx, ws);
    let logits = ws.take(plan.logits);
    let (correct, loss) =
        softmax_xent_into(&logits[..plan.n * plan.classes], plan.n, plan.classes, labels, None);
    ws.put(plan.logits, logits);
    Ok((correct, loss))
}

/// Execute a train plan: forward with tapes, loss head with gradient, STE
/// backward, SGD-momentum update.  Returns the artifact outputs
/// `(new_params…, new_momenta…, loss)` — byte-identical to the tree-walk.
#[allow(clippy::too_many_arguments)]
pub fn run_train(
    plan: &Plan,
    g: &ModelGraph,
    binar: bool,
    params: &[&Tensor],
    momenta: &[&Tensor],
    images: &Tensor,
    labels: &[i32],
    wbits: &[f32],
    abits: &[f32],
    lr: f32,
    ws: &mut Workspace,
) -> anyhow::Result<Vec<Value>> {
    check_inputs(plan, g, images, labels, wbits, abits)?;
    anyhow::ensure!(momenta.len() == params.len(), "momenta arity");
    ws.ensure(plan);
    let cx = Ctx {
        g,
        binar,
        params,
        images: &images.data,
        wbits,
        abits,
        act_maxes: None,
        grad_slots: &plan.grad_slots,
    };
    exec_steps(&plan.steps[..plan.fwd_len], &cx, ws);

    let (n, classes) = (plan.n, plan.classes);
    let logits = ws.take(plan.logits);
    let mut dlogits = ws.take(plan.dlogits);
    let (_, loss) = softmax_xent_into(
        &logits[..n * classes],
        n,
        classes,
        labels,
        Some(&mut dlogits[..n * classes]),
    );
    ws.put(plan.logits, logits);
    ws.put(plan.dlogits, dlogits);

    exec_steps(&plan.steps[plan.fwd_len..], &cx, ws);

    // SGD with momentum 0.9 (same loop as the walk): new_m = 0.9·m + g,
    // new_p = p − lr·new_m.  Outputs are necessarily fresh allocations.
    let np = params.len();
    let mut outs: Vec<Value> = Vec::with_capacity(2 * np + 1);
    let mut new_momenta: Vec<Value> = Vec::with_capacity(np);
    for i in 0..np {
        let grad = ws.slice(plan.grad_slots[i], params[i].data.len());
        let mut m = momenta[i].data.clone();
        for (mv, &gv) in m.iter_mut().zip(grad) {
            *mv = 0.9 * *mv + gv;
        }
        let mut p = params[i].data.clone();
        for (pv, &mv) in p.iter_mut().zip(&m) {
            *pv -= lr * mv;
        }
        outs.push(Value::f32(params[i].shape.clone(), p));
        new_momenta.push(Value::f32(momenta[i].shape.clone(), m));
    }
    outs.extend(new_momenta);
    outs.push(Value::scalar(loss));
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::zoo::model_graph;

    #[test]
    fn planner_reuses_released_slots_best_fit() {
        let mut p = Planner::new();
        let a = p.alloc(100);
        let b = p.alloc(50);
        let c = p.alloc(10);
        assert_eq!([a, b, c], [0, 1, 2]);
        p.release(a);
        p.release(c);
        // 40 fits best into the 100-cap? best fit picks the smallest cap
        // ≥ len — that's slot a (100) vs c (10): c too small, a chosen.
        assert_eq!(p.alloc(40), a);
        // 5 best-fits into c.
        assert_eq!(p.alloc(5), c);
        // Nothing free: grows a new slot.
        assert_eq!(p.alloc(7), 3);
        p.release(b);
        // Oversized request grows the largest free slot instead of minting.
        assert_eq!(p.alloc(500), b);
        let caps = p.finish();
        assert_eq!(caps, vec![100, 500, 10, 7]);
    }

    #[test]
    fn eval_plans_reuse_slots_aggressively() {
        for name in crate::runtime::reference::zoo::MODEL_NAMES {
            let g = model_graph(name).unwrap();
            let plan = compile_eval(&g, 4);
            let (fwd, bwd) = plan.step_counts();
            assert!(fwd > 0, "{name}");
            assert_eq!(bwd, 0, "{name}");
            assert!(plan.uslot_caps.is_empty(), "{name}: eval keeps no pool tape");
            assert!(plan.grad_slots.is_empty(), "{name}");
            // Liveness must compress well below one-slot-per-intermediate:
            // each layer emits ≥ 4 virtual buffers but only a handful can
            // overlap.
            assert!(
                plan.slot_caps.len() < 4 * g.layers.len(),
                "{name}: {} slots for {} layers",
                plan.slot_caps.len(),
                g.layers.len()
            );
        }
    }

    #[test]
    fn train_plans_pin_tapes_and_grads() {
        let g = model_graph("cif10").unwrap();
        let plan = compile_train(&g, 2);
        let (fwd, bwd) = plan.step_counts();
        assert!(fwd > 0 && bwd > 0);
        assert_eq!(plan.grad_slots.len(), g.params.len());
        // Grad slots are distinct physical slots.
        let mut gs = plan.grad_slots.clone();
        gs.sort_unstable();
        gs.dedup();
        assert_eq!(gs.len(), g.params.len());
        // logits / dlogits never alias (both pinned).
        assert_ne!(plan.logits, plan.dlogits);
        // sqnet train keeps its two pool argmax tapes.
        let sq = compile_train(&model_graph("sqnet").unwrap(), 2);
        assert_eq!(sq.uslot_caps.len(), 2);
    }

    #[test]
    fn workspace_grows_monotonically_and_reports_footprint() {
        let g = model_graph("cif10").unwrap();
        let small = compile_eval(&g, 2);
        let big = compile_eval(&g, 4);
        let mut ws = Workspace::new();
        ws.ensure(&small);
        let f_small = ws.f32_len();
        assert!(f_small > 0);
        ws.ensure(&big);
        let f_big = ws.f32_len();
        assert!(f_big >= f_small);
        // Re-ensuring either plan is a no-op once warm.
        ws.ensure(&small);
        ws.ensure(&big);
        assert_eq!(ws.f32_len(), f_big);
    }
}
