//! The pure-Rust reference execution backend: interprets the manifest's
//! model and agent graphs directly — conv/fc forward with per-channel
//! fake-quantization/binarization for eval, STE backward + SGD-momentum
//! for training, and the DDPG actor/critic MLPs with the fused
//! Adam/soft-target update — so pretrain, search, sweep, baselines,
//! fine-tune and repro all run with **zero AOT artifacts** and no native
//! XLA library.
//!
//! Numerics track the JAX graphs within float tolerance (same padding
//! rules, GroupNorm groups/ε, ties-to-even rounding in the quantizers);
//! the opt-in PJRT CI lane cross-checks eval accuracy between backends.
//!
//! Compute routes through the packed, cache-blocked kernels in `kernels/`,
//! and independent eval batches fan out across the backend's persistent
//! worker pool (`execute_batch`) — both bit-exact against the serial naive
//! path at every thread count (`tests/determinism.rs`,
//! `tests/properties.rs`).
//!
//! Model and agent graphs execute through the **planned engine**
//! (`plan.rs`): each graph compiles once — at `Executable` build time —
//! into a flat step list with liveness-assigned buffer slots, then
//! dispatches against reusable per-worker `Workspace` arenas handed out by
//! `util::pool::ScratchArena`, so steady-state batches allocate nothing.
//! Planned output is byte-identical to the retained tree-walk
//! (`tests/plan_engine.rs`).

pub mod agent_exec;
pub mod kernels;
pub mod model_exec;
pub mod nn;
pub mod plan;
pub mod quantize;
pub mod zoo;

pub use zoo::builtin_manifest;

use std::sync::Arc;

use crate::runtime::backend::{Backend, Executable};
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::util::pool::WorkerPool;

/// The reference backend owns the persistent worker pool its eval
/// executables fan batches across; everything else about an executable is
/// self-contained (graph + mode), built straight from the builtin zoo.
#[derive(Debug)]
pub struct RefBackend {
    pool: Arc<WorkerPool>,
}

impl RefBackend {
    /// Serial until [`Backend::set_parallelism`] hands over the resolved
    /// thread budget (the `Runtime` does so before any load).
    pub fn new() -> RefBackend {
        RefBackend { pool: Arc::new(WorkerPool::new(1)) }
    }
}

impl Default for RefBackend {
    fn default() -> RefBackend {
        RefBackend::new()
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn set_parallelism(&mut self, threads: usize) {
        self.pool = Arc::new(WorkerPool::new(threads));
    }

    fn load(
        &mut self,
        spec: &ArtifactSpec,
        _manifest: &Manifest,
    ) -> anyhow::Result<Box<dyn Executable>> {
        let name = spec.name.as_str();
        if let Some(s) = name.strip_prefix("ddpg_act_s") {
            let s_dim: usize = s.parse()?;
            return Ok(Box::new(agent_exec::RefDdpgAct::new(s_dim, zoo::HIDDEN, zoo::ACT_BATCH)));
        }
        if let Some(s) = name.strip_prefix("ddpg_update_s") {
            let s_dim: usize = s.parse()?;
            return Ok(Box::new(agent_exec::RefDdpgUpdate::new(s_dim)));
        }
        // "{model}_{eval|train}_{quant|binar}"
        for (infix, is_train) in [("_eval_", false), ("_train_", true)] {
            if let Some(pos) = name.find(infix) {
                let model = &name[..pos];
                let mode = &name[pos + infix.len()..];
                let binar = match mode {
                    "quant" => false,
                    "binar" => true,
                    other => anyhow::bail!("artifact {name}: unknown mode {other:?}"),
                };
                let graph = zoo::model_graph(model)?;
                return Ok(if is_train {
                    Box::new(model_exec::RefModelTrain::new(graph, binar))
                } else {
                    Box::new(model_exec::RefModelEval::new(graph, binar, self.pool.clone()))
                });
            }
        }
        anyhow::bail!("reference backend cannot interpret artifact {name:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(name: &str) -> anyhow::Result<Box<dyn Executable>> {
        let m = builtin_manifest();
        let spec = m.artifact(name)?.clone();
        RefBackend::new().load(&spec, &m)
    }

    #[test]
    fn every_builtin_artifact_loads() {
        let m = builtin_manifest();
        for name in m.artifacts.keys() {
            assert!(load(name).is_ok(), "{name} must load");
        }
    }

    #[test]
    fn unknown_artifacts_rejected() {
        let m = builtin_manifest();
        let mut spec = m.artifact("cif10_eval_quant").unwrap().clone();
        spec.name = "cif10_compile_quant".into();
        assert!(RefBackend::new().load(&spec, &m).is_err());
        spec.name = "cif10_eval_fp8".into();
        assert!(RefBackend::new().load(&spec, &m).is_err());
    }
}
