//! The model zoo, mirrored from `python/compile/model.py` /
//! `python/compile/agent.py` node-for-node so the reference backend can
//! synthesize the same manifest (layer metadata, parameter specs, artifact
//! shapes) the AOT exporter writes — with zero artifacts on disk.
//!
//! Any change to the python specs must be mirrored here (and vice versa);
//! `tests/runtime_roundtrip.rs` cross-checks the two when the PJRT lane
//! runs with real artifacts.

use std::collections::BTreeMap;

use crate::runtime::manifest::{
    AgentMeta, ArtifactSpec, LayerMeta, Manifest, ModelMeta, ParamSpec, TensorSpec,
};

pub const IMAGE_HW: usize = 32;
pub const NUM_CLASSES: usize = 10;
pub const EVAL_BATCH: usize = 256;
pub const TRAIN_BATCH: usize = 128;

pub const HIDDEN: usize = 300;
pub const ACT_BATCH: usize = 128;
pub const UPD_BATCH: usize = 64;
pub const ACTION_SCALE: f64 = 32.0;

pub const MODEL_NAMES: [&str; 4] = ["cif10", "res18", "sqnet", "monet"];

/// Architecture node mini-DSL (python `SPECS`).
#[derive(Debug, Clone, Copy)]
pub enum Node {
    /// Plain conv; `norm=false, relu=false` is the sqnet classifier conv.
    Conv { k: usize, s: usize, cout: usize, norm: bool, relu: bool },
    Fc { cout: usize },
    /// 2×2 max pool, stride 2, VALID.
    Pool,
    /// Global average pool over H×W (covers python's gap and gap_logits).
    Gap,
    /// ResNet basic block: conv3(s)+relu → conv3(1) → (+proj?) → relu.
    Basic { cout: usize, s: usize },
    /// SqueezeNet fire: squeeze1 → concat(expand1, expand3).
    Fire { sq: usize, e1: usize, e3: usize },
    /// MobileNetV2 inverted residual: expand1 → dw3(s) → project1 (+skip).
    Irb { t: usize, cout: usize, s: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LType {
    Conv,
    DwConv,
    Fc,
}

impl LType {
    pub fn as_str(&self) -> &'static str {
        match self {
            LType::Conv => "conv",
            LType::DwConv => "dwconv",
            LType::Fc => "fc",
        }
    }
}

/// One primitive quantizable layer with everything the interpreter needs
/// (a superset of the manifest's `LayerMeta`: norm/activation flags and the
/// parameter-list offset).
#[derive(Debug, Clone)]
pub struct LayerDef {
    pub name: String,
    pub typ: LType,
    pub k: usize,
    pub s: usize,
    pub cin: usize,
    pub cout: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub h_out: usize,
    pub w_out: usize,
    pub norm: bool,
    pub relu: bool,
    pub macs: u64,
    pub w_off: usize,
    pub w_len: usize,
    pub a_off: usize,
    pub a_len: usize,
    /// Index of `{name}.w` in the manifest param list; `.g`/`.bta` (norm)
    /// or `.b` (bias) follow at `p_w + 1` (+2).
    pub p_w: usize,
}

/// A whole model: the node program plus the flattened layer/param layout.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub layers: Vec<LayerDef>,
    pub params: Vec<ParamSpec>,
    pub w_channels: usize,
    pub a_channels: usize,
    pub total_macs: u64,
}

pub fn spec(name: &str) -> anyhow::Result<Vec<Node>> {
    use Node::*;
    let conv = |k, s, cout| Conv { k, s, cout, norm: true, relu: true };
    Ok(match name {
        // The paper's CIFAR10-7CNN: 7 conv layers + classifier.
        "cif10" => vec![
            conv(3, 1, 16),
            conv(3, 1, 16),
            conv(3, 2, 32),
            conv(3, 1, 32),
            conv(3, 2, 64),
            conv(3, 1, 64),
            conv(3, 1, 64),
            Gap,
            Fc { cout: NUM_CLASSES },
        ],
        // ResNet-18 topology at CIFAR scale: stem + 4 stages × 2 blocks.
        "res18" => vec![
            conv(3, 1, 16),
            Basic { cout: 16, s: 1 },
            Basic { cout: 16, s: 1 },
            Basic { cout: 32, s: 2 },
            Basic { cout: 32, s: 1 },
            Basic { cout: 64, s: 2 },
            Basic { cout: 64, s: 1 },
            Basic { cout: 128, s: 2 },
            Basic { cout: 128, s: 1 },
            Gap,
            Fc { cout: NUM_CLASSES },
        ],
        // SqueezeNet-V1 topology: stem + fire modules + conv classifier.
        "sqnet" => vec![
            conv(3, 1, 32),
            Pool,
            Fire { sq: 16, e1: 32, e3: 32 },
            Fire { sq: 16, e1: 32, e3: 32 },
            Pool,
            Fire { sq: 32, e1: 64, e3: 64 },
            Fire { sq: 32, e1: 64, e3: 64 },
            Conv { k: 1, s: 1, cout: NUM_CLASSES, norm: false, relu: false },
            Gap, // gap_logits
        ],
        // MobileNetV2 topology: stem + inverted-residual blocks.
        "monet" => vec![
            conv(3, 1, 16),
            Irb { t: 1, cout: 16, s: 1 },
            Irb { t: 3, cout: 24, s: 2 },
            Irb { t: 3, cout: 24, s: 1 },
            Irb { t: 3, cout: 32, s: 2 },
            Irb { t: 3, cout: 32, s: 1 },
            Conv { k: 1, s: 1, cout: 96, norm: true, relu: true },
            Gap,
            Fc { cout: NUM_CLASSES },
        ],
        other => anyhow::bail!("unknown zoo model {other:?}"),
    })
}

/// Metadata walker (python `MetaBackend` + `_walk`): expands the node
/// program into the primitive layer list and parameter specs, assigning
/// the flat weight/activation channel offsets.
struct MetaWalk {
    layers: Vec<LayerDef>,
    params: Vec<ParamSpec>,
    w_channels: usize,
    a_channels: usize,
    li: usize,
}

impl MetaWalk {
    fn new() -> MetaWalk {
        MetaWalk { layers: Vec::new(), params: Vec::new(), w_channels: 0, a_channels: 0, li: 0 }
    }

    fn nm(&mut self, base: &str) -> String {
        self.li += 1;
        format!("l{:02}_{base}", self.li)
    }

    #[allow(clippy::too_many_arguments)]
    fn layer(
        &mut self,
        name: String,
        typ: LType,
        k: usize,
        s: usize,
        cin: usize,
        cout: usize,
        h: usize,
        w: usize,
        norm: bool,
        relu: bool,
    ) {
        let h_out = (h + s - 1) / s;
        let w_out = (w + s - 1) / s;
        let groups = if typ == LType::DwConv { cin } else { 1 };
        // MACs for one inference (the bit-independent logic_t of Eq. 1).
        let macs: u64 = match typ {
            LType::Fc => (cin * cout) as u64,
            LType::DwConv => (h_out * w_out * k * k * cin) as u64,
            LType::Conv => (h_out * w_out * k * k * (cin / groups) * cout) as u64,
        };
        let n_act = if typ == LType::Fc { 1 } else { cin };
        let p_w = self.params.len();
        match typ {
            LType::Fc => {
                self.params.push(ParamSpec {
                    name: format!("{name}.w"),
                    shape: vec![cin, cout],
                    init: "he".into(),
                });
                self.params.push(ParamSpec {
                    name: format!("{name}.b"),
                    shape: vec![cout],
                    init: "zeros".into(),
                });
            }
            _ => {
                let kk = if typ == LType::DwConv {
                    vec![k, k, 1, cin]
                } else {
                    vec![k, k, cin / groups, cout]
                };
                self.params.push(ParamSpec {
                    name: format!("{name}.w"),
                    shape: kk,
                    init: "he".into(),
                });
                if norm {
                    self.params.push(ParamSpec {
                        name: format!("{name}.g"),
                        shape: vec![cout],
                        init: "ones".into(),
                    });
                    self.params.push(ParamSpec {
                        name: format!("{name}.bta"),
                        shape: vec![cout],
                        init: "zeros".into(),
                    });
                } else {
                    self.params.push(ParamSpec {
                        name: format!("{name}.b"),
                        shape: vec![cout],
                        init: "zeros".into(),
                    });
                }
            }
        }
        self.layers.push(LayerDef {
            name,
            typ,
            k,
            s,
            cin,
            cout,
            h_in: h,
            w_in: w,
            h_out,
            w_out,
            norm,
            relu,
            macs,
            w_off: self.w_channels,
            w_len: cout,
            a_off: self.a_channels,
            a_len: n_act,
            p_w,
        });
        self.w_channels += cout;
        self.a_channels += n_act;
    }
}

pub fn model_graph(name: &str) -> anyhow::Result<ModelGraph> {
    let nodes = spec(name)?;
    let mut mw = MetaWalk::new();
    let (mut h, mut w, mut c) = (IMAGE_HW, IMAGE_HW, 3usize);
    for node in &nodes {
        match *node {
            Node::Conv { k, s, cout, norm, relu } => {
                let nm = mw.nm("conv");
                mw.layer(nm, LType::Conv, k, s, c, cout, h, w, norm, relu);
                h = (h + s - 1) / s;
                w = (w + s - 1) / s;
                c = cout;
            }
            Node::Fc { cout } => {
                let nm = mw.nm("fc");
                mw.layer(nm, LType::Fc, 1, 1, c, cout, 1, 1, false, false);
                c = cout;
            }
            Node::Pool => {
                h /= 2;
                w /= 2;
            }
            Node::Gap => {
                h = 1;
                w = 1;
            }
            Node::Basic { cout, s } => {
                let proj = s != 1 || c != cout;
                let n1 = mw.nm("conv");
                mw.layer(n1, LType::Conv, 3, s, c, cout, h, w, true, true);
                let h2 = (h + s - 1) / s;
                let w2 = (w + s - 1) / s;
                let n2 = mw.nm("conv");
                mw.layer(n2, LType::Conv, 3, 1, cout, cout, h2, w2, true, false);
                if proj {
                    let n3 = mw.nm("proj");
                    mw.layer(n3, LType::Conv, 1, s, c, cout, h, w, true, false);
                }
                h = h2;
                w = w2;
                c = cout;
            }
            Node::Fire { sq, e1, e3 } => {
                let n1 = mw.nm("squeeze");
                mw.layer(n1, LType::Conv, 1, 1, c, sq, h, w, true, true);
                let n2 = mw.nm("expand1");
                mw.layer(n2, LType::Conv, 1, 1, sq, e1, h, w, true, true);
                let n3 = mw.nm("expand3");
                mw.layer(n3, LType::Conv, 3, 1, sq, e3, h, w, true, true);
                c = e1 + e3;
            }
            Node::Irb { t, cout, s } => {
                let cexp = c * t;
                if t != 1 {
                    let n1 = mw.nm("expand");
                    mw.layer(n1, LType::Conv, 1, 1, c, cexp, h, w, true, true);
                }
                let n2 = mw.nm("dw");
                mw.layer(n2, LType::DwConv, 3, s, cexp, cexp, h, w, true, true);
                let h2 = (h + s - 1) / s;
                let w2 = (w + s - 1) / s;
                let n3 = mw.nm("project");
                mw.layer(n3, LType::Conv, 1, 1, cexp, cout, h2, w2, true, false);
                h = h2;
                w = w2;
                c = cout;
            }
        }
    }
    let total_macs = mw.layers.iter().map(|l| l.macs).sum();
    Ok(ModelGraph {
        name: name.to_string(),
        nodes,
        layers: mw.layers,
        params: mw.params,
        w_channels: mw.w_channels,
        a_channels: mw.a_channels,
        total_macs,
    })
}

pub fn model_meta(g: &ModelGraph) -> ModelMeta {
    ModelMeta {
        name: g.name.clone(),
        image_hw: IMAGE_HW,
        num_classes: NUM_CLASSES,
        eval_batch: EVAL_BATCH,
        train_batch: TRAIN_BATCH,
        layers: g
            .layers
            .iter()
            .map(|l| LayerMeta {
                name: l.name.clone(),
                typ: l.typ.as_str().to_string(),
                k: l.k,
                stride: l.s,
                cin: l.cin,
                cout: l.cout,
                h_in: l.h_in,
                w_in: l.w_in,
                h_out: l.h_out,
                w_out: l.w_out,
                macs: l.macs,
                w_off: l.w_off,
                w_len: l.w_len,
                a_off: l.a_off,
                a_len: l.a_len,
            })
            .collect(),
        params: g.params.clone(),
        w_channels: g.w_channels,
        a_channels: g.a_channels,
        total_macs: g.total_macs,
    }
}

pub fn actor_shapes(s: usize) -> Vec<Vec<usize>> {
    vec![
        vec![s, HIDDEN],
        vec![HIDDEN],
        vec![HIDDEN, HIDDEN],
        vec![HIDDEN],
        vec![HIDDEN, 1],
        vec![1],
    ]
}

pub fn critic_shapes(s: usize) -> Vec<Vec<usize>> {
    // Critic consumes state ⊕ action.
    actor_shapes(s + 1)
}

pub fn agent_meta(s_dim: usize) -> AgentMeta {
    AgentMeta {
        s_dim,
        hidden: HIDDEN,
        act_batch: ACT_BATCH,
        upd_batch: UPD_BATCH,
        action_scale: ACTION_SCALE,
        actor_shapes: actor_shapes(s_dim),
        critic_shapes: critic_shapes(s_dim),
    }
}

fn f32s(shape: &[usize]) -> TensorSpec {
    TensorSpec { shape: shape.to_vec(), dtype: "f32".into() }
}

fn scalar() -> TensorSpec {
    TensorSpec { shape: vec![], dtype: "f32".into() }
}

fn model_artifacts(g: &ModelGraph, out: &mut BTreeMap<String, ArtifactSpec>) {
    let params: Vec<TensorSpec> = g.params.iter().map(|p| f32s(&p.shape)).collect();
    for mode in ["quant", "binar"] {
        // eval(params..., images, labels, wbits, abits) -> (correct, loss)
        let mut inputs = params.clone();
        inputs.push(f32s(&[EVAL_BATCH, IMAGE_HW, IMAGE_HW, 3]));
        inputs.push(TensorSpec { shape: vec![EVAL_BATCH], dtype: "s32".into() });
        inputs.push(f32s(&[g.w_channels]));
        inputs.push(f32s(&[g.a_channels]));
        let name = format!("{}_eval_{mode}", g.name);
        out.insert(
            name.clone(),
            ArtifactSpec {
                name,
                file: "<builtin>".into(),
                inputs,
                outputs: vec![scalar(), scalar()],
            },
        );
        // train(params..., momenta..., images, labels, wbits, abits, lr)
        //   -> (new_params..., new_momenta..., loss)
        let mut inputs = params.clone();
        inputs.extend(params.clone());
        inputs.push(f32s(&[TRAIN_BATCH, IMAGE_HW, IMAGE_HW, 3]));
        inputs.push(TensorSpec { shape: vec![TRAIN_BATCH], dtype: "s32".into() });
        inputs.push(f32s(&[g.w_channels]));
        inputs.push(f32s(&[g.a_channels]));
        inputs.push(scalar());
        let mut outputs = params.clone();
        outputs.extend(params.clone());
        outputs.push(scalar());
        let name = format!("{}_train_{mode}", g.name);
        out.insert(
            name.clone(),
            ArtifactSpec { name, file: "<builtin>".into(), inputs, outputs },
        );
    }
}

fn agent_artifacts(s_dim: usize, out: &mut BTreeMap<String, ArtifactSpec>) {
    let a6: Vec<TensorSpec> = actor_shapes(s_dim).iter().map(|s| f32s(s)).collect();
    let c6: Vec<TensorSpec> = critic_shapes(s_dim).iter().map(|s| f32s(s)).collect();

    // act(actor..., states) -> actions
    let mut inputs = a6.clone();
    inputs.push(f32s(&[ACT_BATCH, s_dim]));
    let name = format!("ddpg_act_s{s_dim}");
    out.insert(
        name.clone(),
        ArtifactSpec {
            name,
            file: "<builtin>".into(),
            inputs,
            outputs: vec![f32s(&[ACT_BATCH, 1])],
        },
    );

    // update(nets + targets + adam moments + t + batch + hypers)
    //   -> (new nets + targets + moments, t+1, critic_loss, actor_loss)
    let mut inputs = Vec::new();
    inputs.extend(a6.clone());
    inputs.extend(c6.clone());
    inputs.extend(a6.clone());
    inputs.extend(c6.clone());
    inputs.extend(a6.clone());
    inputs.extend(a6.clone());
    inputs.extend(c6.clone());
    inputs.extend(c6.clone());
    inputs.push(scalar()); // t
    let b = UPD_BATCH;
    inputs.push(f32s(&[b, s_dim]));
    inputs.push(f32s(&[b, 1]));
    inputs.push(f32s(&[b, 1]));
    inputs.push(f32s(&[b, s_dim]));
    inputs.push(f32s(&[b, 1]));
    for _ in 0..4 {
        inputs.push(scalar()); // gamma, tau, lr_a, lr_c
    }
    let mut outputs = Vec::new();
    outputs.extend(a6.clone());
    outputs.extend(c6.clone());
    outputs.extend(a6.clone());
    outputs.extend(c6.clone());
    outputs.extend(a6.clone());
    outputs.extend(a6);
    outputs.extend(c6.clone());
    outputs.extend(c6);
    outputs.push(scalar()); // t+1
    outputs.push(scalar()); // critic loss
    outputs.push(scalar()); // actor loss
    let name = format!("ddpg_update_s{s_dim}");
    out.insert(name.clone(), ArtifactSpec { name, file: "<builtin>".into(), inputs, outputs });
}

/// The complete manifest the reference backend serves — same content the
/// AOT exporter writes to `artifacts/manifest.json`, minus the HLO files.
pub fn builtin_manifest() -> Manifest {
    let mut artifacts = BTreeMap::new();
    let mut models = BTreeMap::new();
    for name in MODEL_NAMES {
        let g = model_graph(name).expect("builtin zoo");
        model_artifacts(&g, &mut artifacts);
        models.insert(name.to_string(), model_meta(&g));
    }
    let mut agents = BTreeMap::new();
    for s_dim in [16usize, 17] {
        agents.insert(format!("s{s_dim}"), agent_meta(s_dim));
        agent_artifacts(s_dim, &mut artifacts);
    }
    Manifest { artifacts, models, agents }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cif10_layout_matches_paper_cnn() {
        let g = model_graph("cif10").unwrap();
        assert_eq!(g.layers.len(), 8); // 7 convs + fc
        assert_eq!(g.layers[0].name, "l01_conv");
        assert_eq!(g.layers[7].name, "l08_fc");
        assert_eq!(g.layers[7].typ, LType::Fc);
        assert_eq!(g.layers[7].cin, 64);
        assert_eq!(g.layers[7].a_len, 1);
        // l01: 32×32×3×3×3×16 MACs.
        assert_eq!(g.layers[0].macs, (32 * 32 * 9 * 3 * 16) as u64);
        assert_eq!(g.w_channels, 16 + 16 + 32 + 32 + 64 + 64 + 64 + 10);
        assert_eq!(g.a_channels, 3 + 16 + 16 + 32 + 32 + 64 + 64 + 1);
        // Channel slices tile the bit vectors.
        assert_eq!(g.layers.iter().map(|l| l.w_len).sum::<usize>(), g.w_channels);
        assert_eq!(g.layers.iter().map(|l| l.a_len).sum::<usize>(), g.a_channels);
        // Param layout: conv → w/g/bta triples; fc → w/b pair.
        assert_eq!(g.params.len(), 7 * 3 + 2);
        assert_eq!(g.params[0].name, "l01_conv.w");
        assert_eq!(g.params[0].shape, vec![3, 3, 3, 16]);
        assert_eq!(g.params[1].name, "l01_conv.g");
    }

    #[test]
    fn res18_blocks_expand_with_projections() {
        let g = model_graph("res18").unwrap();
        // stem + 8 blocks (2 convs each, 3 with projection) + fc.
        assert_eq!(g.layers.len(), 1 + 8 * 2 + 3 + 1);
        assert!(g.layers.iter().any(|l| l.name.contains("proj")));
        // Stage-transition block downsamples.
        let proj = g.layers.iter().find(|l| l.name.contains("proj")).unwrap();
        assert_eq!(proj.k, 1);
        assert_eq!(proj.s, 2);
    }

    #[test]
    fn monet_uses_dwconv_and_sqnet_skips_norm_on_classifier() {
        let m = model_graph("monet").unwrap();
        let dw = m.layers.iter().find(|l| l.typ == LType::DwConv).unwrap();
        assert_eq!(dw.cin, dw.cout);
        assert_eq!(dw.a_len, dw.w_len);
        // dwconv weight shape (k,k,1,cin).
        let p = &m.params[dw.p_w];
        assert_eq!(p.shape, vec![3, 3, 1, dw.cin]);
        // First irb has t=1 → no expand layer.
        assert!(!m.layers.iter().any(|l| l.name == "l02_expand"));
        assert_eq!(m.layers[1].name, "l02_dw");

        let s = model_graph("sqnet").unwrap();
        let cls = s.layers.iter().find(|l| !l.norm).unwrap();
        assert_eq!(cls.cout, NUM_CLASSES);
        assert!(!cls.relu);
        assert_eq!(s.params[cls.p_w + 1].name, format!("{}.b", cls.name));
    }

    #[test]
    fn builtin_manifest_is_complete() {
        let m = builtin_manifest();
        for model in MODEL_NAMES {
            for fam in ["eval_quant", "eval_binar", "train_quant", "train_binar"] {
                assert!(m.artifact(&format!("{model}_{fam}")).is_ok(), "{model}_{fam}");
            }
            let meta = m.model(model).unwrap();
            assert!(meta.w_channels > 0 && meta.a_channels > 0);
            assert!(meta.param_count() > 0);
        }
        for s in [16, 17] {
            assert!(m.artifact(&format!("ddpg_act_s{s}")).is_ok());
            assert!(m.artifact(&format!("ddpg_update_s{s}")).is_ok());
            assert_eq!(m.agent(s).unwrap().hidden, HIDDEN);
        }
        // Arities: eval = np+4, train = 2np+5, act = 7, update = 58.
        let np = m.model("cif10").unwrap().params.len();
        assert_eq!(m.artifact("cif10_eval_quant").unwrap().inputs.len(), np + 4);
        assert_eq!(m.artifact("cif10_train_quant").unwrap().inputs.len(), 2 * np + 5);
        assert_eq!(m.artifact("cif10_train_quant").unwrap().outputs.len(), 2 * np + 1);
        assert_eq!(m.artifact("ddpg_act_s16").unwrap().inputs.len(), 7);
        assert_eq!(m.artifact("ddpg_update_s17").unwrap().inputs.len(), 58);
        assert_eq!(m.artifact("ddpg_update_s17").unwrap().outputs.len(), 51);
    }

    #[test]
    fn gap_then_fc_threads_flat_dims() {
        for name in MODEL_NAMES {
            let g = model_graph(name).unwrap();
            // Output head ends at NUM_CLASSES channels.
            assert_eq!(g.layers.last().unwrap().cout, NUM_CLASSES);
            // Offsets are dense and increasing.
            let mut w_off = 0;
            let mut a_off = 0;
            for l in &g.layers {
                assert_eq!(l.w_off, w_off);
                assert_eq!(l.a_off, a_off);
                w_off += l.w_len;
                a_off += l.a_len;
            }
        }
    }
}
