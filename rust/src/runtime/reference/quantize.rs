//! Pure-Rust ports of the L1 quantizer oracles
//! (`python/compile/kernels/ref.py`), operating on channel-major `(C, K)`
//! matrices exactly like the Pallas kernels:
//!
//! * [`fake_quant_rows`] — linear (uniform, symmetric max-abs) per-channel
//!   quantize-dequantize.  bits 0 ⇒ channel pruned, ≥ 24 ⇒ passthrough.
//! * [`binarize_rows`] — multi-bit residual binarization (ABC-Net style):
//!   `W ≈ Σ_k α_k · sign(r_k)` with `r_{k+1} = r_k − α_k·sign(r_k)`.
//!
//! Rounding is ties-to-even to match `jnp.round`.

/// Residual-binarization level cap (python `MAX_BBN`).
pub const MAX_BBN: usize = 8;

/// `jnp.round` semantics: round half to even.
pub fn round_te(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (r - x).abs() == 0.5 && (r as i64) % 2 != 0 {
        r - x.signum()
    } else {
        r
    }
}

/// Positive level count of the signed symmetric linear quantizer for a
/// *rounded* bit-width `b`: `2^(b-1) - 1`, floored at 1 so `b == 1` stays a
/// binary {-s, +s} grid.  Computed as an exact integer shift — powers of two
/// up to 2²³ and their minus-one neighbours are exactly representable in
/// f32, so this is bit-identical to the `2.0f32.powf(b - 1.0) - 1.0` it
/// replaces while keeping transcendental math out of the per-row hot loop.
/// The integer kernels (`kernels/qgemm.rs`) derive their per-channel scales
/// from this same function so the int and fake-quant grids agree exactly.
pub fn linear_levels(b: f32) -> f32 {
    let e = (b.clamp(1.0, 24.0) as u32) - 1;
    (((1u64 << e) as f32) - 1.0).max(1.0)
}

/// Max-abs scale of the linear quantizer over `row` at `levels` positive
/// levels: `max|row| / levels`, or 1.0 for an all-zero row (any scale
/// reproduces zeros; 1.0 matches the python oracle).  Shared with the
/// integer kernels so both paths quantize onto the identical grid.
pub fn linear_scale(row_max_abs: f32, levels: f32) -> f32 {
    if row_max_abs > 0.0 {
        row_max_abs / levels
    } else {
        1.0
    }
}

/// Per-channel linear quantize-dequantize over the `cols`-wide row `c` of a
/// channel-major matrix, in place.
fn fake_quant_row(row: &mut [f32], bits: f32) {
    let b = round_te(bits);
    if b <= 0.0 {
        row.fill(0.0);
        return;
    }
    if b >= 24.0 {
        return; // beyond the f32 mantissa quantization is exact identity
    }
    // Signed symmetric quantizer: 2^(b-1) - 1 positive levels; b == 1 is
    // degenerate (0 levels) → binary {-s, +s} via the max(levels, 1) floor.
    let levels = linear_levels(b);
    let max_abs = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = linear_scale(max_abs, levels);
    for x in row.iter_mut() {
        let q = round_te(*x / scale).clamp(-levels, levels);
        *x = q * scale;
    }
}

/// Per-channel multi-bit residual binarization of row `c`, in place.
/// `r` is caller-owned scratch for the residual — grown once and reused
/// across rows instead of allocating per call.
fn binarize_row(row: &mut [f32], bits: f32, r: &mut Vec<f32>) {
    let b = round_te(bits).clamp(0.0, MAX_BBN as f32) as usize;
    let k_cols = row.len().max(1) as f32;
    r.clear();
    r.extend_from_slice(row);
    row.fill(0.0);
    for _ in 0..b {
        let alpha = r.iter().map(|x| x.abs()).sum::<f32>() / k_cols;
        for (o, ri) in row.iter_mut().zip(r.iter_mut()) {
            let level = if *ri >= 0.0 { alpha } else { -alpha };
            *o += level;
            *ri -= level;
        }
    }
}

/// True when the mode's quantizer is an exact identity for every channel:
/// linear fake-quant with all bit-widths rounding to ≥ 24 (beyond the f32
/// mantissa — see [`fake_quant_row`]).  Residual binarization always
/// perturbs values, so binar mode never passes through.  Callers use this
/// to skip the full-tensor channel-major round-trip and quantized copy —
/// the output would equal the input bit-for-bit.
pub fn is_passthrough(bits: &[f32], binar: bool) -> bool {
    !binar && bits.iter().all(|&b| round_te(b) >= 24.0)
}

/// Apply the mode's quantizer to every row of a channel-major `(rows, cols)`
/// matrix; `bits[c]` governs row `c`.
pub fn quantize_rows(x: &mut [f32], rows: usize, cols: usize, bits: &[f32], binar: bool) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(bits.len(), rows);
    // One residual buffer for the whole matrix (binar mode only) — the
    // first row grows it to `cols`, every later row reuses the capacity.
    let mut scratch: Vec<f32> = Vec::new();
    for c in 0..rows {
        let row = &mut x[c * cols..(c + 1) * cols];
        if binar {
            binarize_row(row, bits[c], &mut scratch);
        } else {
            fake_quant_row(row, bits[c]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounding_is_ties_even() {
        assert_eq!(round_te(2.5), 2.0);
        assert_eq!(round_te(3.5), 4.0);
        assert_eq!(round_te(-2.5), -2.0);
        assert_eq!(round_te(-3.5), -4.0);
        assert_eq!(round_te(2.3), 2.0);
        assert_eq!(round_te(-2.7), -3.0);
    }

    #[test]
    fn zero_bits_prunes_and_high_bits_pass_through() {
        let orig = vec![0.5f32, -1.25, 0.0, 2.0];
        let mut x = orig.clone();
        fake_quant_row(&mut x, 0.0);
        assert!(x.iter().all(|&v| v == 0.0));
        let mut x = orig.clone();
        fake_quant_row(&mut x, 32.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn quantization_error_shrinks_with_bits() {
        let orig: Vec<f32> = (0..64).map(|i| ((i * 37 % 101) as f32 / 50.0) - 1.0).collect();
        let err = |bits: f32| {
            let mut x = orig.clone();
            fake_quant_row(&mut x, bits);
            x.iter().zip(&orig).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
        };
        assert!(err(2.0) > err(4.0));
        assert!(err(4.0) > err(8.0));
        assert!(err(16.0) < 1e-3);
    }

    #[test]
    fn one_bit_is_binary_pm_maxabs() {
        let mut x = vec![0.3f32, -0.8, 0.1];
        fake_quant_row(&mut x, 1.0);
        // levels floor = 1, scale = max|x| → values in {-0.8, 0, 0.8}.
        for &v in &x {
            assert!(v == 0.8 || v == -0.8 || v == 0.0, "{v}");
        }
        assert_eq!(x[1], -0.8);
    }

    #[test]
    fn binarize_residual_converges() {
        let orig: Vec<f32> = (0..32).map(|i| ((i * 13 % 17) as f32 / 8.0) - 1.0).collect();
        let err = |bits: f32| {
            let mut x = orig.clone();
            binarize_row(&mut x, bits, &mut Vec::new());
            x.iter().zip(&orig).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
        };
        assert!(err(1.0) > err(3.0));
        assert!(err(3.0) > err(8.0));
        let mut zeroed = orig.clone();
        binarize_row(&mut zeroed, 0.0, &mut Vec::new());
        assert!(zeroed.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn shifted_levels_match_powf() {
        // The hoisted integer-shift level computation must reproduce the
        // original transcendental formula bit-for-bit at every bit-width.
        for b in 1..=24 {
            let bf = b as f32;
            let powf = (2.0f32.powf(bf.clamp(1.0, 24.0) - 1.0) - 1.0).max(1.0);
            assert_eq!(linear_levels(bf).to_bits(), powf.to_bits(), "bits={b}");
        }
        assert_eq!(linear_levels(8.0), 127.0);
        assert_eq!(linear_levels(4.0), 7.0);
        assert_eq!(linear_levels(1.0), 1.0);
    }

    #[test]
    fn passthrough_detection_matches_row_semantics() {
        // ≥ 24 bits everywhere (after ties-to-even rounding) ⇒ identity.
        assert!(is_passthrough(&[32.0, 24.0, 23.5], false)); // 23.5 rounds to 24
        assert!(!is_passthrough(&[32.0, 23.0], false));
        assert!(!is_passthrough(&[32.0, 0.0], false));
        assert!(!is_passthrough(&[32.0, 32.0], true), "binar always perturbs");
        // Agreement with quantize_rows: a passthrough matrix is unchanged.
        let orig = vec![0.1f32, -2.5, 3.25, 0.0, 1.5, -0.75];
        let mut x = orig.clone();
        quantize_rows(&mut x, 2, 3, &[32.0, 25.0], false);
        assert_eq!(x, orig);
    }

    #[test]
    fn rows_quantized_independently() {
        let mut x = vec![
            0.5, -0.5, 0.25, // row 0: 0 bits → pruned
            1.0, -1.0, 0.5, // row 1: passthrough
        ];
        quantize_rows(&mut x, 2, 3, &[0.0, 32.0], false);
        assert_eq!(&x[..3], &[0.0, 0.0, 0.0]);
        assert_eq!(&x[3..], &[1.0, -1.0, 0.5]);
    }
}
