//! Dense NHWC layer ops (forward + backward) for the reference
//! interpreter: conv / depthwise conv (SAME padding), GroupNorm, ReLU,
//! 2×2 max-pool, global average pool, softmax cross-entropy.
//!
//! Semantics mirror the JAX graphs in `python/compile/model.py`: SAME
//! padding splits the total pad floor/ceil, GroupNorm uses 8 groups when
//! the channel count divides (else 1) with ε = 1e-5, pooling is VALID.
//! All compute-heavy contractions route through the packed, cache-blocked
//! kernels in `kernels/` (convs lower to im2col + matmul); this module is
//! layer logic over that API.  Everything is f32 like the artifacts.
//!
//! Every op's core is an `_into` function writing caller-provided output
//! slices — the planned execution engine (`plan.rs`) feeds them workspace
//! buffers so steady-state batches allocate nothing.  The original
//! allocating signatures remain as thin wrappers (used by the reference
//! tree-walk the plan engine is verified against, and by tests).  Each
//! `_into` op either fully overwrites its outputs or zero-fills before
//! accumulating, so stale workspace contents can never leak into results.

// The kernel entry points double as this module's matmul/pad API so layer
// code and the executables import from one place.
pub use crate::runtime::reference::kernels::{
    col2im_acc, im2col, im2col::same_pad, matmul, matmul_a_bt, matmul_a_bt_into, matmul_acc,
    matmul_acc_scratch, matmul_at_b_acc, matmul_panel_len,
};
pub use crate::runtime::reference::kernels::{
    qgemm_into, quantize_rows_i8, quantize_rows_i8_static,
};
use crate::runtime::reference::kernels::{
    packed4_row_len,
    qgemm::{unpack4_hi, unpack4_lo},
    I8_LEVELS,
};
use crate::runtime::reference::quantize::{linear_scale, round_te};

/// NHWC activation dims.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dims {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Dims {
    pub fn elems(&self) -> usize {
        self.n * self.h * self.w * self.c
    }
}

// ---------------------------------------------------------------------------
// Layout shuffles (channel-major views for the per-channel quantizers)
// ---------------------------------------------------------------------------

/// NHWC → channel-major (c, n·h·w) into caller storage (full overwrite),
/// rows ordered by the (n,h,w) scan.
pub fn nhwc_to_cmajor_into(x: &[f32], d: Dims, out: &mut [f32]) {
    let rows = d.n * d.h * d.w;
    debug_assert_eq!(out.len(), x.len());
    for r in 0..rows {
        for c in 0..d.c {
            out[c * rows + r] = x[r * d.c + c];
        }
    }
}

/// NHWC → channel-major (c, n·h·w), allocating.
pub fn nhwc_to_cmajor(x: &[f32], d: Dims) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    nhwc_to_cmajor_into(x, d, &mut out);
    out
}

/// Inverse of [`nhwc_to_cmajor_into`] (full overwrite of `out`).
pub fn cmajor_to_nhwc_into(xc: &[f32], d: Dims, out: &mut [f32]) {
    let rows = d.n * d.h * d.w;
    debug_assert_eq!(out.len(), xc.len());
    for c in 0..d.c {
        for r in 0..rows {
            out[r * d.c + c] = xc[c * rows + r];
        }
    }
}

/// Inverse of [`nhwc_to_cmajor`], allocating.
pub fn cmajor_to_nhwc(xc: &[f32], d: Dims) -> Vec<f32> {
    let mut out = vec![0.0f32; xc.len()];
    cmajor_to_nhwc_into(xc, d, &mut out);
    out
}

/// Weight (…, cout) row-major → channel-major (cout, rest), full overwrite.
pub fn w_to_cmajor_into(w: &[f32], rest: usize, cout: usize, out: &mut [f32]) {
    debug_assert_eq!(w.len(), rest * cout);
    debug_assert_eq!(out.len(), w.len());
    for r in 0..rest {
        for co in 0..cout {
            out[co * rest + r] = w[r * cout + co];
        }
    }
}

/// Weight (…, cout) row-major → channel-major (cout, rest), allocating.
pub fn w_to_cmajor(w: &[f32], rest: usize, cout: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; w.len()];
    w_to_cmajor_into(w, rest, cout, &mut out);
    out
}

/// Inverse of [`w_to_cmajor_into`] (full overwrite of `out`).
pub fn cmajor_to_w_into(w2: &[f32], rest: usize, cout: usize, out: &mut [f32]) {
    debug_assert_eq!(w2.len(), rest * cout);
    debug_assert_eq!(out.len(), w2.len());
    for co in 0..cout {
        for r in 0..rest {
            out[r * cout + co] = w2[co * rest + r];
        }
    }
}

/// Inverse of [`w_to_cmajor`], allocating.
pub fn cmajor_to_w(w2: &[f32], rest: usize, cout: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; w2.len()];
    cmajor_to_w_into(w2, rest, cout, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Convolutions
// ---------------------------------------------------------------------------

/// Scratch size for the im2col patch matrix of a conv over `d` (0 for the
/// pointwise path, which never materializes patches).
pub fn conv_patch_len(d: Dims, k: usize, s: usize) -> usize {
    if k == 1 && s == 1 {
        return 0;
    }
    let (ho, _, _) = same_pad(d.h, k, s);
    let (wo, _, _) = same_pad(d.w, k, s);
    ho * wo * k * k * d.c
}

/// Scratch size for the matmul packing panel of a conv over `d`
/// (reduction dim = `k·k·cin` — which is just `cin` on the pointwise
/// path — against `cout` output columns).
pub fn conv_panel_len(d: Dims, k: usize, cout: usize) -> usize {
    matmul_panel_len(k * k * d.c, cout)
}

/// Dense conv, SAME padding, into caller storage: x NHWC, w (k,k,cin,cout)
/// row-major; `out` is fully overwritten, `patches` is im2col scratch of
/// [`conv_patch_len`] (ignored on the pointwise path) and `panel` is
/// matmul packing scratch of [`conv_panel_len`] (ignored on small
/// shapes).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into(
    x: &[f32],
    d: Dims,
    w: &[f32],
    k: usize,
    s: usize,
    cout: usize,
    out: &mut [f32],
    patches: &mut [f32],
    panel: &mut [f32],
) -> Dims {
    let (ho, _, _) = same_pad(d.h, k, s);
    let (wo, _, _) = same_pad(d.w, k, s);
    let od = Dims { n: d.n, h: ho, w: wo, c: cout };
    debug_assert_eq!(out.len(), od.elems());
    if k == 1 && s == 1 {
        // Pointwise conv == matmul over flattened pixels.
        let m = d.n * d.h * d.w;
        out.fill(0.0);
        matmul_acc_scratch(out, x, w, m, d.c, cout, panel);
        return od;
    }
    let cols = k * k * d.c;
    let img_elems = d.h * d.w * d.c;
    debug_assert_eq!(patches.len(), ho * wo * cols);
    out.fill(0.0);
    for ni in 0..d.n {
        im2col(&x[ni * img_elems..(ni + 1) * img_elems], d.h, d.w, d.c, k, s, patches);
        let dst = &mut out[ni * ho * wo * cout..(ni + 1) * ho * wo * cout];
        matmul_acc_scratch(dst, patches, w, ho * wo, cols, cout, panel);
    }
    od
}

/// Dense conv, SAME padding, allocating: x NHWC, w (k,k,cin,cout) row-major.
pub fn conv2d(x: &[f32], d: Dims, w: &[f32], k: usize, s: usize, cout: usize) -> (Vec<f32>, Dims) {
    let (ho, _, _) = same_pad(d.h, k, s);
    let (wo, _, _) = same_pad(d.w, k, s);
    let mut out = vec![0.0f32; d.n * ho * wo * cout];
    let mut patches = vec![0.0f32; conv_patch_len(d, k, s)];
    let mut panel = vec![0.0f32; conv_panel_len(d, k, cout)];
    let od = conv2d_into(x, d, w, k, s, cout, &mut out, &mut patches, &mut panel);
    (out, od)
}

/// Dense conv backward into caller storage: writes dx (fully), accumulates
/// dw (caller zero-fills for a plain gradient).  `patches`/`dpatch` are
/// per-image scratch of [`conv_patch_len`] each (ignored on the pointwise
/// path).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd_into(
    x: &[f32],
    d: Dims,
    w: &[f32],
    k: usize,
    s: usize,
    cout: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw_acc: &mut [f32],
    patches: &mut [f32],
    dpatch: &mut [f32],
) {
    debug_assert_eq!(dx.len(), x.len());
    debug_assert_eq!(dw_acc.len(), w.len());
    if k == 1 && s == 1 {
        let m = d.n * d.h * d.w;
        matmul_at_b_acc(dw_acc, x, dy, m, d.c, cout);
        matmul_a_bt_into(dx, dy, w, m, cout, d.c);
        return;
    }
    let (ho, _, _) = same_pad(d.h, k, s);
    let (wo, _, _) = same_pad(d.w, k, s);
    let cols = k * k * d.c;
    let img_elems = d.h * d.w * d.c;
    debug_assert_eq!(patches.len(), ho * wo * cols);
    debug_assert_eq!(dpatch.len(), ho * wo * cols);
    dx.fill(0.0);
    for ni in 0..d.n {
        let dy_img = &dy[ni * ho * wo * cout..(ni + 1) * ho * wo * cout];
        im2col(&x[ni * img_elems..(ni + 1) * img_elems], d.h, d.w, d.c, k, s, patches);
        matmul_at_b_acc(dw_acc, patches, dy_img, ho * wo, cols, cout);
        matmul_a_bt_into(dpatch, dy_img, w, ho * wo, cout, cols);
        col2im_acc(dpatch, d.h, d.w, d.c, k, s, &mut dx[ni * img_elems..(ni + 1) * img_elems]);
    }
}

/// Dense conv backward, allocating: returns (dx, dw) for quantized inputs
/// x / weight w.
pub fn conv2d_bwd(
    x: &[f32],
    d: Dims,
    w: &[f32],
    k: usize,
    s: usize,
    cout: usize,
    dy: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; x.len()];
    let mut dw = vec![0.0f32; w.len()];
    let plen = conv_patch_len(d, k, s);
    let mut patches = vec![0.0f32; plen];
    let mut dpatch = vec![0.0f32; plen];
    conv2d_bwd_into(x, d, w, k, s, cout, dy, &mut dx, &mut dw, &mut patches, &mut dpatch);
    (dx, dw)
}

/// Depthwise conv (feature_group_count = cin) into caller storage
/// (zero-filled then accumulated): w (k,k,1,cin).
pub fn dwconv2d_into(x: &[f32], d: Dims, w: &[f32], k: usize, s: usize, out: &mut [f32]) -> Dims {
    let (ho, pad_t, _) = same_pad(d.h, k, s);
    let (wo, pad_l, _) = same_pad(d.w, k, s);
    let od = Dims { n: d.n, h: ho, w: wo, c: d.c };
    debug_assert_eq!(out.len(), od.elems());
    out.fill(0.0);
    let img_elems = d.h * d.w * d.c;
    for ni in 0..d.n {
        let img = &x[ni * img_elems..(ni + 1) * img_elems];
        let dst = &mut out[ni * ho * wo * d.c..(ni + 1) * ho * wo * d.c];
        for oy in 0..ho {
            for ox in 0..wo {
                let orow = &mut dst[(oy * wo + ox) * d.c..(oy * wo + ox + 1) * d.c];
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - pad_t as isize;
                    if iy < 0 || iy >= d.h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - pad_l as isize;
                        if ix < 0 || ix >= d.w as isize {
                            continue;
                        }
                        let src = ((iy as usize) * d.w + ix as usize) * d.c;
                        let wrow = &w[(ky * k + kx) * d.c..(ky * k + kx + 1) * d.c];
                        for c in 0..d.c {
                            orow[c] += img[src + c] * wrow[c];
                        }
                    }
                }
            }
        }
    }
    od
}

/// Depthwise conv (feature_group_count = cin), allocating: w (k,k,1,cin).
pub fn dwconv2d(x: &[f32], d: Dims, w: &[f32], k: usize, s: usize) -> (Vec<f32>, Dims) {
    let (ho, _, _) = same_pad(d.h, k, s);
    let (wo, _, _) = same_pad(d.w, k, s);
    let mut out = vec![0.0f32; d.n * ho * wo * d.c];
    let od = dwconv2d_into(x, d, w, k, s, &mut out);
    (out, od)
}

/// Depthwise conv backward into caller storage: writes dx (zero-filled
/// then scatter-accumulated), accumulates dw (caller zero-fills for a
/// plain gradient).
pub fn dwconv2d_bwd_into(
    x: &[f32],
    d: Dims,
    w: &[f32],
    k: usize,
    s: usize,
    dy: &[f32],
    dx: &mut [f32],
    dw_acc: &mut [f32],
) {
    let (ho, pad_t, _) = same_pad(d.h, k, s);
    let (wo, pad_l, _) = same_pad(d.w, k, s);
    debug_assert_eq!(dx.len(), x.len());
    debug_assert_eq!(dw_acc.len(), w.len());
    dx.fill(0.0);
    let img_elems = d.h * d.w * d.c;
    for ni in 0..d.n {
        let img = &x[ni * img_elems..(ni + 1) * img_elems];
        let dimg = &mut dx[ni * img_elems..(ni + 1) * img_elems];
        let dy_img = &dy[ni * ho * wo * d.c..(ni + 1) * ho * wo * d.c];
        for oy in 0..ho {
            for ox in 0..wo {
                let drow = &dy_img[(oy * wo + ox) * d.c..(oy * wo + ox + 1) * d.c];
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - pad_t as isize;
                    if iy < 0 || iy >= d.h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - pad_l as isize;
                        if ix < 0 || ix >= d.w as isize {
                            continue;
                        }
                        let src = ((iy as usize) * d.w + ix as usize) * d.c;
                        let wi = (ky * k + kx) * d.c;
                        for c in 0..d.c {
                            dimg[src + c] += drow[c] * w[wi + c];
                            dw_acc[wi + c] += img[src + c] * drow[c];
                        }
                    }
                }
            }
        }
    }
}

/// Depthwise conv backward, allocating: (dx, dw).
pub fn dwconv2d_bwd(
    x: &[f32],
    d: Dims,
    w: &[f32],
    k: usize,
    s: usize,
    dy: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; x.len()];
    let mut dw = vec![0.0f32; w.len()];
    dwconv2d_bwd_into(x, d, w, k, s, dy, &mut dx, &mut dw);
    (dx, dw)
}

// ---------------------------------------------------------------------------
// Integer-path convolutions (kernels/qgemm.rs dispatch — see its docs)
// ---------------------------------------------------------------------------

/// i8 scratch size for the int conv path's quantized activation rows:
/// the whole flattened batch for pointwise convs (quantized in one shot),
/// otherwise one image's im2col patch matrix.
pub fn conv_qpatch_len(d: Dims, k: usize, s: usize) -> usize {
    if k == 1 && s == 1 {
        d.elems()
    } else {
        conv_patch_len(d, k, s)
    }
}

/// Activation rows quantized per [`qconv2d_into`] GEMM call — one dynamic
/// i8 scale each: flattened batch pixels for pointwise convs, else one
/// image's output pixels.
pub fn conv_qrows(d: Dims, k: usize, s: usize) -> usize {
    if k == 1 && s == 1 {
        d.n * d.h * d.w
    } else {
        let (ho, _, _) = same_pad(d.h, k, s);
        let (wo, _, _) = same_pad(d.w, k, s);
        ho * wo
    }
}

/// Activation scales the int dwconv path needs: one per (image, channel).
pub fn dwconv_qrows(d: Dims) -> usize {
    d.n * d.c
}

/// Row-matrix activation quantize dispatch: a calibrated static per-layer
/// scale when `act_scale` is set (`--act-scales static`), else the dynamic
/// per-row max pass.
#[inline]
fn quantize_acts(
    x: &[f32],
    m: usize,
    k: usize,
    act_scale: Option<f32>,
    qa: &mut [i8],
    sa: &mut [f32],
) {
    match act_scale {
        Some(s) => quantize_rows_i8_static(x, m, k, s, qa, sa),
        None => quantize_rows_i8(x, m, k, qa, sa),
    }
}

/// Per-(image, channel) symmetric i8 quantization of an NHWC tensor — the
/// depthwise analogue of the per-row GEMM quantizer: channel `c` of image
/// `n` gets `sx[n·C + c] = max|x[n, :, :, c]| / 127` (1.0 for an all-zero
/// slice).  Fully overwrites the first `d.elems()` codes and `n·C` scales.
pub fn quantize_nhwc_i8(x: &[f32], d: Dims, qx: &mut [i8], sx: &mut [f32]) {
    debug_assert_eq!(x.len(), d.elems());
    debug_assert!(qx.len() >= d.elems());
    debug_assert!(sx.len() >= d.n * d.c);
    let img = d.h * d.w * d.c;
    for ni in 0..d.n {
        let xs = &x[ni * img..(ni + 1) * img];
        let srow = &mut sx[ni * d.c..(ni + 1) * d.c];
        srow.fill(0.0);
        for p in 0..d.h * d.w {
            for (s, &v) in srow.iter_mut().zip(&xs[p * d.c..(p + 1) * d.c]) {
                let a = v.abs();
                if a > *s {
                    *s = a;
                }
            }
        }
        for s in srow.iter_mut() {
            *s = linear_scale(*s, I8_LEVELS);
        }
        let qs = &mut qx[ni * img..(ni + 1) * img];
        for p in 0..d.h * d.w {
            let row = &xs[p * d.c..(p + 1) * d.c];
            for (c, (q, &v)) in qs[p * d.c..(p + 1) * d.c].iter_mut().zip(row).enumerate() {
                *q = round_te(v / srow[c]).clamp(-I8_LEVELS, I8_LEVELS) as i8;
            }
        }
    }
}

/// Static-scale variant of [`quantize_nhwc_i8`]: one calibrated scale for
/// every (image, channel) slice — no max pass (values beyond `127·scale`
/// saturate, see `quantize_rows_i8_static`).
pub fn quantize_nhwc_i8_static(x: &[f32], d: Dims, scale: f32, qx: &mut [i8], sx: &mut [f32]) {
    debug_assert_eq!(x.len(), d.elems());
    debug_assert!(qx.len() >= d.elems());
    debug_assert!(sx.len() >= d.n * d.c);
    debug_assert!(scale > 0.0, "static activation scale must be positive");
    sx[..d.n * d.c].fill(scale);
    for (q, &v) in qx[..d.elems()].iter_mut().zip(x) {
        *q = round_te(v / scale).clamp(-I8_LEVELS, I8_LEVELS) as i8;
    }
}

/// Dense conv on the integer path, SAME padding, into caller storage:
/// fake-quantized f32 activations are re-quantized per row to i8
/// (`qpatch` codes + `ascale` dynamic scales, sizes [`conv_qpatch_len`] /
/// [`conv_qrows`]); `qw`/`sw` are channel-major int weight codes and
/// per-channel scales from the `WQ` quantizer (`i4` selects the
/// nibble-packed form).  `patches` is the same f32 im2col scratch as
/// [`conv2d_into`] (ignored on the pointwise path); `out` is fully
/// overwritten by the integer GEMM.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_into(
    x: &[f32],
    d: Dims,
    qw: &[i8],
    sw: &[f32],
    i4: bool,
    k: usize,
    s: usize,
    cout: usize,
    out: &mut [f32],
    patches: &mut [f32],
    qpatch: &mut [i8],
    ascale: &mut [f32],
    act_scale: Option<f32>,
) -> Dims {
    let (ho, _, _) = same_pad(d.h, k, s);
    let (wo, _, _) = same_pad(d.w, k, s);
    let od = Dims { n: d.n, h: ho, w: wo, c: cout };
    debug_assert_eq!(out.len(), od.elems());
    if k == 1 && s == 1 {
        let m = d.n * d.h * d.w;
        quantize_acts(x, m, d.c, act_scale, qpatch, ascale);
        qgemm_into(out, qpatch, ascale, qw, sw, m, d.c, cout, i4);
        return od;
    }
    let cols = k * k * d.c;
    let img_elems = d.h * d.w * d.c;
    debug_assert_eq!(patches.len(), ho * wo * cols);
    for ni in 0..d.n {
        im2col(&x[ni * img_elems..(ni + 1) * img_elems], d.h, d.w, d.c, k, s, patches);
        quantize_acts(patches, ho * wo, cols, act_scale, qpatch, ascale);
        let dst = &mut out[ni * ho * wo * cout..(ni + 1) * ho * wo * cout];
        qgemm_into(dst, qpatch, ascale, qw, sw, ho * wo, cols, cout, i4);
    }
    od
}

/// Dense conv on the integer path, allocating (the tree-walk backend).
#[allow(clippy::too_many_arguments)]
pub fn qconv2d(
    x: &[f32],
    d: Dims,
    qw: &[i8],
    sw: &[f32],
    i4: bool,
    k: usize,
    s: usize,
    cout: usize,
    act_scale: Option<f32>,
) -> (Vec<f32>, Dims) {
    let (ho, _, _) = same_pad(d.h, k, s);
    let (wo, _, _) = same_pad(d.w, k, s);
    let mut out = vec![0.0f32; d.n * ho * wo * cout];
    let mut patches = vec![0.0f32; conv_patch_len(d, k, s)];
    let mut qpatch = vec![0i8; conv_qpatch_len(d, k, s)];
    let mut ascale = vec![0.0f32; conv_qrows(d, k, s)];
    let od = qconv2d_into(
        x, d, qw, sw, i4, k, s, cout, &mut out, &mut patches, &mut qpatch, &mut ascale, act_scale,
    );
    (out, od)
}

/// Dense (fully-connected) layer on the integer path into caller storage:
/// per-sample dynamic i8 re-quantization of `x` (`(n, cin)` row-major)
/// against channel-major int weights, full overwrite of `out` (`n × cout`).
/// Bias is the caller's job, exactly as on the f32 path.
#[allow(clippy::too_many_arguments)]
pub fn qfc_into(
    x: &[f32],
    n: usize,
    cin: usize,
    qw: &[i8],
    sw: &[f32],
    i4: bool,
    cout: usize,
    out: &mut [f32],
    qa: &mut [i8],
    ascale: &mut [f32],
    act_scale: Option<f32>,
) {
    quantize_acts(x, n, cin, act_scale, qa, ascale);
    qgemm_into(out, qa, ascale, qw, sw, n, cin, cout, i4);
}

/// Dense layer on the integer path, allocating (the tree-walk backend).
#[allow(clippy::too_many_arguments)]
pub fn qfc(
    x: &[f32],
    n: usize,
    cin: usize,
    qw: &[i8],
    sw: &[f32],
    i4: bool,
    cout: usize,
    act_scale: Option<f32>,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n * cout];
    let mut qa = vec![0i8; n * cin];
    let mut ascale = vec![0.0f32; n];
    qfc_into(x, n, cin, qw, sw, i4, cout, &mut out, &mut qa, &mut ascale, act_scale);
    out
}

/// Depthwise conv on the integer path, SAME padding, into caller storage.
///
/// Activations quantize per (image, channel) — the depthwise contraction
/// never mixes channels, so the scale factors hoist out of the i32
/// accumulator exactly as per-row scales do for the GEMM form (`qx`/`sx`
/// scratch of `d.elems()` / [`dwconv_qrows`]; `act_scale` pins the static
/// calibrated grid instead).  `qw`/`sw` are the `WQ` quantizer's
/// channel-major codes over `rest = k·k` taps — the (k,k,1,cin) row-major
/// parameter is precisely a `(rest, cout=cin)` weight, so the int dwconv
/// reuses the shared weight quantizer and nibble packing unchanged (`i4`
/// selects the packed form).  Exact i32 accumulation over ≤ k² taps, one
/// f32 dequantize per output element: `out = acc · (sx[n,c] · sw[c])` —
/// the qgemm tolerance contract with `k_eff = k²` (edge pixels sum fewer
/// taps, and the bound is monotone in the tap count).  Fully overwrites
/// `out`.
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d_into(
    x: &[f32],
    d: Dims,
    qw: &[i8],
    sw: &[f32],
    i4: bool,
    k: usize,
    s: usize,
    out: &mut [f32],
    qx: &mut [i8],
    sx: &mut [f32],
    act_scale: Option<f32>,
) -> Dims {
    match act_scale {
        Some(sc) => quantize_nhwc_i8_static(x, d, sc, qx, sx),
        None => quantize_nhwc_i8(x, d, qx, sx),
    }
    let (ho, pad_t, _) = same_pad(d.h, k, s);
    let (wo, pad_l, _) = same_pad(d.w, k, s);
    let od = Dims { n: d.n, h: ho, w: wo, c: d.c };
    debug_assert_eq!(out.len(), od.elems());
    debug_assert!((k * k) as u64 * 16129 <= i32::MAX as u64);
    let prow = packed4_row_len(k * k);
    let wrow_len = if i4 { prow } else { k * k };
    debug_assert!(qw.len() >= wrow_len * d.c);
    debug_assert!(sw.len() >= d.c);
    let img_elems = d.h * d.w * d.c;
    for ni in 0..d.n {
        let img = &qx[ni * img_elems..(ni + 1) * img_elems];
        let ss = &sx[ni * d.c..(ni + 1) * d.c];
        let dst = &mut out[ni * ho * wo * d.c..(ni + 1) * ho * wo * d.c];
        for oy in 0..ho {
            // Valid tap range for this output row: iy = oy·s + ky − pad_t
            // must land in [0, h) — hoisting the bound check off the taps.
            let ky_lo = pad_t.saturating_sub(oy * s);
            let ky_hi = k.min(d.h + pad_t - oy * s);
            for ox in 0..wo {
                let kx_lo = pad_l.saturating_sub(ox * s);
                let kx_hi = k.min(d.w + pad_l - ox * s);
                let orow = &mut dst[(oy * wo + ox) * d.c..(oy * wo + ox + 1) * d.c];
                for (c, o) in orow.iter_mut().enumerate() {
                    let wrow = &qw[c * wrow_len..(c + 1) * wrow_len];
                    let mut acc = 0i32;
                    for ky in ky_lo..ky_hi {
                        let iy = oy * s + ky - pad_t;
                        for kx in kx_lo..kx_hi {
                            let ix = ox * s + kx - pad_l;
                            let tap = ky * k + kx;
                            let wc = if i4 {
                                let byte = wrow[tap / 2];
                                if tap % 2 == 0 {
                                    unpack4_lo(byte)
                                } else {
                                    unpack4_hi(byte)
                                }
                            } else {
                                i32::from(wrow[tap])
                            };
                            acc += i32::from(img[(iy * d.w + ix) * d.c + c]) * wc;
                        }
                    }
                    *o = acc as f32 * (ss[c] * sw[c]);
                }
            }
        }
    }
    od
}

/// Depthwise conv on the integer path, allocating (the tree-walk backend).
#[allow(clippy::too_many_arguments)]
pub fn qdwconv2d(
    x: &[f32],
    d: Dims,
    qw: &[i8],
    sw: &[f32],
    i4: bool,
    k: usize,
    s: usize,
    act_scale: Option<f32>,
) -> (Vec<f32>, Dims) {
    let (ho, _, _) = same_pad(d.h, k, s);
    let (wo, _, _) = same_pad(d.w, k, s);
    let mut out = vec![0.0f32; d.n * ho * wo * d.c];
    let mut qx = vec![0i8; d.elems()];
    let mut sx = vec![0.0f32; dwconv_qrows(d)];
    let od = qdwconv2d_into(x, d, qw, sw, i4, k, s, &mut out, &mut qx, &mut sx, act_scale);
    (out, od)
}

// ---------------------------------------------------------------------------
// Normalization / activation / pooling
// ---------------------------------------------------------------------------

/// GroupNorm groups: 8 when it divides C, else 1 (python `group_norm`).
pub fn gn_groups(c: usize) -> usize {
    if c % 8 == 0 {
        8
    } else {
        1
    }
}

pub struct GnCache {
    /// Normalized activations (pre scale/shift), full tensor.
    pub xn: Vec<f32>,
    /// 1/√(var+ε) per (image, group).
    pub istd: Vec<f32>,
}

/// y = xn·γ + β with per-(n, group) statistics over (h, w, c/groups),
/// into caller storage (full overwrite of `y`).  `cache` = (xn, istd)
/// slices filled for the backward pass when present; the values of `y`
/// are bit-identical either way (eval paths skip the cache entirely).
pub fn group_norm_into(
    x: &[f32],
    d: Dims,
    gamma: &[f32],
    beta: &[f32],
    y: &mut [f32],
    mut cache: Option<(&mut [f32], &mut [f32])>,
) {
    let gr = gn_groups(d.c);
    let cg = d.c / gr;
    let m = (d.h * d.w * cg) as f64;
    debug_assert_eq!(y.len(), x.len());
    if let Some((xn, istd)) = &cache {
        debug_assert_eq!(xn.len(), x.len());
        debug_assert_eq!(istd.len(), d.n * gr);
    }
    let img = d.h * d.w * d.c;
    for ni in 0..d.n {
        for g in 0..gr {
            let (mut sum, mut sq) = (0.0f64, 0.0f64);
            for p in 0..d.h * d.w {
                let base = ni * img + p * d.c + g * cg;
                for j in 0..cg {
                    let v = x[base + j] as f64;
                    sum += v;
                    sq += v * v;
                }
            }
            let mu = sum / m;
            let var = (sq / m - mu * mu).max(0.0);
            let is = 1.0 / (var + 1e-5).sqrt();
            if let Some((_, istd)) = &mut cache {
                istd[ni * gr + g] = is as f32;
            }
            for p in 0..d.h * d.w {
                let base = ni * img + p * d.c + g * cg;
                for j in 0..cg {
                    let c = g * cg + j;
                    let v = ((x[base + j] as f64 - mu) * is) as f32;
                    if let Some((xn, _)) = &mut cache {
                        xn[base + j] = v;
                    }
                    y[base + j] = v * gamma[c] + beta[c];
                }
            }
        }
    }
}

/// y = xn·γ + β, allocating, with the backward cache.
pub fn group_norm(x: &[f32], d: Dims, gamma: &[f32], beta: &[f32]) -> (Vec<f32>, GnCache) {
    let gr = gn_groups(d.c);
    let mut xn = vec![0.0f32; x.len()];
    let mut istd = vec![0.0f32; d.n * gr];
    let mut y = vec![0.0f32; x.len()];
    group_norm_into(x, d, gamma, beta, &mut y, Some((&mut xn, &mut istd)));
    (y, GnCache { xn, istd })
}

/// GroupNorm backward into caller storage: writes dx (fully), accumulates
/// dγ/dβ (callers zero-fill for plain gradients).  `xn`/`istd` are the
/// forward cache slices.
#[allow(clippy::too_many_arguments)]
pub fn group_norm_bwd_into(
    dy: &[f32],
    d: Dims,
    gamma: &[f32],
    xn_c: &[f32],
    istd_c: &[f32],
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    let gr = gn_groups(d.c);
    let cg = d.c / gr;
    let m = (d.h * d.w * cg) as f64;
    let img = d.h * d.w * d.c;
    debug_assert_eq!(dx.len(), dy.len());
    debug_assert_eq!(dgamma.len(), d.c);
    debug_assert_eq!(dbeta.len(), d.c);
    for ni in 0..d.n {
        for g in 0..gr {
            // dxn = dy·γ; group sums of dxn and dxn·xn.
            let (mut s1, mut s2) = (0.0f64, 0.0f64);
            for p in 0..d.h * d.w {
                let base = ni * img + p * d.c + g * cg;
                for j in 0..cg {
                    let c = g * cg + j;
                    let dyv = dy[base + j];
                    let xnv = xn_c[base + j];
                    dgamma[c] += dyv * xnv;
                    dbeta[c] += dyv;
                    let dxn = (dyv * gamma[c]) as f64;
                    s1 += dxn;
                    s2 += dxn * xnv as f64;
                }
            }
            let is = istd_c[ni * gr + g] as f64;
            let mean1 = s1 / m;
            let mean2 = s2 / m;
            for p in 0..d.h * d.w {
                let base = ni * img + p * d.c + g * cg;
                for j in 0..cg {
                    let c = g * cg + j;
                    let dxn = (dy[base + j] * gamma[c]) as f64;
                    let xnv = xn_c[base + j] as f64;
                    dx[base + j] = (is * (dxn - mean1 - xnv * mean2)) as f32;
                }
            }
        }
    }
}

/// GroupNorm backward, allocating: (dx, dγ, dβ).
pub fn group_norm_bwd(
    dy: &[f32],
    d: Dims,
    gamma: &[f32],
    cache: &GnCache,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; dy.len()];
    let mut dgamma = vec![0.0f32; d.c];
    let mut dbeta = vec![0.0f32; d.c];
    group_norm_bwd_into(dy, d, gamma, &cache.xn, &cache.istd, &mut dx, &mut dgamma, &mut dbeta);
    (dx, dgamma, dbeta)
}

/// y += bias per channel (last axis).
pub fn add_bias(y: &mut [f32], c: usize, bias: &[f32]) {
    debug_assert_eq!(bias.len(), c);
    for row in y.chunks_exact_mut(c) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// dβ for a bias add, accumulated into caller storage (callers zero-fill
/// for a plain gradient): channel sums of dy.
pub fn bias_bwd_acc(dy: &[f32], c: usize, db: &mut [f32]) {
    debug_assert_eq!(db.len(), c);
    for row in dy.chunks_exact(c) {
        for (d, &v) in db.iter_mut().zip(row) {
            *d += v;
        }
    }
}

/// dβ for a bias add, allocating: channel sums of dy.
pub fn bias_bwd(dy: &[f32], c: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; c];
    bias_bwd_acc(dy, c, &mut db);
    db
}

pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// dx = dy ⊙ 1[out > 0] — `out` is the post-ReLU activation.
pub fn relu_bwd(dy: &mut [f32], out: &[f32]) {
    for (d, &o) in dy.iter_mut().zip(out) {
        if o <= 0.0 {
            *d = 0.0;
        }
    }
}

/// 2×2 max pool, stride 2, VALID, into caller storage (full overwrite of
/// `y`).  `idx` records argmax flat indices for the backward pass when
/// present; `y` is bit-identical either way.
pub fn maxpool2_into(x: &[f32], d: Dims, y: &mut [f32], mut idx: Option<&mut [u32]>) -> Dims {
    let ho = d.h / 2;
    let wo = d.w / 2;
    let od = Dims { n: d.n, h: ho, w: wo, c: d.c };
    debug_assert_eq!(y.len(), od.elems());
    if let Some(idx) = &idx {
        debug_assert_eq!(idx.len(), od.elems());
    }
    for ni in 0..d.n {
        for oy in 0..ho {
            for ox in 0..wo {
                for c in 0..d.c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0usize;
                    for dy_ in 0..2 {
                        for dx_ in 0..2 {
                            let src =
                                ((ni * d.h + oy * 2 + dy_) * d.w + ox * 2 + dx_) * d.c + c;
                            if x[src] > best {
                                best = x[src];
                                bi = src;
                            }
                        }
                    }
                    let dst = ((ni * ho + oy) * wo + ox) * d.c + c;
                    y[dst] = best;
                    if let Some(idx) = &mut idx {
                        idx[dst] = bi as u32;
                    }
                }
            }
        }
    }
    od
}

/// 2×2 max pool, stride 2, VALID, allocating.  Returns (y, argmax flat
/// indices, dims).
pub fn maxpool2(x: &[f32], d: Dims) -> (Vec<f32>, Vec<u32>, Dims) {
    let od = Dims { n: d.n, h: d.h / 2, w: d.w / 2, c: d.c };
    let mut y = vec![0.0f32; od.elems()];
    let mut idx = vec![0u32; od.elems()];
    maxpool2_into(x, d, &mut y, Some(&mut idx));
    (y, idx, od)
}

/// Max-pool backward into caller storage: dx zero-filled then
/// scatter-accumulated through the argmax indices.
pub fn maxpool2_bwd_into(dy: &[f32], idx: &[u32], dx: &mut [f32]) {
    dx.fill(0.0);
    for (d, &i) in dy.iter().zip(idx) {
        dx[i as usize] += d;
    }
}

pub fn maxpool2_bwd(dy: &[f32], idx: &[u32], in_elems: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; in_elems];
    maxpool2_bwd_into(dy, idx, &mut dx);
    dx
}

/// Global average pool over (h, w) into caller storage (zero-filled then
/// accumulated): NHWC → (n, c).
pub fn gap_into(x: &[f32], d: Dims, y: &mut [f32]) {
    let hw = (d.h * d.w) as f32;
    debug_assert_eq!(y.len(), d.n * d.c);
    y.fill(0.0);
    for ni in 0..d.n {
        let dst = &mut y[ni * d.c..(ni + 1) * d.c];
        for p in 0..d.h * d.w {
            let src = &x[(ni * d.h * d.w + p) * d.c..(ni * d.h * d.w + p + 1) * d.c];
            for c in 0..d.c {
                dst[c] += src[c];
            }
        }
        for v in dst.iter_mut() {
            *v /= hw;
        }
    }
}

/// Global average pool over (h, w), allocating: NHWC → (n, c).
pub fn gap(x: &[f32], d: Dims) -> Vec<f32> {
    let mut y = vec![0.0f32; d.n * d.c];
    gap_into(x, d, &mut y);
    y
}

/// GAP backward into caller storage (full overwrite).
pub fn gap_bwd_into(dy: &[f32], d: Dims, dx: &mut [f32]) {
    let hw = (d.h * d.w) as f32;
    debug_assert_eq!(dx.len(), d.elems());
    for ni in 0..d.n {
        let g = &dy[ni * d.c..(ni + 1) * d.c];
        for p in 0..d.h * d.w {
            let dst = &mut dx[(ni * d.h * d.w + p) * d.c..(ni * d.h * d.w + p + 1) * d.c];
            for c in 0..d.c {
                dst[c] = g[c] / hw;
            }
        }
    }
}

pub fn gap_bwd(dy: &[f32], d: Dims) -> Vec<f32> {
    let mut dx = vec![0.0f32; d.elems()];
    gap_bwd_into(dy, d, &mut dx);
    dx
}

// ---------------------------------------------------------------------------
// Loss head
// ---------------------------------------------------------------------------

/// Softmax cross-entropy head into caller storage: (correct count, mean
/// loss); `grad` is fully overwritten with d(logits) when present.
/// `logits` is (n, c) row-major.
pub fn softmax_xent_into(
    logits: &[f32],
    n: usize,
    c: usize,
    labels: &[i32],
    mut grad: Option<&mut [f32]>,
) -> (f32, f32) {
    debug_assert_eq!(logits.len(), n * c);
    debug_assert_eq!(labels.len(), n);
    if let Some(g) = &grad {
        debug_assert_eq!(g.len(), n * c);
    }
    let mut correct = 0.0f32;
    let mut loss = 0.0f64;
    for i in 0..n {
        let row = &logits[i * c..(i + 1) * c];
        let mut maxv = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > maxv {
                maxv = v;
                arg = j;
            }
        }
        let label = labels[i] as usize;
        if arg == label {
            correct += 1.0;
        }
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - maxv) as f64).exp();
        }
        let logz = maxv as f64 + sum.ln();
        loss += logz - row[label] as f64;
        if let Some(g) = grad.as_mut() {
            let grow = &mut g[i * c..(i + 1) * c];
            for (j, &v) in row.iter().enumerate() {
                let p = ((v as f64 - logz).exp()) as f32;
                grow[j] = (p - if j == label { 1.0 } else { 0.0 }) / n as f32;
            }
        }
    }
    (correct, (loss / n as f64) as f32)
}

/// Softmax cross-entropy head, allocating: (correct count, mean loss,
/// optional d(logits) when `want_grad`).  `logits` is (n, c) row-major.
pub fn softmax_xent(
    logits: &[f32],
    n: usize,
    c: usize,
    labels: &[i32],
    want_grad: bool,
) -> (f32, f32, Option<Vec<f32>>) {
    let mut grad = if want_grad { Some(vec![0.0f32; n * c]) } else { None };
    let (correct, loss) = softmax_xent_into(logits, n, c, labels, grad.as_deref_mut());
    (correct, loss, grad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_reexports_are_the_kernel_entry_points() {
        // aᵀ @ a is symmetric — smoke that the re-exported kernel API is
        // wired; the kernels module owns the real matmul tests.
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut ata = vec![0.0; 9];
        matmul_at_b_acc(&mut ata, &a, &a, 2, 3, 3);
        assert_eq!(ata[1], ata[3]);
        assert_eq!(ata[2], ata[6]);
        assert_eq!(same_pad(32, 3, 2), (16, 0, 1));
    }

    #[test]
    fn cmajor_roundtrips() {
        let d = Dims { n: 2, h: 2, w: 1, c: 3 };
        let x: Vec<f32> = (0..d.elems()).map(|i| i as f32).collect();
        let cm = nhwc_to_cmajor(&x, d);
        // Channel 0 row = every 3rd element.
        assert_eq!(&cm[0..4], &[0.0, 3.0, 6.0, 9.0]);
        assert_eq!(cmajor_to_nhwc(&cm, d), x);
        let w: Vec<f32> = (0..12).map(|i| i as f32).collect();
        assert_eq!(cmajor_to_w(&w_to_cmajor(&w, 4, 3), 4, 3), w);
    }

    #[test]
    fn conv_identity_kernel_preserves_input() {
        // 1×1 conv with identity weight = passthrough.
        let d = Dims { n: 1, h: 3, w: 3, c: 2 };
        let x: Vec<f32> = (0..d.elems()).map(|i| i as f32 * 0.5).collect();
        let w = vec![1.0, 0.0, 0.0, 1.0]; // (1,1,2,2) identity
        let (y, od) = conv2d(&x, d, &w, 1, 1, 2);
        assert_eq!(od, d);
        assert_eq!(y, x);
    }

    #[test]
    fn conv_3x3_counts_neighbours() {
        // All-ones 3×3 kernel on all-ones input counts the valid
        // neighbourhood: 4 at corners, 6 at edges, 9 inside.
        let d = Dims { n: 1, h: 3, w: 3, c: 1 };
        let x = vec![1.0f32; 9];
        let w = vec![1.0f32; 9]; // (3,3,1,1)
        let (y, _) = conv2d(&x, d, &w, 3, 1, 1);
        assert_eq!(y, vec![4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn conv_grad_matches_finite_difference() {
        let d = Dims { n: 1, h: 4, w: 4, c: 2 };
        let mut x: Vec<f32> = (0..d.elems()).map(|i| ((i * 7 % 13) as f32 - 6.0) / 6.0).collect();
        let w: Vec<f32> = (0..3 * 3 * 2 * 3).map(|i| ((i * 5 % 11) as f32 - 5.0) / 10.0).collect();
        let loss = |x: &[f32], w: &[f32]| -> f64 {
            let (y, _) = conv2d(x, d, w, 3, 1, 3);
            y.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() * 0.5
        };
        let (y, od) = conv2d(&x, d, &w, 3, 1, 3);
        let dy: Vec<f32> = y.clone(); // dL/dy for L = ½Σy²
        let (dx, dw) = conv2d_bwd(&x, d, &w, 3, 1, 3, &dy);
        assert_eq!(dy.len(), od.elems());
        let eps = 1e-2f32;
        for &i in &[0usize, 5, 17, 31] {
            let base = loss(&x, &w);
            x[i] += eps;
            let plus = loss(&x, &w);
            x[i] -= eps;
            let fd = ((plus - base) / eps as f64) as f32;
            assert!((fd - dx[i]).abs() < 0.05 * (1.0 + fd.abs()), "dx[{i}]: fd {fd} vs {}", dx[i]);
        }
        let mut wm = w.clone();
        for &i in &[0usize, 10, 30] {
            let base = loss(&x, &wm);
            wm[i] += eps;
            let plus = loss(&x, &wm);
            wm[i] -= eps;
            let fd = ((plus - base) / eps as f64) as f32;
            assert!((fd - dw[i]).abs() < 0.05 * (1.0 + fd.abs()), "dw[{i}]: fd {fd} vs {}", dw[i]);
        }
    }

    #[test]
    fn dwconv_matches_grouped_dense() {
        // Depthwise conv == dense conv per single channel.
        let d = Dims { n: 1, h: 4, w: 4, c: 2 };
        let x: Vec<f32> = (0..d.elems()).map(|i| (i as f32 * 0.3).sin()).collect();
        let w: Vec<f32> = (0..9 * 2).map(|i| (i as f32 * 0.7).cos()).collect(); // (3,3,1,2)
        let (y, od) = dwconv2d(&x, d, &w, 3, 1);
        assert_eq!(od.c, 2);
        // Channel 0 via dense conv on the channel-0 slice.
        let d1 = Dims { n: 1, h: 4, w: 4, c: 1 };
        let x0: Vec<f32> = x.iter().step_by(2).cloned().collect();
        let w0: Vec<f32> = w.iter().step_by(2).cloned().collect();
        let (y0, _) = conv2d(&x0, d1, &w0, 3, 1, 1);
        for p in 0..16 {
            assert!((y[p * 2] - y0[p]).abs() < 1e-5);
        }
    }

    #[test]
    fn dwconv_grad_matches_finite_difference() {
        let d = Dims { n: 1, h: 3, w: 3, c: 2 };
        let mut x: Vec<f32> = (0..d.elems()).map(|i| ((i % 5) as f32 - 2.0) / 3.0).collect();
        let w: Vec<f32> = (0..9 * 2).map(|i| ((i % 7) as f32 - 3.0) / 5.0).collect();
        let loss = |x: &[f32], w: &[f32]| -> f64 {
            let (y, _) = dwconv2d(x, d, w, 3, 2);
            y.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() * 0.5
        };
        let (y, _) = dwconv2d(&x, d, &w, 3, 2);
        let (dx, dw) = dwconv2d_bwd(&x, d, &w, 3, 2, &y);
        let eps = 1e-2f32;
        for &i in &[0usize, 7, 17] {
            let base = loss(&x, &w);
            x[i] += eps;
            let plus = loss(&x, &w);
            x[i] -= eps;
            let fd = ((plus - base) / eps as f64) as f32;
            assert!((fd - dx[i]).abs() < 0.05 * (1.0 + fd.abs()), "dx[{i}]: fd {fd} vs {}", dx[i]);
        }
        for &i in &[1usize, 9] {
            let base = loss(&x, &w);
            let mut wm = w.clone();
            wm[i] += eps;
            let plus = loss(&x, &wm);
            let fd = ((plus - base) / eps as f64) as f32;
            assert!((fd - dw[i]).abs() < 0.05 * (1.0 + fd.abs()), "dw[{i}]: fd {fd} vs {}", dw[i]);
        }
    }

    #[test]
    fn group_norm_normalizes_and_bwd_matches_fd() {
        let d = Dims { n: 2, h: 2, w: 2, c: 8 };
        let x: Vec<f32> = (0..d.elems()).map(|i| ((i * 11 % 23) as f32 - 11.0) / 7.0).collect();
        let gamma = vec![1.0f32; 8];
        let beta = vec![0.0f32; 8];
        let (y, cache) = group_norm(&x, d, &gamma, &beta);
        // Per (n, group) the normalized output has ~zero mean, ~unit var.
        let gr = gn_groups(8);
        let cg = 8 / gr;
        let m = (d.h * d.w * cg) as f64;
        for ni in 0..2 {
            for g in 0..gr {
                let mut sum = 0.0f64;
                for p in 0..4 {
                    for j in 0..cg {
                        sum += y[(ni * 4 + p) * 8 + g * cg + j] as f64;
                    }
                }
                assert!((sum / m).abs() < 1e-4, "group mean {}", sum / m);
            }
        }
        // Finite-difference check of dx through a quadratic loss.
        let gamma2: Vec<f32> = (0..8).map(|i| 0.5 + 0.1 * i as f32).collect();
        let beta2: Vec<f32> = (0..8).map(|i| 0.05 * i as f32).collect();
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = group_norm(x, d, &gamma2, &beta2);
            y.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() * 0.5
        };
        let (y2, cache2) = group_norm(&x, d, &gamma2, &beta2);
        let _ = cache;
        let (dx, dgamma, dbeta) = group_norm_bwd(&y2, d, &gamma2, &cache2);
        assert_eq!(dgamma.len(), 8);
        assert_eq!(dbeta.len(), 8);
        let mut xm = x.clone();
        let eps = 1e-2f32;
        for &i in &[0usize, 13, 40, 63] {
            let base = loss(&xm);
            xm[i] += eps;
            let plus = loss(&xm);
            xm[i] -= eps;
            let fd = ((plus - base) / eps as f64) as f32;
            assert!((fd - dx[i]).abs() < 0.05 * (1.0 + fd.abs()), "dx[{i}]: fd {fd} vs {}", dx[i]);
        }
    }

    #[test]
    fn pool_gap_relu_roundtrip() {
        let d = Dims { n: 1, h: 4, w: 4, c: 1 };
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let (y, idx, od) = maxpool2(&x, d);
        assert_eq!(od.h, 2);
        assert_eq!(y, vec![5.0, 7.0, 13.0, 15.0]);
        let dx = maxpool2_bwd(&[1.0, 2.0, 3.0, 4.0], &idx, 16);
        assert_eq!(dx[5], 1.0);
        assert_eq!(dx[15], 4.0);
        assert_eq!(dx.iter().sum::<f32>(), 10.0);

        let g = gap(&x, d);
        assert_eq!(g, vec![7.5]);
        let dg = gap_bwd(&[16.0], d);
        assert!(dg.iter().all(|&v| v == 1.0));

        let mut r = vec![-1.0f32, 0.0, 2.0];
        relu(&mut r);
        assert_eq!(r, vec![0.0, 0.0, 2.0]);
        let mut dr = vec![5.0f32, 5.0, 5.0];
        relu_bwd(&mut dr, &r);
        assert_eq!(dr, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn xent_grad_sums_to_zero_and_loss_matches() {
        let logits = vec![2.0f32, 1.0, 0.0, 0.0, 3.0, 0.0];
        let (correct, loss, grad) = softmax_xent(&logits, 2, 3, &[0, 1], true);
        assert_eq!(correct, 2.0);
        assert!(loss > 0.0 && loss < 1.0);
        let g = grad.unwrap();
        for i in 0..2 {
            let s: f32 = g[i * 3..(i + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "grad rows sum to 0, got {s}");
        }
        // Gold logit's gradient is negative.
        assert!(g[0] < 0.0);
        assert!(g[4] < 0.0);
    }
}
