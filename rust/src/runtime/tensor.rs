//! Host-side tensor: a flat f32 buffer + shape.  All coordinator math
//! (states, bit vectors, params) lives in `Tensor`s; they cross the
//! executable boundary wrapped in [`crate::runtime::Value`]s, and only the
//! PJRT backend (feature `pjrt`) converts them to `xla::Literal`s at its
//! edge.

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    /// Scalars use shape `[]` (empty product = 1 element); a zero anywhere
    /// in the shape means a legitimate zero-element tensor.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product::<usize>();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product::<usize>();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }
}

/// Dtype string (manifest) → element size in bytes; used for size audits.
pub fn dtype_size(dtype: &str) -> usize {
    match dtype {
        "f32" | "s32" => 4,
        "f64" | "s64" => 8,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 2], vec![0.0; 4]);
        assert_eq!(t.elems(), 4);
        let s = Tensor::scalar(3.0);
        assert_eq!(s.elems(), 1);
        assert!(s.shape.is_empty());
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_bad_shape() {
        let _ = Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn zero_element_tensors_construct() {
        let t = Tensor::new(vec![0], vec![]);
        assert_eq!(t.elems(), 0);
        let t = Tensor::zeros(vec![0, 5]);
        assert_eq!(t.elems(), 0);
        assert_eq!(t.shape, vec![0, 5]);
    }

    #[test]
    fn full_fills() {
        let t = Tensor::full(vec![3], 2.0);
        assert_eq!(t.data, vec![2.0, 2.0, 2.0]);
        assert_eq!(dtype_size("f32"), 4);
        assert_eq!(dtype_size("s64"), 8);
    }
}
