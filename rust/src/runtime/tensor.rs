//! Host-side tensor: a flat f32 buffer + shape, with conversions to/from
//! `xla::Literal`.  All coordinator math (states, bit vectors, params) lives
//! in `Tensor`s; literals are built only at the executable boundary.

use xla::{ArrayElement, Literal};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>().max(1),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product::<usize>().max(1);
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product::<usize>().max(1);
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Convert to an XLA literal (f32).
    pub fn to_literal(&self) -> anyhow::Result<Literal> {
        if self.shape.is_empty() {
            return Ok(Literal::scalar(self.data[0]));
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(Literal::vec1(&self.data).reshape(&dims)?)
    }

    pub fn from_literal(lit: &Literal) -> anyhow::Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(dims, data))
    }
}

/// Build an s32 literal (labels).
pub fn lit_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Read a scalar f32 out of a literal.
pub fn scalar_f32(lit: &Literal) -> anyhow::Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Read any literal as Vec<f32> (must be f32-typed).
pub fn vec_f32(lit: &Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Dtype string (manifest) → element size in bytes; used for size audits.
pub fn dtype_size(dtype: &str) -> usize {
    match dtype {
        "f32" | "s32" => 4,
        "f64" | "s64" => 8,
        _ => 4,
    }
}

/// Sanity trait check: Literal roundtrip preserves f32 payloads.
pub fn roundtrip_check() -> anyhow::Result<()> {
    let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    let l = t.to_literal()?;
    let t2 = Tensor::from_literal(&l)?;
    anyhow::ensure!(t == t2, "roundtrip mismatch");
    let _ = f32::TY; // ensure ArrayElement is in scope / linked
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 2], vec![0.0; 4]);
        assert_eq!(t.elems(), 4);
        let s = Tensor::scalar(3.0);
        assert_eq!(s.elems(), 1);
        assert!(s.shape.is_empty());
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_bad_shape() {
        let _ = Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn literal_roundtrip() {
        roundtrip_check().unwrap();
    }

    #[test]
    fn i32_literal() {
        let l = lit_i32(&[1, 2, 3, 4], &[4]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
    }
}
