//! Runtime layer: PJRT client wrapper (xla crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`), the artifact
//! manifest, and host-side tensors.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::Runtime;
pub use manifest::{AgentMeta, ArtifactSpec, LayerMeta, Manifest, ModelMeta, ParamSpec, TensorSpec};
pub use tensor::Tensor;
