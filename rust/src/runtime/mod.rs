//! Runtime layer: the pluggable execution-backend abstraction
//! ([`Backend`]/[`Executable`]), the [`Runtime`] facade that owns one
//! backend plus an executable cache, the artifact manifest, and host-side
//! tensors/values.
//!
//! Backends: `reference` (pure-Rust interpreter, always available — see
//! `reference/`), `pjrt` (XLA PJRT over AOT HLO artifacts, behind the
//! `pjrt` cargo feature — see `client.rs`) and `shard` (multi-process
//! fan-out over reference-runtime workers — see `shard/`).  DESIGN.md
//! §Execution backends documents the numerics and the selection rules.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod manifest;
pub mod reference;
pub mod shard;
pub mod tensor;
pub mod value;

pub use backend::{Backend, BackendKind, Executable, ScratchStats};
pub use manifest::{AgentMeta, ArtifactSpec, LayerMeta, Manifest, ModelMeta, ParamSpec, TensorSpec};
pub use tensor::Tensor;
pub use value::Value;

pub use crate::util::pool::Parallelism;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Cumulative executable statistics (perf pass / reports).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// Optional runtime knobs beyond the backend choice.  Every field
/// auto-resolves from the environment when `None`, so
/// `RuntimeOpts::default()` reproduces the historical behaviour exactly.
#[derive(Debug, Clone, Default)]
pub struct RuntimeOpts {
    /// Worker threads for `exec_batch` fan-out (`--threads` /
    /// `$AUTOQ_THREADS`, else all cores).  For the shard backend this is
    /// the **total** budget across the local worker processes.
    pub threads: Option<Parallelism>,
    /// Local worker processes for the shard backend (`--shard-workers` /
    /// `$AUTOQ_SHARD_WORKERS`, else 2 — or 0 once hosts are given).
    /// Ignored by other backends.
    pub shard_workers: Option<usize>,
    /// Remote `autoq worker --listen` peers for the shard backend
    /// (`--shard-hosts` / `$AUTOQ_SHARD_HOSTS`).  `Some(vec![])` is an
    /// explicit "no hosts" that beats the env — coordinators partitioning
    /// a fleet across workers pass each worker its own (possibly empty)
    /// bucket this way.  Ignored by other backends.
    pub shard_hosts: Option<Vec<String>>,
    /// Wire encoding the shard client requests at handshake
    /// (`--shard-encoding` / `$AUTOQ_SHARD_ENCODING`, else binary).
    pub shard_encoding: Option<shard::Encoding>,
}

impl RuntimeOpts {
    /// Opts carrying only a thread budget (the pre-shard signature).
    pub fn threads(threads: Option<Parallelism>) -> RuntimeOpts {
        RuntimeOpts { threads, ..Default::default() }
    }
}

/// The execution facade every subsystem holds: one backend, one manifest,
/// a name → executable cache and per-artifact stats.  All callers are
/// backend-agnostic — `exec("cif10_eval_quant", inputs)` behaves
/// identically (within float tolerance) on PJRT and the reference
/// interpreter.
pub struct Runtime {
    backend: Box<dyn Backend>,
    kind: BackendKind,
    threads: usize,
    pub manifest: Manifest,
    cache: HashMap<String, Box<dyn Executable>>,
    stats: HashMap<String, ExecStats>,
}

impl Runtime {
    /// Open with automatic backend selection (see [`BackendKind::resolve`]).
    pub fn open(dir: &Path) -> anyhow::Result<Runtime> {
        Self::open_with(dir, BackendKind::resolve(dir, None)?)
    }

    /// Open with an explicit backend and auto-resolved parallelism
    /// (`$AUTOQ_THREADS`, else all cores).
    pub fn open_with(dir: &Path, kind: BackendKind) -> anyhow::Result<Runtime> {
        Self::open_with_opts(dir, kind, None)
    }

    /// Open with an explicit backend and worker-thread budget (`None` =
    /// `$AUTOQ_THREADS`, else all cores — see [`Parallelism::resolve`]).
    /// Shard worker-process count auto-resolves; use [`Runtime::open_full`]
    /// to pin it.
    pub fn open_with_opts(
        dir: &Path,
        kind: BackendKind,
        threads: Option<Parallelism>,
    ) -> anyhow::Result<Runtime> {
        Self::open_full(dir, kind, RuntimeOpts::threads(threads))
    }

    /// Open with an explicit backend and the full option set.  The
    /// reference backend synthesizes its manifest from the built-in model
    /// zoo and never touches `dir`; PJRT loads `dir/manifest.json` and
    /// compiles HLO from `dir`; shard spawns `opts.shard_workers`
    /// reference-runtime subprocesses (lazily, on first dispatch) and
    /// splits the thread budget evenly across them.
    pub fn open_full(dir: &Path, kind: BackendKind, opts: RuntimeOpts) -> anyhow::Result<Runtime> {
        let par = Parallelism::resolve(opts.threads)?;
        let (mut backend, manifest): (Box<dyn Backend>, Manifest) = match kind {
            BackendKind::Reference => (
                Box::new(reference::RefBackend::new()),
                reference::builtin_manifest(),
            ),
            // Shard workers interpret the same builtin zoo the reference
            // backend does, so the parent shares its manifest.
            BackendKind::Shard => (
                Box::new(shard::ShardBackend::with_opts(&shard::ShardOpts {
                    workers: opts.shard_workers,
                    hosts: opts.shard_hosts.clone(),
                    encoding: opts.shard_encoding,
                })?),
                reference::builtin_manifest(),
            ),
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => {
                (Box::new(client::PjrtBackend::new(dir)?), Manifest::load(dir)?)
            }
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => {
                let _ = dir;
                anyhow::bail!(
                    "backend pjrt requested but this build has no `pjrt` cargo feature \
                     (rebuild with --features pjrt, or use --backend reference)"
                );
            }
        };
        backend.set_parallelism(par.get());
        crate::info!("runtime up: backend={} threads={}", kind.as_str(), par.get());
        Ok(Runtime {
            backend,
            kind,
            threads: par.get(),
            manifest,
            cache: HashMap::new(),
            stats: HashMap::new(),
        })
    }

    /// Default artifact dir: $AUTOQ_ARTIFACTS or ./artifacts — the single
    /// resolver shared with `Coordinator::default_dir`.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(std::env::var("AUTOQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()))
    }

    pub fn open_default() -> anyhow::Result<Runtime> {
        Self::open(&Self::default_dir())
    }

    pub fn backend_kind(&self) -> BackendKind {
        self.kind
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Resolved worker-thread budget for `exec_batch` fan-out.
    pub fn parallelism(&self) -> usize {
        self.threads
    }

    /// Load (once) the executable for `name` into the cache.
    pub fn load(&mut self, name: &str) -> anyhow::Result<()> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.artifact(name)?.clone();
            let t0 = Instant::now();
            let exe = self.backend.load(&spec, &self.manifest)?;
            let dt = t0.elapsed().as_secs_f64();
            self.stats.entry(name.to_string()).or_default().compile_secs = dt;
            crate::debug!("loaded {name} in {dt:.2}s ({})", self.backend.name());
            self.cache.insert(name.to_string(), exe);
        }
        Ok(())
    }

    /// Arity-check every input set against the manifest, then hand back
    /// the loaded executable — the shared front half of `exec`/`exec_batch`.
    fn load_for_dispatch(
        &mut self,
        name: &str,
        set_lens: impl Iterator<Item = usize>,
    ) -> anyhow::Result<&mut Box<dyn Executable>> {
        let expected = self.manifest.artifact(name)?.inputs.len();
        for (bi, len) in set_lens.enumerate() {
            anyhow::ensure!(
                len == expected,
                "artifact {name} batch {bi}: got {len} inputs, manifest says {expected}"
            );
        }
        self.load(name)?;
        Ok(self.cache.get_mut(name).expect("loaded above"))
    }

    /// Shared back half of `exec`/`exec_batch`: stats bookkeeping.
    fn note_calls(&mut self, name: &str, calls: u64, t0: Instant) {
        let st = self.stats.entry(name.to_string()).or_default();
        st.calls += calls;
        st.total_secs += t0.elapsed().as_secs_f64();
    }

    /// Execute artifact `name` on host values; returns the decomposed
    /// output tuple.  Input arity is validated against the manifest.
    /// Accepts owned or borrowed values (`&[Value]` / `&[&Value]`) —
    /// callers that hold long-lived parameter values pass references and
    /// skip a full copy per dispatch (EXPERIMENTS.md §Perf, L3 iteration 2).
    pub fn exec<V: std::borrow::Borrow<Value>>(
        &mut self,
        name: &str,
        inputs: &[V],
    ) -> anyhow::Result<Vec<Value>> {
        let refs: Vec<&Value> = inputs.iter().map(|v| v.borrow()).collect();
        let exe = self.load_for_dispatch(name, std::iter::once(refs.len()))?;
        let t0 = Instant::now();
        let outs = exe.execute(&refs)?;
        self.note_calls(name, 1, t0);
        Ok(outs)
    }

    /// Execute artifact `name` once per input set, outputs in input order
    /// — the batch seam `eval_config` fans out through.  Arity of every
    /// set is validated up front; on the reference backend independent
    /// sets run across the worker pool with byte-identical results to a
    /// serial `exec` loop (deterministic reduction, see `util::pool`).
    /// Stats count one call per input set against the fan-out's wall
    /// clock, so `mean(ms)` reads as wall time per set (throughput), not
    /// CPU time, when threads > 1.
    pub fn exec_batch<V: std::borrow::Borrow<Value>>(
        &mut self,
        name: &str,
        batches: &[Vec<V>],
    ) -> anyhow::Result<Vec<Vec<Value>>> {
        let refs: Vec<Vec<&Value>> =
            batches.iter().map(|b| b.iter().map(|v| v.borrow()).collect()).collect();
        let exe = self.load_for_dispatch(name, refs.iter().map(Vec::len))?;
        let t0 = Instant::now();
        let outs = exe.execute_batch(&refs)?;
        self.note_calls(name, batches.len() as u64, t0);
        Ok(outs)
    }

    pub fn stats(&self) -> &HashMap<String, ExecStats> {
        &self.stats
    }

    /// Resident planned-execution scratch of a loaded executable (`None`
    /// when `name` isn't loaded or its backend keeps no workspaces).  The
    /// workspace-reuse regression test reads this through `eval_config` to
    /// assert zero steady-state allocation growth.
    pub fn scratch_stats(&self, name: &str) -> Option<backend::ScratchStats> {
        self.cache.get(name).and_then(|e| e.scratch_stats())
    }

    pub fn stats_report(&self) -> String {
        let mut rows: Vec<_> = self.stats.iter().collect();
        rows.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        let mut s = format!(
            "backend: {}\nartifact                      calls   total(s)  mean(ms)  compile(s)\n",
            self.backend.name()
        );
        for (name, st) in rows {
            let mean_ms = if st.calls > 0 {
                st.total_secs / st.calls as f64 * 1e3
            } else {
                0.0
            };
            s.push_str(&format!(
                "{name:<28} {:>6} {:>10.2} {:>9.2} {:>11.2}\n",
                st.calls, st.total_secs, mean_ms, st.compile_secs
            ));
        }
        s
    }
}
