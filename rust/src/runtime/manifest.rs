//! Typed view of `artifacts/manifest.json` (written by `python -m
//! compile.aot`): artifact I/O specs, model-zoo metadata and agent layouts.
//!
//! This file is the single source of truth binding the three layers: rust
//! never hard-codes a shape — every literal it builds is sized from here.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// Tensor spec of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "s32"
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT'd HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One quantizable layer (conv / dwconv / fc) of a model — Eq.-1 features
/// plus the weight/activation channel slices into the flat bit vectors.
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub typ: String, // "conv" | "dwconv" | "fc"
    pub k: usize,
    pub stride: usize,
    pub cin: usize,
    pub cout: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub h_out: usize,
    pub w_out: usize,
    /// MACs of one inference through this layer (bit-independent logic_t).
    pub macs: u64,
    pub w_off: usize,
    pub w_len: usize,
    pub a_off: usize,
    pub a_len: usize,
}

/// Parameter spec (shape + init kind) — rust initializes weights itself.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String, // "he" | "zeros" | "ones"
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
    pub fn fan_in(&self) -> usize {
        if self.shape.len() > 1 {
            self.shape[..self.shape.len() - 1].iter().product()
        } else {
            self.shape[0]
        }
    }
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub image_hw: usize,
    pub num_classes: usize,
    pub eval_batch: usize,
    pub train_batch: usize,
    pub layers: Vec<LayerMeta>,
    pub params: Vec<ParamSpec>,
    pub w_channels: usize,
    pub a_channels: usize,
    pub total_macs: u64,
}

impl ModelMeta {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.elems()).sum()
    }
    /// Number of quantized weight scalars (conv/fc weights only — norm/bias
    /// params are not quantized).
    pub fn weight_count(&self) -> usize {
        self.params
            .iter()
            .filter(|p| p.name.ends_with(".w"))
            .map(|p| p.elems())
            .sum()
    }
    pub fn layer(&self, name: &str) -> Option<&LayerMeta> {
        self.layers.iter().find(|l| l.name == name)
    }
}

#[derive(Debug, Clone)]
pub struct AgentMeta {
    pub s_dim: usize,
    pub hidden: usize,
    pub act_batch: usize,
    pub upd_batch: usize,
    pub action_scale: f64,
    pub actor_shapes: Vec<Vec<usize>>,
    pub critic_shapes: Vec<Vec<usize>>,
}

#[derive(Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelMeta>,
    pub agents: BTreeMap<String, AgentMeta>,
}

fn spec_list(j: &Json) -> anyhow::Result<Vec<TensorSpec>> {
    let mut out = Vec::new();
    for s in j.as_arr().ok_or_else(|| anyhow::anyhow!("specs not array"))? {
        out.push(TensorSpec {
            shape: s
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            dtype: s.req("dtype")?.as_str().unwrap_or("f32").to_string(),
        });
    }
    Ok(out)
}

fn usize_of(j: &Json, k: &str) -> anyhow::Result<usize> {
    j.req(k)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("{k} not a number"))
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}) — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in root.req("artifacts")?.as_obj().unwrap() {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.req("file")?.as_str().unwrap_or("").to_string(),
                    inputs: spec_list(a.req("inputs")?)?,
                    outputs: spec_list(a.req("outputs")?)?,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, m) in root.req("models")?.as_obj().unwrap() {
            let mut layers = Vec::new();
            for l in m.req("layers")?.as_arr().unwrap() {
                layers.push(LayerMeta {
                    name: l.req("name")?.as_str().unwrap().to_string(),
                    typ: l.req("type")?.as_str().unwrap().to_string(),
                    k: usize_of(l, "k")?,
                    stride: usize_of(l, "stride")?,
                    cin: usize_of(l, "cin")?,
                    cout: usize_of(l, "cout")?,
                    h_in: usize_of(l, "h_in")?,
                    w_in: usize_of(l, "w_in")?,
                    h_out: usize_of(l, "h_out")?,
                    w_out: usize_of(l, "w_out")?,
                    macs: usize_of(l, "macs")? as u64,
                    w_off: usize_of(l, "w_off")?,
                    w_len: usize_of(l, "w_len")?,
                    a_off: usize_of(l, "a_off")?,
                    a_len: usize_of(l, "a_len")?,
                });
            }
            let mut params = Vec::new();
            for p in m.req("params")?.as_arr().unwrap() {
                params.push(ParamSpec {
                    name: p.req("name")?.as_str().unwrap().to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|d| d.as_usize().unwrap())
                        .collect(),
                    init: p.req("init")?.as_str().unwrap().to_string(),
                });
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    image_hw: usize_of(m, "image_hw")?,
                    num_classes: usize_of(m, "num_classes")?,
                    eval_batch: usize_of(m, "eval_batch")?,
                    train_batch: usize_of(m, "train_batch")?,
                    layers,
                    params,
                    w_channels: usize_of(m, "w_channels")?,
                    a_channels: usize_of(m, "a_channels")?,
                    total_macs: usize_of(m, "total_macs")? as u64,
                },
            );
        }

        let mut agents = BTreeMap::new();
        for (name, a) in root.req("agents")?.as_obj().unwrap() {
            let shapes = |k: &str| -> anyhow::Result<Vec<Vec<usize>>> {
                Ok(a.req(k)?
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|s| s.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect())
                    .collect())
            };
            agents.insert(
                name.clone(),
                AgentMeta {
                    s_dim: usize_of(a, "s_dim")?,
                    hidden: usize_of(a, "hidden")?,
                    act_batch: usize_of(a, "act_batch")?,
                    upd_batch: usize_of(a, "upd_batch")?,
                    action_scale: a.req("action_scale")?.as_f64().unwrap_or(32.0),
                    actor_shapes: shapes("actor_shapes")?,
                    critic_shapes: shapes("critic_shapes")?,
                },
            );
        }

        Ok(Manifest { artifacts, models, agents })
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }
    pub fn model(&self, name: &str) -> anyhow::Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name:?} not in manifest"))
    }
    pub fn agent(&self, s_dim: usize) -> anyhow::Result<&AgentMeta> {
        self.agents
            .get(&format!("s{s_dim}"))
            .ok_or_else(|| anyhow::anyhow!("agent s{s_dim} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "artifacts": {"m_eval_quant": {"file": "m.hlo.txt",
        "inputs": [{"shape": [2, 3], "dtype": "f32"}],
        "outputs": [{"shape": [], "dtype": "f32"}]}},
      "models": {"m": {"name": "m", "image_hw": 32, "num_classes": 10,
        "eval_batch": 256, "train_batch": 128,
        "layers": [{"name": "l01_conv", "type": "conv", "k": 3, "stride": 1,
          "cin": 3, "cout": 16, "h_in": 32, "w_in": 32, "h_out": 32,
          "w_out": 32, "macs": 442368, "w_off": 0, "w_len": 16,
          "a_off": 0, "a_len": 3}],
        "params": [{"name": "l01_conv.w", "shape": [3, 3, 3, 16], "init": "he"}],
        "w_channels": 16, "a_channels": 3, "total_macs": 442368}},
      "agents": {"s16": {"s_dim": 16, "hidden": 300, "act_batch": 128,
        "upd_batch": 64, "action_scale": 32.0,
        "actor_shapes": [[16, 300]], "critic_shapes": [[17, 300]]}}
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        let a = m.artifact("m_eval_quant").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].elems(), 6);
        let model = m.model("m").unwrap();
        assert_eq!(model.layers[0].macs, 442368);
        assert_eq!(model.param_count(), 3 * 3 * 3 * 16);
        assert_eq!(model.weight_count(), 3 * 3 * 3 * 16);
        assert_eq!(m.agent(16).unwrap().hidden, 300);
        assert!(m.agent(99).is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn param_fan_in() {
        let p = ParamSpec { name: "w".into(), shape: vec![3, 3, 3, 16], init: "he".into() };
        assert_eq!(p.fan_in(), 27);
        let b = ParamSpec { name: "b".into(), shape: vec![16], init: "zeros".into() };
        assert_eq!(b.fan_in(), 16);
    }
}
