//! The execution-backend abstraction: [`Backend`] produces [`Executable`]s
//! for manifest artifacts, [`BackendKind`] selects an implementation.
//!
//! Three backends exist:
//!   * `pjrt` (feature-gated) — compiles AOT'd HLO-text artifacts through
//!     the XLA PJRT CPU client (`runtime/client.rs`).  Requires `make
//!     artifacts` and the XLA extension library.
//!   * `reference` — a pure-Rust interpreter of the same graphs
//!     (`runtime/reference/`).  Needs no artifacts, no native library, no
//!     python: the whole search pipeline runs anywhere `cargo test` does.
//!   * `shard` — fans `exec` calls across `autoq worker` subprocesses that
//!     each run an in-process reference runtime (`runtime/shard/`), with
//!     results byte-identical to `reference` at every worker count.
//!
//! Selection precedence: explicit caller choice (`--backend` /
//! `Runtime::open_with`) > `$AUTOQ_BACKEND` > auto (PJRT iff compiled in
//! and `manifest.json` exists in the artifact dir, else reference; the
//! auto rule never picks `shard` — multi-process fan-out is always an
//! explicit opt-in).

use std::path::Path;

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::value::Value;

/// Resident scratch owned by an executable's planned-execution engine —
/// the workspace-reuse regression guard reads this through
/// `Runtime::scratch_stats` to assert steady-state dispatches allocate
/// nothing new.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ScratchStats {
    /// Workspaces ever created (≤ peak concurrent workers).
    pub workspaces: usize,
    /// Total resident f32 elements across those workspaces.
    pub f32_len: usize,
    /// Total resident u32 elements (pool argmax tapes).
    pub u32_len: usize,
}

/// One compiled/loaded artifact, ready to dispatch.
pub trait Executable {
    /// Run on host values; returns the decomposed output tuple in manifest
    /// output order.  Input arity is validated by [`Runtime`] before
    /// dispatch.
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>>;

    /// Run many **independent** input sets, outputs in input order.  The
    /// default is the serial loop; stateless executables may fan out
    /// across worker threads, but must stay byte-identical to the serial
    /// path at every thread count (the reference eval interpreter does —
    /// see `util::pool` and `tests/determinism.rs`).
    fn execute_batch(&mut self, batches: &[Vec<&Value>]) -> anyhow::Result<Vec<Vec<Value>>> {
        batches.iter().map(|b| self.execute(b)).collect()
    }

    /// Resident planned-execution scratch, when this executable keeps any
    /// (`None` for backends without a workspace engine, e.g. PJRT).
    /// Quiescent between dispatches by contract: all workspaces are
    /// checked back in whenever no dispatch is in flight.
    fn scratch_stats(&self) -> Option<ScratchStats> {
        None
    }
}

/// An execution engine: turns manifest artifacts into executables.
pub trait Backend {
    fn name(&self) -> &'static str;
    /// Load (and compile, where that means something) artifact `spec`.
    /// `manifest` provides the model/agent metadata interpreters need.
    fn load(
        &mut self,
        spec: &ArtifactSpec,
        manifest: &Manifest,
    ) -> anyhow::Result<Box<dyn Executable>>;

    /// Worker threads for `execute_batch` fan-out in executables loaded
    /// from now on (`Runtime::open_with_opts` calls this before any
    /// load).  Backends that always run serially ignore it.
    fn set_parallelism(&mut self, threads: usize) {
        let _ = threads;
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust reference interpreter (always available).
    Reference,
    /// PJRT over AOT HLO artifacts (needs the `pjrt` cargo feature).
    Pjrt,
    /// Multi-process fan-out over `autoq worker` reference runtimes
    /// (always available; worker count from `--shard-workers` /
    /// `$AUTOQ_SHARD_WORKERS`).
    Shard,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Reference => "reference",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Shard => "shard",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<BackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "reference" | "ref" => Ok(BackendKind::Reference),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            "shard" | "sharded" => Ok(BackendKind::Shard),
            other => anyhow::bail!("unknown backend {other:?} (expected pjrt|reference|shard)"),
        }
    }

    /// Parse an optional CLI value: empty string means "auto-resolve".
    /// The single parser behind every `--backend` flag.
    pub fn parse_opt(s: &str) -> anyhow::Result<Option<BackendKind>> {
        if s.trim().is_empty() {
            Ok(None)
        } else {
            Ok(Some(Self::parse(s)?))
        }
    }

    /// `$AUTOQ_BACKEND`, if set and non-empty.
    pub fn from_env() -> anyhow::Result<Option<BackendKind>> {
        match std::env::var("AUTOQ_BACKEND") {
            Ok(s) if !s.trim().is_empty() => Ok(Some(Self::parse(&s)?)),
            _ => Ok(None),
        }
    }

    /// Resolve the backend for artifact dir `dir`: explicit choice beats
    /// `$AUTOQ_BACKEND` beats the auto rule (PJRT iff compiled in and the
    /// dir holds a manifest).
    pub fn resolve(dir: &Path, explicit: Option<BackendKind>) -> anyhow::Result<BackendKind> {
        if let Some(k) = explicit {
            return Ok(k);
        }
        if let Some(k) = Self::from_env()? {
            return Ok(k);
        }
        if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
            Ok(BackendKind::Pjrt)
        } else {
            Ok(BackendKind::Reference)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tokens() {
        assert_eq!(BackendKind::parse("reference").unwrap(), BackendKind::Reference);
        assert_eq!(BackendKind::parse("REF").unwrap(), BackendKind::Reference);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("shard").unwrap(), BackendKind::Shard);
        assert_eq!(BackendKind::parse("Sharded").unwrap(), BackendKind::Shard);
        assert!(BackendKind::parse("cuda").is_err());
    }

    #[test]
    fn explicit_beats_auto() {
        let dir = std::env::temp_dir().join("autoq_no_such_artifacts");
        let k = BackendKind::resolve(&dir, Some(BackendKind::Pjrt)).unwrap();
        assert_eq!(k, BackendKind::Pjrt);
    }

    #[test]
    fn auto_falls_back_to_reference_without_manifest() {
        // NOTE: relies on AUTOQ_BACKEND being unset in the test environment;
        // the CI lanes keep it that way.
        if BackendKind::from_env().ok().flatten().is_some() {
            return;
        }
        let dir = std::env::temp_dir().join("autoq_no_such_artifacts");
        let k = BackendKind::resolve(&dir, None).unwrap();
        assert_eq!(k, BackendKind::Reference);
    }
}
