//! PJRT runtime: load AOT'd HLO-text artifacts, compile once, execute from
//! the coordinator hot path.  Adapted from /opt/xla-example/load_hlo/.
//!
//! Python is never on this path: artifacts are produced once by
//! `make artifacts` and this module is self-contained afterwards.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::manifest::Manifest;

/// Cumulative executable statistics (perf pass / reports).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, PjRtLoadedExecutable>,
    stats: HashMap<String, ExecStats>,
}

impl Runtime {
    /// Open the artifact directory (must contain manifest.json).
    pub fn open(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()?;
        crate::info!(
            "pjrt client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
            stats: HashMap::new(),
        })
    }

    /// Default artifact dir: $AUTOQ_ARTIFACTS or ./artifacts — the single
    /// resolver shared with `Coordinator::default_dir`.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(std::env::var("AUTOQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()))
    }

    pub fn open_default() -> anyhow::Result<Runtime> {
        Self::open(&Self::default_dir())
    }

    /// Compile (once) and return the executable for `name`.
    pub fn load(&mut self, name: &str) -> anyhow::Result<&PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.artifact(name)?;
            let path = self.dir.join(&spec.file);
            let t0 = Instant::now();
            let proto = HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let dt = t0.elapsed().as_secs_f64();
            self.stats.entry(name.to_string()).or_default().compile_secs = dt;
            crate::debug!("compiled {name} in {dt:.2}s");
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` on host literals; returns the decomposed
    /// output tuple.  Input arity is validated against the manifest.
    /// Accepts owned or borrowed literals (`&[Literal]` / `&[&Literal]`) —
    /// callers that hold long-lived parameter literals pass references and
    /// skip a full copy per dispatch (EXPERIMENTS.md §Perf, L3 iteration 2).
    pub fn exec<L: std::borrow::Borrow<Literal>>(
        &mut self,
        name: &str,
        inputs: &[L],
    ) -> anyhow::Result<Vec<Literal>> {
        let expected = self.manifest.artifact(name)?.inputs.len();
        anyhow::ensure!(
            inputs.len() == expected,
            "artifact {name}: got {} inputs, manifest says {expected}",
            inputs.len()
        );
        self.load(name)?;
        let t0 = Instant::now();
        let exe = &self.cache[name];
        let result = exe.execute(inputs)?;
        // Lowered with return_tuple=True → single tuple output.
        let mut tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        let st = self.stats.entry(name.to_string()).or_default();
        st.calls += 1;
        st.total_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }

    pub fn stats(&self) -> &HashMap<String, ExecStats> {
        &self.stats
    }

    pub fn stats_report(&self) -> String {
        let mut rows: Vec<_> = self.stats.iter().collect();
        rows.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        let mut s = String::from("artifact                      calls   total(s)  mean(ms)  compile(s)\n");
        for (name, st) in rows {
            let mean_ms = if st.calls > 0 {
                st.total_secs / st.calls as f64 * 1e3
            } else {
                0.0
            };
            s.push_str(&format!(
                "{name:<28} {:>6} {:>10.2} {:>9.2} {:>11.2}\n",
                st.calls, st.total_secs, mean_ms, st.compile_secs
            ));
        }
        s
    }
}
