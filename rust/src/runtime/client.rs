//! PJRT execution backend (cargo feature `pjrt`): load AOT'd HLO-text
//! artifacts, compile once through the XLA PJRT CPU client, execute from
//! the coordinator hot path.  Adapted from /opt/xla-example/load_hlo/.
//!
//! Python is never on this path: artifacts are produced once by
//! `make artifacts` and this module is self-contained afterwards.  The
//! [`Value`] ⇄ `xla::Literal` translation happens here, at the backend
//! edge — the rest of the crate never sees a literal.

use std::path::{Path, PathBuf};

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::backend::{Backend, Executable};
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::tensor::Tensor;
use crate::runtime::value::Value;

pub struct PjrtBackend {
    client: PjRtClient,
    dir: PathBuf,
}

impl PjrtBackend {
    pub fn new(dir: &Path) -> anyhow::Result<PjrtBackend> {
        let client = PjRtClient::cpu()?;
        crate::info!(
            "pjrt client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtBackend { client, dir: dir.to_path_buf() })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(
        &mut self,
        spec: &ArtifactSpec,
        _manifest: &Manifest,
    ) -> anyhow::Result<Box<dyn Executable>> {
        let path = self.dir.join(&spec.file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Box::new(PjrtExecutable { exe }))
    }
}

pub struct PjrtExecutable {
    exe: PjRtLoadedExecutable,
}

impl Executable for PjrtExecutable {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|v| value_to_literal(v))
            .collect::<anyhow::Result<_>>()?;
        let result = self.exe.execute(&lits)?;
        // Lowered with return_tuple=True → single tuple output.
        let mut tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        outs.iter().map(literal_to_value).collect()
    }
}

fn value_to_literal(v: &Value) -> anyhow::Result<Literal> {
    match v {
        Value::F32(t) => {
            if t.shape.is_empty() {
                return Ok(Literal::scalar(t.data[0]));
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            Ok(Literal::vec1(&t.data).reshape(&dims)?)
        }
        Value::I32 { shape, data } => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            Ok(Literal::vec1(data).reshape(&dims)?)
        }
    }
}

fn literal_to_value(lit: &Literal) -> anyhow::Result<Value> {
    // Every artifact output in the manifest is f32 (labels are inputs only),
    // so the translation does not need dtype dispatch.
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    Ok(Value::F32(Tensor::new(dims, lit.to_vec::<f32>()?)))
}
