//! The `shard` execution backend: fans `exec` calls across `autoq worker`
//! subprocesses so paper-scale sweeps scale past one address space.
//!
//! Layout mirrors the transport split:
//! * [`proto`] — length-prefixed JSON framing + bit-exact `Value` codec,
//!   written against `io::Read`/`Write` only (a TCP transport for
//!   multi-host fan-out drops in without touching it);
//! * [`worker`] — the subprocess loop behind the hidden `autoq worker`
//!   subcommand (one in-process reference `Runtime` per worker);
//! * [`client`] — the parent's process pool: balanced chunk partition,
//!   index-ordered merge, restart-on-crash with single replay.
//!
//! Determinism rule: every worker runs the pure reference interpreter,
//! the codec preserves f32 bit patterns, and chunk results merge in input
//! order — so `--backend shard` output is **byte-identical** to
//! `--backend reference` at every worker count (`tests/shard_backend.rs`).
//!
//! Budget rule: the backend's thread budget (`--threads`, resolved by the
//! `Runtime`) is the *total* across the pool — each worker process gets an
//! even share of at least one inner eval thread, composing with `Sweep`'s
//! outer per-cell split so `cells × processes × threads` never
//! oversubscribes by more than the explicit ≥ 1 floors.

pub mod client;
pub mod proto;
pub mod worker;

pub use client::{worker_exe, ShardClient, ShardExecutable};

use std::sync::Arc;

use crate::runtime::backend::{Backend, Executable};
use crate::runtime::manifest::{ArtifactSpec, Manifest};

/// Default worker-process count when neither `--shard-workers` nor
/// `$AUTOQ_SHARD_WORKERS` chooses one.
pub const DEFAULT_WORKERS: usize = 2;

/// Parse an optional `--shard-workers` value: empty, `auto` or `0` mean
/// "auto-resolve".  The single parser behind every CLI occurrence.
pub fn parse_workers_opt(s: &str) -> anyhow::Result<Option<usize>> {
    let t = s.trim().to_ascii_lowercase();
    if t.is_empty() || t == "auto" || t == "0" {
        return Ok(None);
    }
    let n: usize = t
        .parse()
        .map_err(|_| anyhow::anyhow!("expected a worker count or 'auto', got {s:?}"))?;
    Ok(Some(n))
}

/// Resolve the worker-process count: explicit (`--shard-workers`) >
/// `$AUTOQ_SHARD_WORKERS` > [`DEFAULT_WORKERS`].  Always ≥ 1.
pub fn resolve_workers(explicit: Option<usize>) -> anyhow::Result<usize> {
    let n = match explicit {
        Some(n) => Some(n),
        None => match std::env::var("AUTOQ_SHARD_WORKERS") {
            Ok(s) if !s.trim().is_empty() => parse_workers_opt(&s)?,
            _ => None,
        },
    };
    Ok(n.unwrap_or(DEFAULT_WORKERS).max(1))
}

/// The shard backend: owns the process pool and hands out forwarding
/// executables.  Workers interpret the same builtin zoo the reference
/// backend does, so the parent's manifest is `builtin_manifest()` and
/// artifact validation happens before `load` is ever called.
pub struct ShardBackend {
    pool: Arc<ShardClient>,
}

impl ShardBackend {
    /// Build a pool of `workers` subprocesses (spawned lazily on first
    /// dispatch, after the `Runtime` has handed over the thread budget).
    pub fn new(workers: usize) -> anyhow::Result<ShardBackend> {
        let pool = Arc::new(ShardClient::new(worker_exe()?, workers));
        crate::info!("shard backend: {} worker process(es)", pool.workers());
        Ok(ShardBackend { pool })
    }

    /// The process pool (crash-injection hooks for tests live here).
    pub fn pool(&self) -> &Arc<ShardClient> {
        &self.pool
    }
}

impl Backend for ShardBackend {
    fn name(&self) -> &'static str {
        "shard"
    }

    /// The resolved budget is the pool **total**; each worker process gets
    /// an even share, never below one thread.
    fn set_parallelism(&mut self, threads: usize) {
        self.pool.set_total_threads(threads);
    }

    fn load(
        &mut self,
        spec: &ArtifactSpec,
        _manifest: &Manifest,
    ) -> anyhow::Result<Box<dyn Executable>> {
        Ok(Box::new(ShardExecutable::new(self.pool.clone(), spec.name.clone())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_count_parse_and_clamp() {
        assert_eq!(parse_workers_opt("").unwrap(), None);
        assert_eq!(parse_workers_opt("auto").unwrap(), None);
        assert_eq!(parse_workers_opt("0").unwrap(), None);
        assert_eq!(parse_workers_opt("4").unwrap(), Some(4));
        assert!(parse_workers_opt("four").is_err());
        assert_eq!(resolve_workers(Some(3)).unwrap(), 3);
        // NOTE: relies on AUTOQ_SHARD_WORKERS being unset or numeric in the
        // test environment; explicit choices above bypass it either way.
    }

    #[test]
    fn backend_hands_out_forwarding_executables() {
        let m = crate::runtime::reference::builtin_manifest();
        let spec = m.artifact("cif10_eval_quant").unwrap().clone();
        let mut b = ShardBackend::new(2).unwrap();
        b.set_parallelism(4);
        // Loading must not spawn anything — workers come up on first
        // dispatch, so a backend that is opened but never dispatched costs
        // no processes.
        assert!(b.load(&spec, &m).is_ok());
        assert_eq!(b.pool().restarts(), 0);
    }
}
