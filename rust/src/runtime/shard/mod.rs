//! The `shard` execution backend: fans `exec` calls across `autoq worker`
//! peers — local subprocesses and/or remote TCP hosts — so paper-scale
//! sweeps scale past one address space (and past one machine).
//!
//! Layout mirrors the transport split:
//! * [`proto`] — length-prefixed framing + the JSON `Value` codec, written
//!   against `io::Read`/`Write` only (stdio pipes and TCP streams use the
//!   same frame loop);
//! * [`bin`] — the compact binary body codec (varints, raw `f32::to_bits`
//!   payloads, intra-frame dedup), negotiated per session at handshake;
//! * [`worker`] — the worker loop behind the hidden `autoq worker`
//!   subcommand: stdio by default, a one-session-at-a-time TCP accept
//!   loop under `--listen`;
//! * [`client`] — the parent's slot pool: balanced chunk partition,
//!   index-ordered merge, re-establish-on-crash (respawn or reconnect)
//!   with single replay.
//!
//! Determinism rule: every worker runs the pure reference interpreter,
//! both codecs preserve f32 bit patterns, and chunk results merge in input
//! order — so `--backend shard` output is **byte-identical** to
//! `--backend reference` at every slot count, over every transport, in
//! either encoding (`tests/shard_backend.rs`).
//!
//! Budget rule: the backend's thread budget (`--threads`, resolved by the
//! `Runtime`) is the *total* across the **local** workers — each local
//! process gets an even share of at least one inner eval thread, composing
//! with `Sweep`'s outer per-cell split so `cells × processes × threads`
//! never oversubscribes by more than the explicit ≥ 1 floors.  Remote
//! workers size themselves via `worker --listen --threads`.

pub mod bin;
pub mod client;
pub mod proto;
pub mod worker;

pub use client::{worker_exe, ShardClient, ShardExecutable};
pub use proto::Encoding;

use std::sync::Arc;

use crate::runtime::backend::{Backend, Executable};
use crate::runtime::manifest::{ArtifactSpec, Manifest};

/// Default local worker-process count when neither `--shard-workers` nor
/// `$AUTOQ_SHARD_WORKERS` chooses one **and no remote hosts are given**.
/// With hosts present the local count defaults to zero — pointing a run at
/// a fleet should not also fork subprocesses unless asked to.
pub const DEFAULT_WORKERS: usize = 2;

/// Parse an optional `--shard-workers` value: empty, `auto` or `0` mean
/// "auto-resolve".  The single parser behind every CLI occurrence.
pub fn parse_workers_opt(s: &str) -> anyhow::Result<Option<usize>> {
    let t = s.trim().to_ascii_lowercase();
    if t.is_empty() || t == "auto" || t == "0" {
        return Ok(None);
    }
    let n: usize = t
        .parse()
        .map_err(|_| anyhow::anyhow!("expected a worker count or 'auto', got {s:?}"))?;
    Ok(Some(n))
}

/// Parse an optional `--shard-hosts` value: a comma-separated list of
/// `host:port` entries; empty means "unset" (fall through to the env).
pub fn parse_hosts_opt(s: &str) -> anyhow::Result<Option<Vec<String>>> {
    let t = s.trim();
    if t.is_empty() {
        return Ok(None);
    }
    let hosts: Vec<String> =
        t.split(',').map(str::trim).filter(|h| !h.is_empty()).map(String::from).collect();
    if hosts.is_empty() {
        return Ok(None);
    }
    for h in &hosts {
        anyhow::ensure!(h.contains(':'), "shard host {h:?} is not of the form host:port");
    }
    Ok(Some(hosts))
}

/// Resolve the remote host list: explicit (`--shard-hosts`, including an
/// explicitly **empty** list meaning "no hosts, I said so") >
/// `$AUTOQ_SHARD_HOSTS` > none.
pub fn resolve_hosts(explicit: Option<Vec<String>>) -> anyhow::Result<Vec<String>> {
    if let Some(hosts) = explicit {
        return Ok(hosts);
    }
    match std::env::var("AUTOQ_SHARD_HOSTS") {
        Ok(s) if !s.trim().is_empty() => Ok(parse_hosts_opt(&s)?.unwrap_or_default()),
        _ => Ok(Vec::new()),
    }
}

/// Resolve the wire encoding: explicit (`--shard-encoding`) >
/// `$AUTOQ_SHARD_ENCODING` > binary.  (Sessions still fall back to JSON
/// per-connection when the peer does not ack the binary handshake.)
pub fn resolve_encoding(explicit: Option<Encoding>) -> Option<Encoding> {
    if explicit.is_some() {
        return explicit;
    }
    match std::env::var("AUTOQ_SHARD_ENCODING") {
        Ok(s) if !s.trim().is_empty() => Encoding::parse_opt(&s).ok().flatten(),
        _ => None,
    }
}

/// Resolve the **local** worker-process count: explicit
/// (`--shard-workers`) > `$AUTOQ_SHARD_WORKERS` > default.  The default is
/// [`DEFAULT_WORKERS`] for a purely local pool, but **zero** when remote
/// hosts are in play (the hosts are the pool; local forks are opt-in).
/// The client still clamps the *total* pool to ≥ 1 slot.
pub fn resolve_workers(explicit: Option<usize>, have_hosts: bool) -> anyhow::Result<usize> {
    let n = match explicit {
        Some(n) => Some(n),
        None => match std::env::var("AUTOQ_SHARD_WORKERS") {
            Ok(s) if !s.trim().is_empty() => parse_workers_opt(&s)?,
            _ => None,
        },
    };
    Ok(n.unwrap_or(if have_hosts { 0 } else { DEFAULT_WORKERS }))
}

/// Round-robin a host list into `parts` disjoint sublists (host *i* →
/// bucket *i* mod `parts`).  Multiple coordinators sharing a fleet (serve
/// workers, sweep cells) must not share hosts — a listening worker serves
/// **one session at a time**, so two pools dialing the same host would
/// serialize behind each other.  Buckets may come back empty when
/// `parts > hosts`; pass the possibly-empty bucket on explicitly so the
/// env does not re-resolve underneath.
pub fn partition_hosts(hosts: &[String], parts: usize) -> Vec<Vec<String>> {
    let parts = parts.max(1);
    let mut buckets: Vec<Vec<String>> = vec![Vec::new(); parts];
    for (i, h) in hosts.iter().enumerate() {
        buckets[i % parts].push(h.clone());
    }
    buckets
}

/// Everything that shapes a shard pool, pre-resolution.  `None` fields
/// fall through to their env vars and defaults.
#[derive(Debug, Clone, Default)]
pub struct ShardOpts {
    /// Local subprocess count (`--shard-workers`).
    pub workers: Option<usize>,
    /// Remote `host:port` peers (`--shard-hosts`); `Some(vec![])` is an
    /// explicit "no hosts" that beats the env.
    pub hosts: Option<Vec<String>>,
    /// Wire encoding to request at handshake (`--shard-encoding`).
    pub encoding: Option<Encoding>,
}

/// The shard backend: owns the slot pool and hands out forwarding
/// executables.  Workers interpret the same builtin zoo the reference
/// backend does, so the parent's manifest is `builtin_manifest()` and
/// artifact validation happens before `load` is ever called.
pub struct ShardBackend {
    pool: Arc<ShardClient>,
}

impl ShardBackend {
    /// Local-only pool of `workers` subprocesses (spawned lazily on first
    /// dispatch, after the `Runtime` has handed over the thread budget).
    pub fn new(workers: usize) -> anyhow::Result<ShardBackend> {
        ShardBackend::with_opts(&ShardOpts { workers: Some(workers), ..ShardOpts::default() })
    }

    /// Resolve `opts` (explicit > env > default per field) and build the
    /// pool: local slots first, then one remote slot per host.
    pub fn with_opts(opts: &ShardOpts) -> anyhow::Result<ShardBackend> {
        let hosts = resolve_hosts(opts.hosts.clone())?;
        let local = resolve_workers(opts.workers, !hosts.is_empty())?;
        let enc = resolve_encoding(opts.encoding).unwrap_or(Encoding::Binary);
        let n_hosts = hosts.len();
        let pool = Arc::new(ShardClient::with_opts(worker_exe()?, local, hosts, enc));
        crate::info!(
            "shard backend: {} local worker(s), {} remote host(s), {} encoding",
            pool.local_workers(),
            n_hosts,
            enc.as_str()
        );
        Ok(ShardBackend { pool })
    }

    /// The slot pool (crash-injection hooks for tests live here).
    pub fn pool(&self) -> &Arc<ShardClient> {
        &self.pool
    }
}

impl Backend for ShardBackend {
    fn name(&self) -> &'static str {
        "shard"
    }

    /// The resolved budget is the **local** pool total; each local worker
    /// process gets an even share, never below one thread.
    fn set_parallelism(&mut self, threads: usize) {
        self.pool.set_total_threads(threads);
    }

    fn load(
        &mut self,
        spec: &ArtifactSpec,
        _manifest: &Manifest,
    ) -> anyhow::Result<Box<dyn Executable>> {
        Ok(Box::new(ShardExecutable::new(self.pool.clone(), spec.name.clone())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_count_parse_and_clamp() {
        assert_eq!(parse_workers_opt("").unwrap(), None);
        assert_eq!(parse_workers_opt("auto").unwrap(), None);
        assert_eq!(parse_workers_opt("0").unwrap(), None);
        assert_eq!(parse_workers_opt("4").unwrap(), Some(4));
        assert!(parse_workers_opt("four").is_err());
        assert_eq!(resolve_workers(Some(3), false).unwrap(), 3);
        assert_eq!(resolve_workers(Some(3), true).unwrap(), 3);
        // NOTE: relies on AUTOQ_SHARD_WORKERS being unset or numeric in the
        // test environment; explicit choices above bypass it either way.
    }

    #[test]
    fn host_lists_parse_and_partition() {
        assert_eq!(parse_hosts_opt("").unwrap(), None);
        assert_eq!(parse_hosts_opt("  ,  ").unwrap(), None);
        assert_eq!(
            parse_hosts_opt("a:1, b:2 ,c:3").unwrap(),
            Some(vec!["a:1".to_string(), "b:2".to_string(), "c:3".to_string()])
        );
        assert!(parse_hosts_opt("no-port").is_err());
        // Explicit empty beats any env value.
        assert_eq!(resolve_hosts(Some(Vec::new())).unwrap(), Vec::<String>::new());

        let hosts: Vec<String> = ["a:1", "b:2", "c:3", "d:4", "e:5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let parts = partition_hosts(&hosts, 2);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], vec!["a:1", "c:3", "e:5"]);
        assert_eq!(parts[1], vec!["b:2", "d:4"]);
        // More parts than hosts: trailing buckets are empty, never panics.
        let sparse = partition_hosts(&hosts[..1], 3);
        assert_eq!(sparse[0], vec!["a:1"]);
        assert!(sparse[1].is_empty() && sparse[2].is_empty());
    }

    #[test]
    fn backend_hands_out_forwarding_executables() {
        let m = crate::runtime::reference::builtin_manifest();
        let spec = m.artifact("cif10_eval_quant").unwrap().clone();
        let mut b = ShardBackend::new(2).unwrap();
        b.set_parallelism(4);
        // Loading must not spawn anything — workers come up on first
        // dispatch, so a backend that is opened but never dispatched costs
        // no processes.
        assert!(b.load(&spec, &m).is_ok());
        assert_eq!(b.pool().restarts(), 0);
        assert_eq!(b.pool().local_workers(), 2);
    }
}
