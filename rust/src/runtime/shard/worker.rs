//! The shard worker loop: the body of the hidden `autoq worker`
//! subcommand.
//!
//! A worker owns one in-process **reference** [`Runtime`] and serves
//! [`proto`] frames over a transport — stdio by default (requests on
//! stdin, responses on stdout, logging/stderr untouched), or TCP via
//! `autoq worker --listen <addr>` (accept loop, **one session at a
//! time**; `exit` or EOF ends the session, not the process).  Artifacts
//! load lazily through the normal `Runtime` cache on first exec, so a
//! respawned worker — or a reconnecting TCP client — needs no state
//! replay: every request is self-contained (the executables are pure —
//! parameters, optimizer moments and RNG-derived inputs all travel as
//! values), which is what makes the client's crash-replay sound.
//!
//! Sessions start in JSON; a handshake ping carrying `"enc":"bin"` is
//! acked and switches the session to the binary codec (`super::bin`).  In
//! binary mode a malformed request body is an app error (`RESP_ERR`, stay
//! up), while undecodable JSON remains connection-fatal — in JSON mode a
//! broken body means the framing itself has desynced.
//!
//! The backend is pinned to `reference` regardless of `$AUTOQ_BACKEND`, so
//! a worker can never recursively open another shard pool.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpListener;
use std::time::Duration;

use crate::runtime::shard::bin;
use crate::runtime::shard::proto::{self, Encoding, Request};
use crate::runtime::{BackendKind, Parallelism, Runtime};
use crate::util::json::Json;

/// Serve stdio requests until `exit` or EOF.  `threads` is this worker's
/// inner eval-thread budget (the client passes its per-process share of
/// the total via `--threads`).
pub fn run(threads: Option<Parallelism>) -> anyhow::Result<()> {
    let mut rt =
        Runtime::open_with_opts(&Runtime::default_dir(), BackendKind::Reference, threads)?;
    let stdin = std::io::stdin();
    let mut rx = stdin.lock();
    let stdout = std::io::stdout();
    let mut tx = BufWriter::new(stdout.lock());
    serve(&mut rt, &mut rx, &mut tx)
}

/// Serve the shard protocol over TCP: bind `listen`, print the resolved
/// address (so `--listen 127.0.0.1:0` callers can discover the port), then
/// accept one session at a time until a shutdown signal.  `idle` is the
/// per-session read timeout — a client that stalls mid-frame or goes
/// silent for that long is dropped and the accept loop continues
/// (`None` = wait forever).
pub fn run_listen(
    listen: &str,
    threads: Option<Parallelism>,
    idle: Option<Duration>,
) -> anyhow::Result<()> {
    let mut rt =
        Runtime::open_with_opts(&Runtime::default_dir(), BackendKind::Reference, threads)?;
    let listener = TcpListener::bind(listen)
        .map_err(|e| anyhow::anyhow!("worker cannot bind {listen}: {e}"))?;
    let addr = listener.local_addr()?;
    println!("autoq worker listening on {addr}");
    std::io::stdout().flush().ok();
    // Nonblocking accept so the loop can poll the shutdown flag between
    // connection attempts (same shape as the serve daemon's accept loop).
    listener.set_nonblocking(true)?;
    loop {
        if crate::util::signal::shutdown_requested() {
            crate::info!("worker: shutdown signal, leaving accept loop");
            return Ok(());
        }
        let (stream, peer) = match listener.accept() {
            Ok(conn) => conn,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
            Err(e) => return Err(anyhow::anyhow!("worker accept failed: {e}")),
        };
        stream.set_nonblocking(false)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(idle)?;
        crate::debug!("worker: session from {peer}");
        let mut rx = BufReader::new(stream.try_clone()?);
        let mut tx = BufWriter::new(stream);
        match serve(&mut rt, &mut rx, &mut tx) {
            Ok(()) => crate::debug!("worker: session from {peer} ended cleanly"),
            Err(e) if proto::is_timeout(&e) => {
                crate::warn_!("worker: session from {peer} idle-timed out, dropping it");
            }
            Err(e) => crate::warn_!("worker: session from {peer} failed: {e:#}"),
        }
    }
}

/// The transport-agnostic loop behind [`run`]/[`run_listen`]: one response
/// frame per request frame, in order, with per-session encoding
/// negotiation.  Split out so tests can drive it over any `Read`/`Write`
/// pair.
pub fn serve(
    rt: &mut Runtime,
    rx: &mut impl std::io::Read,
    tx: &mut impl Write,
) -> anyhow::Result<()> {
    let mut enc = Encoding::Json;
    while let Some(raw) = proto::read_frame_bytes(rx)? {
        match enc {
            Encoding::Json => {
                // Invalid JSON here is framing desync: connection-fatal.
                let msg = Json::parse(std::str::from_utf8(&raw)?)?;
                if is_binary_handshake(&msg) {
                    proto::write_frame(tx, &binary_ack_json(std::process::id()))?;
                    enc = Encoding::Binary;
                    continue;
                }
                let resp = match proto::request_from_json(&msg) {
                    Ok(Request::Exit) => break,
                    Ok(Request::Ping) => proto::ok_empty_json(std::process::id()),
                    Ok(Request::Exec { artifact, batches }) => {
                        match rt.exec_batch(&artifact, &batches) {
                            Ok(outs) => proto::ok_json(&outs),
                            // Deterministic application failure: report
                            // it, stay up.
                            Err(e) => proto::err_json(&format!("{e:#}")),
                        }
                    }
                    Err(e) => proto::err_json(&format!("malformed request: {e:#}")),
                };
                proto::write_frame(tx, &resp)?;
            }
            Encoding::Binary => {
                let resp = match bin::request_from_bytes(&raw) {
                    Ok(Request::Exit) => break,
                    Ok(Request::Ping) => bin::ok_empty_bytes(std::process::id()),
                    Ok(Request::Exec { artifact, batches }) => {
                        match rt.exec_batch(&artifact, &batches) {
                            Ok(outs) => bin::ok_bytes(&outs),
                            Err(e) => bin::err_bytes(&format!("{e:#}")),
                        }
                    }
                    // Tagged bodies cannot desync the length-prefixed
                    // framing, so a bad body is an app error: stay up.
                    Err(e) => bin::err_bytes(&format!("malformed request: {e:#}")),
                };
                proto::write_frame_bytes(tx, &resp)?;
            }
        }
    }
    Ok(())
}

/// A ping carrying `"enc":"bin"` — the upgrade request.  Old workers parse
/// it as a plain ping (`request_from_json` ignores unknown fields), which
/// is exactly the backward-compatible non-ack.
fn is_binary_handshake(msg: &Json) -> bool {
    msg.get("op").and_then(Json::as_str) == Some("ping")
        && msg.get("enc").and_then(Json::as_str) == Some(Encoding::Binary.as_str())
}

/// Ping ack that also echoes the accepted encoding.
fn binary_ack_json(pid: u32) -> Json {
    Json::obj(vec![
        ("ok", true.into()),
        ("pid", (pid as usize).into()),
        ("enc", Encoding::Binary.as_str().into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::value::Value;

    fn test_rt() -> Runtime {
        Runtime::open_with_opts(
            &std::env::temp_dir(),
            BackendKind::Reference,
            Some(Parallelism::new(1)),
        )
        .unwrap()
    }

    fn roundtrip(requests: &[crate::util::json::Json]) -> Vec<crate::util::json::Json> {
        let mut rt = test_rt();
        let mut input = Vec::new();
        for req in requests {
            proto::write_frame(&mut input, req).unwrap();
        }
        let mut out = Vec::new();
        serve(&mut rt, &mut &input[..], &mut out).unwrap();
        let mut frames = Vec::new();
        let mut r = &out[..];
        while let Some(f) = proto::read_frame(&mut r).unwrap() {
            frames.push(f);
        }
        frames
    }

    #[test]
    fn serves_ping_then_stops_at_exit() {
        let frames = roundtrip(&[proto::ping_json(), proto::exit_json(), proto::ping_json()]);
        assert_eq!(frames.len(), 1, "exit must stop the loop before the trailing ping");
        assert!(proto::response_outputs(&frames[0]).unwrap().is_empty());
    }

    #[test]
    fn bad_artifacts_are_app_errors_not_loop_failures() {
        let bogus = Value::scalar(1.0);
        let frames = roundtrip(&[
            proto::exec_json("no_such_artifact_eval_quant", &[vec![&bogus]]),
            proto::ping_json(),
        ]);
        assert_eq!(frames.len(), 2, "the loop must survive an exec failure");
        assert!(proto::response_outputs(&frames[0]).is_err());
        assert!(proto::response_outputs(&frames[1]).is_ok());
    }

    #[test]
    fn binary_handshake_switches_the_session_encoding() {
        let mut rt = test_rt();
        let mut input = Vec::new();
        let upgrade =
            Json::obj(vec![("op", "ping".into()), ("enc", Encoding::Binary.as_str().into())]);
        proto::write_frame(&mut input, &upgrade).unwrap();
        // After the ack everything must be binary — including errors.
        proto::write_frame_bytes(&mut input, &bin::ping_bytes()).unwrap();
        proto::write_frame_bytes(&mut input, &[0x7f]).unwrap(); // malformed
        proto::write_frame_bytes(&mut input, &bin::exit_bytes()).unwrap();
        let mut out = Vec::new();
        serve(&mut rt, &mut &input[..], &mut out).unwrap();
        let mut r = &out[..];
        let ack = proto::read_frame(&mut r).unwrap().unwrap();
        assert_eq!(ack.get("enc").and_then(Json::as_str), Some("bin"), "ack echoes encoding");
        assert!(proto::response_outputs(&ack).unwrap().is_empty());
        let pong = proto::read_frame_bytes(&mut r).unwrap().unwrap();
        assert!(bin::response_from_bytes(&pong).unwrap().is_empty());
        let err = proto::read_frame_bytes(&mut r).unwrap().unwrap();
        let msg = bin::response_from_bytes(&err).unwrap_err();
        assert!(format!("{msg:#}").contains("malformed request"), "bad body is an app error");
        assert!(proto::read_frame_bytes(&mut r).unwrap().is_none(), "exit ends the session");
    }

    #[test]
    fn plain_ping_does_not_upgrade() {
        let frames = roundtrip(&[proto::ping_json(), proto::exit_json()]);
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].get("enc"), None, "no enc hint → no ack, session stays JSON");
    }
}
