//! The shard worker loop: the body of the hidden `autoq worker`
//! subcommand.
//!
//! A worker owns one in-process **reference** [`Runtime`] and serves
//! [`proto`] frames over stdio — requests on stdin, responses on stdout,
//! logging (stderr) untouched.  Artifacts load lazily through the normal
//! `Runtime` cache on first exec, so a respawned worker needs no state
//! replay: every request is self-contained (the executables are pure —
//! parameters, optimizer moments and RNG-derived inputs all travel as
//! values), which is what makes the client's crash-replay sound.
//!
//! The backend is pinned to `reference` regardless of `$AUTOQ_BACKEND`, so
//! a worker can never recursively open another shard pool.

use std::io::{BufWriter, Write};

use crate::runtime::shard::proto::{self, Request};
use crate::runtime::{BackendKind, Parallelism, Runtime};

/// Serve requests until `exit` or EOF.  `threads` is this worker's inner
/// eval-thread budget (the client passes its per-process share of the
/// total via `--threads`).
pub fn run(threads: Option<Parallelism>) -> anyhow::Result<()> {
    let mut rt =
        Runtime::open_with_opts(&Runtime::default_dir(), BackendKind::Reference, threads)?;
    let stdin = std::io::stdin();
    let mut rx = stdin.lock();
    let stdout = std::io::stdout();
    let mut tx = BufWriter::new(stdout.lock());
    serve(&mut rt, &mut rx, &mut tx)
}

/// The transport-agnostic loop behind [`run`]: one response frame per
/// request frame, in order.  Split out so tests (and a future TCP
/// transport) can drive it over any `Read`/`Write` pair.
pub fn serve(
    rt: &mut Runtime,
    rx: &mut impl std::io::Read,
    tx: &mut impl Write,
) -> anyhow::Result<()> {
    while let Some(msg) = proto::read_frame(rx)? {
        let resp = match proto::request_from_json(&msg) {
            Ok(Request::Exit) => break,
            Ok(Request::Ping) => proto::ok_empty_json(std::process::id()),
            Ok(Request::Exec { artifact, batches }) => match rt.exec_batch(&artifact, &batches) {
                Ok(outs) => proto::ok_json(&outs),
                // Deterministic application failure: report it, stay up.
                Err(e) => proto::err_json(&format!("{e:#}")),
            },
            Err(e) => proto::err_json(&format!("malformed request: {e:#}")),
        };
        proto::write_frame(tx, &resp)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::value::Value;

    fn roundtrip(requests: &[crate::util::json::Json]) -> Vec<crate::util::json::Json> {
        let mut rt = Runtime::open_with_opts(
            &std::env::temp_dir(),
            BackendKind::Reference,
            Some(Parallelism::new(1)),
        )
        .unwrap();
        let mut input = Vec::new();
        for req in requests {
            proto::write_frame(&mut input, req).unwrap();
        }
        let mut out = Vec::new();
        serve(&mut rt, &mut &input[..], &mut out).unwrap();
        let mut frames = Vec::new();
        let mut r = &out[..];
        while let Some(f) = proto::read_frame(&mut r).unwrap() {
            frames.push(f);
        }
        frames
    }

    #[test]
    fn serves_ping_then_stops_at_exit() {
        let frames = roundtrip(&[proto::ping_json(), proto::exit_json(), proto::ping_json()]);
        assert_eq!(frames.len(), 1, "exit must stop the loop before the trailing ping");
        assert!(proto::response_outputs(&frames[0]).unwrap().is_empty());
    }

    #[test]
    fn bad_artifacts_are_app_errors_not_loop_failures() {
        let bogus = Value::scalar(1.0);
        let frames = roundtrip(&[
            proto::exec_json("no_such_artifact_eval_quant", &[vec![&bogus]]),
            proto::ping_json(),
        ]);
        assert_eq!(frames.len(), 2, "the loop must survive an exec failure");
        assert!(proto::response_outputs(&frames[0]).is_err());
        assert!(proto::response_outputs(&frames[1]).is_ok());
    }
}
