//! Parent side of the shard backend: a pool of worker **slots** — local
//! `autoq worker` subprocesses over stdio pipes and/or remote
//! `autoq worker --listen` peers over TCP — plus the [`Executable`] that
//! fans `exec` calls across them.
//!
//! Scheduling mirrors `util::pool`: batches are partitioned into balanced
//! contiguous chunks, chunk *c* goes to slot *c*, and chunk results are
//! concatenated in chunk order — so outputs come back in input order and,
//! because every worker runs the same pure reference interpreter on the
//! same bytes, the merged result is **byte-identical** to the in-process
//! reference backend at every slot count, local or remote.
//!
//! Crash handling: a transport failure (worker died, stream closed,
//! connection reset) tears the slot down, re-establishes it — respawn for
//! a local slot, reconnect for a remote one — and replays the in-flight
//! request exactly once.  Sound because requests are self-contained (see
//! `worker.rs`) and a replayed request recomputes the same bytes.
//! Application errors reported by a live worker are deterministic and
//! surface immediately, never replayed — the decode happens *outside* the
//! retry loop, so only genuine transport failures trigger replay.
//!
//! Encoding: each session negotiates at handshake (see
//! `proto::Encoding`) — the handshake itself is always JSON, so old
//! workers interoperate by simply not acking the binary hint.

use std::io::BufReader;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::runtime::backend::Executable;
use crate::runtime::shard::proto::{self, Encoding};
use crate::runtime::shard::bin;
use crate::runtime::value::Value;
use crate::util::json::Json;
use crate::util::pool::Parallelism;

/// Worker binary: `$AUTOQ_WORKER_EXE` override (integration tests point
/// this at the built `autoq` binary — their own executable is the test
/// harness), else this process's image.
pub fn worker_exe() -> anyhow::Result<PathBuf> {
    match std::env::var("AUTOQ_WORKER_EXE") {
        Ok(p) if !p.trim().is_empty() => Ok(PathBuf::from(p)),
        _ => Ok(std::env::current_exe()?),
    }
}

/// Establishing a TCP session (connect + handshake) gets a hard deadline;
/// steady-state reads are unbounded — a healthy long exec can legitimately
/// take minutes, and idle protection is the listening side's job.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// What a slot talks to.
enum SlotKind {
    /// Spawn a local subprocess, frames over stdio pipes.
    Local,
    /// Connect to `host:port`, frames over TCP.
    Remote(String),
}

/// A live transport to one worker.
enum Transport {
    Proc { child: Child, tx: ChildStdin, rx: BufReader<ChildStdout> },
    Tcp { tx: TcpStream, rx: BufReader<TcpStream> },
}

impl Transport {
    fn writer(&mut self) -> &mut dyn std::io::Write {
        match self {
            Transport::Proc { tx, .. } => tx,
            Transport::Tcp { tx, .. } => tx,
        }
    }

    fn reader(&mut self) -> &mut dyn std::io::Read {
        match self {
            Transport::Proc { rx, .. } => rx,
            Transport::Tcp { rx, .. } => rx,
        }
    }

    /// Hard-stop the transport and reap what needs reaping.
    fn teardown(self) {
        match self {
            Transport::Proc { mut child, .. } => {
                let _ = child.kill();
                let _ = child.wait();
            }
            Transport::Tcp { tx, .. } => {
                let _ = tx.shutdown(Shutdown::Both);
            }
        }
    }

    fn describe(&self) -> String {
        match self {
            Transport::Proc { child, .. } => format!("pid {}", child.id()),
            Transport::Tcp { tx, .. } => match tx.peer_addr() {
                Ok(a) => format!("tcp {a}"),
                Err(_) => "tcp <disconnected>".to_string(),
            },
        }
    }
}

/// One established worker session: a transport plus the encoding the
/// handshake settled on.
struct Conn {
    transport: Transport,
    enc: Encoding,
}

/// A request not yet committed to an encoding — encoded per-connection at
/// send time, so a replay onto a fresh session re-encodes under whatever
/// that session negotiated.
enum WireReq<'a> {
    Ping,
    Exec { artifact: &'a str, chunk: &'a [Vec<&'a Value>] },
}

/// A raw response frame; decoding is deferred past the retry loop so app
/// errors are never mistaken for transport failures.
enum Frame {
    Json(Json),
    Bin(Vec<u8>),
}

impl Frame {
    fn outputs(&self) -> anyhow::Result<Vec<Vec<Value>>> {
        match self {
            Frame::Json(j) => proto::response_outputs(j),
            Frame::Bin(b) => bin::response_from_bytes(b),
        }
    }
}

impl Conn {
    /// One request/response exchange.  Any error here is a transport
    /// failure — the worker itself reports application errors inside a
    /// successful response frame.
    fn roundtrip(&mut self, req: &WireReq) -> anyhow::Result<Frame> {
        match self.enc {
            Encoding::Json => {
                let msg = match req {
                    WireReq::Ping => proto::ping_json(),
                    WireReq::Exec { artifact, chunk } => proto::exec_json(artifact, chunk),
                };
                proto::write_frame(self.transport.writer(), &msg)?;
                let resp = proto::read_frame(self.transport.reader())?
                    .ok_or_else(|| anyhow::anyhow!("worker closed its stream mid-request"))?;
                Ok(Frame::Json(resp))
            }
            Encoding::Binary => {
                let body = match req {
                    WireReq::Ping => bin::ping_bytes(),
                    WireReq::Exec { artifact, chunk } => bin::exec_bytes(artifact, chunk),
                };
                proto::write_frame_bytes(self.transport.writer(), &body)?;
                let resp = proto::read_frame_bytes(self.transport.reader())?
                    .ok_or_else(|| anyhow::anyhow!("worker closed its stream mid-request"))?;
                Ok(Frame::Bin(resp))
            }
        }
    }

    /// Best-effort graceful stop in whatever encoding the session speaks.
    fn send_exit(&mut self) {
        let _ = match self.enc {
            Encoding::Json => proto::write_frame(self.transport.writer(), &proto::exit_json()),
            Encoding::Binary => {
                proto::write_frame_bytes(self.transport.writer(), &bin::exit_bytes())
            }
        };
    }

    /// Handshake (always JSON): ping the worker, optionally asking for the
    /// binary encoding; switch the session iff the worker acks.
    fn handshake(&mut self, want: Encoding) -> anyhow::Result<()> {
        let ping = match want {
            Encoding::Json => proto::ping_json(),
            Encoding::Binary => Json::obj(vec![
                ("op", "ping".into()),
                ("enc", Encoding::Binary.as_str().into()),
            ]),
        };
        proto::write_frame(self.transport.writer(), &ping)?;
        let resp = proto::read_frame(self.transport.reader())?
            .ok_or_else(|| anyhow::anyhow!("worker closed its stream during handshake"))?;
        proto::response_outputs(&resp)?;
        if want == Encoding::Binary
            && resp.get("enc").and_then(Json::as_str) == Some(Encoding::Binary.as_str())
        {
            self.enc = Encoding::Binary;
        }
        Ok(())
    }
}

/// The slot pool: lazily established worker sessions, one mutex per slot
/// so concurrent chunk dispatches to distinct slots proceed in parallel.
pub struct ShardClient {
    exe: PathBuf,
    kinds: Vec<SlotKind>,
    slots: Vec<Mutex<Option<Conn>>>,
    /// Encoding to request at handshake (sessions fall back to JSON when
    /// the peer does not ack).
    encoding: Encoding,
    /// Inner eval-thread budget per **local** worker process (the even
    /// share of the backend's total — see [`ShardClient::set_total_threads`]).
    threads_per_worker: AtomicUsize,
    /// Round-robin cursor for single-set execs.
    rr: AtomicUsize,
    /// Slots re-established (respawn or reconnect) after a transport
    /// failure (test/observability hook).
    restarts: AtomicUsize,
}

impl ShardClient {
    /// Local-only pool (the classic shape): `workers` subprocess slots.
    pub fn new(exe: PathBuf, workers: usize) -> ShardClient {
        let enc = super::resolve_encoding(None).unwrap_or(Encoding::Binary);
        ShardClient::with_opts(exe, workers.max(1), Vec::new(), enc)
    }

    /// Mixed pool: `local` subprocess slots (first, so thread budgeting and
    /// chunk order stay stable) plus one remote slot per host.  An entirely
    /// empty pool degenerates to one local slot.
    pub fn with_opts(
        exe: PathBuf,
        local: usize,
        hosts: Vec<String>,
        encoding: Encoding,
    ) -> ShardClient {
        let mut kinds: Vec<SlotKind> = (0..local).map(|_| SlotKind::Local).collect();
        kinds.extend(hosts.into_iter().map(SlotKind::Remote));
        if kinds.is_empty() {
            kinds.push(SlotKind::Local);
        }
        let slots = kinds.iter().map(|_| Mutex::new(None)).collect();
        ShardClient {
            exe,
            kinds,
            slots,
            encoding,
            threads_per_worker: AtomicUsize::new(1),
            rr: AtomicUsize::new(0),
            restarts: AtomicUsize::new(0),
        }
    }

    /// Total slots (local + remote).
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Local subprocess slots (the ones whose threads this host pays for).
    pub fn local_workers(&self) -> usize {
        self.kinds.iter().filter(|k| matches!(k, SlotKind::Local)).count()
    }

    /// How many slots were re-established after dying mid-request.
    pub fn restarts(&self) -> usize {
        self.restarts.load(Ordering::Relaxed)
    }

    /// Split the backend's total thread budget evenly across the **local**
    /// worker processes (≥ 1 each — `workers > total` must oversubscribe
    /// by the explicit one-thread floor, never resolve to "auto = all
    /// cores").  Remote workers size themselves (`worker --listen
    /// --threads`); their share of this machine's budget is zero.  Takes
    /// effect for workers spawned from now on; the `Runtime` calls this
    /// before any artifact loads, i.e. before the first session.
    pub fn set_total_threads(&self, total: usize) {
        let per = Parallelism::share_of(total, self.local_workers().max(1)).get();
        self.threads_per_worker.store(per, Ordering::Relaxed);
    }

    /// Establish slot `idx`: spawn-and-handshake for a local slot,
    /// connect-and-handshake for a remote one.
    fn establish(&self, idx: usize) -> anyhow::Result<Conn> {
        let transport = match &self.kinds[idx] {
            SlotKind::Local => self.spawn_local(idx)?,
            SlotKind::Remote(host) => connect_remote(host)?,
        };
        let mut conn = Conn { transport, enc: Encoding::Json };
        if let Err(e) = conn.handshake(self.encoding) {
            conn.transport.teardown();
            anyhow::bail!("shard worker {idx} failed its handshake: {e:#}");
        }
        // Handshake done: steady-state reads wait as long as the work
        // takes (the connect-phase timeout must not kill long execs).
        if let Transport::Tcp { tx, .. } = &conn.transport {
            tx.set_read_timeout(None).ok();
        }
        crate::debug!(
            "shard worker {idx} up ({}, {} encoding)",
            conn.transport.describe(),
            conn.enc.as_str()
        );
        Ok(conn)
    }

    fn spawn_local(&self, idx: usize) -> anyhow::Result<Transport> {
        let threads = self.threads_per_worker.load(Ordering::Relaxed);
        let mut child = Command::new(&self.exe)
            .arg("worker")
            .arg("--threads")
            .arg(threads.to_string())
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| {
                anyhow::anyhow!("failed to spawn shard worker {idx} {:?}: {e}", self.exe)
            })?;
        let tx = child.stdin.take().expect("stdin piped");
        let rx = BufReader::new(child.stdout.take().expect("stdout piped"));
        Ok(Transport::Proc { child, tx, rx })
    }

    /// Send `req` to slot `idx`, establishing the session if needed.  On a
    /// transport failure the slot is re-established (respawn/reconnect)
    /// and the request replayed exactly once; a second failure propagates.
    /// Returns the raw frame — decode (where app errors surface) happens
    /// at the caller, outside this retry loop.
    fn request_on(&self, idx: usize, req: &WireReq) -> anyhow::Result<Frame> {
        let mut slot = self.slots[idx].lock().expect("shard worker slot poisoned");
        for attempt in 0..2u32 {
            if slot.is_none() {
                *slot = Some(self.establish(idx)?);
            }
            let conn = slot.as_mut().expect("established above");
            match conn.roundtrip(req) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    let dead = slot.take().expect("held above");
                    dead.transport.teardown();
                    anyhow::ensure!(
                        attempt == 0,
                        "shard worker {idx} failed twice on one request: {e:#}"
                    );
                    // Counted only when a replay actually follows — a
                    // terminal failure above is not a restart.
                    self.restarts.fetch_add(1, Ordering::Relaxed);
                    crate::warn_!(
                        "shard worker {idx} died mid-request ({e:#}); re-establishing and replaying"
                    );
                }
            }
        }
        unreachable!("the retry loop returns or bails")
    }

    /// Exec one chunk on one slot and validate the output arity.
    fn exec_chunk(
        &self,
        idx: usize,
        artifact: &str,
        chunk: &[Vec<&Value>],
    ) -> anyhow::Result<Vec<Vec<Value>>> {
        let frame = self.request_on(idx, &WireReq::Exec { artifact, chunk })?;
        let outs = frame.outputs()?;
        anyhow::ensure!(
            outs.len() == chunk.len(),
            "worker {idx} returned {} output sets for {} input sets",
            outs.len(),
            chunk.len()
        );
        Ok(outs)
    }

    /// Run `artifact` once per input set, outputs in input order — the
    /// chunked fan-out described in the module docs.
    pub fn exec_batch(
        &self,
        artifact: &str,
        batches: &[Vec<&Value>],
    ) -> anyhow::Result<Vec<Vec<Value>>> {
        if batches.is_empty() {
            return Ok(Vec::new());
        }
        let w = self.workers().min(batches.len());
        if w <= 1 {
            let idx = self.rr.fetch_add(1, Ordering::Relaxed) % self.workers();
            return self.exec_chunk(idx, artifact, batches);
        }
        // Balanced contiguous partition: chunk c gets base + 1 extra while
        // remainder lasts, exactly covering 0..n.
        let (base, extra) = (batches.len() / w, batches.len() % w);
        let mut bounds = Vec::with_capacity(w + 1);
        bounds.push(0usize);
        for c in 0..w {
            bounds.push(bounds[c] + base + usize::from(c < extra));
        }
        let chunk_results: Vec<anyhow::Result<Vec<Vec<Value>>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..w)
                .map(|c| {
                    let chunk = &batches[bounds[c]..bounds[c + 1]];
                    s.spawn(move || self.exec_chunk(c, artifact, chunk))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard dispatch thread panicked"))
                .collect()
        });
        let mut merged = Vec::with_capacity(batches.len());
        for res in chunk_results {
            merged.extend(res?);
        }
        Ok(merged)
    }

    /// Fault injection for the crash-replay tests: hard-kill slot `idx`'s
    /// transport (SIGKILL for a local worker, socket shutdown for a remote
    /// session) and leave the corpse in its slot, so the next request
    /// discovers the death through the normal transport-error path.
    pub fn kill_worker(&self, idx: usize) {
        if let Some(conn) = self.slots[idx].lock().expect("shard worker slot poisoned").as_mut() {
            match &mut conn.transport {
                Transport::Proc { child, .. } => {
                    let _ = child.kill();
                    let _ = child.wait(); // reap; Child caches the exit status
                }
                Transport::Tcp { tx, .. } => {
                    let _ = tx.shutdown(Shutdown::Both);
                }
            }
        }
    }
}

/// Resolve and connect with a deadline, nodelay on (frames are small
/// request/response exchanges), and a read timeout that covers only the
/// handshake — `establish` lifts it once the session is up.
fn connect_remote(host: &str) -> anyhow::Result<Transport> {
    let addr = host
        .to_socket_addrs()
        .map_err(|e| anyhow::anyhow!("cannot resolve shard host {host:?}: {e}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("shard host {host:?} resolves to no address"))?;
    let tx = TcpStream::connect_timeout(&addr, CONNECT_TIMEOUT)
        .map_err(|e| anyhow::anyhow!("cannot connect to shard host {host}: {e}"))?;
    tx.set_nodelay(true).ok();
    tx.set_read_timeout(Some(CONNECT_TIMEOUT))?;
    let rx = BufReader::new(tx.try_clone()?);
    Ok(Transport::Tcp { tx, rx })
}

impl Drop for ShardClient {
    fn drop(&mut self) {
        for slot in &self.slots {
            let Ok(mut guard) = slot.lock() else { continue };
            if let Some(mut conn) = guard.take() {
                // Best-effort graceful stop; closing the transport ends
                // the worker's session even if the frame was lost.
                conn.send_exit();
                match conn.transport {
                    Transport::Proc { mut child, tx, .. } => {
                        drop(tx); // EOF on the worker's stdin
                        let _ = child.wait();
                    }
                    // Dropping the stream closes the session; the remote
                    // worker stays up for its next client.
                    Transport::Tcp { .. } => {}
                }
            }
        }
    }
}

/// [`Executable`] forwarding to the slot pool.  Stateless by
/// construction — all model/agent state travels through the inputs — so
/// any worker can serve any call.
pub struct ShardExecutable {
    client: Arc<ShardClient>,
    name: String,
}

impl ShardExecutable {
    pub fn new(client: Arc<ShardClient>, name: String) -> ShardExecutable {
        ShardExecutable { client, name }
    }
}

impl Executable for ShardExecutable {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        let mut outs = self.client.exec_batch(&self.name, &[inputs.to_vec()])?;
        anyhow::ensure!(outs.len() == 1, "single exec returned {} output sets", outs.len());
        Ok(outs.pop().expect("checked above"))
    }

    fn execute_batch(&mut self, batches: &[Vec<&Value>]) -> anyhow::Result<Vec<Vec<Value>>> {
        self.client.exec_batch(&self.name, batches)
    }
}
