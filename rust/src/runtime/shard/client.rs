//! Parent side of the shard backend: a pool of `autoq worker` subprocesses
//! plus the [`Executable`] that fans `exec` calls across them.
//!
//! Scheduling mirrors `util::pool`: batches are partitioned into balanced
//! contiguous chunks, chunk *c* goes to worker *c*, and chunk results are
//! concatenated in chunk order — so outputs come back in input order and,
//! because every worker runs the same pure reference interpreter on the
//! same bytes, the merged result is **byte-identical** to the in-process
//! reference backend at every worker count.
//!
//! Crash handling: a transport failure (worker died, stream closed) kills
//! and respawns that worker, then replays the in-flight request exactly
//! once — sound because requests are self-contained (see `worker.rs`) and
//! a replayed request recomputes the same bytes.  Application errors
//! reported by a live worker are deterministic and surface immediately,
//! never replayed.

use std::io::BufReader;
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::backend::Executable;
use crate::runtime::shard::proto;
use crate::runtime::value::Value;
use crate::util::json::Json;
use crate::util::pool::Parallelism;

/// Worker binary: `$AUTOQ_WORKER_EXE` override (integration tests point
/// this at the built `autoq` binary — their own executable is the test
/// harness), else this process's image.
pub fn worker_exe() -> anyhow::Result<PathBuf> {
    match std::env::var("AUTOQ_WORKER_EXE") {
        Ok(p) if !p.trim().is_empty() => Ok(PathBuf::from(p)),
        _ => Ok(std::env::current_exe()?),
    }
}

/// One live worker subprocess with its pipe endpoints.
struct WorkerProc {
    child: Child,
    tx: ChildStdin,
    rx: BufReader<ChildStdout>,
}

impl WorkerProc {
    /// One request/response exchange.  Any error here is a transport
    /// failure — the worker itself reports application errors inside a
    /// successful response frame.
    fn roundtrip(&mut self, req: &Json) -> anyhow::Result<Json> {
        proto::write_frame(&mut self.tx, req)?;
        proto::read_frame(&mut self.rx)?
            .ok_or_else(|| anyhow::anyhow!("worker closed its stream mid-request"))
    }
}

/// The process pool: lazily spawned workers, one mutex per slot so
/// concurrent chunk dispatches to distinct workers proceed in parallel.
pub struct ShardClient {
    exe: PathBuf,
    slots: Vec<Mutex<Option<WorkerProc>>>,
    /// Inner eval-thread budget per worker process (the even share of the
    /// backend's total — see [`ShardClient::set_total_threads`]).
    threads_per_worker: AtomicUsize,
    /// Round-robin cursor for single-set execs.
    rr: AtomicUsize,
    /// Workers respawned after a transport failure (test/observability hook).
    restarts: AtomicUsize,
}

impl ShardClient {
    pub fn new(exe: PathBuf, workers: usize) -> ShardClient {
        ShardClient {
            exe,
            slots: (0..workers.max(1)).map(|_| Mutex::new(None)).collect(),
            threads_per_worker: AtomicUsize::new(1),
            rr: AtomicUsize::new(0),
            restarts: AtomicUsize::new(0),
        }
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// How many workers were respawned after dying mid-request.
    pub fn restarts(&self) -> usize {
        self.restarts.load(Ordering::Relaxed)
    }

    /// Split the backend's total thread budget evenly across the worker
    /// processes (≥ 1 each — `workers > total` must oversubscribe by the
    /// explicit one-thread floor, never resolve to "auto = all cores").
    /// Takes effect for workers spawned from now on; the `Runtime` calls
    /// this before any artifact loads, i.e. before the first spawn.
    pub fn set_total_threads(&self, total: usize) {
        let per = Parallelism::share_of(total, self.workers()).get();
        self.threads_per_worker.store(per, Ordering::Relaxed);
    }

    fn spawn(&self, idx: usize) -> anyhow::Result<WorkerProc> {
        let threads = self.threads_per_worker.load(Ordering::Relaxed);
        let mut child = Command::new(&self.exe)
            .arg("worker")
            .arg("--threads")
            .arg(threads.to_string())
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| anyhow::anyhow!("failed to spawn shard worker {:?}: {e}", self.exe))?;
        let tx = child.stdin.take().expect("stdin piped");
        let rx = BufReader::new(child.stdout.take().expect("stdout piped"));
        let mut wp = WorkerProc { child, tx, rx };
        // Handshake: the first frame back must be an ok ping response, so a
        // misconfigured binary fails loudly here instead of corrupting an
        // exec exchange later.
        let resp = wp.roundtrip(&proto::ping_json()).map_err(|e| {
            let _ = wp.child.kill();
            let _ = wp.child.wait();
            anyhow::anyhow!("shard worker {idx} failed its spawn handshake: {e:#}")
        })?;
        proto::response_outputs(&resp)?;
        crate::debug!(
            "shard worker {idx} up (pid {}, {} inner thread(s))",
            wp.child.id(),
            threads
        );
        Ok(wp)
    }

    /// Send `req` to worker `idx`, spawning it if needed.  On a transport
    /// failure the worker is respawned and the request replayed exactly
    /// once; a second failure propagates.
    fn request_on(&self, idx: usize, req: &Json) -> anyhow::Result<Json> {
        let mut slot = self.slots[idx].lock().expect("shard worker slot poisoned");
        for attempt in 0..2u32 {
            if slot.is_none() {
                *slot = Some(self.spawn(idx)?);
            }
            let wp = slot.as_mut().expect("spawned above");
            match wp.roundtrip(req) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    let mut dead = slot.take().expect("held above");
                    let _ = dead.child.kill();
                    let _ = dead.child.wait();
                    anyhow::ensure!(
                        attempt == 0,
                        "shard worker {idx} failed twice on one request: {e:#}"
                    );
                    // Counted only when a respawn-and-replay actually
                    // follows — a terminal failure above is not a restart.
                    self.restarts.fetch_add(1, Ordering::Relaxed);
                    crate::warn_!(
                        "shard worker {idx} died mid-request ({e:#}); respawning and replaying"
                    );
                }
            }
        }
        unreachable!("the retry loop returns or bails")
    }

    /// Exec one chunk on one worker and validate the output arity.
    fn exec_chunk(
        &self,
        idx: usize,
        artifact: &str,
        chunk: &[Vec<&Value>],
    ) -> anyhow::Result<Vec<Vec<Value>>> {
        let resp = self.request_on(idx, &proto::exec_json(artifact, chunk))?;
        let outs = proto::response_outputs(&resp)?;
        anyhow::ensure!(
            outs.len() == chunk.len(),
            "worker {idx} returned {} output sets for {} input sets",
            outs.len(),
            chunk.len()
        );
        Ok(outs)
    }

    /// Run `artifact` once per input set, outputs in input order — the
    /// chunked fan-out described in the module docs.
    pub fn exec_batch(
        &self,
        artifact: &str,
        batches: &[Vec<&Value>],
    ) -> anyhow::Result<Vec<Vec<Value>>> {
        if batches.is_empty() {
            return Ok(Vec::new());
        }
        let w = self.workers().min(batches.len());
        if w <= 1 {
            let idx = self.rr.fetch_add(1, Ordering::Relaxed) % self.workers();
            return self.exec_chunk(idx, artifact, batches);
        }
        // Balanced contiguous partition: chunk c gets base + 1 extra while
        // remainder lasts, exactly covering 0..n.
        let (base, extra) = (batches.len() / w, batches.len() % w);
        let mut bounds = Vec::with_capacity(w + 1);
        bounds.push(0usize);
        for c in 0..w {
            bounds.push(bounds[c] + base + usize::from(c < extra));
        }
        let chunk_results: Vec<anyhow::Result<Vec<Vec<Value>>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..w)
                .map(|c| {
                    let chunk = &batches[bounds[c]..bounds[c + 1]];
                    s.spawn(move || self.exec_chunk(c, artifact, chunk))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard dispatch thread panicked"))
                .collect()
        });
        let mut merged = Vec::with_capacity(batches.len());
        for res in chunk_results {
            merged.extend(res?);
        }
        Ok(merged)
    }

    /// Fault injection for the crash-replay tests: SIGKILL worker `idx`
    /// (if it is running) and leave the corpse in its slot, so the next
    /// request discovers the death through the normal transport-error
    /// path.
    pub fn kill_worker(&self, idx: usize) {
        if let Some(wp) = self.slots[idx].lock().expect("shard worker slot poisoned").as_mut() {
            let _ = wp.child.kill();
            let _ = wp.child.wait(); // reap; Child caches the exit status
        }
    }
}

impl Drop for ShardClient {
    fn drop(&mut self) {
        for slot in &self.slots {
            let Ok(mut guard) = slot.lock() else { continue };
            if let Some(mut wp) = guard.take() {
                // Best-effort graceful stop; dropping tx closes the pipe,
                // which ends the worker loop even if the frame was lost.
                let _ = proto::write_frame(&mut wp.tx, &proto::exit_json());
                drop(wp.tx);
                let _ = wp.child.wait();
            }
        }
    }
}

/// [`Executable`] forwarding to the process pool.  Stateless by
/// construction — all model/agent state travels through the inputs — so
/// any worker can serve any call.
pub struct ShardExecutable {
    client: Arc<ShardClient>,
    name: String,
}

impl ShardExecutable {
    pub fn new(client: Arc<ShardClient>, name: String) -> ShardExecutable {
        ShardExecutable { client, name }
    }
}

impl Executable for ShardExecutable {
    fn execute(&mut self, inputs: &[&Value]) -> anyhow::Result<Vec<Value>> {
        let mut outs = self.client.exec_batch(&self.name, &[inputs.to_vec()])?;
        anyhow::ensure!(outs.len() == 1, "single exec returned {} output sets", outs.len());
        Ok(outs.pop().expect("checked above"))
    }

    fn execute_batch(&mut self, batches: &[Vec<&Value>]) -> anyhow::Result<Vec<Vec<Value>>> {
        self.client.exec_batch(&self.name, batches)
    }
}
