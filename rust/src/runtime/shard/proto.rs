//! Wire protocol of the shard backend: length-prefixed frames, a
//! **bit-exact** JSON [`Value`] codec, and the encoding negotiation shared
//! by the JSON and binary (`super::bin`) codecs.
//!
//! Framing is a 4-byte little-endian length followed by that many body
//! bytes — UTF-8 JSON in [`Encoding::Json`] mode, the compact tagged
//! format of `super::bin` in [`Encoding::Binary`] mode.  Both halves are
//! written against plain `io::Read`/`Write`, so the same protocol runs
//! over stdio pipes and TCP streams alike — nothing in this module knows
//! about processes or sockets.
//!
//! The JSON codec must preserve every f32 **bit pattern** (the shard
//! backend's contract is byte-identical results to the in-process
//! reference backend, and eval can legitimately produce -0.0 or propagate
//! NaN), so f32 tensors travel as their `to_bits()` u32 payloads —
//! integers ≤ 2^32 are exact in the JSON substrate's f64 numbers, where a
//! decimal float round-trip would lose NaN payloads and JSON cannot carry
//! NaN/inf at all.

use std::io::{Read, Write};

use crate::runtime::value::Value;
use crate::util::json::Json;

/// Upper bound on one frame (1 GiB).  A length prefix beyond this is
/// treated as stream corruption, not an allocation request.
pub const MAX_FRAME: usize = 1 << 30;

// ---- encodings ------------------------------------------------------------

/// Session body encoding.  Every session starts in `Json`; the client's
/// handshake ping may carry `"enc":"bin"`, and a worker that acks it
/// (`"enc":"bin"` echoed on the ping response) switches both directions of
/// the session to `Binary` from the next frame on.  Workers that predate
/// the binary codec ignore the hint, so negotiation is backward-safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// Debug/interop mode: UTF-8 JSON bodies (`value_to_json` codec).
    Json,
    /// Compact tagged binary bodies (`super::bin` codec).
    Binary,
}

impl Encoding {
    /// Wire token (also the `--shard-encoding` CLI token).
    pub fn as_str(self) -> &'static str {
        match self {
            Encoding::Json => "json",
            Encoding::Binary => "bin",
        }
    }

    /// Parse a CLI/env token; empty and `auto` mean "no preference"
    /// (caller applies the default, which is `Binary`).
    pub fn parse_opt(s: &str) -> anyhow::Result<Option<Encoding>> {
        match s.trim() {
            "" | "auto" => Ok(None),
            "json" => Ok(Some(Encoding::Json)),
            "bin" | "binary" => Ok(Some(Encoding::Binary)),
            other => anyhow::bail!("bad encoding {other:?} (expected json|binary|auto)"),
        }
    }
}

// ---- framing --------------------------------------------------------------

/// Write one `len(u32 LE) + body` frame and flush it.  An oversized body
/// is a hard error — a truncated `as u32` length prefix would silently
/// desync the stream instead.
pub fn write_frame_bytes(w: &mut impl Write, body: &[u8]) -> anyhow::Result<()> {
    anyhow::ensure!(
        body.len() <= MAX_FRAME,
        "frame body {} bytes exceeds cap {MAX_FRAME} (split the batch)",
        body.len()
    );
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Read one raw frame body.  `Ok(None)` on clean EOF (stream closed
/// between frames); errors on truncation mid-frame or oversized lengths.
pub fn read_frame_bytes(r: &mut impl Read) -> anyhow::Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; 4];
    match r.read_exact(&mut len4) {
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        res => res?,
    }
    let len = u32::from_le_bytes(len4) as usize;
    anyhow::ensure!(len <= MAX_FRAME, "frame length {len} exceeds cap {MAX_FRAME}");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(body))
}

/// Write one JSON frame.
pub fn write_frame(w: &mut impl Write, msg: &Json) -> anyhow::Result<()> {
    write_frame_bytes(w, msg.to_string().as_bytes())
}

/// Read one JSON frame (errors additionally on a body that is not valid
/// JSON — in JSON mode that is stream corruption, not an app error).
pub fn read_frame(r: &mut impl Read) -> anyhow::Result<Option<Json>> {
    match read_frame_bytes(r)? {
        None => Ok(None),
        Some(body) => {
            let text = std::str::from_utf8(&body)?;
            Ok(Some(Json::parse(text)?))
        }
    }
}

/// Does this error chain bottom out in a socket read timeout?  Read
/// timeouts surface as `WouldBlock` (Unix `SO_RCVTIMEO`) or `TimedOut`
/// from `read_exact`, wrapped in anyhow context by the framing layer.
pub fn is_timeout(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        matches!(
            c.downcast_ref::<std::io::Error>().map(std::io::Error::kind),
            Some(std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
        )
    })
}

// ---- value codec ----------------------------------------------------------

/// Encode a [`Value`] bit-exactly: f32 data as `to_bits()` u32s, s32 data
/// as plain integers (both exact in f64).
pub fn value_to_json(v: &Value) -> Json {
    let shape = |s: &[usize]| Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect());
    match v {
        Value::F32(t) => Json::obj(vec![
            ("t", "f32".into()),
            ("shape", shape(&t.shape)),
            ("bits", Json::Arr(t.data.iter().map(|x| Json::Num(x.to_bits() as f64)).collect())),
        ]),
        Value::I32 { shape: s, data } => Json::obj(vec![
            ("t", "s32".into()),
            ("shape", shape(s)),
            ("data", Json::Arr(data.iter().map(|&x| Json::Num(x as f64)).collect())),
        ]),
    }
}

fn shape_from(j: &Json) -> anyhow::Result<Vec<usize>> {
    j.req("shape")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("value shape must be an array"))?
        .iter()
        .map(|d| {
            let n = d.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric shape dim"))?;
            anyhow::ensure!(n >= 0.0 && n.fract() == 0.0, "bad shape dim {n}");
            Ok(n as usize)
        })
        .collect()
}

/// Decode a [`value_to_json`] payload, validating dtype, shape and the
/// integer range of every element.
pub fn value_from_json(j: &Json) -> anyhow::Result<Value> {
    let shape = shape_from(j)?;
    // No `.max(1)`: a scalar's empty shape products to 1 on its own, and a
    // zero dim means a legitimate zero-element tensor (0 payload words).
    let elems = shape.iter().product::<usize>();
    match j.req("t")?.as_str() {
        Some("f32") => {
            let bits = j
                .req("bits")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("f32 value needs a bits array"))?;
            anyhow::ensure!(bits.len() == elems, "shape {shape:?} vs {} bit words", bits.len());
            let data = bits
                .iter()
                .map(|b| {
                    let n = b.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric bits"))?;
                    anyhow::ensure!(
                        (0.0..=u32::MAX as f64).contains(&n) && n.fract() == 0.0,
                        "bit word {n} out of u32 range"
                    );
                    Ok(f32::from_bits(n as u32))
                })
                .collect::<anyhow::Result<Vec<f32>>>()?;
            Ok(Value::f32(shape, data))
        }
        Some("s32") => {
            let raw = j
                .req("data")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("s32 value needs a data array"))?;
            anyhow::ensure!(raw.len() == elems, "shape {shape:?} vs {} ints", raw.len());
            let data = raw
                .iter()
                .map(|x| {
                    let n = x.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric s32"))?;
                    anyhow::ensure!(
                        (i32::MIN as f64..=i32::MAX as f64).contains(&n) && n.fract() == 0.0,
                        "s32 element {n} out of range"
                    );
                    Ok(n as i32)
                })
                .collect::<anyhow::Result<Vec<i32>>>()?;
            Ok(Value::i32(shape, data))
        }
        other => anyhow::bail!("unknown value dtype {other:?}"),
    }
}

// ---- requests -------------------------------------------------------------

/// A parsed parent→worker request (the worker's side of the protocol; the
/// client builds frames with the `*_json` helpers below to avoid cloning
/// its borrowed input values).
#[derive(Debug)]
pub enum Request {
    /// Liveness/handshake probe.
    Ping,
    /// Run `artifact` once per input set, outputs in input order.
    Exec { artifact: String, batches: Vec<Vec<Value>> },
    /// Drain and exit the worker loop (no response frame).
    Exit,
}

pub fn ping_json() -> Json {
    Json::obj(vec![("op", "ping".into())])
}

pub fn exit_json() -> Json {
    Json::obj(vec![("op", "exit".into())])
}

/// Build an exec request from borrowed input sets (`&[Vec<&Value>]` or
/// owned vectors — mirrors `Runtime::exec_batch`).
pub fn exec_json<V: std::borrow::Borrow<Value>>(artifact: &str, batches: &[Vec<V>]) -> Json {
    let sets = batches
        .iter()
        .map(|set| Json::Arr(set.iter().map(|v| value_to_json(v.borrow())).collect()))
        .collect();
    Json::obj(vec![
        ("op", "exec".into()),
        ("artifact", artifact.into()),
        ("batches", Json::Arr(sets)),
    ])
}

pub fn request_from_json(j: &Json) -> anyhow::Result<Request> {
    match j.req("op")?.as_str() {
        Some("ping") => Ok(Request::Ping),
        Some("exit") => Ok(Request::Exit),
        Some("exec") => {
            let artifact = j
                .req("artifact")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("exec artifact must be a string"))?
                .to_string();
            let batches = j
                .req("batches")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("exec batches must be an array"))?
                .iter()
                .map(|set| {
                    set.as_arr()
                        .ok_or_else(|| anyhow::anyhow!("input set must be an array"))?
                        .iter()
                        .map(value_from_json)
                        .collect()
                })
                .collect::<anyhow::Result<Vec<Vec<Value>>>>()?;
            Ok(Request::Exec { artifact, batches })
        }
        other => anyhow::bail!("unknown request op {other:?}"),
    }
}

// ---- responses ------------------------------------------------------------

/// Success response carrying output tuples in input order.
pub fn ok_json(outputs: &[Vec<Value>]) -> Json {
    let outs = outputs
        .iter()
        .map(|set| Json::Arr(set.iter().map(value_to_json).collect()))
        .collect();
    Json::obj(vec![("ok", true.into()), ("outputs", Json::Arr(outs))])
}

/// Success response with no payload (ping); carries the worker pid so the
/// client can log which process answered.
pub fn ok_empty_json(pid: u32) -> Json {
    Json::obj(vec![("ok", true.into()), ("pid", (pid as usize).into())])
}

/// Application-level failure (deterministic — the client must surface it,
/// never replay it).
pub fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", false.into()), ("error", msg.into())])
}

/// Parse a response frame into output tuples.  A missing `outputs` field
/// on a success (ping) is an empty result; `ok: false` surfaces the
/// worker's error message.
pub fn response_outputs(j: &Json) -> anyhow::Result<Vec<Vec<Value>>> {
    match j.req("ok")?.as_bool() {
        Some(true) => {}
        Some(false) => {
            let msg = j.get("error").and_then(Json::as_str).unwrap_or("unknown worker error");
            anyhow::bail!("shard worker reported: {msg}");
        }
        None => anyhow::bail!("response ok field must be a bool"),
    }
    match j.get("outputs") {
        None => Ok(Vec::new()),
        Some(outs) => outs
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("outputs must be an array"))?
            .iter()
            .map(|set| {
                set.as_arr()
                    .ok_or_else(|| anyhow::anyhow!("output set must be an array"))?
                    .iter()
                    .map(value_from_json)
                    .collect()
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::Tensor;

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &ping_json()).unwrap();
        write_frame(&mut buf, &exit_json()).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), ping_json());
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), exit_json());
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn truncated_and_oversized_frames_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &ping_json()).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_frame(&mut &buf[..]).is_err(), "mid-frame truncation");
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes().to_vec();
        assert!(read_frame(&mut &huge[..]).is_err(), "length cap");
    }

    #[test]
    fn value_codec_is_bit_exact_including_nan_and_negzero() {
        let specials = vec![
            0.0f32,
            -0.0,
            1.5,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            f32::from_bits(0x7fc0_1234), // NaN with payload
            -3.25e-38,
        ];
        let v = Value::F32(Tensor::new(vec![3, 3], specials.clone()));
        let back = value_from_json(&Json::parse(&value_to_json(&v).to_string()).unwrap()).unwrap();
        let t = back.as_f32().unwrap();
        assert_eq!(t.shape, vec![3, 3]);
        for (a, b) in specials.iter().zip(&t.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} lost its bit pattern");
        }

        let iv = Value::i32(vec![4], vec![i32::MIN, -1, 0, i32::MAX]);
        let iback =
            value_from_json(&Json::parse(&value_to_json(&iv).to_string()).unwrap()).unwrap();
        assert_eq!(iback.as_i32().unwrap(), &[i32::MIN, -1, 0, i32::MAX]);
    }

    #[test]
    fn zero_element_tensors_roundtrip_json() {
        for (v, shape) in [
            (Value::f32(vec![0], vec![]), vec![0]),
            (Value::f32(vec![0, 5], vec![]), vec![0, 5]),
            (Value::i32(vec![0], vec![]), vec![0]),
        ] {
            let back =
                value_from_json(&Json::parse(&value_to_json(&v).to_string()).unwrap()).unwrap();
            assert_eq!(back.shape(), &shape[..], "shape survives");
            assert_eq!(back, v, "zero-element value must roundtrip");
        }
    }

    #[test]
    fn encoding_tokens_parse() {
        assert_eq!(Encoding::parse_opt("").unwrap(), None);
        assert_eq!(Encoding::parse_opt("auto").unwrap(), None);
        assert_eq!(Encoding::parse_opt("json").unwrap(), Some(Encoding::Json));
        assert_eq!(Encoding::parse_opt("bin").unwrap(), Some(Encoding::Binary));
        assert_eq!(Encoding::parse_opt("binary").unwrap(), Some(Encoding::Binary));
        assert!(Encoding::parse_opt("msgpack").is_err());
        assert_eq!(Encoding::Binary.as_str(), "bin");
    }

    #[test]
    fn timeouts_are_detected_through_anyhow_chains() {
        let raw = std::io::Error::new(std::io::ErrorKind::WouldBlock, "timed out");
        let wrapped = anyhow::Error::from(raw).context("reading frame");
        assert!(is_timeout(&wrapped));
        let other = anyhow::anyhow!("plain failure");
        assert!(!is_timeout(&other));
    }

    #[test]
    fn exec_request_roundtrips_batches_in_order() {
        let a = Value::scalar(1.0);
        let b = Value::i32(vec![2], vec![7, 8]);
        let batches: Vec<Vec<&Value>> = vec![vec![&a, &b], vec![&b]];
        let j = Json::parse(&exec_json("cif10_eval_quant", &batches).to_string()).unwrap();
        let Request::Exec { artifact, batches: back } = request_from_json(&j).unwrap() else {
            panic!("wrong op");
        };
        assert_eq!(artifact, "cif10_eval_quant");
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].len(), 2);
        assert_eq!(back[0][1].as_i32().unwrap(), &[7, 8]);
        assert_eq!(back[1].len(), 1);
    }

    #[test]
    fn responses_distinguish_app_errors_from_payloads() {
        let outs = vec![vec![Value::scalar(2.5)], vec![Value::scalar(-0.0)]];
        let j = Json::parse(&ok_json(&outs).to_string()).unwrap();
        let back = response_outputs(&j).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1][0].scalar_f32().unwrap().to_bits(), (-0.0f32).to_bits());

        assert!(response_outputs(&ok_empty_json(1)).unwrap().is_empty());
        let err = response_outputs(&err_json("boom")).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
        assert!(request_from_json(&Json::obj(vec![("op", "nope".into())])).is_err());
    }
}
