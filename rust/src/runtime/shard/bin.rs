//! Compact binary body encoding for the shard protocol (lib0-style).
//!
//! The JSON codec in [`super::proto`] is the debug/interop mode; this
//! module is the wire-efficient default, negotiated at handshake (see
//! `proto::Encoding`).  Bodies are tagged structs over four primitives in
//! the style of y-crdt's `lib0`: LEB128 varints for lengths and unsigned
//! ints, zigzag varints for signed ints, length-prefixed UTF-8 for
//! strings, and raw little-endian `f32::to_bits()` words for float
//! payloads — bit patterns (NaN payloads, -0.0) survive by construction.
//!
//! Two size levers beyond raw words, both lossless and deterministic:
//!
//! - **Intra-frame value dedup.**  `eval_config` repeats the same borrowed
//!   parameter `Value`s in every input set of a batch; the encoder indexes
//!   values by pointer identity and emits a `VAL_REF` backreference for
//!   repeats, so N input sets carry the parameter tensors once.  Sound
//!   because every encoded value is borrowed for the whole encode call —
//!   addresses cannot be reused mid-frame.
//! - **Exponent-plane Huffman.**  For f32 payloads the bits are rotated
//!   left by one (`bits.rotate_left(1)`) so the top byte becomes the full
//!   8-bit exponent (the sign bit lands in the raw low plane) — nearly
//!   constant across a tensor drawn from one distribution (entropy ≈ 2–3
//!   bits) — and that byte plane is canonical-Huffman coded while the
//!   noisy mantissa+sign low 24 bits travel raw.  The encoder
//!   decodes its own stream before committing and falls back to raw words
//!   on any mismatch, so a codec bug can cost bytes but never correctness.
//!
//! Nothing here is a general-purpose serializer: the format covers exactly
//! the shard protocol's request/response frames and is versioned by the
//! handshake (a worker that does not ack `"enc":"bin"` keeps JSON).

use std::borrow::Borrow;
use std::collections::HashMap;

use crate::runtime::tensor::Tensor;
use crate::runtime::value::Value;

use super::proto::{Request, MAX_FRAME};

// Frame tags (request high bit clear, response high bit set).
const REQ_PING: u8 = 0x01;
const REQ_EXIT: u8 = 0x02;
const REQ_EXEC: u8 = 0x03;
const RESP_OK_EMPTY: u8 = 0x81;
const RESP_OK_OUTPUTS: u8 = 0x82;
const RESP_ERR: u8 = 0x83;

// Value tags.
const VAL_FULL: u8 = 0x11;
const VAL_REF: u8 = 0x10;

// Dtypes.
const DT_F32: u8 = 0x00;
const DT_S32: u8 = 0x01;

// f32 payload modes.
const F32_RAW: u8 = 0x00;
const F32_HUFF: u8 = 0x01;
const F32_CONST: u8 = 0x02;

/// Huffman only pays once the 256-byte length table amortizes.
const HUFF_MIN_ELEMS: usize = 64;
/// Canonical codes longer than this fall back to raw (fits in u32).
const MAX_CODE_LEN: u32 = 32;

// ---- primitives -----------------------------------------------------------

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

fn varint_len(v: u64) -> usize {
    (64 - v.leading_zeros() as usize).div_ceil(7).max(1)
}

fn zigzag(v: i32) -> u32 {
    (v.wrapping_shl(1) ^ (v >> 31)) as u32
}

fn unzigzag(z: u32) -> i32 {
    ((z >> 1) as i32) ^ -((z & 1) as i32)
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked cursor over a frame body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        anyhow::ensure!(self.pos < self.buf.len(), "truncated binary frame");
        self.pos += 1;
        Ok(self.buf[self.pos - 1])
    }

    fn bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.remaining() >= n, "truncated binary frame ({n} bytes short)");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn varint(&mut self) -> anyhow::Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            anyhow::ensure!(shift < 64, "varint overflows u64");
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn usize(&mut self) -> anyhow::Result<usize> {
        usize::try_from(self.varint()?).map_err(|_| anyhow::anyhow!("length overflows usize"))
    }

    fn str(&mut self) -> anyhow::Result<&'a str> {
        let n = self.usize()?;
        Ok(std::str::from_utf8(self.bytes(n)?)?)
    }
}

// ---- shapes ---------------------------------------------------------------

fn put_shape(out: &mut Vec<u8>, shape: &[usize]) {
    put_varint(out, shape.len() as u64);
    for &d in shape {
        put_varint(out, d as u64);
    }
}

/// Read a shape and its (overflow-checked, frame-capped) element count.
fn get_shape(r: &mut Reader) -> anyhow::Result<(Vec<usize>, usize)> {
    let ndim = r.usize()?;
    anyhow::ensure!(ndim <= 64, "shape rank {ndim} is implausible");
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.usize()?);
    }
    let elems = shape
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .ok_or_else(|| anyhow::anyhow!("shape {shape:?} element count overflows"))?;
    anyhow::ensure!(elems <= MAX_FRAME / 4, "shape {shape:?} exceeds the frame cap");
    Ok((shape, elems))
}

// ---- f32 payload: raw / const / exponent-plane huffman --------------------

fn rot_hi(bits: u32) -> u8 {
    (bits.rotate_left(1) >> 24) as u8
}

fn put_f32_payload(out: &mut Vec<u8>, data: &[f32]) {
    let n = data.len();
    if n >= 2 && data.iter().all(|x| x.to_bits() == data[0].to_bits()) {
        out.push(F32_CONST);
        out.extend_from_slice(&data[0].to_bits().to_le_bytes());
        return;
    }
    if n >= HUFF_MIN_ELEMS {
        if let Some(huff) = huff_encode(data) {
            out.push(F32_HUFF);
            out.extend_from_slice(&huff);
            return;
        }
    }
    out.push(F32_RAW);
    for x in data {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

fn get_f32_payload(r: &mut Reader, n: usize) -> anyhow::Result<Vec<f32>> {
    match r.u8()? {
        F32_RAW => {
            let raw = r.bytes(4 * n)?;
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
                .collect())
        }
        F32_CONST => {
            let w = r.bytes(4)?;
            Ok(vec![f32::from_bits(u32::from_le_bytes([w[0], w[1], w[2], w[3]])); n])
        }
        F32_HUFF => huff_decode(r, n),
        m => anyhow::bail!("unknown f32 payload mode {m:#04x}"),
    }
}

/// Deterministic Huffman code lengths over the hi-byte alphabet, or `None`
/// when a code would exceed [`MAX_CODE_LEN`].  Tie-breaking is by node
/// creation order (leaves in symbol order first), so identical inputs
/// produce identical tables on every host.
fn huff_code_lengths(freq: &[u64; 256]) -> Option<[u8; 256]> {
    struct Node {
        parent: usize,
    }
    let mut lens = [0u8; 256];
    let syms: Vec<usize> = (0..256).filter(|&s| freq[s] > 0).collect();
    match syms.len() {
        0 => return None,
        1 => {
            lens[syms[0]] = 1;
            return Some(lens);
        }
        _ => {}
    }
    let mut nodes: Vec<Node> = syms.iter().map(|_| Node { parent: usize::MAX }).collect();
    // (freq, node id) of every live root; merging the two smallest by
    // (freq, id) is the standard construction with deterministic ties.
    let mut roots: Vec<(u64, usize)> = syms.iter().enumerate().map(|(i, &s)| (freq[s], i)).collect();
    while roots.len() > 1 {
        roots.sort_unstable();
        let (f1, a) = roots.remove(0);
        let (f2, b) = roots.remove(0);
        let merged = nodes.len();
        nodes[a].parent = merged;
        nodes[b].parent = merged;
        nodes.push(Node { parent: usize::MAX });
        roots.push((f1 + f2, merged));
    }
    for (i, &s) in syms.iter().enumerate() {
        let mut depth = 0u32;
        let mut p = nodes[i].parent;
        while p != usize::MAX {
            depth += 1;
            p = nodes[p].parent;
        }
        if depth > MAX_CODE_LEN {
            return None;
        }
        lens[s] = depth as u8;
    }
    Some(lens)
}

/// Canonical codes from a length table: symbols sorted by (len, symbol),
/// codes assigned in that order — fully determined by the lengths, so only
/// the 256-byte length table travels.
fn canonical_codes(lens: &[u8; 256]) -> [(u32, u8); 256] {
    let mut order: Vec<usize> = (0..256).filter(|&s| lens[s] > 0).collect();
    order.sort_by_key(|&s| (lens[s], s));
    let mut codes = [(0u32, 0u8); 256];
    let mut code = 0u64;
    let mut prev = 0u8;
    for &s in &order {
        code <<= lens[s] - prev;
        prev = lens[s];
        codes[s] = (code as u32, prev);
        code += 1;
    }
    codes
}

/// Canonical decoder tables rebuilt from the wire's length table; all
/// inputs are untrusted, so Kraft validity is checked up front.
struct HuffDecoder {
    first: [u64; 33],
    count: [u64; 33],
    offset: [u32; 33],
    syms: Vec<u8>,
}

impl HuffDecoder {
    fn build(lens: &[u8; 256]) -> anyhow::Result<HuffDecoder> {
        let mut count = [0u64; 33];
        let mut order: Vec<usize> = Vec::new();
        for (s, &l) in lens.iter().enumerate() {
            anyhow::ensure!(l as u32 <= MAX_CODE_LEN, "huffman code length {l} too long");
            if l > 0 {
                count[l as usize] += 1;
                order.push(s);
            }
        }
        anyhow::ensure!(!order.is_empty(), "empty huffman table");
        order.sort_by_key(|&s| (lens[s], s));
        let syms = order.iter().map(|&s| s as u8).collect();
        let mut first = [0u64; 33];
        let mut offset = [0u32; 33];
        let mut code = 0u64;
        let mut off = 0u32;
        for l in 1..=32usize {
            first[l] = code;
            offset[l] = off;
            off += count[l] as u32;
            anyhow::ensure!(code + count[l] <= 1u64 << l, "huffman table violates Kraft");
            code = (code + count[l]) << 1;
        }
        Ok(HuffDecoder { first, count, offset, syms })
    }

    fn decode(&self, bits: &mut BitReader) -> anyhow::Result<u8> {
        let mut code = 0u64;
        for l in 1..=32usize {
            code = (code << 1) | bits.bit()? as u64;
            if code >= self.first[l] && code - self.first[l] < self.count[l] {
                let idx = self.offset[l] as u64 + (code - self.first[l]);
                return Ok(self.syms[idx as usize]);
            }
        }
        anyhow::bail!("corrupt huffman stream")
    }
}

/// MSB-first bit cursor over a packed byte slice.
struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    nbits: usize,
}

impl<'a> BitReader<'a> {
    fn new(buf: &'a [u8], nbits: usize) -> BitReader<'a> {
        BitReader { buf, pos: 0, nbits }
    }

    fn bit(&mut self) -> anyhow::Result<u8> {
        anyhow::ensure!(self.pos < self.nbits, "huffman stream exhausted");
        let b = (self.buf[self.pos >> 3] >> (7 - (self.pos & 7))) & 1;
        self.pos += 1;
        Ok(b)
    }
}

/// Huffman-encode the hi plane; layout `[256-byte len table][varint
/// nbits][packed hi bits][3n raw lo24 bytes]`.  Returns `None` when raw is
/// no bigger, a code overflows, or (defensively) self-verification fails.
fn huff_encode(data: &[f32]) -> Option<Vec<u8>> {
    let n = data.len();
    let mut freq = [0u64; 256];
    for x in data {
        freq[rot_hi(x.to_bits()) as usize] += 1;
    }
    let lens = huff_code_lengths(&freq)?;
    let total_bits: u64 = (0..256).map(|s| freq[s] * lens[s] as u64).sum();
    let est = 256 + varint_len(total_bits) + (total_bits as usize).div_ceil(8) + 3 * n;
    if est >= 4 * n {
        return None;
    }
    let codes = canonical_codes(&lens);
    let mut out = Vec::with_capacity(est + 8);
    out.extend_from_slice(&lens);
    put_varint(&mut out, total_bits);
    let packed_at = out.len();
    let mut acc = 0u64;
    let mut nacc = 0u32;
    for x in data {
        let (code, len) = codes[rot_hi(x.to_bits()) as usize];
        acc = (acc << len) | code as u64;
        nacc += len as u32;
        while nacc >= 8 {
            nacc -= 8;
            out.push((acc >> nacc) as u8);
        }
    }
    if nacc > 0 {
        out.push((acc << (8 - nacc)) as u8);
    }
    // Self-verify the compressed plane before trusting it on the wire: a
    // table/packing bug becomes a size regression, never wrong bytes.
    let dec = HuffDecoder::build(&lens).ok()?;
    let mut bits = BitReader::new(&out[packed_at..], total_bits as usize);
    for x in data {
        if dec.decode(&mut bits).ok()? != rot_hi(x.to_bits()) {
            return None;
        }
    }
    if bits.pos != total_bits as usize {
        return None;
    }
    for x in data {
        let r = x.to_bits().rotate_left(1);
        out.extend_from_slice(&[r as u8, (r >> 8) as u8, (r >> 16) as u8]);
    }
    Some(out)
}

fn huff_decode(r: &mut Reader, n: usize) -> anyhow::Result<Vec<f32>> {
    let table = r.bytes(256)?;
    let mut lens = [0u8; 256];
    lens.copy_from_slice(table);
    let total_bits = r.usize()?;
    anyhow::ensure!(total_bits >= n, "huffman stream shorter than element count");
    let packed = r.bytes(total_bits.div_ceil(8))?;
    let dec = HuffDecoder::build(&lens)?;
    let mut bits = BitReader::new(packed, total_bits);
    let mut hi = Vec::with_capacity(n);
    for _ in 0..n {
        hi.push(dec.decode(&mut bits)?);
    }
    anyhow::ensure!(bits.pos == total_bits, "huffman stream has trailing bits");
    let lo = r.bytes(3 * n)?;
    Ok((0..n)
        .map(|i| {
            let rot = lo[3 * i] as u32
                | (lo[3 * i + 1] as u32) << 8
                | (lo[3 * i + 2] as u32) << 16
                | (hi[i] as u32) << 24;
            f32::from_bits(rot.rotate_right(1))
        })
        .collect())
}

// ---- values ---------------------------------------------------------------

/// Encoder-side dedup state: values already emitted in this frame, keyed
/// by address, mapped to their frame-order index.
#[derive(Default)]
struct ValueEncoder {
    seen: HashMap<usize, u64>,
    next: u64,
}

impl ValueEncoder {
    fn put_value(&mut self, out: &mut Vec<u8>, v: &Value) {
        let key = v as *const Value as usize;
        if let Some(&idx) = self.seen.get(&key) {
            out.push(VAL_REF);
            put_varint(out, idx);
            return;
        }
        self.seen.insert(key, self.next);
        self.next += 1;
        out.push(VAL_FULL);
        match v {
            Value::F32(t) => {
                out.push(DT_F32);
                put_shape(out, &t.shape);
                put_f32_payload(out, &t.data);
            }
            Value::I32 { shape, data } => {
                out.push(DT_S32);
                put_shape(out, shape);
                for &x in data {
                    put_varint(out, zigzag(x) as u64);
                }
            }
        }
    }
}

/// Decoder-side pool mirroring the encoder's frame-order indices.
fn get_value(r: &mut Reader, pool: &mut Vec<Value>) -> anyhow::Result<Value> {
    match r.u8()? {
        VAL_REF => {
            let idx = r.usize()?;
            let v = pool
                .get(idx)
                .ok_or_else(|| anyhow::anyhow!("value backref {idx} out of range"))?;
            Ok(v.clone())
        }
        VAL_FULL => {
            let v = match r.u8()? {
                DT_F32 => {
                    let (shape, elems) = get_shape(r)?;
                    Value::F32(Tensor::new(shape, get_f32_payload(r, elems)?))
                }
                DT_S32 => {
                    let (shape, elems) = get_shape(r)?;
                    anyhow::ensure!(elems <= r.remaining().max(1), "s32 payload short");
                    let mut data = Vec::with_capacity(elems);
                    for _ in 0..elems {
                        let z = u32_checked(r.varint()?)?;
                        data.push(unzigzag(z));
                    }
                    Value::I32 { shape, data }
                }
                d => anyhow::bail!("unknown dtype tag {d:#04x}"),
            };
            pool.push(v.clone());
            Ok(v)
        }
        t => anyhow::bail!("unknown value tag {t:#04x}"),
    }
}

fn u32_checked(v: u64) -> anyhow::Result<u32> {
    u32::try_from(v).map_err(|_| anyhow::anyhow!("zigzag word {v} overflows u32"))
}

fn put_sets<V: Borrow<Value>>(out: &mut Vec<u8>, sets: &[Vec<V>]) {
    put_varint(out, sets.len() as u64);
    let mut enc = ValueEncoder::default();
    for set in sets {
        put_varint(out, set.len() as u64);
        for v in set {
            enc.put_value(out, v.borrow());
        }
    }
}

fn get_sets(r: &mut Reader) -> anyhow::Result<Vec<Vec<Value>>> {
    let nsets = r.usize()?;
    anyhow::ensure!(nsets <= r.remaining().max(1), "set count exceeds frame");
    let mut pool: Vec<Value> = Vec::new();
    let mut sets = Vec::with_capacity(nsets);
    for _ in 0..nsets {
        let nvals = r.usize()?;
        anyhow::ensure!(nvals <= r.remaining().max(1), "value count exceeds frame");
        let mut set = Vec::with_capacity(nvals);
        for _ in 0..nvals {
            set.push(get_value(r, &mut pool)?);
        }
        sets.push(set);
    }
    Ok(sets)
}

// ---- requests -------------------------------------------------------------

pub fn ping_bytes() -> Vec<u8> {
    vec![REQ_PING]
}

pub fn exit_bytes() -> Vec<u8> {
    vec![REQ_EXIT]
}

/// Binary counterpart of `proto::exec_json` — same borrowed-input shape,
/// with repeated values (the parameter set) deduplicated per frame.
pub fn exec_bytes<V: Borrow<Value>>(artifact: &str, batches: &[Vec<V>]) -> Vec<u8> {
    let mut out = vec![REQ_EXEC];
    put_str(&mut out, artifact);
    put_sets(&mut out, batches);
    out
}

pub fn request_from_bytes(buf: &[u8]) -> anyhow::Result<Request> {
    let mut r = Reader::new(buf);
    let req = match r.u8()? {
        REQ_PING => Request::Ping,
        REQ_EXIT => Request::Exit,
        REQ_EXEC => {
            let artifact = r.str()?.to_string();
            Request::Exec { artifact, batches: get_sets(&mut r)? }
        }
        t => anyhow::bail!("unknown request tag {t:#04x}"),
    };
    anyhow::ensure!(r.done(), "trailing bytes after request");
    Ok(req)
}

// ---- responses ------------------------------------------------------------

pub fn ok_bytes(outputs: &[Vec<Value>]) -> Vec<u8> {
    let mut out = vec![RESP_OK_OUTPUTS];
    put_sets(&mut out, outputs);
    out
}

pub fn ok_empty_bytes(pid: u32) -> Vec<u8> {
    let mut out = vec![RESP_OK_EMPTY];
    put_varint(&mut out, pid as u64);
    out
}

pub fn err_bytes(msg: &str) -> Vec<u8> {
    let mut out = vec![RESP_ERR];
    put_str(&mut out, msg);
    out
}

/// Binary counterpart of `proto::response_outputs`: ping acks decode to an
/// empty result, `RESP_ERR` surfaces the worker's message as an app error
/// (same text shape as the JSON path, so callers treat both alike).
pub fn response_from_bytes(buf: &[u8]) -> anyhow::Result<Vec<Vec<Value>>> {
    let mut r = Reader::new(buf);
    match r.u8()? {
        RESP_OK_EMPTY => {
            let _pid = r.varint()?;
            anyhow::ensure!(r.done(), "trailing bytes after response");
            Ok(Vec::new())
        }
        RESP_OK_OUTPUTS => {
            let outs = get_sets(&mut r)?;
            anyhow::ensure!(r.done(), "trailing bytes after response");
            Ok(outs)
        }
        RESP_ERR => {
            let msg = r.str()?;
            anyhow::bail!("shard worker reported: {msg}");
        }
        t => anyhow::bail!("unknown response tag {t:#04x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_value(v: &Value) -> Value {
        let bytes = ok_bytes(std::slice::from_ref(&vec![v.clone()]));
        let mut outs = response_from_bytes(&bytes).unwrap();
        assert_eq!(outs.len(), 1);
        outs.pop().unwrap().pop().unwrap()
    }

    fn bits_of(v: &Value) -> Vec<u32> {
        v.as_f32().unwrap().data.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn varints_and_zigzag_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut out = Vec::new();
            put_varint(&mut out, v);
            assert_eq!(out.len(), varint_len(v));
            assert_eq!(Reader::new(&out).varint().unwrap(), v);
        }
        for v in [0i32, 1, -1, 63, -64, i32::MAX, i32::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn f32_specials_are_bit_exact() {
        let specials = vec![
            0.0f32,
            -0.0,
            1.5,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            f32::from_bits(0x7fc0_1234),
            -3.25e-38,
        ];
        let v = Value::f32(vec![3, 3], specials.clone());
        let back = roundtrip_value(&v);
        assert_eq!(back.shape(), &[3, 3]);
        for (a, b) in specials.iter().zip(&back.as_f32().unwrap().data) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} lost its bit pattern");
        }
    }

    #[test]
    fn zero_element_tensors_roundtrip_binary() {
        for v in [
            Value::f32(vec![0], vec![]),
            Value::f32(vec![0, 5], vec![]),
            Value::i32(vec![0], vec![]),
        ] {
            assert_eq!(roundtrip_value(&v), v);
        }
    }

    #[test]
    fn s32_and_scalars_roundtrip() {
        let iv = Value::i32(vec![4], vec![i32::MIN, -1, 0, i32::MAX]);
        assert_eq!(roundtrip_value(&iv), iv);
        let s = Value::scalar(-0.0);
        assert_eq!(bits_of(&roundtrip_value(&s)), bits_of(&s));
    }

    #[test]
    fn repeated_values_are_deduplicated_and_restored() {
        let shared = Value::f32(vec![128], (0..128).map(|i| i as f32 * 0.25 - 7.0).collect());
        let uniq_a = Value::i32(vec![2], vec![3, 4]);
        let uniq_b = Value::i32(vec![2], vec![5, 6]);
        let sets: Vec<Vec<&Value>> = vec![vec![&shared, &uniq_a], vec![&shared, &uniq_b]];
        let with_dedup = exec_bytes("m", &sets);
        // A copy at a different address must encode in full.
        let shared2 = shared.clone();
        let sets2: Vec<Vec<&Value>> = vec![vec![&shared, &uniq_a], vec![&shared2, &uniq_b]];
        let without = exec_bytes("m", &sets2);
        assert!(
            with_dedup.len() + 64 < without.len(),
            "dedup must shrink the frame ({} vs {})",
            with_dedup.len(),
            without.len()
        );
        for frame in [with_dedup, without] {
            let Request::Exec { artifact, batches } = request_from_bytes(&frame).unwrap() else {
                panic!("wrong request kind");
            };
            assert_eq!(artifact, "m");
            assert_eq!(batches.len(), 2);
            assert_eq!(batches[0][0], shared);
            assert_eq!(batches[1][0], shared);
            assert_eq!(batches[1][1], uniq_b);
        }
    }

    #[test]
    fn constant_tensors_collapse_to_one_word() {
        let v = Value::f32(vec![4096], vec![-0.0; 4096]);
        let bytes = ok_bytes(std::slice::from_ref(&vec![v.clone()]));
        assert!(bytes.len() < 64, "const mode must collapse {} bytes", bytes.len());
        assert_eq!(bits_of(&roundtrip_value(&v)), bits_of(&v));
    }

    #[test]
    fn huffman_payload_shrinks_and_roundtrips() {
        // One distribution, > HUFF_MIN_ELEMS, not constant: the huffman
        // path must engage and stay bit-exact.
        let mut x = 0x2545_f491u32;
        let data: Vec<f32> = (0..10_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                (x as f64 / u32::MAX as f64) as f32 - 0.5
            })
            .collect();
        let v = Value::f32(vec![10_000], data);
        let bytes = ok_bytes(std::slice::from_ref(&vec![v.clone()]));
        assert!(
            bytes.len() < 4 * 10_000,
            "huffman must beat raw words ({} bytes)",
            bytes.len()
        );
        assert_eq!(bits_of(&roundtrip_value(&v)), bits_of(&v));
    }

    #[test]
    fn ping_exit_and_errors_roundtrip() {
        assert!(matches!(request_from_bytes(&ping_bytes()).unwrap(), Request::Ping));
        assert!(matches!(request_from_bytes(&exit_bytes()).unwrap(), Request::Exit));
        assert!(response_from_bytes(&ok_empty_bytes(42)).unwrap().is_empty());
        let err = response_from_bytes(&err_bytes("boom")).unwrap_err();
        assert!(format!("{err:#}").contains("shard worker reported: boom"));
    }

    #[test]
    fn malformed_frames_error_cleanly() {
        assert!(request_from_bytes(&[]).is_err(), "empty frame");
        assert!(request_from_bytes(&[0x7f]).is_err(), "unknown tag");
        let mut trailing = ping_bytes();
        trailing.push(0);
        assert!(request_from_bytes(&trailing).is_err(), "trailing bytes");
        let mut exec = exec_bytes("m", &[vec![&Value::scalar(1.0)]]);
        exec.truncate(exec.len() - 2);
        assert!(request_from_bytes(&exec).is_err(), "truncated exec");
        // Backref pointing forward must not panic.
        let mut bad = vec![RESP_OK_OUTPUTS];
        put_varint(&mut bad, 1); // one set
        put_varint(&mut bad, 1); // one value
        bad.push(VAL_REF);
        put_varint(&mut bad, 7);
        assert!(response_from_bytes(&bad).is_err(), "dangling backref");
    }
}
