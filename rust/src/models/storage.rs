//! §3.4 storage overhead: the searched bit-width of every channel is stored
//! in 6 bits (values 0..=32 fit in 6 bits with headroom).  This module packs
//! and unpacks channel bit-configs and audits the paper's < 0.3 % claim.

/// Pack 6-bit values into a byte stream (LSB-first bit packing).
pub fn pack6(values: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity((values.len() * 6 + 7) / 8);
    let mut acc: u32 = 0;
    let mut nbits = 0u32;
    for &v in values {
        assert!(v < 64, "6-bit overflow: {v}");
        acc |= (v as u32) << nbits;
        nbits += 6;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xFF) as u8);
    }
    out
}

/// Unpack `n` 6-bit values.
pub fn unpack6(bytes: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    let mut acc: u32 = 0;
    let mut nbits = 0u32;
    let mut it = bytes.iter();
    for _ in 0..n {
        while nbits < 6 {
            acc |= (*it.next().expect("truncated pack6 stream") as u32) << nbits;
            nbits += 8;
        }
        out.push((acc & 0x3F) as u8);
        acc >>= 6;
        nbits -= 6;
    }
    out
}

/// Storage audit for a searched model (paper §3.4):
///   * `weight_bytes`  — quantized weight payload: Σ ceil(QBN_c · n_c / 8)
///   * `config_bytes`  — 6-bit records for all weight + activation channels
///   * `overhead`      — config_bytes / weight_bytes
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StorageAudit {
    pub weight_bytes: u64,
    pub config_bytes: u64,
    pub overhead: f64,
}

/// `w_channel_elems[i]` = number of weight scalars in weight channel i;
/// `wbits[i]` its searched QBN; `n_act_channels` activation channel count.
pub fn storage_audit(w_channel_elems: &[u64], wbits: &[u8], n_act_channels: usize) -> StorageAudit {
    assert_eq!(w_channel_elems.len(), wbits.len());
    let weight_bits: u64 = w_channel_elems
        .iter()
        .zip(wbits)
        .map(|(&n, &b)| n * b as u64)
        .sum();
    let weight_bytes = (weight_bits + 7) / 8;
    let config_bytes = ((wbits.len() + n_act_channels) as u64 * 6 + 7) / 8;
    StorageAudit {
        weight_bytes,
        config_bytes,
        overhead: config_bytes as f64 / weight_bytes.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, shrink_vec};

    #[test]
    fn pack_unpack_known() {
        let vals = vec![0u8, 32, 5, 63, 1];
        let packed = pack6(&vals);
        assert_eq!(packed.len(), (vals.len() * 6 + 7) / 8);
        assert_eq!(unpack6(&packed, vals.len()), vals);
    }

    #[test]
    fn prop_pack6_roundtrip() {
        forall(
            77,
            |r| {
                let n = r.below(200);
                (0..n).map(|_| r.below(64) as u8).collect::<Vec<u8>>()
            },
            |v| {
                let rt = unpack6(&pack6(v), v.len());
                if &rt == v {
                    Ok(())
                } else {
                    Err(format!("roundtrip mismatch: {rt:?}"))
                }
            },
            |v| shrink_vec(v),
        );
    }

    #[test]
    fn audit_matches_paper_scale() {
        // Paper: Res18-C stores 8.3 MB of quantized weights; 5.8K + 6.9K
        // channel records cost 9.31 KB → < 0.3 % overhead.  Reconstruct the
        // arithmetic: 12.7K channels * 6 bits = 9.525 KB ≈ 9.31 KiB.
        let n_w = 5_800usize;
        let n_a = 6_900usize;
        // Give each weight channel enough elements for ~8.3 MB at ~4.3 bits.
        let elems_per = (8.3e6 * 8.0 / 4.33 / n_w as f64) as u64;
        let elems = vec![elems_per; n_w];
        let bits = vec![4u8; n_w]; // ~4.3-bit average in the paper
        let audit = storage_audit(&elems, &bits, n_a);
        assert!(audit.overhead < 0.003, "overhead {}", audit.overhead);
        let kb = audit.config_bytes as f64 / 1024.0;
        assert!((8.0..11.0).contains(&kb), "config {kb} KB");
    }

    #[test]
    fn pruned_channels_cost_nothing() {
        let audit = storage_audit(&[100, 100], &[0, 8], 0);
        assert_eq!(audit.weight_bytes, 100);
    }

    #[test]
    #[should_panic(expected = "6-bit overflow")]
    fn pack_rejects_overflow() {
        pack6(&[64]);
    }
}
