//! Model-zoo services: parameter stores (rust-owned buffers), the artifact
//! eval/train runner, and §3.4 bit-config storage.

pub mod eval;
pub mod params;
pub mod storage;

pub use eval::{bits_to_f32, EvalResult, ModelRunner};
pub use params::ParamStore;
