//! Parameter store: rust owns every model/agent buffer.
//!
//! Initialization follows the manifest's per-param `init` kind (`he` — He
//! normal scaled by fan-in, `ones`, `zeros`), so no binary interchange with
//! python is needed.  Trained parameters persist in a simple length-checked
//! binary format (`.apb` — AutoQ Param Blob).

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::{ParamSpec, Tensor};
use crate::util::rng::Rng;

/// Named, ordered set of tensors matching a manifest param list.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
}

const MAGIC: &[u8; 8] = b"AUTOQPB1";

impl ParamStore {
    /// Initialize from manifest specs with a seeded RNG.
    pub fn init(specs: &[ParamSpec], rng: &mut Rng) -> ParamStore {
        let mut names = Vec::with_capacity(specs.len());
        let mut tensors = Vec::with_capacity(specs.len());
        for spec in specs {
            let mut t = Tensor::zeros(spec.shape.clone());
            match spec.init.as_str() {
                "he" => {
                    let sigma = (2.0 / spec.fan_in().max(1) as f64).sqrt() as f32;
                    rng.fill_normal_f32(&mut t.data, sigma);
                }
                "ones" => t.data.fill(1.0),
                "zeros" => {}
                other => panic!("unknown init kind {other:?}"),
            }
            names.push(spec.name.clone());
            tensors.push(t);
        }
        ParamStore { names, tensors }
    }

    /// All-zero momenta/moment buffers shaped like `self`.
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            names: self.names.clone(),
            tensors: self.tensors.iter().map(|t| Tensor::zeros(t.shape.clone())).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.elems()).sum()
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.names.iter().position(|n| n == name).map(|i| &self.tensors[i])
    }

    /// Save to the `.apb` format: magic, count, then per-tensor
    /// (name_len, name, ndim, dims..., f32 data), all little-endian.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for (name, t) in self.names.iter().zip(&self.tensors) {
            f.write_all(&(name.len() as u64).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.shape.len() as u64).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &x in &t.data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<ParamStore> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "{}: not an .apb file", path.display());
        let count = read_u64(&mut f)? as usize;
        anyhow::ensure!(count < 1_000_000, "implausible tensor count {count}");
        let mut names = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u64(&mut f)? as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let ndim = read_u64(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut f)? as usize);
            }
            let n: usize = shape.iter().product::<usize>().max(1);
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            names.push(String::from_utf8(name)?);
            tensors.push(Tensor::new(shape, data));
        }
        Ok(ParamStore { names, tensors })
    }

    /// Verify layout against manifest specs (names + shapes, in order).
    pub fn check_layout(&self, specs: &[ParamSpec]) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.len() == specs.len(),
            "param count {} vs manifest {}",
            self.len(),
            specs.len()
        );
        for (i, spec) in specs.iter().enumerate() {
            anyhow::ensure!(
                self.names[i] == spec.name && self.tensors[i].shape == spec.shape,
                "param {i}: {}{:?} vs manifest {}{:?}",
                self.names[i],
                self.tensors[i].shape,
                spec.name,
                spec.shape
            );
        }
        Ok(())
    }

    /// Per-output-channel weight variances for a conv/fc weight tensor
    /// (the `wvar_i` state feature of Eq. 1).  Conv shape (k,k,cin,cout) →
    /// channel = last dim; fc (cin,cout) → channel = last dim.
    pub fn channel_variances(&self, name: &str) -> Option<Vec<f64>> {
        let t = self.get(name)?;
        let cout = *t.shape.last()?;
        let rows = t.elems() / cout;
        let mut sums = vec![0.0f64; cout];
        let mut sqs = vec![0.0f64; cout];
        // Data layout is row-major with channel last: stride over it.
        for (i, &x) in t.data.iter().enumerate() {
            let c = i % cout;
            sums[c] += x as f64;
            sqs[c] += (x as f64) * (x as f64);
        }
        Some(
            (0..cout)
                .map(|c| {
                    let m = sums[c] / rows as f64;
                    (sqs[c] / rows as f64 - m * m).max(0.0)
                })
                .collect(),
        )
    }
}

fn read_u64(f: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "l1.w".into(), shape: vec![3, 3, 2, 4], init: "he".into() },
            ParamSpec { name: "l1.g".into(), shape: vec![4], init: "ones".into() },
            ParamSpec { name: "l1.b".into(), shape: vec![4], init: "zeros".into() },
        ]
    }

    #[test]
    fn init_kinds() {
        let mut rng = Rng::new(1);
        let ps = ParamStore::init(&specs(), &mut rng);
        assert_eq!(ps.len(), 3);
        let w = ps.get("l1.w").unwrap();
        assert!(w.data.iter().any(|&x| x != 0.0));
        // He sigma = sqrt(2/18) ≈ 0.33 — check empirical std is in range.
        let std = crate::util::stats::variance_f32(&w.data).sqrt();
        assert!((0.15..0.6).contains(&std), "std {std}");
        assert!(ps.get("l1.g").unwrap().data.iter().all(|&x| x == 1.0));
        assert!(ps.get("l1.b").unwrap().data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(2);
        let ps = ParamStore::init(&specs(), &mut rng);
        let dir = std::env::temp_dir().join("autoq_test_params.apb");
        ps.save(&dir).unwrap();
        let ps2 = ParamStore::load(&dir).unwrap();
        assert_eq!(ps.names, ps2.names);
        for (a, b) in ps.tensors.iter().zip(&ps2.tensors) {
            assert_eq!(a, b);
        }
        ps2.check_layout(&specs()).unwrap();
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn layout_mismatch_detected() {
        let mut rng = Rng::new(3);
        let ps = ParamStore::init(&specs(), &mut rng);
        let mut bad = specs();
        bad[0].shape = vec![3, 3, 2, 8];
        assert!(ps.check_layout(&bad).is_err());
    }

    #[test]
    fn channel_variance_per_output_channel() {
        // Build a tensor where channel c has constant value c → variance 0,
        // then perturb channel 1.
        let cout = 4;
        let rows = 6;
        let mut data = vec![0.0f32; rows * cout];
        for i in 0..rows * cout {
            data[i] = (i % cout) as f32;
        }
        data[1] += 3.0; // channel 1 now has nonzero variance
        let ps = ParamStore {
            names: vec!["w".into()],
            tensors: vec![Tensor::new(vec![rows, cout], data)],
        };
        let v = ps.channel_variances("w").unwrap();
        assert_eq!(v.len(), cout);
        assert!(v[0].abs() < 1e-9);
        assert!(v[1] > 0.1);
        assert!(v[2].abs() < 1e-9);
    }
}
