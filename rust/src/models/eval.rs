//! Model runner: evaluate and train zoo models through their artifacts.
//!
//! The search hot path: `eval_config` scores a candidate per-channel bit
//! assignment on held-out validation batches via `{model}_eval_{mode}`
//! (whose quantize/binarize inner loops are the L1 Pallas kernels on the
//! PJRT backend, and the `runtime::reference` planned execution engine
//! otherwise).  All validation batches are built up front and dispatched
//! through the runtime's batch seam, so the reference backend fans them
//! across its worker pool, each worker replaying the compiled
//! `ExecutionPlan` against its reused `Workspace` — steady-state batches
//! allocate no intermediate buffers (`tests/plan_engine.rs` pins this via
//! `Runtime::scratch_stats`).  Parameter `Value`s are cached on the
//! runner and borrowed per dispatch instead of re-cloning every tensor
//! per call (§Perf).

use std::cell::{Ref, RefCell};
use std::sync::Arc;

use crate::cost::hardware::Mode;
use crate::data::synth::{Batch, Split, SynthDataset};
use crate::models::params::ParamStore;
use crate::runtime::{ModelMeta, Runtime, Tensor, Value};
use crate::serve::cache::{self, CacheHandle};

pub struct ModelRunner {
    pub meta: ModelMeta,
    /// Mutate only through `train_step` (or call `invalidate_param_cache`
    /// afterwards) so cached dispatch values stay in sync.
    pub params: ParamStore,
    pub momenta: ParamStore,
    /// Dispatch-ready copies of `params`, built on first use and dropped
    /// whenever the parameters change.
    param_cache: RefCell<Option<Vec<Value>>>,
    /// Content-addressed eval memoization (`autoq serve` or
    /// `Coordinator::set_eval_cache`); `None` = every eval computes.
    eval_cache: Option<Arc<CacheHandle>>,
    /// Cached `cache::param_fingerprint` of `params`, invalidated together
    /// with `param_cache` so cache keys always reflect the live weights.
    param_fp: RefCell<Option<u64>>,
    /// Fingerprint of the installed static activation-scale calibration
    /// table (0 = dynamic per-row scales).  Part of the eval cache key:
    /// static and dynamic evals of the same config may differ within
    /// tolerance, so they must never alias.
    calib_fp: u64,
}

/// Bit config in evaluation form (f32 vectors, network channel order).
pub fn bits_to_f32(bits: &[u8]) -> Vec<f32> {
    bits.iter().map(|&b| b as f32).collect()
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub accuracy: f64,
    pub loss: f64,
    pub images: usize,
}

impl ModelRunner {
    pub fn new(meta: ModelMeta, params: ParamStore) -> anyhow::Result<ModelRunner> {
        params.check_layout(&meta.params)?;
        let momenta = params.zeros_like();
        Ok(ModelRunner {
            meta,
            params,
            momenta,
            param_cache: RefCell::new(None),
            eval_cache: None,
            param_fp: RefCell::new(None),
            calib_fp: 0,
        })
    }

    pub fn init(meta: ModelMeta, rng: &mut crate::util::rng::Rng) -> ModelRunner {
        let params = ParamStore::init(&meta.params, rng);
        let momenta = params.zeros_like();
        ModelRunner {
            meta,
            params,
            momenta,
            param_cache: RefCell::new(None),
            eval_cache: None,
            param_fp: RefCell::new(None),
            calib_fp: 0,
        }
    }

    /// Attach (or detach) the content-addressed eval cache.  The handle is
    /// shared: hits/misses this runner produces show up on its counters.
    pub fn set_eval_cache(&mut self, cache: Option<Arc<CacheHandle>>) {
        self.eval_cache = cache;
    }

    pub fn eval_cache(&self) -> Option<&Arc<CacheHandle>> {
        self.eval_cache.as_ref()
    }

    /// Record the calibration-table fingerprint this runner evaluates
    /// under (0 = dynamic activation scales).  Must change whenever the
    /// installed static scale table does.
    pub fn set_calib_fingerprint(&mut self, fp: u64) {
        self.calib_fp = fp;
    }

    pub fn calib_fingerprint(&self) -> u64 {
        self.calib_fp
    }

    /// Fingerprint of the current parameter tensors, cached until the next
    /// `train_step`/`invalidate_param_cache` (hashing every weight per eval
    /// would erase the cache's win on the search hot path).
    pub fn param_fingerprint(&self) -> u64 {
        if let Some(fp) = *self.param_fp.borrow() {
            return fp;
        }
        let fp = cache::param_fingerprint(&self.params.names, &self.params.tensors);
        *self.param_fp.borrow_mut() = Some(fp);
        fp
    }

    /// Dispatch-ready parameter values, cloned from `params` once and
    /// reused by every eval until the next `train_step` — the per-episode
    /// `Tensor::clone` of the whole parameter set used to dominate
    /// `eval_config` setup.
    pub fn param_values(&self) -> Ref<'_, Vec<Value>> {
        // A live `Ref` from an earlier call implies the cache is filled, so
        // the mutable borrow below only ever happens unobserved.
        if self.param_cache.borrow().is_none() {
            *self.param_cache.borrow_mut() =
                Some(self.params.tensors.iter().map(|t| Value::F32(t.clone())).collect());
        }
        Ref::map(self.param_cache.borrow(), |c| c.as_ref().expect("filled above"))
    }

    /// Drop the cached dispatch values (and the cache-key fingerprint)
    /// after mutating `params` directly.
    pub fn invalidate_param_cache(&mut self) {
        *self.param_cache.get_mut() = None;
        *self.param_fp.get_mut() = None;
    }

    fn artifact(&self, kind: &str, mode: Mode) -> String {
        format!("{}_{}_{}", self.meta.name, kind, mode.as_str())
    }

    fn batch_values(&self, batch: &Batch, n_expected: usize) -> anyhow::Result<(Value, Value)> {
        anyhow::ensure!(batch.n == n_expected, "batch {} vs expected {n_expected}", batch.n);
        let hw = self.meta.image_hw;
        let img = Value::F32(Tensor::new(vec![batch.n, hw, hw, 3], batch.images.clone()));
        let lbl = Value::i32(vec![batch.n], batch.labels.clone());
        Ok((img, lbl))
    }

    /// Evaluate a bit config on `n_batches` × eval_batch validation images.
    pub fn eval_config(
        &self,
        rt: &mut Runtime,
        mode: Mode,
        wbits: &[u8],
        abits: &[u8],
        data: &SynthDataset,
        split: Split,
        n_batches: usize,
    ) -> anyhow::Result<EvalResult> {
        anyhow::ensure!(wbits.len() == self.meta.w_channels, "wbits len");
        anyhow::ensure!(abits.len() == self.meta.a_channels, "abits len");
        let name = self.artifact("eval", mode);
        let eb = self.meta.eval_batch;
        // Content-addressed memoization: both deterministic backends are
        // byte-identical at every thread count, so a key over the eval's
        // actual inputs can return the stored result verbatim.
        let cache_key = self.eval_cache.as_ref().map(|handle| {
            let key = cache::eval_key(
                rt.backend_name(),
                &self.meta.name,
                mode.as_str(),
                wbits,
                abits,
                data.seed(),
                data.noise,
                split.as_str(),
                n_batches,
                eb,
                self.param_fingerprint(),
                self.calib_fp,
            );
            (handle.clone(), key)
        });
        if let Some((handle, key)) = &cache_key {
            if let Some(hit) = handle.get(*key) {
                return Ok(hit);
            }
        }
        // Parameter values come from the runner's cache and bit vectors
        // are built once — every dispatch borrows them (§Perf).
        let param_vals = self.param_values();
        let wb_val = Value::f32(vec![wbits.len()], bits_to_f32(wbits));
        let ab_val = Value::f32(vec![abits.len()], bits_to_f32(abits));
        // Build every validation batch up front so the whole set goes
        // through the batch seam in one dispatch — independent batches fan
        // out across the reference backend's worker pool.
        let mut batch_vals: Vec<(Value, Value)> = Vec::with_capacity(n_batches);
        for bi in 0..n_batches {
            let batch = data.batch(split, (bi * eb) as u64, eb);
            batch_vals.push(self.batch_values(&batch, eb)?);
        }
        let inputs: Vec<Vec<&Value>> = batch_vals
            .iter()
            .map(|(img, lbl)| {
                let mut row: Vec<&Value> = Vec::with_capacity(param_vals.len() + 4);
                row.extend(param_vals.iter());
                row.push(img);
                row.push(lbl);
                row.push(&wb_val);
                row.push(&ab_val);
                row
            })
            .collect();
        let outs = rt.exec_batch(&name, &inputs)?;
        // Reduce in batch-index order — worker scheduling never reorders
        // this sum, keeping results byte-identical at every thread count.
        let mut correct = 0.0f64;
        let mut loss = 0.0f64;
        for out in &outs {
            correct += out[0].scalar_f32()? as f64;
            loss += out[1].scalar_f32()? as f64;
        }
        let images = n_batches * eb;
        let result = EvalResult {
            accuracy: correct / images as f64,
            loss: loss / n_batches as f64,
            images,
        };
        if let Some((handle, key)) = &cache_key {
            handle.insert(*key, result);
        }
        Ok(result)
    }

    /// Full-precision accuracy = all channels at 32 bits (quant path is an
    /// exact passthrough ≥ 24 bits).
    pub fn eval_fp32(
        &self,
        rt: &mut Runtime,
        data: &SynthDataset,
        split: Split,
        n_batches: usize,
    ) -> anyhow::Result<EvalResult> {
        let wbits = vec![32u8; self.meta.w_channels];
        let abits = vec![32u8; self.meta.a_channels];
        self.eval_config(rt, Mode::Quant, &wbits, &abits, data, split, n_batches)
    }

    /// One SGD-momentum training step under a bit config (STE), updating
    /// params/momenta in place.  Returns the batch loss.
    pub fn train_step(
        &mut self,
        rt: &mut Runtime,
        mode: Mode,
        batch: &Batch,
        wbits: &[u8],
        abits: &[u8],
        lr: f32,
    ) -> anyhow::Result<f32> {
        let name = self.artifact("train", mode);
        let (img, lbl) = self.batch_values(batch, self.meta.train_batch)?;
        let np = self.params.len();
        let mut inputs: Vec<Value> = Vec::with_capacity(2 * np + 5);
        for t in &self.params.tensors {
            inputs.push(Value::F32(t.clone()));
        }
        for t in &self.momenta.tensors {
            inputs.push(Value::F32(t.clone()));
        }
        inputs.push(img);
        inputs.push(lbl);
        inputs.push(Value::f32(vec![wbits.len()], bits_to_f32(wbits)));
        inputs.push(Value::f32(vec![abits.len()], bits_to_f32(abits)));
        inputs.push(Value::scalar(lr));
        let mut outs = rt.exec(&name, &inputs)?;
        anyhow::ensure!(outs.len() == 2 * np + 1, "train outputs {}", outs.len());
        let loss = outs[2 * np].scalar_f32()?;
        // Consume outputs back into params/momenta (new params first).
        for (i, v) in outs.drain(..2 * np).enumerate() {
            let t = v.into_f32()?;
            if i < np {
                self.params.tensors[i] = t;
            } else {
                self.momenta.tensors[i - np] = t;
            }
        }
        self.invalidate_param_cache();
        Ok(loss)
    }

    /// Per-output-channel weight variances, network order (Eq.-1 wvar_i).
    pub fn weight_variances(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.meta.w_channels);
        for l in &self.meta.layers {
            let v = self
                .params
                .channel_variances(&format!("{}.w", l.name))
                .unwrap_or_else(|| vec![0.0; l.cout]);
            debug_assert_eq!(v.len(), l.w_len);
            out.extend(v);
        }
        debug_assert_eq!(out.len(), self.meta.w_channels);
        out
    }
}
