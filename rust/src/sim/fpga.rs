//! Cycle-level FPGA accelerator simulators (paper §4.5 substitution —
//! DESIGN.md): the two Zynq-7000 accelerator templates the paper deploys
//! searched models on.
//!
//! * **Temporal** (BISMO-like [31], 150 MHz): bit-serial MAC lanes.  Each
//!   lane retires one 1-bit × 1-bit product per cycle, so a `bw`×`ba` MAC
//!   takes `bw·ba` lane-cycles — any bit-width combination runs without
//!   pipeline bubbles.  This is exactly the bit-level logic-op count of
//!   `cost::logic`, divided by the lane count.
//!
//! * **Spatial** (BitFusion-like [25], 100 MHz): a systolic array of Fusion
//!   Units composed of 2-bit multiplier slices.  Only even effective
//!   bit-widths are composable, and the activation-side precision is
//!   configured per layer, so channel-level mixed precision leaves slices
//!   idle ("pipeline bubbles") — the mechanism behind Fig. 9's
//!   temporal-beats-spatial result for `-C` models.
//!
//! Both templates double-buffer DMA against compute (per-layer time =
//! max(compute, dma)) and share the board's DDR3 bandwidth.

use crate::cost::hardware::Mode;
use crate::cost::logic;
use crate::runtime::LayerMeta;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Temporal,
    Spatial,
}

impl Arch {
    pub fn as_str(&self) -> &'static str {
        match self {
            Arch::Temporal => "temporal",
            Arch::Spatial => "spatial",
        }
    }
}

/// Accelerator instance (constants are Zynq-7000-class; see module doc).
#[derive(Debug, Clone)]
pub struct FpgaSim {
    pub arch: Arch,
    pub mode: Mode,
    /// Clock (Hz).  Paper: spatial @100 MHz, temporal @150 MHz.
    pub freq: f64,
    /// Bit-level ops retired per cycle at full utilization.
    pub lanes: f64,
    /// DDR3 bytes per second.
    pub bandwidth: f64,
    /// Dynamic energy per bit-level op (J).
    pub e_op: f64,
    /// DMA energy per byte (J).
    pub e_byte: f64,
    /// Static power (J/s).
    pub p_static: f64,
}

impl FpgaSim {
    pub fn new(arch: Arch, mode: Mode) -> FpgaSim {
        // Binarized datapaths pack ~4× the lanes into the same fabric and
        // switch less charge per op (Fig.-1 transistor ratio).
        let binar_lane_boost = 4.0;
        let (freq, base_lanes, e_op, p_static) = match arch {
            Arch::Temporal => (150e6, 4096.0, 2.0e-12, 0.5),
            Arch::Spatial => (100e6, 6144.0, 1.6e-12, 0.7),
        };
        let (lanes, e_op) = match mode {
            Mode::Quant => (base_lanes, e_op),
            Mode::Binar => (base_lanes * binar_lane_boost, e_op * 0.25),
        };
        FpgaSim {
            arch,
            mode,
            freq,
            lanes,
            bandwidth: 4.2e9,
            e_op,
            e_byte: 80.0e-12,
            p_static,
        }
    }

    /// Round a bit-width up to the spatial array's composable precision
    /// (even, ≥2; 0 stays 0 = pruned).
    fn spatial_round(b: u8) -> u64 {
        match b {
            0 => 0,
            b => ((b as u64) + 1) / 2 * 2,
        }
    }

    /// Effective bit-level ops the datapath must retire for one layer —
    /// equals the true logic-op count on the temporal design; includes
    /// bubble (idle-slice) overhead on the spatial design.
    fn effective_ops(&self, layer: &LayerMeta, wbits: &[u8], abits: &[u8]) -> u64 {
        match self.arch {
            Arch::Temporal => logic::layer_logic_ops(layer, wbits, abits),
            Arch::Spatial => {
                // Activation precision is configured once per layer: the
                // array runs at the max (rounded-even) input bit-width.
                let ba_eff = abits.iter().map(|&b| Self::spatial_round(b)).max().unwrap_or(0);
                let per_out: u64 = match layer.typ.as_str() {
                    "fc" => layer.cin as u64,
                    "dwconv" => (layer.h_out * layer.w_out * layer.k * layer.k) as u64,
                    _ => (layer.h_out * layer.w_out * layer.k * layer.k * layer.cin) as u64,
                };
                wbits
                    .iter()
                    .map(|&bw| per_out * Self::spatial_round(bw) * ba_eff)
                    .sum()
            }
        }
    }

    /// Bytes DMA'd for one layer: packed quantized weights + input feature
    /// map at its activation precision + output at accumulator width.
    fn layer_bytes(&self, layer: &LayerMeta, wbits: &[u8], abits: &[u8]) -> u64 {
        let w_bits = logic::layer_weight_bits(layer, wbits);
        let a_in_bits: u64 = if layer.typ == "fc" {
            layer.cin as u64 * abits[0] as u64
        } else {
            let hw = (layer.h_in * layer.w_in) as u64;
            abits.iter().map(|&b| hw * b as u64).sum()
        };
        let out_bits = (layer.h_out * layer.w_out * layer.cout) as u64 * 16; // 16-bit psums
        (w_bits + a_in_bits + out_bits + 7) / 8
    }

    /// Simulate one inference of the whole model (batch 1).
    pub fn run(&self, layers: &[LayerMeta], wbits: &[u8], abits: &[u8]) -> SimReport {
        let mut compute_cycles = 0.0f64;
        let mut dma_cycles = 0.0f64;
        let mut total_cycles = 0.0f64;
        let mut bytes = 0u64;
        let mut true_ops = 0u64;
        let mut eff_ops = 0u64;
        for l in layers {
            let wb = &wbits[l.w_off..l.w_off + l.w_len];
            let ab = &abits[l.a_off..l.a_off + l.a_len];
            let eff = self.effective_ops(l, wb, ab);
            let cyc_c = eff as f64 / self.lanes;
            let by = self.layer_bytes(l, wb, ab);
            let cyc_d = by as f64 * self.freq / self.bandwidth;
            compute_cycles += cyc_c;
            dma_cycles += cyc_d;
            // Double-buffered: layer time is the binding resource.
            total_cycles += cyc_c.max(cyc_d);
            bytes += by;
            true_ops += logic::layer_logic_ops(l, wb, ab);
            eff_ops += eff;
        }
        let secs = total_cycles / self.freq;
        let dyn_energy = eff_ops as f64 * self.e_op + bytes as f64 * self.e_byte;
        SimReport {
            cycles: total_cycles,
            compute_cycles,
            dma_cycles,
            secs,
            fps: 1.0 / secs.max(1e-12),
            energy_j: dyn_energy + self.p_static * secs,
            bytes,
            true_ops,
            eff_ops,
            utilization: if eff_ops > 0 { true_ops as f64 / eff_ops as f64 } else { 1.0 },
        }
    }
}

/// Result of simulating one inference.
#[derive(Debug, Clone, Copy)]
pub struct SimReport {
    pub cycles: f64,
    pub compute_cycles: f64,
    pub dma_cycles: f64,
    pub secs: f64,
    pub fps: f64,
    pub energy_j: f64,
    pub bytes: u64,
    /// Bit-level ops actually required by the model.
    pub true_ops: u64,
    /// Ops the datapath retires including bubble overhead.
    pub eff_ops: u64,
    /// true/effective — 1.0 on the temporal design, ≤1.0 on spatial.
    pub utilization: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> LayerMeta {
        LayerMeta {
            name: "l01_conv".into(),
            typ: "conv".into(),
            k: 3,
            stride: 1,
            cin: 16,
            cout: 32,
            h_in: 32,
            w_in: 32,
            h_out: 32,
            w_out: 32,
            macs: (32 * 32 * 3 * 3 * 16 * 32) as u64,
            w_off: 0,
            w_len: 32,
            a_off: 0,
            a_len: 16,
        }
    }

    #[test]
    fn temporal_has_no_bubbles() {
        let sim = FpgaSim::new(Arch::Temporal, Mode::Quant);
        let mut wb = vec![5u8; 32];
        wb[3] = 3; // mixed precision
        let ab = vec![4u8; 16];
        let r = sim.run(&[layer()], &wb, &ab);
        assert!((r.utilization - 1.0).abs() < 1e-12);
        assert_eq!(r.true_ops, r.eff_ops);
    }

    #[test]
    fn spatial_mixed_precision_wastes_slices() {
        let sim = FpgaSim::new(Arch::Spatial, Mode::Quant);
        // Odd bits round up to even → bubbles.
        let wb = vec![5u8; 32];
        let ab = vec![3u8; 16];
        let r = sim.run(&[layer()], &wb, &ab);
        assert!(r.utilization < 1.0, "util {}", r.utilization);
        // 5→6, 3→4: effective = macs·24, true = macs·15.
        assert!((r.utilization - 15.0 / 24.0).abs() < 1e-9);
    }

    #[test]
    fn spatial_even_uniform_has_full_utilization() {
        let sim = FpgaSim::new(Arch::Spatial, Mode::Quant);
        let r = sim.run(&[layer()], &vec![4u8; 32], &vec![4u8; 16]);
        assert!((r.utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn binar_faster_and_cheaper_than_quant() {
        // Fig. 9/10 headline: same bit-widths, binarized models run faster
        // and burn less energy on either architecture.
        for arch in [Arch::Temporal, Arch::Spatial] {
            let q = FpgaSim::new(arch, Mode::Quant).run(&[layer()], &vec![4u8; 32], &vec![4u8; 16]);
            let b = FpgaSim::new(arch, Mode::Binar).run(&[layer()], &vec![4u8; 32], &vec![4u8; 16]);
            assert!(b.fps > q.fps, "{arch:?}: binar fps {} !> quant {}", b.fps, q.fps);
            assert!(b.energy_j < q.energy_j);
        }
    }

    #[test]
    fn fewer_bits_means_more_fps() {
        let sim = FpgaSim::new(Arch::Temporal, Mode::Quant);
        let hi = sim.run(&[layer()], &vec![8u8; 32], &vec![8u8; 16]);
        let lo = sim.run(&[layer()], &vec![4u8; 32], &vec![4u8; 16]);
        assert!(lo.fps > hi.fps);
        assert!(lo.energy_j < hi.energy_j);
    }

    #[test]
    fn temporal_beats_spatial_on_channel_level_models() {
        // The paper's §4.5 claim, for mixed odd per-channel bit-widths.
        let mut wb = vec![0u8; 32];
        for (i, b) in wb.iter_mut().enumerate() {
            *b = 3 + (i % 4) as u8; // 3,4,5,6 mixed
        }
        let ab = vec![3u8; 16];
        let t = FpgaSim::new(Arch::Temporal, Mode::Quant).run(&[layer()], &wb, &ab);
        let s = FpgaSim::new(Arch::Spatial, Mode::Quant).run(&[layer()], &wb, &ab);
        assert!(t.fps > s.fps, "temporal {} !> spatial {}", t.fps, s.fps);
    }

    #[test]
    fn fc_layer_is_memory_bound() {
        // §4.5: fully-connected layers spend their time fetching weights.
        let fc = LayerMeta {
            name: "fc".into(),
            typ: "fc".into(),
            k: 1,
            stride: 1,
            cin: 4096,
            cout: 1000,
            h_in: 1,
            w_in: 1,
            h_out: 1,
            w_out: 1,
            macs: 4096 * 1000,
            w_off: 0,
            w_len: 1000,
            a_off: 0,
            a_len: 1,
        };
        let sim = FpgaSim::new(Arch::Temporal, Mode::Quant);
        let wb = vec![8u8; 1000];
        let ab = vec![8u8; 1];
        let eff = sim.effective_ops(&fc, &wb, &ab) as f64 / sim.lanes;
        let dma = sim.layer_bytes(&fc, &wb, &ab) as f64 * sim.freq / sim.bandwidth;
        assert!(dma > eff, "fc should be memory-bound: dma {dma} compute {eff}");
    }
}
