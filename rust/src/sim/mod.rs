//! Deployment simulators: cycle-level spatial (BitFusion-like) and temporal
//! (BISMO-like) FPGA accelerators for the §4.5 performance/energy studies.

pub mod fpga;

pub use fpga::{Arch, FpgaSim, SimReport};
