//! Reimplemented comparison baselines (paper §4.4 and §4.6, Table 4 and
//! Fig. 8), all running on the same evaluation stack as AutoQ so the
//! comparison isolates the search *policy*:
//!
//! * `FlatDdpg`   — traditional (non-hierarchical) DDPG emitting a QBN/BBN
//!   per channel directly (the Fig.-8 comparison): one controller per side,
//!   no goals, no relabeling.
//! * `Haq`        — HAQ [32]: layer-level DDPG assigning one weight QBN and
//!   one activation QBN per layer.
//! * `Releq`      — ReLeQ [5]: layer-level RL over *weights only*
//!   (activations pinned at 8 bits; the original uses an LSTM policy — we
//!   keep the paper's "weights-only, layer-level" semantics with the same
//!   DDPG machinery, isolating what the comparison measures).
//! * `Amc`        — AMC [9]: channel-level *pruning* — each output channel
//!   is kept (8-bit) or pruned (0), driven by the FLOP reward.

use crate::agent::ddpg::{DdpgAgent, DdpgHyper};
use crate::agent::noise::NoiseSchedule;
use crate::agent::replay::{ReplayBuffer, Transition};
use crate::cost::logic::model_cost;
use crate::cost::Mode;
use crate::data::synth::{Split, SynthDataset};
use crate::env::state::{StateBuilder, StateCtx, STATE_DIM};
use crate::models::ModelRunner;
use crate::runtime::Runtime;
use crate::search::episode::{EpisodeOutcome, LayerBits};
use crate::search::runner::{EpisodeStats, SearchResult};
use crate::search::Protocol;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselinePolicy {
    FlatDdpg,
    Haq,
    Releq,
    Amc,
}

impl BaselinePolicy {
    pub fn parse(s: &str) -> anyhow::Result<BaselinePolicy> {
        match s {
            "flat" | "ddpg" => Ok(BaselinePolicy::FlatDdpg),
            "haq" => Ok(BaselinePolicy::Haq),
            "releq" => Ok(BaselinePolicy::Releq),
            "amc" => Ok(BaselinePolicy::Amc),
            _ => anyhow::bail!("baseline must be flat|haq|releq|amc, got {s:?}"),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            BaselinePolicy::FlatDdpg => "flat-ddpg",
            BaselinePolicy::Haq => "haq",
            BaselinePolicy::Releq => "releq",
            BaselinePolicy::Amc => "amc",
        }
    }
    fn channel_level(&self) -> bool {
        matches!(self, BaselinePolicy::FlatDdpg | BaselinePolicy::Amc)
    }
}

#[derive(Debug, Clone)]
pub struct BaselineConfig {
    pub policy: BaselinePolicy,
    pub mode: Mode,
    pub protocol: Protocol,
    pub episodes: usize,
    pub warmup: usize,
    pub noise_decay: f64,
    pub eval_batches: usize,
    pub seed: u64,
}

impl BaselineConfig {
    pub fn quick(policy: BaselinePolicy, mode: Mode, protocol: Protocol) -> BaselineConfig {
        BaselineConfig {
            policy,
            mode,
            protocol,
            episodes: 40,
            warmup: 10,
            noise_decay: 0.95,
            eval_batches: 2,
            seed: 1,
        }
    }
}

/// AMC keep/prune threshold on the raw [0,32] action.
const AMC_THRESHOLD: f32 = 16.0;
const AMC_KEEP_BITS: u8 = 8;
const RELEQ_ACT_BITS: u8 = 8;

pub fn run_baseline(
    rt: &mut Runtime,
    runner: &ModelRunner,
    data: &SynthDataset,
    cfg: &BaselineConfig,
) -> anyhow::Result<SearchResult> {
    // Same contract as `run_search`: a zero-episode config must be a
    // structured error, not a post-loop `expect` panic.
    anyhow::ensure!(
        cfg.episodes >= 1,
        "baseline needs at least one episode, got episodes == 0"
    );
    let t0 = std::time::Instant::now();
    let meta = runner.meta.clone();
    let wvar = runner.weight_variances();
    let sb = StateBuilder::new(&meta, &wvar);
    let m16 = rt.manifest.agent(STATE_DIM)?.clone();
    let mut rng = Rng::new(cfg.seed ^ 0xBA5E);
    let mut agent_w = DdpgAgent::new(m16.clone(), DdpgHyper::default(), &mut rng);
    let mut agent_a = DdpgAgent::new(m16, DdpgHyper::default(), &mut rng);
    let mut replay_w = ReplayBuffer::new(2000);
    let mut replay_a = ReplayBuffer::new(2000);
    let mut noise = NoiseSchedule::new(0.5, cfg.warmup, cfg.noise_decay);

    let mut best: Option<EpisodeOutcome> = None;
    let mut history = Vec::with_capacity(cfg.episodes);

    for ep in 0..cfg.episodes {
        let mut wbits = vec![0u8; meta.w_channels];
        let mut abits = vec![0u8; meta.a_channels];
        let mut staged_w: Vec<(Vec<f32>, f32)> = Vec::new();
        let mut staged_a: Vec<(Vec<f32>, f32)> = Vec::new();
        let mut rdc = 0.0f64;
        let mut visited = 0.0f64;
        let mut gi = 0usize;
        let (mut prev_aw, mut prev_aa) = (32.0f32, 32.0f32);
        let sigma = noise.sigma_scaled(32.0);

        for (t, l) in meta.layers.iter().enumerate() {
            let rst = sb.total_macs - visited;
            let layer_wvar = &wvar[l.w_off..l.w_off + l.w_len];
            let macs_per_oc = l.macs as f64 / l.w_len as f64;
            let act = |agent: &DdpgAgent, rt: &mut Runtime, s: &[f32], rng: &mut Rng| -> anyhow::Result<f32> {
                let mu = agent.act_one(rt, s)?;
                Ok(((mu as f64 + rng.normal() * sigma).clamp(0.0, 32.0)) as f32)
            };

            if cfg.policy.channel_level() {
                // Per output channel.
                for c in 0..l.w_len {
                    let ctx = StateCtx {
                        i: gi, t, rdc, rst,
                        gw: prev_aw, ga: prev_aa,
                        prev_aw, prev_aa, wvar: layer_wvar[c],
                    };
                    let s = sb.state(&meta, t, &ctx).to_vec();
                    let raw = act(&agent_w, rt, &s, &mut rng)?;
                    let bits = match cfg.policy {
                        BaselinePolicy::Amc => {
                            if raw >= AMC_THRESHOLD { AMC_KEEP_BITS } else { 0 }
                        }
                        _ => raw.round().clamp(0.0, 32.0) as u8,
                    };
                    wbits[l.w_off + c] = bits;
                    rdc += macs_per_oc * (32.0 - bits as f64) / 32.0;
                    prev_aw = raw;
                    gi += 1;
                    staged_w.push((s, raw));
                }
                // Activations: flat-ddpg searches them; AMC keeps 8-bit.
                for c in 0..l.a_len {
                    let bits = match cfg.policy {
                        BaselinePolicy::Amc => AMC_KEEP_BITS,
                        _ => {
                            let ctx = StateCtx {
                                i: gi, t, rdc, rst,
                                gw: prev_aw, ga: prev_aa,
                                prev_aw, prev_aa, wvar: 0.0,
                            };
                            let s = sb.state(&meta, t, &ctx).to_vec();
                            let raw = act(&agent_a, rt, &s, &mut rng)?;
                            prev_aa = raw;
                            staged_a.push((s, raw));
                            raw.round().clamp(0.0, 32.0) as u8
                        }
                    };
                    abits[l.a_off + c] = bits;
                    gi += 1;
                }
            } else {
                // Layer-level (HAQ / ReLeQ).
                let ctx = StateCtx {
                    i: gi, t, rdc, rst,
                    gw: prev_aw, ga: prev_aa,
                    prev_aw, prev_aa,
                    wvar: layer_wvar.iter().sum::<f64>() / l.w_len as f64,
                };
                let s = sb.state(&meta, t, &ctx).to_vec();
                let raw_w = act(&agent_w, rt, &s, &mut rng)?;
                let bw = raw_w.round().clamp(0.0, 32.0) as u8;
                wbits[l.w_off..l.w_off + l.w_len].fill(bw);
                staged_w.push((s.clone(), raw_w));
                prev_aw = raw_w;
                let ba = match cfg.policy {
                    BaselinePolicy::Releq => RELEQ_ACT_BITS,
                    _ => {
                        let raw_a = act(&agent_a, rt, &s, &mut rng)?;
                        staged_a.push((s, raw_a));
                        prev_aa = raw_a;
                        raw_a.round().clamp(0.0, 32.0) as u8
                    }
                };
                abits[l.a_off..l.a_off + l.a_len].fill(ba);
                rdc += l.macs as f64 * (32.0 - bw as f64) / 32.0;
                gi += l.w_len + l.a_len;
            }
            visited += l.macs as f64;
        }

        // Evaluate and assign the final reward to all staged transitions.
        let eval =
            runner.eval_config(rt, cfg.mode, &wbits, &abits, data, Split::Val, cfg.eval_batches)?;
        let cost = model_cost(&meta.layers, &wbits, &abits);
        let reward = cfg.protocol.netscore.reward(eval.accuracy, &cost) as f32;
        for (staged, replay) in [(&staged_w, &mut replay_w), (&staged_a, &mut replay_a)] {
            for i in 0..staged.len() {
                let s2 = if i + 1 < staged.len() { staged[i + 1].0.clone() } else { staged[i].0.clone() };
                replay.push(Transition {
                    s: staged[i].0.clone(),
                    a: staged[i].1,
                    r: reward,
                    s2,
                    done: i + 1 == staged.len(),
                });
            }
        }
        let n_upd = (staged_w.len() / 4).max(1);
        for _ in 0..n_upd {
            agent_w.update(rt, &replay_w, &mut rng)?;
            if !staged_a.is_empty() {
                agent_a.update(rt, &replay_a, &mut rng)?;
            }
        }
        noise.advance_episode();

        let per_layer = meta
            .layers
            .iter()
            .map(|l| LayerBits {
                name: l.name.clone(),
                avg_w: wbits[l.w_off..l.w_off + l.w_len].iter().map(|&b| b as f64).sum::<f64>()
                    / l.w_len as f64,
                avg_a: abits[l.a_off..l.a_off + l.a_len].iter().map(|&b| b as f64).sum::<f64>()
                    / l.a_len as f64,
            })
            .collect();
        let out = EpisodeOutcome {
            avg_wbits: wbits.iter().map(|&b| b as f64).sum::<f64>() / wbits.len() as f64,
            avg_abits: abits.iter().map(|&b| b as f64).sum::<f64>() / abits.len() as f64,
            wbits,
            abits,
            accuracy: eval.accuracy,
            loss: eval.loss,
            cost,
            reward: reward as f64,
            score: cfg.protocol.netscore.score(eval.accuracy, &cost),
            per_layer,
        };
        history.push(EpisodeStats {
            episode: ep,
            accuracy: out.accuracy,
            reward: out.reward,
            avg_wbits: out.avg_wbits,
            avg_abits: out.avg_abits,
            norm_logic: out.cost.norm_logic(),
        });
        if best.as_ref().map_or(true, |b| out.reward > b.reward) {
            best = Some(out);
        }
        if ep % 10 == 0 {
            crate::info!(
                "[baseline {} {}] ep {ep}/{} acc={:.4} reward={:.4}",
                cfg.policy.name(),
                runner.meta.name,
                cfg.episodes,
                history[ep].accuracy,
                history[ep].reward
            );
        }
    }

    let best = best.ok_or_else(|| {
        anyhow::anyhow!("baseline finished without completing a single episode")
    })?;
    Ok(SearchResult { best, history, secs: t0.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parsing() {
        assert_eq!(BaselinePolicy::parse("haq").unwrap(), BaselinePolicy::Haq);
        assert_eq!(BaselinePolicy::parse("flat").unwrap(), BaselinePolicy::FlatDdpg);
        assert!(BaselinePolicy::parse("x").is_err());
        assert!(BaselinePolicy::FlatDdpg.channel_level());
        assert!(!BaselinePolicy::Haq.channel_level());
    }
}
