//! Small statistics helpers shared by reports, benches and tests.

/// Mean of a slice (0.0 for empty — callers treat empty as degenerate).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn mean_f32(xs: &[f32]) -> f64 {
    mean(&xs.iter().map(|&x| x as f64).collect::<Vec<_>>())
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn variance_f32(xs: &[f32]) -> f64 {
    variance(&xs.iter().map(|&x| x as f64).collect::<Vec<_>>())
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Percentile via linear interpolation on the sorted copy (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Exponential moving average over a series (used for learning curves).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let next = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        out.push(next);
        acc = Some(next);
    }
    out
}

/// Histogram with `bins` equal-width buckets over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if hi <= lo || bins == 0 {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let i = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[i] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [3.0, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn ema_smooths() {
        let xs = [0.0, 10.0, 10.0, 10.0];
        let e = ema(&xs, 0.5);
        assert_eq!(e[0], 0.0);
        assert_eq!(e[1], 5.0);
        assert!(e[3] > e[2]);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.2, 0.9, 1.9, 5.0, -1.0];
        let h = histogram(&xs, 0.0, 2.0, 2);
        // -1 clamps into bin 0; 5.0 clamps into bin 1.
        assert_eq!(h, vec![4, 2]);
    }
}
